//! Fixed logical-shard schedule — the pure half of accuracy-consistent
//! elasticity (EasyScale, DESIGN.md §11).
//!
//! Everything trajectory-relevant about the data pipeline is a function
//! of `(seed, epoch, shard)` only — never of the physical worker count P
//! or of assignment timing:
//!
//!  * **per-epoch shard permutation** — replayed Fisher–Yates draws from
//!    the assigner's seed. The live [`Assigner`](super::Assigner)
//!    consumes the same draws from its persisted generator (which now
//!    survives encode/decode), so the live queue and this pure
//!    derivation can never disagree;
//!  * **within-shard sample order** — sequential: a remainder handoff
//!    (`start + done`) resumes exactly where the departing holder
//!    stopped, so migration cannot reorder a shard's samples;
//!  * **per-shard RNG stream** — an independent PCG stream per
//!    `(seed, epoch, shard)` consuming exactly one draw per sample, so a
//!    migrated assignment's stream position equals its sample offset and
//!    is re-derivable by O(log n) jump-ahead ([`shard_stream_at`]).
//!
//! The permutation derivation deliberately REPLAYS the shuffles rather
//! than jumping the generator ahead: `gen_range` uses Lemire rejection
//! sampling, so the number of draws per epoch is data-dependent and the
//! assigner's generator position is not a closed-form function of the
//! epoch. Replay is exact by construction.

use super::PartitionTable;
use crate::util::rng::Pcg;

/// Stream-id salt separating per-shard data streams from every other PCG
/// stream family in the tree (cf. `Pcg::seeded`'s default stream).
const SHARD_STREAM_SALT: u64 = 0x51AD_0557_3EA3_11D7;

/// splitmix64 finaliser — decorrelates the `(epoch, shard)` lattice into
/// stream ids so neighbouring shards get unrelated streams.
fn mix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The RNG stream owned by logical shard `shard` in `epoch`, positioned
/// at the shard's first sample. One draw per sample is the contract:
/// anything that consumes more breaks [`shard_stream_at`]'s jump-ahead.
pub fn shard_stream(seed: u64, epoch: u64, shard: u64) -> Pcg {
    Pcg::new(mix(seed), mix(SHARD_STREAM_SALT ^ (epoch << 20) ^ shard))
}

/// [`shard_stream`] jumped to sample `offset` within the shard — the
/// stream state the leader hands out with a remainder assignment whose
/// first `offset` samples were consumed by earlier holders.
pub fn shard_stream_at(seed: u64, epoch: u64, shard: u64, offset: u64) -> Pcg {
    let mut rng = shard_stream(seed, epoch, shard);
    rng.advance(offset);
    rng
}

/// Assignment order of fresh shards for `epoch`: the Fisher–Yates
/// permutation the live assigner builds for that epoch, in the order
/// shards leave the pool (the live queue is popped from the back).
/// Replays the draws of epochs `0..=epoch` from `seed`.
pub fn epoch_permutation(seed: u64, epoch: u64, n_partitions: u64) -> Vec<u64> {
    let mut rng = Pcg::seeded(seed);
    let mut idx: Vec<u64> = Vec::new();
    for _ in 0..=epoch {
        idx = (0..n_partitions).collect();
        for i in (1..idx.len()).rev() {
            let j = rng.gen_range(i as u64 + 1) as usize;
            idx.swap(i, j);
        }
    }
    idx.reverse();
    idx
}

/// The canonical global sample order of `epoch`: shards in
/// [`epoch_permutation`] order, each shard's samples sequentially. Every
/// physical execution — any P, any scale-event schedule — consumes the
/// epoch's samples in exactly this logical order (property-tested in
/// `data::tests`).
pub fn global_order(seed: u64, epoch: u64, table: &PartitionTable) -> Vec<u64> {
    epoch_permutation(seed, epoch, table.n_partitions)
        .into_iter()
        .flat_map(|idx| {
            let m = table.partition(idx, epoch);
            m.start..m.start + m.len
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Assigner;

    #[test]
    fn pure_permutation_matches_live_assigner_across_epochs() {
        for seed in [0u64, 7, 0xDEAD_BEEF] {
            let table = PartitionTable::new(300, 11);
            let mut a = Assigner::new(table.clone(), seed);
            for epoch in 0..4u64 {
                let mut want = epoch_permutation(seed, epoch, table.n_partitions);
                want.reverse(); // live queue pops from the back
                assert_eq!(a.queue, want, "seed {seed} epoch {epoch}");
                // drain the epoch through one worker and advance
                while let Some(_m) = a.next_partition(1) {
                    a.complete(1);
                }
                a.advance_epoch();
            }
        }
    }

    #[test]
    fn shard_stream_at_equals_sequential_draws() {
        let mut base = shard_stream(9, 2, 5);
        for _ in 0..37 {
            base.next_u32();
        }
        let mut jumped = shard_stream_at(9, 2, 5, 37);
        for _ in 0..16 {
            assert_eq!(base.next_u32(), jumped.next_u32());
        }
    }

    #[test]
    fn shard_streams_are_distinct() {
        // neighbouring (epoch, shard) cells must not share streams
        let mut seen = std::collections::BTreeSet::new();
        for epoch in 0..4u64 {
            for shard in 0..8u64 {
                let mut r = shard_stream(1, epoch, shard);
                let sig = (r.next_u64(), r.next_u64());
                assert!(seen.insert(sig), "stream collision at epoch {epoch} shard {shard}");
            }
        }
    }

    #[test]
    fn global_order_is_an_epoch_permutation_of_samples() {
        let table = PartitionTable::new(103, 7);
        let order = global_order(3, 0, &table);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..103).collect::<Vec<u64>>());
        // and differs across epochs (shard order reshuffles)
        assert_ne!(order, global_order(3, 1, &table));
    }
}
