//! API-compatible stub for the `xla` PJRT bindings, compiled when the
//! `pjrt` feature is off (the offline/CI build). Every entry point that
//! would need the real XLA runtime returns [`XlaError`]; the rest of the
//! stack treats that exactly like any other device failure. The surface
//! mirrors the subset of `xla-rs` used by [`super::Runtime`] and the
//! PJRT worker backend — keep the two in sync.

#[derive(Debug, Clone)]
pub struct XlaError(pub &'static str);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

const DISABLED: XlaError =
    XlaError("edl was built without the `pjrt` feature; PJRT execution is unavailable");

pub type Result<T> = std::result::Result<T, XlaError>;

/// Stub PJRT client — construction always fails, so no other stub method
/// is reachable in practice (they still typecheck every call site).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(DISABLED)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(DISABLED)
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(DISABLED)
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(DISABLED)
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(DISABLED)
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(DISABLED)
    }
}

pub struct Literal;

impl Literal {
    pub fn scalar<T>(_v: T) -> Literal {
        Literal
    }

    pub fn vec1<T>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(DISABLED)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(DISABLED)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(DISABLED)
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(DISABLED)
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
