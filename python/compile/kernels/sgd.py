"""L1 Pallas kernel: fused SGD parameter update over the flat param vector.

p ← p − lr·g, tiled as a 1-D grid of VPU-width blocks. Deliberately
bandwidth-bound: two streaming reads + one streaming write per element and
no intermediate scaled-gradient tensor (the fusion the paper gets from
framework-level optimizer fusion).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096  # multiple of the 8×128 VPU tile


def _sgd_kernel(lr_ref, p_ref, g_ref, o_ref):
    o_ref[...] = p_ref[...] - lr_ref[0] * g_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def sgd_update(params, grads, lr, block=BLOCK):
    """params, grads: (P,) f32; lr: () or (1,) f32. Returns updated (P,)."""
    (n,) = params.shape
    assert grads.shape == (n,)
    lr = jnp.asarray(lr, jnp.float32).reshape(1)
    rem = (-n) % block
    p = jnp.pad(params.astype(jnp.float32), (0, rem))
    g = jnp.pad(grads.astype(jnp.float32), (0, rem))
    nb = p.shape[0] // block
    out = pl.pallas_call(
        _sgd_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(p.shape, jnp.float32),
        interpret=True,
    )(lr, p, g)
    return out[:n]
