//! The paper's Table-1 job-control API as ONE versioned, transport-
//! agnostic surface (`scale_out`, `scale_in`, `migrate`, `profile`,
//! `status`, plus `checkpoint`/`restore`/`stop`).
//!
//! A scheduler talks to a job exclusively through [`JobControl`]. Three
//! implementations share the trait, so the same policy code drives all of
//! them:
//!
//!  * [`coordinator::ElasticTrainer`](crate::coordinator::ElasticTrainer)
//!    — the live in-process engine;
//!  * [`JobClient`] ⇄ [`JobServer`] — the TCP deployment: requests travel
//!    as [`wire::Envelope`] frames (version byte + sequence number +
//!    encoded [`Request`]/[`Response`]) over the same framed codec the
//!    rest of the system uses;
//!  * [`cluster::SimJobHandle`](crate::cluster::SimJobHandle) — jobs
//!    inside the discrete-event cluster simulator, so simulated
//!    scheduling policies are written against the real control surface.
//!
//! Errors are typed ([`ElasticError`]); the §3.1 "an adjustment is in
//! flight → retry later" contract is [`ElasticError::AdjustmentInFlight`]
//! plus the [`JobControlExt`] retry-with-backoff helpers, written once
//! here instead of at every call site.

use crate::transport::NodeId;
use crate::wire::{self, Dec, Enc, Envelope, WireError};
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// errors
// ---------------------------------------------------------------------------

/// Typed failure modes of the Table-1 API.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ElasticError {
    /// a parallelism adjustment is already in flight (§3.1) — retry later
    AdjustmentInFlight,
    /// a worker id named in the request is not part of the job
    UnknownWorker(NodeId),
    /// the cluster/simulator cannot provide the requested resources
    InsufficientResources(String),
    /// the request is malformed or would leave the job in an invalid
    /// state (e.g. scale-in removing every worker)
    InvalidRequest(String),
    /// the operation started but could not complete (worker died mid-
    /// switch, leader gone, unexpected reply)
    Aborted(String),
    /// transport / filesystem failure
    Io(String),
}

impl std::fmt::Display for ElasticError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ElasticError::AdjustmentInFlight => {
                write!(f, "an adjustment is in flight; retry later")
            }
            ElasticError::UnknownWorker(id) => write!(f, "unknown worker {id}"),
            ElasticError::InsufficientResources(m) => write!(f, "insufficient resources: {m}"),
            ElasticError::InvalidRequest(m) => write!(f, "invalid request: {m}"),
            ElasticError::Aborted(m) => write!(f, "operation aborted: {m}"),
            ElasticError::Io(m) => write!(f, "io: {m}"),
        }
    }
}

impl std::error::Error for ElasticError {}

// ---------------------------------------------------------------------------
// data types
// ---------------------------------------------------------------------------

/// Reply to `status()` (Table 1 `status`): a point-in-time view of the job.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JobStatus {
    pub parallelism: u32,
    pub step: u64,
    pub epoch: u64,
    pub throughput_sps: f64,
    pub last_loss: f32,
    pub workers: Vec<NodeId>,
    /// machine label of each worker, aligned with `workers` — what a
    /// cluster master needs to return shrunk GPUs to the right machine
    pub worker_machines: Vec<String>,
    /// physical-machine identity digest of each worker, aligned with
    /// `workers` (0 = unknown / shm disabled): two workers with equal
    /// nonzero digests share an OS instance and run their data-plane
    /// link over shared memory — `ctl status --json` surfaces this so
    /// operators (and CI) can verify the negotiation actually happened
    pub worker_digests: Vec<u64>,
}

/// One level of a `profile()` sweep (Table 1 `profile`, §5.2).
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileRow {
    pub parallelism: u32,
    pub throughput: f64,
    pub per_gpu_throughput: f64,
    /// per-GPU throughput normalised by the best level in the sweep
    pub efficiency: f64,
}

/// The Table-1 efficiency definition, in one place: normalise each row's
/// per-GPU throughput by the best level in the sweep. Every `profile`
/// implementation (live engine, simulator) funnels through this.
pub fn normalise_efficiency(rows: &mut [ProfileRow]) {
    let best = rows.iter().map(|r| r.per_gpu_throughput).fold(f64::MIN, f64::max);
    if best > 0.0 {
        for r in rows.iter_mut() {
            r.efficiency = r.per_gpu_throughput / best;
        }
    }
}

// ---------------------------------------------------------------------------
// the trait
// ---------------------------------------------------------------------------

/// The scheduler-facing job-control surface (the paper's Table 1).
///
/// All methods are synchronous: they return once the job has durably
/// accepted (and for scaling ops, committed) the operation, or with a
/// typed [`ElasticError`]. Implementations must return
/// [`ElasticError::AdjustmentInFlight`] — never block indefinitely — when
/// a previous adjustment has not committed yet (§3.1).
pub trait JobControl {
    /// `scale_out` (Table 1): add one worker per entry of `machines`
    /// (opaque placement strings, "machine:gpu"). Stop-free: existing
    /// workers keep training while joiners prepare.
    fn scale_out(&mut self, machines: Vec<String>) -> Result<(), ElasticError>;

    /// `scale_in` (Table 1): gracefully remove the named workers.
    fn scale_in(&mut self, workers: Vec<NodeId>) -> Result<(), ElasticError>;

    /// `migrate` (§5.2): scale-in `remove` + scale-out `add` committed
    /// with ONE topology switch.
    fn migrate(&mut self, remove: Vec<NodeId>, add: Vec<String>) -> Result<(), ElasticError>;

    /// `profile` (Table 1): measure throughput from the current
    /// parallelism down to `min_p`, `steps_per_level` mini-batches per
    /// level (§5.2).
    fn profile(&mut self, min_p: u32, steps_per_level: u64)
        -> Result<Vec<ProfileRow>, ElasticError>;

    /// `status` (Table 1).
    fn status(&mut self) -> Result<JobStatus, ElasticError>;

    /// Write a consistent checkpoint to `path`.
    fn checkpoint(&mut self, path: &str) -> Result<(), ElasticError>;

    /// Restore model + data-pipeline state from `path`.
    fn restore(&mut self, path: &str) -> Result<(), ElasticError>;

    /// Stop the job.
    fn stop(&mut self) -> Result<(), ElasticError>;
}

/// The §3.1 retry contract, written once: callers that want blocking
/// semantics wrap any [`JobControl`] call in `with_retry`, which backs
/// off exponentially while the job reports
/// [`ElasticError::AdjustmentInFlight`].
pub trait JobControlExt: JobControl {
    fn with_retry<T, F>(&mut self, timeout: Duration, mut op: F) -> Result<T, ElasticError>
    where
        F: FnMut(&mut Self) -> Result<T, ElasticError>,
    {
        let deadline = Instant::now() + timeout;
        let mut backoff = Duration::from_millis(50);
        loop {
            match op(self) {
                Err(ElasticError::AdjustmentInFlight) if Instant::now() < deadline => {
                    std::thread::sleep(
                        backoff.min(deadline.saturating_duration_since(Instant::now())),
                    );
                    backoff = (backoff * 2).min(Duration::from_secs(2));
                }
                other => return other,
            }
        }
    }

    fn scale_out_retry(
        &mut self,
        machines: Vec<String>,
        timeout: Duration,
    ) -> Result<(), ElasticError> {
        self.with_retry(timeout, |j| j.scale_out(machines.clone()))
    }

    fn scale_in_retry(
        &mut self,
        workers: Vec<NodeId>,
        timeout: Duration,
    ) -> Result<(), ElasticError> {
        self.with_retry(timeout, |j| j.scale_in(workers.clone()))
    }

    fn migrate_retry(
        &mut self,
        remove: Vec<NodeId>,
        add: Vec<String>,
        timeout: Duration,
    ) -> Result<(), ElasticError> {
        self.with_retry(timeout, |j| j.migrate(remove.clone(), add.clone()))
    }
}

impl<J: JobControl + ?Sized> JobControlExt for J {}

// ---------------------------------------------------------------------------
// wire forms
// ---------------------------------------------------------------------------

/// One request per [`JobControl`] method; the body of a request
/// [`Envelope`]. The in-process trainer moves these through a typed
/// channel without serialisation; the TCP deployment encodes them.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    ScaleOut { machines: Vec<String> },
    ScaleIn { workers: Vec<NodeId> },
    Migrate { remove: Vec<NodeId>, add: Vec<String> },
    Profile { min_p: u32, steps_per_level: u64 },
    Status,
    Checkpoint { path: String },
    Restore { path: String },
    Stop,
}

/// The body of a response [`Envelope`].
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok,
    Status(JobStatus),
    Profile(Vec<ProfileRow>),
    Err(ElasticError),
}

impl Response {
    /// Unwrap an ack-style reply.
    pub fn unit(self) -> Result<(), ElasticError> {
        match self {
            Response::Ok => Ok(()),
            Response::Err(e) => Err(e),
            other => Err(ElasticError::Aborted(format!("unexpected reply {other:?}"))),
        }
    }

    pub fn status(self) -> Result<JobStatus, ElasticError> {
        match self {
            Response::Status(s) => Ok(s),
            Response::Err(e) => Err(e),
            other => Err(ElasticError::Aborted(format!("unexpected reply {other:?}"))),
        }
    }

    pub fn profile(self) -> Result<Vec<ProfileRow>, ElasticError> {
        match self {
            Response::Profile(rows) => Ok(rows),
            Response::Err(e) => Err(e),
            other => Err(ElasticError::Aborted(format!("unexpected reply {other:?}"))),
        }
    }
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            Request::ScaleOut { machines } => {
                e.u8(1).strs(machines);
            }
            Request::ScaleIn { workers } => {
                e.u8(2).u32s(workers);
            }
            Request::Migrate { remove, add } => {
                e.u8(3).u32s(remove).strs(add);
            }
            Request::Profile { min_p, steps_per_level } => {
                e.u8(4).u32(*min_p).u64(*steps_per_level);
            }
            Request::Status => {
                e.u8(5);
            }
            Request::Checkpoint { path } => {
                e.u8(6).str(path);
            }
            Request::Restore { path } => {
                e.u8(7).str(path);
            }
            Request::Stop => {
                e.u8(8);
            }
        }
        e.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> wire::Result<Request> {
        let mut d = Dec::new(buf);
        match d.u8()? {
            1 => Ok(Request::ScaleOut { machines: d.strs()? }),
            2 => Ok(Request::ScaleIn { workers: d.u32s()? }),
            3 => Ok(Request::Migrate { remove: d.u32s()?, add: d.strs()? }),
            4 => Ok(Request::Profile { min_p: d.u32()?, steps_per_level: d.u64()? }),
            5 => Ok(Request::Status),
            6 => Ok(Request::Checkpoint { path: d.str()? }),
            7 => Ok(Request::Restore { path: d.str()? }),
            8 => Ok(Request::Stop),
            tag => Err(WireError::BadTag { tag: tag as u32, ty: "api::Request" }),
        }
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            Response::Ok => {
                e.u8(1);
            }
            Response::Status(s) => {
                e.u8(2);
                s.encode(&mut e);
            }
            Response::Profile(rows) => {
                e.u8(3).u32(rows.len() as u32);
                for r in rows {
                    r.encode(&mut e);
                }
            }
            Response::Err(err) => {
                e.u8(4);
                err.encode(&mut e);
            }
        }
        e.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> wire::Result<Response> {
        let mut d = Dec::new(buf);
        match d.u8()? {
            1 => Ok(Response::Ok),
            2 => Ok(Response::Status(JobStatus::decode(&mut d)?)),
            3 => {
                let n = d.u32()? as usize;
                let rows = (0..n).map(|_| ProfileRow::decode(&mut d)).collect::<wire::Result<_>>()?;
                Ok(Response::Profile(rows))
            }
            4 => Ok(Response::Err(ElasticError::decode(&mut d)?)),
            tag => Err(WireError::BadTag { tag: tag as u32, ty: "api::Response" }),
        }
    }
}

impl JobStatus {
    pub fn encode(&self, e: &mut Enc) {
        e.u32(self.parallelism)
            .u64(self.step)
            .u64(self.epoch)
            .f64(self.throughput_sps)
            .f32(self.last_loss)
            .u32s(&self.workers)
            .strs(&self.worker_machines)
            .u64s(&self.worker_digests);
    }

    pub fn decode(d: &mut Dec) -> wire::Result<JobStatus> {
        Ok(JobStatus {
            parallelism: d.u32()?,
            step: d.u64()?,
            epoch: d.u64()?,
            throughput_sps: d.f64()?,
            last_loss: d.f32()?,
            workers: d.u32s()?,
            worker_machines: d.strs()?,
            worker_digests: d.u64s()?,
        })
    }
}

impl ProfileRow {
    pub fn encode(&self, e: &mut Enc) {
        e.u32(self.parallelism)
            .f64(self.throughput)
            .f64(self.per_gpu_throughput)
            .f64(self.efficiency);
    }

    pub fn decode(d: &mut Dec) -> wire::Result<ProfileRow> {
        Ok(ProfileRow {
            parallelism: d.u32()?,
            throughput: d.f64()?,
            per_gpu_throughput: d.f64()?,
            efficiency: d.f64()?,
        })
    }
}

impl ElasticError {
    pub fn encode(&self, e: &mut Enc) {
        match self {
            ElasticError::AdjustmentInFlight => {
                e.u8(1);
            }
            ElasticError::UnknownWorker(id) => {
                e.u8(2).u32(*id);
            }
            ElasticError::InsufficientResources(m) => {
                e.u8(3).str(m);
            }
            ElasticError::InvalidRequest(m) => {
                e.u8(4).str(m);
            }
            ElasticError::Aborted(m) => {
                e.u8(5).str(m);
            }
            ElasticError::Io(m) => {
                e.u8(6).str(m);
            }
        }
    }

    pub fn decode(d: &mut Dec) -> wire::Result<ElasticError> {
        match d.u8()? {
            1 => Ok(ElasticError::AdjustmentInFlight),
            2 => Ok(ElasticError::UnknownWorker(d.u32()?)),
            3 => Ok(ElasticError::InsufficientResources(d.str()?)),
            4 => Ok(ElasticError::InvalidRequest(d.str()?)),
            5 => Ok(ElasticError::Aborted(d.str()?)),
            6 => Ok(ElasticError::Io(d.str()?)),
            tag => Err(WireError::BadTag { tag: tag as u32, ty: "api::ElasticError" }),
        }
    }
}

// ---------------------------------------------------------------------------
// TCP deployment: JobServer / JobClient
// ---------------------------------------------------------------------------

/// Exposes any [`JobControl`] implementation (in practice the live
/// `ElasticTrainer`) to remote schedulers over TCP — the paper's
/// deployment, where the cluster scheduler and the job leader are
/// separate processes. Thread-per-connection; every connection shares the
/// one job behind a mutex, so concurrent scheduler requests serialise
/// exactly like the in-process command channel.
pub struct JobServer<J: JobControl + Send + 'static> {
    pub addr: String,
    job: Arc<Mutex<J>>,
    stop: Arc<AtomicBool>,
    /// one cloned handle per accepted connection, so `shutdown` can
    /// force-close clients that never hang up
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl<J: JobControl + Send + 'static> JobServer<J> {
    /// Bind on 127.0.0.1:0 (ephemeral port) and serve until `shutdown`.
    pub fn start(job: J) -> std::io::Result<JobServer<J>> {
        JobServer::start_on("127.0.0.1:0", job)
    }

    /// Bind on an explicit address (the deployment path: `edl serve
    /// --ctl host:port` gives schedulers a well-known endpoint).
    pub fn start_on(bind_addr: &str, job: J) -> std::io::Result<JobServer<J>> {
        let listener = TcpListener::bind(bind_addr)?;
        let addr = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let job = Arc::new(Mutex::new(job));
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let job = job.clone();
            let stop = stop.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name("edl-jobserver".into())
                .spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                if let Ok(clone) = stream.try_clone() {
                                    conns.lock().unwrap_or_else(|p| p.into_inner()).push(clone);
                                }
                                let job = job.clone();
                                std::thread::spawn(move || {
                                    let _ = serve_job_conn(stream, job);
                                });
                            }
                            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(2));
                            }
                            Err(_) => break,
                        }
                    }
                })
                .expect("spawn job server")
        };
        Ok(JobServer { addr, job, stop, conns, accept: Some(accept) })
    }

    /// Shared handle to the job (e.g. to drive it locally as well).
    pub fn job(&self) -> Arc<Mutex<J>> {
        self.job.clone()
    }

    /// Stop accepting, force-close remaining client connections, and hand
    /// the job back once the connection threads have drained.
    pub fn shutdown(mut self) -> J {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for s in self.conns.lock().unwrap_or_else(|p| p.into_inner()).drain(..) {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        let mut job = self.job;
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match Arc::try_unwrap(job) {
                Ok(m) => return m.into_inner().unwrap_or_else(|p| p.into_inner()),
                Err(back) => {
                    assert!(
                        Instant::now() < deadline,
                        "JobServer::shutdown: a connection thread is stuck \
                         (mid-request?) and still holds the job"
                    );
                    job = back;
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        }
    }
}

fn serve_job_conn<J: JobControl>(
    stream: TcpStream,
    job: Arc<Mutex<J>>,
) -> wire::Result<()> {
    wire::serve_framed(stream, move |raw| {
        let (seq, resp) = match Envelope::decode(raw) {
            Ok(env) => {
                let resp = match Request::decode(&env.body) {
                    Ok(req) => {
                        let mut guard = job.lock().unwrap_or_else(|p| p.into_inner());
                        dispatch(&mut *guard, req)
                    }
                    Err(e) => Response::Err(ElasticError::InvalidRequest(format!(
                        "undecodable request: {e}"
                    ))),
                };
                (env.seq, resp)
            }
            // version mismatch / garbage: reply (seq 0) instead of
            // dropping the connection so old clients get a typed error
            Err(e) => {
                (0, Response::Err(ElasticError::InvalidRequest(format!("bad envelope: {e}"))))
            }
        };
        Ok(Envelope::new(seq, resp.encode()).encode())
    })
}

/// Map one decoded request onto the [`JobControl`] surface.
pub fn dispatch<J: JobControl + ?Sized>(job: &mut J, req: Request) -> Response {
    fn ack(r: Result<(), ElasticError>) -> Response {
        match r {
            Ok(()) => Response::Ok,
            Err(e) => Response::Err(e),
        }
    }
    match req {
        Request::ScaleOut { machines } => ack(job.scale_out(machines)),
        Request::ScaleIn { workers } => ack(job.scale_in(workers)),
        Request::Migrate { remove, add } => ack(job.migrate(remove, add)),
        Request::Profile { min_p, steps_per_level } => {
            match job.profile(min_p, steps_per_level) {
                Ok(rows) => Response::Profile(rows),
                Err(e) => Response::Err(e),
            }
        }
        Request::Status => match job.status() {
            Ok(s) => Response::Status(s),
            Err(e) => Response::Err(e),
        },
        Request::Checkpoint { path } => ack(job.checkpoint(&path)),
        Request::Restore { path } => ack(job.restore(&path)),
        Request::Stop => ack(job.stop()),
    }
}

/// Blocking TCP client implementing [`JobControl`] against a remote
/// [`JobServer`] — a scheduler process controls a live job through this
/// exactly as it would an in-process one.
pub struct JobClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    seq: u64,
}

impl JobClient {
    pub fn connect(addr: &str) -> std::io::Result<JobClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?; // §4.4
        Ok(JobClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            seq: 0,
        })
    }

    /// One request/reply round-trip in a versioned envelope.
    pub fn call(&mut self, req: &Request) -> Result<Response, ElasticError> {
        let io = |e: WireError| ElasticError::Io(e.to_string());
        self.seq += 1;
        let env = Envelope::new(self.seq, req.encode());
        wire::write_frame(&mut self.writer, &env.encode()).map_err(io)?;
        let raw = wire::read_frame(&mut self.reader).map_err(io)?;
        let env = Envelope::decode(&raw).map_err(io)?;
        if env.seq != self.seq && env.seq != 0 {
            return Err(ElasticError::Io(format!(
                "reply out of sequence: got {}, want {}",
                env.seq, self.seq
            )));
        }
        Response::decode(&env.body).map_err(io)
    }
}

impl JobControl for JobClient {
    fn scale_out(&mut self, machines: Vec<String>) -> Result<(), ElasticError> {
        self.call(&Request::ScaleOut { machines })?.unit()
    }
    fn scale_in(&mut self, workers: Vec<NodeId>) -> Result<(), ElasticError> {
        self.call(&Request::ScaleIn { workers })?.unit()
    }
    fn migrate(&mut self, remove: Vec<NodeId>, add: Vec<String>) -> Result<(), ElasticError> {
        self.call(&Request::Migrate { remove, add })?.unit()
    }
    fn profile(
        &mut self,
        min_p: u32,
        steps_per_level: u64,
    ) -> Result<Vec<ProfileRow>, ElasticError> {
        self.call(&Request::Profile { min_p, steps_per_level })?.profile()
    }
    fn status(&mut self) -> Result<JobStatus, ElasticError> {
        self.call(&Request::Status)?.status()
    }
    fn checkpoint(&mut self, path: &str) -> Result<(), ElasticError> {
        self.call(&Request::Checkpoint { path: path.to_string() })?.unit()
    }
    fn restore(&mut self, path: &str) -> Result<(), ElasticError> {
        self.call(&Request::Restore { path: path.to_string() })?.unit()
    }
    fn stop(&mut self) -> Result<(), ElasticError> {
        self.call(&Request::Stop)?.unit()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::API_VERSION;

    fn all_requests() -> Vec<Request> {
        vec![
            Request::ScaleOut { machines: vec!["m0:g1".into(), "m1:g7".into()] },
            Request::ScaleIn { workers: vec![1, 2, 3] },
            Request::Migrate { remove: vec![5], add: vec!["m2:g0".into()] },
            Request::Profile { min_p: 1, steps_per_level: 10 },
            Request::Status,
            Request::Checkpoint { path: "/tmp/ckpt.bin".into() },
            Request::Restore { path: "/tmp/ckpt.bin".into() },
            Request::Stop,
        ]
    }

    fn all_responses() -> Vec<Response> {
        vec![
            Response::Ok,
            Response::Status(JobStatus {
                parallelism: 4,
                step: 100,
                epoch: 2,
                throughput_sps: 512.5,
                last_loss: 1.25,
                workers: vec![1, 2, 3, 4],
                worker_machines: vec!["m0".into(), "m0".into(), "m1".into(), "m1".into()],
                worker_digests: vec![0xA1, 0xA1, 0xB2, 0xB2],
            }),
            Response::Profile(vec![ProfileRow {
                parallelism: 2,
                throughput: 100.0,
                per_gpu_throughput: 50.0,
                efficiency: 0.9,
            }]),
            Response::Err(ElasticError::AdjustmentInFlight),
            Response::Err(ElasticError::UnknownWorker(9)),
            Response::Err(ElasticError::InsufficientResources("2 free".into())),
            Response::Err(ElasticError::InvalidRequest("empty".into())),
            Response::Err(ElasticError::Aborted("worker died".into())),
            Response::Err(ElasticError::Io("connection reset".into())),
        ]
    }

    #[test]
    fn every_request_variant_roundtrips_in_versioned_envelope() {
        for (i, req) in all_requests().into_iter().enumerate() {
            let env = Envelope::new(i as u64 + 1, req.encode());
            let bytes = env.encode();
            assert_eq!(bytes[0], API_VERSION, "{req:?} must lead with the version byte");
            let back = Envelope::decode(&bytes).unwrap();
            assert_eq!(back.seq, i as u64 + 1);
            assert_eq!(Request::decode(&back.body).unwrap(), req);
        }
    }

    #[test]
    fn every_response_variant_roundtrips_in_versioned_envelope() {
        for (i, resp) in all_responses().into_iter().enumerate() {
            let env = Envelope::new(i as u64 + 1, resp.encode());
            let bytes = env.encode();
            assert_eq!(bytes[0], API_VERSION);
            let back = Envelope::decode(&bytes).unwrap();
            assert_eq!(Response::decode(&back.body).unwrap(), resp);
        }
    }

    #[test]
    fn bad_tags_rejected() {
        assert!(matches!(Request::decode(&[0]), Err(WireError::BadTag { .. })));
        assert!(matches!(Response::decode(&[99]), Err(WireError::BadTag { .. })));
    }

    // -- loopback server/client over a mock job ------------------------------

    struct MockJob {
        p: u32,
        stopped: bool,
    }

    impl JobControl for MockJob {
        fn scale_out(&mut self, machines: Vec<String>) -> Result<(), ElasticError> {
            self.p += machines.len() as u32;
            Ok(())
        }
        fn scale_in(&mut self, workers: Vec<NodeId>) -> Result<(), ElasticError> {
            if let Some(&bad) = workers.iter().find(|&&w| w >= self.p) {
                return Err(ElasticError::UnknownWorker(bad));
            }
            self.p -= workers.len() as u32;
            Ok(())
        }
        fn migrate(&mut self, _r: Vec<NodeId>, _a: Vec<String>) -> Result<(), ElasticError> {
            Err(ElasticError::AdjustmentInFlight)
        }
        fn profile(&mut self, min_p: u32, _s: u64) -> Result<Vec<ProfileRow>, ElasticError> {
            Ok((min_p..=self.p)
                .rev()
                .map(|q| ProfileRow {
                    parallelism: q,
                    throughput: q as f64,
                    per_gpu_throughput: 1.0,
                    efficiency: 1.0,
                })
                .collect())
        }
        fn status(&mut self) -> Result<JobStatus, ElasticError> {
            Ok(JobStatus {
                parallelism: self.p,
                workers: (0..self.p).collect(),
                ..Default::default()
            })
        }
        fn checkpoint(&mut self, _p: &str) -> Result<(), ElasticError> {
            Ok(())
        }
        fn restore(&mut self, p: &str) -> Result<(), ElasticError> {
            Err(ElasticError::Io(format!("no such checkpoint: {p}")))
        }
        fn stop(&mut self) -> Result<(), ElasticError> {
            self.stopped = true;
            Ok(())
        }
    }

    #[test]
    fn job_server_client_roundtrip_over_tcp() {
        let server = JobServer::start(MockJob { p: 2, stopped: false }).unwrap();
        let mut c = JobClient::connect(&server.addr).unwrap();

        assert_eq!(c.status().unwrap().parallelism, 2);
        c.scale_out(vec!["m1".into(), "m1".into()]).unwrap();
        assert_eq!(c.status().unwrap().parallelism, 4);
        assert_eq!(c.scale_in(vec![9]), Err(ElasticError::UnknownWorker(9)));
        c.scale_in(vec![3]).unwrap();
        assert_eq!(
            c.migrate(vec![0], vec!["m2".into()]),
            Err(ElasticError::AdjustmentInFlight)
        );
        let rows = c.profile(1, 5).unwrap();
        assert_eq!(rows.first().unwrap().parallelism, 3);
        assert!(matches!(c.restore("/nope"), Err(ElasticError::Io(_))));
        c.stop().unwrap();

        drop(c);
        let job = server.shutdown();
        assert!(job.stopped);
        assert_eq!(job.p, 3);
    }

    #[test]
    fn job_server_rejects_wrong_version_with_typed_error() {
        let server = JobServer::start(MockJob { p: 1, stopped: false }).unwrap();
        let stream = TcpStream::connect(&server.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        // hand-craft an envelope with a future version byte
        let mut bytes = Envelope::new(1, Request::Status.encode()).encode();
        bytes[0] = API_VERSION + 1;
        wire::write_frame(&mut writer, &bytes).unwrap();
        let raw = wire::read_frame(&mut reader).unwrap();
        let env = Envelope::decode(&raw).unwrap();
        assert_eq!(env.seq, 0, "unattributable reply uses seq 0");
        match Response::decode(&env.body).unwrap() {
            Response::Err(ElasticError::InvalidRequest(m)) => {
                assert!(m.contains("version"), "{m}");
            }
            other => panic!("expected version error, got {other:?}"),
        }
        drop(reader);
        drop(writer);
        server.shutdown();
    }

    #[test]
    fn retry_helper_waits_out_adjustment_in_flight() {
        struct Flaky {
            until: u32,
            calls: u32,
        }
        impl JobControl for Flaky {
            fn scale_out(&mut self, _m: Vec<String>) -> Result<(), ElasticError> {
                self.calls += 1;
                if self.calls <= self.until {
                    Err(ElasticError::AdjustmentInFlight)
                } else {
                    Ok(())
                }
            }
            fn scale_in(&mut self, _w: Vec<NodeId>) -> Result<(), ElasticError> {
                Err(ElasticError::AdjustmentInFlight)
            }
            fn migrate(&mut self, _r: Vec<NodeId>, _a: Vec<String>) -> Result<(), ElasticError> {
                Ok(())
            }
            fn profile(&mut self, _p: u32, _s: u64) -> Result<Vec<ProfileRow>, ElasticError> {
                Ok(Vec::new())
            }
            fn status(&mut self) -> Result<JobStatus, ElasticError> {
                Ok(JobStatus::default())
            }
            fn checkpoint(&mut self, _p: &str) -> Result<(), ElasticError> {
                Ok(())
            }
            fn restore(&mut self, _p: &str) -> Result<(), ElasticError> {
                Ok(())
            }
            fn stop(&mut self) -> Result<(), ElasticError> {
                Ok(())
            }
        }

        let mut j = Flaky { until: 2, calls: 0 };
        j.scale_out_retry(vec!["m".into()], Duration::from_secs(5)).unwrap();
        assert_eq!(j.calls, 3, "two in-flight rejections then success");

        // a persistently busy job times out with the typed error
        let err = j.scale_in_retry(vec![1], Duration::from_millis(120)).unwrap_err();
        assert_eq!(err, ElasticError::AdjustmentInFlight);
    }
}
