//! The policy/engine split of cluster scheduling (§2, §5, §6).
//!
//! A scheduling **policy** (FIFO, Static, ElasticSimple, Tiresias,
//! Elastic-Tiresias — [`crate::schedulers`]) never touches an execution
//! engine directly. It reads an abstract cluster state through
//! [`ClusterView`] (machine/GPU inventory, per-job state, attained
//! service, adjustability) and emits typed [`Decision`]s through
//! [`ClusterCtl::submit`]. An **engine** implements both traits and is
//! responsible for applying each decision to real (or simulated) jobs:
//!
//!  * [`ClusterSim`](crate::cluster::ClusterSim) — the discrete-event
//!    simulator; decisions route through the Table-1
//!    [`SimJobHandle`](crate::cluster::SimJobHandle) and are recorded in
//!    `decision_log`, so a run can be replayed decision-by-decision;
//!  * [`master::Master`](crate::master) — the live multi-job cluster
//!    daemon; decisions route through [`api::JobControl`](crate::api)
//!    against each job's real leader (stop-free scale-out into idle GPUs,
//!    graceful shrink on contention).
//!
//! Decisions are applied EAGERLY: `submit` returns once the engine has
//! accepted (sim: applied; live: committed or dispatched) the decision,
//! and subsequent `ClusterView` reads observe its effect on the
//! inventory. That keeps policies sequential and engine-agnostic — the
//! same policy object ticks against either engine unchanged.

use crate::gpu_sim::Dnn;
use crate::transport::NodeId;

/// A typed scheduling decision — everything a policy may ask of an engine.
#[derive(Debug, Clone, PartialEq)]
pub enum Decision {
    /// Place a pending job and start it at parallelism `p`.
    Start { job: usize, p: u32 },
    /// Take a running job's GPUs away and requeue it (checkpoint/restart
    /// engines only — the live master refuses, it never restarts a job).
    Preempt { job: usize },
    /// Stop-free scale-out of a running job to `to` GPUs (Table-1
    /// `scale_out`; the engine chooses the machines).
    Grow { job: usize, to: u32 },
    /// Graceful scale-in of a running job to `to` GPUs (Table-1
    /// `scale_in`; victims are the most recently added workers).
    Shrink { job: usize, to: u32 },
    /// Placement move in one topology switch (Table-1 `migrate`).
    Migrate { job: usize, remove: Vec<NodeId>, add: Vec<String> },
}

impl Decision {
    /// The job index the decision targets.
    pub fn job(&self) -> usize {
        match *self {
            Decision::Start { job, .. }
            | Decision::Preempt { job }
            | Decision::Grow { job, .. }
            | Decision::Shrink { job, .. }
            | Decision::Migrate { job, .. } => job,
        }
    }
}

/// A point-in-time, policy-facing view of one job. Cheap to copy; engines
/// synthesise it on demand.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobView {
    /// external job id (trace id / submit order)
    pub id: u64,
    pub model: Dnn,
    pub requested_p: u32,
    /// GPUs currently held (0 unless running)
    pub current_p: u32,
    /// aggregate batch size, constant under scaling (§3.1)
    pub global_batch: u32,
    /// submit time has passed (the job is visible to the scheduler)
    pub submitted: bool,
    /// submitted and waiting for placement
    pub pending: bool,
    /// holding GPUs (running or mid-scale-out)
    pub running: bool,
    pub finished: bool,
    /// can accept a Table-1 adjustment NOW (running, no adjustment in
    /// flight — the §3.1 guard surfaced to policies)
    pub adjustable: bool,
    /// user marked the job elastic (§5.1)
    pub elastic: bool,
    pub submit_s: f64,
    /// GPU·s consumed so far (Tiresias priority input)
    pub attained_gpu_s: f64,
}

/// Read-only cluster state, per the paper's scheduler inputs (§5.1):
/// inventory, per-job state, attained service, adjustability, plus the
/// calibrated device model for what-if throughput/efficiency queries.
pub trait ClusterView {
    /// scheduler clock (s) — simulated time or wall time since engine start
    fn now_s(&self) -> f64;
    fn n_machines(&self) -> usize;
    fn gpus_per_machine(&self) -> u32;
    fn total_gpus(&self) -> u32;
    fn free_gpus(&self) -> u32;
    /// max parallelism used for efficiency normalisation
    fn max_p_norm(&self) -> u32;
    /// number of jobs the engine tracks (stable indices `0..n_jobs()`)
    fn n_jobs(&self) -> usize;
    fn job_view(&self, job: usize) -> JobView;
    /// predicted aggregate throughput of `job` at parallelism `p`
    /// (samples/s, from the calibrated device model)
    fn predicted_throughput(&self, job: usize, p: u32) -> f64;
    /// predicted GPU efficiency of `job` at parallelism `p` (footnote 1)
    fn predicted_efficiency(&self, job: usize, p: u32, max_p: u32) -> f64;
}

/// What a policy drives: the view plus decision submission.
pub trait ClusterCtl: ClusterView {
    /// Apply a decision. Returns false if the engine rejects it (no
    /// resources, job not in the right state, adjustment in flight).
    fn submit(&mut self, d: Decision) -> bool;
}

/// Scheduler plug-in surface: one policy object drives ANY engine.
/// Engines call `replan` after every event (sim) or on a clock (master).
pub trait Scheduler {
    fn name(&self) -> &'static str;
    fn replan(&mut self, ctl: &mut dyn ClusterCtl);
}

/// Placeholder policy that never issues a decision (used by engines that
/// need to temporarily take ownership of their scheduler).
pub struct NoopScheduler;

impl Scheduler for NoopScheduler {
    fn name(&self) -> &'static str {
        "noop"
    }
    fn replan(&mut self, _ctl: &mut dyn ClusterCtl) {}
}

/// An owned, point-in-time materialisation of a [`ClusterView`].
///
/// Datacenter-scale engines keep their inventory sharded behind many locks;
/// letting a policy call straight into the engine would re-take those locks
/// on every `free_gpus()` / `job_view()` probe. Instead the engine
/// assembles a snapshot once per tick (reading each shard briefly, never
/// all at once — no stop-the-world) and the policy plans against the
/// owned copy.
#[derive(Debug, Clone, PartialEq)]
pub struct ViewSnapshot {
    pub now_s: f64,
    pub n_machines: usize,
    pub gpus_per_machine: u32,
    pub total_gpus: u32,
    pub free_gpus: u32,
    pub max_p_norm: u32,
    pub jobs: Vec<JobView>,
}

impl ViewSnapshot {
    /// Materialise every scalar and job row of `view`.
    pub fn assemble<V: ClusterView + ?Sized>(view: &V) -> ViewSnapshot {
        ViewSnapshot {
            now_s: view.now_s(),
            n_machines: view.n_machines(),
            gpus_per_machine: view.gpus_per_machine(),
            total_gpus: view.total_gpus(),
            free_gpus: view.free_gpus(),
            max_p_norm: view.max_p_norm(),
            jobs: (0..view.n_jobs()).map(|j| view.job_view(j)).collect(),
        }
    }

    /// Re-read the rows an accepted decision may have changed: the fleet's
    /// free count and the target job's view. Everything else stays frozen —
    /// engine decisions touch exactly one job plus the inventory.
    pub fn refresh_job<V: ClusterView + ?Sized>(&mut self, view: &V, job: usize) {
        self.free_gpus = view.free_gpus();
        if job < self.jobs.len() {
            self.jobs[job] = view.job_view(job);
        }
    }
}

/// [`ClusterCtl`] adapter that serves reads from a [`ViewSnapshot`] and
/// forwards decisions to the wrapped engine, re-reading only what the
/// decision changed.
///
/// This preserves the module contract above — decisions are applied
/// eagerly and subsequent view reads observe their effect — because
/// `submit` refreshes the snapshot's free count and the target job's row
/// from the engine after every accepted decision. What a policy may
/// observe mid-tick therefore differs from the direct path in exactly one
/// way: rows of *other* jobs (and the clock) stay frozen at
/// tick-assembly time. Engine decisions only ever mutate their target job
/// plus the inventory, so for every policy in [`crate::schedulers`] the
/// two paths produce byte-identical decision logs (golden-tested).
///
/// `predicted_throughput` / `predicted_efficiency` still delegate to the
/// engine: they are pure functions of the calibrated device model (no
/// inventory locks), and policies probe them at arbitrary `p`, which no
/// finite snapshot could pre-answer.
pub struct SnapshotCtl<'a, C: ClusterCtl + ?Sized> {
    snap: ViewSnapshot,
    inner: &'a mut C,
}

impl<'a, C: ClusterCtl + ?Sized> SnapshotCtl<'a, C> {
    pub fn new(inner: &'a mut C) -> SnapshotCtl<'a, C> {
        let snap = ViewSnapshot::assemble(&*inner);
        SnapshotCtl { snap, inner }
    }

    /// The snapshot as last refreshed (for post-replan inspection).
    pub fn snapshot(&self) -> &ViewSnapshot {
        &self.snap
    }
}

impl<C: ClusterCtl + ?Sized> ClusterView for SnapshotCtl<'_, C> {
    fn now_s(&self) -> f64 {
        self.snap.now_s
    }
    fn n_machines(&self) -> usize {
        self.snap.n_machines
    }
    fn gpus_per_machine(&self) -> u32 {
        self.snap.gpus_per_machine
    }
    fn total_gpus(&self) -> u32 {
        self.snap.total_gpus
    }
    fn free_gpus(&self) -> u32 {
        self.snap.free_gpus
    }
    fn max_p_norm(&self) -> u32 {
        self.snap.max_p_norm
    }
    fn n_jobs(&self) -> usize {
        self.snap.jobs.len()
    }
    fn job_view(&self, job: usize) -> JobView {
        self.snap.jobs[job]
    }
    fn predicted_throughput(&self, job: usize, p: u32) -> f64 {
        self.inner.predicted_throughput(job, p)
    }
    fn predicted_efficiency(&self, job: usize, p: u32, max_p: u32) -> f64 {
        self.inner.predicted_efficiency(job, p, max_p)
    }
}

impl<C: ClusterCtl + ?Sized> ClusterCtl for SnapshotCtl<'_, C> {
    fn submit(&mut self, d: Decision) -> bool {
        let job = d.job();
        let ok = self.inner.submit(d);
        if ok {
            self.snap.refresh_job(&*self.inner, job);
        }
        ok
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal engine: one machine of 4 GPUs, two jobs, Start/Grow only.
    struct MockEngine {
        free: u32,
        p: [u32; 2],
        log: Vec<Decision>,
    }

    impl ClusterView for MockEngine {
        fn now_s(&self) -> f64 {
            0.0
        }
        fn n_machines(&self) -> usize {
            1
        }
        fn gpus_per_machine(&self) -> u32 {
            4
        }
        fn total_gpus(&self) -> u32 {
            4
        }
        fn free_gpus(&self) -> u32 {
            self.free
        }
        fn max_p_norm(&self) -> u32 {
            4
        }
        fn n_jobs(&self) -> usize {
            2
        }
        fn job_view(&self, job: usize) -> JobView {
            JobView {
                id: job as u64,
                model: Dnn::ResNet50,
                requested_p: 1,
                current_p: self.p[job],
                global_batch: 32,
                submitted: true,
                pending: self.p[job] == 0,
                running: self.p[job] > 0,
                finished: false,
                adjustable: self.p[job] > 0,
                elastic: true,
                submit_s: 0.0,
                attained_gpu_s: 0.0,
            }
        }
        fn predicted_throughput(&self, _job: usize, p: u32) -> f64 {
            p as f64
        }
        fn predicted_efficiency(&self, _job: usize, _p: u32, _max_p: u32) -> f64 {
            1.0
        }
    }

    impl ClusterCtl for MockEngine {
        fn submit(&mut self, d: Decision) -> bool {
            let ok = match d {
                Decision::Start { job, p } => {
                    if self.p[job] == 0 && p <= self.free {
                        self.free -= p;
                        self.p[job] = p;
                        true
                    } else {
                        false
                    }
                }
                Decision::Grow { job, to } => {
                    let cur = self.p[job];
                    if to > cur && to - cur <= self.free {
                        self.free -= to - cur;
                        self.p[job] = to;
                        true
                    } else {
                        false
                    }
                }
                _ => false,
            };
            if ok {
                self.log.push(d);
            }
            ok
        }
    }

    struct GreedyPolicy;
    impl Scheduler for GreedyPolicy {
        fn name(&self) -> &'static str {
            "greedy"
        }
        fn replan(&mut self, ctl: &mut dyn ClusterCtl) {
            // start every pending job at 1, then grow job 0 into the rest
            for i in 0..ctl.n_jobs() {
                if ctl.job_view(i).pending {
                    ctl.submit(Decision::Start { job: i, p: 1 });
                }
            }
            let free = ctl.free_gpus();
            if free > 0 {
                let cur = ctl.job_view(0).current_p;
                ctl.submit(Decision::Grow { job: 0, to: cur + free });
            }
        }
    }

    #[test]
    fn policy_drives_engine_through_trait_objects() {
        let mut eng = MockEngine { free: 4, p: [0, 0], log: Vec::new() };
        let mut pol = GreedyPolicy;
        pol.replan(&mut eng);
        assert_eq!(eng.p, [3, 1]);
        assert_eq!(eng.free, 0);
        assert_eq!(
            eng.log,
            vec![
                Decision::Start { job: 0, p: 1 },
                Decision::Start { job: 1, p: 1 },
                Decision::Grow { job: 0, to: 3 },
            ]
        );
    }

    #[test]
    fn rejected_decisions_report_false() {
        let mut eng = MockEngine { free: 0, p: [0, 0], log: Vec::new() };
        assert!(!eng.submit(Decision::Start { job: 0, p: 1 }));
        assert!(!eng.submit(Decision::Preempt { job: 0 }));
        assert!(eng.log.is_empty());
    }

    #[test]
    fn snapshot_materialises_every_row() {
        let eng = MockEngine { free: 3, p: [1, 0], log: Vec::new() };
        let snap = ViewSnapshot::assemble(&eng);
        assert_eq!(snap.now_s, eng.now_s());
        assert_eq!(snap.n_machines, 1);
        assert_eq!(snap.total_gpus, 4);
        assert_eq!(snap.free_gpus, 3);
        assert_eq!(snap.max_p_norm, 4);
        assert_eq!(snap.jobs.len(), 2);
        assert_eq!(snap.jobs[0], eng.job_view(0));
        assert_eq!(snap.jobs[1], eng.job_view(1));
    }

    #[test]
    fn snapshot_ctl_refreshes_eagerly_after_accepted_decisions() {
        let mut eng = MockEngine { free: 4, p: [0, 0], log: Vec::new() };
        let mut ctl = SnapshotCtl::new(&mut eng);
        assert!(ctl.submit(Decision::Start { job: 0, p: 2 }));
        // the module contract: reads observe the decision's effect
        assert_eq!(ctl.free_gpus(), 2);
        assert!(ctl.job_view(0).running);
        assert_eq!(ctl.job_view(0).current_p, 2);
        // untouched rows stay frozen (and correct: job 1 never changed)
        assert!(ctl.job_view(1).pending);
        // rejected decisions leave the snapshot untouched
        assert!(!ctl.submit(Decision::Grow { job: 1, to: 9 }));
        assert_eq!(ctl.free_gpus(), 2);
        assert!(!ctl.submit(Decision::Preempt { job: 0 }));
        assert_eq!(ctl.job_view(0).current_p, 2);
    }

    #[test]
    fn policy_through_snapshot_matches_direct_engine_byte_for_byte() {
        let mut direct = MockEngine { free: 4, p: [0, 0], log: Vec::new() };
        GreedyPolicy.replan(&mut direct);

        let mut snapped = MockEngine { free: 4, p: [0, 0], log: Vec::new() };
        {
            let mut ctl = SnapshotCtl::new(&mut snapped);
            GreedyPolicy.replan(&mut ctl);
        }
        assert_eq!(format!("{:?}", snapped.log), format!("{:?}", direct.log));
        assert_eq!(snapped.p, direct.p);
        assert_eq!(snapped.free, direct.free);
    }
}
