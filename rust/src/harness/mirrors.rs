//! Independent invariant mirrors shared by the chaos harness
//! ([`super::chaos`]) and the exhaustive protocol model checker
//! ([`crate::verify::model`]).
//!
//! A mirror re-derives a protocol guarantee from the *observable* message
//! flow only — never from `LeaderCore` internals — so a bug in the core
//! cannot hide itself by also corrupting the checker. The chaos harness
//! samples deep random schedules against these mirrors; the model checker
//! asserts the same mirrors on every reachable state of a small scope.

use std::collections::BTreeMap;
use std::hash::Hasher;

/// Independent §4.3 coverage mirror: per-epoch consumed marks. Each credit
/// marks `[start, start+len)` of an epoch exactly once; completing an epoch
/// with any sample unmarked (or marking one twice) is a violation of the
/// paper's exactly-once guarantee.
#[derive(Debug, Clone)]
pub struct Coverage {
    n: u64,
    epochs: BTreeMap<u64, Vec<bool>>,
}

impl Coverage {
    pub fn new(n: u64) -> Coverage {
        Coverage { n, epochs: BTreeMap::new() }
    }

    pub fn credit(&mut self, epoch: u64, start: u64, len: u64) -> Result<(), String> {
        let map = self.epochs.entry(epoch).or_insert_with(|| vec![false; self.n as usize]);
        for i in start..start + len {
            let slot = map
                .get_mut(i as usize)
                .ok_or_else(|| format!("credit out of range: epoch {epoch} sample {i}"))?;
            if *slot {
                return Err(format!("sample {i} credited twice in epoch {epoch}"));
            }
            *slot = true;
        }
        Ok(())
    }

    /// Epoch `done` finished (we saw epoch `done+1` begin): it must cover
    /// the dataset exactly once.
    pub fn check_complete(&self, done: u64) -> Result<(), String> {
        match self.epochs.get(&done) {
            Some(map) => {
                let missing = map.iter().filter(|&&b| !b).count();
                if missing > 0 {
                    return Err(format!("epoch {done} completed with {missing} samples omitted"));
                }
                Ok(())
            }
            None => Err(format!("epoch {done} completed but nothing was ever credited")),
        }
    }

    /// Rebuild after a restore: the restored epoch's map is everything
    /// outside the decoded assigner's outstanding ranges; later epochs are
    /// rolled back entirely.
    pub fn rebuild(&mut self, epoch: u64, outstanding: &[(u64, u64)]) {
        self.epochs.retain(|&e, _| e < epoch);
        let mut map = vec![true; self.n as usize];
        for &(s, l) in outstanding {
            for i in s..s + l {
                map[i as usize] = false;
            }
        }
        self.epochs.insert(epoch, map);
    }

    /// Fold the mirror state into a hasher (model-checker state dedup).
    pub fn hash_state<H: Hasher>(&self, h: &mut H) {
        h.write_u64(self.n);
        h.write_usize(self.epochs.len());
        for (e, map) in &self.epochs {
            h.write_u64(*e);
            for (i, b) in map.iter().enumerate() {
                if *b {
                    h.write_usize(i);
                }
            }
            h.write_u8(0xFE);
        }
    }
}

/// Trajectory-equality mirror (DESIGN.md §11): the committed loss curve,
/// step → loss **bits**. Two guarantees hang off it:
///
///  * **within-run redo consistency** — when a restore rolls the cluster
///    back and steps are re-executed (possibly by different physical
///    workers), the redone barrier must commit the *bit-identical* loss,
///    or the run was not deterministic ([`Trajectory::record`]);
///  * **cross-run equality** — the same seed must yield the same curve
///    at any worker count and under any scale-event storm
///    ([`Trajectory::diverges_from`]).
///
/// Only barriers that actually commit a loss (positive total weight) are
/// recorded, mirroring `LeaderCore::complete_barrier`.
#[derive(Debug, Clone, Default)]
pub struct Trajectory {
    points: BTreeMap<u64, u32>,
}

impl Trajectory {
    /// Record the loss committed at `step`. A second commit for the same
    /// step (a post-restore redo) must reproduce the exact bits.
    pub fn record(&mut self, step: u64, loss: f32) -> Result<(), String> {
        let bits = loss.to_bits();
        match self.points.insert(step, bits) {
            Some(prev) if prev != bits => Err(format!(
                "step {step} redone with different loss: {} vs {}",
                f32::from_bits(prev),
                loss
            )),
            _ => Ok(()),
        }
    }

    /// Roll back to `at_step` (checkpoint restore): steps after it are
    /// forgotten *except* that we keep them for redo-consistency checking
    /// via [`Trajectory::record`] — so nothing to erase. Kept as an
    /// explicit no-op hook so call sites document the restore.
    pub fn on_restore(&mut self, _at_step: u64) {}

    /// First step where the two curves disagree bit-wise, if any.
    /// Only steps present in BOTH curves are compared; use
    /// [`Trajectory::common_steps`] to assert the overlap is non-trivial.
    pub fn diverges_from(&self, other: &Trajectory) -> Option<(u64, f32, f32)> {
        for (step, bits) in &self.points {
            if let Some(ob) = other.points.get(step) {
                if ob != bits {
                    return Some((*step, f32::from_bits(*bits), f32::from_bits(*ob)));
                }
            }
        }
        None
    }

    /// Number of steps recorded by both curves.
    pub fn common_steps(&self, other: &Trajectory) -> usize {
        self.points.keys().filter(|s| other.points.contains_key(s)).count()
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Fold the mirror state into a hasher (model-checker state dedup).
    pub fn hash_state<H: Hasher>(&self, h: &mut H) {
        h.write_usize(self.points.len());
        for (s, b) in &self.points {
            h.write_u64(*s);
            h.write_u32(*b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coverage_catches_double_credit_and_omission() {
        let mut c = Coverage::new(10);
        c.credit(0, 0, 4).unwrap();
        c.credit(0, 4, 6).unwrap();
        assert!(c.check_complete(0).is_ok());
        assert!(c.credit(0, 3, 1).unwrap_err().contains("credited twice"));
        let mut c = Coverage::new(10);
        c.credit(1, 0, 9).unwrap();
        assert!(c.check_complete(1).unwrap_err().contains("omitted"));
        assert!(c.check_complete(2).is_err(), "never-credited epoch cannot be complete");
        assert!(c.credit(1, 9, 2).is_err(), "out-of-range credit rejected");
    }

    #[test]
    fn coverage_rebuild_rolls_back_later_epochs() {
        let mut c = Coverage::new(8);
        c.credit(0, 0, 8).unwrap();
        c.credit(1, 0, 5).unwrap();
        c.credit(2, 0, 2).unwrap();
        // restore to epoch 1 with samples 5..8 outstanding
        c.rebuild(1, &[(5, 3)]);
        assert!(c.check_complete(0).is_ok(), "earlier epochs survive the rollback");
        // the rebuilt epoch can consume exactly the outstanding tail again
        c.credit(1, 5, 3).unwrap();
        assert!(c.check_complete(1).is_ok());
        // epoch 2 was rolled back entirely: a fresh pass re-credits it
        c.credit(2, 0, 8).unwrap();
        assert!(c.check_complete(2).is_ok());
    }

    #[test]
    fn coverage_hash_distinguishes_states() {
        use std::collections::hash_map::DefaultHasher;
        let digest = |c: &Coverage| {
            let mut h = DefaultHasher::new();
            c.hash_state(&mut h);
            h.finish()
        };
        let mut a = Coverage::new(8);
        let d0 = digest(&a);
        a.credit(0, 0, 3).unwrap();
        let d1 = digest(&a);
        assert_ne!(d0, d1);
        let mut b = Coverage::new(8);
        b.credit(0, 0, 3).unwrap();
        assert_eq!(digest(&b), d1, "same marks, same digest");
    }

    #[test]
    fn trajectory_redo_must_be_bit_identical() {
        let mut t = Trajectory::default();
        t.record(1, 0.5).unwrap();
        t.record(2, 0.25).unwrap();
        t.on_restore(1);
        t.record(2, 0.25).unwrap(); // faithful redo: fine
        assert!(t.record(2, 0.250001).unwrap_err().contains("redone"));
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn trajectory_divergence_and_overlap() {
        let mut a = Trajectory::default();
        let mut b = Trajectory::default();
        for s in 0..5u64 {
            a.record(s, s as f32).unwrap();
            b.record(s, s as f32).unwrap();
        }
        b.record(7, 9.0).unwrap(); // extra step only in b: not a divergence
        assert_eq!(a.common_steps(&b), 5);
        assert!(a.diverges_from(&b).is_none());
        let mut c = b.clone();
        c.points.insert(3, 11.0f32.to_bits());
        let (step, x, y) = a.diverges_from(&c).unwrap();
        assert_eq!(step, 3);
        assert_eq!((x, y), (3.0, 11.0));
    }

    #[test]
    fn trajectory_hash_distinguishes_curves() {
        use std::collections::hash_map::DefaultHasher;
        let digest = |t: &Trajectory| {
            let mut h = DefaultHasher::new();
            t.hash_state(&mut h);
            h.finish()
        };
        let mut a = Trajectory::default();
        assert!(a.is_empty());
        let d0 = digest(&a);
        a.record(4, 1.5).unwrap();
        assert_ne!(d0, digest(&a));
    }
}
