"""L1 Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (including non-block-aligned ones) and value
scales; every case asserts elementwise closeness against ref.py — this is
the core correctness signal for the kernel layer.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as at
from compile.kernels import fused_linear as fl
from compile.kernels import ref
from compile.kernels import sgd as sg

SETTINGS = dict(max_examples=20, deadline=None)


def _rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


# ---------------------------------------------------------------------------
# fused matmul + bias + activation
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    act=st.sampled_from(["none", "relu", "gelu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_bias_act_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, m, k), _rand(rng, k, n), _rand(rng, n)
    got = fl.matmul_bias_act(x, w, b, act=act, bm=32, bn=32, bk=32)
    want = ref.matmul_bias_act(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(1, 1, 1), (128, 128, 128), (130, 64, 257), (5, 300, 3)])
def test_matmul_block_boundary_shapes(shape):
    m, k, n = shape
    rng = np.random.default_rng(0)
    x, w, b = _rand(rng, m, k), _rand(rng, k, n), _rand(rng, n)
    got = fl.matmul_bias_act(x, w, b, act="gelu")
    np.testing.assert_allclose(got, ref.matmul_bias_act(x, w, b, "gelu"), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 64), (128, 128, 128)])
def test_matmul_block_size_invariance(bm, bn, bk):
    """Result must not depend on the VMEM tiling chosen."""
    rng = np.random.default_rng(1)
    x, w, b = _rand(rng, 33, 47), _rand(rng, 47, 29), _rand(rng, 29)
    got = fl.matmul_bias_act(x, w, b, act="none", bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.matmul_bias_act(x, w, b, "none"), rtol=1e-4, atol=1e-4)


def test_matmul_large_values_stable():
    rng = np.random.default_rng(2)
    x = _rand(rng, 16, 16, scale=100.0)
    w = _rand(rng, 16, 16, scale=100.0)
    b = _rand(rng, 16, scale=100.0)
    got = fl.matmul_bias_act(x, w, b, act="relu")
    np.testing.assert_allclose(got, ref.matmul_bias_act(x, w, b, "relu"), rtol=1e-3)


def test_matmul_helper_equals_plain_matmul():
    rng = np.random.default_rng(3)
    x, w = _rand(rng, 12, 34), _rand(rng, 34, 9)
    np.testing.assert_allclose(fl.matmul(x, w), x @ w, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_act_grad_matches_numeric(seed):
    rng = np.random.default_rng(seed)
    z = _rand(rng, 64)
    eps = 1e-3
    for act in ("relu", "gelu", "none"):
        if act == "relu":
            z_safe = jnp.where(jnp.abs(z) < 0.05, 0.2, z)  # keep away from kink
        else:
            z_safe = z
        from compile.kernels.fused_linear import _apply_act

        num = (_apply_act(z_safe + eps, act) - _apply_act(z_safe - eps, act)) / (2 * eps)
        np.testing.assert_allclose(fl.act_grad(z_safe, act), num, rtol=2e-2, atol=2e-3)


# ---------------------------------------------------------------------------
# causal attention
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    bh=st.integers(1, 8),
    s=st.integers(1, 48),
    dh=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(bh, s, dh, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, bh, s, dh) for _ in range(3))
    got = at.causal_attention(q, k, v)
    np.testing.assert_allclose(got, ref.causal_attention(q, k, v), rtol=1e-4, atol=1e-4)


def test_attention_is_causal():
    """Changing a future key/value must not change earlier outputs."""
    rng = np.random.default_rng(4)
    q, k, v = (_rand(rng, 2, 8, 4) for _ in range(3))
    out1 = at.causal_attention(q, k, v)
    k2 = k.at[:, -1, :].set(99.0)
    v2 = v.at[:, -1, :].set(-99.0)
    out2 = at.causal_attention(q, k2, v2)
    np.testing.assert_allclose(out1[:, :-1, :], out2[:, :-1, :], rtol=1e-5, atol=1e-6)
    assert not np.allclose(out1[:, -1, :], out2[:, -1, :])


def test_attention_first_position_is_v0():
    """Position 0 attends only to itself."""
    rng = np.random.default_rng(5)
    q, k, v = (_rand(rng, 3, 6, 4) for _ in range(3))
    out = at.causal_attention(q, k, v)
    np.testing.assert_allclose(out[:, 0, :], v[:, 0, :], rtol=1e-5, atol=1e-6)


def test_attention_large_logits_stable():
    rng = np.random.default_rng(6)
    q = _rand(rng, 1, 8, 4, scale=50.0)
    k = _rand(rng, 1, 8, 4, scale=50.0)
    v = _rand(rng, 1, 8, 4)
    out = at.causal_attention(q, k, v)
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_allclose(out, ref.causal_attention(q, k, v), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused SGD update
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.integers(1, 10000),
    lr=st.floats(0.0, 10.0, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_matches_ref(n, lr, seed):
    rng = np.random.default_rng(seed)
    p, g = _rand(rng, n), _rand(rng, n)
    got = sg.sgd_update(p, g, lr, block=256)
    np.testing.assert_allclose(got, ref.sgd_update(p, g, lr), rtol=1e-5, atol=1e-6)


def test_sgd_zero_lr_identity():
    rng = np.random.default_rng(7)
    p, g = _rand(rng, 513), _rand(rng, 513)
    np.testing.assert_allclose(sg.sgd_update(p, g, 0.0), p, rtol=0, atol=0)


def test_sgd_block_invariance():
    rng = np.random.default_rng(8)
    p, g = _rand(rng, 1000), _rand(rng, 1000)
    a = sg.sgd_update(p, g, 0.3, block=128)
    b = sg.sgd_update(p, g, 0.3, block=4096)
    np.testing.assert_allclose(a, b, rtol=0, atol=0)
