//! Fig 8 — GPU resource loss (GPU·s not spent training) when scaling out
//! from 4 GPUs to 4+k, stop-resume vs EDL, for ResNet50 and VGG16.
//!
//! stop-resume idles ALL GPUs for the restart; EDL idles only the joiners
//! during context prep plus everyone for the sub-second broadcast — an
//! order of magnitude less.

use edl::gpu_sim::{edl_scale_out_e2e, edl_stop_time, stop_resume_overhead, Dnn};
use edl::metrics::{edl_scale_out_loss, stop_resume_loss};
use edl::util::json::{write_results, Json};

fn main() {
    let mut out = Json::obj();
    for model in [Dnn::ResNet50, Dnn::VGG16] {
        println!("\n== Fig 8: GPU resource loss of scaling out, {} (from p=4) ==", model.spec().name);
        println!("{:>8} {:>16} {:>12} {:>8}", "target p", "stop-resume", "EDL", "ratio");
        let mut rows = Json::Arr(vec![]);
        for add in [1u32, 2, 4] {
            let p_new = 4 + add;
            let sr = stop_resume_loss(4, p_new, stop_resume_overhead(model, p_new));
            let edl = edl_scale_out_loss(4, add, edl_scale_out_e2e(model), edl_stop_time(model));
            let ratio = sr.gpu_seconds / edl.gpu_seconds;
            println!(
                "{:>8} {:>13.0}GPUs {:>9.0}GPUs {:>7.1}x",
                p_new, sr.gpu_seconds, edl.gpu_seconds, ratio
            );
            assert!(ratio > 4.0, "EDL loss must be far below stop-resume");
            let mut r = Json::obj();
            r.set("p_new", p_new)
                .set("stop_resume_gpu_s", sr.gpu_seconds)
                .set("edl_gpu_s", edl.gpu_seconds)
                .set("ratio", ratio);
            rows.push(r);
        }
        out.set(model.spec().name, rows);
    }
    // the paper's remark: EDL's loss is dominated by the (inevitable) new-
    // GPU context prep, not by stopping existing workers
    for model in [Dnn::ResNet50, Dnn::VGG16] {
        let joiner = edl_scale_out_e2e(model); // 1 joiner
        let existing = 4.0 * edl_stop_time(model);
        assert!(joiner > existing, "joiner prep should dominate EDL loss");
    }
    let path = write_results("fig08_resource_loss", &out).unwrap();
    println!("\nshape checks OK; results -> {}", path.display());
}
