//! Tag-layout lint: prove the allreduce tag bitfields cannot alias.
//!
//! PR 2 shipped (and fixed) a tag-alias bug where the generation field
//! overlapped the sequence field, so segment N of step S could match
//! segment M of step S'. This lint re-proves the fix on every run, against
//! the *actual source* of `ring_tag`/`bcast_tag`: it extracts the constant
//! and function definitions with the verify lexer, evaluates them with a
//! tiny const-expression interpreter, and then measures which output bits
//! each input field can influence. The checks are semantic — rewriting the
//! layout in any equivalent form still passes; re-introducing an overlap
//! fails no matter how it is spelled.

use std::collections::BTreeMap;

use super::lexer::{ident_like, lex, strip_tests, Tok};
use super::{Diagnostic, SourceFile};

pub const LINT_TAGS: &str = "tag-layout";

/// A parsed `fn name(p1, p2, ..) -> T { .. }` body: parameter names plus
/// the tokens of its final expression (statements such as `debug_assert!`
/// are dropped — only the value expression matters to the interpreter).
#[derive(Debug, Clone)]
struct FnDef {
    params: Vec<String>,
    body: Vec<Tok>,
}

#[derive(Debug, Default, Clone)]
pub struct TagDefs {
    consts: BTreeMap<String, u64>,
    fns: BTreeMap<String, FnDef>,
}

/// Extract `const NAME: T = <expr>;` and `fn name(..) -> T { .. }` items.
pub fn extract_defs(src: &str) -> Result<TagDefs, String> {
    let toks = strip_tests(&lex(src));
    let mut defs = TagDefs::default();
    let mut i = 0usize;
    while i < toks.len() {
        match toks[i].text.as_str() {
            "const" if i + 1 < toks.len() && toks[i + 1].text != "fn" => {
                let name = toks[i + 1].text.clone();
                // skip to '='
                let mut j = i + 2;
                while j < toks.len() && toks[j].text != "=" {
                    j += 1;
                }
                let start = j + 1;
                let mut k = start;
                let mut d = 0i32;
                while k < toks.len() && !(d == 0 && toks[k].text == ";") {
                    match toks[k].text.as_str() {
                        "{" | "(" | "[" => d += 1,
                        "}" | ")" | "]" => d -= 1,
                        _ => {}
                    }
                    k += 1;
                }
                if start < k {
                    // non-integer consts (strings, arrays, paths) simply
                    // don't enter the environment; the tag functions only
                    // reference integer consts, which must evaluate
                    if let Ok(v) = Eval::new(&defs, &BTreeMap::new()).expr(&toks[start..k]) {
                        defs.consts.insert(name, v);
                    }
                }
                i = k + 1;
            }
            "fn" if i + 1 < toks.len() => {
                let name = toks[i + 1].text.clone();
                // parameter names: idents directly followed by ':' at paren
                // depth 1 of the signature
                let mut j = i + 2;
                while j < toks.len() && toks[j].text != "(" {
                    j += 1;
                }
                let mut depth = 1i32;
                let mut params = Vec::new();
                let mut k = j + 1;
                while k < toks.len() && depth > 0 {
                    match toks[k].text.as_str() {
                        "(" => depth += 1,
                        ")" => depth -= 1,
                        t => {
                            if depth == 1
                                && k + 1 < toks.len()
                                && toks[k + 1].text == ":"
                                && ident_like(t)
                            {
                                params.push(t.to_string());
                            }
                        }
                    }
                    k += 1;
                }
                // body: matching braces
                while k < toks.len() && toks[k].text != "{" {
                    k += 1;
                }
                let body_start = k + 1;
                let mut bdepth = 1i32;
                k += 1;
                while k < toks.len() && bdepth > 0 {
                    match toks[k].text.as_str() {
                        "{" => bdepth += 1,
                        "}" => bdepth -= 1,
                        _ => {}
                    }
                    k += 1;
                }
                let body_toks = &toks[body_start..k.saturating_sub(1)];
                // final expression = tokens after the last top-level ';'
                let mut last_semi = None;
                let mut d = 0i32;
                for (ix, t) in body_toks.iter().enumerate() {
                    match t.text.as_str() {
                        "{" | "(" | "[" => d += 1,
                        "}" | ")" | "]" => d -= 1,
                        ";" if d == 0 => last_semi = Some(ix),
                        _ => {}
                    }
                }
                let expr_start = last_semi.map(|s| s + 1).unwrap_or(0);
                defs.fns.insert(
                    name,
                    FnDef { params, body: body_toks[expr_start..].to_vec() },
                );
                i = k;
            }
            _ => i += 1,
        }
    }
    Ok(defs)
}

/// Recursive-descent const-expression interpreter over u64.
/// Precedence (low→high): `|`, `^`, `&`, `<< >>`, `+ -`, `* / %`, unary,
/// atoms. `expr as T` casts are applied with the target width (`u32`
/// truncates — a tag function that silently overflows u32 shows up as a
/// field influencing no output bits, which the disjointness checks catch).
struct Eval<'a> {
    defs: &'a TagDefs,
    env: &'a BTreeMap<String, u64>,
}

struct P<'a> {
    toks: &'a [Tok],
    i: usize,
}

impl<'a> Eval<'a> {
    fn new(defs: &'a TagDefs, env: &'a BTreeMap<String, u64>) -> Self {
        Eval { defs, env }
    }

    fn expr(&self, toks: &[Tok]) -> Result<u64, String> {
        let mut p = P { toks, i: 0 };
        let v = self.bitor(&mut p)?;
        if p.i < p.toks.len() {
            return Err(format!(
                "trailing tokens at {:?}",
                p.toks[p.i..].iter().map(|t| &t.text).collect::<Vec<_>>()
            ));
        }
        Ok(v)
    }

    fn bitor(&self, p: &mut P) -> Result<u64, String> {
        let mut v = self.bitxor(p)?;
        while p.i < p.toks.len() && p.toks[p.i].text == "|" {
            p.i += 1;
            v |= self.bitxor(p)?;
        }
        Ok(v)
    }

    fn bitxor(&self, p: &mut P) -> Result<u64, String> {
        let mut v = self.bitand(p)?;
        while p.i < p.toks.len() && p.toks[p.i].text == "^" {
            p.i += 1;
            v ^= self.bitand(p)?;
        }
        Ok(v)
    }

    fn bitand(&self, p: &mut P) -> Result<u64, String> {
        let mut v = self.shift(p)?;
        while p.i < p.toks.len() && p.toks[p.i].text == "&" {
            p.i += 1;
            v &= self.shift(p)?;
        }
        Ok(v)
    }

    fn shift(&self, p: &mut P) -> Result<u64, String> {
        let mut v = self.add(p)?;
        loop {
            if p.i + 1 < p.toks.len() && p.toks[p.i].text == "<" && p.toks[p.i + 1].text == "<" {
                p.i += 2;
                let s = self.add(p)?;
                v = if s >= 64 { 0 } else { v.wrapping_shl(s as u32) };
            } else if p.i + 1 < p.toks.len()
                && p.toks[p.i].text == ">"
                && p.toks[p.i + 1].text == ">"
            {
                p.i += 2;
                let s = self.add(p)?;
                v = if s >= 64 { 0 } else { v.wrapping_shr(s as u32) };
            } else {
                break;
            }
        }
        Ok(v)
    }

    fn add(&self, p: &mut P) -> Result<u64, String> {
        let mut v = self.mul(p)?;
        while p.i < p.toks.len() && (p.toks[p.i].text == "+" || p.toks[p.i].text == "-") {
            let op = p.toks[p.i].text.clone();
            p.i += 1;
            let rhs = self.mul(p)?;
            v = if op == "+" { v.wrapping_add(rhs) } else { v.wrapping_sub(rhs) };
        }
        Ok(v)
    }

    fn mul(&self, p: &mut P) -> Result<u64, String> {
        let mut v = self.unary(p)?;
        while p.i < p.toks.len()
            && (p.toks[p.i].text == "*" || p.toks[p.i].text == "/" || p.toks[p.i].text == "%")
        {
            let op = p.toks[p.i].text.clone();
            p.i += 1;
            let rhs = self.unary(p)?;
            v = match op.as_str() {
                "*" => v.wrapping_mul(rhs),
                "/" => v.checked_div(rhs).ok_or("division by zero")?,
                _ => v.checked_rem(rhs).ok_or("modulo by zero")?,
            };
        }
        Ok(v)
    }

    fn unary(&self, p: &mut P) -> Result<u64, String> {
        if p.i < p.toks.len() && p.toks[p.i].text == "!" {
            p.i += 1;
            return Ok(!self.unary(p)?);
        }
        if p.i < p.toks.len() && p.toks[p.i].text == "-" {
            p.i += 1;
            return Ok(self.unary(p)?.wrapping_neg());
        }
        self.postfix(p)
    }

    /// Atom plus trailing `as <type>` casts.
    fn postfix(&self, p: &mut P) -> Result<u64, String> {
        let mut v = self.atom(p)?;
        while p.i + 1 < p.toks.len() && p.toks[p.i].text == "as" {
            let ty = p.toks[p.i + 1].text.as_str();
            v = match ty {
                "u8" => v & 0xFF,
                "u16" => v & 0xFFFF,
                "u32" => v & 0xFFFF_FFFF,
                _ => v, // u64 / usize: identity at model width
            };
            p.i += 2;
        }
        Ok(v)
    }

    fn atom(&self, p: &mut P) -> Result<u64, String> {
        let Some(t) = p.toks.get(p.i) else {
            return Err("unexpected end of expression".into());
        };
        if t.text == "(" {
            p.i += 1;
            let v = self.bitor(p)?;
            if p.toks.get(p.i).map(|t| t.text.as_str()) != Some(")") {
                return Err("missing closing paren".into());
            }
            p.i += 1;
            return self.trailing_casts(p, v);
        }
        let first = t.text.chars().next().unwrap_or(' ');
        if first.is_ascii_digit() {
            p.i += 1;
            return parse_num(&t.text);
        }
        // identifier: parameter, const, or a call `name(args..)`
        let name = t.text.clone();
        p.i += 1;
        if p.toks.get(p.i).map(|t| t.text.as_str()) == Some("(") {
            // call: evaluate comma-separated args, then the callee body
            p.i += 1;
            let mut args = Vec::new();
            if p.toks.get(p.i).map(|t| t.text.as_str()) != Some(")") {
                loop {
                    args.push(self.bitor(p)?);
                    match p.toks.get(p.i).map(|t| t.text.as_str()) {
                        Some(",") => p.i += 1,
                        Some(")") => break,
                        other => return Err(format!("bad call syntax near {other:?}")),
                    }
                }
            }
            p.i += 1;
            let f = self
                .defs
                .fns
                .get(&name)
                .ok_or_else(|| format!("call to unknown fn {name}"))?;
            if f.params.len() != args.len() {
                return Err(format!("{name}: arity {} vs {}", f.params.len(), args.len()));
            }
            let env: BTreeMap<String, u64> =
                f.params.iter().cloned().zip(args).collect();
            return Eval::new(self.defs, &env).expr(&f.body);
        }
        if let Some(v) = self.env.get(&name).or_else(|| self.defs.consts.get(&name)) {
            return Ok(*v);
        }
        Err(format!("unknown identifier {name}"))
    }

    fn trailing_casts(&self, p: &mut P, mut v: u64) -> Result<u64, String> {
        while p.i + 1 < p.toks.len() && p.toks[p.i].text == "as" {
            v = match p.toks[p.i + 1].text.as_str() {
                "u8" => v & 0xFF,
                "u16" => v & 0xFFFF,
                "u32" => v & 0xFFFF_FFFF,
                _ => v,
            };
            p.i += 2;
        }
        Ok(v)
    }
}

fn parse_num(s: &str) -> Result<u64, String> {
    let clean: String = s.chars().filter(|c| *c != '_').collect();
    // strip integer type suffixes (u8/u16/u32/u64/usize/i32/..)
    let strip = |txt: &str| -> String {
        for suf in ["usize", "isize", "u64", "i64", "u32", "i32", "u16", "i16", "u8", "i8"] {
            if let Some(base) = txt.strip_suffix(suf) {
                if !base.is_empty() {
                    return base.to_string();
                }
            }
        }
        txt.to_string()
    };
    if let Some(hex) = clean.strip_prefix("0x").or_else(|| clean.strip_prefix("0X")) {
        return u64::from_str_radix(&strip(hex), 16).map_err(|e| format!("bad hex {s}: {e}"));
    }
    if let Some(bin) = clean.strip_prefix("0b") {
        return u64::from_str_radix(&strip(bin), 2).map_err(|e| format!("bad bin {s}: {e}"));
    }
    strip(&clean).parse::<u64>().map_err(|e| format!("bad number {s}: {e}"))
}

// -- the lint itself ------------------------------------------------------

const STEP_SAMPLES: &[u64] = &[
    0, 1, 2, 3, 5, 7, 100, 0x7FFE, 0x7FFF, 0x8000, 0xFFFE, 0xFFFF,
    1 << 24, (1 << 24) + 1, (1 << 24) + 2, 123_456_789, (1 << 40) + 3,
];
const SEQ_SAMPLES: &[u64] = &[0, 1, 2, 3, 7, 100, 0x1FFF, 0x3FFE, 0x3FFF];

/// Compute the ring/bcast tag layout checks against the allreduce source
/// (and the transport source, for the control-plane constants).
pub fn tag_layout(allreduce: &SourceFile, transport: &SourceFile) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut diag = |msg: String| {
        out.push(Diagnostic {
            lint: LINT_TAGS.into(),
            file: allreduce.path.clone(),
            line: 0,
            msg,
        });
    };

    let defs = match extract_defs(&allreduce.text) {
        Ok(d) => d,
        Err(e) => {
            diag(format!("failed to parse tag definitions: {e}"));
            return out;
        }
    };
    for f in ["ring_tag", "bcast_tag", "abort_tag", "hier_tag"] {
        if !defs.fns.contains_key(f) {
            diag(format!("tag function {f} not found in {}", allreduce.path));
            return out;
        }
    }
    let call = |f: &str, args: &[(&str, u64)]| -> Result<u64, String> {
        let fd = defs.fns.get(f).ok_or("missing fn")?;
        let env: BTreeMap<String, u64> = fd
            .params
            .iter()
            .enumerate()
            .map(|(ix, p)| (p.clone(), args.get(ix).map(|a| a.1).unwrap_or(0)))
            .collect();
        Eval::new(&defs, &env).expr(&fd.body)
    };
    let rt = |step: u64, phase: u64, seq: u64| -> Result<u64, String> {
        call("ring_tag", &[("step", step), ("phase", phase), ("seq", seq)])
    };
    let bt = |step: u64, seq: u64| -> Result<u64, String> {
        call("bcast_tag", &[("step", step), ("seq", seq)])
    };
    let at = |step: u64| -> Result<u64, String> { call("abort_tag", &[("step", step)]) };
    let ht = |step: u64, phase: u64, seq: u64| -> Result<u64, String> {
        call("hier_tag", &[("step", step), ("phase", phase), ("seq", seq)])
    };

    // sample every combination; abort the lint on evaluator errors
    let mut ring_vals = Vec::new();
    let mut bcast_vals = Vec::new();
    let mut hier_vals = Vec::new();
    for &s in STEP_SAMPLES {
        for &q in SEQ_SAMPLES {
            for p in [0u64, 1] {
                match rt(s, p, q) {
                    Ok(v) => ring_vals.push(v),
                    Err(e) => {
                        diag(format!("ring_tag({s},{p},{q}) failed to evaluate: {e}"));
                        return out;
                    }
                }
                match ht(s, p, q) {
                    Ok(v) => hier_vals.push(v),
                    Err(e) => {
                        diag(format!("hier_tag({s},{p},{q}) failed to evaluate: {e}"));
                        return out;
                    }
                }
            }
            match bt(s, q) {
                Ok(v) => bcast_vals.push(v),
                Err(e) => {
                    diag(format!("bcast_tag({s},{q}) failed to evaluate: {e}"));
                    return out;
                }
            }
        }
    }
    let mut abort_vals = Vec::new();
    for &s in STEP_SAMPLES {
        match at(s) {
            Ok(v) => abort_vals.push(v),
            Err(e) => {
                diag(format!("abort_tag({s}) failed to evaluate: {e}"));
                return out;
            }
        }
    }

    // influence masks: which output bits can each input toggle?
    let base = (STEP_SAMPLES[6], 0u64, SEQ_SAMPLES[5]); // arbitrary interior point
    let mut seq_mask = 0u64;
    let mut phase_mask = 0u64;
    let mut gen_mask = 0u64;
    let mut bseq_mask = 0u64;
    let mut bgen_mask = 0u64;
    let mut hseq_mask = 0u64;
    let mut hphase_mask = 0u64;
    let mut hgen_mask = 0u64;
    for &s in STEP_SAMPLES {
        for &q in SEQ_SAMPLES {
            for p in [0u64, 1] {
                seq_mask |= rt(s, p, q).unwrap_or(0) ^ rt(s, p, base.2).unwrap_or(0);
                phase_mask |= rt(s, 0, q).unwrap_or(0) ^ rt(s, 1, q).unwrap_or(0);
                gen_mask |= rt(s, p, q).unwrap_or(0) ^ rt(base.0, p, q).unwrap_or(0);
                hseq_mask |= ht(s, p, q).unwrap_or(0) ^ ht(s, p, base.2).unwrap_or(0);
                hphase_mask |= ht(s, 0, q).unwrap_or(0) ^ ht(s, 1, q).unwrap_or(0);
                hgen_mask |= ht(s, p, q).unwrap_or(0) ^ ht(base.0, p, q).unwrap_or(0);
            }
            bseq_mask |= bt(s, q).unwrap_or(0) ^ bt(s, base.2).unwrap_or(0);
            bgen_mask |= bt(s, q).unwrap_or(0) ^ bt(base.0, q).unwrap_or(0);
        }
    }

    // 1. field disjointness within ring_tag and hier_tag
    for (a, an, b, bn) in [
        (seq_mask, "seq", phase_mask, "phase"),
        (seq_mask, "seq", gen_mask, "generation"),
        (phase_mask, "phase", gen_mask, "generation"),
    ] {
        if a & b != 0 {
            diag(format!(
                "ring_tag fields overlap: {an} and {bn} share bits {:#010x} — tags from \
                 different {bn}s can alias",
                a & b
            ));
        }
    }
    for (a, an, b, bn) in [
        (hseq_mask, "seq", hphase_mask, "phase"),
        (hseq_mask, "seq", hgen_mask, "generation"),
        (hphase_mask, "phase", hgen_mask, "generation"),
    ] {
        if a & b != 0 {
            diag(format!(
                "hier_tag fields overlap: {an} and {bn} share bits {:#010x} — tags from \
                 different {bn}s can alias",
                a & b
            ));
        }
    }
    if bseq_mask & bgen_mask != 0 {
        diag(format!(
            "bcast_tag fields overlap: seq and generation share bits {:#010x}",
            bseq_mask & bgen_mask
        ));
    }

    // 2. family separation: the invariant bits of each family must be
    //    non-empty and disjoint, so no ring tag can ever equal a bcast tag
    let ring_family = ring_vals.iter().fold(u64::MAX, |a, v| a & v);
    let bcast_family = bcast_vals.iter().fold(u64::MAX, |a, v| a & v);
    if ring_family == 0 {
        diag("ring_tag has no invariant family bit — ring tags are not namespaced".into());
    }
    if bcast_family == 0 {
        diag("bcast_tag has no invariant family bit — bcast tags are not namespaced".into());
    }
    if ring_family & bcast_family != 0 {
        diag(format!(
            "ring/bcast families share invariant bits {:#010x} — the two collectives can \
             alias each other's segments",
            ring_family & bcast_family
        ));
    }
    // decisive cross-family check on the sampled values themselves
    let ring_set: std::collections::HashSet<u64> = ring_vals.iter().copied().collect();
    if let Some(v) = bcast_vals.iter().find(|v| ring_set.contains(v)) {
        diag(format!("tag value {v:#010x} is produced by BOTH ring_tag and bcast_tag"));
    }

    // 2b. abort family (fault-tolerant collectives): the out-of-band abort
    //     channel is identified by an invariant bit PATTERN that no ring or
    //     bcast tag may ever present. NOTE the abort family's invariant
    //     bits deliberately intersect both data families (it is the
    //     both-bits-set quadrant), so the property is "no other tag
    //     carries the full pattern", not bitwise disjointness.
    let abort_family = abort_vals.iter().fold(u64::MAX, |a, v| a & v);
    if abort_family == 0 {
        diag("abort_tag has no invariant family bit — abort frames are not namespaced".into());
    } else {
        if let Some(v) = ring_vals
            .iter()
            .chain(bcast_vals.iter())
            .chain(hier_vals.iter())
            .find(|v| **v & abort_family == abort_family)
        {
            diag(format!(
                "data-plane tag {v:#010x} presents the full abort-family pattern \
                 {abort_family:#010x} — a data segment could be mistaken for an abort"
            ));
        }
        let mut agen_mask = 0u64;
        for &s in STEP_SAMPLES {
            agen_mask |= at(s).unwrap_or(0) ^ at(base.0).unwrap_or(0);
        }
        if agen_mask & abort_family != 0 {
            diag(format!(
                "abort_tag generation bits overlap its family bits {:#010x} — some step's \
                 abort loses the family signature",
                agen_mask & abort_family
            ));
        }
    }
    let abort_set: std::collections::HashSet<u64> = abort_vals.iter().copied().collect();
    if let Some(v) = ring_vals
        .iter()
        .chain(bcast_vals.iter())
        .chain(hier_vals.iter())
        .find(|v| abort_set.contains(v))
    {
        diag(format!("tag value {v:#010x} is produced by BOTH abort_tag and a data-plane tag"));
    }

    // 2c. hierarchical family (topology-aware allreduce): like abort, its
    //     invariant bit PATTERN deliberately shares bit 31 with the bcast
    //     family, so the property is full-pattern exclusivity (no other
    //     tag presents every hier family bit) plus exact-value
    //     disjointness — not bitwise disjointness.
    let hier_family = hier_vals.iter().fold(u64::MAX, |a, v| a & v);
    if hier_family == 0 {
        diag("hier_tag has no invariant family bit — hier frames are not namespaced".into());
    } else if let Some(v) = ring_vals
        .iter()
        .chain(bcast_vals.iter())
        .chain(abort_vals.iter())
        .find(|v| **v & hier_family == hier_family)
    {
        diag(format!(
            "non-hierarchical tag {v:#010x} presents the full hier-family pattern \
             {hier_family:#010x} — it could be mistaken for an intra-node reduce/broadcast frame"
        ));
    }
    let hier_set: std::collections::HashSet<u64> = hier_vals.iter().copied().collect();
    if let Some(v) = ring_vals
        .iter()
        .chain(bcast_vals.iter())
        .chain(abort_vals.iter())
        .find(|v| hier_set.contains(v))
    {
        diag(format!("tag value {v:#010x} is produced by BOTH hier_tag and another family"));
    }

    // 3. generation sensitivity: adjacent steps and ring-version bumps
    //    (step + 2^24 in the sync-tag encoding) must change the tag
    for s in 0..64u64 {
        if rt(s, 0, 1) == rt(s + 1, 0, 1) {
            diag(format!("ring_tag is insensitive to step {s} -> {} — late traffic from \
                          the previous step aliases the current one", s + 1));
            break;
        }
    }
    if rt(3, 0, 1) == rt(3 + (1 << 24), 0, 1) {
        diag("ring_tag generation folds a ring-version bump (step + 2^24) onto the same \
              tag — post-rescale traffic aliases pre-rescale traffic"
            .into());
    }
    if rt(5, 0, 2) == rt(5, 1, 2) {
        diag(
            "ring_tag is insensitive to phase — reduce-scatter and allgather traffic alias".into(),
        );
    }
    for s in 0..64u64 {
        if at(s) == at(s + 1) {
            diag(format!(
                "abort_tag is insensitive to step {s} -> {} — a stale abort could cancel \
                 the NEXT step's healthy collective",
                s + 1
            ));
            break;
        }
    }
    for s in 0..64u64 {
        if ht(s, 0, 1) == ht(s + 1, 0, 1) {
            diag(format!(
                "hier_tag is insensitive to step {s} -> {} — late intra-node traffic from \
                 the previous step aliases the current one",
                s + 1
            ));
            break;
        }
    }
    if ht(5, 0, 2) == ht(5, 1, 2) {
        diag(
            "hier_tag is insensitive to phase — intra-node reduce and broadcast traffic alias"
                .into(),
        );
    }

    // 4. control-plane constants must live outside both data families
    match extract_defs(&transport.text) {
        Ok(tdefs) => {
            let rpc = tdefs.consts.get("RPC").copied();
            let kv = tdefs.consts.get("KV").copied();
            match (rpc, kv) {
                (Some(rpc), Some(kv)) => {
                    if rpc == kv {
                        diag("transport tag::RPC == tag::KV — control channels alias".into());
                    }
                    for (name, c) in [("RPC", rpc), ("KV", kv)] {
                        if ring_set.contains(&c)
                            || bcast_vals.contains(&c)
                            || abort_set.contains(&c)
                            || hier_set.contains(&c)
                        {
                            diag(format!(
                                "transport tag::{name} ({c:#x}) collides with a data-plane tag"
                            ));
                        }
                        if c & (ring_family | bcast_family | abort_family | hier_family) != 0 {
                            diag(format!(
                                "transport tag::{name} ({c:#x}) sets a data-plane family bit"
                            ));
                        }
                    }
                }
                _ => diag("transport tag consts RPC/KV not found".into()),
            }
        }
        Err(e) => diag(format!("failed to parse transport tag consts: {e}")),
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"
        const FAMILY_RING: u32 = 0x4000_0000;
        const FAMILY_BCAST: u32 = 0x8000_0000;
        const FAMILY_ABORT: u32 = 0xC000_0000;
        fn gen_field(step: u64) -> u32 {
            (step % 0x7FFF) as u32
        }
        pub fn ring_tag(step: u64, phase: u32, seq: u32) -> u32 {
            debug_assert!(phase < 2);
            FAMILY_RING | (phase << 29) | (gen_field(step) << 14) | (seq & 0x3FFF)
        }
        pub fn bcast_tag(step: u64, seq: u32) -> u32 {
            FAMILY_BCAST | (gen_field(step) << 14) | (seq & 0x3FFF)
        }
        pub fn abort_tag(step: u64) -> u32 {
            FAMILY_ABORT | (gen_field(step) << 14)
        }
        const FAMILY_HIER: u32 = 0xA000_0000;
        pub fn hier_tag(step: u64, phase: u32, seq: u32) -> u32 {
            FAMILY_HIER | (gen_field(step) << 14) | (phase << 13) | (seq & 0x1FFF)
        }
    "#;

    const TRANSPORT: &str = r#"
        pub mod tag {
            pub const RPC: u32 = 0x3000;
            pub const KV: u32 = 0x3001;
        }
    "#;

    fn sf(path: &str, text: &str) -> SourceFile {
        SourceFile { path: path.into(), text: text.into() }
    }

    #[test]
    fn const_expr_interpreter_basics() {
        let defs = TagDefs::default();
        let env = BTreeMap::new();
        let eval = |src: &str| Eval::new(&defs, &env).expr(&lex(src)).unwrap();
        assert_eq!(eval("0x4000_0000 | (1 << 29)"), 0x6000_0000);
        assert_eq!(eval("(7 % 0x7FFF) as u32"), 7);
        assert_eq!(eval("(0x1_0000_0003 as u32)"), 3);
        assert_eq!(eval("100 - 2 * 3"), 94);
        assert_eq!(eval("5 & 0x3FFF"), 5);
    }

    #[test]
    fn good_layout_is_clean() {
        let diags = tag_layout(
            &sf("rust/src/allreduce/mod.rs", GOOD),
            &sf("rust/src/transport/mod.rs", TRANSPORT),
        );
        assert!(diags.is_empty(), "{diags:#?}");
    }

    #[test]
    fn aliased_generation_field_is_caught() {
        // the PR-2 regression: generation shifted only 13, so its low bit
        // lands inside the 14-bit seq field
        let bad = GOOD.replace("gen_field(step) << 14", "gen_field(step) << 13");
        let diags = tag_layout(
            &sf("rust/src/allreduce/mod.rs", &bad),
            &sf("rust/src/transport/mod.rs", TRANSPORT),
        );
        assert!(
            diags.iter().any(|d| d.msg.contains("overlap")),
            "expected an overlap diagnostic, got {diags:#?}"
        );
    }

    #[test]
    fn abort_family_collision_is_caught() {
        // abort frames demoted into the ring family: every ring tag now
        // presents the full abort pattern, and abort_tag(s) literally
        // equals ring_tag(s, 0, 0) — a data segment would cancel a step
        let bad = GOOD.replace("0xC000_0000", "0x4000_0000");
        let diags = tag_layout(
            &sf("rust/src/allreduce/mod.rs", &bad),
            &sf("rust/src/transport/mod.rs", TRANSPORT),
        );
        assert!(
            diags.iter().any(|d| d.msg.contains("abort")),
            "expected an abort-family diagnostic, got {diags:#?}"
        );
    }

    #[test]
    fn hier_phase_folded_into_seq_is_caught() {
        // the hier phase bit demoted inside the seq field: member→leader
        // reduce frames would alias leader→member broadcast frames
        let bad = GOOD.replace("(phase << 13) | (seq & 0x1FFF)", "(phase << 12) | (seq & 0x1FFF)");
        let diags = tag_layout(
            &sf("rust/src/allreduce/mod.rs", &bad),
            &sf("rust/src/transport/mod.rs", TRANSPORT),
        );
        assert!(
            diags.iter().any(|d| d.msg.contains("hier_tag fields overlap")),
            "expected a hier overlap diagnostic, got {diags:#?}"
        );
    }

    #[test]
    fn hier_family_collapse_into_bcast_is_caught() {
        // hier demoted to the bare bcast bit: every bcast tag then presents
        // the full hier pattern
        let bad = GOOD.replace("0xA000_0000", "0x8000_0000");
        let diags = tag_layout(
            &sf("rust/src/allreduce/mod.rs", &bad),
            &sf("rust/src/transport/mod.rs", TRANSPORT),
        );
        assert!(
            diags.iter().any(|d| d.msg.contains("hier")),
            "expected a hier-family diagnostic, got {diags:#?}"
        );
    }

    #[test]
    fn missing_hier_tag_is_reported() {
        let bad = GOOD.replace("fn hier_tag", "fn hier_tag_renamed");
        let diags = tag_layout(
            &sf("rust/src/allreduce/mod.rs", &bad),
            &sf("rust/src/transport/mod.rs", TRANSPORT),
        );
        assert!(
            diags.iter().any(|d| d.msg.contains("hier_tag not found")),
            "expected a missing-fn diagnostic, got {diags:#?}"
        );
    }

    #[test]
    fn shared_family_bit_is_caught() {
        let bad = GOOD.replace("0x8000_0000", "0x4000_0000");
        let diags = tag_layout(
            &sf("rust/src/allreduce/mod.rs", &bad),
            &sf("rust/src/transport/mod.rs", TRANSPORT),
        );
        assert!(
            diags.iter().any(|d| d.msg.contains("famil")),
            "expected a family diagnostic, got {diags:#?}"
        );
    }
}
