//! `edl` CLI — leader entrypoint and experiment driver.
//!
//! Subcommands:
//!   train        run elastic data-parallel training on the AOT artifacts
//!   serve        run a training job AND a TCP JobServer so a remote
//!                scheduler can drive it through the Table-1 API; with
//!                --remote the workers are separate `edl worker` processes
//!   worker       one worker process of a --remote job (the true multi-
//!                process deployment: control over rpc frames, TcpNode
//!                data plane)
//!   ctl          Table-1 client: control a served job over TCP (by addr
//!                or by name via `--job <name> --kv <addr>`)
//!   master       multi-job cluster daemon: machine inventory, `edl
//!                submit` queue, one leader + worker processes per job,
//!                scheduler policies ticking live (also: `master jobs`,
//!                `master shutdown` client verbs)
//!   submit       submit a job to a running master
//!   profile      profile a job over a parallelism range (Table 1 API)
//!   sim          trace-driven cluster-scheduling simulation
//!   trace-stats  generate + summarise a synthetic Philly-like trace
//!   kv           run a standalone coordination (etcd-like) KV server

use edl::api::{JobClient, JobControl, JobServer, Request};
use edl::cluster::{ClusterSim, ScaleMode};
use edl::coordinator::{ElasticTrainer, TrainerConfig};
use edl::coordsvc::KvClient;
use edl::data::corpus::Corpus;
use edl::deploy::{LeaderEndpoint, WorkerParams};
use edl::master::proto::{MasterClient, SubmitSpec};
use edl::master::{MachineSpec, Master, MasterConfig};
use edl::metrics::JctStats;
use edl::runtime::artifacts_dir;
use edl::sched::Scheduler;
use edl::schedulers::{ElasticTiresias, FifoScheduler, Tiresias};
use edl::trace::{self, TraceConfig};
use edl::util::args::Args;
use edl::util::json::Json;
use edl::worker::{Backend, PjrtBackend, SimBackend};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.positional().first().map(String::as_str) {
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("worker") => cmd_worker(&args),
        Some("ctl") => cmd_ctl(&args),
        Some("master") => cmd_master(&args),
        Some("submit") => cmd_submit(&args),
        Some("profile") => cmd_profile(&args),
        Some("sim") => cmd_sim(&args),
        Some("trace-stats") => cmd_trace_stats(&args),
        Some("kv") => cmd_kv(),
        Some("verify") => cmd_verify(&args),
        _ => {
            eprintln!(
                "usage: edl <train|serve|worker|ctl|master|submit|profile|sim|trace-stats|kv|verify> [--flags]\n\
                 \n  train       --config tiny|small --workers N --steps N --agg-batch B --lr F\n\
                 \n  serve       (train flags; prints the job-control address, serves until the job stops)\n\
                 \n              --remote: workers are separate `edl worker` processes;\n\
                 \n              --listen h:p (worker endpoint) --ctl h:p (job-control endpoint)\n\
                 \n  worker      --leader <addr> --machine m1 [--backend sim] [--headless]\n\
                 \n  ctl <addr>|--job <name> --kv <addr> <status|scale-out|scale-in|migrate|profile|checkpoint|restore|stop>\n\
                 \n              --machines m1,m1 --workers 3,4|last --path ckpt.bin --min-p 1 [--json]\n\
                 \n  master      --machines N --gpus G --scheduler elastic-tiresias|tiresias|fifo\n\
                 \n              --listen h:p --kv-listen h:p --tick-ms 250 (daemon; sim-backend jobs)\n\
                 \n              --rack-size 32 (inventory shard width) --sim-slots (no worker procs)\n\
                 \n              --headless-workers (workers without a data plane) --serial\n\
                 \n              --executors 4 --pollers 4 (decision/status thread pools)\n\
                 \n  master jobs     --master <addr> [--json]   (list jobs on a running master)\n\
                 \n  master stats    --master <addr> [--json]   (tick latency, decision + shard stats)\n\
                 \n  master shutdown --master <addr>\n\
                 \n  submit      --master <addr> --name j1 --gpus N --steps N [--model ResNet50]\n\
                 \n              [--inelastic] [--params 512] [--compute-ms 5]\n\
                 \n  profile     --config tiny --max-p 4 --min-p 1 --steps-per-level K\n\
                 \n  sim         --scheduler tiresias|elastic-tiresias --jobs N --machines M\n\
                 \n  trace-stats --jobs N\n\
                 \n  kv          (serves an etcd-like KV on an ephemeral port)\n\
                 \n  verify      static-analysis pass + protocol model checker (DESIGN.md §7)\n\
                 \n              --root rust/src,rust/tests --allow rust/verify_allow.txt\n\
                 \n              --skip-model|--model-only --model-steps 4 --model-ops 2\n\
                 \n              --model-workers 3 --max-states 250000\n\
                 \n  common      --backend pjrt|sim (sim: artifact-free synthetic device)"
            );
            Ok(())
        }
    }
}

/// Model backend + matching corpus. `--backend sim` runs the deterministic
/// synthetic device (no AOT artifacts needed — what CI's multi-process
/// smoke job uses); the default is the real PJRT path.
fn build_parts(args: &Args) -> anyhow::Result<(Arc<dyn Backend>, Arc<Corpus>)> {
    let samples = args.u64("samples", 4096);
    let data_seed = args.u64("data-seed", 1);
    match args.str("backend", "pjrt").as_str() {
        "sim" => {
            let backend = SimBackend {
                compute_ms: args.u64("compute-ms", 5),
                ..SimBackend::fast(args.usize("params", 512))
            };
            let corpus = Arc::new(Corpus::markov(256, backend.seq, samples, data_seed));
            Ok((Arc::new(backend), corpus))
        }
        _ => {
            let config = args.str("config", "tiny");
            let agg_batch = args.usize("agg-batch", 32) as u32;
            let backend = Arc::new(PjrtBackend::new(artifacts_dir(), &config, agg_batch, 16)?);
            let meta = backend.meta.clone();
            let corpus =
                Arc::new(Corpus::markov(meta.vocab, meta.seq_len, samples, data_seed));
            Ok((backend, corpus))
        }
    }
}

/// The leader/worker agreement digest for the multi-process deployment:
/// both sides derive it from the same flags, so a mismatched worker is
/// refused at the handshake instead of training on different data.
fn deploy_digest(args: &Args, backend: &Arc<dyn Backend>) -> u64 {
    edl::deploy::config_digest(
        args.u64("samples", 4096),
        args.u64("data-seed", 1),
        backend.param_count(),
        backend.seq_len(),
        args.f64("lr", 0.05) as f32,
    )
}

fn build_cfg(args: &Args) -> TrainerConfig {
    TrainerConfig {
        agg_batch: args.usize("agg-batch", 32) as u32,
        lr: args.f64("lr", 0.05) as f32,
        n_partitions: args.u64("partitions", 64),
        seed: args.u64("seed", 7),
        switch_allowance_ms: args.f64("switch-allowance-ms", 500.0),
        failure_timeout: std::time::Duration::from_millis(args.u64(
            "failure-timeout-ms",
            TrainerConfig::default().failure_timeout.as_millis() as u64,
        )),
        straggler_mitigation: args.bool("straggler-mitigation", false),
        // the paper's USE_APPX_RECOVERY switch, resolved ONCE here at
        // config construction — the trainer never reads the environment
        approx_recovery: args.bool("approx-recovery", TrainerConfig::approx_recovery_from_env()),
        ..Default::default()
    }
}

fn build_trainer(args: &Args, workers: usize) -> anyhow::Result<(ElasticTrainer, Arc<Corpus>)> {
    let (backend, corpus) = build_parts(args)?;
    let cfg = build_cfg(args);
    Ok((ElasticTrainer::start(cfg, backend, corpus.clone(), workers), corpus))
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let workers = args.usize("workers", 2);
    let steps = args.u64("steps", 50);
    let (trainer, _corpus) = build_trainer(args, workers)?;
    println!("training with {workers} workers for {steps} steps...");
    trainer.wait_step(steps, std::time::Duration::from_secs(3600));
    let st = trainer.status();
    println!(
        "step={} epoch={} p={} throughput={:.1} samples/s loss={:.4}",
        st.step, st.epoch, st.parallelism, st.throughput_sps, st.last_loss
    );
    let report = trainer.stop();
    for ev in &report.events {
        println!("[event] step={} {}", ev.step, ev.what);
    }
    let pts = &report.loss_history;
    for chunk in pts.chunks((pts.len() / 20).max(1)) {
        let first = &chunk[0];
        println!("step {:>5}  loss {:.4}  p={}", first.step, first.loss, first.parallelism);
    }
    Ok(())
}

/// Paper deployment: the job trains while a TCP `JobServer` exposes the
/// Table-1 API to remote schedulers (`edl ctl <addr> ...`). With
/// `--remote`, workers are separate `edl worker` OS processes speaking
/// `rpc` frames to a leader endpoint in THIS process — the true
/// multi-process topology.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    if args.bool("remote", false) {
        return cmd_serve_remote(args);
    }
    let workers = args.usize("workers", 2);
    let (trainer, _corpus) = build_trainer(args, workers)?;
    let server = JobServer::start(trainer)?;
    println!("job-control API serving on {}", server.addr);
    println!("drive it with: edl ctl {} status", server.addr);
    // serve until a scheduler issues `stop`
    loop {
        std::thread::sleep(std::time::Duration::from_millis(500));
        let job = server.job();
        let done = {
            let mut j = job.lock().unwrap_or_else(|p| p.into_inner());
            JobControl::status(&mut *j).is_err()
        };
        if done {
            break;
        }
    }
    Ok(())
}

/// Leader process of the multi-process deployment: a worker endpoint for
/// `edl worker` processes plus a `JobServer` for `edl ctl`. Serves until
/// a scheduler issues `stop`.
fn cmd_serve_remote(args: &Args) -> anyhow::Result<()> {
    let workers = args.usize("workers", 2);
    let (backend, corpus) = build_parts(args)?;
    let digest = deploy_digest(args, &backend);
    let cfg = build_cfg(args);
    let endpoint = LeaderEndpoint::start(
        cfg,
        backend,
        corpus.n_samples,
        workers,
        &args.str("listen", "127.0.0.1:0"),
        digest,
    )?;
    println!("worker-endpoint {}", endpoint.addr);
    let server = JobServer::start_on(&args.str("ctl", "127.0.0.1:0"), endpoint.handle())?;
    println!("job-control {}", server.addr);
    println!("waiting for {workers} `edl worker --leader {}` processes...", endpoint.addr);
    let report = endpoint.join();
    for ev in &report.events {
        println!("[event] step={} {}", ev.step, ev.what);
    }
    println!("steps={} epochs={}", report.steps, report.epochs);
    let _ = server.shutdown();
    Ok(())
}

/// One worker process of a `serve --remote` job. Connects, prepares its
/// execution context (stop-free if joining a running job), and trains
/// until `stop` or graceful exit.
fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    let leader = args
        .opt_str("leader")
        .ok_or_else(|| anyhow::anyhow!("worker: missing --leader <addr>"))?;
    let (backend, corpus) = build_parts(args)?;
    let digest = deploy_digest(args, &backend);
    edl::deploy::run_worker(WorkerParams {
        leader_addr: leader,
        machine: args.str("machine", "m0"),
        backend,
        corpus,
        lr: args.f64("lr", 0.05) as f32,
        config_digest: digest,
        headless: args.bool("headless", false),
    })
}

/// Table-1 client over TCP: the scheduler side of the paper's deployment.
/// The target is an explicit `<addr>` positional, or `--job <name>`
/// resolved through the coordination KV (`--kv <addr>`) where a master
/// registers every live job's ctl address under a TTL lease.
fn cmd_ctl(args: &Args) -> anyhow::Result<()> {
    let pos = args.positional();
    let (addr, verb) = match args.opt_str("job") {
        Some(job) => {
            let kv_addr = args.str("kv", "127.0.0.1:7501");
            let mut kv = KvClient::connect(&kv_addr)?;
            let key = format!("edl/jobs/{job}/ctl");
            let entry = kv
                .get(&key)
                .map_err(|e| anyhow::anyhow!("kv lookup of {key} failed: {e}"))?;
            let Some((raw, _version)) = entry else {
                anyhow::bail!("no live job named {job:?} registered in the KV at {kv_addr}");
            };
            let addr = String::from_utf8_lossy(&raw).to_string();
            (addr, pos.get(1).cloned().unwrap_or_else(|| "status".into()))
        }
        None => {
            let addr = pos
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("ctl: missing <addr> (or --job/--kv)"))?
                .clone();
            (addr, pos.get(2).cloned().unwrap_or_else(|| "status".into()))
        }
    };
    let verb = verb.as_str();
    let mut client = JobClient::connect(&addr)?;
    let machines = || -> Vec<String> {
        args.str("machines", "m1").split(',').filter(|s| !s.is_empty()).map(Into::into).collect()
    };
    let workers = || -> Vec<u32> {
        args.str("workers", "")
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.parse().expect("--workers expects comma-separated ids"))
            .collect()
    };
    match verb {
        "status" => {
            let st = client.status().map_err(anyhow::Error::msg)?;
            if args.bool("json", false) {
                let mut o = Json::obj();
                o.set("step", st.step)
                    .set("epoch", st.epoch)
                    .set("parallelism", st.parallelism)
                    .set("throughput_sps", st.throughput_sps)
                    .set(
                        "loss",
                        if st.last_loss.is_nan() {
                            Json::Null
                        } else {
                            Json::Num(st.last_loss as f64)
                        },
                    )
                    .set("workers", st.workers.clone())
                    .set("worker_machines", st.worker_machines.clone())
                    // hex strings: digests are full 64-bit values and JSON
                    // numbers here are f64 (53-bit mantissa)
                    .set(
                        "worker_digests",
                        st.worker_digests
                            .iter()
                            .map(|d| format!("{d:016x}"))
                            .collect::<Vec<_>>(),
                    );
                println!("{}", o.to_string_pretty());
            } else {
                println!(
                    "step={} epoch={} p={} throughput={:.1} samples/s loss={:.4} workers={:?} machines={:?}",
                    st.step,
                    st.epoch,
                    st.parallelism,
                    st.throughput_sps,
                    st.last_loss,
                    st.workers,
                    st.worker_machines
                );
            }
        }
        "scale-out" => {
            client.scale_out(machines()).map_err(anyhow::Error::msg)?;
            println!("scaled out");
        }
        "scale-in" => {
            // `--workers last` picks the newest worker from `status` (CI
            // scripts need not parse worker ids)
            let ids = if args.str("workers", "") == "last" {
                let st = client.status().map_err(anyhow::Error::msg)?;
                vec![*st
                    .workers
                    .last()
                    .ok_or_else(|| anyhow::anyhow!("scale-in: job has no workers"))?]
            } else {
                workers()
            };
            client.scale_in(ids).map_err(anyhow::Error::msg)?;
            println!("scaled in");
        }
        "migrate" => {
            client.migrate(workers(), machines()).map_err(anyhow::Error::msg)?;
            println!("migrated");
        }
        "profile" => {
            let rows = client
                .call(&Request::Profile {
                    min_p: args.usize("min-p", 1) as u32,
                    steps_per_level: args.u64("steps-per-level", 10),
                })
                .map_err(anyhow::Error::msg)?
                .profile()
                .map_err(anyhow::Error::msg)?;
            println!("{:>4} {:>12} {:>14} {:>10}", "p", "samples/s", "per-GPU", "efficiency");
            for r in &rows {
                println!(
                    "{:>4} {:>12.1} {:>14.1} {:>10.3}",
                    r.parallelism, r.throughput, r.per_gpu_throughput, r.efficiency
                );
            }
        }
        "checkpoint" => {
            client.checkpoint(&args.str("path", "ckpt.bin")).map_err(anyhow::Error::msg)?;
            println!("checkpoint written");
        }
        "restore" => {
            client.restore(&args.str("path", "ckpt.bin")).map_err(anyhow::Error::msg)?;
            println!("restored");
        }
        "stop" => {
            JobControl::stop(&mut client).map_err(anyhow::Error::msg)?;
            println!("job stopped");
        }
        other => anyhow::bail!("ctl: unknown verb {other:?}"),
    }
    Ok(())
}

/// The multi-tenant control plane: `edl master` (daemon) plus the
/// `master jobs` / `master shutdown` client verbs.
fn cmd_master(args: &Args) -> anyhow::Result<()> {
    match args.positional().get(1).map(String::as_str) {
        Some("jobs") => {
            let addr = args.str("master", "127.0.0.1:7500");
            let jobs = MasterClient::connect(&addr)?.jobs()?;
            if args.bool("json", false) {
                let mut arr = Json::Arr(Vec::new());
                for j in &jobs {
                    let mut o = Json::obj();
                    o.set("name", j.name.clone())
                        .set("phase", j.phase.clone())
                        .set("requested_p", j.requested_p)
                        .set("parallelism", j.parallelism)
                        .set("step", j.step)
                        .set("peak_p", j.peak_p)
                        .set("grow_ops", j.grow_ops)
                        .set("shrink_ops", j.shrink_ops)
                        .set("ctl_addr", j.ctl_addr.clone())
                        .set("machines", j.machines.clone());
                    arr.push(o);
                }
                println!("{}", arr.to_string_pretty());
            } else {
                println!(
                    "{:<12} {:<9} {:>4} {:>4} {:>8} {:>5} {:>5} {:>7}  {}",
                    "name", "phase", "req", "p", "step", "peak", "grow", "shrink", "ctl"
                );
                for j in &jobs {
                    println!(
                        "{:<12} {:<9} {:>4} {:>4} {:>8} {:>5} {:>5} {:>7}  {}",
                        j.name,
                        j.phase,
                        j.requested_p,
                        j.parallelism,
                        j.step,
                        j.peak_p,
                        j.grow_ops,
                        j.shrink_ops,
                        j.ctl_addr
                    );
                }
            }
            Ok(())
        }
        Some("stats") => {
            let addr = args.str("master", "127.0.0.1:7500");
            let st = MasterClient::connect(&addr)?.stats()?;
            if args.bool("json", false) {
                let mut o = Json::obj();
                o.set("ticks", st.ticks)
                    .set("tick_p50_us", st.tick_p50_us)
                    .set("tick_p99_us", st.tick_p99_us)
                    .set("tick_max_us", st.tick_max_us)
                    .set("decisions", st.decisions)
                    .set("starts", st.starts)
                    .set("grows", st.grows)
                    .set("shrinks", st.shrinks)
                    .set("stops", st.stops)
                    .set("jobs_total", st.jobs_total)
                    .set("jobs_running", st.jobs_running)
                    .set("conservation_ok", st.conservation_ok)
                    .set("shards", st.shards.len() as u64);
                println!("{}", o.to_string_pretty());
            } else {
                println!(
                    "ticks={} tick_p50={}us tick_p99={}us decisions={} \
                     (start {} / grow {} / shrink {} / stop {}) jobs {}/{} running \
                     conservation_ok={}",
                    st.ticks,
                    st.tick_p50_us,
                    st.tick_p99_us,
                    st.decisions,
                    st.starts,
                    st.grows,
                    st.shrinks,
                    st.stops,
                    st.jobs_running,
                    st.jobs_total,
                    st.conservation_ok
                );
                println!(
                    "{:<6} {:>8} {:>8} {:>8} {:>8}",
                    "shard", "machines", "cap", "free", "held"
                );
                for s in &st.shards {
                    println!(
                        "{:<6} {:>8} {:>8} {:>8} {:>8}",
                        s.shard, s.machines, s.capacity, s.free, s.held
                    );
                }
            }
            Ok(())
        }
        Some("shutdown") => {
            let addr = args.str("master", "127.0.0.1:7500");
            MasterClient::connect(&addr)?.shutdown()?;
            println!("master stopped");
            Ok(())
        }
        _ => {
            let n = args.usize("machines", 2);
            let gpus = args.usize("gpus", 2) as u32;
            let sched: Box<dyn Scheduler + Send> =
                match args.str("scheduler", "elastic-tiresias").as_str() {
                    "fifo" => Box::new(FifoScheduler),
                    "tiresias" => Box::new(Tiresias::new(vec![500.0, 10_000.0])),
                    _ => Box::new(ElasticTiresias::new(
                        vec![500.0, 10_000.0],
                        args.usize("waiting-threshold", 10),
                        args.f64("r", 0.5),
                    )),
                };
            let cfg = MasterConfig {
                machines: (1..=n)
                    .map(|i| MachineSpec { name: format!("m{i}"), gpus })
                    .collect(),
                tick_ms: args.u64("tick-ms", 250),
                lease_ttl_ms: args.u64("lease-ttl-ms", 5_000),
                listen: args.str("listen", "127.0.0.1:0"),
                kv_listen: args.str("kv-listen", "127.0.0.1:0"),
                worker_bin: None,
                rack_size: args.usize("rack-size", 32),
                sim_slots: args.bool("sim-slots", false),
                headless_workers: args.bool("headless-workers", false),
                pipeline: !args.bool("serial", false),
                executors: args.usize("executors", 4),
                pollers: args.usize("pollers", 4),
            };
            let master = Master::start(cfg, sched)?;
            println!("master-control {}", master.addr);
            println!("kv {}", master.kv_addr);
            println!(
                "submit jobs with: edl submit --master {} --name job1 --gpus 1 --steps 200",
                master.addr
            );
            master.join();
            Ok(())
        }
    }
}

/// Submit one job to a running master.
fn cmd_submit(args: &Args) -> anyhow::Result<()> {
    let addr = args.str("master", "127.0.0.1:7500");
    let spec = SubmitSpec {
        name: args
            .opt_str("name")
            .ok_or_else(|| anyhow::anyhow!("submit: missing --name <job>"))?,
        model: args.str("model", "ResNet50"),
        gpus: args.usize("gpus", 1) as u32,
        steps: args.u64("steps", 200),
        elastic: !args.bool("inelastic", false),
        params: args.u64("params", 512),
        compute_ms: args.u64("compute-ms", 5),
    };
    let id = MasterClient::connect(&addr)?.submit(&spec)?;
    println!("submitted job {:?} (id {id})", spec.name);
    Ok(())
}

fn cmd_profile(args: &Args) -> anyhow::Result<()> {
    let max_p = args.usize("max-p", 4);
    let min_p = args.usize("min-p", 1) as u32;
    let k = args.u64("steps-per-level", 10);
    let (trainer, _corpus) = build_trainer(args, max_p)?;
    trainer.wait_step(3, std::time::Duration::from_secs(600));
    let rows = trainer.profile(min_p, k);
    println!("{:>4} {:>12} {:>14} {:>10}", "p", "samples/s", "per-GPU", "efficiency");
    for r in &rows {
        println!(
            "{:>4} {:>12.1} {:>14.1} {:>10.3}",
            r.parallelism, r.throughput, r.per_gpu_throughput, r.efficiency
        );
    }
    trainer.stop();
    Ok(())
}

fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    let n_jobs = args.usize("jobs", 2000);
    let machines = args.usize("machines", 36);
    let trace = trace::generate(&TraceConfig {
        n_jobs,
        span_s: args.f64("span-days", 14.0) * 86_400.0,
        ..Default::default()
    });
    let sched_name = args.str("scheduler", "elastic-tiresias");
    let mut sim = ClusterSim::new(machines, 8, &trace, ScaleMode::Edl);
    match sched_name.as_str() {
        "tiresias" => {
            let mut s = Tiresias::new(vec![500.0, 10_000.0]);
            sim.run(&mut s, 1e9);
        }
        _ => {
            let mut s = ElasticTiresias::new(vec![500.0, 10_000.0], 10, 0.5);
            sim.run(&mut s, 1e9);
        }
    }
    let stats = JctStats::from(&sim.jcts());
    println!("scheduler={sched_name} jobs={} machines={machines}x8", n_jobs);
    println!(
        "JCT  mean={:.0}s median={:.0}s p95={:.0}s  (finished {}/{})",
        stats.mean,
        stats.median,
        stats.p95,
        stats.count,
        trace.len()
    );
    println!(
        "util(tw-mean)={:.3} cluster-eff(tw-mean)={:.3}",
        sim.util_ts.time_weighted_mean(),
        sim.cluster_eff_ts.time_weighted_mean()
    );
    Ok(())
}

fn cmd_trace_stats(args: &Args) -> anyhow::Result<()> {
    let n_jobs = args.usize("jobs", 20_000);
    let cfg = TraceConfig { n_jobs, ..Default::default() };
    let jobs = trace::generate(&cfg);
    let st = trace::stats_of(&jobs, cfg.span_s);
    println!("jobs={} span={:.0} days", st.n_jobs, cfg.span_s / 86_400.0);
    println!(
        "job size GPU·s: p20={:.0} p50={:.0} p90={:.0} p99={:.0}",
        st.size_p20, st.size_p50, st.size_p90, st.size_p99
    );
    println!("(paper Fig 2b: p20=85, p90=58,330)");
    Ok(())
}

/// `edl verify` — the repo's custom static-analysis pass plus the bounded
/// protocol model checker (DESIGN.md §7). Exits nonzero on any surviving
/// diagnostic, any model invariant violation, or a non-exhausted
/// exploration (state cap hit means the scope was NOT fully checked).
fn cmd_verify(args: &Args) -> anyhow::Result<()> {
    use edl::verify::{self, model, Allowlist};
    use std::path::Path;

    let model_only = args.bool("model-only", false);
    let mut failed = false;

    if !model_only {
        // default roots work from the repo root or from rust/; the tests
        // tree must be scanned too — wire-coverage counts constructions in
        // integration tests
        let root = args.opt_str("root").unwrap_or_else(|| {
            if Path::new("rust/src").is_dir() {
                "rust/src,rust/tests".into()
            } else {
                "src,tests".into()
            }
        });
        let allow_path = args.opt_str("allow").unwrap_or_else(|| {
            if Path::new("rust/verify_allow.txt").is_file() {
                "rust/verify_allow.txt".into()
            } else {
                "verify_allow.txt".into()
            }
        });
        let roots: Vec<&Path> = root.split(',').map(Path::new).collect();
        let sources = verify::collect_sources(&roots)?;
        anyhow::ensure!(
            !sources.is_empty(),
            "verify: no .rs sources under {root:?} (run from the repo root or pass --root)"
        );
        let allow = Allowlist::load(Path::new(&allow_path)).map_err(anyhow::Error::msg)?;
        let report = verify::run_lints(&sources, &allow);
        for d in &report.diagnostics {
            println!("{d}");
        }
        println!(
            "verify: {} files linted, {} diagnostics, {} suppressed via {}",
            sources.len(),
            report.diagnostics.len(),
            report.suppressed,
            allow_path
        );
        failed |= !report.diagnostics.is_empty();
    }

    if !args.bool("skip-model", false) {
        let scope = model::ModelScope {
            max_workers: args.usize("model-workers", 3),
            max_ops: args.usize("model-ops", 2),
            step_cap: args.u64("model-steps", 4),
            max_states: args.usize("max-states", 250_000),
            max_fails: args.usize("model-fails", 2),
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let rep = model::explore(scope);
        println!(
            "model: {} states, {} transitions, max depth {}, {} mid-reform, \
             exhausted={} ({:.1}s)",
            rep.states,
            rep.transitions,
            rep.max_depth,
            rep.reform_states,
            rep.exhausted,
            t0.elapsed().as_secs_f64()
        );
        match &rep.violation {
            Some((what, trace)) => {
                println!("model: INVARIANT VIOLATION: {what}");
                for (i, step) in trace.iter().enumerate() {
                    println!("  {:>3}. {step}", i + 1);
                }
                failed = true;
            }
            None if !rep.exhausted => {
                println!(
                    "model: state cap hit before the scope was exhausted — raise \
                     --max-states or shrink --model-steps/--model-ops"
                );
                failed = true;
            }
            None => {}
        }
    }

    anyhow::ensure!(!failed, "verify failed");
    println!("verify: OK");
    Ok(())
}

fn cmd_kv() -> anyhow::Result<()> {
    let server = edl::coordsvc::KvServer::start()?;
    println!("coordination KV serving on {}", server.addr);
    println!("press ctrl-c to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}
