//! L1/L2 hot-path bench on the REAL artifacts: per-call latency of the
//! compiled grad_step / apply_update / train_step executables (tiny
//! config), plus executable compile times (= context preparation on this
//! substrate). Requires `make artifacts`.

use edl::data::corpus::Corpus;
use edl::runtime::{artifacts_dir, ModelMeta, Runtime};
use edl::util::json::{write_results, Json};
use edl::util::stats;
use std::time::Instant;

fn main() {
    if ModelMeta::load(artifacts_dir(), "tiny").is_err() {
        println!("artifacts not built; run `make artifacts` first — skipping");
        return;
    }
    let rt = Runtime::open(artifacts_dir(), "tiny").unwrap();
    let corpus = Corpus::markov(rt.meta.vocab, rt.meta.seq_len, 256, 7);
    let params = rt.init_params(0).unwrap();
    let mut out = Json::obj();

    println!("== compile (context preparation) ==");
    let mut compile_rows = Json::Arr(vec![]);
    for name in ["tiny_grad_b4", "tiny_train_b4", "tiny_apply"] {
        let (_e, t) = rt.load_with_timing(name).unwrap();
        println!("  {name:<16} parse {:>7.1}ms compile {:>9.1}ms", t.parse_s * 1e3, t.compile_s * 1e3);
        let mut r = Json::obj();
        r.set("artifact", name).set("parse_ms", t.parse_s * 1e3).set("compile_ms", t.compile_s * 1e3);
        compile_rows.push(r);
    }
    out.set("compile", compile_rows);

    println!("\n== execution (per call, batch 4) ==");
    let toks = corpus.batch(0, 4);
    let measure = |f: &dyn Fn() -> (), n: usize| -> Vec<f64> {
        // warmup
        f();
        (0..n)
            .map(|_| {
                let t0 = Instant::now();
                f();
                t0.elapsed().as_secs_f64() * 1e3
            })
            .collect()
    };
    let grad_t = measure(&|| {
        rt.grad_step(&params, &toks, 4).unwrap();
    }, 10);
    let apply_t = {
        let (_, grads) = rt.grad_step(&params, &toks, 4).unwrap();
        measure(&|| {
            rt.apply_update(&params, &grads, 0.1).unwrap();
        }, 10)
    };
    let train_t = measure(&|| {
        rt.train_step(&params, &toks, 4, 0.1).unwrap();
    }, 10);
    for (name, t) in [("grad_step", &grad_t), ("apply_update", &apply_t), ("train_step", &train_t)] {
        println!("  {name:<14} p50 {:>8.1}ms  min {:>8.1}ms", stats::median(t), stats::min(t));
        let mut r = Json::obj();
        r.set("p50_ms", stats::median(t)).set("min_ms", stats::min(t));
        out.set(name, r);
    }
    // fused train_step must not be slower than grad+apply separately (the
    // L2 fusion win)
    let fused = stats::median(&train_t);
    let split = stats::median(&grad_t) + stats::median(&apply_t);
    println!("\nfused train_step {:.1}ms vs grad+apply {:.1}ms ({:.0}%)", fused, split, fused / split * 100.0);
    out.set("fused_over_split", fused / split);

    // -- §Perf: device-resident parameter path (the trainer's hot loop) ----
    println!("\n== device-resident path (params stay in PJRT buffers) ==");
    rt.executable(&format!("{}_applyb", rt.meta.name)).unwrap();
    let mut pbuf = rt.upload_params(&params).unwrap();
    let grad_dev_t = measure(&|| {
        rt.grad_step_dev(&pbuf, &toks, 4).unwrap();
    }, 10);
    let apply_dev_t: Vec<f64> = {
        let (_, grads) = rt.grad_step_dev(&pbuf, &toks, 4).unwrap();
        // chain buffers exactly as the worker loop does
        let mut times = Vec::new();
        for _ in 0..10 {
            let t0 = Instant::now();
            pbuf = rt.apply_update_dev(&pbuf, &grads, 0.0).unwrap();
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        times
    };
    for (name, t, host) in [
        ("grad_step_dev", &grad_dev_t, &grad_t),
        ("apply_update_dev", &apply_dev_t, &apply_t),
    ] {
        let dev = stats::median(t);
        let h = stats::median(host);
        println!("  {name:<18} p50 {:>8.2}ms  (host path {:>8.2}ms, {:.2}x)", dev, h, h / dev);
        let mut r = Json::obj();
        r.set("p50_ms", dev).set("host_p50_ms", h).set("speedup", h / dev);
        out.set(name, r);
    }
    let step_dev = stats::median(&grad_dev_t) + stats::median(&apply_dev_t);
    let step_host = stats::median(&grad_t) + stats::median(&apply_t);
    println!("  full step: device {:.1}ms vs host {:.1}ms ({:+.0}%)", step_dev, step_host, (step_dev / step_host - 1.0) * 100.0);
    out.set("step_dev_ms", step_dev);
    out.set("step_host_ms", step_host);

    let sps = 4.0 / (stats::median(&grad_t) / 1e3);
    println!("effective grad throughput: {sps:.1} samples/s/worker (tiny, b=4)");
    out.set("grad_sps", sps);
    let path = write_results("perf_runtime_step", &out).unwrap();
    println!("results -> {}", path.display());
}
