//! Leader election over the TCP coordination service — the §4.1 protocol
//! at integration scale (many contending clients, failure, re-election,
//! lease refresh), including a leader-restart-under-lease-expiry case
//! driven through the chaos harness's fault hooks.

use edl::coordsvc::{KvClient, KvServer};
use edl::harness::{FaultKind, FaultPlan, FaultRule, Family};
use edl::transport::FaultHook;
use edl::util::stats;
use std::sync::Arc;

#[test]
fn contended_election_many_workers() {
    let server = KvServer::start().unwrap();
    let addr = server.addr.clone();
    let n = 64;
    let winners: Vec<String> = std::thread::scope(|s| {
        (0..n)
            .map(|i| {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = KvClient::connect(&addr).unwrap();
                    c.elect("bigjob", &format!("w{i}"), 10_000).unwrap()
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    assert!(winners.windows(2).all(|w| w[0] == w[1]), "split brain");
}

#[test]
fn election_latency_reasonable() {
    // the paper reports 7 ms avg / 33 ms max with 256 workers on etcd;
    // sanity-check that our substrate is in a usable range (loopback)
    let server = KvServer::start().unwrap();
    let mut c = KvClient::connect(&server.addr).unwrap();
    let mut lat = Vec::new();
    for i in 0..50 {
        let job = format!("job{i}");
        let t0 = std::time::Instant::now();
        let w = c.elect(&job, "me", 5_000).unwrap();
        lat.push(t0.elapsed().as_secs_f64() * 1e3);
        assert_eq!(w, "me");
    }
    let p50 = stats::median(&lat);
    assert!(p50 < 50.0, "election median {p50:.2} ms too slow");
}

#[test]
fn failover_after_leader_crash() {
    let server = KvServer::start().unwrap();
    let mut c1 = KvClient::connect(&server.addr).unwrap();
    let mut c2 = KvClient::connect(&server.addr).unwrap();
    // w1 wins with a short lease and then "crashes" (never refreshes)
    assert_eq!(c1.elect("job", "w1", 60).unwrap(), "w1");
    // w2 sees w1 while the lease is live
    assert_eq!(c2.elect("job", "w2", 60).unwrap(), "w1");
    std::thread::sleep(std::time::Duration::from_millis(150));
    // lease expired server-side; w2 must win re-election
    assert_eq!(c2.elect("job", "w2", 60).unwrap(), "w2");
}

#[test]
fn leader_keeps_leadership_with_refresh() {
    let server = KvServer::start().unwrap();
    let mut c1 = KvClient::connect(&server.addr).unwrap();
    let mut c2 = KvClient::connect(&server.addr).unwrap();
    assert_eq!(c1.elect("job", "w1", 100).unwrap(), "w1");
    for _ in 0..5 {
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(c1.refresh("edl/leader/job", b"w1", 100).unwrap(), "refresh failed");
    }
    // still w1 after 250ms (>> original lease)
    assert_eq!(c2.elect("job", "w2", 100).unwrap(), "w1");
}

#[test]
fn graceful_resignation_hands_over() {
    let server = KvServer::start().unwrap();
    let mut c1 = KvClient::connect(&server.addr).unwrap();
    let mut c2 = KvClient::connect(&server.addr).unwrap();
    assert_eq!(c1.elect("job", "w1", 10_000).unwrap(), "w1");
    // graceful exit (§4.2): the leader erases its address
    assert!(c1.delete("edl/leader/job").unwrap());
    assert_eq!(c2.elect("job", "w2", 10_000).unwrap(), "w2");
}

#[test]
fn leader_restart_under_lease_expiry_with_fault_hook() {
    // TTL-lease handover regression, driven through the SAME fault hooks
    // the chaos harness arms elsewhere: the incumbent leader keeps
    // refreshing its lease, but a fault window delays every KV request
    // past the TTL — exactly what a partition between the leader machine
    // and the coordination service looks like. The lease must expire, a
    // restarted leader must win the re-election, and after the window
    // heals the OLD leader's refresh must fail (leadership lost) instead
    // of resurrecting a split brain.
    let server = KvServer::start().unwrap();
    let mut old_leader = KvClient::connect(&server.addr).unwrap();
    let mut new_leader = KvClient::connect(&server.addr).unwrap();

    const TTL_MS: u64 = 120;
    assert_eq!(old_leader.elect("job", "w-old", TTL_MS).unwrap(), "w-old");
    // healthy refreshes keep leadership
    for _ in 0..3 {
        std::thread::sleep(std::time::Duration::from_millis(40));
        assert!(old_leader.refresh("edl/leader/job", b"w-old", TTL_MS).unwrap());
    }

    // fault window: every KV request is delayed past the TTL, so the
    // incumbent's refresh arrives only after its lease already expired
    let plan = FaultPlan::new(0xE1EC);
    plan.add(
        FaultRule::always(FaultKind::Delay(2 * TTL_MS))
            .family(Family::Kv)
            .window(0, u64::MAX),
    );
    let hook: Arc<dyn FaultHook> = plan.clone();
    server.set_fault_hook(Some(hook));
    // the delayed refresh lands after expiry: it must report failure
    assert!(
        !old_leader.refresh("edl/leader/job", b"w-old", TTL_MS).unwrap(),
        "a refresh that arrived after lease expiry must not extend it"
    );
    assert!(plan.hits() > 0, "the fault hook never fired");
    server.set_fault_hook(None); // heal

    // the restarted leader claims the vacant key
    assert_eq!(new_leader.elect("job", "w-new", 10_000).unwrap(), "w-new");
    // the old incumbent cannot refresh a lease it lost, and re-election
    // tells it who the real leader is now
    assert!(!old_leader.refresh("edl/leader/job", b"w-old", TTL_MS).unwrap());
    assert_eq!(old_leader.elect("job", "w-old", TTL_MS).unwrap(), "w-new");
}

#[test]
fn job_metadata_handoff_via_kv() {
    // the departing leader parks job metadata for its successor
    let server = KvServer::start().unwrap();
    let mut old_leader = KvClient::connect(&server.addr).unwrap();
    let mut new_leader = KvClient::connect(&server.addr).unwrap();
    old_leader.put("edl/job/42/meta", b"batch=32;step=100", 0).unwrap();
    old_leader.delete("edl/leader/42").unwrap();
    assert_eq!(new_leader.elect("42", "w9", 5_000).unwrap(), "w9");
    let (meta, _) = new_leader.get("edl/job/42/meta").unwrap().unwrap();
    assert_eq!(meta, b"batch=32;step=100".to_vec());
}
