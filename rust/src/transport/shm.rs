//! Shared-memory data plane: per-link SPSC ring buffers in `mmap`'d files
//! plus the machine-identity digest that gates them (DESIGN.md §9).
//!
//! Workers that prove they share a machine (equal nonzero
//! [`machine_identity`] digests, exchanged through the Hello/Welcome
//! handshake and the `Peers` directory) route their data-plane frames
//! through [`ShmNode`] instead of loopback TCP; everything else stays on
//! [`TcpNode`]. [`MixedNode`] wraps both behind the one
//! [`PointToPoint`] surface, so allreduce, abort/reform and the chaos
//! harness are untouched at the call site.
//!
//! §Ring layout — one file per *directed* link, `link-<from>-<to>.ring`
//! inside a per-job namespace directory under `/dev/shm` (fallback: the
//! system temp dir):
//!
//! ```text
//! [Hdr 192 B: magic | version | state | cap | pids
//!             | head+space_seq   (consumer cacheline)
//!             | tail+data_seq    (producer cacheline)]
//! [data: cap bytes, cap a power of two]
//! ```
//!
//! `head`/`tail` are MONOTONIC byte positions (index = `pos & (cap-1)`);
//! the data region is a circular *byte stream*, so a frame
//! (`[len u32][tag u32][payload]`) may wrap, and a payload larger than
//! the ring streams through in capacity-bounded partial writes — there
//! is no separate spill path and no frame-size ceiling below
//! `wire::MAX_FRAME`. The producer is the sole writer of `tail`, the
//! consumer of `head` (SPSC: no CAS on the hot path, one release store
//! per transfer).
//!
//! §Parking — blocked sides sleep on a futex word (`data_seq` for
//! empty-ring consumers, `space_seq` for full-ring producers) that the
//! other side bumps after every transfer; wake syscalls are skipped
//! unless a waiter registered. Every wait is timeout-bounded (≤
//! [`PARK`]), so a missed wake degrades to sub-millisecond polling and
//! can never deadlock; on architectures without a wired-up futex
//! syscall the same protocol runs on a sleep-poll fallback. A producer
//! blocked on a full ring re-checks the consumer's liveness via
//! `/proc/<pid>` so a dead peer surfaces as [`NetError::UnknownPeer`]
//! instead of a 30 s stall; a *vanished* consumer on the receive side
//! needs no check — it simply times out, exactly like TCP.
//!
//! Fault injection: the [`FaultCell`] seam is applied sender-side
//! (drop/duplicate/delay) before bytes enter the ring, so chaos
//! verdicts are byte-for-byte identical to the TCP path.

use super::{
    Body, BufPool, FaultCell, FaultHook, Frame, FrameFate, Msg, NetError, NodeId, PendingQueue,
    PointToPoint, Result, Shared, TcpNode,
};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Upper bound on one futex/poll park: a missed wake costs at most this
/// much latency and a broken wake path degrades to polling, not deadlock.
const PARK: Duration = Duration::from_micros(500);

/// How long a producer tolerates a full ring before declaring the link
/// stalled (mirrors the data-plane receive timeouts).
const SEND_STALL: Duration = Duration::from_secs(30);

/// Re-check the blocked-producer's consumer liveness this often.
const LIVENESS_EVERY: Duration = Duration::from_millis(10);

/// Default per-link ring capacity (bytes; power of two). Allreduce
/// segments are 256 KiB, so 4 MiB keeps the lock-step pipeline from ever
/// blocking on space in steady state. Override: `EDL_SHM_RING_CAP`.
const DEFAULT_RING_CAP: usize = 4 << 20;

// ---------------------------------------------------------------------------
// machine identity
// ---------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// This process's machine-identity digest: equal nonzero digests mean
/// "same physical machine" and unlock the shm path for that link.
/// Digest 0 means "shm unsupported/disabled" (always negotiate TCP).
///
/// Sources, in priority order:
///  * `EDL_SHM=0` — kill switch, returns 0;
///  * `EDL_MACHINE_ID` — explicit label (the master stamps each spawned
///    worker with its machine label, so same-label workers — which truly
///    share the host — negotiate shm even in single-host simulations);
///  * the kernel boot id + hostname, hashed (two hosts cannot collide on
///    a shared filesystem, and containers get distinct boot ids).
pub fn machine_identity() -> u64 {
    if std::env::var("EDL_SHM").ok().as_deref() == Some("0") {
        return 0;
    }
    if let Ok(label) = std::env::var("EDL_MACHINE_ID") {
        if label.is_empty() {
            return 0;
        }
        return nonzero(fnv1a(FNV_OFFSET, label.as_bytes()));
    }
    let mut h = FNV_OFFSET;
    let mut any = false;
    for src in ["/proc/sys/kernel/random/boot_id", "/etc/hostname"] {
        if let Ok(s) = std::fs::read_to_string(src) {
            h = fnv1a(h, s.trim().as_bytes());
            any = true;
        }
    }
    if any {
        nonzero(h)
    } else {
        0
    }
}

/// Digest 0 is the "no shm" sentinel; remap the (astronomically
/// unlikely) genuine 0 hash so a real machine is never mistaken for it.
fn nonzero(h: u64) -> u64 {
    if h == 0 {
        1
    } else {
        h
    }
}

/// Namespace directory for a job's ring files: `/dev/shm` when present
/// (Linux: a tmpfs, so ring traffic never touches a disk), else the
/// system temp dir.
pub fn shm_base_dir() -> PathBuf {
    let dev_shm = Path::new("/dev/shm");
    if dev_shm.is_dir() {
        dev_shm.to_path_buf()
    } else {
        std::env::temp_dir()
    }
}

// ---------------------------------------------------------------------------
// mmap + futex FFI (std-only: libc is already linked by std)
// ---------------------------------------------------------------------------

#[cfg(unix)]
mod sys {
    use std::ffi::{c_int, c_long, c_void};
    use std::time::Duration;

    pub const PROT_READ: c_int = 1;
    pub const PROT_WRITE: c_int = 2;
    pub const MAP_SHARED: c_int = 1;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> c_int;
        #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
        fn syscall(num: c_long, ...) -> c_long;
    }

    pub unsafe fn map_shared(fd: c_int, len: usize) -> Option<*mut u8> {
        let p = mmap(std::ptr::null_mut(), len, PROT_READ | PROT_WRITE, MAP_SHARED, fd, 0);
        if p as isize == -1 || p.is_null() {
            None
        } else {
            Some(p as *mut u8)
        }
    }

    pub unsafe fn unmap(ptr: *mut u8, len: usize) {
        munmap(ptr as *mut c_void, len);
    }

    #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
    const SYS_FUTEX: c_long = 202;
    #[cfg(all(target_os = "linux", target_arch = "aarch64"))]
    const SYS_FUTEX: c_long = 98;
    // futex op codes WITHOUT FUTEX_PRIVATE_FLAG: the word lives in a
    // MAP_SHARED mapping and must wake across processes
    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    const FUTEX_WAIT: c_long = 0;
    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    const FUTEX_WAKE: c_long = 1;

    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }

    /// Sleep until `word != expected`, a wake, or `dur` — whichever is
    /// first. Callers always bound `dur` (≤ `PARK`), so a lost wake or a
    /// fallback build degrades to polling, never a hang.
    pub fn futex_wait(word: *const u32, expected: u32, dur: Duration) {
        #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
        unsafe {
            let ts = Timespec {
                tv_sec: dur.as_secs() as i64,
                tv_nsec: dur.subsec_nanos() as i64,
            };
            // result intentionally ignored: EAGAIN (word changed),
            // ETIMEDOUT and EINTR are all "go re-check the ring"
            syscall(
                SYS_FUTEX,
                word,
                FUTEX_WAIT,
                expected as c_long,
                &ts as *const Timespec,
                0 as c_long,
                0 as c_long,
            );
        }
        #[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
        {
            let _ = (word, expected);
            std::thread::sleep(dur.min(Duration::from_micros(200)));
        }
    }

    pub fn futex_wake(word: *const u32) {
        #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
        unsafe {
            syscall(SYS_FUTEX, word, FUTEX_WAKE, i32::MAX as c_long, 0 as c_long);
        }
        #[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
        {
            let _ = word;
        }
    }

    /// Best-effort liveness of another local process (`/proc` probe).
    /// Non-Linux unix has no `/proc`; report alive and let the bounded
    /// stall timeout make the call instead.
    pub fn pid_alive(pid: u32) -> bool {
        #[cfg(target_os = "linux")]
        {
            std::path::Path::new(&format!("/proc/{pid}")).exists()
        }
        #[cfg(not(target_os = "linux"))]
        {
            let _ = pid;
            true
        }
    }
}

// ---------------------------------------------------------------------------
// ring header + mapping
// ---------------------------------------------------------------------------

#[cfg(unix)]
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// File-ring magic: "EDLSHM1\0" little-endian.
#[cfg(unix)]
const RING_MAGIC: u64 = 0x004d_4853_4c44_4531;
#[cfg(unix)]
const RING_VERSION: u32 = 1;
#[cfg(unix)]
const STATE_EMPTY: u32 = 0;
#[cfg(unix)]
const STATE_INIT: u32 = 1;
#[cfg(unix)]
const STATE_READY: u32 = 2;

/// Ring header. `head` (+ the space futex word the producer waits on)
/// and `tail` (+ the data futex word the consumer waits on) live on
/// separate cachelines so the SPSC hot path never false-shares.
#[cfg(unix)]
#[repr(C)]
struct Hdr {
    magic: AtomicU64,
    version: AtomicU32,
    state: AtomicU32,
    cap: AtomicU64,
    producer_pid: AtomicU32,
    consumer_pid: AtomicU32,
    _pad0: [u8; 32],
    /// consumer's monotonic byte position (sole writer: consumer)
    head: AtomicU64,
    /// bumped by the consumer after freeing space; producers park on it
    space_seq: AtomicU32,
    space_waiters: AtomicU32,
    _pad1: [u8; 48],
    /// producer's monotonic byte position (sole writer: producer)
    tail: AtomicU64,
    /// bumped by the producer after publishing bytes; consumers park on it
    data_seq: AtomicU32,
    data_waiters: AtomicU32,
    _pad2: [u8; 48],
}

#[cfg(unix)]
const HDR_SIZE: usize = 192;
#[cfg(unix)]
const _: () = assert!(std::mem::size_of::<Hdr>() == HDR_SIZE);

/// One mapped ring file. Unmapped on drop; the fd is closed immediately
/// after mapping (the mapping keeps the inode alive).
#[cfg(unix)]
struct RingMap {
    ptr: *mut u8,
    len: usize,
    cap: usize,
    mask: u64,
    path: PathBuf,
}

// raw pointer into a MAP_SHARED file; every access goes through atomics
// or SPSC-disciplined copies
#[cfg(unix)]
unsafe impl Send for RingMap {}

#[cfg(unix)]
impl Drop for RingMap {
    fn drop(&mut self) {
        unsafe { sys::unmap(self.ptr, self.len) };
    }
}

#[cfg(unix)]
impl RingMap {
    fn hdr(&self) -> &Hdr {
        unsafe { &*(self.ptr as *const Hdr) }
    }

    fn data(&self) -> *mut u8 {
        unsafe { self.ptr.add(HDR_SIZE) }
    }

    /// Open-or-create the ring at `path`. The first toucher wins the
    /// `state` CAS, sizes and stamps the header, and flips it READY;
    /// the loser spins (bounded) until READY and verifies the layout.
    /// Both orders work — a consumer may create the ring before its
    /// producer has ever sent.
    fn open(path: &Path, want_cap: usize) -> std::io::Result<RingMap> {
        use std::os::unix::io::AsRawFd;
        assert!(want_cap.is_power_of_two());
        let file = std::fs::OpenOptions::new().read(true).write(true).create(true).open(path)?;
        let total = HDR_SIZE + want_cap;
        // grow-only sizing: never shrink a ring another process mapped
        if file.metadata()?.len() < total as u64 {
            file.set_len(total as u64)?;
        }
        let ptr = unsafe { sys::map_shared(file.as_raw_fd(), total) }.ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::Other, "mmap of shm ring failed")
        })?;
        let map = RingMap {
            ptr,
            len: total,
            cap: want_cap,
            mask: (want_cap - 1) as u64,
            path: path.into(),
        };
        let h = map.hdr();
        match h.state.compare_exchange(
            STATE_EMPTY,
            STATE_INIT,
            Ordering::AcqRel,
            Ordering::Acquire,
        ) {
            Ok(_) => {
                h.magic.store(RING_MAGIC, Ordering::Relaxed);
                h.version.store(RING_VERSION, Ordering::Relaxed);
                h.cap.store(want_cap as u64, Ordering::Relaxed);
                h.state.store(STATE_READY, Ordering::Release);
            }
            Err(_) => {
                let deadline = Instant::now() + Duration::from_secs(5);
                while h.state.load(Ordering::Acquire) != STATE_READY {
                    if Instant::now() >= deadline {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            format!("shm ring {} stuck initializing", path.display()),
                        ));
                    }
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
        }
        if h.magic.load(Ordering::Acquire) != RING_MAGIC
            || h.version.load(Ordering::Acquire) != RING_VERSION
            || h.cap.load(Ordering::Acquire) != want_cap as u64
        {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("shm ring {} has incompatible layout", path.display()),
            ));
        }
        Ok(map)
    }

    /// Bytes available to read.
    fn avail(&self) -> usize {
        let h = self.hdr();
        (h.tail.load(Ordering::Acquire) - h.head.load(Ordering::Relaxed)) as usize
    }

    /// Copy `src` into the stream at monotonic position `pos` (wraps).
    unsafe fn copy_in(&self, pos: u64, src: &[u8]) {
        let i = (pos & self.mask) as usize;
        let first = src.len().min(self.cap - i);
        std::ptr::copy_nonoverlapping(src.as_ptr(), self.data().add(i), first);
        std::ptr::copy_nonoverlapping(src.as_ptr().add(first), self.data(), src.len() - first);
    }

    /// Copy `dst.len()` stream bytes at monotonic position `pos` out.
    unsafe fn copy_out(&self, pos: u64, dst: &mut [u8]) {
        let i = (pos & self.mask) as usize;
        let first = dst.len().min(self.cap - i);
        std::ptr::copy_nonoverlapping(self.data().add(i), dst.as_mut_ptr(), first);
        std::ptr::copy_nonoverlapping(self.data(), dst.as_mut_ptr().add(first), dst.len() - first);
    }
}

// ---------------------------------------------------------------------------
// producer / consumer link halves
// ---------------------------------------------------------------------------

#[cfg(unix)]
struct OutLink {
    map: RingMap,
    last_live_check: Instant,
}

#[cfg(unix)]
impl OutLink {
    fn open(path: &Path, cap: usize) -> std::io::Result<OutLink> {
        let map = RingMap::open(path, cap)?;
        map.hdr().producer_pid.store(std::process::id(), Ordering::Release);
        Ok(OutLink { map, last_live_check: Instant::now() })
    }

    /// Stream `src` into the ring in capacity-bounded chunks, parking on
    /// the space futex while full. Uniform for every payload size: a
    /// frame larger than the ring simply streams through it.
    fn write_bytes(&mut self, mut src: &[u8], to: NodeId, deadline: Instant) -> Result<()> {
        let h = self.map.hdr();
        while !src.is_empty() {
            let tail = h.tail.load(Ordering::Relaxed);
            let head = h.head.load(Ordering::Acquire);
            let space = self.map.cap - (tail - head) as usize;
            if space == 0 {
                let now = Instant::now();
                if now >= deadline {
                    // a consumer that stopped draining for the whole
                    // stall window is as good as dead: surface an Io
                    // error so allreduce unwinds it as PeerLost
                    return Err(NetError::Io(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        format!("shm ring to {to} stalled: consumer not draining"),
                    )));
                }
                if now.duration_since(self.last_live_check) >= LIVENESS_EVERY {
                    self.last_live_check = now;
                    let pid = h.consumer_pid.load(Ordering::Acquire);
                    // pid 0 = consumer not attached yet (rendezvous:
                    // the ring itself is the buffer); a known-dead
                    // consumer fails fast like a dropped in-proc peer
                    if pid != 0 && !sys::pid_alive(pid) {
                        return Err(NetError::UnknownPeer(to));
                    }
                }
                self.wait_space(deadline);
                continue;
            }
            let n = space.min(src.len());
            unsafe { self.map.copy_in(tail, &src[..n]) };
            h.tail.store(tail + n as u64, Ordering::Release);
            h.data_seq.fetch_add(1, Ordering::Release);
            if h.data_waiters.load(Ordering::Acquire) > 0 {
                sys::futex_wake(&h.data_seq as *const AtomicU32 as *const u32);
            }
            src = &src[n..];
        }
        Ok(())
    }

    fn wait_space(&self, deadline: Instant) {
        let h = self.map.hdr();
        let seq = h.space_seq.load(Ordering::Acquire);
        let full = |h: &Hdr| {
            let tail = h.tail.load(Ordering::Relaxed);
            let head = h.head.load(Ordering::Acquire);
            (tail - head) as usize == self.map.cap
        };
        if !full(h) {
            return;
        }
        h.space_waiters.fetch_add(1, Ordering::AcqRel);
        if full(h) {
            let dur = PARK.min(deadline.saturating_duration_since(Instant::now()));
            if !dur.is_zero() {
                sys::futex_wait(&h.space_seq as *const AtomicU32 as *const u32, seq, dur);
            }
        }
        h.space_waiters.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Mid-frame read state, preserved across timeouts so a slow producer
/// never poisons the stream: the next receive resumes exactly where the
/// bytes stopped.
#[cfg(unix)]
enum Partial {
    Head { got: usize, bytes: [u8; 8] },
    Body { tag: u32, buf: Vec<u8>, need: usize },
}

#[cfg(unix)]
struct InLink {
    map: RingMap,
    partial: Option<Partial>,
}

#[cfg(unix)]
impl InLink {
    fn open(path: &Path, cap: usize) -> std::io::Result<InLink> {
        let map = RingMap::open(path, cap)?;
        map.hdr().consumer_pid.store(std::process::id(), Ordering::Release);
        Ok(InLink { map, partial: None })
    }

    /// Consume `n` stream bytes into `dst`, publishing the freed space.
    fn consume(&self, dst: &mut [u8]) {
        let h = self.map.hdr();
        let head = h.head.load(Ordering::Relaxed);
        unsafe { self.map.copy_out(head, dst) };
        h.head.store(head + dst.len() as u64, Ordering::Release);
        h.space_seq.fetch_add(1, Ordering::Release);
        if h.space_waiters.load(Ordering::Acquire) > 0 {
            sys::futex_wake(&h.space_seq as *const AtomicU32 as *const u32);
        }
    }

    /// Read one complete frame, parking on the data futex while the ring
    /// is empty. `deadline` in the past = non-blocking poll. On timeout
    /// the partial state is kept for the next call.
    fn read_frame(&mut self, pool: &mut BufPool, deadline: Instant) -> Result<(u32, Vec<u8>)> {
        loop {
            // complete any stage that needs no further bytes first, so a
            // zero-length payload never waits on an empty ring
            if let Some(Partial::Body { need: 0, .. }) = self.partial {
                match self.partial.take() {
                    Some(Partial::Body { tag, buf, .. }) => return Ok((tag, buf)),
                    _ => unreachable!("matched Body above"),
                }
            }
            let avail = self.map.avail();
            if avail == 0 {
                if Instant::now() >= deadline {
                    return Err(NetError::Timeout { from: None, tag: None });
                }
                self.wait_data(deadline);
                continue;
            }
            match self.partial.take() {
                None => self.partial = Some(Partial::Head { got: 0, bytes: [0u8; 8] }),
                Some(Partial::Head { mut got, mut bytes }) => {
                    let n = avail.min(8 - got);
                    self.consume(&mut bytes[got..got + n]);
                    got += n;
                    if got < 8 {
                        self.partial = Some(Partial::Head { got, bytes });
                        continue;
                    }
                    let len = u32::from_le_bytes(bytes[..4].try_into().expect("4-byte slice"))
                        as usize;
                    let tag = u32::from_le_bytes(bytes[4..8].try_into().expect("4-byte slice"));
                    if len > crate::wire::MAX_FRAME {
                        return Err(NetError::Io(std::io::Error::new(
                            std::io::ErrorKind::InvalidData,
                            format!(
                                "shm ring {}: corrupt frame length {len}",
                                self.map.path.display()
                            ),
                        )));
                    }
                    self.partial = Some(Partial::Body { tag, buf: pool.take(len), need: len });
                }
                Some(Partial::Body { tag, mut buf, need }) => {
                    let n = avail.min(need);
                    let old = buf.len();
                    buf.resize(old + n, 0);
                    self.consume(&mut buf[old..old + n]);
                    self.partial = Some(Partial::Body { tag, buf, need: need - n });
                }
            }
        }
    }

    fn wait_data(&self, deadline: Instant) {
        let h = self.map.hdr();
        let seq = h.data_seq.load(Ordering::Acquire);
        if self.map.avail() > 0 {
            return;
        }
        h.data_waiters.fetch_add(1, Ordering::AcqRel);
        if self.map.avail() == 0 {
            let dur = PARK.min(deadline.saturating_duration_since(Instant::now()));
            if !dur.is_zero() {
                sys::futex_wait(&h.data_seq as *const AtomicU32 as *const u32, seq, dur);
            }
        }
        h.data_waiters.fetch_sub(1, Ordering::AcqRel);
    }
}

// ---------------------------------------------------------------------------
// ShmNode
// ---------------------------------------------------------------------------

/// Shared-memory [`PointToPoint`] endpoint: one SPSC ring per directed
/// link under a per-job namespace directory. SPSC discipline holds
/// because `PointToPoint` takes `&mut self` — the owning thread is the
/// sole consumer, so (unlike `TcpNode`) there are no reader threads and
/// frames are pulled from the rings on demand into the same
/// selective-receive [`PendingQueue`].
#[cfg(unix)]
pub struct ShmNode {
    id: NodeId,
    dir: PathBuf,
    ring_cap: usize,
    out: HashMap<NodeId, OutLink>,
    inn: HashMap<NodeId, InLink>,
    pending: PendingQueue,
    pool: BufPool,
    faults: FaultCell,
}

#[cfg(unix)]
impl ShmNode {
    /// Join namespace `ns` (created under [`shm_base_dir`]) as node `id`.
    pub fn start(id: NodeId, ns: &str) -> Result<ShmNode> {
        let cap = std::env::var("EDL_SHM_RING_CAP")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .map(|v| v.max(64 * 1024).next_power_of_two())
            .unwrap_or(DEFAULT_RING_CAP);
        ShmNode::start_with(id, shm_base_dir().join(ns), cap)
    }

    /// Explicit directory + ring capacity (tests force tiny rings to
    /// exercise wrap-around and large-payload streaming).
    pub fn start_with(id: NodeId, dir: PathBuf, ring_cap: usize) -> Result<ShmNode> {
        assert!(ring_cap.is_power_of_two(), "ring capacity must be a power of two");
        std::fs::create_dir_all(&dir)?;
        Ok(ShmNode {
            id,
            dir,
            ring_cap,
            out: HashMap::new(),
            inn: HashMap::new(),
            pending: PendingQueue::default(),
            pool: BufPool::new(),
            faults: FaultCell::new(),
        })
    }

    fn link_path(&self, from: NodeId, to: NodeId) -> PathBuf {
        self.dir.join(format!("link-{from}-{to}.ring"))
    }

    /// Install/remove the chaos-harness fault hook for frames this node
    /// sends (zero-cost when off; verdicts match the TCP path exactly).
    pub fn set_fault_hook(&self, hook: Option<Arc<dyn FaultHook>>) {
        self.faults.arm(hook);
    }

    /// (hits, misses) of the node's buffer pool.
    pub fn pool_stats(&self) -> (u64, u64) {
        self.pool.stats()
    }

    /// Pre-open the consumer half of the link from `peer`, so `recv_any`
    /// can see its frames before the first selective receive targets it.
    /// Either side may create the ring file; first toucher initialises.
    pub fn ensure_link_from(&mut self, peer: NodeId) -> Result<()> {
        if !self.inn.contains_key(&peer) {
            let link = InLink::open(&self.link_path(peer, self.id), self.ring_cap)?;
            self.inn.insert(peer, link);
        }
        Ok(())
    }

    fn out_link(&mut self, to: NodeId) -> Result<&mut OutLink> {
        if !self.out.contains_key(&to) {
            let link = OutLink::open(&self.link_path(self.id, to), self.ring_cap)?;
            self.out.insert(to, link);
        }
        Ok(self.out.get_mut(&to).expect("inserted above"))
    }

    /// Write one `[len][tag][payload]` frame (streamed; any size).
    fn write_frame(&mut self, to: NodeId, tag: u32, payload: &[u8]) -> Result<()> {
        let deadline = Instant::now() + SEND_STALL;
        let mut head = [0u8; 8];
        head[..4].copy_from_slice(&(payload.len() as u32).to_le_bytes());
        head[4..8].copy_from_slice(&tag.to_le_bytes());
        let link = self.out_link(to)?;
        link.write_bytes(&head, to, deadline)?;
        link.write_bytes(payload, to, deadline)
    }

    fn send_slice(&mut self, to: NodeId, tag: u32, payload: &[u8]) -> Result<()> {
        if 8 + payload.len() > crate::wire::MAX_FRAME {
            return Err(NetError::Io(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("frame too large: {} bytes", payload.len()),
            )));
        }
        match self.faults.fate(self.id, to, tag) {
            FrameFate::Deliver => {}
            FrameFate::Drop => return Ok(()),
            FrameFate::Duplicate => self.write_frame(to, tag, payload)?,
            FrameFate::Delay(d) => std::thread::sleep(d),
        }
        self.write_frame(to, tag, payload)
    }

    /// Pull the next complete frame from `from`'s ring (pending-queue
    /// misses only), respecting `deadline`.
    fn pull_from(&mut self, from: NodeId, deadline: Instant) -> Result<(u32, Vec<u8>)> {
        self.ensure_link_from(from)?;
        let link = self.inn.get_mut(&from).expect("ensured above");
        link.read_frame(&mut self.pool, deadline)
    }
}

#[cfg(unix)]
impl Drop for ShmNode {
    fn drop(&mut self) {
        // unlink every ring file this node touched (idempotent: the
        // other side's unlink of the same file just ENOENTs) and try to
        // remove the namespace dir once it empties
        let to_ids: Vec<NodeId> = self.out.keys().copied().collect();
        for to in to_ids {
            let _ = std::fs::remove_file(self.link_path(self.id, to));
        }
        let from_ids: Vec<NodeId> = self.inn.keys().copied().collect();
        for from in from_ids {
            let _ = std::fs::remove_file(self.link_path(from, self.id));
        }
        let _ = std::fs::remove_dir(&self.dir);
    }
}

#[cfg(unix)]
impl PointToPoint for ShmNode {
    fn id(&self) -> NodeId {
        self.id
    }

    fn send(&mut self, to: NodeId, tag: u32, payload: Vec<u8>) -> Result<()> {
        self.send_slice(to, tag, &payload)?;
        self.pool.put(payload);
        Ok(())
    }

    fn send_shared(&mut self, to: NodeId, tag: u32, payload: &Shared) -> Result<()> {
        // no intermediate serialisation: bytes go straight from the
        // shared buffer into the mapped ring
        self.send_slice(to, tag, payload)
    }

    fn recv_from(&mut self, from: NodeId, tag: u32, timeout: Duration) -> Result<Vec<u8>> {
        if let Some(b) = self.pending.pop_match(from, tag) {
            return Ok(b.into_vec());
        }
        let deadline = Instant::now() + timeout;
        loop {
            match self.pull_from(from, deadline) {
                Ok((ftag, payload)) if ftag == tag => return Ok(payload),
                Ok((ftag, payload)) => {
                    self.pending.push(Frame { from, tag: ftag, body: Body::Owned(payload) })
                }
                Err(NetError::Timeout { .. }) => {
                    return Err(NetError::Timeout { from: Some(from), tag: Some(tag) })
                }
                Err(e) => return Err(e),
            }
        }
    }

    fn recv_into(
        &mut self,
        from: NodeId,
        tag: u32,
        dst: &mut Vec<u8>,
        timeout: Duration,
    ) -> Result<usize> {
        let payload = self.recv_from(from, tag, timeout)?;
        dst.clear();
        dst.extend_from_slice(&payload);
        self.pool.put(payload);
        Ok(dst.len())
    }

    fn recv_any(&mut self, timeout: Duration) -> Result<Msg> {
        if let Some(f) = self.pending.pop_any() {
            return Ok(Msg { from: f.from, tag: f.tag, payload: f.body.into_vec() });
        }
        let deadline = Instant::now() + timeout;
        loop {
            // poll every linked ring without blocking; with several
            // producers there is no single futex word to park on
            let peers: Vec<NodeId> = self.inn.keys().copied().collect();
            for from in peers {
                let link = self.inn.get_mut(&from).expect("key from iteration");
                match link.read_frame(&mut self.pool, Instant::now()) {
                    Ok((tag, payload)) => return Ok(Msg { from, tag, payload }),
                    Err(NetError::Timeout { .. }) => {}
                    Err(e) => return Err(e),
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(NetError::Timeout { from: None, tag: None });
            }
            std::thread::sleep(PARK.min(deadline - now));
        }
    }

    fn take_buf(&mut self, cap: usize) -> Vec<u8> {
        self.pool.take(cap)
    }

    fn recycle(&mut self, spent: Vec<u8>) {
        self.pool.put(spent);
    }
}

/// Non-unix stub: shm is never negotiated ([`machine_identity`] needs
/// `/proc`/`/etc` or an env override, and [`MixedNode`] treats a failed
/// `start` as "TCP only"), but the type must exist for cross-platform
/// builds.
#[cfg(not(unix))]
pub struct ShmNode;

#[cfg(not(unix))]
impl ShmNode {
    pub fn start(_id: NodeId, _ns: &str) -> Result<ShmNode> {
        Err(NetError::Io(std::io::Error::new(
            std::io::ErrorKind::Unsupported,
            "shm transport requires a unix platform",
        )))
    }

    pub fn set_fault_hook(&self, _hook: Option<Arc<dyn FaultHook>>) {}

    pub fn ensure_link_from(&mut self, _peer: NodeId) -> Result<()> {
        Ok(())
    }
}

#[cfg(not(unix))]
impl PointToPoint for ShmNode {
    fn id(&self) -> NodeId {
        0
    }
    fn send(&mut self, to: NodeId, _tag: u32, _payload: Vec<u8>) -> Result<()> {
        Err(NetError::UnknownPeer(to))
    }
    fn recv_from(&mut self, from: NodeId, tag: u32, _timeout: Duration) -> Result<Vec<u8>> {
        Err(NetError::Timeout { from: Some(from), tag: Some(tag) })
    }
    fn recv_any(&mut self, _timeout: Duration) -> Result<Msg> {
        Err(NetError::Timeout { from: None, tag: None })
    }
}

// ---------------------------------------------------------------------------
// MixedNode: per-peer shm/TCP routing
// ---------------------------------------------------------------------------

/// Slice of the receive timeout spent inside the TCP mailbox per probe
/// round when shm links are also live (only `recv_any` needs to
/// interleave — selective receives route to exactly one transport).
const MIX_SLICE: Duration = Duration::from_millis(1);

/// The negotiated per-link data plane: frames to a peer whose
/// machine-identity digest equals ours ride the shm rings, everything
/// else rides TCP. The routing decision is a pure function of the two
/// digests (carried in `Peers`), so both ends of every link agree on the
/// transport without any per-link handshake bytes.
pub struct MixedNode {
    tcp: TcpNode,
    shm: Option<ShmNode>,
    my_digest: u64,
    peer_digests: Arc<Mutex<HashMap<NodeId, u64>>>,
}

impl MixedNode {
    /// Start the TCP half immediately; attach the shm half only when
    /// this process has a usable machine identity and namespace (any shm
    /// setup failure degrades to TCP-only, never to an error).
    pub fn start(
        id: NodeId,
        directory: Arc<Mutex<HashMap<NodeId, String>>>,
        my_digest: u64,
        shm_ns: &str,
    ) -> Result<MixedNode> {
        let tcp = TcpNode::start(id, directory)?;
        let shm = if my_digest != 0 && !shm_ns.is_empty() {
            ShmNode::start(id, shm_ns).ok()
        } else {
            None
        };
        Ok(MixedNode {
            tcp,
            shm,
            my_digest,
            peer_digests: Arc::new(Mutex::new(HashMap::new())),
        })
    }

    /// The TCP listen address (what `Register` advertises).
    pub fn addr(&self) -> &str {
        &self.tcp.addr
    }

    /// Whether the shm half is live (namespace mapped, digest nonzero).
    pub fn shm_active(&self) -> bool {
        self.shm.is_some()
    }

    /// Handle to the digest directory, shared with whatever thread
    /// applies `Peers` updates.
    pub fn peer_digests(&self) -> Arc<Mutex<HashMap<NodeId, u64>>> {
        self.peer_digests.clone()
    }

    /// Record `peer`'s machine digest (from a `Peers` frame). Same-
    /// machine peers get their inbound ring linked eagerly so `recv_any`
    /// sees them.
    pub fn set_peer_digest(&mut self, peer: NodeId, digest: u64) {
        if peer == self.tcp.id() {
            return;
        }
        self.peer_digests.lock().unwrap().insert(peer, digest);
        if digest != 0 && digest == self.my_digest {
            if let Some(shm) = &mut self.shm {
                let _ = shm.ensure_link_from(peer);
            }
        }
    }

    /// Install/remove the chaos fault hook on BOTH halves, so verdicts
    /// are independent of which transport a link negotiated.
    pub fn set_fault_hook(&self, hook: Option<Arc<dyn FaultHook>>) {
        self.tcp.set_fault_hook(hook.clone());
        if let Some(shm) = &self.shm {
            shm.set_fault_hook(hook);
        }
    }

    /// Pure routing predicate: shm iff both digests are nonzero and
    /// equal. Both ends compute the same answer from the same `Peers`
    /// data, so a link's frames always travel (and are awaited) on
    /// exactly one transport.
    fn routes_shm(&self, peer: NodeId) -> bool {
        self.shm.is_some()
            && self.my_digest != 0
            && self.peer_digests.lock().unwrap().get(&peer) == Some(&self.my_digest)
    }
}

impl PointToPoint for MixedNode {
    fn id(&self) -> NodeId {
        self.tcp.id()
    }

    fn send(&mut self, to: NodeId, tag: u32, payload: Vec<u8>) -> Result<()> {
        if self.routes_shm(to) {
            self.shm.as_mut().expect("routes_shm checked").send(to, tag, payload)
        } else {
            self.tcp.send(to, tag, payload)
        }
    }

    fn send_shared(&mut self, to: NodeId, tag: u32, payload: &Shared) -> Result<()> {
        if self.routes_shm(to) {
            self.shm.as_mut().expect("routes_shm checked").send_shared(to, tag, payload)
        } else {
            self.tcp.send_shared(to, tag, payload)
        }
    }

    fn recv_from(&mut self, from: NodeId, tag: u32, timeout: Duration) -> Result<Vec<u8>> {
        if self.routes_shm(from) {
            self.shm.as_mut().expect("routes_shm checked").recv_from(from, tag, timeout)
        } else {
            self.tcp.recv_from(from, tag, timeout)
        }
    }

    fn recv_shared(&mut self, from: NodeId, tag: u32, timeout: Duration) -> Result<Shared> {
        if self.routes_shm(from) {
            self.shm.as_mut().expect("routes_shm checked").recv_shared(from, tag, timeout)
        } else {
            self.tcp.recv_shared(from, tag, timeout)
        }
    }

    fn recv_into(
        &mut self,
        from: NodeId,
        tag: u32,
        dst: &mut Vec<u8>,
        timeout: Duration,
    ) -> Result<usize> {
        if self.routes_shm(from) {
            self.shm.as_mut().expect("routes_shm checked").recv_into(from, tag, dst, timeout)
        } else {
            self.tcp.recv_into(from, tag, dst, timeout)
        }
    }

    fn recv_any(&mut self, timeout: Duration) -> Result<Msg> {
        match &mut self.shm {
            None => self.tcp.recv_any(timeout),
            Some(shm) => {
                // interleave short probes of both halves; selective
                // receives never pay this — only recv_any must multiplex
                let deadline = Instant::now() + timeout;
                loop {
                    match shm.recv_any(Duration::ZERO) {
                        Ok(m) => return Ok(m),
                        Err(NetError::Timeout { .. }) => {}
                        Err(e) => return Err(e),
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        return Err(NetError::Timeout { from: None, tag: None });
                    }
                    match self.tcp.recv_any(MIX_SLICE.min(deadline - now)) {
                        Ok(m) => return Ok(m),
                        Err(NetError::Timeout { .. }) => {}
                        Err(e) => return Err(e),
                    }
                }
            }
        }
    }

    fn take_buf(&mut self, cap: usize) -> Vec<u8> {
        match &mut self.shm {
            Some(shm) => shm.take_buf(cap),
            None => self.tcp.take_buf(cap),
        }
    }

    fn recycle(&mut self, spent: Vec<u8>) {
        match &mut self.shm {
            Some(shm) => shm.recycle(spent),
            None => self.tcp.recycle(spent),
        }
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64 as StdAtomicU64, Ordering as StdOrdering};

    const T: Duration = Duration::from_secs(5);

    /// Fresh namespace dir per test (pid + counter), so parallel test
    /// binaries and leftover runs can never cross-talk.
    fn test_dir() -> PathBuf {
        static SEQ: StdAtomicU64 = StdAtomicU64::new(0);
        let n = SEQ.fetch_add(1, StdOrdering::Relaxed);
        shm_base_dir().join(format!("edl-shmtest-{}-{n}", std::process::id()))
    }

    fn pair(cap: usize) -> (ShmNode, ShmNode) {
        let dir = test_dir();
        let a = ShmNode::start_with(1, dir.clone(), cap).unwrap();
        let b = ShmNode::start_with(2, dir, cap).unwrap();
        (a, b)
    }

    #[test]
    fn shm_roundtrip() {
        let (mut a, mut b) = pair(64 * 1024);
        a.send(2, 5, b"ping".to_vec()).unwrap();
        assert_eq!(b.recv_from(1, 5, T).unwrap(), b"ping".to_vec());
        b.send(1, 6, b"pong".to_vec()).unwrap();
        assert_eq!(a.recv_from(2, 6, T).unwrap(), b"pong".to_vec());
    }

    #[test]
    fn shm_selective_receive_buffers_others() {
        let (mut a, mut b) = pair(64 * 1024);
        a.send(2, 10, vec![10]).unwrap();
        a.send(2, 20, vec![20]).unwrap();
        assert_eq!(b.recv_from(1, 20, T).unwrap(), vec![20]);
        assert_eq!(b.recv_from(1, 10, T).unwrap(), vec![10]);
    }

    #[test]
    fn shm_zero_and_empty_payloads() {
        let (mut a, mut b) = pair(64 * 1024);
        a.send(2, 1, vec![]).unwrap();
        a.send(2, 2, vec![9]).unwrap();
        assert_eq!(b.recv_from(1, 1, T).unwrap(), Vec::<u8>::new());
        assert_eq!(b.recv_from(1, 2, T).unwrap(), vec![9]);
    }

    #[test]
    fn shm_timeout_on_silence() {
        let (_a, mut b) = pair(64 * 1024);
        let err = b.recv_from(1, 9, Duration::from_millis(20)).unwrap_err();
        assert!(matches!(err, NetError::Timeout { from: Some(1), tag: Some(9) }));
    }

    #[test]
    fn shm_payload_larger_than_ring_streams_through() {
        // 4 MiB payload through a 64 KiB ring: the frame must stream in
        // capacity-bounded chunks while the consumer drains concurrently
        let (a, b) = pair(64 * 1024);
        let big: Vec<u8> = (0..(4 << 20)).map(|i| (i * 31 % 251) as u8).collect();
        let want = big.clone();
        let (mut a, mut b) = (a, b);
        std::thread::scope(|s| {
            s.spawn(move || a.send(2, 1, big).unwrap());
            let got = b.recv_from(1, 1, Duration::from_secs(30)).unwrap();
            assert_eq!(got.len(), want.len());
            assert_eq!(got, want);
        });
    }

    #[test]
    fn shm_wraparound_many_frames() {
        // frames repeatedly wrap a tiny ring; framing must survive every
        // split position
        let (a, mut b) = pair(64 * 1024);
        let mut a = a;
        std::thread::scope(|s| {
            s.spawn(move || {
                for i in 0..500u32 {
                    let len = 100 + (i as usize * 37) % 5000;
                    a.send(2, i, vec![(i % 251) as u8; len]).unwrap();
                }
            });
            for i in 0..500u32 {
                let len = 100 + (i as usize * 37) % 5000;
                assert_eq!(b.recv_from(1, i, T).unwrap(), vec![(i % 251) as u8; len]);
            }
        });
    }

    #[test]
    fn shm_shared_send_and_recv_shared() {
        let (mut a, mut b) = pair(64 * 1024);
        let payload: Shared = Arc::new(vec![0xEE; 4096]);
        a.send_shared(2, 9, &payload).unwrap();
        let got = b.recv_shared(1, 9, T).unwrap();
        assert_eq!(*got, *payload);
    }

    #[test]
    fn shm_recv_into_reuses_capacity_and_pools() {
        let (mut a, mut b) = pair(64 * 1024);
        let mut dst = Vec::with_capacity(64);
        for i in 0..10u8 {
            a.send(2, 1, vec![i; 16]).unwrap();
            let n = b.recv_into(1, 1, &mut dst, T).unwrap();
            assert_eq!(n, 16);
            assert_eq!(dst, vec![i; 16]);
        }
        // transported buffers were pooled: a take_buf now hits
        let before = b.pool_stats().0;
        let buf = b.take_buf(16);
        assert!(buf.capacity() >= 16);
        assert_eq!(b.pool_stats().0, before + 1, "pooled receive buffer reused");
    }

    #[test]
    fn shm_recv_any_sees_all_linked_peers() {
        let dir = test_dir();
        let mut a = ShmNode::start_with(1, dir.clone(), 64 * 1024).unwrap();
        let mut b = ShmNode::start_with(2, dir.clone(), 64 * 1024).unwrap();
        let mut c = ShmNode::start_with(3, dir, 64 * 1024).unwrap();
        a.send(3, 1, vec![1]).unwrap();
        b.send(3, 2, vec![2]).unwrap();
        c.ensure_link_from(1).unwrap();
        c.ensure_link_from(2).unwrap();
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..2 {
            let m = c.recv_any(T).unwrap();
            seen.insert((m.from, m.tag, m.payload));
        }
        assert!(seen.contains(&(1, 1, vec![1])));
        assert!(seen.contains(&(2, 2, vec![2])));
    }

    /// Fixed fate for every frame matching (from, to) — mirrors the
    /// transport::tests hook so shm verdicts can be compared 1:1.
    struct FixedFate(NodeId, NodeId, FrameFate);

    impl FaultHook for FixedFate {
        fn fate(&self, from: NodeId, to: NodeId, _tag: u32) -> FrameFate {
            if from == self.0 && to == self.1 {
                self.2
            } else {
                FrameFate::Deliver
            }
        }
    }

    #[test]
    fn shm_fault_hook_drops_and_duplicates() {
        let (mut a, mut b) = pair(64 * 1024);
        a.set_fault_hook(Some(Arc::new(FixedFate(1, 2, FrameFate::Drop))));
        a.send(2, 1, vec![1]).unwrap(); // lost
        assert!(matches!(
            b.recv_from(1, 1, Duration::from_millis(30)),
            Err(NetError::Timeout { .. })
        ));
        a.set_fault_hook(Some(Arc::new(FixedFate(1, 2, FrameFate::Duplicate))));
        a.send(2, 2, vec![2]).unwrap(); // delivered twice
        assert_eq!(b.recv_from(1, 2, T).unwrap(), vec![2]);
        assert_eq!(b.recv_from(1, 2, T).unwrap(), vec![2]);
        a.set_fault_hook(None); // healed: exactly-once again
        a.send(2, 3, vec![3]).unwrap();
        assert_eq!(b.recv_from(1, 3, T).unwrap(), vec![3]);
        assert!(matches!(
            b.recv_from(1, 3, Duration::from_millis(30)),
            Err(NetError::Timeout { .. })
        ));
    }

    #[test]
    fn shm_drop_unlinks_ring_files() {
        let dir = test_dir();
        {
            let mut a = ShmNode::start_with(1, dir.clone(), 64 * 1024).unwrap();
            let mut b = ShmNode::start_with(2, dir.clone(), 64 * 1024).unwrap();
            a.send(2, 1, vec![1]).unwrap();
            assert_eq!(b.recv_from(1, 1, T).unwrap(), vec![1]);
            assert!(dir.join("link-1-2.ring").exists());
        }
        assert!(!dir.join("link-1-2.ring").exists(), "ring file leaked");
        assert!(!dir.exists(), "namespace dir leaked");
    }

    #[test]
    fn machine_identity_is_deterministic() {
        // only READS the ambient identity (env-mutating variants would
        // race the parallel test runner); determinism is the property
        // the negotiation protocol actually depends on
        let a = machine_identity();
        let b = machine_identity();
        assert_eq!(a, b, "machine identity must be deterministic within a process");
    }

    #[test]
    fn mixed_node_routes_by_digest() {
        // two MixedNodes sharing a digest route via shm; a third with a
        // different digest stays on TCP — and both sides agree
        let dir = Arc::new(Mutex::new(HashMap::new()));
        let ns = format!("edl-mixtest-{}-{}", std::process::id(), line!());
        let mut a = MixedNode::start(1, dir.clone(), 7, &ns).unwrap();
        let mut b = MixedNode::start(2, dir.clone(), 7, &ns).unwrap();
        let mut c = MixedNode::start(3, dir.clone(), 99, &ns).unwrap();
        for n in [&mut a, &mut b, &mut c] {
            n.set_peer_digest(1, 7);
            n.set_peer_digest(2, 7);
            n.set_peer_digest(3, 99);
        }
        assert!(a.routes_shm(2) && b.routes_shm(1));
        assert!(!a.routes_shm(3) && !c.routes_shm(1));
        a.send(2, 5, vec![5]).unwrap();
        assert_eq!(b.recv_from(1, 5, T).unwrap(), vec![5]);
        a.send(3, 6, vec![6]).unwrap();
        assert_eq!(c.recv_from(1, 6, T).unwrap(), vec![6]);
        b.send(1, 7, vec![7]).unwrap();
        c.send(1, 8, vec![8]).unwrap();
        // recv_any multiplexes both halves
        let mut tags = Vec::new();
        for _ in 0..2 {
            tags.push(a.recv_any(T).unwrap().tag);
        }
        tags.sort_unstable();
        assert_eq!(tags, vec![7, 8]);
    }

    #[test]
    fn mixed_node_digest_zero_is_tcp_only() {
        let dir = Arc::new(Mutex::new(HashMap::new()));
        let mut a = MixedNode::start(1, dir.clone(), 0, "never-created").unwrap();
        let mut b = MixedNode::start(2, dir.clone(), 0, "never-created").unwrap();
        assert!(!a.shm_active() && !b.shm_active());
        a.set_peer_digest(2, 0);
        b.set_peer_digest(1, 0);
        a.send(2, 1, vec![1]).unwrap();
        assert_eq!(b.recv_from(1, 1, T).unwrap(), vec![1]);
    }
}
