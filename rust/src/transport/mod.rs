//! Point-to-point transport used by the ring-allreduce engine and the
//! model-broadcast path. Two implementations share one trait:
//!
//!  * [`InProcHub`]/[`InProcEndpoint`] — lock-free-ish MPSC channels for
//!    workers living in one process (the elastic trainer's data plane; the
//!    stand-in for NCCL on the paper's NVLink/IB fabric),
//!  * [`TcpNode`] — framed TCP with `TCP_NODELAY` (§4.4 of the paper:
//!    Nagle's algorithm disabled on every coordination socket) for the
//!    multi-process deployment and the latency benchmark.
//!
//! Messages are tagged; `recv_from` performs selective receive with an
//! internal pending queue so ring neighbours and broadcast frames can
//! interleave safely on one endpoint.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub type NodeId = u32;

/// Well-known tags.
pub mod tag {
    /// ring allreduce reduce-scatter/allgather chunks (base; +step)
    pub const RING: u32 = 0x1000;
    /// model broadcast to joining workers
    pub const BCAST: u32 = 0x2000;
    /// RPC frames
    pub const RPC: u32 = 0x3000;
}

#[derive(Debug, Clone)]
pub struct Msg {
    pub from: NodeId,
    pub tag: u32,
    pub payload: Vec<u8>,
}

#[derive(Debug)]
pub enum NetError {
    UnknownPeer(NodeId),
    Timeout { from: Option<NodeId>, tag: Option<u32> },
    Closed,
    Io(std::io::Error),
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::UnknownPeer(id) => write!(f, "peer {id} unknown/disconnected"),
            NetError::Timeout { from, tag } => {
                write!(f, "receive timed out (from={from:?}, tag={tag:?})")
            }
            NetError::Closed => write!(f, "endpoint closed"),
            NetError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for NetError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            NetError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for NetError {
    fn from(e: std::io::Error) -> NetError {
        NetError::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, NetError>;

/// Point-to-point messaging with selective receive.
pub trait PointToPoint: Send {
    fn id(&self) -> NodeId;
    fn send(&mut self, to: NodeId, tag: u32, payload: Vec<u8>) -> Result<()>;
    /// Receive the next message matching (from, tag); other messages are
    /// buffered, not dropped.
    fn recv_from(&mut self, from: NodeId, tag: u32, timeout: Duration) -> Result<Vec<u8>>;
    /// Receive any message.
    fn recv_any(&mut self, timeout: Duration) -> Result<Msg>;
}

// ---------------------------------------------------------------------------
// in-process hub
// ---------------------------------------------------------------------------

/// Registry connecting in-process endpoints. Dynamic membership: endpoints
/// can join/leave at any time (that *is* the elasticity under test).
#[derive(Default)]
pub struct InProcHub {
    senders: Mutex<HashMap<NodeId, Sender<Msg>>>,
}

impl InProcHub {
    pub fn new() -> Arc<InProcHub> {
        Arc::new(InProcHub::default())
    }

    pub fn join(self: &Arc<Self>, id: NodeId) -> InProcEndpoint {
        let (tx, rx) = channel();
        let prev = self.senders.lock().unwrap().insert(id, tx);
        assert!(prev.is_none(), "node id {id} already joined");
        InProcEndpoint { id, hub: self.clone(), rx, pending: VecDeque::new() }
    }

    pub fn members(&self) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = self.senders.lock().unwrap().keys().copied().collect();
        v.sort_unstable();
        v
    }

    fn send(&self, msg: Msg, to: NodeId) -> Result<()> {
        let senders = self.senders.lock().unwrap();
        let tx = senders.get(&to).ok_or(NetError::UnknownPeer(to))?;
        tx.send(msg).map_err(|_| NetError::UnknownPeer(to))
    }

    fn leave(&self, id: NodeId) {
        self.senders.lock().unwrap().remove(&id);
    }
}

pub struct InProcEndpoint {
    id: NodeId,
    hub: Arc<InProcHub>,
    rx: Receiver<Msg>,
    pending: VecDeque<Msg>,
}

impl InProcEndpoint {
    /// Leave the hub (graceful exit); subsequent sends to this node fail.
    pub fn leave(self) {
        self.hub.leave(self.id);
    }
}

impl PointToPoint for InProcEndpoint {
    fn id(&self) -> NodeId {
        self.id
    }

    fn send(&mut self, to: NodeId, tag: u32, payload: Vec<u8>) -> Result<()> {
        self.hub.send(Msg { from: self.id, tag, payload }, to)
    }

    fn recv_from(&mut self, from: NodeId, tag: u32, timeout: Duration) -> Result<Vec<u8>> {
        if let Some(pos) = self.pending.iter().position(|m| m.from == from && m.tag == tag) {
            return Ok(self.pending.remove(pos).unwrap().payload);
        }
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(NetError::Timeout { from: Some(from), tag: Some(tag) });
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(m) if m.from == from && m.tag == tag => return Ok(m.payload),
                Ok(m) => self.pending.push_back(m),
                Err(RecvTimeoutError::Timeout) => {
                    return Err(NetError::Timeout { from: Some(from), tag: Some(tag) })
                }
                Err(RecvTimeoutError::Disconnected) => return Err(NetError::Closed),
            }
        }
    }

    fn recv_any(&mut self, timeout: Duration) -> Result<Msg> {
        if let Some(m) = self.pending.pop_front() {
            return Ok(m);
        }
        match self.rx.recv_timeout(timeout) {
            Ok(m) => Ok(m),
            Err(RecvTimeoutError::Timeout) => Err(NetError::Timeout { from: None, tag: None }),
            Err(RecvTimeoutError::Disconnected) => Err(NetError::Closed),
        }
    }
}

// ---------------------------------------------------------------------------
// TCP node
// ---------------------------------------------------------------------------

/// Framed-TCP endpoint: a listener thread accepts peer connections and
/// pumps decoded frames into the same selective-receive queue the in-proc
/// endpoint uses. Outbound connections are cached per peer.
pub struct TcpNode {
    id: NodeId,
    pub addr: String,
    rx: Receiver<Msg>,
    pending: VecDeque<Msg>,
    outbound: HashMap<NodeId, std::net::TcpStream>,
    directory: Arc<Mutex<HashMap<NodeId, String>>>,
    stop: Arc<std::sync::atomic::AtomicBool>,
}

impl TcpNode {
    pub fn start(id: NodeId, directory: Arc<Mutex<HashMap<NodeId, String>>>) -> Result<TcpNode> {
        let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        directory.lock().unwrap().insert(id, addr.clone());
        let (tx, rx) = channel::<Msg>();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));

        let stop2 = stop.clone();
        listener.set_nonblocking(true)?;
        std::thread::spawn(move || {
            while !stop2.load(std::sync::atomic::Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let tx = tx.clone();
                        std::thread::spawn(move || {
                            let _ = stream.set_nodelay(true);
                            let mut reader = std::io::BufReader::new(stream);
                            loop {
                                let frame = match crate::wire::read_frame(&mut reader) {
                                    Ok(f) => f,
                                    Err(_) => break,
                                };
                                let mut d = crate::wire::Dec::new(&frame);
                                let from = match d.u32() {
                                    Ok(f) => f,
                                    Err(_) => break,
                                };
                                let tag = match d.u32() {
                                    Ok(t) => t,
                                    Err(_) => break,
                                };
                                let payload = frame[8..].to_vec();
                                if tx.send(Msg { from, tag, payload }).is_err() {
                                    break;
                                }
                            }
                        });
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
        });

        Ok(TcpNode { id, addr, rx, pending: VecDeque::new(), outbound: HashMap::new(), directory, stop })
    }

    fn stream_to(&mut self, to: NodeId) -> Result<&mut std::net::TcpStream> {
        if !self.outbound.contains_key(&to) {
            let addr = self
                .directory
                .lock()
                .unwrap()
                .get(&to)
                .cloned()
                .ok_or(NetError::UnknownPeer(to))?;
            let stream = std::net::TcpStream::connect(&addr)?;
            stream.set_nodelay(true)?; // §4.4
            self.outbound.insert(to, stream);
        }
        Ok(self.outbound.get_mut(&to).unwrap())
    }
}

impl Drop for TcpNode {
    fn drop(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::Relaxed);
        self.directory.lock().unwrap().remove(&self.id);
    }
}

impl PointToPoint for TcpNode {
    fn id(&self) -> NodeId {
        self.id
    }

    fn send(&mut self, to: NodeId, tag: u32, payload: Vec<u8>) -> Result<()> {
        let id = self.id;
        let stream = self.stream_to(to)?;
        let mut e = crate::wire::Enc::with_capacity(8 + payload.len());
        e.u32(id).u32(tag);
        let mut frame = e.into_bytes();
        frame.extend_from_slice(&payload);
        crate::wire::write_frame(stream, &frame).map_err(|e| match e {
            crate::wire::WireError::Io(io) => NetError::Io(io),
            _ => NetError::Closed,
        })
    }

    fn recv_from(&mut self, from: NodeId, tag: u32, timeout: Duration) -> Result<Vec<u8>> {
        if let Some(pos) = self.pending.iter().position(|m| m.from == from && m.tag == tag) {
            return Ok(self.pending.remove(pos).unwrap().payload);
        }
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(NetError::Timeout { from: Some(from), tag: Some(tag) });
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(m) if m.from == from && m.tag == tag => return Ok(m.payload),
                Ok(m) => self.pending.push_back(m),
                Err(RecvTimeoutError::Timeout) => {
                    return Err(NetError::Timeout { from: Some(from), tag: Some(tag) })
                }
                Err(RecvTimeoutError::Disconnected) => return Err(NetError::Closed),
            }
        }
    }

    fn recv_any(&mut self, timeout: Duration) -> Result<Msg> {
        if let Some(m) = self.pending.pop_front() {
            return Ok(m);
        }
        match self.rx.recv_timeout(timeout) {
            Ok(m) => Ok(m),
            Err(RecvTimeoutError::Timeout) => Err(NetError::Timeout { from: None, tag: None }),
            Err(RecvTimeoutError::Disconnected) => Err(NetError::Closed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T: Duration = Duration::from_secs(5);

    #[test]
    fn inproc_basic_send_recv() {
        let hub = InProcHub::new();
        let mut a = hub.join(1);
        let mut b = hub.join(2);
        a.send(2, 7, vec![1, 2, 3]).unwrap();
        assert_eq!(b.recv_from(1, 7, T).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn inproc_selective_receive_buffers_others() {
        let hub = InProcHub::new();
        let mut a = hub.join(1);
        let mut b = hub.join(2);
        a.send(2, 10, vec![10]).unwrap();
        a.send(2, 20, vec![20]).unwrap();
        // ask for tag 20 first; tag 10 must not be lost
        assert_eq!(b.recv_from(1, 20, T).unwrap(), vec![20]);
        assert_eq!(b.recv_from(1, 10, T).unwrap(), vec![10]);
    }

    #[test]
    fn inproc_send_to_departed_peer_fails() {
        let hub = InProcHub::new();
        let mut a = hub.join(1);
        let b = hub.join(2);
        b.leave();
        assert!(matches!(a.send(2, 0, vec![]), Err(NetError::UnknownPeer(2))));
    }

    #[test]
    fn inproc_timeout() {
        let hub = InProcHub::new();
        let mut a = hub.join(1);
        let err = a.recv_from(9, 9, Duration::from_millis(20)).unwrap_err();
        assert!(matches!(err, NetError::Timeout { .. }));
    }

    #[test]
    fn inproc_members_sorted() {
        let hub = InProcHub::new();
        let _c = hub.join(3);
        let _a = hub.join(1);
        let _b = hub.join(2);
        assert_eq!(hub.members(), vec![1, 2, 3]);
    }

    #[test]
    fn tcp_roundtrip() {
        let dir = Arc::new(Mutex::new(HashMap::new()));
        let mut a = TcpNode::start(1, dir.clone()).unwrap();
        let mut b = TcpNode::start(2, dir.clone()).unwrap();
        a.send(2, 5, b"ping".to_vec()).unwrap();
        assert_eq!(b.recv_from(1, 5, T).unwrap(), b"ping".to_vec());
        b.send(1, 6, b"pong".to_vec()).unwrap();
        assert_eq!(a.recv_from(2, 6, T).unwrap(), b"pong".to_vec());
    }

    #[test]
    fn tcp_large_payload() {
        let dir = Arc::new(Mutex::new(HashMap::new()));
        let mut a = TcpNode::start(1, dir.clone()).unwrap();
        let mut b = TcpNode::start(2, dir.clone()).unwrap();
        let big = vec![0xABu8; 4 << 20];
        a.send(2, 1, big.clone()).unwrap();
        assert_eq!(b.recv_from(1, 1, T).unwrap(), big);
    }

    #[test]
    fn tcp_selective_receive() {
        let dir = Arc::new(Mutex::new(HashMap::new()));
        let mut a = TcpNode::start(1, dir.clone()).unwrap();
        let mut b = TcpNode::start(2, dir.clone()).unwrap();
        let mut c = TcpNode::start(3, dir.clone()).unwrap();
        a.send(3, 1, vec![1]).unwrap();
        b.send(3, 1, vec![2]).unwrap();
        // order of arrival from different peers is arbitrary; selective
        // receive must untangle it
        assert_eq!(c.recv_from(2, 1, T).unwrap(), vec![2]);
        assert_eq!(c.recv_from(1, 1, T).unwrap(), vec![1]);
    }
}
