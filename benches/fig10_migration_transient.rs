//! Fig 10 — (a) worker migration: consolidating a 2×4-GPU job onto one
//! 8-GPU machine raises throughput for big models (cross-machine ring →
//! NVLink ring); the migration itself uses ONE topology switch and stops
//! training for well under a second. (b) transient idle GPUs: Baseline /
//! stop-resume / EDL / Ideal with revocation every 4 minutes — EDL ≥97%
//! of Ideal, stop-resume BELOW Baseline.

use edl::coordinator::{ElasticTrainer, TrainerConfig};
use edl::data::corpus::Corpus;
use edl::gpu_sim::{edl_stop_time, stop_resume_overhead, throughput, Dnn, HwConfig};
use edl::util::json::{write_results, Json};
use edl::worker::SimBackend;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let hw = HwConfig::default();
    let mut out = Json::obj();

    // ---- (a) migration throughput gain -------------------------------------
    println!("== Fig 10a: migrate 2x4 GPUs -> 1x8 GPUs (consolidation) ==");
    println!("{:<12} {:>12} {:>12} {:>8}", "model", "before", "after", "gain");
    for model in [Dnn::VGG19, Dnn::VGG16, Dnn::ResNet152, Dnn::ResNet50] {
        let b = 32 * 8;
        // before: 8 GPUs across 2 machines -> cross-machine ring
        let before = {
            let mut hw2 = hw;
            hw2.gpus_per_machine = 4; // forces the cross-machine bandwidth
            throughput(model, 8, b, &hw2)
        };
        let after = throughput(model, 8, b, &hw); // one machine: NVLink
        let gain = after / before - 1.0;
        println!("{:<12} {:>12.1} {:>12.1} {:>7.1}%", model.spec().name, before, after, gain * 100.0);
        let mut r = Json::obj();
        r.set("before_sps", before).set("after_sps", after).set("gain_pct", gain * 100.0);
        out.set(&format!("migration_{}", model.spec().name), r);
    }
    let g_vgg = {
        let mut hw2 = hw;
        hw2.gpus_per_machine = 4;
        throughput(Dnn::VGG16, 8, 256, &hw) / throughput(Dnn::VGG16, 8, 256, &hw2) - 1.0
    };
    let g_res = {
        let mut hw2 = hw;
        hw2.gpus_per_machine = 4;
        throughput(Dnn::ResNet152, 8, 256, &hw) / throughput(Dnn::ResNet152, 8, 256, &hw2) - 1.0
    };
    assert!(g_vgg > g_res, "big models must gain more from consolidation");

    // live protocol: merged migration = one switch, sub-second stop
    println!("\n== Fig 10a (measured): merged migration on the live protocol ==");
    let backend = SimBackend { compute_ms: 30, ctx_prep_ms: 1_000, ..SimBackend::fast(1 << 16) };
    let corpus = Arc::new(Corpus::markov(256, 16, 1 << 20, 8));
    let cfg = TrainerConfig { agg_batch: 32, n_partitions: 4096, ..Default::default() };
    let t = ElasticTrainer::start(cfg, Arc::new(backend), corpus, 4);
    assert!(t.wait_step(10, Duration::from_secs(60)));
    let victim = *t.status().workers.first().unwrap();
    let r = t.migrate(vec![victim], vec!["target-machine".into()]);
    assert!(r.is_ok(), "{r:?}");
    assert_eq!(t.status().parallelism, 4);
    assert!(t.wait_step(t.status().step + 10, Duration::from_secs(60)));
    let report = t.stop();
    let commits = report.events.iter().filter(|e| e.what.contains("switch-committed")).count();
    println!("migration committed with {commits} topology switch(es) — paper: merged into ONE");
    assert_eq!(commits, 1);
    out.set("measured_migration_switches", commits);

    // ---- (b) transient idle GPUs -------------------------------------------
    println!("\n== Fig 10b: ResNet50, 4 persistent GPUs + k transient, 4-min revocation ==");
    let model = Dnn::ResNet50;
    let b = 32 * 4;
    let interval = 240.0; // 4 minutes
    println!("{:>10} {:>10} {:>12} {:>10} {:>10} {:>12}", "idle GPUs", "baseline", "stop-resume", "EDL", "ideal", "EDL/ideal");
    let mut rows = Json::Arr(vec![]);
    for k in [1u32, 2, 4] {
        let th4 = throughput(model, 4, b, &hw);
        let th4k = throughput(model, 4 + k, b, &hw);
        let baseline = th4;
        // ideal: train at 4+k for the whole interval, instant switches
        let ideal = th4k;
        // stop-resume: two restarts per interval (out then in), everyone
        // stopped for each restart
        let sr_overhead = stop_resume_overhead(model, 4 + k) + stop_resume_overhead(model, 4);
        let sr_train = (interval - sr_overhead).max(0.0);
        let sr = (th4k * sr_train) / interval;
        // EDL: joiners prep concurrently (existing workers keep training at
        // p=4 for ctx-prep ~21 s), brief broadcast stop, graceful exit
        let ctx = edl_scale_out_e2e_local(model);
        let stop = edl_stop_time(model);
        let edl = (th4 * ctx + th4k * (interval - ctx - stop)).max(0.0) / interval;
        println!(
            "{:>10} {:>10.1} {:>12.1} {:>10.1} {:>10.1} {:>11.1}%",
            k, baseline, sr, edl, ideal, edl / ideal * 100.0
        );
        assert!(edl / ideal > 0.9, "EDL must stay close to Ideal: {:.3}", edl / ideal);
        if k == 1 {
            // the paper's breakeven analysis (§2.2/§6.2) is for 1 idle GPU:
            // stop-resume needs ≥11.7-min intervals to break even
            assert!(sr < baseline, "stop-resume must underperform Baseline at 4-min intervals");
        }
        assert!(edl > sr, "EDL must dominate stop-resume");
        assert!(edl > baseline, "EDL must beat Baseline");
        let mut r = Json::obj();
        r.set("idle_gpus", k)
            .set("baseline", baseline)
            .set("stop_resume", sr)
            .set("edl", edl)
            .set("ideal", ideal)
            .set("edl_over_ideal", edl / ideal);
        rows.push(r);
    }
    out.set("transient", rows);
    println!("(paper: EDL ≥ 97% of Ideal; stop-resume below Baseline; breakeven ≈ 11.7 min)");

    // breakeven interval for stop-resume with 1 idle GPU (paper: 11.7 min)
    let th4 = throughput(model, 4, b, &hw);
    let th5 = throughput(model, 5, b, &hw);
    let ov = stop_resume_overhead(model, 5) + stop_resume_overhead(model, 4);
    // solve th5*(T-ov)/T = th4  =>  T = ov * th5 / (th5 - th4)
    let breakeven_min = ov * th5 / (th5 - th4) / 60.0;
    println!("stop-resume breakeven interval: {breakeven_min:.1} min (paper: 11.7 min)");
    assert!(breakeven_min > 6.0, "breakeven must far exceed typical transient intervals");
    out.set("sr_breakeven_min", breakeven_min);

    let path = write_results("fig10_migration_transient", &out).unwrap();
    println!("\nshape checks OK; results -> {}", path.display());
}

fn edl_scale_out_e2e_local(model: Dnn) -> f64 {
    edl::gpu_sim::edl_scale_out_e2e(model)
}
