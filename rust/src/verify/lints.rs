//! Token-level lints: determinism, panic-path, wire-coverage.
//!
//! Each lint takes `(path, source)` pairs rather than touching the
//! filesystem itself, so `verify_self.rs` can feed deliberately broken
//! fixture sources through the exact code path `edl verify` runs.

use super::lexer::{ident_like, lex, only_tests, strip_tests, Tok};
use super::{Diagnostic, SourceFile};

pub const LINT_DETERMINISM: &str = "determinism";
pub const LINT_PANIC: &str = "panic-path";
pub const LINT_WIRE: &str = "wire-coverage";

/// Modules that must stay pure: no wall-clock reads, no sleeps, no ambient
/// RNG. `coordinator::core` and the harness are the replay/model-checking
/// substrate; `sched`/`schedulers`/`data` feed deterministic simulations;
/// `verify` itself must be deterministic so CI diagnostics are stable.
const PURE_MODULES: &[&str] = &[
    "/coordinator/core.rs",
    "/harness/fault.rs",
    "/harness/chaos.rs",
    "/harness/mirrors.rs",
    "/sched/",
    "/schedulers/",
    "/data/",
    "/verify/",
    "/worker/vw.rs",
];

/// Banned token runs inside pure modules. Matched contiguously, so both
/// `Instant::now()` and `std::time::Instant::now()` trip the first entry.
const BANNED: &[(&[&str], &str)] = &[
    (&["Instant", ":", ":", "now"], "wall-clock read (Instant::now)"),
    (&["SystemTime", ":", ":", "now"], "wall-clock read (SystemTime::now)"),
    (&["thread", ":", ":", "sleep"], "real sleep (thread::sleep)"),
    (&["thread_rng"], "ambient RNG (thread_rng) — use util::rng::Pcg with an explicit seed"),
    (&["util", ":", ":", "now_ms"], "wall-clock read (util::now_ms)"),
];

fn is_pure_module(path: &str) -> bool {
    let p = path.replace('\\', "/");
    PURE_MODULES.iter().any(|m| p.contains(m))
}

fn run_matches(toks: &[Tok], at: usize, run: &[&str]) -> bool {
    toks.len() >= at + run.len() && run.iter().enumerate().all(|(k, w)| toks[at + k].text == *w)
}

/// Determinism lint: pure modules must not read wall clocks, sleep, or use
/// ambient RNG. Test modules are excluded (they may time things).
pub fn determinism(sources: &[SourceFile]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for sf in sources {
        if !is_pure_module(&sf.path) {
            continue;
        }
        let toks = strip_tests(&lex(&sf.text));
        for i in 0..toks.len() {
            for (run, why) in BANNED {
                if run_matches(&toks, i, run) {
                    out.push(Diagnostic {
                        lint: LINT_DETERMINISM.into(),
                        file: sf.path.clone(),
                        line: toks[i].line,
                        msg: format!(
                            "{why} in pure module — inject the value through the event/config \
                             surface instead [{}]",
                            run.join("")
                        ),
                    });
                }
            }
        }
    }
    out
}

/// Files whose non-test code forms the protocol handle paths: a panic here
/// takes down a leader or worker mid-protocol instead of surfacing a typed
/// error, so `unwrap`/`expect`/`panic!` are banned (assert!/debug_assert!
/// remain allowed — they state invariants, and the model checker exercises
/// them).
const PANIC_SCOPE: &[&str] = &[
    "/coordinator/core.rs",
    "/rpc/mod.rs",
    "/wire/mod.rs",
    "/api/mod.rs",
    "/master/proto.rs",
];

pub fn panic_paths(sources: &[SourceFile]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for sf in sources {
        let p = sf.path.replace('\\', "/");
        if !PANIC_SCOPE.iter().any(|m| p.contains(m)) {
            continue;
        }
        let lines: Vec<&str> = sf.text.lines().collect();
        let toks = strip_tests(&lex(&sf.text));
        for i in 0..toks.len() {
            let t = &toks[i];
            let hit = match t.text.as_str() {
                // `.unwrap()` / `.expect(..)` — exact ident match, so
                // unwrap_or / unwrap_or_else / map_or never trip it.
                "unwrap" | "expect" => i > 0 && toks[i - 1].text == ".",
                "panic" | "unreachable" | "todo" | "unimplemented" => {
                    i + 1 < toks.len() && toks[i + 1].text == "!"
                }
                _ => false,
            };
            if hit {
                let src_line = lines
                    .get(t.line as usize - 1)
                    .map(|l| l.trim())
                    .unwrap_or("");
                out.push(Diagnostic {
                    lint: LINT_PANIC.into(),
                    file: sf.path.clone(),
                    line: t.line,
                    msg: format!(
                        "`{}` on a protocol handle path — return a typed error instead: {src_line}",
                        t.text
                    ),
                });
            }
        }
    }
    out
}

/// Wire-coverage lint: every variant of these protocol enums must be
/// constructed by name (`Enum::Variant`) somewhere in a test — the
/// round-trip property tests are only exhaustive if nobody can add a
/// variant without also adding it to a test.
const WIRE_ENUMS: &[(&str, &str)] = &[
    ("/rpc/mod.rs", "ToLeader"),
    ("/rpc/mod.rs", "FromLeader"),
    ("/coordinator/mod.rs", "CtrlMsg"),
    ("/coordinator/mod.rs", "WorkerEvent"),
    ("/api/mod.rs", "Request"),
    ("/api/mod.rs", "Response"),
    ("/api/mod.rs", "ElasticError"),
    ("/master/proto.rs", "MasterRequest"),
    ("/master/proto.rs", "MasterResponse"),
];

/// Extract the variant names of `enum <name> { .. }` from a token stream.
/// Variant names are exactly the identifiers at brace-depth 1 of the enum
/// body (field names and types sit at depth ≥ 2; attribute contents sit
/// inside `[..]` which we also track).
pub fn enum_variants(toks: &[Tok], name: &str) -> Option<Vec<String>> {
    let mut i = 0;
    while i + 2 < toks.len() {
        if toks[i].text == "enum" && toks[i + 1].text == name {
            // skip generics etc. up to the opening brace
            let mut j = i + 2;
            while j < toks.len() && toks[j].text != "{" {
                j += 1;
            }
            let mut depth = 1i32;
            let mut variants = Vec::new();
            j += 1;
            while j < toks.len() && depth > 0 {
                match toks[j].text.as_str() {
                    "{" | "(" | "[" => {
                        depth += 1;
                    }
                    "}" | ")" | "]" => {
                        depth -= 1;
                    }
                    txt => {
                        if depth == 1 && ident_like(txt) {
                            variants.push(txt.to_string());
                        }
                    }
                }
                j += 1;
            }
            return Some(variants);
        }
        i += 1;
    }
    None
}

pub fn wire_coverage(sources: &[SourceFile]) -> Vec<Diagnostic> {
    wire_coverage_for(sources, WIRE_ENUMS)
}

/// Parameterised core so fixtures can check synthetic enums.
pub fn wire_coverage_for(sources: &[SourceFile], enums: &[(&str, &str)]) -> Vec<Diagnostic> {
    // Test corpus: every `mod tests` region in src files, plus everything in
    // integration-test files (path containing "/tests/").
    let mut corpus: Vec<Tok> = Vec::new();
    let mut lexed: Vec<(String, Vec<Tok>)> = Vec::new();
    for sf in sources {
        let toks = lex(&sf.text);
        let p = sf.path.replace('\\', "/");
        if p.contains("/tests/") {
            corpus.extend(toks.iter().cloned());
        } else {
            corpus.extend(only_tests(&toks));
        }
        lexed.push((p, toks));
    }
    let constructed = |enum_name: &str, variant: &str| -> bool {
        (0..corpus.len()).any(|i| {
            corpus[i].text == enum_name
                && run_matches(&corpus, i + 1, &[":", ":", variant])
        })
    };

    let mut out = Vec::new();
    for (file_suffix, enum_name) in enums {
        let Some((path, toks)) = lexed.iter().find(|(p, _)| p.contains(file_suffix)) else {
            out.push(Diagnostic {
                lint: LINT_WIRE.into(),
                file: (*file_suffix).into(),
                line: 0,
                msg: format!("protocol enum source {file_suffix} not found in scanned tree"),
            });
            continue;
        };
        let Some(variants) = enum_variants(toks, enum_name) else {
            out.push(Diagnostic {
                lint: LINT_WIRE.into(),
                file: path.clone(),
                line: 0,
                msg: format!("protocol enum {enum_name} not found in {path}"),
            });
            continue;
        };
        for v in variants {
            if !constructed(enum_name, &v) {
                out.push(Diagnostic {
                    lint: LINT_WIRE.into(),
                    file: path.clone(),
                    line: 0,
                    msg: format!(
                        "{enum_name}::{v} is never constructed in any test — add it to the \
                         round-trip property test"
                    ),
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sf(path: &str, text: &str) -> SourceFile {
        SourceFile { path: path.into(), text: text.into() }
    }

    #[test]
    fn determinism_flags_instant_now_in_pure_module() {
        let diags = determinism(&[sf(
            "rust/src/coordinator/core.rs",
            "fn t(&mut self) { let t0 = std::time::Instant::now(); }",
        )]);
        assert_eq!(diags.len(), 1);
        assert!(diags[0].msg.contains("Instant"));
    }

    #[test]
    fn determinism_ignores_shell_modules_and_tests() {
        // shell module: allowed to read clocks
        assert!(determinism(&[sf(
            "rust/src/transport/mod.rs",
            "fn t() { let t0 = Instant::now(); }",
        )])
        .is_empty());
        // test region in a pure module: allowed
        assert!(determinism(&[sf(
            "rust/src/coordinator/core.rs",
            "mod tests { fn t() { let t0 = Instant::now(); } }",
        )])
        .is_empty());
    }

    #[test]
    fn panic_lint_exact_ident_only() {
        let diags = panic_paths(&[sf(
            "rust/src/rpc/mod.rs",
            "fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) + x.unwrap() }",
        )]);
        assert_eq!(diags.len(), 1, "unwrap_or must not trip the lint");
        assert!(diags[0].msg.contains("`unwrap`"));
    }

    #[test]
    fn wire_coverage_reports_missing_variant() {
        let src = sf(
            "rust/src/rpc/mod.rs",
            "pub enum ToLeader { Hello { m: String }, Goodbye }\n\
             mod tests { fn t() { let _ = ToLeader::Hello { m: String::new() }; } }",
        );
        let diags = wire_coverage_for(&[src], &[("/rpc/mod.rs", "ToLeader")]);
        assert_eq!(diags.len(), 1);
        assert!(diags[0].msg.contains("ToLeader::Goodbye"), "{}", diags[0].msg);
    }

    #[test]
    fn enum_variant_extraction_skips_fields() {
        let toks = lex("pub enum E { A { x: Vec<u32>, y: B }, C(D, F), G }");
        assert_eq!(enum_variants(&toks, "E").unwrap(), vec!["A", "C", "G"]);
    }
}
