//! Descriptive statistics, percentiles, CDFs and histograms used by the
//! trace analysis and every benchmark harness.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile with linear interpolation; `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=100.0).contains(&q));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q / 100.0 * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Empirical CDF evaluated at `points`: fraction of xs <= point.
pub fn cdf_at(xs: &[f64], points: &[f64]) -> Vec<f64> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    points
        .iter()
        .map(|p| {
            let idx = v.partition_point(|x| x <= p);
            idx as f64 / v.len().max(1) as f64
        })
        .collect()
}

/// Histogram over log-spaced bins between lo and hi; returns (edges, counts).
pub fn log_histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> (Vec<f64>, Vec<usize>) {
    assert!(lo > 0.0 && hi > lo && bins > 0);
    let ratio = (hi / lo).powf(1.0 / bins as f64);
    let edges: Vec<f64> = (0..=bins).map(|i| lo * ratio.powi(i as i32)).collect();
    let mut counts = vec![0usize; bins];
    for &x in xs {
        if x < lo || x >= hi {
            continue;
        }
        let b = ((x / lo).ln() / ratio.ln()).floor() as usize;
        counts[b.min(bins - 1)] += 1;
    }
    (edges, counts)
}

/// Histogram over linear bins; returns (edges, counts).
pub fn linear_histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> (Vec<f64>, Vec<usize>) {
    assert!(hi > lo && bins > 0);
    let w = (hi - lo) / bins as f64;
    let edges: Vec<f64> = (0..=bins).map(|i| lo + w * i as f64).collect();
    let mut counts = vec![0usize; bins];
    for &x in xs {
        if x < lo || x >= hi {
            continue;
        }
        counts[(((x - lo) / w) as usize).min(bins - 1)] += 1;
    }
    (edges, counts)
}

/// Online time-weighted average of a step function (used for utilization
/// and efficiency time series in the cluster simulator).
#[derive(Default, Clone)]
pub struct TimeWeighted {
    last_t: f64,
    last_v: f64,
    acc: f64,
    total_t: f64,
    started: bool,
}

impl TimeWeighted {
    pub fn observe(&mut self, t: f64, v: f64) {
        if self.started {
            let dt = t - self.last_t;
            assert!(dt >= -1e-9, "time went backwards: {} -> {}", self.last_t, t);
            self.acc += self.last_v * dt.max(0.0);
            self.total_t += dt.max(0.0);
        }
        self.started = true;
        self.last_t = t;
        self.last_v = v;
    }

    pub fn finish(&mut self, t: f64) -> f64 {
        self.observe(t, self.last_v);
        if self.total_t == 0.0 {
            self.last_v
        } else {
            self.acc / self.total_t
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn median_odd() {
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
    }

    #[test]
    fn cdf_monotone() {
        let xs = [1.0, 2.0, 2.0, 10.0];
        let c = cdf_at(&xs, &[0.5, 1.0, 2.0, 5.0, 10.0]);
        assert_eq!(c, vec![0.0, 0.25, 0.75, 0.75, 1.0]);
    }

    #[test]
    fn log_hist_counts_everything_in_range() {
        let xs: Vec<f64> = (1..100).map(|i| i as f64).collect();
        let (_e, counts) = log_histogram(&xs, 1.0, 100.0, 10);
        assert_eq!(counts.iter().sum::<usize>(), xs.len());
    }

    #[test]
    fn linear_hist_bins() {
        let xs = [0.5, 1.5, 2.5];
        let (_e, counts) = linear_histogram(&xs, 0.0, 3.0, 3);
        assert_eq!(counts, vec![1, 1, 1]);
    }

    #[test]
    fn time_weighted_average() {
        let mut tw = TimeWeighted::default();
        tw.observe(0.0, 1.0); // 1.0 for t in [0, 2)
        tw.observe(2.0, 3.0); // 3.0 for t in [2, 4)
        let avg = tw.finish(4.0);
        assert!((avg - 2.0).abs() < 1e-12);
    }
}
