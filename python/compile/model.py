"""L2: decoder-only transformer LM over a *flat* f32 parameter vector.

This is the JAX compute graph that the Rust coordinator executes through
PJRT. Every exported entry point works on a single flat `f32[P]` parameter
vector so that the Rust side can treat parameters/gradients as one tensor —
exactly what the paper's ring allreduce synchronises (tensor fusion).

Entry points (lowered to HLO text by aot.py):

  init_params(seed)                   -> f32[P]
  grad_step(params, tokens)           -> (loss f32[], grads f32[P])
  apply_update(params, grads, lr)     -> f32[P]         (L1 sgd kernel)
  train_step(params, tokens, lr)      -> (loss, new_params)   (fused)
  fwd_loss(params, tokens)            -> f32[]          (eval only)

The compute hot-spots route through the L1 Pallas kernels:
`kernels.fused_linear.matmul_bias_act` (QKV/proj/MLP matmuls, fused
bias+GeLU epilogue) and `kernels.attention.causal_attention`. Backward
passes are provided via jax.custom_vjp so the backward matmuls *also* run
through the Pallas matmul kernel.
"""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import attention as attn_k
from .kernels import fused_linear as fl
from .kernels import sgd as sgd_k


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int

    @property
    def d_head(self):
        return self.d_model // self.n_heads


# Exported configurations. `tiny` is the pytest/integration config; `small`
# is the end-to-end example config (~6M params — the paper's V100 testbed is
# substituted by CPU PJRT, see DESIGN.md §1, so the e2e model is sized for
# CPU while keeping the full architecture).
CONFIGS = {
    "tiny": ModelConfig("tiny", vocab=256, d_model=64, n_layers=2, n_heads=4, d_ff=256, seq_len=64),
    "small": ModelConfig("small", vocab=2048, d_model=256, n_layers=4, n_heads=8, d_ff=1024, seq_len=128),
    "base": ModelConfig("base", vocab=8192, d_model=512, n_layers=8, n_heads=8, d_ff=2048, seq_len=256),
}


# ---------------------------------------------------------------------------
# flat parameter layout
# ---------------------------------------------------------------------------

def param_spec(cfg: ModelConfig):
    """Ordered (name, shape) list defining the flat layout."""
    D, F, V, S = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    spec = [("embed", (V, D)), ("pos", (S, D))]
    for l in range(cfg.n_layers):
        spec += [
            (f"l{l}.ln1_s", (D,)),
            (f"l{l}.ln1_b", (D,)),
            (f"l{l}.wqkv", (D, 3 * D)),
            (f"l{l}.bqkv", (3 * D,)),
            (f"l{l}.wo", (D, D)),
            (f"l{l}.bo", (D,)),
            (f"l{l}.ln2_s", (D,)),
            (f"l{l}.ln2_b", (D,)),
            (f"l{l}.w1", (D, F)),
            (f"l{l}.b1", (F,)),
            (f"l{l}.w2", (F, D)),
            (f"l{l}.b2", (D,)),
        ]
    spec += [("lnf_s", (D,)), ("lnf_b", (D,)), ("unembed", (D, V))]
    return spec


def param_count(cfg: ModelConfig) -> int:
    return int(sum(np.prod(s) for _, s in param_spec(cfg)))


def unflatten(cfg: ModelConfig, flat):
    out = {}
    off = 0
    for name, shape in param_spec(cfg):
        n = int(np.prod(shape))
        out[name] = flat[off : off + n].reshape(shape)
        off += n
    return out


def flatten(cfg: ModelConfig, params) -> jnp.ndarray:
    return jnp.concatenate([params[name].reshape(-1) for name, _ in param_spec(cfg)])


# ---------------------------------------------------------------------------
# differentiable wrappers around the L1 kernels
# ---------------------------------------------------------------------------

def _make_linear(act):
    """custom_vjp linear layer: fwd AND bwd matmuls run the Pallas kernel."""

    @jax.custom_vjp
    def linear(x, w, b):
        return fl.matmul_bias_act(x, w, b, act=act)

    def fwd(x, w, b):
        return linear(x, w, b), (x, w, b)

    def bwd(res, dy):
        x, w, b = res
        # recompute pre-activation; cheaper than saving it at train scale
        z = fl.matmul_bias_act(x, w, b, act="none")
        dz = dy * fl.act_grad(z, act)
        dx = fl.matmul(dz, w.T)
        dw = fl.matmul(x.T, dz)
        db = jnp.sum(dz, axis=0)
        return dx, dw, db

    linear.defvjp(fwd, bwd)
    return linear


_linear_none = _make_linear("none")
_linear_gelu = _make_linear("gelu")


@jax.custom_vjp
def _attention(q, k, v):
    return attn_k.causal_attention(q, k, v)


def _attention_fwd(q, k, v):
    return _attention(q, k, v), (q, k, v)


def _attention_bwd(res, do):
    # Recompute scores and softmax in jnp for the backward pass (the
    # standard recompute-bwd of flash attention); forward stays on the
    # Pallas kernel.
    q, k, v = res
    bh, s, dh = q.shape
    scale = 1.0 / (dh**0.5)
    scores = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))[None]
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    dv = jnp.einsum("bqk,bqd->bkd", p, do)
    dp = jnp.einsum("bqd,bkd->bqk", do, v)
    # softmax jacobian-vector product
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    ds = jnp.where(mask, ds, 0.0) * scale
    dq = jnp.einsum("bqk,bkd->bqd", ds, k)
    dk = jnp.einsum("bqk,bqd->bkd", ds, q)
    return dq, dk, dv


_attention.defvjp(_attention_fwd, _attention_bwd)


def _layernorm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


# ---------------------------------------------------------------------------
# model forward
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params, tokens):
    """tokens: i32 (B, S). Returns logits (B, S, V)."""
    B, S = tokens.shape
    D, H = cfg.d_model, cfg.n_heads
    dh = cfg.d_head

    h = params["embed"][tokens] + params["pos"][None, :S, :]
    for l in range(cfg.n_layers):
        p = lambda k: params[f"l{l}.{k}"]
        # --- attention block ---
        x = _layernorm(h, p("ln1_s"), p("ln1_b"))
        qkv = _linear_none(x.reshape(B * S, D), p("wqkv"), p("bqkv"))
        qkv = qkv.reshape(B, S, 3, H, dh)
        q = qkv[:, :, 0].transpose(0, 2, 1, 3).reshape(B * H, S, dh)
        k = qkv[:, :, 1].transpose(0, 2, 1, 3).reshape(B * H, S, dh)
        v = qkv[:, :, 2].transpose(0, 2, 1, 3).reshape(B * H, S, dh)
        o = _attention(q, k, v)
        o = o.reshape(B, H, S, dh).transpose(0, 2, 1, 3).reshape(B * S, D)
        h = h + _linear_none(o, p("wo"), p("bo")).reshape(B, S, D)
        # --- MLP block (fused GeLU epilogue in the Pallas kernel) ---
        x = _layernorm(h, p("ln2_s"), p("ln2_b"))
        y = _linear_gelu(x.reshape(B * S, D), p("w1"), p("b1"))
        h = h + _linear_none(y, p("w2"), p("b2")).reshape(B, S, D)

    h = _layernorm(h, params["lnf_s"], params["lnf_b"])
    # unembed has no bias; route through the custom-VJP linear so the
    # backward matmuls also use the Pallas kernel
    zero_b = jnp.zeros((cfg.vocab,), jnp.float32)
    logits = _linear_none(h.reshape(B * S, D), params["unembed"], zero_b)
    return logits.reshape(B, S, cfg.vocab)


def loss_fn(cfg: ModelConfig, flat_params, tokens):
    """Mean next-token cross entropy over positions 0..S-2."""
    params = unflatten(cfg, flat_params)
    logits = forward(cfg, params, tokens)[:, :-1, :]
    targets = tokens[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - tgt)


# ---------------------------------------------------------------------------
# exported entry points
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed):
    """seed: i32 scalar -> flat f32[P]. Scaled-normal init."""
    key = jax.random.PRNGKey(seed)
    spec = param_spec(cfg)
    keys = jax.random.split(key, len(spec))
    parts = []
    for (name, shape), k in zip(spec, keys):
        if name.endswith(("_s",)):  # layernorm scales
            parts.append(jnp.ones(shape, jnp.float32).reshape(-1))
        elif name.endswith(("_b", ".bqkv", ".bo", ".b1", ".b2")) or len(shape) == 1:
            parts.append(jnp.zeros(shape, jnp.float32).reshape(-1))
        else:
            fan_in = shape[0]
            std = 0.02 if name in ("embed", "pos") else fan_in**-0.5
            parts.append((jax.random.normal(k, shape, jnp.float32) * std).reshape(-1))
    return jnp.concatenate(parts)


def grad_step(cfg: ModelConfig, flat_params, tokens):
    """-> (loss f32[], grads f32[P]); grads are the mean over the local batch."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens))(flat_params)
    return loss, grads


def apply_update(flat_params, grads, lr):
    """SGD via the L1 fused-update kernel."""
    return sgd_k.sgd_update(flat_params, grads, lr)


def train_step(cfg: ModelConfig, flat_params, tokens, lr):
    loss, grads = grad_step(cfg, flat_params, tokens)
    return loss, apply_update(flat_params, grads, lr)


def fwd_loss(cfg: ModelConfig, flat_params, tokens):
    return loss_fn(cfg, flat_params, tokens)
