//! External coordination service substrate — the ZooKeeper/etcd equivalent
//! the paper's leader election depends on (§4.1), built from scratch.
//!
//! Provides exactly what the EDL protocol needs:
//!  * `compare_and_swap` transactions on string keys,
//!  * TTL **leases**: a value written with a lease expires unless refreshed,
//!  * expiry **watches**: registered waiters are notified when a key
//!    expires or is deleted, triggering re-election.
//!
//! Two deployments share one `KvCore`:
//!  * [`KvHandle`] — in-process handle (used by the elastic trainer and by
//!    deterministic tests, which drive time explicitly via `tick`),
//!  * [`KvServer`]/[`KvClient`] — TCP server speaking the wire protocol
//!    (used by the multi-process deployment and the leader-election
//!    latency benchmark).

mod server;

pub use server::{KvClient, KvServer};

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

/// Milliseconds since an arbitrary epoch. Callers supply time explicitly so
/// tests are deterministic; the TCP server uses wall-clock.
pub type Ms = u64;

#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    pub value: Vec<u8>,
    /// absolute expiry; None = persistent
    pub expires_at: Option<Ms>,
    /// monotonically increasing per-key version (CAS generation counter)
    pub version: u64,
}

#[derive(Default)]
struct State {
    map: HashMap<String, Entry>,
    /// bumped on every mutation; watchers wait on this
    epoch: u64,
}

/// Shared coordination-state core.
pub struct KvCore {
    state: Mutex<State>,
    changed: Condvar,
}

/// Result of a get: value + version, or None if absent/expired.
pub type GetResult = Option<(Vec<u8>, u64)>;

impl KvCore {
    pub fn new() -> Arc<KvCore> {
        Arc::new(KvCore { state: Mutex::new(State::default()), changed: Condvar::new() })
    }

    /// Remove expired entries as of `now`. Returns the expired keys.
    pub fn tick(&self, now: Ms) -> Vec<String> {
        let mut st = self.state.lock().unwrap();
        let expired: Vec<String> = st
            .map
            .iter()
            .filter(|(_, e)| e.expires_at.map(|t| t <= now).unwrap_or(false))
            .map(|(k, _)| k.clone())
            .collect();
        for k in &expired {
            st.map.remove(k);
        }
        if !expired.is_empty() {
            st.epoch += 1;
            self.changed.notify_all();
        }
        expired
    }

    pub fn get(&self, now: Ms, key: &str) -> GetResult {
        let st = self.state.lock().unwrap();
        st.map.get(key).and_then(|e| {
            if e.expires_at.map(|t| t <= now).unwrap_or(false) {
                None
            } else {
                Some((e.value.clone(), e.version))
            }
        })
    }

    /// The leader-election primitive: atomically set `key` to `new` iff the
    /// current value matches `expected` (None = key absent/expired).
    /// Returns Ok(new_version) on success, Err(current) on mismatch.
    pub fn compare_and_swap(
        &self,
        now: Ms,
        key: &str,
        expected: Option<&[u8]>,
        new: &[u8],
        ttl: Option<Ms>,
    ) -> Result<u64, GetResult> {
        let mut st = self.state.lock().unwrap();
        let current = st.map.get(key).and_then(|e| {
            if e.expires_at.map(|t| t <= now).unwrap_or(false) {
                None
            } else {
                Some((e.value.clone(), e.version))
            }
        });
        let matches = match (&current, expected) {
            (None, None) => true,
            (Some((v, _)), Some(exp)) => v.as_slice() == exp,
            _ => false,
        };
        if !matches {
            return Err(current);
        }
        let version = current.map(|(_, v)| v + 1).unwrap_or(1);
        st.map.insert(
            key.to_string(),
            Entry { value: new.to_vec(), expires_at: ttl.map(|t| now + t), version },
        );
        st.epoch += 1;
        self.changed.notify_all();
        Ok(version)
    }

    /// Unconditional put.
    pub fn put(&self, now: Ms, key: &str, value: &[u8], ttl: Option<Ms>) -> u64 {
        let mut st = self.state.lock().unwrap();
        let version = st.map.get(key).map(|e| e.version + 1).unwrap_or(1);
        st.map.insert(
            key.to_string(),
            Entry { value: value.to_vec(), expires_at: ttl.map(|t| now + t), version },
        );
        st.epoch += 1;
        self.changed.notify_all();
        version
    }

    /// Delete a key (leader erasing its address on graceful exit, §4.2).
    pub fn delete(&self, key: &str) -> bool {
        let mut st = self.state.lock().unwrap();
        let existed = st.map.remove(key).is_some();
        if existed {
            st.epoch += 1;
            self.changed.notify_all();
        }
        existed
    }

    /// Refresh a lease: extend expiry to now + ttl. Fails if the key is
    /// absent, expired, or holds a different value (lost leadership).
    pub fn refresh_lease(&self, now: Ms, key: &str, value: &[u8], ttl: Ms) -> bool {
        let mut st = self.state.lock().unwrap();
        match st.map.get_mut(key) {
            Some(e)
                if e.value == value
                    && !e.expires_at.map(|t| t <= now).unwrap_or(false) =>
            {
                e.expires_at = Some(now + ttl);
                true
            }
            _ => false,
        }
    }

    /// Block until the key's state differs from `last_version` (or absent
    /// when version given), or until `timeout_ms` of *real* time passes.
    /// Used by workers watching the leader key.
    pub fn wait_for_change(&self, key: &str, last_version: Option<u64>, timeout_ms: u64) -> bool {
        let deadline = std::time::Instant::now() + std::time::Duration::from_millis(timeout_ms);
        let mut st = self.state.lock().unwrap();
        loop {
            let cur = st.map.get(key).map(|e| e.version);
            if cur != last_version {
                return true;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (g, _t) = self.changed.wait_timeout(st, deadline - now).unwrap();
            st = g;
        }
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// In-process handle with a supplied clock function (wall or simulated).
#[derive(Clone)]
pub struct KvHandle {
    core: Arc<KvCore>,
    clock: Arc<dyn Fn() -> Ms + Send + Sync>,
}

impl KvHandle {
    pub fn new(core: Arc<KvCore>, clock: Arc<dyn Fn() -> Ms + Send + Sync>) -> Self {
        KvHandle { core, clock }
    }

    /// Wall-clock handle over a fresh core.
    pub fn wall() -> Self {
        KvHandle::new(KvCore::new(), Arc::new(|| crate::util::now_ms() as Ms))
    }

    pub fn core(&self) -> &Arc<KvCore> {
        &self.core
    }

    pub fn now(&self) -> Ms {
        (self.clock)()
    }

    pub fn get(&self, key: &str) -> GetResult {
        self.core.get(self.now(), key)
    }
    pub fn cas(&self, key: &str, expected: Option<&[u8]>, new: &[u8], ttl: Option<Ms>) -> Result<u64, GetResult> {
        self.core.compare_and_swap(self.now(), key, expected, new, ttl)
    }
    pub fn put(&self, key: &str, value: &[u8], ttl: Option<Ms>) -> u64 {
        self.core.put(self.now(), key, value, ttl)
    }
    pub fn delete(&self, key: &str) -> bool {
        self.core.delete(key)
    }
    pub fn refresh_lease(&self, key: &str, value: &[u8], ttl: Ms) -> bool {
        self.core.refresh_lease(self.now(), key, value, ttl)
    }
    pub fn tick(&self) -> Vec<String> {
        self.core.tick(self.now())
    }
}

// ---------------------------------------------------------------------------
// leader election on top of the KV (the §4.1 protocol)
// ---------------------------------------------------------------------------

/// Attempt leader election for `job` as candidate `my_addr`.
/// Returns the winning leader's address (possibly ours).
pub fn elect_leader(kv: &KvHandle, job: &str, my_addr: &str, lease_ttl: Ms) -> String {
    let key = format!("edl/leader/{job}");
    loop {
        match kv.get(&key) {
            Some((addr, _)) => return String::from_utf8_lossy(&addr).to_string(),
            None => {
                // void or expired: try to claim it
                match kv.cas(&key, None, my_addr.as_bytes(), Some(lease_ttl)) {
                    Ok(_) => return my_addr.to_string(),
                    Err(_) => continue, // someone else won; re-read
                }
            }
        }
    }
}

/// Leader-side lease refresh. Returns false if leadership was lost.
pub fn refresh_leadership(kv: &KvHandle, job: &str, my_addr: &str, lease_ttl: Ms) -> bool {
    kv.refresh_lease(&format!("edl/leader/{job}"), my_addr.as_bytes(), lease_ttl)
}

/// Leader-side resignation (graceful exit of the leader, §4.2).
pub fn resign_leadership(kv: &KvHandle, job: &str) {
    kv.delete(&format!("edl/leader/{job}"));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn sim_kv() -> (KvHandle, Arc<AtomicU64>) {
        let t = Arc::new(AtomicU64::new(0));
        let t2 = t.clone();
        let kv = KvHandle::new(KvCore::new(), Arc::new(move || t2.load(Ordering::SeqCst)));
        (kv, t)
    }

    #[test]
    fn cas_claims_empty_key_once() {
        let (kv, _t) = sim_kv();
        assert!(kv.cas("k", None, b"a", None).is_ok());
        let err = kv.cas("k", None, b"b", None).unwrap_err();
        assert_eq!(err.unwrap().0, b"a".to_vec());
        assert_eq!(kv.get("k").unwrap().0, b"a".to_vec());
    }

    #[test]
    fn cas_with_expected_value() {
        let (kv, _t) = sim_kv();
        kv.put("k", b"v1", None);
        assert!(kv.cas("k", Some(b"v0"), b"v2", None).is_err());
        assert!(kv.cas("k", Some(b"v1"), b"v2", None).is_ok());
        assert_eq!(kv.get("k").unwrap().0, b"v2".to_vec());
    }

    #[test]
    fn lease_expires_and_key_reclaimable() {
        let (kv, t) = sim_kv();
        kv.cas("k", None, b"a", Some(100)).unwrap();
        t.store(99, Ordering::SeqCst);
        assert!(kv.get("k").is_some());
        t.store(100, Ordering::SeqCst);
        assert!(kv.get("k").is_none(), "lease should have expired");
        // CAS with expected=None succeeds on the expired key
        assert!(kv.cas("k", None, b"b", Some(100)).is_ok());
        assert_eq!(kv.get("k").unwrap().0, b"b".to_vec());
    }

    #[test]
    fn refresh_extends_lease() {
        let (kv, t) = sim_kv();
        kv.cas("k", None, b"a", Some(100)).unwrap();
        t.store(90, Ordering::SeqCst);
        assert!(kv.refresh_lease("k", b"a", 100));
        t.store(150, Ordering::SeqCst);
        assert!(kv.get("k").is_some(), "refresh should extend to 190");
        t.store(190, Ordering::SeqCst);
        assert!(kv.get("k").is_none());
    }

    #[test]
    fn refresh_fails_for_wrong_holder() {
        let (kv, _t) = sim_kv();
        kv.cas("k", None, b"a", Some(100)).unwrap();
        assert!(!kv.refresh_lease("k", b"other", 100));
    }

    #[test]
    fn tick_removes_expired() {
        let (kv, t) = sim_kv();
        kv.put("a", b"1", Some(10));
        kv.put("b", b"2", None);
        t.store(20, Ordering::SeqCst);
        let mut expired = kv.tick();
        expired.sort();
        assert_eq!(expired, vec!["a".to_string()]);
        assert_eq!(kv.core().len(), 1);
    }

    #[test]
    fn version_increases_monotonically() {
        let (kv, _t) = sim_kv();
        let v1 = kv.put("k", b"1", None);
        let v2 = kv.put("k", b"2", None);
        let v3 = kv.cas("k", Some(b"2"), b"3", None).unwrap();
        assert!(v1 < v2 && v2 < v3);
    }

    #[test]
    fn election_single_winner_under_contention() {
        let (kv, _t) = sim_kv();
        let winners: Vec<String> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..32)
                .map(|i| {
                    let kv = kv.clone();
                    s.spawn(move || elect_leader(&kv, "job1", &format!("worker-{i}"), 1000))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let first = &winners[0];
        assert!(winners.iter().all(|w| w == first), "split brain: {winners:?}");
    }

    #[test]
    fn reelection_after_leader_resigns() {
        let (kv, _t) = sim_kv();
        let l1 = elect_leader(&kv, "j", "w1", 1000);
        assert_eq!(l1, "w1");
        resign_leadership(&kv, "j");
        let l2 = elect_leader(&kv, "j", "w2", 1000);
        assert_eq!(l2, "w2");
    }

    #[test]
    fn reelection_after_lease_expiry() {
        let (kv, t) = sim_kv();
        assert_eq!(elect_leader(&kv, "j", "w1", 100), "w1");
        // w1 crashes (no refresh); lease runs out
        t.store(101, Ordering::SeqCst);
        assert_eq!(elect_leader(&kv, "j", "w2", 100), "w2");
    }

    #[test]
    fn wait_for_change_sees_update() {
        let (kv, _t) = sim_kv();
        kv.put("k", b"1", None);
        let core = kv.core().clone();
        let waiter = std::thread::spawn(move || core.wait_for_change("k", Some(1), 5_000));
        std::thread::sleep(std::time::Duration::from_millis(20));
        kv.put("k", b"2", None);
        assert!(waiter.join().unwrap());
    }

    #[test]
    fn wait_for_change_times_out() {
        let (kv, _t) = sim_kv();
        kv.put("k", b"1", None);
        assert!(!kv.core().wait_for_change("k", Some(1), 50));
    }
}
