//! A minimal Rust token scanner for the `edl verify` lints.
//!
//! `syn` is unavailable in the offline registry, so the lints work on a
//! hand-rolled token stream instead of a real AST. The scanner only has to
//! be faithful about the things the lints key on:
//!
//!  * comments (line, nested block) and string/char literals are skipped —
//!    a `lock()` inside a doc comment must not trip the lock lint;
//!  * lifetimes (`'a`) are distinguished from char literals (`'x'`);
//!  * every token carries its 1-based source line for diagnostics;
//!  * identifiers, numbers and single-character punctuation come out as
//!    separate tokens, so lints match on contiguous token runs like
//!    `["Instant", ":", ":", "now"]`.
//!
//! This is NOT a general Rust lexer — it is exactly as much lexer as the
//! lints in this module need, with property tests pinning that contract.

/// One scanned token: its text and the 1-based line it starts on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tok {
    pub text: String,
    pub line: u32,
}

impl Tok {
    fn new(text: impl Into<String>, line: u32) -> Tok {
        Tok { text: text.into(), line }
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

/// True when the token text starts like an identifier (letter or `_`).
pub fn ident_like(t: &str) -> bool {
    t.chars().next().is_some_and(is_ident_start)
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Scan `src` into tokens, skipping whitespace, comments and the insides
/// of string/char literals (a literal leaves no token at all — the lints
/// only care about code).
pub fn lex(src: &str) -> Vec<Tok> {
    let b: Vec<char> = src.chars().collect();
    let mut out = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let n = b.len();

    // advance over one char, tracking newlines
    macro_rules! bump {
        () => {{
            if b[i] == '\n' {
                line += 1;
            }
            i += 1;
        }};
    }

    while i < n {
        let c = b[i];
        // -- whitespace ----------------------------------------------------
        if c.is_whitespace() {
            bump!();
            continue;
        }
        // -- comments ------------------------------------------------------
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                i += 1;
            }
            continue;
        }
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    bump!();
                }
            }
            continue;
        }
        // -- raw / byte strings -------------------------------------------
        if c == 'r' || c == 'b' {
            // r"..."  r#"..."#  br"..."  b"..."  b'..'
            let mut j = i;
            let mut is_byte = false;
            if b[j] == 'b' {
                is_byte = true;
                j += 1;
            }
            let mut raw = false;
            if j < n && b[j] == 'r' {
                raw = true;
                j += 1;
            }
            let mut hashes = 0usize;
            while raw && j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' && (raw || (is_byte && j == i + 1)) {
                // consume the whole (raw/byte) string literal
                i = j + 1;
                'outer: while i < n {
                    if b[i] == '\\' && !raw {
                        i += 2;
                        continue;
                    }
                    if b[i] == '"' {
                        let mut k = i + 1;
                        let mut seen = 0usize;
                        while seen < hashes && k < n && b[k] == '#' {
                            seen += 1;
                            k += 1;
                        }
                        if seen == hashes {
                            i = k;
                            break 'outer;
                        }
                    }
                    bump!();
                }
                continue;
            }
            if is_byte && j < n && b[j] == '\'' {
                // byte char b'x' / b'\n'
                i = j + 1;
                if i < n && b[i] == '\\' {
                    i += 1;
                }
                while i < n && b[i] != '\'' {
                    i += 1;
                }
                i += 1;
                continue;
            }
            // plain identifier starting with r/b — fall through
        }
        // -- plain strings -------------------------------------------------
        if c == '"' {
            bump!();
            while i < n && b[i] != '"' {
                if b[i] == '\\' {
                    i += 1;
                }
                if i < n {
                    bump!();
                }
            }
            i += 1;
            continue;
        }
        // -- char literal vs lifetime -------------------------------------
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // escaped char literal '\n'
                i += 2;
                while i < n && b[i] != '\'' {
                    i += 1;
                }
                i += 1;
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' {
                // one-char literal 'x'
                i += 3;
                continue;
            }
            // lifetime: consume the tick + ident, emit nothing
            i += 1;
            while i < n && is_ident_char(b[i]) {
                i += 1;
            }
            continue;
        }
        // -- identifiers ---------------------------------------------------
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_char(b[i]) {
                i += 1;
            }
            out.push(Tok::new(b[start..i].iter().collect::<String>(), line));
            continue;
        }
        // -- numbers (covers 0x7FFF, 1_000, 1e3, 0.5, suffixed ints) ------
        if c.is_ascii_digit() {
            let start = i;
            while i < n && (is_ident_char(b[i]) || b[i] == '.') {
                // a second '.' means a range expr like `0..n` — stop before
                if b[i] == '.' {
                    if i + 1 < n && b[i + 1] == '.' {
                        break;
                    }
                    if i + 1 < n && !b[i + 1].is_ascii_digit() {
                        break;
                    }
                }
                i += 1;
            }
            out.push(Tok::new(b[start..i].iter().collect::<String>(), line));
            continue;
        }
        // -- punctuation: one char per token ------------------------------
        out.push(Tok::new(c.to_string(), line));
        bump!();
    }
    out
}

/// The index ranges (over a `lex` result) covered by `mod tests { .. }`
/// blocks — lints exclude them (tests may unwrap and sleep at will).
pub fn test_regions(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let mut i = 0;
    while i + 2 < toks.len() {
        if toks[i].text == "mod" && toks[i + 1].text == "tests" && toks[i + 2].text == "{" {
            let mut depth = 1usize;
            let mut j = i + 3;
            while j < toks.len() && depth > 0 {
                match toks[j].text.as_str() {
                    "{" => depth += 1,
                    "}" => depth -= 1,
                    _ => {}
                }
                j += 1;
            }
            regions.push((i, j));
            i = j;
        } else {
            i += 1;
        }
    }
    regions
}

/// `toks` with every `mod tests` region removed.
pub fn strip_tests(toks: &[Tok]) -> Vec<Tok> {
    let regions = test_regions(toks);
    if regions.is_empty() {
        return toks.to_vec();
    }
    let mut out = Vec::with_capacity(toks.len());
    let mut r = 0usize;
    for (ix, t) in toks.iter().enumerate() {
        while r < regions.len() && ix >= regions[r].1 {
            r += 1;
        }
        if r < regions.len() && ix >= regions[r].0 {
            continue;
        }
        out.push(t.clone());
    }
    out
}

/// Only the `mod tests` regions of `toks` (for coverage-style lints).
pub fn only_tests(toks: &[Tok]) -> Vec<Tok> {
    let mut out = Vec::new();
    for (a, z) in test_regions(toks) {
        out.extend(toks[a..z].iter().cloned());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn idents_puncts_and_lines() {
        let toks = lex("fn a() {\n  b.lock();\n}");
        assert_eq!(
            toks.iter().map(|t| t.text.as_str()).collect::<Vec<_>>(),
            vec!["fn", "a", "(", ")", "{", "b", ".", "lock", "(", ")", ";", "}"]
        );
        assert_eq!(toks[5].line, 2, "b is on line 2");
    }

    #[test]
    fn comments_and_strings_leave_no_tokens() {
        assert_eq!(texts("// Instant::now()\nx"), vec!["x"]);
        assert_eq!(texts("/* a /* nested */ b */ y"), vec!["y"]);
        assert_eq!(texts(r#"let s = "Instant::now()";"#), vec!["let", "s", "=", ";"]);
        assert_eq!(texts("let s = r#\"unwrap()\"#;"), vec!["let", "s", "=", ";"]);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        assert_eq!(texts("fn f<'a>(x: &'a str) {}"),
            vec!["fn", "f", "<", ">", "(", "x", ":", "&", "str", ")", "{", "}"]);
        assert_eq!(texts("let c = 'x'; let d = '\\n';"),
            vec!["let", "c", "=", ";", "let", "d", "=", ";"]);
    }

    #[test]
    fn numbers_stay_single_tokens() {
        assert_eq!(texts("0x4000_0000 | (p << 29)"),
            vec!["0x4000_0000", "|", "(", "p", "<", "<", "29", ")"]);
        assert_eq!(texts("0..n"), vec!["0", ".", ".", "n"]);
        assert_eq!(texts("1.5e3 + 2"), vec!["1.5e3", "+", "2"]);
    }

    #[test]
    fn test_region_stripping() {
        let src = "fn a() { x.unwrap(); }\n#[cfg(test)]\nmod tests { fn t() { y.unwrap(); } }";
        let toks = lex(src);
        let stripped = strip_tests(&toks);
        let joined: Vec<&str> = stripped.iter().map(|t| t.text.as_str()).collect();
        assert!(joined.contains(&"x"));
        assert!(!joined.contains(&"y"), "test region must be stripped: {joined:?}");
        let only: Vec<String> = only_tests(&toks).into_iter().map(|t| t.text).collect();
        assert!(only.contains(&"y".to_string()));
        assert!(!only.contains(&"x".to_string()));
    }
}
