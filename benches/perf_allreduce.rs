//! L3 hot-path microbench: in-process ring allreduce throughput vs worker
//! count and tensor size — the per-mini-batch data-plane cost of the
//! trainer. Reports effective algorithm bandwidth
//! (2(N−1)/N × bytes / time) and per-call latency.

use edl::allreduce::ring_allreduce;
use edl::transport::InProcHub;
use edl::util::json::{write_results, Json};
use edl::util::stats;
use std::time::{Duration, Instant};

const T: Duration = Duration::from_secs(60);

fn bench(n_workers: usize, len: usize, iters: u64) -> (f64, f64) {
    let hub = InProcHub::new();
    let ring: Vec<u32> = (0..n_workers as u32).collect();
    let eps: Vec<_> = (0..n_workers).map(|i| hub.join(i as u32)).collect();
    let times: Vec<Vec<f64>> = std::thread::scope(|s| {
        eps.into_iter()
            .map(|mut ep| {
                let ring = ring.clone();
                s.spawn(move || {
                    let mut buf = vec![1.0f32; len];
                    let mut times = Vec::with_capacity(iters as usize);
                    for step in 0..iters {
                        let t0 = Instant::now();
                        ring_allreduce(&mut ep, &ring, step, &mut buf, 1.0, T).unwrap();
                        times.push(t0.elapsed().as_secs_f64());
                        // renormalise so values stay finite
                        for x in buf.iter_mut() {
                            *x = 1.0;
                        }
                    }
                    times
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    let per_call: Vec<f64> = times[0].clone();
    let mean_s = stats::mean(&per_call);
    let volume = 2.0 * (n_workers as f64 - 1.0) / n_workers as f64 * (len * 4) as f64;
    let bw_gbs = volume / mean_s / 1e9;
    (mean_s * 1e3, bw_gbs)
}

fn main() {
    println!("== ring allreduce (in-process data plane) ==");
    println!("{:>8} {:>12} {:>12} {:>14}", "workers", "elems", "ms/call", "algo GB/s");
    let mut out = Json::obj();
    let mut rows = Json::Arr(vec![]);
    for &n in &[2usize, 4, 8] {
        for &len in &[1_000usize, 100_000, 1_000_000, 4_250_000] {
            let iters = if len > 500_000 { 10 } else { 50 };
            let (ms, bw) = bench(n, len, iters);
            println!("{n:>8} {len:>12} {ms:>12.3} {bw:>14.2}");
            let mut r = Json::obj();
            r.set("workers", n).set("elems", len).set("ms_per_call", ms).set("algo_gbs", bw);
            rows.push(r);
        }
    }
    out.set("rows", rows);
    // the 4.25M-element case is the `small` model's full gradient (the e2e
    // per-step payload) — it must complete well under a second
    let (ms, _) = bench(4, 4_250_000, 5);
    assert!(ms < 1_000.0, "full-gradient allreduce too slow: {ms:.1}ms");
    out.set("small_model_grad_ms", ms);
    let path = write_results("perf_allreduce", &out).unwrap();
    println!("\nresults -> {}", path.display());
}
