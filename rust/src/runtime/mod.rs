//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! Python never runs here — the Rust binary is self-contained once
//! `make artifacts` has produced `artifacts/*.hlo.txt`. One compiled
//! executable is cached per (entry-point, batch-size) variant; the leader
//! picks the variant matching the current per-worker batch when the
//! parallelism changes (§3.1: aggregate batch stays constant).

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

// The PJRT bindings are only linkable where the `xla` crate (and its XLA
// C++ runtime) is available. The default build uses an API-compatible
// stub whose client construction fails at runtime, so the whole crate —
// trainer, simulator, schedulers, benches — builds and tests offline;
// `--features pjrt` (plus adding the `xla` dependency to Cargo.toml)
// switches in the real bindings without touching any call site.
#[cfg(feature = "pjrt")]
pub use ::xla;
#[cfg(not(feature = "pjrt"))]
#[path = "xla_stub.rs"]
pub mod xla;

/// Parsed `<cfg>.meta` file (flat "key value" lines written by aot.py).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub param_count: usize,
    pub vocab: u32,
    pub d_model: u32,
    pub n_layers: u32,
    pub n_heads: u32,
    pub d_ff: u32,
    pub seq_len: usize,
    pub eval_batch: u32,
    pub batches: Vec<u32>,
}

impl ModelMeta {
    pub fn parse(text: &str) -> Result<ModelMeta> {
        let mut kv = HashMap::new();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once(char::is_whitespace) {
                kv.insert(k.to_string(), v.trim().to_string());
            }
        }
        let get = |k: &str| kv.get(k).ok_or_else(|| anyhow!("meta missing key {k}"));
        Ok(ModelMeta {
            name: get("name")?.clone(),
            param_count: get("param_count")?.parse()?,
            vocab: get("vocab")?.parse()?,
            d_model: get("d_model")?.parse()?,
            n_layers: get("n_layers")?.parse()?,
            n_heads: get("n_heads")?.parse()?,
            d_ff: get("d_ff")?.parse()?,
            seq_len: get("seq_len")?.parse()?,
            eval_batch: get("eval_batch")?.parse()?,
            batches: get("batches")?
                .split(',')
                .map(|s| s.parse::<u32>().map_err(Into::into))
                .collect::<Result<_>>()?,
        })
    }

    /// Largest exported per-worker batch that fits `wanted`.
    /// With parallelism p and aggregate batch B, the leader asks for
    /// `pick_batch(B / p)`.
    pub fn pick_batch(&self, wanted: u32) -> Option<u32> {
        self.batches.iter().copied().filter(|&b| b <= wanted).max()
    }
}

/// A loaded model family: the PJRT client plus lazily compiled executables
/// for each artifact variant.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub meta: ModelMeta,
    exes: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

/// Wall-clock breakdown of an executable load (feeds the Fig 5 context-
/// preparation decomposition for the CPU substrate).
#[derive(Debug, Clone, Copy, Default)]
pub struct LoadTiming {
    pub parse_s: f64,
    pub compile_s: f64,
}

impl ModelMeta {
    /// Load and parse `<config>.meta` without creating a PJRT client.
    pub fn load(artifacts_dir: impl AsRef<Path>, config: &str) -> Result<ModelMeta> {
        let meta_path = artifacts_dir.as_ref().join(format!("{config}.meta"));
        let meta_text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?} — run `make artifacts` first"))?;
        ModelMeta::parse(&meta_text)
    }
}

impl Runtime {
    /// Open `artifacts/` for the named config (e.g. "tiny", "small").
    ///
    /// NOTE: the PJRT client is not `Send`/`Sync`; each worker thread owns
    /// its own `Runtime` (which is exactly the paper's per-worker
    /// execution-context model — context preparation happens per worker).
    pub fn open(artifacts_dir: impl AsRef<Path>, config: &str) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let meta = ModelMeta::load(&dir, config)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, dir, meta, exes: Mutex::new(HashMap::new()) })
    }

    /// Compile (or fetch cached) the artifact `<name>.hlo.txt`.
    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let (exe, _t) = self.load_with_timing(name)?;
        Ok(exe)
    }

    /// Compile an artifact and report parse/compile timing (used by the
    /// scaling-overhead benchmarks; this *is* the execution-context-
    /// preparation cost on the CPU substrate).
    pub fn load_with_timing(&self, name: &str) -> Result<(Arc<xla::PjRtLoadedExecutable>, LoadTiming)> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let t1 = std::time::Instant::now();
        let exe = Arc::new(
            self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))?,
        );
        let t2 = std::time::Instant::now();
        let timing = LoadTiming {
            parse_s: (t1 - t0).as_secs_f64(),
            compile_s: (t2 - t1).as_secs_f64(),
        };
        self.exes.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok((exe, timing))
    }

    /// Pre-compile every variant needed for parallelism in `1..=max_p`
    /// at aggregate batch `agg_batch` (context preparation, §4.2).
    pub fn warmup(&self, agg_batch: u32, max_p: u32) -> Result<()> {
        let cfg = self.meta.name.clone();
        self.executable(&format!("{cfg}_init"))?;
        self.executable(&format!("{cfg}_apply"))?;
        let mut wanted: Vec<u32> = Vec::new();
        for p in 1..=max_p {
            if let Some(b) = self.meta.pick_batch(agg_batch / p.max(1)) {
                if !wanted.contains(&b) {
                    wanted.push(b);
                }
            }
        }
        for b in wanted {
            self.executable(&format!("{cfg}_grad_b{b}"))?;
        }
        Ok(())
    }

    // -- typed entry points --------------------------------------------------

    fn run(&self, exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = exe.execute::<xla::Literal>(args).map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = out[0][0].to_literal_sync().map_err(|e| anyhow!("fetch: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }

    /// init(seed) -> flat params
    pub fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let exe = self.executable(&format!("{}_init", self.meta.name))?;
        let out = self.run(&exe, &[xla::Literal::scalar(seed)])?;
        let params = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("init returned empty tuple"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))?;
        if params.len() != self.meta.param_count {
            bail!("init produced {} params, meta says {}", params.len(), self.meta.param_count);
        }
        Ok(params)
    }

    fn tokens_literal(&self, tokens: &[i32], b: u32) -> Result<xla::Literal> {
        let s = self.meta.seq_len;
        if tokens.len() != b as usize * s {
            bail!("batch buffer is {} tokens, want {}x{}", tokens.len(), b, s);
        }
        xla::Literal::vec1(tokens)
            .reshape(&[b as i64, s as i64])
            .map_err(|e| anyhow!("{e:?}"))
    }

    /// grad_step(params, tokens[b,S]) -> (loss, grads)
    pub fn grad_step(&self, params: &[f32], tokens: &[i32], b: u32) -> Result<(f32, Vec<f32>)> {
        let exe = self.executable(&format!("{}_grad_b{}", self.meta.name, b))?;
        let p = xla::Literal::vec1(params);
        let t = self.tokens_literal(tokens, b)?;
        let out = self.run(&exe, &[p, t])?;
        let mut it = out.into_iter();
        let loss = it
            .next()
            .ok_or_else(|| anyhow!("missing loss"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))?[0];
        let grads = it
            .next()
            .ok_or_else(|| anyhow!("missing grads"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))?;
        Ok((loss, grads))
    }

    /// apply(params, grads, lr) -> new params (L1 fused SGD kernel)
    pub fn apply_update(&self, params: &[f32], grads: &[f32], lr: f32) -> Result<Vec<f32>> {
        let exe = self.executable(&format!("{}_apply", self.meta.name))?;
        let out = self.run(
            &exe,
            &[xla::Literal::vec1(params), xla::Literal::vec1(grads), xla::Literal::scalar(lr)],
        )?;
        out.into_iter()
            .next()
            .ok_or_else(|| anyhow!("apply returned empty tuple"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))
    }

    /// fused train_step(params, tokens, lr) -> (loss, new params)
    pub fn train_step(&self, params: &[f32], tokens: &[i32], b: u32, lr: f32) -> Result<(f32, Vec<f32>)> {
        let exe = self.executable(&format!("{}_train_b{}", self.meta.name, b))?;
        let p = xla::Literal::vec1(params);
        let t = self.tokens_literal(tokens, b)?;
        let out = self.run(&exe, &[p, t, xla::Literal::scalar(lr)])?;
        let mut it = out.into_iter();
        let loss = it
            .next()
            .ok_or_else(|| anyhow!("missing loss"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))?[0];
        let new_params = it
            .next()
            .ok_or_else(|| anyhow!("missing params"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))?;
        Ok((loss, new_params))
    }

    // -- device-resident fast path (§Perf) -----------------------------------
    //
    // Parameters live in a PJRT buffer across steps; only gradients cross
    // the host boundary (they must, for the Rust-side ring allreduce).
    // The `apply` executable is compiled without a tuple wrapper so its
    // output buffer feeds the next grad_step directly.

    /// Upload the flat parameter vector once.
    pub fn upload_params(&self, params: &[f32]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(params, &[params.len()], None)
            .map_err(|e| anyhow!("upload params: {e:?}"))
    }

    /// Download parameters (model broadcast to joiners / checkpointing).
    /// NOTE: goes through a Literal — this CPU PJRT build does not
    /// implement CopyRawToHost. Off the hot path (broadcast/checkpoint
    /// only), so the extra copy is irrelevant.
    pub fn download_params(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf.to_literal_sync().map_err(|e| anyhow!("download params: {e:?}"))?;
        let out = lit.to_vec::<f32>().map_err(|e| anyhow!("download params: {e:?}"))?;
        if out.len() != self.meta.param_count {
            bail!("downloaded {} params, expected {}", out.len(), self.meta.param_count);
        }
        Ok(out)
    }

    /// grad_step against device-resident params: only tokens go up and
    /// (loss, grads) come down.
    pub fn grad_step_dev(
        &self,
        params: &xla::PjRtBuffer,
        tokens: &[i32],
        b: u32,
    ) -> Result<(f32, Vec<f32>)> {
        let exe = self.executable(&format!("{}_grad_b{}", self.meta.name, b))?;
        let s = self.meta.seq_len;
        if tokens.len() != b as usize * s {
            bail!("batch buffer is {} tokens, want {}x{}", tokens.len(), b, s);
        }
        let tok_buf = self
            .client
            .buffer_from_host_buffer(tokens, &[b as usize, s], None)
            .map_err(|e| anyhow!("upload tokens: {e:?}"))?;
        let out = exe
            .execute_b(&[params, &tok_buf])
            .map_err(|e| anyhow!("execute_b: {e:?}"))?;
        let lit = out[0][0].to_literal_sync().map_err(|e| anyhow!("fetch: {e:?}"))?;
        let mut it = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?.into_iter();
        let loss = it
            .next()
            .ok_or_else(|| anyhow!("missing loss"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))?[0];
        let grads = it
            .next()
            .ok_or_else(|| anyhow!("missing grads"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))?;
        Ok((loss, grads))
    }

    /// SGD update on device: params buffer in, params buffer out (no host
    /// round-trip for the parameter vector).
    pub fn apply_update_dev(
        &self,
        params: &xla::PjRtBuffer,
        grads: &[f32],
        lr: f32,
    ) -> Result<xla::PjRtBuffer> {
        let exe = self.executable(&format!("{}_applyb", self.meta.name))?;
        let grads_buf = self
            .client
            .buffer_from_host_buffer(grads, &[grads.len()], None)
            .map_err(|e| anyhow!("upload grads: {e:?}"))?;
        let lr_buf = self
            .client
            .buffer_from_host_buffer(&[lr], &[], None)
            .map_err(|e| anyhow!("upload lr: {e:?}"))?;
        let mut out = exe
            .execute_b(&[params, &grads_buf, &lr_buf])
            .map_err(|e| anyhow!("execute_b: {e:?}"))?;
        out.pop()
            .and_then(|mut v| v.pop())
            .ok_or_else(|| anyhow!("applyb returned no buffer"))
    }

    /// eval loss on one batch (batch size = meta.eval_batch)
    pub fn eval_loss(&self, params: &[f32], tokens: &[i32]) -> Result<f32> {
        let b = self.meta.eval_batch;
        let exe = self.executable(&format!("{}_loss_b{}", self.meta.name, b))?;
        let p = xla::Literal::vec1(params);
        let t = self.tokens_literal(tokens, b)?;
        let out = self.run(&exe, &[p, t])?;
        Ok(out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("missing loss"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))?[0])
    }
}

/// Locate the artifacts directory: $EDL_ARTIFACTS, ./artifacts, or
/// ../artifacts (for tests running from target dirs).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("EDL_ARTIFACTS") {
        return PathBuf::from(p);
    }
    for cand in ["artifacts", "../artifacts", "../../artifacts"] {
        let p = PathBuf::from(cand);
        if p.is_dir() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parse_roundtrip() {
        let text = "name tiny\nparam_count 136960\nvocab 256\nd_model 64\nn_layers 2\nn_heads 4\nd_ff 256\nseq_len 64\neval_batch 1\nbatches 1,2,4,8,16\n";
        let m = ModelMeta::parse(text).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.param_count, 136960);
        assert_eq!(m.batches, vec![1, 2, 4, 8, 16]);
        assert_eq!(m.seq_len, 64);
    }

    #[test]
    fn meta_missing_key_rejected() {
        assert!(ModelMeta::parse("name tiny\n").is_err());
    }

    #[test]
    fn pick_batch_rounds_down() {
        let m = ModelMeta::parse(
            "name t\nparam_count 1\nvocab 2\nd_model 1\nn_layers 1\nn_heads 1\nd_ff 1\nseq_len 1\neval_batch 1\nbatches 1,2,4,8\n",
        )
        .unwrap();
        assert_eq!(m.pick_batch(8), Some(8));
        assert_eq!(m.pick_batch(7), Some(4));
        assert_eq!(m.pick_batch(3), Some(2));
        assert_eq!(m.pick_batch(1), Some(1));
        assert_eq!(m.pick_batch(0), None);
    }

    // Integration tests against real artifacts live in rust/tests/.
}
