//! Lock-order lint: build the (conservative) inter-procedural lock graph
//! and fail on cycles.
//!
//! Acquisition sites are `.lock()` / `.read()` / `.write()` calls with no
//! arguments (the no-argument rule keeps `io::Read::read(&mut buf)` out).
//! A lock's identity is the receiver chain (`self.state`, `knobs`, ...)
//! qualified by file, which merges same-named fields of different structs
//! in one file — conservative, but cheap and allowlistable.
//!
//! Guard lifetimes follow the two Rust idioms that matter here:
//!  * `let g = x.lock()...;` — held to the end of the enclosing block;
//!  * `x.lock().unwrap().f();` — a temporary, dropped at the statement's
//!    semicolon.
//!
//! While any guard is held, acquiring another lock adds a directed edge.
//! Calls to same-file functions propagate: if `f` calls `g` while holding
//! `A`, every lock `g` (transitively) acquires is ordered after `A`.
//! A cycle in the resulting graph is a potential deadlock.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{lex, strip_tests, Tok};
use super::{Diagnostic, SourceFile};

pub const LINT_LOCKS: &str = "lock-order";

#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
struct Edge {
    from: String,
    to: String,
    file: String,
    line: u32,
}

#[derive(Debug, Default)]
struct FnFacts {
    /// locks this function acquires directly
    acquires: BTreeSet<String>,
    /// (callee, locks held at the call site)
    calls: Vec<(String, BTreeSet<String>)>,
    /// direct edges observed inside the body
    edges: Vec<Edge>,
}

/// One function body: tokens of the body plus the fn's name.
fn functions(toks: &[Tok]) -> Vec<(String, Vec<Tok>)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 1 < toks.len() {
        if toks[i].text == "fn" {
            let name = toks[i + 1].text.clone();
            let mut j = i + 2;
            // scan to the body's opening brace; a `;` first means a trait
            // method declaration with no body
            let mut body = None;
            while j < toks.len() {
                match toks[j].text.as_str() {
                    "{" => {
                        let start = j + 1;
                        let mut depth = 1i32;
                        j += 1;
                        while j < toks.len() && depth > 0 {
                            match toks[j].text.as_str() {
                                "{" => depth += 1,
                                "}" => depth -= 1,
                                _ => {}
                            }
                            j += 1;
                        }
                        body = Some(toks[start..j.saturating_sub(1)].to_vec());
                        break;
                    }
                    ";" => break,
                    _ => j += 1,
                }
            }
            if let Some(b) = body {
                out.push((name, b));
            }
            i = j;
        } else {
            i += 1;
        }
    }
    out
}

/// Walk back from the `.lock()` dot to collect the receiver chain
/// (`self.state`, `pool`, ...). Returns None when the receiver is a call
/// expression (e.g. `stdout().lock()`): those get per-site unique names.
fn receiver_chain(toks: &[Tok], dot_ix: usize) -> Option<String> {
    let mut parts: Vec<String> = Vec::new();
    let mut i = dot_ix; // index of the '.' before lock/read/write
    loop {
        if i == 0 {
            break;
        }
        let prev = &toks[i - 1];
        let c = prev.text.chars().next().unwrap_or(' ');
        if !(c.is_ascii_alphabetic() || c == '_') {
            // receiver is not a plain ident chain (call/paren/index result)
            if prev.text == ")" || prev.text == "]" {
                return None;
            }
            break;
        }
        parts.push(prev.text.clone());
        if i >= 2 && toks[i - 2].text == "." {
            i -= 2;
        } else {
            break;
        }
    }
    if parts.is_empty() {
        return None;
    }
    parts.reverse();
    Some(parts.join("."))
}

/// Is the statement containing token `ix` a `let` binding? Scan back to the
/// statement start (`;`, `{`, `}`) at the same brace depth.
fn is_let_bound(toks: &[Tok], ix: usize) -> bool {
    let mut depth = 0i32;
    let mut i = ix;
    while i > 0 {
        i -= 1;
        match toks[i].text.as_str() {
            ")" | "]" => depth += 1,
            "(" | "[" => {
                if depth == 0 {
                    // we are inside a call argument — keep walking out
                } else {
                    depth -= 1;
                }
            }
            ";" | "{" | "}" if depth == 0 => {
                return toks.get(i + 1).map(|t| t.text.as_str()) == Some("let");
            }
            _ => {}
        }
    }
    toks.first().map(|t| t.text.as_str()) == Some("let")
}

fn analyze_fn(file: &str, fn_name: &str, body: &[Tok], fn_names: &BTreeSet<String>) -> FnFacts {
    let mut facts = FnFacts::default();
    // held guards: (lock name, brace depth at acquisition, let-bound?)
    let mut held: Vec<(String, i32, bool)> = Vec::new();
    let mut depth = 0i32;
    let mut uniq = 0u32;

    for i in 0..body.len() {
        match body[i].text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                held.retain(|(_, d, _)| *d <= depth);
            }
            ";" => {
                // temporaries die at their statement's semicolon
                held.retain(|(_, d, let_bound)| *let_bound || *d != depth);
            }
            "lock" | "read" | "write" => {
                let is_acquire = i > 0
                    && body[i - 1].text == "."
                    && body.get(i + 1).map(|t| t.text.as_str()) == Some("(")
                    && body.get(i + 2).map(|t| t.text.as_str()) == Some(")");
                if is_acquire {
                    let name = match receiver_chain(body, i - 1) {
                        Some(c) => format!("{file}::{c}"),
                        None => {
                            uniq += 1;
                            format!("{file}::{fn_name}::<expr#{uniq}>")
                        }
                    };
                    for (h, _, _) in &held {
                        if *h != name {
                            facts.edges.push(Edge {
                                from: h.clone(),
                                to: name.clone(),
                                file: file.to_string(),
                                line: body[i].line,
                            });
                        }
                    }
                    facts.acquires.insert(name.clone());
                    held.push((name, depth, is_let_bound(body, i)));
                }
            }
            t => {
                // same-file call with locks held: `foo(..)` or `.foo(..)`
                if !held.is_empty()
                    && fn_names.contains(t)
                    && body.get(i + 1).map(|x| x.text.as_str()) == Some("(")
                    && t != fn_name
                {
                    let held_set: BTreeSet<String> =
                        held.iter().map(|(h, _, _)| h.clone()).collect();
                    facts.calls.push((t.to_string(), held_set));
                }
            }
        }
    }
    facts
}

/// Run the lint over all sources; returns one diagnostic per distinct cycle
/// entry point.
pub fn lock_order(sources: &[SourceFile]) -> Vec<Diagnostic> {
    // per-file function analysis
    let mut all_facts: BTreeMap<String, FnFacts> = BTreeMap::new(); // "file::fn"
    let mut edges: Vec<Edge> = Vec::new();
    for sf in sources {
        let p = sf.path.replace('\\', "/");
        if p.contains("/tests/") {
            continue;
        }
        let toks = strip_tests(&lex(&sf.text));
        let fns = functions(&toks);
        let names: BTreeSet<String> = fns.iter().map(|(n, _)| n.clone()).collect();
        for (name, body) in &fns {
            let facts = analyze_fn(&p, name, body, &names);
            edges.extend(facts.edges.iter().cloned());
            all_facts.insert(format!("{p}::{name}"), facts);
        }
    }

    // transitive lock sets per function (fixpoint over same-file call graph)
    let mut trans: BTreeMap<String, BTreeSet<String>> = all_facts
        .iter()
        .map(|(k, f)| (k.clone(), f.acquires.clone()))
        .collect();
    loop {
        let mut changed = false;
        for (key, facts) in &all_facts {
            let file = key.rsplit_once("::").map(|(f, _)| f).unwrap_or("");
            let mut add = BTreeSet::new();
            for (callee, _) in &facts.calls {
                if let Some(t) = trans.get(&format!("{file}::{callee}")) {
                    add.extend(t.iter().cloned());
                }
            }
            let mine = trans.get_mut(key).expect("own entry");
            for a in add {
                changed |= mine.insert(a);
            }
        }
        if !changed {
            break;
        }
    }

    // inter-procedural edges: held locks at a call site order before every
    // lock the callee transitively acquires
    for (key, facts) in &all_facts {
        let file = key.rsplit_once("::").map(|(f, _)| f).unwrap_or("");
        for (callee, held) in &facts.calls {
            if let Some(t) = trans.get(&format!("{file}::{callee}")) {
                for h in held {
                    for l in t {
                        if h != l {
                            edges.push(Edge {
                                from: h.clone(),
                                to: l.clone(),
                                file: file.to_string(),
                                line: 0,
                            });
                        }
                    }
                }
            }
        }
    }

    // cycle detection (iterative DFS, three colors)
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for e in &edges {
        adj.entry(&e.from).or_default().insert(&e.to);
    }
    let mut color: BTreeMap<&str, u8> = BTreeMap::new(); // 0 white 1 grey 2 black
    let mut out = Vec::new();
    let nodes: Vec<&str> = adj.keys().copied().collect();
    for start in nodes {
        if color.get(start).copied().unwrap_or(0) != 0 {
            continue;
        }
        // stack of (node, iterator position over its successors)
        let mut stack: Vec<(&str, Vec<&str>, usize)> = Vec::new();
        let succ = |n: &str| -> Vec<&str> {
            adj.get(n).map(|s| s.iter().copied().collect()).unwrap_or_default()
        };
        color.insert(start, 1);
        stack.push((start, succ(start), 0));
        while !stack.is_empty() {
            let top = stack.len() - 1;
            let node = stack[top].0;
            if stack[top].2 >= stack[top].1.len() {
                color.insert(node, 2);
                stack.pop();
                continue;
            }
            let next = stack[top].1[stack[top].2];
            stack[top].2 += 1;
            match color.get(next).copied().unwrap_or(0) {
                0 => {
                    color.insert(next, 1);
                    let s = succ(next);
                    stack.push((next, s, 0));
                }
                1 => {
                    // grey → cycle; reconstruct the path on the stack
                    let mut cyc: Vec<&str> =
                        stack.iter().map(|(n, _, _)| *n).collect();
                    if let Some(pos) = cyc.iter().position(|n| *n == next) {
                        cyc = cyc[pos..].to_vec();
                    }
                    cyc.push(next);
                    let site = edges
                        .iter()
                        .find(|e| e.from == node && e.to == next)
                        .cloned();
                    let (file, line) = site
                        .map(|e| (e.file, e.line))
                        .unwrap_or_else(|| ("<multiple>".into(), 0));
                    out.push(Diagnostic {
                        lint: LINT_LOCKS.into(),
                        file,
                        line,
                        msg: format!(
                            "lock-order cycle: {} — two threads taking these locks in \
                             opposite order deadlock",
                            cyc.join(" -> ")
                        ),
                    });
                }
                _ => {}
            }
        }
    }
    out.sort_by(|a, b| a.msg.cmp(&b.msg));
    out.dedup_by(|a, b| a.msg == b.msg);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sf(path: &str, text: &str) -> SourceFile {
        SourceFile { path: path.into(), text: text.into() }
    }

    #[test]
    fn nested_opposite_order_is_a_cycle() {
        let src = sf(
            "rust/src/x.rs",
            r#"
            fn ab(&self) {
                let g = self.a.lock().unwrap();
                let h = self.b.lock().unwrap();
            }
            fn ba(&self) {
                let g = self.b.lock().unwrap();
                let h = self.a.lock().unwrap();
            }
            "#,
        );
        let diags = lock_order(&[src]);
        assert_eq!(diags.len(), 1, "{diags:#?}");
        assert!(diags[0].msg.contains("cycle"), "{}", diags[0].msg);
    }

    #[test]
    fn sequential_block_scoped_guards_are_clean() {
        // the harness::fault idiom: guard dropped at block end before the
        // next lock is taken
        let src = sf(
            "rust/src/x.rs",
            r#"
            fn f(&self) {
                { let g = self.a.lock().unwrap(); g.touch(); }
                { let g = self.b.lock().unwrap(); g.touch(); }
            }
            fn g(&self) {
                { let g = self.b.lock().unwrap(); g.touch(); }
                { let g = self.a.lock().unwrap(); g.touch(); }
            }
            "#,
        );
        assert!(lock_order(&[src]).is_empty());
    }

    #[test]
    fn temporary_guard_dies_at_semicolon() {
        let src = sf(
            "rust/src/x.rs",
            r#"
            fn f(&self) {
                self.a.lock().unwrap().push(1);
                self.b.lock().unwrap().push(2);
            }
            fn g(&self) {
                self.b.lock().unwrap().push(1);
                self.a.lock().unwrap().push(2);
            }
            "#,
        );
        assert!(lock_order(&[src]).is_empty());
    }

    #[test]
    fn interprocedural_cycle_is_caught() {
        let src = sf(
            "rust/src/x.rs",
            r#"
            fn outer(&self) {
                let g = self.a.lock().unwrap();
                self.inner();
            }
            fn inner(&self) {
                let g = self.b.lock().unwrap();
            }
            fn other(&self) {
                let g = self.b.lock().unwrap();
                let h = self.a.lock().unwrap();
            }
            "#,
        );
        let diags = lock_order(&[src]);
        assert!(!diags.is_empty(), "expected inter-procedural cycle");
    }

    #[test]
    fn io_read_with_args_is_not_a_lock() {
        let src = sf(
            "rust/src/x.rs",
            r#"
            fn f(&self, s: &mut TcpStream) {
                let g = self.a.lock().unwrap();
                s.read(&mut buf).unwrap();
                s.write(&buf).unwrap();
            }
            "#,
        );
        assert!(lock_order(&[src]).is_empty());
    }
}
