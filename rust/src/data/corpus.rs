//! Synthetic training corpus with learnable structure.
//!
//! The paper trains on ImageNet-class datasets we don't have; the e2e
//! substitution (DESIGN.md §1) is a token corpus drawn from a seeded
//! order-1 Markov chain over the model's vocabulary — structured enough
//! that the transformer's loss drops well below the uniform baseline
//! within a few hundred steps, which is what the loss-curve experiment
//! needs to demonstrate.

use crate::util::rng::Pcg;

/// A corpus of `n_samples` sequences, each `seq_len` tokens.
pub struct Corpus {
    pub vocab: u32,
    pub seq_len: usize,
    pub n_samples: u64,
    tokens: Vec<u16>,
}

impl Corpus {
    /// Generate a Markov-chain corpus. Each vocabulary symbol has a sparse
    /// successor set (k likely successors), giving per-token entropy around
    /// log(k) — far below log(vocab) — so the model has signal to learn.
    pub fn markov(vocab: u32, seq_len: usize, n_samples: u64, seed: u64) -> Corpus {
        assert!(vocab >= 4 && vocab <= u16::MAX as u32 + 1);
        let mut rng = Pcg::seeded(seed);
        let k = 4usize; // successors per symbol
        // successor table: vocab x k
        let succ: Vec<u32> = (0..vocab as usize * k)
            .map(|_| rng.gen_range(vocab as u64) as u32)
            .collect();
        let total = n_samples as usize * seq_len;
        let mut tokens = Vec::with_capacity(total);
        let mut cur = rng.gen_range(vocab as u64) as u32;
        for _ in 0..total {
            tokens.push(cur as u16);
            // mostly follow the chain; occasionally jump (noise floor)
            cur = if rng.bool_with(0.95) {
                succ[cur as usize * k + rng.gen_range(k as u64) as usize]
            } else {
                rng.gen_range(vocab as u64) as u32
            };
        }
        Corpus { vocab, seq_len, n_samples, tokens }
    }

    /// Tokens of sample `i` as i32 (the dtype the HLO artifact expects).
    pub fn sample(&self, i: u64) -> Vec<i32> {
        let s = i as usize * self.seq_len;
        self.tokens[s..s + self.seq_len].iter().map(|&t| t as i32).collect()
    }

    /// Flatten samples [start, start+count) into one (count*seq_len) batch
    /// buffer, row-major — the layout `Literal::vec1(..).reshape([b, s])`
    /// expects.
    pub fn batch(&self, start: u64, count: u64) -> Vec<i32> {
        let mut out = Vec::with_capacity((count as usize) * self.seq_len);
        for i in start..start + count {
            let s = (i % self.n_samples) as usize * self.seq_len;
            out.extend(self.tokens[s..s + self.seq_len].iter().map(|&t| t as i32));
        }
        out
    }

    /// Gather an arbitrary list of sample indices into a batch buffer.
    pub fn gather(&self, indices: &[u64]) -> Vec<i32> {
        let mut out = Vec::with_capacity(indices.len() * self.seq_len);
        for &i in indices {
            let s = (i % self.n_samples) as usize * self.seq_len;
            out.extend(self.tokens[s..s + self.seq_len].iter().map(|&t| t as i32));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a = Corpus::markov(256, 16, 10, 42);
        let b = Corpus::markov(256, 16, 10, 42);
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn tokens_in_vocab() {
        let c = Corpus::markov(64, 8, 100, 1);
        assert!(c.tokens.iter().all(|&t| (t as u32) < 64));
    }

    #[test]
    fn batch_layout_row_major() {
        let c = Corpus::markov(256, 4, 10, 2);
        let b = c.batch(3, 2);
        assert_eq!(b.len(), 8);
        assert_eq!(&b[0..4], c.sample(3).as_slice());
        assert_eq!(&b[4..8], c.sample(4).as_slice());
    }

    #[test]
    fn markov_structure_lowers_entropy() {
        // successor distribution should be far more concentrated than
        // uniform: measure empirical bigram entropy vs uniform entropy
        let c = Corpus::markov(256, 64, 200, 3);
        let mut counts = std::collections::HashMap::<(u16, u16), usize>::new();
        for w in c.tokens.windows(2) {
            *counts.entry((w[0], w[1])).or_default() += 1;
        }
        let mut first = std::collections::HashMap::<u16, usize>::new();
        for w in c.tokens.windows(2) {
            *first.entry(w[0]).or_default() += 1;
        }
        let total2: f64 = counts.values().map(|&c| c as f64).sum();
        let _ = total2;
        // conditional entropy H(next | cur)
        let mut h = 0.0;
        let n: f64 = counts.values().map(|&c| c as f64).sum();
        for ((a, _b), &cnt) in &counts {
            let p_ab = cnt as f64 / n;
            let p_a = first[a] as f64 / n;
            h -= p_ab * (p_ab / p_a).ln();
        }
        let uniform = (256f64).ln();
        assert!(h < 0.6 * uniform, "conditional entropy {h:.2} vs uniform {uniform:.2}");
    }

    #[test]
    fn gather_wraps_modulo() {
        let c = Corpus::markov(256, 4, 5, 4);
        let g = c.gather(&[7]); // 7 % 5 == 2
        assert_eq!(g, c.sample(2));
    }
}
