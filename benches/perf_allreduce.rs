//! L3 hot-path microbench: ring allreduce throughput vs worker count and
//! tensor size — the per-mini-batch data-plane cost of the trainer.
//! Reports effective algorithm bandwidth (2(N−1)/N × bytes / time) and
//! per-call latency, for BOTH the pre-PR baseline (one whole chunk per
//! ring step, a fresh encode buffer per send, a fresh `Vec` per receive)
//! and the segment-pipelined, pooled data plane — and for a real TCP
//! ring, not just the in-process hub.
//!
//! Env knobs:
//!  * `EDL_BENCH_SMOKE=1`   — tiny sizes/iters for CI (no perf asserts)
//!  * `EDL_BENCH_BASELINE=1` — also write `BENCH_perf_allreduce.json`
//!    into the current directory (the committed trajectory baseline)

use edl::allreduce::{chunks, ring_allreduce, topo_allreduce};
use edl::transport::{InProcHub, MixedNode, PointToPoint, ShmNode, TcpNode};
use edl::util::json::{write_results, Json};
use edl::util::stats;
use edl::wire::{Dec, Enc};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const T: Duration = Duration::from_secs(60);

// ---------------------------------------------------------------------------
// pre-PR baseline, reproduced verbatim so old-vs-new runs on one machine
// ---------------------------------------------------------------------------

fn add_assign_from_payload(dst: &mut [f32], payload: &[u8]) {
    let mut d = Dec::new(payload);
    let n = d.u32().unwrap() as usize;
    assert_eq!(n, dst.len(), "baseline payload length mismatch");
    let raw = &payload[4..4 + n * 4];
    for (x, b) in dst.iter_mut().zip(raw.chunks_exact(4)) {
        *x += f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    }
}

fn copy_from_payload(dst: &mut [f32], payload: &[u8]) {
    let mut d = Dec::new(payload);
    let n = d.u32().unwrap() as usize;
    assert_eq!(n, dst.len(), "baseline payload length mismatch");
    let raw = &payload[4..4 + n * 4];
    for (x, b) in dst.iter_mut().zip(raw.chunks_exact(4)) {
        *x = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    }
}

/// The seed's ring allreduce: one unsegmented chunk per ring step, an
/// `Enc` allocation per send and a payload `Vec` per receive.
fn naive_ring_allreduce<N: PointToPoint>(
    net: &mut N,
    ring: &[u32],
    step: u64,
    buf: &mut [f32],
    timeout: Duration,
) {
    let n = ring.len();
    let me = ring.iter().position(|&id| id == net.id()).unwrap();
    if n == 1 {
        return;
    }
    let right = ring[(me + 1) % n];
    let left = ring[(me + n - 1) % n];
    let bounds = chunks(buf.len(), n);
    let step_tag = 0x1000u32 ^ (((step as u32) & 0xFFF) << 4);

    for s in 0..n - 1 {
        let send_chunk = (me + n - s) % n;
        let recv_chunk = (me + n - s - 1) % n;
        let (a, b) = bounds[send_chunk];
        let mut e = Enc::with_capacity(8 + (b - a) * 4);
        e.f32s(&buf[a..b]);
        net.send(right, step_tag + s as u32, e.into_bytes()).unwrap();
        let payload = net.recv_from(left, step_tag + s as u32, timeout).unwrap();
        let (ra, rb) = bounds[recv_chunk];
        add_assign_from_payload(&mut buf[ra..rb], &payload);
    }
    for s in 0..n - 1 {
        let send_chunk = (me + 1 + n - s) % n;
        let recv_chunk = (me + n - s) % n;
        let (a, b) = bounds[send_chunk];
        let mut e = Enc::with_capacity(8 + (b - a) * 4);
        e.f32s(&buf[a..b]);
        net.send(right, step_tag + 0x100 + s as u32, e.into_bytes()).unwrap();
        let payload = net.recv_from(left, step_tag + 0x100 + s as u32, timeout).unwrap();
        let (ra, rb) = bounds[recv_chunk];
        copy_from_payload(&mut buf[ra..rb], &payload);
    }
}

// ---------------------------------------------------------------------------
// harness
// ---------------------------------------------------------------------------

/// (ms/call, algo GB/s, summed pool (hits, misses)) over the in-proc hub.
fn bench_inproc(n_workers: usize, len: usize, iters: u64, naive: bool) -> (f64, f64, (u64, u64)) {
    let hub = InProcHub::new();
    let ring: Vec<u32> = (0..n_workers as u32).collect();
    let eps: Vec<_> = (0..n_workers).map(|i| hub.join(i as u32)).collect();
    let results: Vec<(Vec<f64>, (u64, u64))> = std::thread::scope(|s| {
        eps.into_iter()
            .map(|mut ep| {
                let ring = ring.clone();
                s.spawn(move || {
                    let mut buf = vec![1.0f32; len];
                    let mut times = Vec::with_capacity(iters as usize);
                    for step in 0..iters {
                        let t0 = Instant::now();
                        if naive {
                            naive_ring_allreduce(&mut ep, &ring, step, &mut buf, T);
                        } else {
                            ring_allreduce(&mut ep, &ring, step, &mut buf, 1.0, T).unwrap();
                        }
                        times.push(t0.elapsed().as_secs_f64());
                        // renormalise so values stay finite
                        for x in buf.iter_mut() {
                            *x = 1.0;
                        }
                    }
                    (times, ep.pool_stats())
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    let per_call = &results[0].0;
    let mean_s = stats::mean(per_call);
    let volume = 2.0 * (n_workers as f64 - 1.0) / n_workers as f64 * (len * 4) as f64;
    let (hits, misses) = results
        .iter()
        .fold((0u64, 0u64), |(h, m), (_, (wh, wm))| (h + wh, m + wm));
    (mean_s * 1e3, volume / mean_s / 1e9, (hits, misses))
}

/// (ms/call, algo GB/s) over a loopback-TCP ring.
fn bench_tcp(n_workers: usize, len: usize, iters: u64) -> (f64, f64) {
    let dir = Arc::new(Mutex::new(HashMap::new()));
    let ring: Vec<u32> = (0..n_workers as u32).collect();
    let nodes: Vec<TcpNode> =
        (0..n_workers as u32).map(|i| TcpNode::start(i, dir.clone()).unwrap()).collect();
    let times: Vec<Vec<f64>> = std::thread::scope(|s| {
        nodes
            .into_iter()
            .map(|mut node| {
                let ring = ring.clone();
                s.spawn(move || {
                    let mut buf = vec![1.0f32; len];
                    let mut times = Vec::with_capacity(iters as usize);
                    for step in 0..iters {
                        let t0 = Instant::now();
                        ring_allreduce(&mut node, &ring, step, &mut buf, 1.0, T).unwrap();
                        times.push(t0.elapsed().as_secs_f64());
                        for x in buf.iter_mut() {
                            *x = 1.0;
                        }
                    }
                    times
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    let mean_s = stats::mean(&times[0]);
    let volume = 2.0 * (n_workers as f64 - 1.0) / n_workers as f64 * (len * 4) as f64;
    (mean_s * 1e3, volume / mean_s / 1e9)
}

/// (ms/call, algo GB/s) over shared-memory rings (DESIGN.md §9) — the
/// intra-machine data plane `MixedNode` negotiates for co-located
/// workers. Unix-only at runtime (the rings live under /dev/shm).
fn bench_shm(n_workers: usize, len: usize, iters: u64, tag: &str) -> (f64, f64) {
    let ns = format!("edl-bench-{}-{tag}", std::process::id());
    let ring: Vec<u32> = (0..n_workers as u32).collect();
    let nodes: Vec<ShmNode> =
        (0..n_workers as u32).map(|i| ShmNode::start(i, &ns).unwrap()).collect();
    let times: Vec<Vec<f64>> = std::thread::scope(|s| {
        nodes
            .into_iter()
            .map(|mut node| {
                let ring = ring.clone();
                s.spawn(move || {
                    let mut buf = vec![1.0f32; len];
                    let mut times = Vec::with_capacity(iters as usize);
                    for step in 0..iters {
                        let t0 = Instant::now();
                        ring_allreduce(&mut node, &ring, step, &mut buf, 1.0, T).unwrap();
                        times.push(t0.elapsed().as_secs_f64());
                        for x in buf.iter_mut() {
                            *x = 1.0;
                        }
                    }
                    times
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    let mean_s = stats::mean(&times[0]);
    let volume = 2.0 * (n_workers as f64 - 1.0) / n_workers as f64 * (len * 4) as f64;
    (mean_s * 1e3, volume / mean_s / 1e9)
}

/// ms/call over the MIXED data plane on a simulated two-machine
/// topology (digest 0xA: nodes 0,1 / digest 0xB: nodes 2,3 — intra-pair
/// links negotiate shm, the rest ride loopback TCP). `hier` picks the
/// topology-aware hierarchical path vs the flat ring over the same links.
fn bench_mixed(len: usize, iters: u64, hier: bool) -> f64 {
    let dir = Arc::new(Mutex::new(HashMap::new()));
    let ns = format!("edl-bench-mix-{}-{}", std::process::id(), u8::from(hier));
    let digests: HashMap<u32, u64> = HashMap::from([(0u32, 0xAu64), (1, 0xA), (2, 0xB), (3, 0xB)]);
    let ring: Vec<u32> = (0..4).collect();
    let nodes: Vec<MixedNode> = (0..4u32)
        .map(|i| {
            let mut m = MixedNode::start(i, dir.clone(), digests[&i], &ns).unwrap();
            for p in 0..4u32 {
                if p != i {
                    m.set_peer_digest(p, digests[&p]);
                }
            }
            m
        })
        .collect();
    let times: Vec<Vec<f64>> = std::thread::scope(|s| {
        nodes
            .into_iter()
            .map(|mut node| {
                let ring = ring.clone();
                let digests = digests.clone();
                s.spawn(move || {
                    let mut buf = vec![1.0f32; len];
                    let mut times = Vec::with_capacity(iters as usize);
                    for step in 0..iters {
                        let t0 = Instant::now();
                        if hier {
                            topo_allreduce(&mut node, &ring, &digests, step, &mut buf, 1.0, T)
                                .unwrap();
                        } else {
                            ring_allreduce(&mut node, &ring, step, &mut buf, 1.0, T).unwrap();
                        }
                        times.push(t0.elapsed().as_secs_f64());
                        for x in buf.iter_mut() {
                            *x = 1.0;
                        }
                    }
                    times
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    stats::mean(&times[0]) * 1e3
}

fn main() {
    let smoke = std::env::var("EDL_BENCH_SMOKE").is_ok();
    let mut out = Json::obj();
    out.set("smoke", smoke);

    println!("== ring allreduce: pre-PR baseline vs segment-pipelined (in-process) ==");
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>9} {:>14}",
        "workers", "elems", "naive ms", "new ms", "speedup", "new algo GB/s"
    );
    let lens: &[usize] = if smoke {
        &[1_000, 100_000]
    } else {
        &[1_000, 100_000, 1_000_000, 4_250_000]
    };
    let mut rows = Json::Arr(vec![]);
    for &n in &[2usize, 4, 8] {
        for &len in lens {
            let iters = match (smoke, len > 500_000) {
                (true, _) => 5,
                (false, true) => 10,
                (false, false) => 50,
            };
            let (naive_ms, _, _) = bench_inproc(n, len, iters, true);
            let (new_ms, bw, pool) = bench_inproc(n, len, iters, false);
            let speedup = naive_ms / new_ms;
            println!("{n:>8} {len:>12} {naive_ms:>12.3} {new_ms:>12.3} {speedup:>8.2}x {bw:>14.2}");
            let mut r = Json::obj();
            r.set("workers", n)
                .set("elems", len)
                .set("naive_ms_per_call", naive_ms)
                .set("ms_per_call", new_ms)
                .set("speedup", speedup)
                .set("algo_gbs", bw)
                .set("pool_hits", pool.0)
                .set("pool_misses", pool.1);
            rows.push(r);
        }
    }
    out.set("rows", rows);

    // the 4.25M-element case is the `small` model's full gradient (the e2e
    // per-step payload) — it must complete well under a second, the pooled
    // hot path must stay O(1)-allocation, and the acceptance target is a
    // >=2x speedup over the pre-PR data plane on the same machine
    if !smoke {
        let (naive_ms, _, _) = bench_inproc(4, 4_250_000, 10, true);
        let (new_ms, _, (hits, misses)) = bench_inproc(4, 4_250_000, 10, false);
        let speedup = naive_ms / new_ms;
        let hit_rate = hits as f64 / (hits + misses).max(1) as f64;
        println!(
            "\nheadline 4x4.25M: naive {naive_ms:.1}ms vs new {new_ms:.1}ms \
             ({speedup:.2}x), pool hit-rate {:.1}%",
            hit_rate * 100.0
        );
        assert!(new_ms < 1_000.0, "full-gradient allreduce too slow: {new_ms:.1}ms");
        assert!(
            hit_rate > 0.8,
            "hot path should be O(1)-allocation (pool hit-rate {hit_rate:.2})"
        );
        // the PR acceptance gate: >= 2x over the pre-PR data plane on the
        // same machine (full mode is the acceptance run; smoke skips it)
        assert!(
            speedup >= 2.0,
            "acceptance: segment-pipelined data plane must be >= 2x the \
             seed baseline, measured {speedup:.2}x"
        );
        out.set("small_model_grad_ms", new_ms);
        out.set("small_model_grad_naive_ms", naive_ms);
        out.set("headline_speedup", speedup);
        out.set("pool_hit_rate", hit_rate);
    }

    // TCP ring: the multi-process data plane (the seed benched in-proc only)
    println!("\n== ring allreduce (loopback TCP ring) ==");
    let (tcp_n, tcp_len, tcp_iters) = if smoke { (2, 100_000, 3) } else { (4, 4_250_000, 5) };
    let (tcp_ms, tcp_bw) = bench_tcp(tcp_n, tcp_len, tcp_iters);
    println!("{tcp_n:>8} {tcp_len:>12} {tcp_ms:>12.3} {tcp_bw:>14.2} GB/s");
    let mut tcp = Json::obj();
    tcp.set("workers", tcp_n)
        .set("elems", tcp_len)
        .set("ms_per_call", tcp_ms)
        .set("algo_gbs", tcp_bw);
    out.set("tcp", tcp);

    // shm rings vs loopback TCP at >=1 MiB payloads: the intra-machine
    // data plane (DESIGN.md §9); acceptance is >=5x on the same machine
    if cfg!(unix) {
        println!("\n== ring allreduce: shm rings vs loopback TCP (same machine) ==");
        println!(
            "{:>8} {:>12} {:>12} {:>12} {:>9} {:>14}",
            "workers", "elems", "tcp ms", "shm ms", "speedup", "shm algo GB/s"
        );
        let cases: &[(usize, usize, u64)] = if smoke {
            &[(2, 262_144, 3)]
        } else {
            &[(2, 262_144, 20), (4, 1_000_000, 10), (4, 4_250_000, 5)]
        };
        let mut shm_rows = Json::Arr(vec![]);
        for &(n, len, iters) in cases {
            let (tcp_ms, _) = bench_tcp(n, len, iters);
            let (shm_ms, shm_bw) = bench_shm(n, len, iters, &format!("{n}x{len}"));
            let speedup = tcp_ms / shm_ms;
            println!(
                "{n:>8} {len:>12} {tcp_ms:>12.3} {shm_ms:>12.3} {speedup:>8.2}x {shm_bw:>14.2}"
            );
            let mut r = Json::obj();
            r.set("workers", n)
                .set("elems", len)
                .set("tcp_ms_per_call", tcp_ms)
                .set("shm_ms_per_call", shm_ms)
                .set("speedup", speedup)
                .set("shm_algo_gbs", shm_bw);
            shm_rows.push(r);
            // the PR acceptance gate: every case is >=1 MiB of payload
            if !smoke {
                assert!(
                    speedup >= 5.0,
                    "acceptance: shm rings must be >= 5x loopback TCP at \
                     {len} elems, measured {speedup:.2}x"
                );
            }
        }
        out.set("shm", shm_rows);

        // hierarchical vs flat on the mixed two-machine topology: the
        // topology-aware path must win once intra-machine traffic is free
        println!("\n== hierarchical vs flat allreduce (2 machines x 2 workers, mixed) ==");
        let (hier_len, hier_iters) = if smoke { (100_000, 3) } else { (4_250_000, 5) };
        let flat_ms = bench_mixed(hier_len, hier_iters, false);
        let hier_ms = bench_mixed(hier_len, hier_iters, true);
        let hier_speedup = flat_ms / hier_ms;
        println!(
            "{:>8} {:>12} {flat_ms:>12.3} {hier_ms:>12.3} {hier_speedup:>8.2}x",
            "4", hier_len
        );
        let mut hier = Json::obj();
        hier.set("workers", 4)
            .set("elems", hier_len)
            .set("flat_ms_per_call", flat_ms)
            .set("hier_ms_per_call", hier_ms)
            .set("speedup", hier_speedup);
        out.set("hier", hier);
        if !smoke {
            assert!(
                hier_ms < flat_ms,
                "acceptance: hierarchical allreduce must beat the flat ring \
                 on the mixed two-machine topology ({hier_ms:.1}ms vs {flat_ms:.1}ms)"
            );
        }
    }

    let path = write_results("perf_allreduce", &out).unwrap();
    println!("\nresults -> {}", path.display());
    if std::env::var("EDL_BENCH_BASELINE").is_ok() {
        std::fs::write("BENCH_perf_allreduce.json", out.to_string_pretty()).unwrap();
        println!("baseline -> BENCH_perf_allreduce.json");
    }
}
