//! `EasyScaleThread`-style virtual workers (DESIGN.md §11).
//!
//! P physical workers always emulate the same N logical workers. A
//! logical (virtual) worker is a logical-shard consumer: its identity is
//! the shard id, its mutable state is a PCG stream consuming exactly one
//! draw per sample (augmentation/dropout-class randomness). The stream
//! rides `CtrlMsg::Assign` (serialised via `wire::Enc::pcg`), so it
//! migrates with the shard through Grow/Shrink/Migrate and survives
//! checkpoint restore: whoever physically executes the shard next
//! continues the same stream at the same position. Because consumption
//! is one draw per sample, the position always equals the assignment's
//! sample offset and the leader can re-derive it by jump-ahead
//! (`data::schedule::shard_stream_at`) — physical state and pure
//! derivation can never disagree.
//!
//! The module also defines the **canonical loss** used by the chaos
//! harness and the model checker as the virtual workers' training
//! oracle. It is built so that trajectory equality is *bit-exact* at any
//! worker count:
//!
//!  * every quantity is an integer count of `LOSS_UNIT` = 2⁻⁹, and
//!    |units| < 2¹³, so the f32 value is exact;
//!  * barrier arithmetic multiplies it by integer batch weights ≤ 2⁶
//!    (≤ 19 significant bits, exact) and sums ≤ 2⁵ members (≤ 24 bits,
//!    exact, associativity-independent);
//!  * every member of a step reports the SAME canonical value, and a
//!    correctly-rounded division of `x·Σw` by `Σw` returns `x` exactly —
//!    so the leader's weighted mean is bit-identical no matter which
//!    physical workers carried the step or in which order they were
//!    folded.

use crate::data::PartitionMeta;
use crate::util::rng::Pcg;
use std::collections::BTreeMap;

/// One virtual worker: a logical shard's consumer state.
#[derive(Debug, Clone, PartialEq)]
pub struct VirtualWorker {
    /// logical shard id (= logical worker id)
    pub shard: u64,
    /// migrated stream; exactly one draw per consumed sample
    pub rng: Pcg,
}

impl VirtualWorker {
    /// Consume the per-sample draw. The value feeds sample-local
    /// randomness (augmentation, dropout masks); the SimDevice has no
    /// stochastic ops, so today only the stream *position* is observable
    /// — which is exactly what the determinism tests pin down.
    pub fn sample_draw(&mut self) -> u32 {
        self.rng.next_u32()
    }
}

/// The set of virtual workers a physical worker currently embodies.
/// Ordered by shard id so iteration (and any future serialisation) is
/// deterministic.
#[derive(Debug, Clone, Default)]
pub struct VwSet {
    active: BTreeMap<u64, VirtualWorker>,
}

impl VwSet {
    /// Begin emulating the shard's virtual worker with the migrated
    /// stream the leader sent alongside the assignment.
    pub fn adopt(&mut self, meta: &PartitionMeta, rng: Pcg) {
        self.active.insert(meta.id, VirtualWorker { shard: meta.id, rng });
    }

    /// Per-sample draw for `shard`; `None` if this physical worker is not
    /// currently emulating that virtual worker.
    pub fn draw(&mut self, shard: u64) -> Option<u32> {
        self.active.get_mut(&shard).map(VirtualWorker::sample_draw)
    }

    /// Stop emulating `shard` (assignment finished or abandoned). The
    /// stream is not lost: the leader re-derives it from the shard's
    /// consumed-sample offset when the remainder is reassigned.
    pub fn release(&mut self, shard: u64) -> Option<VirtualWorker> {
        self.active.remove(&shard)
    }

    /// Drop every emulated virtual worker (restore: the worker no longer
    /// holds its shards; fresh Assigns re-seed the set).
    pub fn clear(&mut self) {
        self.active.clear();
    }

    pub fn len(&self) -> usize {
        self.active.len()
    }

    pub fn is_empty(&self) -> bool {
        self.active.is_empty()
    }
}

/// Exact representable quantum of the canonical loss: 2⁻⁹.
pub const LOSS_UNIT: f32 = 1.0 / 512.0;

/// Stream-id salt for per-virtual-worker loss-noise streams (disjoint
/// from the shard data streams in `data::schedule`).
const LOSS_STREAM_SALT: u64 = 0x1055_CA2B_0DE7_E2A1;

fn mix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Virtual worker `vw`'s loss-noise stream: exactly one draw per step,
/// so the position at step `s` is `s` and [`noise_units`] re-derives it
/// by jump-ahead.
pub fn loss_stream(seed: u64, vw: u64) -> Pcg {
    Pcg::new(mix(seed), mix(LOSS_STREAM_SALT ^ vw))
}

/// `vw`'s loss noise at `step`, in integer units of [`LOSS_UNIT`]:
/// uniform in [-256, 255].
pub fn noise_units(seed: u64, vw: u64, step: u64) -> i64 {
    let mut r = loss_stream(seed, vw);
    r.advance(step);
    (r.next_u32() >> 23) as i64 - 256
}

/// Deterministic base curve in units of [`LOSS_UNIT`]: 0.125·(step mod
/// 97), i.e. 64 units per step with a period keeping magnitudes small.
fn base_units(step: u64) -> i64 {
    ((step % 97) * 64) as i64
}

/// The canonical loss of `step`: base curve plus the mean of the N
/// logical workers' noise, computed entirely in integer units so the
/// result is an exact multiple of [`LOSS_UNIT`] with |units| < 2¹³.
/// Independent of P by construction — it never mentions physical
/// workers.
pub fn canonical_loss(seed: u64, n_logical: u64, step: u64) -> f32 {
    assert!(n_logical > 0, "canonical loss needs at least one virtual worker");
    let sum: i64 = (0..n_logical).map(|vw| noise_units(seed, vw, step)).sum();
    (base_units(step) + sum / n_logical as i64) as f32 * LOSS_UNIT
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schedule;
    use crate::util::prop;

    #[test]
    fn migrated_stream_equals_rederived_stream() {
        // worker A consumes k samples of a shard, dies; the leader hands
        // the remainder to worker B with a jump-ahead re-derived stream —
        // B must continue A's stream exactly
        let (seed, epoch, shard) = (77u64, 1u64, 4u64);
        let meta = PartitionMeta { id: shard, start: 40, len: 10, epoch };
        let mut a = VwSet::default();
        a.adopt(&meta, schedule::shard_stream(seed, epoch, shard));
        let mut consumed_stream = Vec::new();
        for _ in 0..6 {
            consumed_stream.push(a.draw(shard).unwrap());
        }
        a.release(shard);
        let mut b = VwSet::default();
        let remainder = PartitionMeta { id: shard, start: 46, len: 4, epoch };
        b.adopt(&remainder, schedule::shard_stream_at(seed, epoch, shard, 6));
        let mut direct = schedule::shard_stream(seed, epoch, shard);
        for x in consumed_stream {
            assert_eq!(x, direct.next_u32());
        }
        for _ in 0..4 {
            assert_eq!(b.draw(shard).unwrap(), direct.next_u32());
        }
        assert!(b.draw(99).is_none(), "drawing for a shard not held must fail");
    }

    #[test]
    fn noise_units_bounded_and_stream_positioned() {
        for step in [0u64, 1, 50, 1000] {
            let n = noise_units(5, 3, step);
            assert!((-256..=255).contains(&n), "noise {n} out of range");
        }
        // jump-ahead position matches sequential draws
        let mut seq = loss_stream(5, 3);
        for step in 0..20u64 {
            let want = (seq.next_u32() >> 23) as i64 - 256;
            assert_eq!(noise_units(5, 3, step), want, "step {step}");
        }
    }

    #[test]
    fn canonical_loss_is_exact_under_any_barrier_arithmetic() {
        // THE property the trajectory-equality invariant rests on: fold
        // the same canonical value through a weighted mean with random
        // integer weights, membership sizes, and fold order — the result
        // must be BIT-identical to the value itself.
        prop::check("canonical-loss-exact", 100, |rng| {
            let seed = rng.next_u64();
            let n_logical = 1 + rng.gen_range(16);
            let step = rng.gen_range(10_000);
            let x = canonical_loss(seed, n_logical, step);
            let members = 1 + rng.gen_range(8) as usize;
            let mut lsum = 0.0f32;
            let mut wsum = 0.0f32;
            for _ in 0..members {
                let w = (1 + rng.gen_range(32)) as f32;
                lsum += x * w;
                wsum += w;
            }
            let mean = lsum / wsum;
            if mean.to_bits() != x.to_bits() {
                return Err(format!(
                    "weighted mean {mean} != canonical {x} (n={n_logical}, members={members})"
                ));
            }
            // unweighted fallback (wsum == 0 barriers) must be exact too
            let k = members as f32;
            let unweighted = (x * k) / k;
            if unweighted.to_bits() != x.to_bits() {
                return Err(format!("unweighted mean {unweighted} != canonical {x}"));
            }
            Ok(())
        });
    }

    #[test]
    fn canonical_loss_never_mentions_physical_workers() {
        // same (seed, n_logical, step) → same bits, full stop; and the
        // value reacts to each of its actual inputs
        assert_eq!(
            canonical_loss(1, 8, 5).to_bits(),
            canonical_loss(1, 8, 5).to_bits()
        );
        assert_ne!(canonical_loss(1, 8, 5), canonical_loss(2, 8, 5));
        assert_ne!(canonical_loss(1, 8, 5), canonical_loss(1, 8, 6));
    }
}
