//! `edl verify` — the repo's custom static-analysis pass and bounded model
//! checker (see DESIGN.md §7).
//!
//! Five lints enforce invariants the rest of the stack leans on:
//!
//! | lint            | invariant                                           |
//! |-----------------|-----------------------------------------------------|
//! | `determinism`   | pure modules read no clocks, sleep never, no        |
//! |                 | ambient RNG                                         |
//! | `tag-layout`    | allreduce tag bitfields are disjoint, namespaced,   |
//! |                 | generation-sensitive                                |
//! | `wire-coverage` | every protocol enum variant appears in a round-trip |
//! |                 | test                                                |
//! | `lock-order`    | the inter-procedural lock graph is acyclic          |
//! | `panic-path`    | protocol handle paths return typed errors, never    |
//! |                 | unwrap/expect/panic                                 |
//!
//! `verify::model` then BFS-explores the pure `LeaderCore` exhaustively
//! over a small scope where the PR 5 chaos harness only samples.
//!
//! All lints run on `(path, source-text)` pairs so the self-tests can feed
//! seeded-regression fixtures through the same code path, and diagnostics
//! are deterministic (sorted) so CI output is stable.

pub mod lexer;
pub mod lints;
pub mod locks;
pub mod model;
pub mod tags;

use std::fmt;
use std::path::{Path, PathBuf};

/// One lint finding. `line == 0` means "whole file" (layout/coverage lints).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub lint: String,
    pub file: String,
    pub line: u32,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.line > 0 {
            write!(f, "[{}] {}:{}: {}", self.lint, self.file, self.line, self.msg)
        } else {
            write!(f, "[{}] {}: {}", self.lint, self.file, self.msg)
        }
    }
}

/// A source file fed to the lints (real or fixture).
#[derive(Debug, Clone)]
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

/// Recursively collect `.rs` files under each root, sorted by path so every
/// run sees the same order.
pub fn collect_sources(roots: &[&Path]) -> std::io::Result<Vec<SourceFile>> {
    let mut paths: Vec<PathBuf> = Vec::new();
    fn walk(dir: &Path, paths: &mut Vec<PathBuf>) -> std::io::Result<()> {
        let mut entries: Vec<PathBuf> =
            std::fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                walk(&p, paths)?;
            } else if p.extension().is_some_and(|e| e == "rs") {
                paths.push(p);
            }
        }
        Ok(())
    }
    for root in roots {
        if root.is_dir() {
            walk(root, &mut paths)?;
        }
    }
    paths.sort();
    paths
        .into_iter()
        .map(|p| {
            Ok(SourceFile {
                path: p.to_string_lossy().replace('\\', "/"),
                text: std::fs::read_to_string(&p)?,
            })
        })
        .collect()
}

/// One allowlist entry: `lint | path-suffix | message-needle  # why`.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub lint: String,
    pub path: String,
    pub needle: String,
    pub why: String,
}

#[derive(Debug, Clone, Default)]
pub struct Allowlist {
    pub entries: Vec<AllowEntry>,
}

impl Allowlist {
    /// Parse the allowlist format: one entry per line,
    /// `lint | path-suffix | message-needle # justification`.
    /// Blank lines and lines starting with `#` are comments. An entry with
    /// no `#` justification is itself a parse error — exceptions must say
    /// why they exist.
    pub fn parse(text: &str) -> Result<Allowlist, String> {
        let mut entries = Vec::new();
        for (ix, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (body, why) = line
                .split_once('#')
                .ok_or_else(|| format!("allowlist line {}: missing `# justification`", ix + 1))?;
            let why = why.trim();
            if why.is_empty() {
                return Err(format!("allowlist line {}: empty justification", ix + 1));
            }
            let parts: Vec<&str> = body.split('|').map(|s| s.trim()).collect();
            if parts.len() != 3 || parts.iter().any(|p| p.is_empty()) {
                return Err(format!(
                    "allowlist line {}: expected `lint | path-suffix | needle # why`",
                    ix + 1
                ));
            }
            entries.push(AllowEntry {
                lint: parts[0].to_string(),
                path: parts[1].to_string(),
                needle: parts[2].to_string(),
                why: why.to_string(),
            });
        }
        Ok(Allowlist { entries })
    }

    pub fn load(path: &Path) -> Result<Allowlist, String> {
        match std::fs::read_to_string(path) {
            Ok(text) => Self::parse(&text),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Allowlist::default()),
            Err(e) => Err(format!("reading {}: {e}", path.display())),
        }
    }

    pub fn suppresses(&self, d: &Diagnostic) -> bool {
        self.entries.iter().any(|e| {
            e.lint == d.lint && d.file.contains(&e.path) && d.msg.contains(&e.needle)
        })
    }
}

/// Result of the static pass: surviving diagnostics plus suppression count.
#[derive(Debug)]
pub struct LintReport {
    pub diagnostics: Vec<Diagnostic>,
    pub suppressed: usize,
}

/// Run every lint over `sources`, apply the allowlist, and return the
/// surviving diagnostics sorted (lint, file, line) for stable output.
pub fn run_lints(sources: &[SourceFile], allow: &Allowlist) -> LintReport {
    let mut diags = Vec::new();
    diags.extend(lints::determinism(sources));
    diags.extend(lints::panic_paths(sources));
    diags.extend(lints::wire_coverage(sources));
    diags.extend(locks::lock_order(sources));
    let find = |suffix: &str| sources.iter().find(|s| s.path.contains(suffix));
    match (find("/allreduce/mod.rs"), find("/transport/mod.rs")) {
        (Some(ar), Some(tp)) => diags.extend(tags::tag_layout(ar, tp)),
        _ => diags.push(Diagnostic {
            lint: tags::LINT_TAGS.into(),
            file: "<tree>".into(),
            line: 0,
            msg: "allreduce/transport sources not found — tag lint could not run".into(),
        }),
    }

    let before = diags.len();
    let mut survived: Vec<Diagnostic> =
        diags.into_iter().filter(|d| !allow.suppresses(d)).collect();
    survived.sort_by(|a, b| {
        (&a.lint, &a.file, a.line, &a.msg).cmp(&(&b.lint, &b.file, b.line, &b.msg))
    });
    let suppressed = before - survived.len();
    LintReport { diagnostics: survived, suppressed }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allowlist_requires_justification() {
        assert!(Allowlist::parse("panic-path | wire/mod.rs | unwrap").is_err());
        assert!(Allowlist::parse("panic-path | wire/mod.rs | unwrap #   ").is_err());
        let ok = Allowlist::parse(
            "# comment\n\npanic-path | wire/mod.rs | try_into # take(N) guarantees length\n",
        )
        .unwrap();
        assert_eq!(ok.entries.len(), 1);
        assert_eq!(ok.entries[0].lint, "panic-path");
    }

    #[test]
    fn allowlist_suppression_matches_lint_path_and_needle() {
        let allow = Allowlist::parse(
            "panic-path | wire/mod.rs | try_into # infallible\n",
        )
        .unwrap();
        let hit = Diagnostic {
            lint: "panic-path".into(),
            file: "rust/src/wire/mod.rs".into(),
            line: 189,
            msg: "`unwrap` on a protocol handle path: try_into().unwrap()".into(),
        };
        let miss = Diagnostic { lint: "determinism".into(), ..hit.clone() };
        assert!(allow.suppresses(&hit));
        assert!(!allow.suppresses(&miss));
    }
}
