//! Binary wire codec + length-prefixed framing (serde/bincode are not
//! available offline, so EDL's coordination messages serialise through this
//! hand-rolled little-endian codec).
//!
//! The framing matches the paper's observation (§4.4): coordination
//! messages are small (hundreds of bytes) and latency-critical, so frames
//! are a single 4-byte length prefix + payload, written with one syscall,
//! and the TCP transport layer disables Nagle's algorithm.

use std::io::{Read, Write};

#[derive(Debug)]
pub enum WireError {
    Truncated { wanted: usize, have: usize },
    BadTag { tag: u32, ty: &'static str },
    BadUtf8,
    FrameTooLarge(usize),
    /// envelope version byte does not match this build's [`API_VERSION`]
    Version { got: u8, want: u8 },
    Io(std::io::Error),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { wanted, have } => {
                write!(f, "truncated message: wanted {wanted} more bytes, have {have}")
            }
            WireError::BadTag { tag, ty } => write!(f, "invalid enum tag {tag} for {ty}"),
            WireError::BadUtf8 => write!(f, "invalid utf-8 string"),
            WireError::FrameTooLarge(n) => write!(f, "frame too large: {n} bytes"),
            WireError::Version { got, want } => {
                write!(f, "api version mismatch: got v{got}, want v{want}")
            }
            WireError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        WireError::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, WireError>;

/// Hard cap on frame size — coordination messages are small; model
/// broadcast frames carry full parameter vectors, so allow up to 1 GiB.
pub const MAX_FRAME: usize = 1 << 30;

// ---------------------------------------------------------------------------
// encoder
// ---------------------------------------------------------------------------

#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Self {
        Enc { buf: Vec::with_capacity(64) }
    }

    pub fn with_capacity(n: usize) -> Self {
        Enc { buf: Vec::with_capacity(n) }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }
    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn i64(&mut self, v: i64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn f32(&mut self, v: f32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn bool(&mut self, v: bool) -> &mut Self {
        self.u8(v as u8)
    }

    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
        self
    }

    pub fn str(&mut self, v: &str) -> &mut Self {
        self.bytes(v.as_bytes())
    }

    /// f32 vector with length prefix; bulk memcpy of the raw bytes.
    pub fn f32s(&mut self, v: &[f32]) -> &mut Self {
        self.u32(v.len() as u32);
        let bytes = unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
        };
        self.buf.extend_from_slice(bytes);
        self
    }

    pub fn u64s(&mut self, v: &[u64]) -> &mut Self {
        self.u32(v.len() as u32);
        for &x in v {
            self.u64(x);
        }
        self
    }

    /// u32 vector with length prefix (worker-id lists in control messages)
    pub fn u32s(&mut self, v: &[u32]) -> &mut Self {
        self.u32(v.len() as u32);
        for &x in v {
            self.u32(x);
        }
        self
    }

    /// string vector with length prefix (machine lists in control messages)
    pub fn strs(&mut self, v: &[String]) -> &mut Self {
        self.u32(v.len() as u32);
        for s in v {
            self.str(s);
        }
        self
    }

    /// PCG generator state: 16 bytes, LCG state then stream increment.
    /// Carries virtual-worker / assigner RNG streams across migration and
    /// checkpoint restore (DESIGN.md §11) — the decoded generator resumes
    /// the u32 stream exactly where the encoded one stopped.
    pub fn pcg(&mut self, rng: &crate::util::rng::Pcg) -> &mut Self {
        let (state, inc) = rng.to_parts();
        self.u64(state).u64(inc)
    }
}

// ---------------------------------------------------------------------------
// decoder
// ---------------------------------------------------------------------------

pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(WireError::Truncated { wanted: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn bool(&mut self) -> Result<bool> {
        Ok(self.u8()? != 0)
    }

    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    pub fn str(&mut self) -> Result<String> {
        let b = self.bytes()?;
        String::from_utf8(b).map_err(|_| WireError::BadUtf8)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        let mut out = vec![0f32; n];
        unsafe {
            std::ptr::copy_nonoverlapping(raw.as_ptr(), out.as_mut_ptr() as *mut u8, n * 4);
        }
        Ok(out)
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.u64()).collect()
    }

    pub fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.u32()).collect()
    }

    pub fn strs(&mut self) -> Result<Vec<String>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.str()).collect()
    }

    /// Counterpart of [`Enc::pcg`].
    pub fn pcg(&mut self) -> Result<crate::util::rng::Pcg> {
        let state = self.u64()?;
        let inc = self.u64()?;
        Ok(crate::util::rng::Pcg::from_parts(state, inc))
    }
}

// ---------------------------------------------------------------------------
// versioned request/response envelope
// ---------------------------------------------------------------------------

/// Version byte carried by every [`Envelope`]. Bump on any incompatible
/// change to the `api` request/response encodings; decoders reject
/// mismatched versions instead of mis-parsing.
pub const API_VERSION: u8 = 1;

/// The versioned envelope every job-control frame travels in:
/// `[version u8][seq u64][body bytes]`. `seq` lets a client match replies
/// to requests over a plain byte stream; `body` is an encoded
/// `api::Request` or `api::Response`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Envelope {
    pub seq: u64,
    pub body: Vec<u8>,
}

impl Envelope {
    pub fn new(seq: u64, body: Vec<u8>) -> Envelope {
        Envelope { seq, body }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::with_capacity(13 + self.body.len());
        e.u8(API_VERSION).u64(self.seq).bytes(&self.body);
        e.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<Envelope> {
        let mut d = Dec::new(buf);
        let got = d.u8()?;
        if got != API_VERSION {
            return Err(WireError::Version { got, want: API_VERSION });
        }
        Ok(Envelope { seq: d.u64()?, body: d.bytes()? })
    }
}

// ---------------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------------

/// Write `head` then `tail` as one logical message using vectored I/O:
/// a single syscall in the common case (important for latency with
/// TCP_NODELAY: one frame, one segment) with NO intermediate framed
/// buffer — the payload is transmitted straight from the caller's slice.
pub fn write_all_vectored<W: Write>(w: &mut W, head: &[u8], tail: &[u8]) -> std::io::Result<()> {
    let total = head.len() + tail.len();
    let mut done = 0usize;
    while done < total {
        let n = if done < head.len() {
            w.write_vectored(&[std::io::IoSlice::new(&head[done..]), std::io::IoSlice::new(tail)])?
        } else {
            w.write(&tail[done - head.len()..])?
        };
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::WriteZero,
                "write returned zero bytes",
            ));
        }
        done += n;
    }
    Ok(())
}

/// Write one length-prefixed frame (vectored: length prefix + payload in
/// one write, zero-copy with respect to the payload).
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(WireError::FrameTooLarge(payload.len()));
    }
    let head = (payload.len() as u32).to_le_bytes();
    write_all_vectored(w, &head, payload)?;
    w.flush()?;
    Ok(())
}

/// Write MANY length-prefixed frames as one vectored burst: every length
/// prefix and payload goes into a single `write_vectored` call (resumed
/// on partial writes), then one flush. With TCP_NODELAY each scalar
/// [`write_frame`] costs a syscall and usually a segment; a scale
/// operation fans Peers + Assign + SyncGo to every worker, and batching
/// the burst collapses each worker's run to one write.
pub fn write_frames<W: Write>(w: &mut W, payloads: &[Vec<u8>]) -> Result<()> {
    if payloads.is_empty() {
        return Ok(());
    }
    for p in payloads {
        if p.len() > MAX_FRAME {
            return Err(WireError::FrameTooLarge(p.len()));
        }
    }
    let heads: Vec<[u8; 4]> = payloads.iter().map(|p| (p.len() as u32).to_le_bytes()).collect();
    let mut parts: Vec<&[u8]> = Vec::with_capacity(payloads.len() * 2);
    for (h, p) in heads.iter().zip(payloads) {
        parts.push(&h[..]);
        parts.push(&p[..]);
    }
    let total: usize = parts.iter().map(|s| s.len()).sum();
    let mut done = 0usize;
    while done < total {
        // find the first unwritten byte, then hand the kernel everything
        // from there in one vectored call; partial writes resume here
        let mut skip = done;
        let mut first = 0usize;
        while skip >= parts[first].len() {
            skip -= parts[first].len();
            first += 1;
        }
        let mut iov: Vec<std::io::IoSlice<'_>> = Vec::with_capacity(parts.len() - first);
        iov.push(std::io::IoSlice::new(&parts[first][skip..]));
        iov.extend(parts[first + 1..].iter().map(|p| std::io::IoSlice::new(p)));
        let n = w.write_vectored(&iov)?;
        if n == 0 {
            return Err(WireError::Io(std::io::Error::new(
                std::io::ErrorKind::WriteZero,
                "write returned zero bytes",
            )));
        }
        done += n;
    }
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(WireError::FrameTooLarge(len));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(payload)
}

/// Request/reply loop shared by the framed TCP servers
/// (`coordsvc::KvServer`, `api::JobServer`): Nagle off (§4.4), one frame
/// in → one handler call → one frame out, returning cleanly when the peer
/// closes the connection. Run it on a thread per connection.
pub fn serve_framed(
    stream: std::net::TcpStream,
    mut handler: impl FnMut(&[u8]) -> Result<Vec<u8>>,
) -> Result<()> {
    stream.set_nodelay(true)?;
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    let mut writer = std::io::BufWriter::new(stream);
    loop {
        let req = match read_frame(&mut reader) {
            Ok(r) => r,
            Err(_) => return Ok(()), // peer closed
        };
        let resp = handler(&req)?;
        write_frame(&mut writer, &resp)?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Pcg};

    #[test]
    fn scalar_roundtrip() {
        let mut e = Enc::new();
        e.u8(7).u32(0xDEAD_BEEF).u64(u64::MAX).i64(-42).f32(1.5).f64(-2.25).bool(true);
        let b = e.into_bytes();
        let mut d = Dec::new(&b);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(d.u64().unwrap(), u64::MAX);
        assert_eq!(d.i64().unwrap(), -42);
        assert_eq!(d.f32().unwrap(), 1.5);
        assert_eq!(d.f64().unwrap(), -2.25);
        assert!(d.bool().unwrap());
        assert_eq!(d.remaining(), 0);
    }

    #[test]
    fn string_and_bytes_roundtrip() {
        let mut e = Enc::new();
        e.str("héllo ✓").bytes(&[0, 1, 2, 255]);
        let b = e.into_bytes();
        let mut d = Dec::new(&b);
        assert_eq!(d.str().unwrap(), "héllo ✓");
        assert_eq!(d.bytes().unwrap(), vec![0, 1, 2, 255]);
    }

    #[test]
    fn truncated_detected() {
        let mut e = Enc::new();
        e.u64(1);
        let b = e.into_bytes();
        let mut d = Dec::new(&b[..4]);
        assert!(matches!(d.u64(), Err(WireError::Truncated { .. })));
    }

    #[test]
    fn f32s_bulk_roundtrip_property() {
        prop::check("f32s-roundtrip", 50, |rng: &mut Pcg| {
            let n = rng.gen_range(2000) as usize;
            let v: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let mut e = Enc::new();
            e.f32s(&v);
            let b = e.into_bytes();
            let got = Dec::new(&b).f32s().map_err(|e| e.to_string())?;
            if got != v {
                return Err(format!("mismatch at n={n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn pcg_roundtrip_resumes_stream_property() {
        prop::check("pcg-roundtrip", 50, |rng: &mut Pcg| {
            let mut src = Pcg::new(rng.next_u64(), rng.next_u64() & 0x7FFF_FFFF);
            for _ in 0..rng.gen_range(64) {
                src.next_u32();
            }
            let mut e = Enc::new();
            e.pcg(&src);
            let b = e.into_bytes();
            if b.len() != 16 {
                return Err(format!("pcg encoding must be 16 bytes, got {}", b.len()));
            }
            let mut got = Dec::new(&b).pcg().map_err(|e| e.to_string())?;
            for i in 0..32 {
                let (want, have) = (src.next_u32(), got.next_u32());
                if want != have {
                    return Err(format!("stream diverged at draw {i}: {want} != {have}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn envelope_roundtrip_carries_version_byte() {
        let env = Envelope::new(42, vec![1, 2, 3]);
        let bytes = env.encode();
        assert_eq!(bytes[0], API_VERSION, "first byte on the wire is the version");
        assert_eq!(Envelope::decode(&bytes).unwrap(), env);
    }

    #[test]
    fn envelope_rejects_wrong_version() {
        let mut bytes = Envelope::new(1, vec![9]).encode();
        bytes[0] = API_VERSION + 1;
        assert!(matches!(
            Envelope::decode(&bytes),
            Err(WireError::Version { got, want }) if got == API_VERSION + 1 && want == API_VERSION
        ));
    }

    #[test]
    fn u32s_and_strs_roundtrip() {
        let mut e = Enc::new();
        e.u32s(&[7, 8, 9]).strs(&["m0:g1".to_string(), "m1:g0".to_string()]);
        let b = e.into_bytes();
        let mut d = Dec::new(&b);
        assert_eq!(d.u32s().unwrap(), vec![7, 8, 9]);
        assert_eq!(d.strs().unwrap(), vec!["m0:g1".to_string(), "m1:g0".to_string()]);
    }

    #[test]
    fn frame_roundtrip_over_cursor() {
        let payload = b"coordination message".to_vec();
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cursor).unwrap(), payload);
    }

    #[test]
    fn multiple_frames_in_sequence() {
        let mut buf = Vec::new();
        for i in 0..5u8 {
            write_frame(&mut buf, &[i; 3]).unwrap();
        }
        let mut cursor = std::io::Cursor::new(buf);
        for i in 0..5u8 {
            assert_eq!(read_frame(&mut cursor).unwrap(), vec![i; 3]);
        }
    }

    #[test]
    fn vectored_write_survives_partial_writers() {
        // a writer that accepts one byte per call exercises every resume
        // offset in write_all_vectored
        struct OneByte(Vec<u8>);
        impl std::io::Write for OneByte {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                if b.is_empty() {
                    return Ok(0);
                }
                self.0.push(b[0]);
                Ok(1)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut w = OneByte(Vec::new());
        write_all_vectored(&mut w, &[1, 2, 3], &[4, 5]).unwrap();
        assert_eq!(w.0, vec![1, 2, 3, 4, 5]);
        let mut w = OneByte(Vec::new());
        write_all_vectored(&mut w, &[9], &[]).unwrap();
        assert_eq!(w.0, vec![9]);
        // the multi-frame burst must resume through every offset too,
        // including across empty payloads
        let frames = vec![b"abc".to_vec(), Vec::new(), b"defgh".to_vec()];
        let mut w = OneByte(Vec::new());
        write_frames(&mut w, &frames).unwrap();
        let mut cursor = std::io::Cursor::new(w.0);
        for f in &frames {
            assert_eq!(&read_frame(&mut cursor).unwrap(), f);
        }
    }

    #[test]
    fn frame_burst_matches_scalar_framing() {
        // batching is a transport optimisation: the bytes on the wire must
        // be EXACTLY what N scalar write_frame calls would have produced
        prop::check("frame_burst_matches_scalar_framing", 40, |rng| {
            let n = rng.gen_range(6) as usize;
            let frames: Vec<Vec<u8>> = (0..n)
                .map(|_| {
                    let len = rng.gen_range(100) as usize;
                    (0..len).map(|_| rng.gen_range(256) as u8).collect()
                })
                .collect();
            let mut burst = Vec::new();
            write_frames(&mut burst, &frames).unwrap();
            let mut scalar = Vec::new();
            for f in &frames {
                write_frame(&mut scalar, f).unwrap();
            }
            assert_eq!(burst, scalar);
            Ok(())
        });
    }

    #[test]
    fn oversize_frame_rejected() {
        struct Sink;
        impl std::io::Write for Sink {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        // don't allocate a real >1GiB buffer; check the length gate with a
        // fake slice via the frame length test on read side
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        let mut cursor = std::io::Cursor::new(buf);
        assert!(matches!(read_frame(&mut cursor), Err(WireError::FrameTooLarge(_))));
        let _ = Sink; // silence unused in case of cfg changes
    }
}
