//! Discrete-event multi-tenant GPU-cluster simulator — the substrate for
//! the paper's scheduling experiments (Fig 11, Fig 12, Table 4), playing
//! the role of the Tiresias simulator the authors used (§6.3).
//!
//! Jobs progress at a rate derived from the calibrated device model
//! (`gpu_sim`): a job running at parallelism `p` advances its work at
//! `throughput(p) / throughput(p_requested)` wall-seconds per second.
//! Scaling operations cost what the paper measured:
//!
//!  * stop-resume: the whole job pauses for `stop_resume_overhead`;
//!  * EDL scale-out: the job keeps running at the old parallelism while
//!    the joiners prepare (scale_out_e2e), then pauses briefly for the
//!    model broadcast (edl_stop) before running at the new parallelism;
//!  * EDL scale-in: the rate drops immediately; overhead is negligible.
//!
//! Schedulers plug in through the policy/engine split ([`crate::sched`]):
//! the simulator implements [`ClusterView`] + [`ClusterCtl`] and applies
//! each typed [`Decision`] a policy submits — placement decisions
//! (`Start` / `Preempt`) via the simulator-level `start_job` /
//! `preempt_job`, parallelism adjustments on a RUNNING job through the
//! Table-1 surface ([`SimJobHandle`] implements
//! [`api::JobControl`](crate::api::JobControl)), so policy code written
//! against the simulator also drives live jobs. Every applied decision is
//! recorded in [`ClusterSim::decision_log`] with its simulation time:
//! replaying the log through a fresh simulator reproduces the run's
//! metrics byte for byte (see `rust/tests/sched_policies.rs`).

use crate::api::{ElasticError, JobControl, JobStatus, ProfileRow, Request};
use crate::sched::{ClusterCtl, ClusterView, Decision, JobView};
use crate::coordinator::replay::{scheduled_join_step, ScriptedLeader};
use crate::coordinator::{Action, TrainerConfig};
use crate::gpu_sim::{self, Dnn, HwConfig};
use crate::metrics::TimeSeries;
use crate::trace::TraceJob;
use crate::transport::NodeId;
use crate::worker::SimBackend;
use std::sync::Arc;

/// The §4.2 stop-free switch lag, measured by replaying a scripted
/// scale-out through the REAL [`LeaderCore`](crate::coordinator::LeaderCore)
/// under a virtual clock instead of a parallel hand-derived formula: two
/// founders train at `step_s` seconds per mini-batch, one joiner becomes
/// ready, and the core schedules the switch `k = ceil(T_a / T_b)` steps
/// ahead. Returns the wall time between joiner readiness and the topology
/// switch — the tail of the scale-out transient the cluster simulator
/// charges after context preparation.
pub fn edl_switch_lag_s(step_s: f64, allowance_ms: f64) -> f64 {
    let step_ms = (step_s * 1e3).max(0.1);
    let cfg = TrainerConfig { switch_allowance_ms: allowance_ms, ..TrainerConfig::default() };
    let mut leader = ScriptedLeader::new(cfg, Arc::new(SimBackend::fast(8)), 2);
    leader.join_worker(1, "m0", false);
    leader.join_worker(2, "m0", false);
    // seed the core's barrier history so switch_k sees the real step time
    leader.run_barriers(8, step_ms);
    let (_token, acts) = leader.request(Request::ScaleOut { machines: vec!["sim".into()] });
    let joiner = acts
        .iter()
        .find_map(|a| match a {
            Action::Spawn { id, .. } => Some(*id),
            _ => None,
        })
        .expect("scale-out emits a Spawn");
    let acts = leader.join_worker(joiner, "m1", true);
    let at_step = scheduled_join_step(&acts).expect("joiner readiness schedules the switch");
    at_step.saturating_sub(leader.core.step()) as f64 * step_s
}

/// [`edl_switch_lag_s`] at the trainer's default allowance, memoized per
/// step time — the simulator replays the scripted scale-out once per
/// distinct job speed instead of once per scale event.
fn edl_switch_lag_cached_s(step_s: f64) -> f64 {
    use std::collections::HashMap;
    use std::sync::Mutex;
    static CACHE: Mutex<Option<HashMap<u64, f64>>> = Mutex::new(None);
    let key = step_s.to_bits();
    let mut guard = CACHE.lock().unwrap_or_else(|p| p.into_inner());
    let map = guard.get_or_insert_with(HashMap::new);
    if let Some(&lag) = map.get(&key) {
        return lag;
    }
    let lag = edl_switch_lag_s(step_s, TrainerConfig::default().switch_allowance_ms);
    map.insert(key, lag);
    lag
}

/// How parallelism adjustments are charged (the §6 comparison axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleMode {
    /// EDL: stop-free scale-out + graceful-exit scale-in
    Edl,
    /// checkpoint + restart with the new parallelism
    StopResume,
    /// zero-overhead scaling (the Fig 10b "Ideal" upper bound)
    Ideal,
}

#[derive(Debug, Clone, PartialEq)]
pub enum JobState {
    Pending,
    /// running at `p`; if `paused_until > now` the job holds its GPUs but
    /// makes no progress (scaling/restart overhead)
    Running { p: u32, paused_until: f64 },
    /// mid-EDL-scale-out: still training at `old_p`, `new_p` GPUs reserved;
    /// at `ready_at` the job pauses `stop_s` then runs at `new_p`
    ScalingOut { old_p: u32, new_p: u32, ready_at: f64 },
    Finished { at: f64 },
}

#[derive(Debug, Clone)]
pub struct SimJob {
    pub id: u64,
    pub model: Dnn,
    pub requested_p: u32,
    pub submit_s: f64,
    /// runtime at requested parallelism (s)
    pub total_work_s: f64,
    pub done_work_s: f64,
    pub state: JobState,
    /// GPU·s consumed so far (Tiresias priority input)
    pub attained_gpu_s: f64,
    /// user marked the job inelastic (§5.1)
    pub elastic: bool,
    /// per-machine allocation (machine index -> gpus)
    pub placement: Vec<(usize, u32)>,
    pub finish_s: Option<f64>,
    /// count of scaling operations applied (for spike accounting)
    pub n_scales: u32,
}

impl SimJob {
    pub fn from_trace(t: &TraceJob) -> SimJob {
        SimJob {
            id: t.id,
            model: t.model,
            requested_p: t.gpus,
            submit_s: t.submit_s,
            total_work_s: t.duration_s(),
            done_work_s: 0.0,
            state: JobState::Pending,
            attained_gpu_s: 0.0,
            elastic: true,
            placement: Vec::new(),
            finish_s: None,
            n_scales: 0,
        }
    }

    pub fn current_p(&self) -> u32 {
        match self.state {
            JobState::Running { p, .. } => p,
            JobState::ScalingOut { old_p, new_p, .. } => old_p.max(new_p),
            _ => 0,
        }
    }

    /// parallelism actually training right now
    pub fn training_p(&self, now: f64) -> u32 {
        match self.state {
            JobState::Running { p, paused_until } if paused_until <= now => p,
            JobState::ScalingOut { old_p, .. } => old_p,
            _ => 0,
        }
    }

    pub fn global_batch(&self) -> u32 {
        32 * self.requested_p
    }

    pub fn jct(&self) -> Option<f64> {
        self.finish_s.map(|f| f - self.submit_s)
    }
}

pub struct ClusterSim {
    pub now: f64,
    pub hw: HwConfig,
    pub n_machines: usize,
    /// free GPUs per machine
    pub free: Vec<u32>,
    pub jobs: Vec<SimJob>,
    pub scale_mode: ScaleMode,
    /// next arrival cursor into `jobs` (sorted by submit time)
    next_arrival: usize,
    pub util_ts: TimeSeries,
    pub cluster_eff_ts: TimeSeries,
    pub avg_gpu_eff_ts: TimeSeries,
    sample_every_s: f64,
    last_sample_s: f64,
    /// max parallelism used for efficiency normalisation
    pub max_p_norm: u32,
    /// every decision this engine applied, stamped with its simulation
    /// time — the replayable record of a scheduled run
    pub decision_log: Vec<(f64, Decision)>,
}

/// Re-exported policy surface (see [`crate::sched`]): policies read a
/// [`ClusterView`] and submit [`Decision`]s; this simulator is one engine
/// implementing it, the live [`master`](crate::master) is the other.
pub use crate::sched::Scheduler;

impl ClusterSim {
    pub fn new(n_machines: usize, gpus_per_machine: u32, trace: &[TraceJob], mode: ScaleMode) -> ClusterSim {
        let mut jobs: Vec<SimJob> = trace.iter().map(SimJob::from_trace).collect();
        jobs.sort_by(|a, b| a.submit_s.partial_cmp(&b.submit_s).unwrap());
        let hw = HwConfig { gpus_per_machine, ..Default::default() };
        ClusterSim {
            now: 0.0,
            hw,
            n_machines,
            free: vec![gpus_per_machine; n_machines],
            jobs,
            scale_mode: mode,
            next_arrival: 0,
            util_ts: TimeSeries::default(),
            cluster_eff_ts: TimeSeries::default(),
            avg_gpu_eff_ts: TimeSeries::default(),
            sample_every_s: 30.0,
            last_sample_s: -1.0,
            max_p_norm: 64,
            decision_log: Vec::new(),
        }
    }

    pub fn total_gpus(&self) -> u32 {
        self.n_machines as u32 * self.hw.gpus_per_machine
    }

    pub fn free_gpus(&self) -> u32 {
        self.free.iter().sum()
    }

    pub fn allocated_gpus(&self) -> u32 {
        self.total_gpus() - self.free_gpus()
    }

    /// ids of jobs submitted and not finished, split by state
    pub fn pending_jobs(&self) -> Vec<usize> {
        (0..self.jobs.len())
            .filter(|&i| {
                self.jobs[i].submit_s <= self.now && matches!(self.jobs[i].state, JobState::Pending)
            })
            .collect()
    }

    pub fn running_jobs(&self) -> Vec<usize> {
        (0..self.jobs.len())
            .filter(|&i| {
                matches!(self.jobs[i].state, JobState::Running { .. } | JobState::ScalingOut { .. })
            })
            .collect()
    }

    // -- placement ----------------------------------------------------------

    /// Allocate `p` GPUs with best-fit machine packing; respects the R1
    /// locality constraint (≤ ceil(p/m) machines) approximately by filling
    /// the emptiest-fitting machines first. Returns None if impossible.
    fn allocate(&mut self, p: u32) -> Option<Vec<(usize, u32)>> {
        if p > self.free_gpus() {
            return None;
        }
        let mut need = p;
        let mut placement = Vec::new();
        // fill machines with most free GPUs first (minimises fragmentation)
        let mut order: Vec<usize> = (0..self.n_machines).collect();
        order.sort_by_key(|&m| std::cmp::Reverse(self.free[m]));
        for m in order {
            if need == 0 {
                break;
            }
            let take = self.free[m].min(need);
            if take > 0 {
                self.free[m] -= take;
                placement.push((m, take));
                need -= take;
            }
        }
        debug_assert_eq!(need, 0);
        Some(placement)
    }

    fn release(&mut self, placement: &[(usize, u32)]) {
        for &(m, g) in placement {
            self.free[m] += g;
        }
    }

    /// Release `count` GPUs from a job's placement (most fragmented first).
    fn release_partial(&mut self, job: usize, count: u32) {
        let mut need = count;
        let mut placement = std::mem::take(&mut self.jobs[job].placement);
        placement.sort_by_key(|&(_, g)| g); // shed from smallest shards
        let mut kept = Vec::new();
        for (m, g) in placement {
            if need == 0 {
                kept.push((m, g));
            } else {
                let take = g.min(need);
                self.free[m] += take;
                need -= take;
                if g > take {
                    kept.push((m, g - take));
                }
            }
        }
        assert_eq!(need, 0, "released more GPUs than allocated");
        self.jobs[job].placement = kept;
    }

    // -- scheduler actions ----------------------------------------------------

    /// Start a pending job at parallelism `p`. Cold starts always pay
    /// context preparation (launch-up), regardless of scale mode.
    pub fn start_job(&mut self, job: usize, p: u32) -> bool {
        assert!(matches!(self.jobs[job].state, JobState::Pending));
        let Some(placement) = self.allocate(p) else { return false };
        let model = self.jobs[job].model;
        let launch = match self.scale_mode {
            ScaleMode::Ideal => 0.0,
            // launch-up ≈ context preparation for `p` workers
            _ => gpu_sim::scale_out_breakdown(model, p).context_prep_s,
        };
        self.jobs[job].placement = placement;
        self.jobs[job].state = JobState::Running { p, paused_until: self.now + launch };
        true
    }

    /// Preempt a running job back to the pending queue (Tiresias).
    pub fn preempt_job(&mut self, job: usize) {
        let placement = std::mem::take(&mut self.jobs[job].placement);
        self.release(&placement);
        self.jobs[job].state = JobState::Pending;
    }

    /// Adjust parallelism of a running job. Returns false if GPUs are not
    /// available (scale-out) or the job isn't running.
    pub fn scale_job(&mut self, job: usize, new_p: u32) -> bool {
        let JobState::Running { p, paused_until } = self.jobs[job].state else {
            return false;
        };
        if paused_until > self.now || new_p == p || new_p == 0 {
            return false;
        }
        let model = self.jobs[job].model;
        self.jobs[job].n_scales += 1;
        if new_p > p {
            let added = new_p - p;
            let Some(extra) = self.allocate(added) else {
                self.jobs[job].n_scales -= 1;
                return false;
            };
            self.jobs[job].placement.extend(extra);
            match self.scale_mode {
                ScaleMode::Ideal => {
                    self.jobs[job].state = JobState::Running { p: new_p, paused_until: self.now };
                }
                ScaleMode::Edl => {
                    // stop-free: keep training at p while joiners prepare.
                    // transient = context preparation (device model) + the
                    // switch lag the REAL leader core schedules (§4.2)
                    let b = self.jobs[job].global_batch();
                    let tput = gpu_sim::throughput(model, p, b, &self.hw);
                    let step_s = if tput > 0.0 { b as f64 / tput } else { 0.1 };
                    let prep = gpu_sim::scale_out_breakdown(model, new_p).context_prep_s;
                    let ready = self.now + prep + edl_switch_lag_cached_s(step_s);
                    self.jobs[job].state = JobState::ScalingOut { old_p: p, new_p, ready_at: ready };
                }
                ScaleMode::StopResume => {
                    let t = gpu_sim::stop_resume_overhead(model, new_p);
                    self.jobs[job].state =
                        JobState::Running { p: new_p, paused_until: self.now + t };
                }
            }
        } else {
            let removed = p - new_p;
            self.release_partial(job, removed);
            match self.scale_mode {
                ScaleMode::Ideal | ScaleMode::Edl => {
                    // graceful exit: negligible overhead (§4.2)
                    self.jobs[job].state = JobState::Running { p: new_p, paused_until: self.now };
                }
                ScaleMode::StopResume => {
                    let t = gpu_sim::stop_resume_overhead(model, new_p);
                    self.jobs[job].state =
                        JobState::Running { p: new_p, paused_until: self.now + t };
                }
            }
        }
        true
    }

    // -- decision application -------------------------------------------------

    /// Apply one typed scheduling decision (the engine half of the
    /// policy/engine split). Placement decisions use the simulator-level
    /// actions; parallelism adjustments route through the job's Table-1
    /// handle, exactly as a live engine would. Applied decisions are
    /// appended to [`ClusterSim::decision_log`] with the current
    /// simulation time; rejected ones return false and leave no trace.
    pub fn apply(&mut self, d: &Decision) -> bool {
        let ok = match *d {
            Decision::Start { job, p } => {
                self.jobs[job].submit_s <= self.now
                    && matches!(self.jobs[job].state, JobState::Pending)
                    && self.start_job(job, p)
            }
            Decision::Preempt { job } => {
                if matches!(
                    self.jobs[job].state,
                    JobState::Running { .. } | JobState::ScalingOut { .. }
                ) {
                    self.preempt_job(job);
                    true
                } else {
                    false
                }
            }
            Decision::Grow { job, to } => {
                let p = self.jobs[job].current_p();
                if to <= p {
                    false
                } else {
                    let machines = vec![String::from("sim-gpu"); (to - p) as usize];
                    self.job(job).scale_out(machines).is_ok()
                }
            }
            Decision::Shrink { job, to } => {
                let p = self.jobs[job].current_p();
                if to == 0 || to >= p {
                    false
                } else {
                    // victims are the most recently added workers, the
                    // same choice ElasticTiresias::shrink_job makes live
                    let victims: Vec<crate::transport::NodeId> = (to..p).collect();
                    self.job(job).scale_in(victims).is_ok()
                }
            }
            Decision::Migrate { job, ref remove, ref add } => {
                self.job(job).migrate(remove.clone(), add.clone()).is_ok()
            }
        };
        if ok {
            self.decision_log.push((self.now, d.clone()));
        }
        ok
    }

    // -- dynamics -------------------------------------------------------------

    /// progress rate (work-seconds per wall-second) of job i at `now`
    fn rate(&self, i: usize) -> f64 {
        let j = &self.jobs[i];
        let tp = j.training_p(self.now);
        if tp == 0 {
            return 0.0;
        }
        let b = j.global_batch();
        gpu_sim::throughput(j.model, tp, b, &self.hw)
            / gpu_sim::throughput(j.model, j.requested_p, b, &self.hw)
    }

    /// next state-change time strictly after `now` that the dynamics know
    /// about (arrival, finish, unpause, scale-out ready, sample tick)
    fn next_event_time(&self) -> Option<f64> {
        let mut t = f64::INFINITY;
        if self.next_arrival < self.jobs.len() {
            // jobs sorted by submit; find next submit > now
            for j in &self.jobs[self.next_arrival..] {
                if j.submit_s > self.now {
                    t = t.min(j.submit_s);
                    break;
                }
            }
        }
        for i in 0..self.jobs.len() {
            match self.jobs[i].state {
                JobState::Running { paused_until, .. } => {
                    if paused_until > self.now {
                        t = t.min(paused_until);
                    } else {
                        let r = self.rate(i);
                        if r > 0.0 {
                            let remain = self.jobs[i].total_work_s - self.jobs[i].done_work_s;
                            t = t.min(self.now + remain / r);
                        }
                    }
                }
                JobState::ScalingOut { ready_at, .. } => {
                    t = t.min(ready_at);
                    let r = self.rate(i);
                    if r > 0.0 {
                        let remain = self.jobs[i].total_work_s - self.jobs[i].done_work_s;
                        t = t.min(self.now + remain / r);
                    }
                }
                _ => {}
            }
        }
        // metric sampling tick
        t = t.min(self.last_sample_s.max(0.0) + self.sample_every_s);
        if t.is_finite() {
            Some(t)
        } else {
            None
        }
    }

    fn advance_to(&mut self, t: f64) {
        let dt = t - self.now;
        debug_assert!(dt >= -1e-9);
        if dt > 0.0 {
            for i in 0..self.jobs.len() {
                let r = self.rate(i);
                let tp = self.jobs[i].training_p(self.now);
                if r > 0.0 {
                    self.jobs[i].done_work_s =
                        (self.jobs[i].done_work_s + r * dt).min(self.jobs[i].total_work_s);
                }
                // attained service counts held GPUs (Tiresias semantics)
                let held = self.jobs[i].current_p();
                let _ = tp;
                if held > 0 {
                    self.jobs[i].attained_gpu_s += held as f64 * dt;
                }
            }
        }
        self.now = t;
    }

    fn handle_transitions(&mut self) {
        for i in 0..self.jobs.len() {
            // arrivals handled implicitly via pending_jobs(); advance cursor
            match self.jobs[i].state.clone() {
                JobState::ScalingOut { old_p: _, new_p, ready_at } if ready_at <= self.now => {
                    let stop = match self.scale_mode {
                        ScaleMode::Edl => gpu_sim::edl_stop_time(self.jobs[i].model),
                        _ => 0.0,
                    };
                    self.jobs[i].state =
                        JobState::Running { p: new_p, paused_until: self.now + stop };
                }
                _ => {}
            }
            // completion
            if matches!(self.jobs[i].state, JobState::Running { .. } | JobState::ScalingOut { .. })
                && self.jobs[i].done_work_s >= self.jobs[i].total_work_s - 1e-9
            {
                let placement = std::mem::take(&mut self.jobs[i].placement);
                self.release(&placement);
                self.jobs[i].state = JobState::Finished { at: self.now };
                self.jobs[i].finish_s = Some(self.now);
            }
        }
        while self.next_arrival < self.jobs.len()
            && self.jobs[self.next_arrival].submit_s <= self.now
        {
            self.next_arrival += 1;
        }
    }

    fn sample_metrics(&mut self) {
        if self.now - self.last_sample_s < self.sample_every_s - 1e-9 {
            return;
        }
        self.last_sample_s = self.now;
        let total = self.total_gpus() as f64;
        let util = self.allocated_gpus() as f64 / total;
        // per-GPU efficiency: training GPUs get efficiency(model, p);
        // paused/preparing GPUs contribute 0 (the Fig 11 spikes)
        let mut eff_sum = 0.0;
        let mut active = 0.0;
        for i in 0..self.jobs.len() {
            let j = &self.jobs[i];
            let tp = j.training_p(self.now);
            if tp > 0 {
                let e = gpu_sim::efficiency(j.model, tp, j.global_batch(), self.max_p_norm, &self.hw);
                eff_sum += e * tp as f64;
            }
            active += j.current_p() as f64;
        }
        self.util_ts.push(self.now, util);
        self.cluster_eff_ts.push(self.now, eff_sum / total);
        self.avg_gpu_eff_ts.push(self.now, if active > 0.0 { eff_sum / active } else { 0.0 });
    }

    /// Run until every job finishes (or `max_t`), calling the scheduler
    /// after each event.
    pub fn run(&mut self, sched: &mut dyn Scheduler, max_t: f64) {
        self.run_with(|sim| sched.replan(sim), max_t)
    }

    /// [`ClusterSim::run`], but every replan reads through a
    /// [`SnapshotCtl`](crate::sched::SnapshotCtl) — the same view
    /// assembly the sharded live master uses. Since accepted decisions
    /// refresh their own job's row eagerly, a policy observes exactly
    /// what it would observe against the engine directly, so the
    /// decision log must come out byte-identical (the golden test in
    /// `rust/tests/sched_policies.rs` holds both engines to that).
    pub fn run_snapshot(&mut self, sched: &mut dyn Scheduler, max_t: f64) {
        self.run_with(
            |sim| {
                let mut ctl = crate::sched::SnapshotCtl::new(sim);
                sched.replan(&mut ctl);
            },
            max_t,
        )
    }

    /// The event loop with an arbitrary replan callback — what `run` uses
    /// and what decision-log replay / oracle tests drive directly.
    pub fn run_with<F: FnMut(&mut ClusterSim)>(&mut self, mut replan: F, max_t: f64) {
        replan(self);
        self.sample_metrics();
        let mut guard = 0u64;
        while let Some(t) = self.next_event_time() {
            guard += 1;
            assert!(guard < 50_000_000, "simulator event-loop runaway");
            if t > max_t {
                self.advance_to(max_t);
                self.handle_transitions();
                break;
            }
            self.advance_to(t);
            self.handle_transitions();
            replan(self);
            self.handle_transitions(); // a replan may complete/transition
            self.sample_metrics();
            if self.jobs.iter().all(|j| matches!(j.state, JobState::Finished { .. })) {
                break;
            }
        }
    }

    /// Replay a recorded decision log (timestamps + decisions, as
    /// captured in [`ClusterSim::decision_log`]) with no policy in the
    /// loop. Every decision must apply cleanly at its recorded time;
    /// returns the number of decisions applied.
    pub fn replay(&mut self, log: &[(f64, Decision)], max_t: f64) -> usize {
        let mut next = 0usize;
        self.run_with(
            |sim| {
                while next < log.len() && log[next].0 <= sim.now {
                    let (t, ref d) = log[next];
                    assert!(
                        sim.apply(d),
                        "replay: decision {d:?} recorded at t={t} rejected at t={}",
                        sim.now
                    );
                    next += 1;
                }
            },
            max_t,
        );
        next
    }

    pub fn jcts(&self) -> Vec<f64> {
        self.jobs.iter().filter_map(|j| j.jct()).collect()
    }

    /// Table-1 control handle for job `job` — the simulator's
    /// [`JobControl`] implementation. Workers of a simulated job are the
    /// virtual ids `0..p` (`status().workers`), so policies pick scale-in
    /// victims exactly as they do against a live job.
    pub fn job(&mut self, job: usize) -> SimJobHandle<'_> {
        SimJobHandle { sim: self, job }
    }
}

// ---------------------------------------------------------------------------
// the simulator as a scheduling engine
// ---------------------------------------------------------------------------

impl ClusterView for ClusterSim {
    fn now_s(&self) -> f64 {
        self.now
    }
    fn n_machines(&self) -> usize {
        self.n_machines
    }
    fn gpus_per_machine(&self) -> u32 {
        self.hw.gpus_per_machine
    }
    fn total_gpus(&self) -> u32 {
        ClusterSim::total_gpus(self)
    }
    fn free_gpus(&self) -> u32 {
        ClusterSim::free_gpus(self)
    }
    fn max_p_norm(&self) -> u32 {
        self.max_p_norm
    }
    fn n_jobs(&self) -> usize {
        self.jobs.len()
    }
    fn job_view(&self, job: usize) -> JobView {
        let j = &self.jobs[job];
        let submitted = j.submit_s <= self.now;
        let (pending, running, finished, adjustable) = match j.state {
            JobState::Pending => (submitted, false, false, false),
            JobState::Running { paused_until, .. } => {
                (false, true, false, paused_until <= self.now)
            }
            JobState::ScalingOut { .. } => (false, true, false, false),
            JobState::Finished { .. } => (false, false, true, false),
        };
        JobView {
            id: j.id,
            model: j.model,
            requested_p: j.requested_p,
            current_p: j.current_p(),
            global_batch: j.global_batch(),
            submitted,
            pending,
            running,
            finished,
            adjustable,
            elastic: j.elastic,
            submit_s: j.submit_s,
            attained_gpu_s: j.attained_gpu_s,
        }
    }
    fn predicted_throughput(&self, job: usize, p: u32) -> f64 {
        let j = &self.jobs[job];
        gpu_sim::throughput(j.model, p, j.global_batch(), &self.hw)
    }
    fn predicted_efficiency(&self, job: usize, p: u32, max_p: u32) -> f64 {
        let j = &self.jobs[job];
        gpu_sim::efficiency(j.model, p, j.global_batch(), max_p, &self.hw)
    }
}

impl ClusterCtl for ClusterSim {
    fn submit(&mut self, d: Decision) -> bool {
        self.apply(&d)
    }
}

// ---------------------------------------------------------------------------
// Table-1 job control in simulation
// ---------------------------------------------------------------------------

/// A borrowed [`JobControl`] view of one simulated job. Scaling costs are
/// charged per [`ScaleMode`] exactly as in [`ClusterSim::scale_job`];
/// the §3.1 contract maps onto simulator state: a paused or mid-scale-out
/// job reports [`ElasticError::AdjustmentInFlight`].
pub struct SimJobHandle<'a> {
    sim: &'a mut ClusterSim,
    job: usize,
}

impl SimJobHandle<'_> {
    /// index of the underlying job in `sim.jobs`
    pub fn index(&self) -> usize {
        self.job
    }

    /// current parallelism if the job can accept an adjustment NOW
    fn adjustable_p(&self) -> Result<u32, ElasticError> {
        match self.sim.jobs[self.job].state {
            JobState::Running { p, paused_until } => {
                if paused_until > self.sim.now {
                    Err(ElasticError::AdjustmentInFlight)
                } else {
                    Ok(p)
                }
            }
            JobState::ScalingOut { .. } => Err(ElasticError::AdjustmentInFlight),
            _ => Err(ElasticError::InvalidRequest("job is not running".into())),
        }
    }

    fn scale_to(&mut self, new_p: u32) -> Result<(), ElasticError> {
        if self.sim.scale_job(self.job, new_p) {
            Ok(())
        } else {
            Err(ElasticError::Aborted("simulator rejected the adjustment".into()))
        }
    }
}

impl JobControl for SimJobHandle<'_> {
    fn scale_out(&mut self, machines: Vec<String>) -> Result<(), ElasticError> {
        let p = self.adjustable_p()?;
        let added = machines.len() as u32;
        if added == 0 {
            return Ok(());
        }
        if added > self.sim.free_gpus() {
            return Err(ElasticError::InsufficientResources(format!(
                "want {added} more GPUs, {} free",
                self.sim.free_gpus()
            )));
        }
        self.scale_to(p + added)
    }

    fn scale_in(&mut self, workers: Vec<NodeId>) -> Result<(), ElasticError> {
        let p = self.adjustable_p()?;
        if let Some(&bad) = workers.iter().find(|&&w| w >= p) {
            return Err(ElasticError::UnknownWorker(bad));
        }
        let n = workers.len() as u32;
        if n == 0 {
            return Ok(());
        }
        if n >= p {
            return Err(ElasticError::InvalidRequest(
                "scale-in would remove every worker".into(),
            ));
        }
        self.scale_to(p - n)
    }

    fn migrate(&mut self, remove: Vec<NodeId>, add: Vec<String>) -> Result<(), ElasticError> {
        let p = self.adjustable_p()?;
        if let Some(&bad) = remove.iter().find(|&&w| w >= p) {
            return Err(ElasticError::UnknownWorker(bad));
        }
        let (removed, added) = (remove.len() as u32, add.len() as u32);
        if removed >= p + added {
            return Err(ElasticError::InvalidRequest("migration would empty the job".into()));
        }
        let new_p = p + added - removed;
        if new_p > p && new_p - p > self.sim.free_gpus() {
            return Err(ElasticError::InsufficientResources(format!(
                "want {} more GPUs, {} free",
                new_p - p,
                self.sim.free_gpus()
            )));
        }
        if new_p == p {
            // pure placement move: one merged switch, negligible cost at
            // this level of abstraction (the paper's merged migration)
            self.sim.jobs[self.job].n_scales += 1;
            return Ok(());
        }
        self.scale_to(new_p)
    }

    fn profile(
        &mut self,
        min_p: u32,
        _steps_per_level: u64,
    ) -> Result<Vec<ProfileRow>, ElasticError> {
        // the simulator profiles analytically from the calibrated device
        // model instead of paying simulated steps per level
        let p = self.adjustable_p()?;
        let j = &self.sim.jobs[self.job];
        let b = j.global_batch();
        let mut rows: Vec<ProfileRow> = (min_p.max(1)..=p)
            .rev()
            .map(|q| {
                let th = gpu_sim::throughput(j.model, q, b, &self.sim.hw);
                ProfileRow {
                    parallelism: q,
                    throughput: th,
                    per_gpu_throughput: th / q as f64,
                    efficiency: 0.0,
                }
            })
            .collect();
        crate::api::normalise_efficiency(&mut rows);
        Ok(rows)
    }

    fn status(&mut self) -> Result<JobStatus, ElasticError> {
        let rate = self.sim.rate(self.job);
        let j = &self.sim.jobs[self.job];
        let p = j.current_p();
        // one machine label per virtual worker, in placement order —
        // mirrors the live leader's per-worker machine report
        let mut worker_machines = Vec::with_capacity(p as usize);
        for &(m, g) in &j.placement {
            for _ in 0..g {
                worker_machines.push(format!("m{m}"));
            }
        }
        Ok(JobStatus {
            parallelism: p,
            // work-seconds completed stands in for the step counter
            step: j.done_work_s as u64,
            epoch: 0,
            throughput_sps: rate * j.global_batch() as f64,
            last_loss: f32::NAN,
            workers: (0..p).collect(),
            worker_machines,
        })
    }

    fn checkpoint(&mut self, _path: &str) -> Result<(), ElasticError> {
        // instantaneous at this level of abstraction (charged inside
        // stop_resume_overhead when the scheduler preempts)
        Ok(())
    }

    fn restore(&mut self, _path: &str) -> Result<(), ElasticError> {
        Ok(())
    }

    fn stop(&mut self) -> Result<(), ElasticError> {
        let placement = std::mem::take(&mut self.sim.jobs[self.job].placement);
        self.sim.release(&placement);
        self.sim.jobs[self.job].state = JobState::Finished { at: self.sim.now };
        self.sim.jobs[self.job].finish_s = Some(self.sim.now);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedulers::FifoScheduler;
    use crate::trace::TraceJob;

    fn mk_trace(n: usize, gap: f64, gpus: u32, dur: f64) -> Vec<TraceJob> {
        (0..n)
            .map(|i| TraceJob {
                id: i as u64,
                submit_s: i as f64 * gap,
                gpus,
                service_gpu_s: dur * gpus as f64,
                model: Dnn::ResNet50,
            })
            .collect()
    }

    #[test]
    fn single_job_runs_to_completion() {
        let trace = mk_trace(1, 0.0, 4, 100.0);
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
        let mut sched = FifoScheduler::default();
        sim.run(&mut sched, 1e7);
        let j = &sim.jobs[0];
        assert!(matches!(j.state, JobState::Finished { .. }));
        // Ideal mode: no launch overhead, so JCT == duration
        assert!((j.jct().unwrap() - 100.0).abs() < 1.0, "jct={:?}", j.jct());
        assert_eq!(sim.free_gpus(), 8);
    }

    #[test]
    fn launch_overhead_charged_outside_ideal() {
        let trace = mk_trace(1, 0.0, 4, 100.0);
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Edl);
        let mut sched = FifoScheduler::default();
        sim.run(&mut sched, 1e7);
        let jct = sim.jobs[0].jct().unwrap();
        assert!(jct > 110.0, "launch-up should delay completion: {jct}");
    }

    #[test]
    fn queueing_when_cluster_full() {
        // 3 jobs of 8 GPUs on an 8-GPU machine: must serialise
        let trace = mk_trace(3, 1.0, 8, 50.0);
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
        let mut sched = FifoScheduler::default();
        sim.run(&mut sched, 1e7);
        let jcts = sim.jcts();
        assert_eq!(jcts.len(), 3);
        let mut finishes: Vec<f64> = sim.jobs.iter().map(|j| j.finish_s.unwrap()).collect();
        finishes.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(finishes[1] >= finishes[0] + 49.0);
        assert!(finishes[2] >= finishes[1] + 49.0);
    }

    #[test]
    fn scale_out_ideal_speeds_up_job() {
        let trace = mk_trace(1, 0.0, 2, 100.0);
        // replan that scales the job to 4 GPUs immediately
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
        sim.run_with(
            |sim| {
                for i in sim.pending_jobs() {
                    sim.start_job(i, 2);
                }
                for i in sim.running_jobs() {
                    if sim.jobs[i].current_p() == 2 {
                        sim.scale_job(i, 4);
                    }
                }
            },
            1e7,
        );
        let jct = sim.jobs[0].jct().unwrap();
        assert!(jct < 100.0, "scaled job should finish faster: {jct}");
        assert_eq!(sim.free_gpus(), 8);
    }

    #[test]
    fn edl_scale_out_keeps_training_during_prep() {
        let trace = mk_trace(1, 0.0, 2, 200.0);
        fn scale_once(sim: &mut ClusterSim, done: &mut bool) {
            for i in sim.pending_jobs() {
                sim.start_job(i, 2);
            }
            if !*done {
                for i in sim.running_jobs() {
                    if let JobState::Running { paused_until, .. } = sim.jobs[i].state {
                        if paused_until <= sim.now && sim.scale_job(i, 4) {
                            *done = true;
                        }
                    }
                }
            }
        }
        let mut edl = ClusterSim::new(1, 8, &trace, ScaleMode::Edl);
        let mut done = false;
        edl.run_with(|sim| scale_once(sim, &mut done), 1e7);
        let mut sr = ClusterSim::new(1, 8, &trace, ScaleMode::StopResume);
        let mut done = false;
        sr.run_with(|sim| scale_once(sim, &mut done), 1e7);
        let jct_edl = edl.jobs[0].jct().unwrap();
        let jct_sr = sr.jobs[0].jct().unwrap();
        assert!(
            jct_edl < jct_sr,
            "EDL scaling must beat stop-resume: edl={jct_edl:.1} sr={jct_sr:.1}"
        );
        assert_eq!(edl.jobs[0].n_scales, 1);
    }

    #[test]
    fn scale_in_releases_gpus() {
        let trace = mk_trace(1, 0.0, 4, 1000.0);
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Edl);
        let mut shrunk = false;
        // don't run to completion; stop mid-flight and check allocation
        sim.run_with(
            |sim| {
                for i in sim.pending_jobs() {
                    sim.start_job(i, 4);
                }
                if !shrunk && sim.now > 50.0 {
                    for i in sim.running_jobs() {
                        if sim.scale_job(i, 2) {
                            shrunk = true;
                        }
                    }
                }
            },
            200.0,
        );
        assert_eq!(sim.jobs[0].current_p(), 2);
        assert_eq!(sim.free_gpus(), 6);
    }

    #[test]
    fn metrics_sampled() {
        let trace = mk_trace(2, 10.0, 4, 120.0);
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
        sim.run(&mut FifoScheduler::default(), 1e7);
        assert!(sim.util_ts.len() > 3);
        assert!(sim.cluster_eff_ts.len() == sim.util_ts.len());
        // utilization peaked at 1.0 while both jobs ran
        let peak = sim.util_ts.points.iter().map(|&(_, v)| v).fold(0.0, f64::max);
        assert!(peak >= 0.99, "peak={peak}");
    }

    #[test]
    fn job_handle_speaks_table1() {
        let trace = mk_trace(1, 0.0, 2, 1000.0);
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
        sim.start_job(0, 2);
        sim.job(0).scale_out(vec!["m1".into()]).unwrap();
        assert_eq!(sim.jobs[0].current_p(), 3);
        let st = sim.job(0).status().unwrap();
        assert_eq!(st.workers, vec![0, 1, 2]);
        assert!(matches!(
            sim.job(0).scale_in(vec![9]),
            Err(ElasticError::UnknownWorker(9))
        ));
        sim.job(0).scale_in(vec![2]).unwrap();
        assert_eq!(sim.jobs[0].current_p(), 2);
        let rows = sim.job(0).profile(1, 0).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().any(|r| (r.efficiency - 1.0).abs() < 1e-9));
    }

    #[test]
    fn job_handle_reports_adjustment_in_flight() {
        // EDL mode: a scale-out leaves the job mid-preparation, so the
        // next adjustment gets the typed §3.1 retry error
        let trace = mk_trace(1, 0.0, 2, 10_000.0);
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Edl);
        sim.start_job(0, 2);
        let JobState::Running { paused_until, .. } = sim.jobs[0].state else {
            panic!("job should be running")
        };
        sim.now = paused_until + 1.0; // skip past the launch pause
        sim.job(0).scale_out(vec!["m1".into()]).unwrap();
        assert!(matches!(sim.jobs[0].state, JobState::ScalingOut { .. }));
        assert_eq!(
            sim.job(0).scale_out(vec!["m2".into()]),
            Err(ElasticError::AdjustmentInFlight)
        );
    }

    #[test]
    fn switch_lag_comes_from_real_leader_core() {
        // k = ceil(T_a / T_b): the lag covers the allowance and is
        // quantised to whole mini-batches by the real state machine
        let lag = edl_switch_lag_s(0.1, 500.0);
        assert!((0.45..=0.75).contains(&lag), "lag={lag}");
        // coarse steps: one step already exceeds the allowance
        let lag2 = edl_switch_lag_s(2.0, 500.0);
        assert!((2.0..=4.0).contains(&lag2), "lag2={lag2}");
        // a larger allowance pushes the switch further out
        let lag3 = edl_switch_lag_s(0.1, 2000.0);
        assert!(lag3 > lag, "lag3={lag3} lag={lag}");
    }

    #[test]
    fn decisions_apply_log_and_replay() {
        let trace = mk_trace(2, 0.0, 2, 400.0);
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
        assert!(sim.apply(&Decision::Start { job: 0, p: 2 }));
        assert!(sim.apply(&Decision::Grow { job: 0, to: 4 }));
        assert_eq!(sim.jobs[0].current_p(), 4);
        assert!(sim.apply(&Decision::Shrink { job: 0, to: 3 }));
        assert_eq!(sim.jobs[0].current_p(), 3);
        // rejected decisions leave no trace
        assert!(!sim.apply(&Decision::Grow { job: 0, to: 2 }), "grow must raise p");
        assert!(!sim.apply(&Decision::Shrink { job: 0, to: 0 }), "shrink to 0 is invalid");
        assert!(!sim.apply(&Decision::Start { job: 0, p: 1 }), "job 0 is not pending");
        assert!(sim.apply(&Decision::Start { job: 1, p: 2 }));
        assert!(sim.apply(&Decision::Preempt { job: 1 }));
        assert!(matches!(sim.jobs[1].state, JobState::Pending));
        assert_eq!(sim.decision_log.len(), 5);

        // a fresh sim replaying the log lands in the identical state
        let log = sim.decision_log.clone();
        let mut sim2 = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
        for (_, d) in &log {
            assert!(sim2.apply(d));
        }
        assert_eq!(sim2.jobs[0].current_p(), 3);
        assert!(matches!(sim2.jobs[1].state, JobState::Pending));
        assert_eq!(sim2.decision_log, log);
    }

    #[test]
    fn preempt_requeues_job() {
        let trace = mk_trace(1, 0.0, 4, 500.0);
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
        sim.start_job(0, 4);
        assert_eq!(sim.free_gpus(), 4);
        sim.preempt_job(0);
        assert_eq!(sim.free_gpus(), 8);
        assert!(matches!(sim.jobs[0].state, JobState::Pending));
    }
}
