//! §4.1 — leader-election latency against the coordination service.
//! The paper measured 7 ms average / 33 ms max with 256 workers on etcd;
//! this bench runs 256 contending clients against the TCP KV service and
//! reports per-client election latency, plus uncontended single-client
//! latency.

use edl::coordsvc::{KvClient, KvServer};
use edl::util::json::{write_results, Json};
use edl::util::stats;
use std::time::Instant;

fn main() {
    let server = KvServer::start().unwrap();
    let addr = server.addr.clone();

    // ---- uncontended election ----------------------------------------------
    let mut c = KvClient::connect(&addr).unwrap();
    let mut solo = Vec::new();
    for i in 0..200 {
        let t0 = Instant::now();
        c.elect(&format!("solo{i}"), "me", 5_000).unwrap();
        solo.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    println!(
        "uncontended election: mean={:.2}ms p50={:.2}ms max={:.2}ms",
        stats::mean(&solo),
        stats::median(&solo),
        stats::max(&solo)
    );

    // ---- 256 contending workers (the paper's setup) -------------------------
    let n = 256;
    let lats: Vec<f64> = std::thread::scope(|s| {
        (0..n)
            .map(|i| {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = KvClient::connect(&addr).unwrap();
                    let t0 = Instant::now();
                    let w = c.elect("bigjob", &format!("w{i}"), 30_000).unwrap();
                    let dt = t0.elapsed().as_secs_f64() * 1e3;
                    (w, dt)
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .map(|(w, dt)| {
                assert!(!w.is_empty());
                dt
            })
            .collect()
    });
    let mean = stats::mean(&lats);
    let max = stats::max(&lats);
    println!("256-way contended election: mean={mean:.2}ms p95={:.2}ms max={max:.2}ms", stats::percentile(&lats, 95.0));
    println!("(paper: 7 ms average, 33 ms max with 256 workers on etcd)");

    assert!(mean < 500.0, "contended election too slow: {mean:.1}ms");

    let mut out = Json::obj();
    out.set("solo_mean_ms", stats::mean(&solo))
        .set("contended_mean_ms", mean)
        .set("contended_max_ms", max)
        .set("paper_mean_ms", 7.0)
        .set("paper_max_ms", 33.0);
    let path = write_results("perf_leader_election", &out).unwrap();
    println!("results -> {}", path.display());
}
