//! Dynamic data pipeline (§4.3 of the paper).
//!
//! The dataset is logically divided into `d` partitions at the *metadata*
//! level (offset + length into the corpus); the leader owns a per-epoch
//! random permutation of partition indices and hands partitions to workers
//! **on demand**. Workers report their intra-partition offset with every
//! mini-batch; when a worker leaves (graceful exit or failure), the
//! unprocessed remainder of its partition returns to the pool, so each
//! epoch visits every sample exactly once — no repetition, no omission —
//! regardless of the scale in/out schedule. That invariant is
//! property-tested below under random scale event schedules.

pub mod corpus;
pub mod schedule;

use crate::util::rng::Pcg;
use crate::wire::{Dec, Enc};
use std::collections::HashMap;

/// Metadata handed to a worker for one partition (file path analogue is an
/// offset range into the corpus; see DESIGN.md §1 HDFS substitution).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PartitionMeta {
    pub id: u64,
    /// starting sample index within the dataset
    pub start: u64,
    /// number of samples in this assignment (may be a partial remainder)
    pub len: u64,
    pub epoch: u64,
}

impl PartitionMeta {
    pub fn encode(&self, e: &mut Enc) {
        e.u64(self.id).u64(self.start).u64(self.len).u64(self.epoch);
    }
    pub fn decode(d: &mut Dec) -> crate::wire::Result<PartitionMeta> {
        Ok(PartitionMeta { id: d.u64()?, start: d.u64()?, len: d.u64()?, epoch: d.u64()? })
    }
}

/// Logical partition table over a dataset of `n_samples` samples.
#[derive(Debug, Clone)]
pub struct PartitionTable {
    pub n_samples: u64,
    pub n_partitions: u64,
    pub partition_size: u64,
}

impl PartitionTable {
    /// `d` partitions, sized so partitions stay large enough for
    /// high-bandwidth reads (the paper's guidance: d ≫ workers).
    /// The effective partition count is adjusted so every partition is
    /// non-empty (ceil sizing can otherwise leave trailing empties).
    pub fn new(n_samples: u64, n_partitions: u64) -> PartitionTable {
        assert!(n_partitions > 0 && n_samples >= n_partitions);
        let partition_size = n_samples.div_ceil(n_partitions);
        PartitionTable {
            n_samples,
            n_partitions: n_samples.div_ceil(partition_size),
            partition_size,
        }
    }

    pub fn partition(&self, idx: u64, epoch: u64) -> PartitionMeta {
        assert!(idx < self.n_partitions);
        let start = idx * self.partition_size;
        let len = self.partition_size.min(self.n_samples - start);
        PartitionMeta { id: idx, start, len, epoch }
    }
}

/// Leader-side dynamic assigner: epoch permutation + in-flight tracking +
/// remainder pool.
#[derive(Clone)]
pub struct Assigner {
    table: PartitionTable,
    rng: Pcg,
    pub epoch: u64,
    /// permuted partition indices not yet assigned this epoch
    queue: Vec<u64>,
    /// partial partitions returned by departing workers: (meta of remainder)
    returned: Vec<PartitionMeta>,
    /// in-flight: worker -> (assignment, consumed samples within it)
    in_flight: HashMap<u32, (PartitionMeta, u64)>,
    /// samples fully consumed this epoch (for accounting)
    consumed: u64,
}

impl Assigner {
    pub fn new(table: PartitionTable, seed: u64) -> Assigner {
        let mut a = Assigner {
            table,
            rng: Pcg::seeded(seed),
            epoch: 0,
            queue: Vec::new(),
            returned: Vec::new(),
            in_flight: HashMap::new(),
            consumed: 0,
        };
        a.start_epoch();
        a
    }

    fn start_epoch(&mut self) {
        let mut idx: Vec<u64> = (0..self.table.n_partitions).collect();
        // Fisher–Yates permutation — the paper's "random permutation of the
        // indexes of the partitions"
        for i in (1..idx.len()).rev() {
            let j = self.rng.gen_range(i as u64 + 1) as usize;
            idx.swap(i, j);
        }
        self.queue = idx;
        self.consumed = 0;
    }

    pub fn epoch_total(&self) -> u64 {
        self.table.n_samples
    }

    /// Samples consumed so far this epoch (completed assignments only).
    pub fn consumed(&self) -> u64 {
        self.consumed
    }

    /// Next partition for `worker`, or None when the epoch's pool is empty
    /// (in-flight work may still be running). Returned partial remainders
    /// are preferred to keep fragmentation bounded.
    pub fn next_partition(&mut self, worker: u32) -> Option<PartitionMeta> {
        // A re-request while an assignment is outstanding means the worker
        // lost/abandoned it (e.g. a restore raced the Assign reply):
        // credit reported progress and return the remainder to the pool.
        if self.in_flight.contains_key(&worker) {
            self.worker_left(worker);
        }
        let meta = if let Some(m) = self.returned.pop() {
            m
        } else if let Some(idx) = self.queue.pop() {
            self.table.partition(idx, self.epoch)
        } else {
            return None;
        };
        self.in_flight.insert(worker, (meta, 0));
        Some(meta)
    }

    /// Record progress: `consumed` samples of the worker's current
    /// assignment are done (piggybacked on gradient sync requests, §4.3).
    pub fn report_progress(&mut self, worker: u32, consumed_in_partition: u64) {
        if let Some((meta, done)) = self.in_flight.get_mut(&worker) {
            assert!(
                consumed_in_partition <= meta.len,
                "worker {worker} progressed past its assignment"
            );
            assert!(consumed_in_partition >= *done, "progress went backwards");
            *done = consumed_in_partition;
        }
    }

    /// Worker finished its current assignment entirely.
    pub fn complete(&mut self, worker: u32) {
        if let Some((meta, _)) = self.in_flight.remove(&worker) {
            self.consumed += meta.len;
        }
    }

    /// Worker leaves (graceful exit or failure): unprocessed remainder goes
    /// back to the pool for another worker (§4.3). Consumed prefix counts.
    pub fn worker_left(&mut self, worker: u32) {
        if let Some((meta, done)) = self.in_flight.remove(&worker) {
            self.consumed += done;
            if done < meta.len {
                self.returned.push(PartitionMeta {
                    id: meta.id,
                    start: meta.start + done,
                    len: meta.len - done,
                    epoch: meta.epoch,
                });
            }
        }
    }

    /// Abandon every in-flight assignment (used after a checkpoint
    /// restore: workers no longer hold their shards). Consumed prefixes
    /// count as done; remainders return to the pool.
    pub fn reset_in_flight(&mut self) {
        let mut workers: Vec<u32> = self.in_flight.keys().copied().collect();
        // sorted so the returned-remainder pool order (and therefore every
        // subsequent Assign) is independent of hash order — the leader
        // core's deterministic-replay guarantee depends on it
        workers.sort_unstable();
        for w in workers {
            self.worker_left(w);
        }
    }

    /// Sample offset of `meta` within its full logical shard: how many of
    /// the shard's samples earlier holders already consumed. This is the
    /// migrated per-shard RNG stream position (one draw per sample, so
    /// the leader re-derives a remainder assignment's stream with
    /// `schedule::shard_stream_at(seed, epoch, shard, offset)`).
    pub fn shard_offset(&self, meta: &PartitionMeta) -> u64 {
        meta.start - self.table.partition(meta.id, meta.epoch).start
    }

    /// True when every sample of the epoch is consumed and nothing is in
    /// flight.
    pub fn epoch_exhausted(&self) -> bool {
        self.queue.is_empty() && self.returned.is_empty() && self.in_flight.is_empty()
    }

    /// Pool empty (workers should finish in-flight work then wait).
    pub fn pool_empty(&self) -> bool {
        self.queue.is_empty() && self.returned.is_empty()
    }

    /// Advance to the next epoch. Panics if the current epoch is incomplete
    /// (would violate the no-omission guarantee).
    pub fn advance_epoch(&mut self) {
        assert!(self.epoch_exhausted(), "advance_epoch with work outstanding");
        assert_eq!(self.consumed, self.table.n_samples, "epoch under/over-consumed");
        self.epoch += 1;
        self.start_epoch();
    }

    /// Sample ranges of the CURRENT epoch not yet credited as consumed:
    /// unassigned partitions, returned remainders, and the unconsumed
    /// tails of in-flight assignments. Used by the chaos harness to
    /// rebuild its independent coverage tracker from a decoded checkpoint
    /// (everything outside these ranges is credited after the restore's
    /// `reset_in_flight`).
    pub fn outstanding_ranges(&self) -> Vec<(u64, u64)> {
        let mut v: Vec<(u64, u64)> = self
            .queue
            .iter()
            .map(|&idx| {
                let m = self.table.partition(idx, self.epoch);
                (m.start, m.len)
            })
            .collect();
        v.extend(self.returned.iter().map(|m| (m.start, m.len)));
        v.extend(self.in_flight.values().map(|(m, done)| (m.start + done, m.len - done)));
        v
    }

    /// Fold the assignment state into a hasher (model-checker state
    /// dedup). The RNG is included: since it survives encode/decode it is
    /// first-class trajectory state — two assigners that agree on
    /// everything else but hold different generator positions would
    /// produce different future permutations. `returned` is hashed
    /// in order — it is a stack, so order affects future assignments.
    pub fn hash_state<H: std::hash::Hasher>(&self, h: &mut H) {
        let (rng_state, rng_inc) = self.rng.to_parts();
        h.write_u64(rng_state);
        h.write_u64(rng_inc);
        h.write_u64(self.table.n_samples);
        h.write_u64(self.table.n_partitions);
        h.write_u64(self.epoch);
        h.write_u64(self.consumed);
        h.write_usize(self.queue.len());
        for q in &self.queue {
            h.write_u64(*q);
        }
        h.write_usize(self.returned.len());
        for m in &self.returned {
            h.write_u64(m.id);
            h.write_u64(m.start);
            h.write_u64(m.len);
            h.write_u64(m.epoch);
        }
        let mut keys: Vec<u32> = self.in_flight.keys().copied().collect();
        keys.sort_unstable();
        h.write_usize(keys.len());
        for w in keys {
            let (m, done) = &self.in_flight[&w];
            h.write_u32(w);
            h.write_u64(m.id);
            h.write_u64(m.start);
            h.write_u64(m.len);
            h.write_u64(m.epoch);
            h.write_u64(*done);
        }
    }

    /// Serialise assigner state for leader handoff (§4.2: the departing
    /// leader sends the permutation list + progress to the new leader) and
    /// for checkpointing.
    pub fn encode(&self, e: &mut Enc) {
        e.pcg(&self.rng);
        e.u64(self.table.n_samples).u64(self.table.n_partitions).u64(self.epoch).u64(self.consumed);
        e.u64s(&self.queue);
        e.u32(self.returned.len() as u32);
        for m in &self.returned {
            m.encode(e);
        }
        e.u32(self.in_flight.len() as u32);
        let mut keys: Vec<_> = self.in_flight.keys().copied().collect();
        keys.sort_unstable();
        for w in keys {
            let (meta, done) = &self.in_flight[&w];
            e.u32(w).u64(*done);
            meta.encode(e);
        }
    }

    /// Restore from `encode` output. The RNG state is carried across the
    /// roundtrip, so the restored assigner continues the EXACT permutation
    /// stream of the original — epoch permutations after a leader handoff
    /// or checkpoint restore match an uninterrupted run bit for bit. (It
    /// used to restart from the seed, which preserved §4.3 coverage but
    /// silently diverged the training trajectory; see DESIGN.md §11.)
    pub fn decode(d: &mut Dec) -> crate::wire::Result<Assigner> {
        let rng = d.pcg()?;
        let n_samples = d.u64()?;
        let n_partitions = d.u64()?;
        let epoch = d.u64()?;
        let consumed = d.u64()?;
        let queue = d.u64s()?;
        let n_ret = d.u32()? as usize;
        let returned = (0..n_ret).map(|_| PartitionMeta::decode(d)).collect::<crate::wire::Result<_>>()?;
        let n_if = d.u32()? as usize;
        let mut in_flight = HashMap::new();
        for _ in 0..n_if {
            let w = d.u32()?;
            let done = d.u64()?;
            let meta = PartitionMeta::decode(d)?;
            in_flight.insert(w, (meta, done));
        }
        Ok(Assigner {
            table: PartitionTable::new(n_samples, n_partitions),
            rng,
            epoch,
            queue,
            returned,
            in_flight,
            consumed,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn collect_epoch(a: &mut Assigner, workers: &[u32]) -> Vec<(u64, u64)> {
        // drive all workers round-robin to exhaustion; return consumed
        // (start, len) ranges
        let mut ranges = Vec::new();
        let mut active: Vec<u32> = workers.to_vec();
        while !a.epoch_exhausted() {
            let mut progressed = false;
            for &w in active.clone().iter() {
                if let Some(m) = a.next_partition(w) {
                    ranges.push((m.start, m.len));
                    a.complete(w);
                    progressed = true;
                }
            }
            if !progressed {
                break;
            }
            active.rotate_left(1);
        }
        ranges
    }

    fn assert_exact_cover(ranges: &[(u64, u64)], n: u64) {
        let mut seen = vec![false; n as usize];
        for &(s, l) in ranges {
            for i in s..s + l {
                assert!(!seen[i as usize], "sample {i} repeated");
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "omitted samples");
    }

    #[test]
    fn partition_table_covers_dataset() {
        let t = PartitionTable::new(1003, 10);
        let total: u64 = (0..10).map(|i| t.partition(i, 0).len).sum();
        assert_eq!(total, 1003);
        // last partition is the short one
        assert_eq!(t.partition(9, 0).len, 1003 - 9 * t.partition_size);
    }

    #[test]
    fn epoch_exactly_once_static_workers() {
        let mut a = Assigner::new(PartitionTable::new(1000, 16), 1);
        let ranges = collect_epoch(&mut a, &[1, 2, 3]);
        assert_exact_cover(&ranges, 1000);
        a.advance_epoch();
        assert_eq!(a.epoch, 1);
    }

    #[test]
    fn permutation_differs_across_epochs() {
        let mut a = Assigner::new(PartitionTable::new(160, 16), 2);
        let e0: Vec<u64> = a.queue.clone();
        let r = collect_epoch(&mut a, &[1]);
        assert_exact_cover(&r, 160);
        a.advance_epoch();
        assert_ne!(a.queue, e0, "epoch permutations should differ");
    }

    #[test]
    fn departed_worker_remainder_reassigned() {
        let mut a = Assigner::new(PartitionTable::new(100, 4), 3);
        let m = a.next_partition(1).unwrap();
        a.report_progress(1, 10);
        a.worker_left(1); // 10 consumed, len-10 returned
        let m2 = a.next_partition(2).unwrap();
        assert_eq!(m2.id, m.id);
        assert_eq!(m2.start, m.start + 10);
        assert_eq!(m2.len, m.len - 10);
    }

    #[test]
    fn failure_with_zero_progress_returns_whole_partition() {
        let mut a = Assigner::new(PartitionTable::new(100, 4), 4);
        let m = a.next_partition(1).unwrap();
        a.worker_left(1);
        let m2 = a.next_partition(2).unwrap();
        assert_eq!((m2.start, m2.len), (m.start, m.len));
    }

    #[test]
    fn double_request_requeues_lost_assignment() {
        // a re-request supersedes the outstanding assignment: the old one
        // returns to the pool so nothing is omitted
        let mut a = Assigner::new(PartitionTable::new(100, 4), 5);
        let m1 = a.next_partition(1).unwrap();
        let m2 = a.next_partition(1).unwrap();
        // the lost assignment returns to the pool (it may be re-issued to
        // the same worker immediately — it is fresh state either way)
        assert_eq!((m1.start, m1.len), (m2.start, m2.len));
        a.complete(1);
        // drain: the re-queued m1 must come back out
        let mut seen = vec![m2.len];
        while let Some(m) = a.next_partition(2) {
            seen.push(m.len);
            a.complete(2);
        }
        assert_eq!(seen.iter().sum::<u64>(), 100, "full coverage despite requeue");
    }

    #[test]
    #[should_panic(expected = "progressed past")]
    fn overrun_progress_rejected() {
        let mut a = Assigner::new(PartitionTable::new(100, 4), 6);
        let m = a.next_partition(1).unwrap();
        a.report_progress(1, m.len + 1);
    }

    #[test]
    fn exactly_once_under_random_scaling_property() {
        // The paper's core §4.3 claim: arbitrary join/leave schedules never
        // repeat or omit a sample within an epoch.
        prop::check("exactly-once-under-scaling", 60, |rng| {
            let n = 200 + rng.gen_range(2000);
            let parts = 4 + rng.gen_range(28);
            let mut a = Assigner::new(PartitionTable::new(n, parts), rng.next_u64());
            let mut covered: Vec<(u64, u64)> = Vec::new();
            let mut next_worker: u32 = 0;
            // map worker -> (meta, progress)
            let mut running: Vec<(u32, PartitionMeta, u64)> = Vec::new();
            // seed a couple of workers
            for _ in 0..(1 + rng.gen_range(4)) {
                next_worker += 1;
                if let Some(m) = a.next_partition(next_worker) {
                    running.push((next_worker, m, 0));
                }
            }
            let mut steps = 0;
            while !(a.epoch_exhausted() && running.is_empty()) {
                steps += 1;
                if steps > 100_000 {
                    return Err("did not terminate".into());
                }
                match rng.gen_range(10) {
                    // scale out: add a worker
                    0 | 1 => {
                        next_worker += 1;
                        if let Some(m) = a.next_partition(next_worker) {
                            running.push((next_worker, m, 0));
                        }
                    }
                    // scale in / failure: remove a random worker
                    2 | 3 if !running.is_empty() => {
                        let i = rng.gen_range(running.len() as u64) as usize;
                        let (w, m, done) = running.swap_remove(i);
                        // consumed prefix counts as covered
                        if done > 0 {
                            covered.push((m.start, done));
                        }
                        a.report_progress(w, done);
                        a.worker_left(w);
                    }
                    // progress: a random worker consumes some samples
                    _ if !running.is_empty() => {
                        let i = rng.gen_range(running.len() as u64) as usize;
                        let (w, m, done) = running[i];
                        let room = m.len - done;
                        let take = 1 + rng.gen_range(room.max(1));
                        let take = take.min(room);
                        let new_done = done + take;
                        a.report_progress(w, new_done);
                        if new_done == m.len {
                            covered.push((m.start, m.len));
                            a.complete(w);
                            // grab the next partition if any
                            if let Some(m2) = a.next_partition(w) {
                                running[i] = (w, m2, 0);
                            } else {
                                running.swap_remove(i);
                            }
                        } else {
                            running[i].2 = new_done;
                        }
                    }
                    _ => {}
                }
            }
            // verify exactly-once coverage
            let mut seen = vec![false; n as usize];
            for &(s, l) in &covered {
                for i in s..s + l {
                    if seen[i as usize] {
                        return Err(format!("sample {i} repeated"));
                    }
                    seen[i as usize] = true;
                }
            }
            if !seen.iter().all(|&b| b) {
                let missing = seen.iter().filter(|&&b| !b).count();
                return Err(format!("{missing} samples omitted"));
            }
            if a.consumed() != n {
                return Err(format!("consumed {} != {}", a.consumed(), n));
            }
            Ok(())
        });
    }

    #[test]
    fn handoff_roundtrip_preserves_state() {
        let mut a = Assigner::new(PartitionTable::new(500, 8), 7);
        let _m1 = a.next_partition(1).unwrap();
        a.report_progress(1, 5);
        let m2 = a.next_partition(2).unwrap();
        a.complete(2);
        let _ = m2;
        let mut e = Enc::new();
        a.encode(&mut e);
        let bytes = e.into_bytes();
        let mut b = Assigner::decode(&mut Dec::new(&bytes)).unwrap();
        assert_eq!(b.epoch, a.epoch);
        assert_eq!(b.consumed, a.consumed);
        assert_eq!(b.queue, a.queue);
        // worker 1 still in flight after handoff; leaving returns remainder
        b.worker_left(1);
        let m = b.next_partition(3).unwrap();
        assert_eq!(m.start % b.table.partition_size, 5);
    }

    #[test]
    fn restore_resumes_permutation_stream() {
        // Regression for the reseed-on-restore bug: `decode` used to
        // rebuild the RNG from a seed, so every epoch permutation AFTER a
        // restore diverged from an uninterrupted run. The generator state
        // now rides the encoding: restore-then-run must produce the same
        // permutation stream as never-restored, indefinitely.
        let mut a = Assigner::new(PartitionTable::new(240, 12), 42);
        let mut e = Enc::new();
        a.encode(&mut e);
        let bytes = e.into_bytes();
        let mut b = Assigner::decode(&mut Dec::new(&bytes)).unwrap();
        assert_eq!(b.queue, a.queue);
        for epoch in 0..4 {
            let ra = collect_epoch(&mut a, &[1]);
            let rb = collect_epoch(&mut b, &[1]);
            assert_eq!(ra, rb, "epoch {epoch}: assignment streams diverged after restore");
            a.advance_epoch();
            b.advance_epoch();
            assert_eq!(
                b.queue, a.queue,
                "epoch {}: post-restore permutation diverged from uninterrupted run",
                epoch + 1
            );
        }
        // and a restore taken mid-stream (after epochs already elapsed)
        // resumes that later position, not epoch 0's
        let mut e = Enc::new();
        a.encode(&mut e);
        let bytes = e.into_bytes();
        let c = Assigner::decode(&mut Dec::new(&bytes)).unwrap();
        assert_eq!(c.queue, a.queue);
        assert_eq!(c.epoch, 4);
    }

    #[test]
    fn schedule_is_worker_count_independent_property() {
        // EasyScale-style claim (DESIGN.md §11): the logical-shard
        // schedule — which samples belong to which shard, the per-epoch
        // shard permutation, and each shard's internal sample order — is
        // a function of (seed, epoch, shard) only. Physical worker count
        // P and scale-event timing affect WHO consumes a shard, never
        // WHAT the shard's sample stream is.
        prop::check("schedule-worker-count-independent", 20, |rng| {
            let n = 200 + rng.gen_range(1000);
            let parts = 4 + rng.gen_range(12);
            let seed = rng.next_u64();
            let table = PartitionTable::new(n, parts);
            let canonical = schedule::global_order(seed, 0, &table);
            for &p in &[1usize, 2, 3, 5] {
                let mut a = Assigner::new(table.clone(), seed);
                // the live queue must match the pure derivation before a
                // single assignment happens
                let mut want_queue = schedule::epoch_permutation(seed, 0, a.table.n_partitions);
                want_queue.reverse(); // queue pops from the back
                if a.queue != want_queue {
                    return Err(format!("P={p}: live queue != pure epoch permutation"));
                }
                // per-shard consumption traces under a random scale storm
                let mut order: Vec<Vec<u64>> = vec![Vec::new(); a.table.n_partitions as usize];
                let mut running: Vec<(u32, PartitionMeta, u64)> = Vec::new();
                let mut next_worker: u32 = 0;
                for _ in 0..p {
                    next_worker += 1;
                    if let Some(m) = a.next_partition(next_worker) {
                        running.push((next_worker, m, 0));
                    }
                }
                let mut steps = 0;
                while !(a.epoch_exhausted() && running.is_empty()) {
                    steps += 1;
                    if steps > 100_000 {
                        return Err("did not terminate".into());
                    }
                    match rng.gen_range(10) {
                        0 => {
                            next_worker += 1;
                            if let Some(m) = a.next_partition(next_worker) {
                                running.push((next_worker, m, 0));
                            }
                        }
                        1 if !running.is_empty() => {
                            let i = rng.gen_range(running.len() as u64) as usize;
                            let (w, m, done) = running.swap_remove(i);
                            for s in m.start..m.start + done {
                                order[m.id as usize].push(s);
                            }
                            a.report_progress(w, done);
                            a.worker_left(w);
                        }
                        _ if !running.is_empty() => {
                            let i = rng.gen_range(running.len() as u64) as usize;
                            let (w, m, done) = running[i];
                            let room = m.len - done;
                            let take = (1 + rng.gen_range(room.max(1))).min(room);
                            let new_done = done + take;
                            a.report_progress(w, new_done);
                            if new_done == m.len {
                                for s in m.start..m.start + m.len {
                                    order[m.id as usize].push(s);
                                }
                                a.complete(w);
                                if let Some(m2) = a.next_partition(w) {
                                    running[i] = (w, m2, 0);
                                } else {
                                    running.swap_remove(i);
                                }
                            } else {
                                running[i].2 = new_done;
                            }
                        }
                        _ => {}
                    }
                }
                // global logical order: shards in permutation order, each
                // shard's samples in its consumption order — must equal
                // the canonical pure derivation for EVERY P and storm
                let got: Vec<u64> = schedule::epoch_permutation(seed, 0, a.table.n_partitions)
                    .into_iter()
                    .flat_map(|idx| order[idx as usize].clone())
                    .collect();
                if got != canonical {
                    return Err(format!(
                        "P={p}: global sample order diverged from canonical schedule"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn total_coverage_with_handoff_mid_epoch() {
        // serialise mid-epoch, restore, finish: still exactly-once
        let mut a = Assigner::new(PartitionTable::new(300, 6), 8);
        let mut covered = Vec::new();
        let m = a.next_partition(1).unwrap();
        a.report_progress(1, 7);
        covered.push((m.start, 7));
        let mut e = Enc::new();
        a.encode(&mut e);
        let bytes = e.into_bytes();
        let mut b = Assigner::decode(&mut Dec::new(&bytes)).unwrap();
        b.worker_left(1); // credits 7 consumed, returns remainder
        let ranges = {
            let mut r = Vec::new();
            while let Some(m) = b.next_partition(9) {
                r.push((m.start, m.len));
                b.complete(9);
            }
            r
        };
        covered.extend(ranges);
        let mut seen = vec![false; 300];
        for &(s, l) in &covered {
            for i in s..s + l {
                assert!(!seen[i as usize]);
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
        assert!(b.epoch_exhausted());
    }
}
