//! Worker ⇄ leader wire messages (the §4.2 scaling-protocol messages) for
//! the multi-process deployment. Each type carries a hand-rolled wire
//! encoding (see `wire`); the in-process trainer moves the equivalent
//! typed-channel messages (`coordinator::WorkerEvent`/`CtrlMsg`) without
//! serialisation.
//!
//! The scheduler ⇄ leader half of the control plane (the paper's Table-1
//! API) lives in [`crate::api`]: a versioned `wire::Envelope` carrying
//! `api::Request`/`api::Response`, served by `api::JobServer`.

use crate::data::PartitionMeta;
use crate::transport::NodeId;
use crate::wire::{Dec, Enc, Result, WireError};

/// Worker → leader messages.
#[derive(Debug, Clone, PartialEq)]
pub enum ToLeader {
    /// background-thread registration during stop-free scale-out (§4.2)
    Register { worker: NodeId, machine: String },
    /// context preparation finished; blocked awaiting OK
    Ready { worker: NodeId },
    /// per-mini-batch gradient synchronisation request; doubles as
    /// liveness signal and carries data-pipeline progress (§4.3)
    SyncRequest { worker: NodeId, step: u64, step_ms: f64, partition: u64, offset: u64 },
    /// worker needs the next data partition
    PartitionRequest { worker: NodeId },
    /// graceful exit report: unprocessed remainder of current partition
    Goodbye { worker: NodeId, partition: u64, offset: u64 },
}

/// Leader → worker messages.
#[derive(Debug, Clone, PartialEq)]
pub enum FromLeader {
    /// reply to PartitionRequest
    Assign { partition: PartitionMeta },
    /// no partitions left in this epoch
    EpochEnd { epoch: u64 },
    /// continue training, no change
    Proceed,
    /// switch to a new communication topology at mini-batch `at_step`
    Switch {
        at_step: u64,
        version: u64,
        ring: Vec<NodeId>,
        local_batch: u32,
        /// worker that must broadcast the model to joiners (one sender, §4.2)
        broadcast_src: NodeId,
        /// joining workers awaiting the model
        joiners: Vec<NodeId>,
        /// whether the receiving worker should exit at the switch point
        exit: bool,
    },
    /// job complete
    Stop,
    /// OK + future timestamp for a blocked new worker (stop-free scaling)
    Ok { join_at_step: u64 },
}

// ---------------------------------------------------------------------------
// wire encodings
// ---------------------------------------------------------------------------

impl ToLeader {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            ToLeader::Register { worker, machine } => {
                e.u8(1).u32(*worker).str(machine);
            }
            ToLeader::Ready { worker } => {
                e.u8(2).u32(*worker);
            }
            ToLeader::SyncRequest { worker, step, step_ms, partition, offset } => {
                e.u8(3).u32(*worker).u64(*step).f64(*step_ms).u64(*partition).u64(*offset);
            }
            ToLeader::PartitionRequest { worker } => {
                e.u8(4).u32(*worker);
            }
            ToLeader::Goodbye { worker, partition, offset } => {
                e.u8(5).u32(*worker).u64(*partition).u64(*offset);
            }
        }
        e.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<ToLeader> {
        let mut d = Dec::new(buf);
        match d.u8()? {
            1 => Ok(ToLeader::Register { worker: d.u32()?, machine: d.str()? }),
            2 => Ok(ToLeader::Ready { worker: d.u32()? }),
            3 => Ok(ToLeader::SyncRequest {
                worker: d.u32()?,
                step: d.u64()?,
                step_ms: d.f64()?,
                partition: d.u64()?,
                offset: d.u64()?,
            }),
            4 => Ok(ToLeader::PartitionRequest { worker: d.u32()? }),
            5 => Ok(ToLeader::Goodbye { worker: d.u32()?, partition: d.u64()?, offset: d.u64()? }),
            tag => Err(WireError::BadTag { tag: tag as u32, ty: "ToLeader" }),
        }
    }
}

impl FromLeader {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            FromLeader::Assign { partition } => {
                e.u8(1);
                partition.encode(&mut e);
            }
            FromLeader::EpochEnd { epoch } => {
                e.u8(2).u64(*epoch);
            }
            FromLeader::Proceed => {
                e.u8(3);
            }
            FromLeader::Switch { at_step, version, ring, local_batch, broadcast_src, joiners, exit } => {
                e.u8(4).u64(*at_step).u64(*version);
                e.u32s(ring);
                e.u32(*local_batch).u32(*broadcast_src);
                e.u32s(joiners);
                e.bool(*exit);
            }
            FromLeader::Stop => {
                e.u8(5);
            }
            FromLeader::Ok { join_at_step } => {
                e.u8(6).u64(*join_at_step);
            }
        }
        e.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<FromLeader> {
        let mut d = Dec::new(buf);
        match d.u8()? {
            1 => Ok(FromLeader::Assign { partition: PartitionMeta::decode(&mut d)? }),
            2 => Ok(FromLeader::EpochEnd { epoch: d.u64()? }),
            3 => Ok(FromLeader::Proceed),
            4 => Ok(FromLeader::Switch {
                at_step: d.u64()?,
                version: d.u64()?,
                ring: d.u32s()?,
                local_batch: d.u32()?,
                broadcast_src: d.u32()?,
                joiners: d.u32s()?,
                exit: d.bool()?,
            }),
            5 => Ok(FromLeader::Stop),
            6 => Ok(FromLeader::Ok { join_at_step: d.u64()? }),
            tag => Err(WireError::BadTag { tag: tag as u32, ty: "FromLeader" }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_leader_roundtrips() {
        for m in [
            ToLeader::Register { worker: 3, machine: "m1".into() },
            ToLeader::Ready { worker: 3 },
            ToLeader::SyncRequest { worker: 1, step: 42, step_ms: 123.4, partition: 7, offset: 99 },
            ToLeader::PartitionRequest { worker: 2 },
            ToLeader::Goodbye { worker: 1, partition: 7, offset: 512 },
        ] {
            assert_eq!(ToLeader::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn from_leader_roundtrips() {
        for m in [
            FromLeader::EpochEnd { epoch: 3 },
            FromLeader::Proceed,
            FromLeader::Switch {
                at_step: 100,
                version: 2,
                ring: vec![1, 2, 3],
                local_batch: 8,
                broadcast_src: 1,
                joiners: vec![3],
                exit: false,
            },
            FromLeader::Stop,
            FromLeader::Ok { join_at_step: 101 },
        ] {
            assert_eq!(FromLeader::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn bad_tag_rejected() {
        assert!(matches!(FromLeader::decode(&[99]), Err(WireError::BadTag { .. })));
        assert!(matches!(ToLeader::decode(&[0]), Err(WireError::BadTag { .. })));
    }
}
