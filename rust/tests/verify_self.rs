//! Self-tests for `edl verify` (DESIGN.md §7): the repo must lint clean
//! under the checked-in allowlist, the allowlist must be tight (it may
//! suppress only the justified sites, nothing else), and — the part that
//! keeps the lints honest — every lint must provably catch a seeded
//! regression injected into the REAL tree through the exact code path
//! `edl verify` runs. A lint that cannot fail is not a lint.

use std::path::{Path, PathBuf};

use edl::verify::model::{explore, ModelScope};
use edl::verify::{collect_sources, lints, locks, run_lints, tags, Allowlist, SourceFile};

fn repo_path(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join(rel)
}

/// The real tree exactly as `edl verify` scans it (src + integration tests).
fn real_sources() -> Vec<SourceFile> {
    let src = repo_path("rust/src");
    let tests = repo_path("rust/tests");
    let sources = collect_sources(&[src.as_path(), tests.as_path()]).expect("scan tree");
    assert!(sources.len() > 30, "suspiciously small tree: {} files", sources.len());
    sources
}

fn real_allowlist() -> Allowlist {
    Allowlist::load(&repo_path("rust/verify_allow.txt")).expect("parse allowlist")
}

#[test]
fn repo_lints_clean_under_the_checked_in_allowlist() {
    let report = run_lints(&real_sources(), &real_allowlist());
    assert!(
        report.diagnostics.is_empty(),
        "tree must lint clean; got:\n{}",
        report
            .diagnostics
            .iter()
            .map(|d| format!("  {d}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(report.suppressed >= 2, "allowlist entries went unused — prune them");
}

#[test]
fn allowlist_is_tight() {
    // with NO allowlist, the only findings may be the two justified
    // exception classes — anything else means a real regression crept in
    // (or an allowlist entry is broader than its justification)
    let report = run_lints(&real_sources(), &Allowlist::default());
    assert!(!report.diagnostics.is_empty(), "expected the known panic-path exceptions");
    for d in &report.diagnostics {
        assert_eq!(d.lint, "panic-path", "unexpected non-exception finding: {d}");
        assert!(
            d.msg.contains("try_into") || d.msg.contains("spawn job server"),
            "finding outside the justified exception classes: {d}"
        );
    }
}

/// Append `extra` to the real file whose path contains `suffix`, returning
/// the mutated tree — a seeded regression in production code, linted
/// through the production pass.
fn seed_into(suffix: &str, extra: &str) -> Vec<SourceFile> {
    let mut sources = real_sources();
    let sf = sources
        .iter_mut()
        .find(|s| s.path.contains(suffix))
        .unwrap_or_else(|| panic!("{suffix} not in tree"));
    sf.text.push_str(extra);
    sources
}

#[test]
fn determinism_lint_catches_seeded_clock_read() {
    let sources = seed_into(
        "/coordinator/core.rs",
        "\nfn _seeded_regression() -> u128 { std::time::Instant::now().elapsed().as_millis() }\n",
    );
    let report = run_lints(&sources, &real_allowlist());
    assert!(
        report.diagnostics.iter().any(|d| {
            d.lint == "determinism"
                && d.file.contains("/coordinator/core.rs")
                && d.msg.contains("Instant")
        }),
        "seeded Instant::now in a pure module went undetected: {:?}",
        report.diagnostics
    );
}

#[test]
fn panic_lint_catches_seeded_unwrap_on_protocol_path() {
    let sources = seed_into(
        "/rpc/mod.rs",
        "\nfn _seeded_regression(o: Option<u32>) -> u32 { o.unwrap() }\n",
    );
    let report = run_lints(&sources, &real_allowlist());
    assert!(
        report.diagnostics.iter().any(|d| {
            d.lint == "panic-path" && d.file.contains("/rpc/mod.rs") && d.msg.contains("`unwrap`")
        }),
        "seeded unwrap on a protocol path went undetected: {:?}",
        report.diagnostics
    );
}

#[test]
fn lock_lint_catches_seeded_order_inversion() {
    // two functions taking the same two locks in opposite orders, seeded
    // into a real shell module, must surface as a cycle
    let sources = seed_into(
        "/transport/mod.rs",
        r#"
struct _SeededRegression {
    a: std::sync::Mutex<u32>,
    b: std::sync::Mutex<u32>,
}
impl _SeededRegression {
    fn ab(&self) {
        let _g = self.a.lock().unwrap();
        let _h = self.b.lock().unwrap();
    }
    fn ba(&self) {
        let _g = self.b.lock().unwrap();
        let _h = self.a.lock().unwrap();
    }
}
"#,
    );
    let report = run_lints(&sources, &real_allowlist());
    assert!(
        report
            .diagnostics
            .iter()
            .any(|d| d.lint == "lock-order" && d.msg.contains("cycle")),
        "seeded lock-order inversion went undetected: {:?}",
        report.diagnostics
    );
}

#[test]
fn wire_lint_catches_variant_missing_from_every_test() {
    let src = SourceFile {
        path: "rust/src/rpc/mod.rs".into(),
        text: "pub enum ToLeader { Hello { m: String }, Sync { step: u64 }, Goodbye }\n\
               mod tests { fn t() { let _ = ToLeader::Hello { m: String::new() }; \
               let _ = ToLeader::Sync { step: 3 }; } }"
            .into(),
    };
    let diags = lints::wire_coverage_for(&[src], &[("/rpc/mod.rs", "ToLeader")]);
    assert_eq!(diags.len(), 1, "{diags:?}");
    assert!(diags[0].msg.contains("ToLeader::Goodbye"), "{}", diags[0].msg);
}

const TAG_FIXTURE: &str = r#"
const FAMILY_RING: u32 = 0x4000_0000;
const FAMILY_BCAST: u32 = 0x8000_0000;
fn gen_field(step: u64) -> u32 {
    (step % 0x7FFF) as u32
}
pub fn ring_tag(step: u64, phase: u32, seq: u32) -> u32 {
    FAMILY_RING | (phase << 29) | (gen_field(step) << 14) | (seq & 0x3FFF)
}
pub fn bcast_tag(step: u64, seq: u32) -> u32 {
    FAMILY_BCAST | (gen_field(step) << 14) | (seq & 0x3FFF)
}
"#;

const TRANSPORT_FIXTURE: &str =
    "pub mod tag { pub const RPC: u32 = 0x3000; pub const KV: u32 = 0x3001; }";

fn tag_diags(allreduce_src: &str) -> Vec<String> {
    let ar = SourceFile { path: "rust/src/allreduce/mod.rs".into(), text: allreduce_src.into() };
    let tp = SourceFile {
        path: "rust/src/transport/mod.rs".into(),
        text: TRANSPORT_FIXTURE.into(),
    };
    tags::tag_layout(&ar, &tp).into_iter().map(|d| d.msg).collect()
}

#[test]
fn tag_lint_catches_seeded_field_alias() {
    assert!(tag_diags(TAG_FIXTURE).is_empty(), "fixture layout must be clean");
    // the PR-2 regression: generation shifted one bit short, overlapping seq
    let aliased = TAG_FIXTURE.replace("gen_field(step) << 14", "gen_field(step) << 13");
    let msgs = tag_diags(&aliased);
    assert!(msgs.iter().any(|m| m.contains("overlap")), "{msgs:?}");
}

#[test]
fn tag_lint_catches_seeded_family_collision() {
    let shared = TAG_FIXTURE.replace("0x8000_0000", "0x4000_0000");
    let msgs = tag_diags(&shared);
    assert!(msgs.iter().any(|m| m.contains("famil")), "{msgs:?}");
}

#[test]
fn lock_lint_fixture_interprocedural_cycle() {
    // the inter-procedural shape: outer holds A and calls inner (takes B),
    // other takes B then A — a cycle across three functions
    let src = SourceFile {
        path: "rust/src/fixture.rs".into(),
        text: r#"
impl S {
    fn outer(&self) {
        let _g = self.a.lock().unwrap();
        self.inner();
    }
    fn inner(&self) {
        let _g = self.b.lock().unwrap();
    }
    fn other(&self) {
        let _g = self.b.lock().unwrap();
        let _h = self.a.lock().unwrap();
    }
}
"#
        .into(),
    };
    let diags = locks::lock_order(&[src]);
    assert!(!diags.is_empty(), "inter-procedural cycle went undetected");
}

// ---------------------------------------------------------------------------
// bounded model checker
// ---------------------------------------------------------------------------

/// A scope small enough for debug-mode CI: one concurrent op, two steps of
/// horizon. The release-mode `edl verify` run explores the full scope.
fn small_scope() -> ModelScope {
    ModelScope { max_ops: 1, step_cap: 2, max_states: 200_000, ..Default::default() }
}

#[test]
fn model_checker_exhausts_small_scope_with_no_violation() {
    let report = explore(small_scope());
    if let Some((what, trace)) = &report.violation {
        panic!("model violation: {what}\ntrace:\n  {}", trace.join("\n  "));
    }
    assert!(report.exhausted, "state cap hit: {} states", report.states);
    assert!(
        report.states > 100,
        "scope suspiciously shallow: {} states — did the enabled-set collapse?",
        report.states
    );
}

#[test]
fn model_exploration_is_deterministic() {
    let a = explore(small_scope());
    let b = explore(small_scope());
    assert_eq!(a.states, b.states);
    assert_eq!(a.transitions, b.transitions);
    assert_eq!(a.max_depth, b.max_depth);
}
