//! Deterministic chaos suite: hundreds of seeded fault schedules driven
//! through the REAL `LeaderCore` by `edl::harness::chaos`, with every
//! invariant checked after every event (see DESIGN.md §6).
//!
//! On failure the suite SHRINKS the seed to its shortest failing script
//! prefix and prints the exact local repro:
//!
//! ```text
//! EDL_CHAOS_SEED=0x2a cargo test -q chaos
//! ```
//!
//! Knobs:
//!  * `EDL_CHAOS_SEED=<n|0xhex>` — run exactly one seed (debugging);
//!  * `EDL_CHAOS_ITERS=<n>` — extended run of n seeds (nightly CI).

use edl::harness::chaos::{run_schedule, run_seed, ChaosSchedule};

/// Default per-push seed count (acceptance: ≥ 200 schedules).
const DEFAULT_SEEDS: u64 = 220;

fn parse_env_u64(name: &str) -> Option<u64> {
    let raw = std::env::var(name).ok()?;
    let raw = raw.trim();
    if let Some(hex) = raw.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).ok()
    } else {
        raw.parse().ok()
    }
}

/// Shrink a failing seed: find the shortest failing prefix of its script.
fn shrink_and_report(seed: u64) -> String {
    let full = ChaosSchedule::generate(seed, usize::MAX);
    let mut shortest = full.events.len();
    let mut last_err = match run_schedule(&full) {
        Err(e) => format!("{e}"),
        Ok(_) => return format!("seed {seed:#x} failed once but passes on replay (FLAKY — \
                                 determinism broken?)"),
    };
    for n in 0..full.events.len() {
        if let Err(e) = run_schedule(&full.prefix(n)) {
            shortest = n;
            last_err = format!("{e}");
            break;
        }
    }
    format!(
        "chaos seed {seed:#x} fails (shortest failing prefix: {shortest}/{} events)\n\
         reproduce locally with:\n\n    EDL_CHAOS_SEED={seed:#x} cargo test -q chaos\n\n{last_err}",
        full.events.len()
    )
}

fn run_seed_range(from: u64, n: u64) {
    let mut failures = Vec::new();
    let mut barriers = 0u64;
    let mut hits = 0u64;
    for seed in from..from + n {
        match run_seed(seed) {
            Ok(r) => {
                barriers += r.barriers;
                hits += r.fault_hits;
            }
            Err(_) => failures.push(seed),
        }
    }
    if let Some(&seed) = failures.first() {
        panic!(
            "{} of {n} chaos seeds failed ({failures:?})\n\n{}",
            failures.len(),
            shrink_and_report(seed)
        );
    }
    // the harness must actually exercise the stack, not vacuously pass
    assert!(barriers > n * 50, "suspiciously few barriers across all seeds: {barriers}");
    assert!(hits > n, "fault plans almost never fired: {hits} hits over {n} seeds");
}

#[test]
fn two_hundred_seeded_schedules_hold_every_invariant() {
    if let Some(seed) = parse_env_u64("EDL_CHAOS_SEED") {
        // single-seed debug mode: print the full event log on failure
        match run_seed(seed) {
            Ok(r) => {
                eprintln!(
                    "seed {seed:#x}: OK — {} barriers, {} events, {} fault hits, {} leader \
                     generation(s), log {} lines",
                    r.barriers,
                    r.events_run,
                    r.fault_hits,
                    r.generations,
                    r.log.len()
                );
            }
            Err(e) => {
                eprintln!("seed {seed:#x} failure detail:\n{e}");
                panic!("{}", shrink_and_report(seed));
            }
        }
        return;
    }
    let iters = parse_env_u64("EDL_CHAOS_ITERS").unwrap_or(DEFAULT_SEEDS);
    run_seed_range(1, iters);
}

#[test]
fn same_seed_yields_byte_identical_event_logs() {
    for seed in [3u64, 17, 99] {
        let a = run_seed(seed).unwrap_or_else(|e| panic!("seed {seed:#x} failed:\n{e}"));
        let b = run_seed(seed).unwrap_or_else(|e| panic!("seed {seed:#x} failed:\n{e}"));
        assert_eq!(
            a.log.join("\n").into_bytes(),
            b.log.join("\n").into_bytes(),
            "seed {seed:#x}: two runs diverged — determinism broken"
        );
        assert_eq!(a.barriers, b.barriers);
    }
}

/// Pillar 1 end-to-end: the SAME live TCP deployment code paths
/// (`LeaderEndpoint` control plane + `run_worker` + `TcpNode` data plane)
/// run with the fault hook armed. A window of delayed control frames
/// must not stop training and must leave no protocol damage behind: the
/// job scales and stops cleanly after the window heals. (Hard
/// partitions/kills are the virtual suite's job — live, a dropped
/// barrier release costs the full data-plane timeout by design.)
#[test]
fn live_deploy_trains_through_injected_control_delays() {
    use edl::coordinator::TrainerConfig;
    use edl::data::corpus::Corpus;
    use edl::deploy::{config_digest, run_worker, LeaderEndpoint, WorkerParams};
    use edl::harness::testutil::{poll_until, wait_until, POLL_EVERY};
    use edl::harness::{FaultKind, FaultPlan, FaultRule, Family};
    use edl::transport::FaultHook;
    use edl::worker::{Backend, SimBackend};
    use std::sync::Arc;
    use std::time::Duration;

    const SAMPLES: u64 = 4096;
    let backend = SimBackend { compute_ms: 2, ..SimBackend::fast(16) };
    let digest = config_digest(SAMPLES, 1, backend.param_count(), backend.seq_len(), 0.05);
    let cfg = TrainerConfig {
        failure_timeout: Duration::from_secs(2),
        switch_allowance_ms: 300.0,
        ..TrainerConfig::default()
    };
    let endpoint = LeaderEndpoint::start(
        cfg,
        Arc::new(backend.clone()),
        SAMPLES,
        2,
        "127.0.0.1:0",
        digest,
    )
    .expect("leader endpoint");
    let leader_addr = endpoint.addr.clone();
    let spawn = |machine: &str| {
        let machine = machine.to_string();
        let leader_addr = leader_addr.clone();
        let backend = backend.clone();
        std::thread::spawn(move || {
            let corpus = Arc::new(Corpus::markov(256, backend.seq, SAMPLES, 1));
            let _ = run_worker(WorkerParams {
                leader_addr,
                machine,
                backend: Arc::new(backend),
                corpus,
                lr: 0.05,
                config_digest: digest,
                headless: false,
            });
        })
    };
    let w1 = spawn("m1");
    let _w2 = spawn("m2"); // exits at the scale-in or the final Stop
    let handle = endpoint.handle();
    let step0 = poll_until(Duration::from_secs(30), POLL_EVERY, || {
        let st = handle.call(edl::api::Request::Status).status().ok()?;
        (st.parallelism == 2 && st.step >= 5).then_some(st.step)
    })
    .expect("2-worker job must start training");

    // flaky window: every control frame to every worker delayed 30 ms —
    // training must keep advancing through it, and the §3.1 surface must
    // still answer with typed results (not hangs)
    let plan = FaultPlan::new(0xF1A6);
    plan.add(FaultRule::always(FaultKind::Delay(30)).family(Family::Rpc));
    let hook: Arc<dyn FaultHook> = plan.clone();
    endpoint.set_fault_hook(Some(hook));
    assert!(
        handle.wait_step(step0 + 20, Duration::from_secs(30)),
        "training stalled under a 30ms-delay control plane"
    );
    assert!(plan.hits() > 0, "delay rule never fired");
    endpoint.set_fault_hook(None);

    // after healing: a graceful scale-in still commits and training goes on
    let st = handle.call(edl::api::Request::Status).status().expect("status");
    assert_eq!(st.parallelism, 2, "the delay window must not cost a worker: {st:?}");
    let victim = *st.workers.last().expect("two workers");
    wait_until("post-heal scale-in to commit", Duration::from_secs(30), || {
        match handle.call(edl::api::Request::ScaleIn { workers: vec![victim] }) {
            edl::api::Response::Ok => true,
            edl::api::Response::Err(edl::api::ElasticError::AdjustmentInFlight) => false,
            other => panic!("scale-in failed: {other:?}"),
        }
    });
    let st = handle.call(edl::api::Request::Status).status().expect("status");
    assert_eq!(st.parallelism, 1, "{st:?}");
    assert!(
        handle.wait_step(st.step + 10, Duration::from_secs(30)),
        "survivor did not keep training after the scale-in"
    );

    let resp = handle.call(edl::api::Request::Stop);
    assert!(matches!(resp, edl::api::Response::Ok), "stop failed: {resp:?}");
    let _ = endpoint.join();
    let _ = w1.join();
}

/// §4.2 tentpole acceptance: a worker killed HALFWAY through a ring
/// collective must cost the job one redone step, not a checkpoint
/// restore — the survivors abort the torn collective, the leader reforms
/// the ring from live membership, and the redo commits exactly once.
/// The engine event log must show the `ring-reform` and must contain no
/// `load-checkpoint` anywhere (the mirror invariants inside the harness
/// already proved the redone reduction bit-identical to a clean run).
#[test]
fn mid_collective_kill_reforms_without_checkpoint_restore() {
    use edl::harness::chaos::ChaosEvent as E;
    for (ev, armed_line) in [
        (E::KillDuringReduceScatter, "armed kill-during-reduce-scatter"),
        (E::KillRingNeighbourPair, "armed kill-ring-neighbour-pair"),
    ] {
        let schedule = ChaosSchedule {
            seed: 0xFEED_F00D,
            founders: 4,
            n_samples: 256,
            n_partitions: 8,
            events: vec![(1500, ev), (2500, E::Calm), (2500, E::Calm)],
        };
        let r = run_schedule(&schedule)
            .unwrap_or_else(|e| panic!("{ev:?} schedule failed:\n{e}"));
        let log = r.log.join("\n");
        assert!(log.contains(armed_line), "{ev:?}: kill never armed:\n{log}");
        assert!(
            log.contains("armed-kill") && log.contains("fires victims="),
            "{ev:?}: armed kill never fired:\n{log}"
        );
        let events = r.engine_events.join("\n");
        assert!(
            events.contains("ring-reform step="),
            "{ev:?}: no abort/reform round in the engine log:\n{events}"
        );
        assert!(
            !events.contains("load-checkpoint") && !log.contains("load-checkpoint"),
            "{ev:?}: the reform escalated to a checkpoint restore:\n{events}"
        );
        assert!(r.barriers > 0, "{ev:?}: job never trained");
    }
}

#[test]
fn schedules_cover_the_whole_fault_taxonomy() {
    // across the default seed set, every chaos event kind must appear —
    // otherwise the suite silently stopped testing a failure mode
    use edl::harness::chaos::ChaosEvent as E;
    let mut kinds: std::collections::BTreeSet<&'static str> = Default::default();
    for seed in 1..=DEFAULT_SEEDS {
        for (_, ev) in ChaosSchedule::generate(seed, usize::MAX).events {
            kinds.insert(match ev {
                E::Calm => "calm",
                E::Grow(_) => "grow",
                E::Shrink(_) => "shrink",
                E::Migrate => "migrate",
                E::Storm => "storm",
                E::Kill => "kill",
                E::Partition { .. } => "partition",
                E::DelayLink { .. } => "delay",
                E::DupRelease { .. } => "duplicate",
                E::Checkpoint => "checkpoint",
                E::RestartLeader => "restart-leader",
                E::GrowGhost => "grow-ghost",
                E::KillDuringReduceScatter => "kill-during-reduce-scatter",
                E::KillDuringBroadcastRelay => "kill-during-broadcast-relay",
                E::KillRingNeighbourPair => "kill-ring-neighbour-pair",
            });
        }
    }
    for want in [
        "calm", "grow", "shrink", "migrate", "storm", "kill", "partition", "delay",
        "duplicate", "checkpoint", "restart-leader", "grow-ghost",
        "kill-during-reduce-scatter", "kill-during-broadcast-relay",
        "kill-ring-neighbour-pair",
    ] {
        assert!(kinds.contains(want), "no generated schedule contains {want:?}: {kinds:?}");
    }
}
