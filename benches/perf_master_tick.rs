//! Scheduler-throughput bench for the PR 9 datacenter master (§5–§6
//! scale claims): a real `Master` engine with the sharded inventory,
//! pipelined decision application and sim-slot jobs is loaded to a
//! Philly-scale fleet (1,000 machines × 8 slots, 200+ live jobs), hit
//! with a storm of concurrent submits, and measured on
//!
//!  * scheduler decisions/sec over a steady-state window,
//!  * tick p50/p99 latency (the master's own ring-buffer timings),
//!  * end-to-end submit→running latency across the storm,
//!
//! against an in-bench **unsharded baseline**: the pre-PR tick shape —
//! one global lock, full-fleet sweep + sort per decision, serial apply —
//! run over the same fleet sizes. Full mode asserts the master's tick
//! p99 grows sub-linearly with fleet size relative to that baseline, and
//! that the storm drains with zero lost or double-held slots (the
//! engine's own per-shard `free + held == capacity` check, re-proven
//! every tick, is reported over the wire as `conservation_ok`).
//!
//!  * `EDL_BENCH_SMOKE=1`    — tiny fleet for CI (no perf asserts)
//!  * `EDL_BENCH_BASELINE=1` — also write `BENCH_master_tick.json`

use edl::harness::testutil::poll_until;
use edl::master::proto::{MasterClient, MasterStats, SubmitSpec};
use edl::master::{MachineSpec, Master, MasterConfig};
use edl::sched::Scheduler;
use edl::schedulers::ElasticTiresias;
use edl::util::json::{write_results, Json};
use edl::util::stats;
use std::time::{Duration, Instant};

/// Fleet + load shape for one measured arm.
struct Arm {
    machines: usize,
    gpus: u32,
    rack_size: usize,
    load_jobs: usize,
    storm_jobs: usize,
    measure_s: u64,
}

struct ArmResult {
    st: MasterStats,
    decisions_per_sec: f64,
    submit_running_ms: Vec<f64>,
}

fn run_arm(a: &Arm) -> ArmResult {
    let cfg = MasterConfig {
        machines: (0..a.machines)
            .map(|i| MachineSpec { name: format!("m{i}"), gpus: a.gpus })
            .collect(),
        tick_ms: 50,
        lease_ttl_ms: 5_000,
        listen: "127.0.0.1:0".into(),
        kv_listen: "127.0.0.1:0".into(),
        worker_bin: None,
        rack_size: a.rack_size,
        sim_slots: true,
        headless_workers: false,
        pipeline: true,
        executors: 4,
        pollers: 4,
    };
    let sched: Box<dyn Scheduler + Send> =
        Box::new(ElasticTiresias::new(vec![500.0, 10_000.0], 10, 0.5));
    let master = Master::start(cfg, sched).expect("start master");
    let addr = master.addr.clone();
    let mut mc = MasterClient::connect(&addr).expect("connect");

    // -- load: long-running jobs that stay live through the window --------
    for k in 0..a.load_jobs {
        mc.submit(&SubmitSpec {
            name: format!("load{k}"),
            gpus: 1 + (k % 2) as u32,
            steps: 1_000_000_000,
            compute_ms: 2,
            ..Default::default()
        })
        .expect("submit load");
    }
    let want = a.load_jobs as u64;
    poll_until(Duration::from_secs(120), Duration::from_millis(200), || {
        (mc.stats().ok()?.jobs_running >= want).then_some(())
    })
    .unwrap_or_else(|| {
        panic!("load never reached running: {:?}", mc.stats());
    });

    // -- storm: concurrent submits, measuring submit→running end to end --
    let threads = 10usize.min(a.storm_jobs.max(1));
    let per = a.storm_jobs / threads;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut mc = MasterClient::connect(&addr).expect("storm client");
                let mut lat = Vec::with_capacity(per);
                for k in 0..per {
                    let name = format!("storm{t}x{k}");
                    let t0 = Instant::now();
                    mc.submit(&SubmitSpec {
                        name: name.clone(),
                        gpus: 1,
                        steps: 1_000_000_000,
                        compute_ms: 2,
                        ..Default::default()
                    })
                    .expect("submit storm");
                    poll_until(Duration::from_secs(120), Duration::from_millis(50), || {
                        let jobs = mc.jobs().ok()?;
                        jobs.iter()
                            .any(|j| j.name == name && j.phase == "running")
                            .then_some(())
                    })
                    .unwrap_or_else(|| panic!("storm job {name} never reached running"));
                    lat.push(t0.elapsed().as_secs_f64() * 1e3);
                }
                lat
            })
        })
        .collect();
    let mut submit_running_ms = Vec::new();
    for h in handles {
        submit_running_ms.extend(h.join().expect("storm thread"));
    }

    // -- steady-state window: decisions/sec + tick latency distribution --
    let s0 = mc.stats().expect("stats");
    std::thread::sleep(Duration::from_secs(a.measure_s));
    let st = mc.stats().expect("stats");
    let decisions_per_sec = (st.decisions - s0.decisions) as f64 / a.measure_s as f64;

    assert!(st.conservation_ok, "inventory conservation violated: {st:?}");
    for s in &st.shards {
        assert_eq!(
            s.free + s.held,
            s.capacity,
            "shard {} lost or double-held slots: {st:?}",
            s.shard
        );
    }

    mc.shutdown().expect("shutdown");
    master.join();
    ArmResult { st, decisions_per_sec, submit_running_ms }
}

/// The pre-PR tick, reproduced in-bench as the unsharded baseline: one
/// global lock around the whole machine array, a full-fleet view sweep
/// under that lock, and serial decision application that re-sorts the
/// entire fleet per decision — the shape PR 9 replaced. Returns per-tick
/// latencies in microseconds.
fn unsharded_baseline_tick_us(
    machines: usize,
    gpus: u32,
    jobs: usize,
    ticks: usize,
    decisions_per_tick: usize,
) -> Vec<f64> {
    let free = std::sync::Mutex::new(vec![gpus; machines]);
    let mut held: Vec<(usize, u32)> = Vec::new();
    let mut out = Vec::with_capacity(ticks);
    for _ in 0..ticks {
        let t0 = Instant::now();
        let mut g = free.lock().unwrap();
        // full view sweep under the global lock (what every pre-PR tick did)
        let total_free: u32 = g.iter().sum();
        let mut rows: Vec<(usize, u32)> = g.iter().copied().enumerate().collect();
        let _jobs_scanned = (0..jobs).map(|j| j % machines).sum::<usize>();
        for d in 0..decisions_per_tick {
            // serial apply: greedy most-free placement, full sort per decision
            rows.sort_by_key(|&(m, f)| (std::cmp::Reverse(f), m));
            if d % 2 == 0 && total_free > 0 {
                let (m, f) = rows[0];
                if f > 0 {
                    g[m] -= 1;
                    rows[0].1 -= 1;
                    held.push((m, 1));
                }
            } else if let Some((m, n)) = held.pop() {
                g[m] += n;
                if let Some(r) = rows.iter_mut().find(|r| r.0 == m) {
                    r.1 += n;
                }
            }
        }
        drop(g);
        out.push(t0.elapsed().as_micros() as f64);
    }
    out
}

fn arm_json(label: &str, machines: usize, slots: u32, r: &ArmResult) -> Json {
    let mut o = Json::obj();
    o.set("label", label)
        .set("machines", machines)
        .set("slots", slots as u64)
        .set("shards", r.st.shards.len() as u64)
        .set("jobs_total", r.st.jobs_total)
        .set("jobs_running", r.st.jobs_running)
        .set("ticks", r.st.ticks)
        .set("tick_p50_us", r.st.tick_p50_us)
        .set("tick_p99_us", r.st.tick_p99_us)
        .set("tick_max_us", r.st.tick_max_us)
        .set("decisions", r.st.decisions)
        .set("decisions_per_sec", r.decisions_per_sec)
        .set("submit_running_p50_ms", stats::median(&r.submit_running_ms))
        .set("submit_running_p99_ms", stats::percentile(&r.submit_running_ms, 99.0))
        .set("conservation_ok", r.st.conservation_ok);
    o
}

fn main() {
    let smoke = std::env::var("EDL_BENCH_SMOKE").is_ok();
    let mut out = Json::obj();
    out.set("smoke", smoke);

    println!("== master tick throughput: sharded+pipelined engine at fleet scale ==");
    let (arms, base_ticks, base_decisions): (Vec<Arm>, usize, usize) = if smoke {
        (
            vec![Arm {
                machines: 40,
                gpus: 4,
                rack_size: 8,
                load_jobs: 12,
                storm_jobs: 10,
                measure_s: 2,
            }],
            50,
            8,
        )
    } else {
        (
            vec![
                Arm {
                    machines: 250,
                    gpus: 8,
                    rack_size: 32,
                    load_jobs: 220,
                    storm_jobs: 100,
                    measure_s: 10,
                },
                Arm {
                    machines: 1000,
                    gpus: 8,
                    rack_size: 32,
                    load_jobs: 220,
                    storm_jobs: 100,
                    measure_s: 10,
                },
            ],
            400,
            64,
        )
    };

    let mut rows = Json::Arr(vec![]);
    let mut results = Vec::new();
    println!(
        "{:>9} {:>7} {:>7} {:>12} {:>12} {:>14} {:>16}",
        "machines", "slots", "jobs", "tick p50 us", "tick p99 us", "decisions/s", "sub->run p99 ms"
    );
    for a in &arms {
        let r = run_arm(a);
        let slots = a.machines as u32 * a.gpus;
        println!(
            "{:>9} {:>7} {:>7} {:>12} {:>12} {:>14.1} {:>16.1}",
            a.machines,
            slots,
            r.st.jobs_total,
            r.st.tick_p50_us,
            r.st.tick_p99_us,
            r.decisions_per_sec,
            stats::percentile(&r.submit_running_ms, 99.0),
        );
        rows.push(arm_json(&format!("master_{}x{}", a.machines, a.gpus), a.machines, slots, &r));
        results.push(r);
    }
    out.set("rows", rows);

    // -- in-bench unsharded baseline over the same fleet sizes ------------
    println!("\n-- unsharded pre-PR baseline (in-bench, same fleets) --");
    let mut base_rows = Json::Arr(vec![]);
    let mut base_p99 = Vec::new();
    for a in &arms {
        let ts =
            unsharded_baseline_tick_us(a.machines, a.gpus, a.load_jobs, base_ticks, base_decisions);
        let (p50, p99) = (stats::median(&ts), stats::percentile(&ts, 99.0));
        println!("{:>9} machines: tick p50 {p50:.1}us p99 {p99:.1}us", a.machines);
        let mut o = Json::obj();
        o.set("machines", a.machines).set("tick_p50_us", p50).set("tick_p99_us", p99);
        base_rows.push(o);
        base_p99.push(p99);
    }
    out.set("unsharded_baseline", base_rows);

    // -- acceptance -------------------------------------------------------
    for r in &results {
        assert!(r.st.decisions > 0, "no scheduler decisions recorded");
        assert!(r.decisions_per_sec >= 0.0);
        assert!(!r.submit_running_ms.is_empty(), "storm measured nothing");
    }
    if !smoke {
        // Philly scale actually reached: ≥1,000 machines / ≥8,000 slots,
        // ≥200 concurrent jobs + a 100-submit storm, all running at once.
        let big = &results[1];
        assert!(big.st.jobs_running >= 320, "big fleet not at load: {:?}", big.st);
        // sub-linear tick growth vs the unsharded baseline: growing the
        // fleet 4x must cost the sharded engine a smaller p99 multiple
        // than it costs the pre-PR tick shape
        let master_growth =
            results[1].st.tick_p99_us.max(1) as f64 / results[0].st.tick_p99_us.max(1) as f64;
        let base_growth = base_p99[1].max(1.0) / base_p99[0].max(1.0);
        println!(
            "\ntick p99 growth 250->1000 machines: sharded {master_growth:.2}x \
             vs unsharded baseline {base_growth:.2}x"
        );
        assert!(
            master_growth < base_growth,
            "sharded tick p99 must grow sub-linearly vs the unsharded baseline \
             (sharded {master_growth:.2}x vs baseline {base_growth:.2}x)"
        );
        let mut acc = Json::obj();
        acc.set("master_p99_growth", master_growth).set("baseline_p99_growth", base_growth);
        out.set("acceptance_observed", acc);
    }

    let path = write_results("perf_master_tick", &out).unwrap();
    println!("\nresults -> {}", path.display());
    if std::env::var("EDL_BENCH_BASELINE").is_ok() {
        let mut acceptance = Json::obj();
        acceptance
            .set("min_machines", 1000u64)
            .set("min_slots", 8000u64)
            .set("min_concurrent_jobs", 200u64)
            .set("storm_submits", 100u64)
            .set("conservation_ok", true)
            .set("tick_p99_growth_must_beat_unsharded_baseline", true);
        let mut baseline = Json::obj();
        baseline
            .set(
                "_comment",
                "Master tick-throughput baseline for benches/perf_master_tick.rs. \
                 Numbers are machine-dependent; regenerate with: EDL_BENCH_BASELINE=1 \
                 cargo bench --bench perf_master_tick (the bench overwrites this file \
                 in the current directory).",
            )
            .set("generated", true)
            .set("acceptance", acceptance)
            .set("results", out.clone());
        std::fs::write("BENCH_master_tick.json", baseline.to_string_pretty()).unwrap();
        println!("baseline -> BENCH_master_tick.json");
    }
}
