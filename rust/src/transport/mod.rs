//! Point-to-point transport used by the ring-allreduce engine and the
//! model-broadcast path. The implementations share one trait:
//!
//!  * [`InProcHub`]/[`InProcEndpoint`] — lock-free-ish MPSC channels for
//!    workers living in one process (the elastic trainer's data plane; the
//!    stand-in for NCCL on the paper's NVLink/IB fabric),
//!  * [`TcpNode`] — framed TCP with `TCP_NODELAY` (§4.4 of the paper:
//!    Nagle's algorithm disabled on every coordination socket) for the
//!    multi-process deployment and the latency benchmark,
//!  * [`ShmNode`]/[`MixedNode`] (`transport::shm`, DESIGN.md §9) —
//!    mmap'd per-link SPSC ring buffers for worker processes that share
//!    a machine, negotiated per link by [`machine_identity`] digest with
//!    automatic TCP fallback for cross-machine links.
//!
//! Messages are tagged; `recv_from` performs selective receive with an
//! internal pending queue so ring neighbours and broadcast frames can
//! interleave safely on one endpoint.
//!
//! §Perf (DESIGN.md "Data-plane performance"): the hot path is zero-copy
//! and allocation-free in steady state —
//!
//!  * payloads travel as a [`Body`]: either an owned `Vec<u8>` (moved, not
//!    copied, through the in-proc channel) or a refcounted [`Shared`]
//!    buffer ([`PointToPoint::send_shared`]), so a model broadcast to K
//!    in-proc joiners costs K refcount bumps, not K serialisations;
//!  * every endpoint owns a [`BufPool`]; [`PointToPoint::take_buf`] /
//!    [`PointToPoint::recycle`] let the allreduce engine reuse segment
//!    buffers across all 2(N−1) ring steps instead of allocating per send;
//!  * `TcpNode` writes `[len][from][tag]` + payload with vectored I/O
//!    (one syscall, no framed intermediate `Vec`), and its reader threads
//!    draw payload buffers from the node's pool;
//!  * selective receive is indexed by `(from, tag)` — O(1) per frame even
//!    when many tags interleave on a laggy link;
//!  * the TCP accept loop blocks (no busy-poll); shutdown wakes it with a
//!    self-connect.

use std::collections::{HashMap, VecDeque};
use std::io::{BufReader, Read};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub mod shm;

pub use shm::{machine_identity, MixedNode, ShmNode};

pub type NodeId = u32;

/// Refcounted payload: one buffer, many receivers (model broadcast).
pub type Shared = Arc<Vec<u8>>;

/// Well-known tags. The allreduce/broadcast data plane derives its tags
/// in `allreduce::ring_tag`/`allreduce::bcast_tag` (disjoint
/// step/phase/seq bit fields under the 0x4000_0000/0x8000_0000 families);
/// only coordination traffic uses a static base.
pub mod tag {
    /// RPC frames
    pub const RPC: u32 = 0x3000;
    /// coordination-KV frames (fault-family marker only; the KV speaks
    /// its own framed protocol, not a `PointToPoint` transport)
    pub const KV: u32 = 0x3001;
}

// ---------------------------------------------------------------------------
// fault injection hook (the chaos harness's transport seam)
// ---------------------------------------------------------------------------

/// What should happen to one frame about to be sent. Returned by a
/// [`FaultHook`]; interpreted identically by every transport (and by the
/// deploy/KV control planes, which frame their own sockets).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameFate {
    Deliver,
    /// silently lose the frame (lossy link / partition)
    Drop,
    /// deliver the frame twice (retransmission storm)
    Duplicate,
    /// stall the link for this long before delivering (slow/congested
    /// link; implemented sender-side, so subsequent frames queue behind it)
    Delay(Duration),
}

/// Decides the fate of every frame `from → to` with transport tag `tag`.
/// Implemented by `harness::FaultPlan`; threaded through [`InProcHub`],
/// [`TcpNode`], the deploy control plane and the coordination KV behind a
/// zero-cost-when-off check (one relaxed atomic load per send).
pub trait FaultHook: Send + Sync {
    fn fate(&self, from: NodeId, to: NodeId, tag: u32) -> FrameFate;
}

/// Optional fault hook with a zero-cost disarmed fast path. Embedded by
/// every fault-injectable layer; `arm`/`disarm` flips it at runtime.
#[derive(Default)]
pub struct FaultCell {
    armed: AtomicBool,
    hook: Mutex<Option<Arc<dyn FaultHook>>>,
}

impl FaultCell {
    pub fn new() -> FaultCell {
        FaultCell::default()
    }

    /// Install (Some) or remove (None) the hook.
    pub fn arm(&self, hook: Option<Arc<dyn FaultHook>>) {
        let mut g = self.hook.lock().unwrap();
        self.armed.store(hook.is_some(), Ordering::Release);
        *g = hook;
    }

    /// Fate of a frame: `Deliver` (one relaxed load) unless armed.
    pub fn fate(&self, from: NodeId, to: NodeId, tag: u32) -> FrameFate {
        if !self.armed.load(Ordering::Relaxed) {
            return FrameFate::Deliver;
        }
        match self.hook.lock().unwrap().as_ref() {
            Some(h) => h.fate(from, to, tag),
            None => FrameFate::Deliver,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Msg {
    pub from: NodeId,
    pub tag: u32,
    pub payload: Vec<u8>,
}

/// A payload in flight: owned (moved through the channel) or shared
/// (refcounted — one buffer fanned out to many receivers).
#[derive(Debug, Clone)]
enum Body {
    Owned(Vec<u8>),
    Shared(Shared),
}

impl Body {
    fn as_slice(&self) -> &[u8] {
        match self {
            Body::Owned(v) => v,
            Body::Shared(s) => s,
        }
    }

    fn into_vec(self) -> Vec<u8> {
        match self {
            Body::Owned(v) => v,
            Body::Shared(s) => Arc::try_unwrap(s).unwrap_or_else(|s| (*s).clone()),
        }
    }

    fn into_shared(self) -> Shared {
        match self {
            Body::Owned(v) => Arc::new(v),
            Body::Shared(s) => s,
        }
    }
}

/// Copy `body` into `dst` (cleared first; capacity reused) and surface
/// the transported buffer, if owned, for pooling — the shared core of
/// both transports' `recv_into`.
fn body_into(body: Body, dst: &mut Vec<u8>) -> Option<Vec<u8>> {
    dst.clear();
    dst.extend_from_slice(body.as_slice());
    match body {
        Body::Owned(v) => Some(v),
        Body::Shared(_) => None,
    }
}

/// One frame in flight between endpoints.
#[derive(Debug)]
struct Frame {
    from: NodeId,
    tag: u32,
    body: Body,
}

#[derive(Debug)]
pub enum NetError {
    UnknownPeer(NodeId),
    Timeout { from: Option<NodeId>, tag: Option<u32> },
    Closed,
    Io(std::io::Error),
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::UnknownPeer(id) => write!(f, "peer {id} unknown/disconnected"),
            NetError::Timeout { from, tag } => {
                write!(f, "receive timed out (from={from:?}, tag={tag:?})")
            }
            NetError::Closed => write!(f, "endpoint closed"),
            NetError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for NetError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            NetError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for NetError {
    fn from(e: std::io::Error) -> NetError {
        NetError::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, NetError>;

// ---------------------------------------------------------------------------
// buffer pool
// ---------------------------------------------------------------------------

/// Small free-list of byte buffers so the per-segment send path allocates
/// O(1) amortised: in a ring every endpoint receives as many segments as
/// it sends per step, so recycled receive buffers feed the next sends.
#[derive(Debug, Default)]
pub struct BufPool {
    free: Vec<Vec<u8>>,
    hits: u64,
    misses: u64,
}

/// Bound on pooled buffers — enough for the deepest send pipeline plus
/// slack; beyond this, recycled buffers are dropped.
const POOL_KEEP: usize = 32;

/// Largest buffer the pool retains (data-plane segments are ~256 KiB;
/// pooling one-off giant frames would pin up to `POOL_KEEP` copies of
/// them for the endpoint's lifetime).
const POOL_MAX_BUF: usize = 2 << 20;

impl BufPool {
    pub fn new() -> BufPool {
        BufPool::default()
    }

    /// An empty buffer with capacity ≥ `cap` (pooled if available). Only
    /// buffers within 4× of the ask (with a 4 KiB floor) qualify, so a
    /// tiny control-frame ask cannot walk off with a pooled data-plane
    /// segment buffer and starve the hot path.
    pub fn take(&mut self, cap: usize) -> Vec<u8> {
        let ceil = cap.max(4096).saturating_mul(4);
        if let Some(pos) =
            self.free.iter().rposition(|b| b.capacity() >= cap && b.capacity() <= ceil)
        {
            let mut b = self.free.swap_remove(pos);
            b.clear();
            self.hits += 1;
            return b;
        }
        self.misses += 1;
        Vec::with_capacity(cap)
    }

    /// Return a spent buffer to the pool.
    pub fn put(&mut self, buf: Vec<u8>) {
        let cap = buf.capacity();
        if cap > 0 && cap <= POOL_MAX_BUF && self.free.len() < POOL_KEEP {
            self.free.push(buf);
        }
    }

    /// (hits, misses) over the pool's lifetime — the hot-path O(1)
    /// allocation claim is asserted against this.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

/// The pool's lock is cfg(loom)-switchable so the take/put race between a
/// `TcpNode`'s send path and its reader threads can be exhaustively
/// permuted by the loom model checker (`verify` stack, DESIGN.md §7).
#[cfg(loom)]
use loom::sync::Mutex as PoolMutex;
#[cfg(not(loom))]
use std::sync::Mutex as PoolMutex;

/// Thread-safe pool handle shared between a `TcpNode` and its reader
/// threads.
#[derive(Clone)]
struct SharedBufPool(Arc<PoolMutex<BufPool>>);

impl Default for SharedBufPool {
    fn default() -> SharedBufPool {
        SharedBufPool(Arc::new(PoolMutex::new(BufPool::default())))
    }
}

impl SharedBufPool {
    fn take(&self, cap: usize) -> Vec<u8> {
        self.0.lock().unwrap().take(cap)
    }
    fn put(&self, buf: Vec<u8>) {
        self.0.lock().unwrap().put(buf);
    }
    fn stats(&self) -> (u64, u64) {
        self.0.lock().unwrap().stats()
    }
}

// ---------------------------------------------------------------------------
// selective-receive mailbox (shared by both transports)
// ---------------------------------------------------------------------------

/// Out-of-order frames indexed by `(from, tag)` for O(1) selective
/// receive. Every buffered frame gets a monotonic sequence number;
/// `order` records `(key, seq)` in arrival order so `recv_any` returns
/// EXACT arrival order even when selective receives have taken frames
/// out from under it (a stale order entry never aliases to a later frame
/// of the same key — the seq check rejects it). Stale entries are
/// skipped lazily and compacted once they outnumber the live ones, so an
/// endpoint that only ever uses `recv_from` cannot leak order entries.
#[derive(Default)]
struct PendingQueue {
    by_key: HashMap<(NodeId, u32), VecDeque<(u64, Body)>>,
    order: VecDeque<((NodeId, u32), u64)>,
    next_seq: u64,
    stale: usize,
}

impl PendingQueue {
    fn push(&mut self, f: Frame) {
        let key = (f.from, f.tag);
        let seq = self.next_seq;
        self.next_seq += 1;
        self.by_key.entry(key).or_default().push_back((seq, f.body));
        self.order.push_back((key, seq));
    }

    fn pop_match(&mut self, from: NodeId, tag: u32) -> Option<Body> {
        let key = (from, tag);
        let (body, now_empty) = {
            let q = self.by_key.get_mut(&key)?;
            (q.pop_front().map(|(_, b)| b), q.is_empty())
        };
        if now_empty {
            self.by_key.remove(&key);
        }
        if body.is_some() {
            self.stale += 1;
            if self.stale > 64 && self.stale * 2 > self.order.len() {
                self.compact();
            }
        }
        body
    }

    /// Drop `order` entries whose frame a selective receive already took
    /// (amortised O(1): runs only when stale entries dominate).
    fn compact(&mut self) {
        let live: std::collections::HashSet<u64> =
            self.by_key.values().flat_map(|q| q.iter().map(|&(s, _)| s)).collect();
        self.order.retain(|&(_, s)| live.contains(&s));
        self.stale = 0;
    }

    fn pop_any(&mut self) -> Option<Frame> {
        // skip stale order entries; the seq check guarantees an entry only
        // ever yields the exact frame it was recorded for
        while let Some((key, seq)) = self.order.pop_front() {
            let (body, now_empty) = match self.by_key.get_mut(&key) {
                Some(q) if q.front().map(|&(s, _)| s) == Some(seq) => {
                    (q.pop_front().map(|(_, b)| b), q.is_empty())
                }
                _ => (None, false),
            };
            if now_empty {
                self.by_key.remove(&key);
            }
            match body {
                Some(body) => return Some(Frame { from: key.0, tag: key.1, body }),
                None => self.stale = self.stale.saturating_sub(1),
            }
        }
        None
    }
}

/// Receiver half shared by [`InProcEndpoint`] and [`TcpNode`]: an MPSC
/// drain plus the indexed pending queue.
struct Mailbox {
    rx: Receiver<Frame>,
    pending: PendingQueue,
}

impl Mailbox {
    fn new(rx: Receiver<Frame>) -> Mailbox {
        Mailbox { rx, pending: PendingQueue::default() }
    }

    fn recv_match(&mut self, from: NodeId, tag: u32, timeout: Duration) -> Result<Body> {
        if let Some(b) = self.pending.pop_match(from, tag) {
            return Ok(b);
        }
        let deadline = Instant::now() + timeout;
        loop {
            let now = Instant::now();
            if now >= deadline {
                return Err(NetError::Timeout { from: Some(from), tag: Some(tag) });
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(f) if f.from == from && f.tag == tag => return Ok(f.body),
                Ok(f) => self.pending.push(f),
                Err(RecvTimeoutError::Timeout) => {
                    return Err(NetError::Timeout { from: Some(from), tag: Some(tag) })
                }
                Err(RecvTimeoutError::Disconnected) => return Err(NetError::Closed),
            }
        }
    }

    fn recv_any(&mut self, timeout: Duration) -> Result<Frame> {
        if let Some(f) = self.pending.pop_any() {
            return Ok(f);
        }
        match self.rx.recv_timeout(timeout) {
            Ok(f) => Ok(f),
            Err(RecvTimeoutError::Timeout) => Err(NetError::Timeout { from: None, tag: None }),
            Err(RecvTimeoutError::Disconnected) => Err(NetError::Closed),
        }
    }
}

// ---------------------------------------------------------------------------
// trait
// ---------------------------------------------------------------------------

/// Point-to-point messaging with selective receive.
///
/// The zero-copy extensions (`send_shared`, `recv_shared`, `recv_into`,
/// `take_buf`/`recycle`) have copying defaults so the trait stays easy to
/// implement; both built-in transports override them.
pub trait PointToPoint: Send {
    fn id(&self) -> NodeId;

    fn send(&mut self, to: NodeId, tag: u32, payload: Vec<u8>) -> Result<()>;

    /// Send one refcounted buffer without copying it (in-proc: a refcount
    /// bump; TCP: vectored write straight from the shared buffer).
    fn send_shared(&mut self, to: NodeId, tag: u32, payload: &Shared) -> Result<()> {
        self.send(to, tag, payload.as_ref().clone())
    }

    /// Receive the next message matching (from, tag); other messages are
    /// buffered, not dropped.
    fn recv_from(&mut self, from: NodeId, tag: u32, timeout: Duration) -> Result<Vec<u8>>;

    /// Receive a matching message as a refcounted buffer suitable for
    /// relaying with [`PointToPoint::send_shared`] (zero-copy fan-out).
    fn recv_shared(&mut self, from: NodeId, tag: u32, timeout: Duration) -> Result<Shared> {
        Ok(Arc::new(self.recv_from(from, tag, timeout)?))
    }

    /// Receive a matching message into `dst` (cleared first; capacity is
    /// reused). Returns the payload length.
    fn recv_into(
        &mut self,
        from: NodeId,
        tag: u32,
        dst: &mut Vec<u8>,
        timeout: Duration,
    ) -> Result<usize> {
        let payload = self.recv_from(from, tag, timeout)?;
        dst.clear();
        dst.extend_from_slice(&payload);
        self.recycle(payload);
        Ok(dst.len())
    }

    /// Receive any message.
    fn recv_any(&mut self, timeout: Duration) -> Result<Msg>;

    /// An empty send buffer with capacity ≥ `cap`, pooled when possible.
    fn take_buf(&mut self, cap: usize) -> Vec<u8> {
        Vec::with_capacity(cap)
    }

    /// Return a spent buffer to the endpoint's pool.
    fn recycle(&mut self, _spent: Vec<u8>) {}
}

/// A data-plane endpoint that goes nowhere: sends are swallowed, receives
/// time out immediately. Headless workers (`edl worker --headless`) plug
/// this in so the training loop keeps its shape — same `WorkerCtx`, same
/// step cadence — without opening sockets or moving gradients. Only valid
/// when *every* worker of the job is headless; a mixed job would wait on
/// frames that never arrive.
pub struct NullNode {
    id: NodeId,
}

impl NullNode {
    pub fn new(id: NodeId) -> NullNode {
        NullNode { id }
    }
}

impl PointToPoint for NullNode {
    fn id(&self) -> NodeId {
        self.id
    }

    fn send(&mut self, _to: NodeId, _tag: u32, _payload: Vec<u8>) -> Result<()> {
        Ok(())
    }

    fn recv_from(&mut self, from: NodeId, tag: u32, _timeout: Duration) -> Result<Vec<u8>> {
        Err(NetError::Timeout { from: Some(from), tag: Some(tag) })
    }

    fn recv_any(&mut self, _timeout: Duration) -> Result<Msg> {
        Err(NetError::Timeout { from: None, tag: None })
    }
}

// ---------------------------------------------------------------------------
// in-process hub
// ---------------------------------------------------------------------------

/// Registry connecting in-process endpoints. Dynamic membership: endpoints
/// can join/leave at any time (that *is* the elasticity under test).
#[derive(Default)]
pub struct InProcHub {
    senders: Mutex<HashMap<NodeId, Sender<Frame>>>,
    faults: FaultCell,
}

impl InProcHub {
    pub fn new() -> Arc<InProcHub> {
        Arc::new(InProcHub::default())
    }

    pub fn join(self: &Arc<Self>, id: NodeId) -> InProcEndpoint {
        let (tx, rx) = channel();
        let prev = self.senders.lock().unwrap().insert(id, tx);
        assert!(prev.is_none(), "node id {id} already joined");
        InProcEndpoint { id, hub: self.clone(), mbox: Mailbox::new(rx), pool: BufPool::new() }
    }

    pub fn members(&self) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = self.senders.lock().unwrap().keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Install/remove the chaos-harness fault hook for every frame sent
    /// through this hub (zero-cost when off).
    pub fn set_fault_hook(&self, hook: Option<Arc<dyn FaultHook>>) {
        self.faults.arm(hook);
    }

    fn send(&self, frame: Frame, to: NodeId) -> Result<()> {
        let dup = match self.faults.fate(frame.from, to, frame.tag) {
            FrameFate::Deliver => false,
            FrameFate::Drop => return Ok(()),
            FrameFate::Duplicate => true,
            FrameFate::Delay(d) => {
                // sender-side stall: subsequent frames queue behind it,
                // like a congested link
                std::thread::sleep(d);
                false
            }
        };
        let senders = self.senders.lock().unwrap();
        let tx = senders.get(&to).ok_or(NetError::UnknownPeer(to))?;
        if dup {
            let copy = Frame { from: frame.from, tag: frame.tag, body: frame.body.clone() };
            tx.send(copy).map_err(|_| NetError::UnknownPeer(to))?;
        }
        tx.send(frame).map_err(|_| NetError::UnknownPeer(to))
    }

    fn leave(&self, id: NodeId) {
        self.senders.lock().unwrap().remove(&id);
    }
}

pub struct InProcEndpoint {
    id: NodeId,
    hub: Arc<InProcHub>,
    mbox: Mailbox,
    pool: BufPool,
}

impl InProcEndpoint {
    /// Leave the hub (graceful exit); subsequent sends to this node fail.
    pub fn leave(self) {
        self.hub.leave(self.id);
    }

    /// (hits, misses) of the endpoint's buffer pool.
    pub fn pool_stats(&self) -> (u64, u64) {
        self.pool.stats()
    }
}

impl PointToPoint for InProcEndpoint {
    fn id(&self) -> NodeId {
        self.id
    }

    fn send(&mut self, to: NodeId, tag: u32, payload: Vec<u8>) -> Result<()> {
        self.hub.send(Frame { from: self.id, tag, body: Body::Owned(payload) }, to)
    }

    fn send_shared(&mut self, to: NodeId, tag: u32, payload: &Shared) -> Result<()> {
        self.hub.send(Frame { from: self.id, tag, body: Body::Shared(payload.clone()) }, to)
    }

    fn recv_from(&mut self, from: NodeId, tag: u32, timeout: Duration) -> Result<Vec<u8>> {
        Ok(self.mbox.recv_match(from, tag, timeout)?.into_vec())
    }

    fn recv_shared(&mut self, from: NodeId, tag: u32, timeout: Duration) -> Result<Shared> {
        Ok(self.mbox.recv_match(from, tag, timeout)?.into_shared())
    }

    fn recv_into(
        &mut self,
        from: NodeId,
        tag: u32,
        dst: &mut Vec<u8>,
        timeout: Duration,
    ) -> Result<usize> {
        let body = self.mbox.recv_match(from, tag, timeout)?;
        if let Some(v) = body_into(body, dst) {
            self.pool.put(v);
        }
        Ok(dst.len())
    }

    fn recv_any(&mut self, timeout: Duration) -> Result<Msg> {
        let f = self.mbox.recv_any(timeout)?;
        Ok(Msg { from: f.from, tag: f.tag, payload: f.body.into_vec() })
    }

    fn take_buf(&mut self, cap: usize) -> Vec<u8> {
        self.pool.take(cap)
    }

    fn recycle(&mut self, spent: Vec<u8>) {
        self.pool.put(spent);
    }
}

// ---------------------------------------------------------------------------
// TCP node
// ---------------------------------------------------------------------------

/// Framed-TCP endpoint: a listener thread accepts peer connections and
/// pumps decoded frames into the same selective-receive queue the in-proc
/// endpoint uses. Outbound connections are cached per peer.
///
/// Wire format per frame: `[len u32][from u32][tag u32][payload]` with
/// `len = 8 + payload.len()`; header and payload leave in one vectored
/// write (no intermediate framed buffer).
pub struct TcpNode {
    id: NodeId,
    pub addr: String,
    mbox: Mailbox,
    outbound: HashMap<NodeId, std::net::TcpStream>,
    directory: Arc<Mutex<HashMap<NodeId, String>>>,
    stop: Arc<std::sync::atomic::AtomicBool>,
    pool: SharedBufPool,
    faults: FaultCell,
}

impl TcpNode {
    pub fn start(id: NodeId, directory: Arc<Mutex<HashMap<NodeId, String>>>) -> Result<TcpNode> {
        let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        directory.lock().unwrap().insert(id, addr.clone());
        let (tx, rx) = channel::<Frame>();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let pool = SharedBufPool::default();

        // Blocking accept loop; `drop` wakes it with a self-connect so an
        // idle node burns no CPU (the seed busy-polled at 1 ms).
        let stop2 = stop.clone();
        let pool2 = pool.clone();
        std::thread::spawn(move || loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if stop2.load(std::sync::atomic::Ordering::Relaxed) {
                        break;
                    }
                    let tx = tx.clone();
                    let pool = pool2.clone();
                    std::thread::spawn(move || reader_loop(stream, tx, pool));
                }
                Err(_) => break,
            }
        });

        Ok(TcpNode {
            id,
            addr,
            mbox: Mailbox::new(rx),
            outbound: HashMap::new(),
            directory,
            stop,
            pool,
            faults: FaultCell::new(),
        })
    }

    /// Install/remove the chaos-harness fault hook for frames this node
    /// sends (zero-cost when off).
    pub fn set_fault_hook(&self, hook: Option<Arc<dyn FaultHook>>) {
        self.faults.arm(hook);
    }

    fn stream_to(&mut self, to: NodeId) -> Result<&mut std::net::TcpStream> {
        if !self.outbound.contains_key(&to) {
            let addr = self
                .directory
                .lock()
                .unwrap()
                .get(&to)
                .cloned()
                .ok_or(NetError::UnknownPeer(to))?;
            let stream = std::net::TcpStream::connect(&addr)?;
            stream.set_nodelay(true)?; // §4.4
            self.outbound.insert(to, stream);
        }
        Ok(self.outbound.get_mut(&to).unwrap())
    }

    fn send_slice(&mut self, to: NodeId, tag: u32, payload: &[u8]) -> Result<()> {
        if 8 + payload.len() > crate::wire::MAX_FRAME {
            return Err(NetError::Io(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("frame too large: {} bytes", payload.len()),
            )));
        }
        match self.faults.fate(self.id, to, tag) {
            FrameFate::Deliver => {}
            FrameFate::Drop => return Ok(()),
            FrameFate::Duplicate => self.write_frame_to(to, tag, payload)?,
            FrameFate::Delay(d) => std::thread::sleep(d),
        }
        self.write_frame_to(to, tag, payload)
    }

    fn write_frame_to(&mut self, to: NodeId, tag: u32, payload: &[u8]) -> Result<()> {
        let id = self.id;
        let stream = self.stream_to(to)?;
        let mut head = [0u8; 12];
        head[..4].copy_from_slice(&((8 + payload.len()) as u32).to_le_bytes());
        head[4..8].copy_from_slice(&id.to_le_bytes());
        head[8..12].copy_from_slice(&tag.to_le_bytes());
        crate::wire::write_all_vectored(stream, &head, payload)?;
        Ok(())
    }

    /// (hits, misses) of the node's buffer pool (shared with its readers).
    pub fn pool_stats(&self) -> (u64, u64) {
        self.pool.stats()
    }
}

/// Per-connection reader: parses `[len][from][tag][payload]` frames,
/// drawing payload buffers from the node's pool.
fn reader_loop(stream: std::net::TcpStream, tx: Sender<Frame>, pool: SharedBufPool) {
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(stream);
    loop {
        let mut head = [0u8; 12];
        if reader.read_exact(&mut head).is_err() {
            break;
        }
        let len = u32::from_le_bytes(head[..4].try_into().unwrap()) as usize;
        if !(8..=crate::wire::MAX_FRAME).contains(&len) {
            break;
        }
        let from = u32::from_le_bytes(head[4..8].try_into().unwrap());
        let tag = u32::from_le_bytes(head[8..12].try_into().unwrap());
        let plen = len - 8;
        // read_to_end appends into the pooled buffer without the memset a
        // resize + read_exact would pay on every frame
        let mut payload = pool.take(plen);
        match reader.by_ref().take(plen as u64).read_to_end(&mut payload) {
            Ok(n) if n == plen => {}
            _ => break,
        }
        if tx.send(Frame { from, tag, body: Body::Owned(payload) }).is_err() {
            break;
        }
    }
}

impl Drop for TcpNode {
    fn drop(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::Relaxed);
        self.directory.lock().unwrap().remove(&self.id);
        // wake the blocking accept so the listener thread can exit
        let _ = std::net::TcpStream::connect(&self.addr);
    }
}

impl PointToPoint for TcpNode {
    fn id(&self) -> NodeId {
        self.id
    }

    fn send(&mut self, to: NodeId, tag: u32, payload: Vec<u8>) -> Result<()> {
        self.send_slice(to, tag, &payload)?;
        self.pool.put(payload);
        Ok(())
    }

    fn send_shared(&mut self, to: NodeId, tag: u32, payload: &Shared) -> Result<()> {
        self.send_slice(to, tag, payload)
    }

    fn recv_from(&mut self, from: NodeId, tag: u32, timeout: Duration) -> Result<Vec<u8>> {
        Ok(self.mbox.recv_match(from, tag, timeout)?.into_vec())
    }

    fn recv_shared(&mut self, from: NodeId, tag: u32, timeout: Duration) -> Result<Shared> {
        Ok(self.mbox.recv_match(from, tag, timeout)?.into_shared())
    }

    fn recv_into(
        &mut self,
        from: NodeId,
        tag: u32,
        dst: &mut Vec<u8>,
        timeout: Duration,
    ) -> Result<usize> {
        let body = self.mbox.recv_match(from, tag, timeout)?;
        if let Some(v) = body_into(body, dst) {
            self.pool.put(v);
        }
        Ok(dst.len())
    }

    fn recv_any(&mut self, timeout: Duration) -> Result<Msg> {
        let f = self.mbox.recv_any(timeout)?;
        Ok(Msg { from: f.from, tag: f.tag, payload: f.body.into_vec() })
    }

    fn take_buf(&mut self, cap: usize) -> Vec<u8> {
        self.pool.take(cap)
    }

    fn recycle(&mut self, spent: Vec<u8>) {
        self.pool.put(spent);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T: Duration = Duration::from_secs(5);

    #[test]
    fn inproc_basic_send_recv() {
        let hub = InProcHub::new();
        let mut a = hub.join(1);
        let mut b = hub.join(2);
        a.send(2, 7, vec![1, 2, 3]).unwrap();
        assert_eq!(b.recv_from(1, 7, T).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn inproc_selective_receive_buffers_others() {
        let hub = InProcHub::new();
        let mut a = hub.join(1);
        let mut b = hub.join(2);
        a.send(2, 10, vec![10]).unwrap();
        a.send(2, 20, vec![20]).unwrap();
        // ask for tag 20 first; tag 10 must not be lost
        assert_eq!(b.recv_from(1, 20, T).unwrap(), vec![20]);
        assert_eq!(b.recv_from(1, 10, T).unwrap(), vec![10]);
    }

    #[test]
    fn inproc_send_to_departed_peer_fails() {
        let hub = InProcHub::new();
        let mut a = hub.join(1);
        let b = hub.join(2);
        b.leave();
        assert!(matches!(a.send(2, 0, vec![]), Err(NetError::UnknownPeer(2))));
    }

    #[test]
    fn inproc_timeout() {
        let hub = InProcHub::new();
        let mut a = hub.join(1);
        let err = a.recv_from(9, 9, Duration::from_millis(20)).unwrap_err();
        assert!(matches!(err, NetError::Timeout { .. }));
    }

    #[test]
    fn inproc_members_sorted() {
        let hub = InProcHub::new();
        let _c = hub.join(3);
        let _a = hub.join(1);
        let _b = hub.join(2);
        assert_eq!(hub.members(), vec![1, 2, 3]);
    }

    #[test]
    fn inproc_shared_send_is_zero_copy() {
        let hub = InProcHub::new();
        let mut a = hub.join(1);
        let mut b = hub.join(2);
        let mut c = hub.join(3);
        let payload: Shared = Arc::new(vec![0xEE; 4096]);
        a.send_shared(2, 9, &payload).unwrap();
        a.send_shared(3, 9, &payload).unwrap();
        let rb = b.recv_shared(1, 9, T).unwrap();
        let rc = c.recv_shared(1, 9, T).unwrap();
        // same allocation fanned out to both receivers
        assert!(Arc::ptr_eq(&payload, &rb));
        assert!(Arc::ptr_eq(&payload, &rc));
    }

    #[test]
    fn inproc_recv_into_reuses_capacity_and_pools() {
        let hub = InProcHub::new();
        let mut a = hub.join(1);
        let mut b = hub.join(2);
        let mut dst = Vec::with_capacity(64);
        for i in 0..10u8 {
            a.send(2, 1, vec![i; 16]).unwrap();
            let n = b.recv_into(1, 1, &mut dst, T).unwrap();
            assert_eq!(n, 16);
            assert_eq!(dst, vec![i; 16]);
        }
        let (hits, misses) = b.pool_stats();
        assert_eq!(hits + misses, 0, "recv_into only fills the pool");
        // transported buffers were pooled: the next take_buf hits
        let buf = b.take_buf(16);
        assert!(buf.capacity() >= 16);
        assert_eq!(b.pool_stats().0, 1, "pooled receive buffer reused");
    }

    #[test]
    fn pool_take_put_amortises_allocations() {
        let mut pool = BufPool::new();
        let a = pool.take(100);
        pool.put(a);
        let b = pool.take(50);
        assert!(b.capacity() >= 100);
        assert_eq!(pool.stats(), (1, 1));
        // too-small pooled buffer is not returned for a bigger ask
        pool.put(b);
        let c = pool.take(1000);
        assert!(c.capacity() >= 1000);
        assert_eq!(pool.stats(), (1, 2));
    }

    #[test]
    fn pending_queue_interleaved_many_tags() {
        let hub = InProcHub::new();
        let mut a = hub.join(1);
        let mut b = hub.join(2);
        for i in 0..100u32 {
            a.send(2, i % 10, vec![i as u8]).unwrap();
        }
        // selectively drain tags in reverse order; per-key FIFO must hold
        for tag in (0..10u32).rev() {
            for k in 0..10u32 {
                let got = b.recv_from(1, tag, T).unwrap();
                assert_eq!(got, vec![(k * 10 + tag) as u8]);
            }
        }
    }

    #[test]
    fn pending_order_compacts_under_selective_receive_only() {
        // an endpoint that only ever uses recv_from must not leak order
        // entries (recv_any is what normally drains them)
        let mut pq = PendingQueue::default();
        for round in 0..1_000u32 {
            pq.push(Frame { from: 1, tag: round % 7, body: Body::Owned(vec![1]) });
            assert!(pq.pop_match(1, round % 7).is_some());
        }
        assert!(pq.by_key.is_empty());
        assert!(pq.order.len() <= 130, "stale order entries leaked: {}", pq.order.len());
    }

    #[test]
    fn recv_any_sees_buffered_then_fresh() {
        let hub = InProcHub::new();
        let mut a = hub.join(1);
        let mut b = hub.join(2);
        a.send(2, 1, vec![1]).unwrap();
        a.send(2, 2, vec![2]).unwrap();
        a.send(2, 3, vec![3]).unwrap();
        // selective receive for tag 2 buffers tags 1 and 3
        assert_eq!(b.recv_from(1, 2, T).unwrap(), vec![2]);
        let m1 = b.recv_any(T).unwrap();
        let m2 = b.recv_any(T).unwrap();
        assert_eq!((m1.tag, m2.tag), (1, 3));
    }

    #[test]
    fn recv_any_arrival_order_survives_stale_entries() {
        // a stale order entry (left by a selective receive) must never
        // alias to a LATER frame of the same tag: recv_any keeps exact
        // arrival order
        let hub = InProcHub::new();
        let mut a = hub.join(1);
        let mut b = hub.join(2);
        a.send(2, 1, vec![1]).unwrap();
        a.send(2, 2, vec![2]).unwrap();
        assert_eq!(b.recv_from(1, 2, T).unwrap(), vec![2]); // buffers tag 1
        assert_eq!(b.recv_from(1, 1, T).unwrap(), vec![1]); // stale entry for tag 1
        a.send(2, 3, vec![3]).unwrap();
        a.send(2, 1, vec![4]).unwrap();
        a.send(2, 9, vec![9]).unwrap();
        assert_eq!(b.recv_from(1, 9, T).unwrap(), vec![9]); // buffers tags 3 and 1
        // arrival order: tag 3 (x3) BEFORE the second tag-1 frame (x4)
        let m1 = b.recv_any(T).unwrap();
        let m2 = b.recv_any(T).unwrap();
        assert_eq!((m1.tag, m1.payload), (3, vec![3]));
        assert_eq!((m2.tag, m2.payload), (1, vec![4]));
    }

    #[test]
    fn tcp_roundtrip() {
        let dir = Arc::new(Mutex::new(HashMap::new()));
        let mut a = TcpNode::start(1, dir.clone()).unwrap();
        let mut b = TcpNode::start(2, dir.clone()).unwrap();
        a.send(2, 5, b"ping".to_vec()).unwrap();
        assert_eq!(b.recv_from(1, 5, T).unwrap(), b"ping".to_vec());
        b.send(1, 6, b"pong".to_vec()).unwrap();
        assert_eq!(a.recv_from(2, 6, T).unwrap(), b"pong".to_vec());
    }

    #[test]
    fn tcp_large_payload() {
        let dir = Arc::new(Mutex::new(HashMap::new()));
        let mut a = TcpNode::start(1, dir.clone()).unwrap();
        let mut b = TcpNode::start(2, dir.clone()).unwrap();
        let big = vec![0xABu8; 4 << 20];
        a.send(2, 1, big.clone()).unwrap();
        assert_eq!(b.recv_from(1, 1, T).unwrap(), big);
    }

    #[test]
    fn tcp_shared_payload() {
        let dir = Arc::new(Mutex::new(HashMap::new()));
        let mut a = TcpNode::start(1, dir.clone()).unwrap();
        let mut b = TcpNode::start(2, dir.clone()).unwrap();
        let payload: Shared = Arc::new(vec![7u8; 100_000]);
        a.send_shared(2, 3, &payload).unwrap();
        assert_eq!(b.recv_from(1, 3, T).unwrap(), *payload);
    }

    #[test]
    fn tcp_selective_receive() {
        let dir = Arc::new(Mutex::new(HashMap::new()));
        let mut a = TcpNode::start(1, dir.clone()).unwrap();
        let mut b = TcpNode::start(2, dir.clone()).unwrap();
        let mut c = TcpNode::start(3, dir.clone()).unwrap();
        a.send(3, 1, vec![1]).unwrap();
        b.send(3, 1, vec![2]).unwrap();
        // order of arrival from different peers is arbitrary; selective
        // receive must untangle it
        assert_eq!(c.recv_from(2, 1, T).unwrap(), vec![2]);
        assert_eq!(c.recv_from(1, 1, T).unwrap(), vec![1]);
    }

    /// Test hook: a fixed fate for every frame matching (from, to).
    struct FixedFate(NodeId, NodeId, FrameFate);

    impl FaultHook for FixedFate {
        fn fate(&self, from: NodeId, to: NodeId, _tag: u32) -> FrameFate {
            if from == self.0 && to == self.1 {
                self.2
            } else {
                FrameFate::Deliver
            }
        }
    }

    #[test]
    fn inproc_fault_hook_drops_and_duplicates() {
        let hub = InProcHub::new();
        let mut a = hub.join(1);
        let mut b = hub.join(2);
        hub.set_fault_hook(Some(Arc::new(FixedFate(1, 2, FrameFate::Drop))));
        a.send(2, 1, vec![1]).unwrap(); // lost
        assert!(matches!(
            b.recv_from(1, 1, Duration::from_millis(30)),
            Err(NetError::Timeout { .. })
        ));
        hub.set_fault_hook(Some(Arc::new(FixedFate(1, 2, FrameFate::Duplicate))));
        a.send(2, 2, vec![2]).unwrap(); // delivered twice
        assert_eq!(b.recv_from(1, 2, T).unwrap(), vec![2]);
        assert_eq!(b.recv_from(1, 2, T).unwrap(), vec![2]);
        // disarmed: back to exactly-once
        hub.set_fault_hook(None);
        a.send(2, 3, vec![3]).unwrap();
        assert_eq!(b.recv_from(1, 3, T).unwrap(), vec![3]);
        assert!(matches!(
            b.recv_from(1, 3, Duration::from_millis(30)),
            Err(NetError::Timeout { .. })
        ));
    }

    #[test]
    fn tcp_fault_hook_drops_matching_frames_only() {
        let dir = Arc::new(Mutex::new(HashMap::new()));
        let mut a = TcpNode::start(1, dir.clone()).unwrap();
        let mut b = TcpNode::start(2, dir.clone()).unwrap();
        let mut c = TcpNode::start(3, dir.clone()).unwrap();
        a.set_fault_hook(Some(Arc::new(FixedFate(1, 2, FrameFate::Drop))));
        a.send(2, 1, vec![2]).unwrap(); // partitioned link: lost
        a.send(3, 1, vec![3]).unwrap(); // other link unaffected
        assert_eq!(c.recv_from(1, 1, T).unwrap(), vec![3]);
        assert!(matches!(
            b.recv_from(1, 1, Duration::from_millis(50)),
            Err(NetError::Timeout { .. })
        ));
        a.set_fault_hook(None); // heal
        a.send(2, 1, vec![4]).unwrap();
        assert_eq!(b.recv_from(1, 1, T).unwrap(), vec![4]);
    }

    #[test]
    fn tcp_drop_shuts_down_promptly() {
        let dir = Arc::new(Mutex::new(HashMap::new()));
        let t0 = Instant::now();
        for i in 0..5 {
            let node = TcpNode::start(100 + i, dir.clone()).unwrap();
            drop(node);
        }
        // the blocking accept must be woken, not waited out
        assert!(t0.elapsed() < Duration::from_secs(2));
        assert!(dir.lock().unwrap().is_empty());
    }
}

/// loom permutation tests for the transport's shared mutable state
/// (DESIGN.md §7). loom cannot model `std::sync::mpsc`, so the `Mailbox`
/// `Receiver` drain itself is out of scope here; what IS exhaustively
/// permuted is everything behind a lock: the `SharedBufPool` take/put race
/// between a sender and a reader thread, and a `PendingQueue` shared under
/// a mutex the way a future multi-reader mailbox would share it. Run with
/// `RUSTFLAGS="--cfg loom" cargo test --lib loom_` (nightly CI job).
#[cfg(all(test, loom))]
mod loom_transport {
    use super::{Body, Frame, PendingQueue, SharedBufPool};
    use loom::sync::{Arc, Mutex};
    use loom::thread;

    #[test]
    fn loom_pool_accounts_every_take_across_threads() {
        loom::model(|| {
            let pool = SharedBufPool::default();
            let p2 = pool.clone();
            let t = thread::spawn(move || {
                let b = p2.take(1024);
                p2.put(b);
            });
            let b = pool.take(1024);
            pool.put(b);
            t.join().unwrap();
            let (hits, misses) = pool.stats();
            // every take is classified exactly once, in every interleaving
            assert_eq!(hits + misses, 2, "pool stats lost a take: {hits}+{misses}");
        });
    }

    #[test]
    fn loom_pool_recycled_buffer_is_always_clean() {
        loom::model(|| {
            let pool = SharedBufPool::default();
            let p2 = pool.clone();
            let t = thread::spawn(move || {
                // return a dirty spent buffer, as the reader thread does
                let mut dirty = Vec::with_capacity(8192);
                dirty.extend_from_slice(&[0xAA; 64]);
                p2.put(dirty);
            });
            let b = pool.take(4096);
            // whether the take hit the recycled buffer or allocated fresh,
            // the hot path must never observe stale bytes
            assert_eq!(b.len(), 0, "pool handed out a dirty buffer");
            assert!(b.capacity() >= 4096);
            t.join().unwrap();
        });
    }

    #[test]
    fn loom_pending_queue_no_frame_lost_or_duplicated() {
        loom::model(|| {
            let pq = Arc::new(Mutex::new(PendingQueue::default()));
            let producer = {
                let pq = pq.clone();
                thread::spawn(move || {
                    for from in [1u32, 2u32] {
                        pq.lock().unwrap().push(Frame {
                            from,
                            tag: 7,
                            body: Body::Owned(vec![from as u8]),
                        });
                    }
                })
            };
            let consumer = {
                let pq = pq.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    for _ in 0..2 {
                        if let Some(f) = pq.lock().unwrap().pop_any() {
                            got.push(f.from);
                        }
                    }
                    got
                })
            };
            producer.join().unwrap();
            let mut got = consumer.join().unwrap();
            while let Some(f) = pq.lock().unwrap().pop_any() {
                got.push(f.from);
            }
            got.sort_unstable();
            // exactly the two pushed frames surface, in every interleaving
            assert_eq!(got, vec![1, 2], "frames lost or duplicated: {got:?}");
        });
    }
}
