//! Data-plane correctness under the microscope: the segment-pipelined
//! ring allreduce must be BIT-IDENTICAL to a straight-line weighted-sum
//! reference for every (N, len, segment size, weights) — segmentation and
//! buffer pooling change scheduling, never floating-point results — and
//! the pooled hot path must stay O(1)-allocation over TCP as well.

use edl::allreduce::{broadcast_recv, broadcast_send, chunks, ring_allreduce_seg, SEG_ELEMS};
use edl::transport::{InProcHub, PointToPoint, TcpNode};
use edl::util::{prop, rng::Pcg};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

const T: Duration = Duration::from_secs(30);

/// Straight-line reference of the ring's exact reduction order: chunk
/// `c`'s accumulation starts at rank `c` and folds ranks `c+1, c+2, …`
/// as `local + acc` — the same association the pipelined implementation
/// performs, written without any networking.
fn reference_allreduce(inputs: &[Vec<f32>], weights: &[f32]) -> Vec<f32> {
    let n = inputs.len();
    let len = inputs[0].len();
    // mirror the implementation exactly: weight 1.0 skips the multiply
    let scaled: Vec<Vec<f32>> = inputs
        .iter()
        .zip(weights)
        .map(|(v, &w)| {
            if w == 1.0 {
                v.clone()
            } else {
                v.iter().map(|x| x * w).collect()
            }
        })
        .collect();
    let mut out = vec![0f32; len];
    for (c, &(a, b)) in chunks(len, n).iter().enumerate() {
        for i in a..b {
            let mut acc = scaled[c][i];
            for j in 1..n {
                acc = scaled[(c + j) % n][i] + acc;
            }
            out[i] = acc;
        }
    }
    out
}

fn run_ring(inputs: &[Vec<f32>], weights: &[f32], step: u64, seg: usize) -> Vec<Vec<f32>> {
    let n = inputs.len();
    let hub = InProcHub::new();
    let ring: Vec<u32> = (0..n as u32).collect();
    let eps: Vec<_> = (0..n).map(|i| hub.join(i as u32)).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = eps
            .into_iter()
            .enumerate()
            .map(|(i, mut ep)| {
                let ring = ring.clone();
                let mut buf = inputs[i].clone();
                let w = weights[i];
                s.spawn(move || {
                    ring_allreduce_seg(&mut ep, &ring, step, &mut buf, w, T, seg).unwrap();
                    buf
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[test]
fn segmented_allreduce_bit_identical_to_reference() {
    prop::check("segmented-allreduce-bit-identical", 12, |rng: &mut Pcg| {
        let n = 2 + rng.gen_range(5) as usize;
        let len = 1 + rng.gen_range(30_000) as usize;
        let seg = 1 + rng.gen_range(4_000) as usize;
        let step = rng.next_u64();
        let mut data_rng = Pcg::seeded(rng.next_u64());
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| data_rng.normal() as f32).collect())
            .collect();
        let weights: Vec<f32> = (0..n).map(|_| 0.05 + data_rng.f64() as f32).collect();
        let expected = reference_allreduce(&inputs, &weights);
        let outs = run_ring(&inputs, &weights, step, seg);
        for (w, o) in outs.iter().enumerate() {
            for (i, (a, b)) in o.iter().zip(&expected).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "worker {w} elt {i}: {a} ({:#x}) != reference {b} ({:#x}) \
                         [n={n} len={len} seg={seg}]",
                        a.to_bits(),
                        b.to_bits()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn segment_size_never_changes_bits() {
    // same inputs across wildly different segmentations -> identical bits
    let mut rng = Pcg::seeded(42);
    let n = 4;
    let len = 10_007;
    let inputs: Vec<Vec<f32>> =
        (0..n).map(|_| (0..len).map(|_| rng.normal() as f32).collect()).collect();
    let weights = vec![0.25f32, 1.0, 0.5, 0.125];
    let baseline = run_ring(&inputs, &weights, 5, SEG_ELEMS);
    for seg in [1usize, 7, 100, 2048, len] {
        let outs = run_ring(&inputs, &weights, 5, seg);
        for (a, b) in outs.iter().zip(&baseline) {
            assert!(
                a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "segment size {seg} changed results"
            );
        }
    }
}

#[test]
fn tcp_pooled_hot_path_is_allocation_free_in_steady_state() {
    let dir = Arc::new(Mutex::new(HashMap::new()));
    let nodes: Vec<TcpNode> = (0..2).map(|i| TcpNode::start(i, dir.clone()).unwrap()).collect();
    let stats: Vec<(u64, u64)> = std::thread::scope(|s| {
        nodes
            .into_iter()
            .enumerate()
            .map(|(i, mut node)| {
                s.spawn(move || {
                    let mut buf = vec![i as f32 + 0.5; 200_000];
                    for step in 0..10u64 {
                        ring_allreduce_seg(&mut node, &[0, 1], step, &mut buf, 0.5, T, 8_192)
                            .unwrap();
                    }
                    node.pool_stats()
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    for &(hits, misses) in &stats {
        // 10 calls x 2 passes x 13 segments = 260 sends + 260 receives
        // drawing from one pool; only warm-up may allocate
        assert!(hits + misses >= 500, "unexpected buffer traffic: {hits}+{misses}");
        assert!(misses <= 64, "TCP hot path still allocating: {misses} misses");
        assert!(hits >= misses * 5, "pool barely used: {hits} hits / {misses} misses");
    }
}

#[test]
fn broadcast_matches_over_mixed_topology_sizes() {
    // K = 1..9 joiners in-proc: every tree shape delivers identical bits
    for k in 1..=9u32 {
        let hub = InProcHub::new();
        let dests: Vec<u32> = (1..=k).collect();
        let model: Vec<f32> = (0..65_537).map(|i| (i as f32) * 0.125 - 9.0).collect();
        let model2 = model.clone();
        std::thread::scope(|s| {
            let mut src = hub.join(0);
            let joiners: Vec<_> = dests.iter().map(|&d| hub.join(d)).collect();
            let dests2 = dests.clone();
            s.spawn(move || broadcast_send(&mut src, &dests2, u64::from(k), &model2).unwrap());
            let handles: Vec<_> = joiners
                .into_iter()
                .map(|mut ep| {
                    let dests = dests.clone();
                    s.spawn(move || broadcast_recv(&mut ep, 0, &dests, u64::from(k), T).unwrap())
                })
                .collect();
            for h in handles {
                let got = h.join().unwrap();
                assert!(got.iter().zip(&model).all(|(a, b)| a.to_bits() == b.to_bits()));
            }
        });
    }
}

#[test]
fn selective_receive_timeout_with_busy_pending_queue() {
    // a full pending queue must not satisfy a non-matching receive
    let hub = InProcHub::new();
    let mut a = hub.join(1);
    let mut b = hub.join(2);
    for i in 0..50u32 {
        a.send(2, 7, vec![i as u8]).unwrap();
    }
    let err = b.recv_from(1, 8, Duration::from_millis(30)).unwrap_err();
    assert!(matches!(err, edl::transport::NetError::Timeout { .. }));
    // and the buffered frames are all still there, in order
    for i in 0..50u32 {
        assert_eq!(b.recv_from(1, 7, T).unwrap(), vec![i as u8]);
    }
}
