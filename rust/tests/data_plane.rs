//! Data-plane correctness under the microscope: the segment-pipelined
//! ring allreduce must be BIT-IDENTICAL to a straight-line weighted-sum
//! reference for every (N, len, segment size, weights) — segmentation and
//! buffer pooling change scheduling, never floating-point results — and
//! the pooled hot path must stay O(1)-allocation over TCP as well.

use edl::allreduce::{broadcast_recv, broadcast_send, chunks, ring_allreduce_seg, SEG_ELEMS};
use edl::transport::{InProcHub, PointToPoint, TcpNode};
use edl::util::{prop, rng::Pcg};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

const T: Duration = Duration::from_secs(30);

/// Straight-line reference of the ring's exact reduction order: chunk
/// `c`'s accumulation starts at rank `c` and folds ranks `c+1, c+2, …`
/// as `local + acc` — the same association the pipelined implementation
/// performs, written without any networking.
fn reference_allreduce(inputs: &[Vec<f32>], weights: &[f32]) -> Vec<f32> {
    let n = inputs.len();
    let len = inputs[0].len();
    // mirror the implementation exactly: weight 1.0 skips the multiply
    let scaled: Vec<Vec<f32>> = inputs
        .iter()
        .zip(weights)
        .map(|(v, &w)| {
            if w == 1.0 {
                v.clone()
            } else {
                v.iter().map(|x| x * w).collect()
            }
        })
        .collect();
    let mut out = vec![0f32; len];
    for (c, &(a, b)) in chunks(len, n).iter().enumerate() {
        for i in a..b {
            let mut acc = scaled[c][i];
            for j in 1..n {
                acc = scaled[(c + j) % n][i] + acc;
            }
            out[i] = acc;
        }
    }
    out
}

fn run_ring(inputs: &[Vec<f32>], weights: &[f32], step: u64, seg: usize) -> Vec<Vec<f32>> {
    let n = inputs.len();
    let hub = InProcHub::new();
    let ring: Vec<u32> = (0..n as u32).collect();
    let eps: Vec<_> = (0..n).map(|i| hub.join(i as u32)).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = eps
            .into_iter()
            .enumerate()
            .map(|(i, mut ep)| {
                let ring = ring.clone();
                let mut buf = inputs[i].clone();
                let w = weights[i];
                s.spawn(move || {
                    ring_allreduce_seg(&mut ep, &ring, step, &mut buf, w, T, seg).unwrap();
                    buf
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[test]
fn segmented_allreduce_bit_identical_to_reference() {
    prop::check("segmented-allreduce-bit-identical", 12, |rng: &mut Pcg| {
        let n = 2 + rng.gen_range(5) as usize;
        let len = 1 + rng.gen_range(30_000) as usize;
        let seg = 1 + rng.gen_range(4_000) as usize;
        let step = rng.next_u64();
        let mut data_rng = Pcg::seeded(rng.next_u64());
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| data_rng.normal() as f32).collect())
            .collect();
        let weights: Vec<f32> = (0..n).map(|_| 0.05 + data_rng.f64() as f32).collect();
        let expected = reference_allreduce(&inputs, &weights);
        let outs = run_ring(&inputs, &weights, step, seg);
        for (w, o) in outs.iter().enumerate() {
            for (i, (a, b)) in o.iter().zip(&expected).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "worker {w} elt {i}: {a} ({:#x}) != reference {b} ({:#x}) \
                         [n={n} len={len} seg={seg}]",
                        a.to_bits(),
                        b.to_bits()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn segment_size_never_changes_bits() {
    // same inputs across wildly different segmentations -> identical bits
    let mut rng = Pcg::seeded(42);
    let n = 4;
    let len = 10_007;
    let inputs: Vec<Vec<f32>> =
        (0..n).map(|_| (0..len).map(|_| rng.normal() as f32).collect()).collect();
    let weights = vec![0.25f32, 1.0, 0.5, 0.125];
    let baseline = run_ring(&inputs, &weights, 5, SEG_ELEMS);
    for seg in [1usize, 7, 100, 2048, len] {
        let outs = run_ring(&inputs, &weights, 5, seg);
        for (a, b) in outs.iter().zip(&baseline) {
            assert!(
                a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "segment size {seg} changed results"
            );
        }
    }
}

#[test]
fn tcp_pooled_hot_path_is_allocation_free_in_steady_state() {
    let dir = Arc::new(Mutex::new(HashMap::new()));
    let nodes: Vec<TcpNode> = (0..2).map(|i| TcpNode::start(i, dir.clone()).unwrap()).collect();
    let stats: Vec<(u64, u64)> = std::thread::scope(|s| {
        nodes
            .into_iter()
            .enumerate()
            .map(|(i, mut node)| {
                s.spawn(move || {
                    let mut buf = vec![i as f32 + 0.5; 200_000];
                    for step in 0..10u64 {
                        ring_allreduce_seg(&mut node, &[0, 1], step, &mut buf, 0.5, T, 8_192)
                            .unwrap();
                    }
                    node.pool_stats()
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    for &(hits, misses) in &stats {
        // 10 calls x 2 passes x 13 segments = 260 sends + 260 receives
        // drawing from one pool; only warm-up may allocate
        assert!(hits + misses >= 500, "unexpected buffer traffic: {hits}+{misses}");
        assert!(misses <= 64, "TCP hot path still allocating: {misses} misses");
        assert!(hits >= misses * 5, "pool barely used: {hits} hits / {misses} misses");
    }
}

#[test]
fn broadcast_matches_over_mixed_topology_sizes() {
    // K = 1..9 joiners in-proc: every tree shape delivers identical bits
    for k in 1..=9u32 {
        let hub = InProcHub::new();
        let dests: Vec<u32> = (1..=k).collect();
        let model: Vec<f32> = (0..65_537).map(|i| (i as f32) * 0.125 - 9.0).collect();
        let model2 = model.clone();
        std::thread::scope(|s| {
            let mut src = hub.join(0);
            let joiners: Vec<_> = dests.iter().map(|&d| hub.join(d)).collect();
            let dests2 = dests.clone();
            s.spawn(move || broadcast_send(&mut src, &dests2, u64::from(k), &model2).unwrap());
            let handles: Vec<_> = joiners
                .into_iter()
                .map(|mut ep| {
                    let dests = dests.clone();
                    s.spawn(move || broadcast_recv(&mut ep, 0, &dests, u64::from(k), T).unwrap())
                })
                .collect();
            for h in handles {
                let got = h.join().unwrap();
                assert!(got.iter().zip(&model).all(|(a, b)| a.to_bits() == b.to_bits()));
            }
        });
    }
}

// ---------------------------------------------------------------------------
// shared-memory transport: framing parity with TCP + deterministic chaos
// (DESIGN.md §9)
// ---------------------------------------------------------------------------

#[cfg(unix)]
mod shm {
    use super::*;
    use edl::harness::{FaultKind, FaultPlan, FaultRule, Family};
    use edl::transport::{FaultHook, ShmNode};
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Fresh ring-file directory per call (pid + counter) so parallel
    /// tests never share a namespace.
    fn ring_dir() -> PathBuf {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let n = SEQ.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!("edl-dp-shm-{}-{n}", std::process::id()))
    }

    /// Play `frames` through a transport pair and return what arrived,
    /// alternating the three receive entry points so the framed byte
    /// stream is exercised through every read path.
    fn play<N: edl::transport::PointToPoint + Send>(
        mut tx: N,
        mut rx: N,
        frames: &[(u32, Vec<u8>)],
    ) -> Vec<Vec<u8>> {
        std::thread::scope(|s| {
            let sent: Vec<(u32, Vec<u8>)> = frames.to_vec();
            s.spawn(move || {
                for (tag, p) in sent {
                    tx.send(2, tag, p).unwrap();
                }
            });
            frames
                .iter()
                .enumerate()
                .map(|(i, (tag, _))| match i % 3 {
                    0 => rx.recv_from(1, *tag, T).unwrap(),
                    1 => rx.recv_shared(1, *tag, T).unwrap().to_vec(),
                    _ => {
                        let mut dst = Vec::new();
                        rx.recv_into(1, *tag, &mut dst, T).unwrap();
                        dst
                    }
                })
                .collect()
        })
    }

    #[test]
    fn shm_framing_bit_identical_to_tcp() {
        // the same frame schedule over a tiny shm ring (forcing
        // wrap-around splits) and over loopback TCP must deliver
        // byte-identical payloads: framing is transport-invariant
        prop::check("shm-framing-bit-identical-to-tcp", 6, |rng: &mut Pcg| {
            let nframes = 1 + rng.gen_range(30) as usize;
            let frames: Vec<(u32, Vec<u8>)> = (0..nframes)
                .map(|i| {
                    let len = rng.gen_range(20_000) as usize;
                    let mut fr = Pcg::seeded(rng.next_u64());
                    (100 + i as u32, (0..len).map(|_| fr.next_u64() as u8).collect())
                })
                .collect();
            let dir = ring_dir();
            let sa = ShmNode::start_with(1, dir.clone(), 64 * 1024).unwrap();
            let sb = ShmNode::start_with(2, dir, 64 * 1024).unwrap();
            let via_shm = play(sa, sb, &frames);
            let tdir = Arc::new(Mutex::new(HashMap::new()));
            let ta = TcpNode::start(1, tdir.clone()).unwrap();
            let tb = TcpNode::start(2, tdir).unwrap();
            let via_tcp = play(ta, tb, &frames);
            for (i, ((_, want), (got_s, got_t))) in
                frames.iter().zip(via_shm.iter().zip(&via_tcp)).enumerate()
            {
                if got_s != want || got_t != want {
                    return Err(format!(
                        "frame {i}: shm/tcp delivery diverged from source \
                         (len {} vs shm {} / tcp {})",
                        want.len(),
                        got_s.len(),
                        got_t.len()
                    ));
                }
            }
            Ok(())
        });
    }

    /// One armed run: 200 uniquely-tagged frames through a FaultPlan with
    /// probabilistic drop + duplicate rules, fault clock stepped per
    /// frame. Returns how many copies of each frame arrived.
    fn chaos_run(seed: u64) -> Vec<usize> {
        let dir = ring_dir();
        let mut a = ShmNode::start_with(1, dir.clone(), 64 * 1024).unwrap();
        let mut b = ShmNode::start_with(2, dir, 64 * 1024).unwrap();
        let plan = FaultPlan::new(seed);
        plan.add(FaultRule::always(FaultKind::Drop).per_mille(250).family(Family::Data));
        plan.add(FaultRule::always(FaultKind::Duplicate).per_mille(250).family(Family::Data));
        let clock = plan.clock();
        let hook: Arc<dyn FaultHook> = plan.clone();
        a.set_fault_hook(Some(hook));
        for i in 0..200u32 {
            clock.set_ms(u64::from(i));
            a.send(2, 1000 + i, vec![(i % 251) as u8; 64]).unwrap();
        }
        a.set_fault_hook(None);
        assert!(plan.hits() > 0, "fault plan never fired");
        (0..200u32)
            .map(|i| {
                let mut copies = 0;
                while b.recv_from(1, 1000 + i, Duration::from_millis(5)).is_ok() {
                    copies += 1;
                }
                copies
            })
            .collect()
    }

    #[test]
    fn shm_fault_injection_replays_deterministically() {
        // the chaos FaultCell on the shm send path is driven by the pure
        // (seed, from, to, family, clock) coin: same seed -> identical
        // delivery multiset, different seed -> different one
        let one = chaos_run(7);
        let two = chaos_run(7);
        assert_eq!(one, two, "same seed must replay bit-identically");
        assert!(one.iter().any(|&c| c == 0), "no frame was ever dropped");
        assert!(one.iter().any(|&c| c == 2), "no frame was ever duplicated");
        assert!(one.iter().any(|&c| c == 1), "no frame was delivered clean");
        let other = chaos_run(8);
        assert_ne!(one, other, "different seed should draw different fates");
    }
}

#[test]
fn selective_receive_timeout_with_busy_pending_queue() {
    // a full pending queue must not satisfy a non-matching receive
    let hub = InProcHub::new();
    let mut a = hub.join(1);
    let mut b = hub.join(2);
    for i in 0..50u32 {
        a.send(2, 7, vec![i as u8]).unwrap();
    }
    let err = b.recv_from(1, 8, Duration::from_millis(30)).unwrap_err();
    assert!(matches!(err, edl::transport::NetError::Timeout { .. }));
    // and the buffered frames are all still there, in order
    for i in 0..50u32 {
        assert_eq!(b.recv_from(1, 7, T).unwrap(), vec![i as u8]);
    }
}
