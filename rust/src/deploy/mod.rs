//! The multi-process TCP deployment: the paper's actual topology, where
//! the leader and every worker are separate OS processes.
//!
//! ```text
//!   edl ctl ──wire::Envelope──► api::JobServer
//!                                    │ (LeaderHandle)
//!   edl serve ──────────────► DeployShell ⟳ LeaderCore   (pure §4 protocol)
//!                                 ▲    │
//!                 rpc::ToLeader frames │ rpc::FromLeader frames
//!                                 │    ▼
//!   edl worker ───────────► control socket ⇄ worker_loop
//!                                │
//!                            MixedNode data plane (ring allreduce +
//!                            model broadcast between worker processes;
//!                            shm ring-buffers between same-machine
//!                            peers, TCP across machines)
//! ```
//!
//! The SAME [`LeaderCore`] drives this deployment and the in-process
//! [`ElasticTrainer`](crate::coordinator::ElasticTrainer); this module is
//! only transport: it frames control messages through [`crate::rpc`],
//! matches connecting worker processes to the core's `Spawn` actions, and
//! pushes the data-plane peer directory ([`rpc::FromLeader::Peers`]:
//! address + machine digest per worker) so data planes can dial each
//! other and same-machine pairs can negotiate the shm transport.
//!
//! Worker arrival model (PyTorch-Elastic-style rendezvous): `edl worker`
//! processes connect unsolicited. The first `n_workers` connections become
//! founders; later connections wait in a lobby until a Table-1 `scale_out`
//! / `migrate` produces `Spawn` slots (or arrive after the request and are
//! matched immediately). Training never stops while they prepare — the
//! §4.2 stop-free path, now across real process boundaries.

use crate::api::{ElasticError, JobControl, JobStatus, ProfileRow, Request, Response};
use crate::coordinator::{
    deliver_reply, perform_load_checkpoint, perform_write_checkpoint, profile_sweep, Action,
    CtrlMsg, Event, LeaderCore, ReplyMap, ReqToken, StepCell, TrainReport, TrainerConfig,
    WorkerEvent,
};
use crate::data::corpus::Corpus;
use crate::rpc::{FromLeader, ToLeader};
use crate::transport::{
    machine_identity, tag, FaultCell, FaultHook, FrameFate, MixedNode, NodeId, NullNode,
};
use crate::util::now_ms;
use crate::wire;
use crate::worker::{worker_loop, Backend, WorkerCtx, WorkerKnobs};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// leader endpoint
// ---------------------------------------------------------------------------

/// Digest of the configuration a leader and its worker processes MUST
/// agree on (corpus shape/seed, model size, learning rate). Carried by
/// [`rpc::ToLeader::Hello`]; the leader refuses mismatched workers with a
/// typed [`rpc::FromLeader::Reject`] instead of letting them silently
/// train on different data (FNV-1a over the packed fields).
pub fn config_digest(
    corpus_samples: u64,
    data_seed: u64,
    param_count: usize,
    seq_len: usize,
    lr: f32,
) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for word in
        [corpus_samples, data_seed, param_count as u64, seq_len as u64, lr.to_bits() as u64]
    {
        for b in word.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// A worker connection that completed the `Hello` handshake but has no
/// worker id yet. (The machine label arrives again with `Register`, which
/// is what the leader core records.)
struct ConnHandle {
    writer: TcpStream,
    config_digest: u64,
    /// physical-machine identity from the Hello (0 = shm disabled); kept
    /// so the peer directory can tell workers which peers share a machine
    machine_digest: u64,
}

enum In {
    /// a worker process said Hello
    Conn(ConnHandle),
    /// a decoded frame from a registered worker's connection
    Wire(ToLeader),
    /// a Table-1 request from a [`LeaderHandle`]
    Ctl(Request, Sender<Response>),
}

/// The leader side of the multi-process deployment: accepts `edl worker`
/// connections and drives the pure [`LeaderCore`] over them.
pub struct LeaderEndpoint {
    /// the worker-endpoint address (`edl worker --leader <this>`)
    pub addr: String,
    tx: Sender<In>,
    shell: Option<std::thread::JoinHandle<TrainReport>>,
    accept_stop: Arc<AtomicBool>,
    step_cell: Arc<StepCell>,
    faults: Arc<FaultCell>,
}

impl LeaderEndpoint {
    /// Bind the worker endpoint on `listen_addr` (use `127.0.0.1:0` for
    /// an ephemeral port) and wait for `n_workers` founding worker
    /// processes. Returns immediately; the job starts once they connect.
    pub fn start(
        cfg: TrainerConfig,
        backend: Arc<dyn Backend>,
        corpus_samples: u64,
        n_workers: usize,
        listen_addr: &str,
        expected_digest: u64,
    ) -> std::io::Result<LeaderEndpoint> {
        assert!(n_workers >= 1);
        let listener = TcpListener::bind(listen_addr)?;
        let addr = listener.local_addr()?.to_string();
        let (tx, rx) = channel::<In>();
        let accept_stop = Arc::new(AtomicBool::new(false));

        // accept loop: handshake each connection, then pump its frames
        {
            let tx = tx.clone();
            let stop = accept_stop.clone();
            std::thread::Builder::new()
                .name("edl-deploy-accept".into())
                .spawn(move || {
                    while let Ok((stream, _)) = listener.accept() {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        let tx = tx.clone();
                        std::thread::spawn(move || {
                            let _ = conn_loop(stream, tx);
                        });
                    }
                })
                .expect("spawn deploy accept loop");
        }

        let assigner = cfg.assigner_for(corpus_samples);
        let reclaim_timeout = cfg.failure_timeout;
        let core = LeaderCore::new(cfg, backend, assigner, n_workers);
        let step_cell = StepCell::new();
        let faults = Arc::new(FaultCell::new());
        // per-job shm namespace: every worker of THIS job maps rings under
        // the same directory, and two jobs never collide (time + port)
        let port = addr.rsplit(':').next().unwrap_or("0");
        let shm_ns = format!("edl-{:x}-{port}", now_ms());
        let shell = DeployShell {
            core,
            rx,
            faults: faults.clone(),
            writers: HashMap::new(),
            joiner_flag: HashMap::new(),
            attached: std::collections::HashSet::new(),
            welcomed_at: HashMap::new(),
            lobby: VecDeque::new(),
            pending_spawns: VecDeque::new(),
            expected_founders: n_workers,
            founders_assigned: 0,
            expected_digest,
            reclaim_timeout,
            directory: BTreeMap::new(),
            digests: BTreeMap::new(),
            shm_ns,
            replies: HashMap::new(),
            next_token: 0,
            step_cell: step_cell.clone(),
        };
        let shell_handle = std::thread::Builder::new()
            .name("edl-deploy-leader".into())
            .spawn(move || shell.run())
            .expect("spawn deploy leader");

        Ok(LeaderEndpoint { addr, tx, shell: Some(shell_handle), accept_stop, step_cell, faults })
    }

    /// A cloneable Table-1 control handle (wrap it in `api::JobServer` to
    /// expose the job to remote schedulers).
    pub fn handle(&self) -> LeaderHandle {
        LeaderHandle { tx: self.tx.clone(), step_cell: self.step_cell.clone() }
    }

    /// Arm/disarm the chaos-harness fault hook on the leader's OUTBOUND
    /// control frames (`rpc::FromLeader`, from pseudo-node 0 to the worker
    /// id, `tag::RPC` family). Zero-cost when off; the §4.2 failure
    /// detector is what turns injected silence into recovery.
    pub fn set_fault_hook(&self, hook: Option<Arc<dyn FaultHook>>) {
        self.faults.arm(hook);
    }

    /// Block until the job stops (a scheduler issued `stop`), then tear
    /// down the accept loop and return the training report.
    pub fn join(mut self) -> TrainReport {
        let report = self
            .shell
            .take()
            .and_then(|h| h.join().ok())
            .unwrap_or_default();
        self.accept_stop.store(true, Ordering::Relaxed);
        // wake the blocking accept so the listener thread can exit
        let _ = TcpStream::connect(&self.addr);
        report
    }
}

impl Drop for LeaderEndpoint {
    fn drop(&mut self) {
        self.accept_stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(&self.addr);
    }
}

/// Per-connection reader: handshake (`Hello`), then decode frames into
/// the shell's mailbox until the peer closes.
fn conn_loop(stream: TcpStream, tx: Sender<In>) -> wire::Result<()> {
    stream.set_nodelay(true)?; // §4.4
    let mut reader = BufReader::new(stream.try_clone()?);
    let first = wire::read_frame(&mut reader)?;
    match ToLeader::decode(&first) {
        Ok(ToLeader::Hello { machine: _, config_digest, machine_digest }) => {
            let conn = ConnHandle { writer: stream, config_digest, machine_digest };
            if tx.send(In::Conn(conn)).is_err() {
                return Ok(());
            }
        }
        _ => return Ok(()), // not a worker handshake: drop the connection
    }
    loop {
        let raw = match wire::read_frame(&mut reader) {
            Ok(r) => r,
            Err(_) => return Ok(()), // peer closed; the §4.2 failure
                                     // detector handles silent deaths
        };
        match ToLeader::decode(&raw) {
            Ok(msg) => {
                if tx.send(In::Wire(msg)).is_err() {
                    return Ok(());
                }
            }
            Err(_) => return Ok(()), // malformed frame: drop the peer
        }
    }
}

struct DeployShell {
    core: LeaderCore,
    rx: Receiver<In>,
    /// chaos-harness hook over outbound control frames (off by default)
    faults: Arc<FaultCell>,
    /// control-message writers, one socket per registered worker
    writers: HashMap<NodeId, TcpStream>,
    joiner_flag: HashMap<NodeId, bool>,
    attached: std::collections::HashSet<NodeId>,
    /// Welcome sent, Register not yet seen — reclaimed after
    /// `reclaim_timeout` so a process that dies mid-handshake cannot
    /// wedge a founder slot or a Spawn slot forever
    welcomed_at: HashMap<NodeId, Instant>,
    /// connections waiting for a Spawn slot
    lobby: VecDeque<ConnHandle>,
    /// Spawn slots waiting for a connection (with the slot's birth time,
    /// so a slot no process ever claims can be expired)
    pending_spawns: VecDeque<(NodeId, String, bool, Instant)>,
    expected_founders: usize,
    founders_assigned: usize,
    expected_digest: u64,
    reclaim_timeout: Duration,
    /// data-plane peer directory (worker id → TcpNode listen addr)
    directory: BTreeMap<NodeId, String>,
    /// worker id → machine-identity digest (from Hello); pushed alongside
    /// addresses so every pair of same-machine workers negotiates the shm
    /// transport, and fed to the core for topology-aware ring order
    digests: BTreeMap<NodeId, u64>,
    /// job-unique shm namespace, told to each worker in its Welcome
    shm_ns: String,
    replies: ReplyMap,
    next_token: ReqToken,
    step_cell: Arc<StepCell>,
}

impl DeployShell {
    fn run(mut self) -> TrainReport {
        loop {
            let actions = match self.rx.recv_timeout(Duration::from_millis(25)) {
                Ok(In::Conn(conn)) => {
                    self.place_conn(conn);
                    Vec::new()
                }
                Ok(In::Wire(msg)) => self.handle_wire(msg),
                Ok(In::Ctl(req, reply)) => {
                    self.next_token += 1;
                    let token = self.next_token;
                    self.replies.insert(token, reply);
                    self.core.handle(now_ms(), Event::Request { token, req })
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    let mut acts = self.reclaim_stale_welcomes();
                    acts.extend(self.core.handle(now_ms(), Event::Tick));
                    acts
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            };
            let shutdown = self.apply(actions);
            self.step_cell.publish(self.core.step());
            if shutdown {
                // drain window: let worker Goodbyes land before teardown
                let deadline = Instant::now() + Duration::from_millis(200);
                while let Ok(msg) =
                    self.rx.recv_timeout(deadline.saturating_duration_since(Instant::now()))
                {
                    if let In::Wire(m) = msg {
                        if let Some(ev) = m.into_event() {
                            let _ = self.core.handle(now_ms(), Event::Worker(ev));
                        }
                    }
                }
                break;
            }
        }
        // release any never-welcomed workers so their processes exit
        for conn in self.lobby.drain(..) {
            let mut w = conn.writer;
            let _ = wire::write_frame(&mut w, &FromLeader::Stop.encode());
        }
        self.step_cell.leader_gone();
        self.core.into_report()
    }

    /// Assign a freshly connected worker process: founder slot first,
    /// then pending Spawn slots, else the lobby. A config-digest mismatch
    /// is refused outright — a worker building a different corpus/model
    /// would silently train on wrong data.
    fn place_conn(&mut self, conn: ConnHandle) {
        if conn.config_digest != self.expected_digest {
            let mut w = conn.writer;
            let _ = wire::write_frame(
                &mut w,
                &FromLeader::Reject {
                    reason: format!(
                        "config digest mismatch: worker {:#x}, leader {:#x} \
                         (check --samples/--data-seed/--params/--lr/--backend)",
                        conn.config_digest, self.expected_digest
                    ),
                }
                .encode(),
            );
            return;
        }
        if self.founders_assigned < self.expected_founders {
            self.founders_assigned += 1;
            let id = self.core.next_worker_id();
            self.welcome(conn, id, false);
        } else if let Some((id, _machine, joiner, _born)) = self.pending_spawns.pop_front() {
            self.welcome(conn, id, joiner);
        } else {
            self.lobby.push_back(conn);
        }
    }

    fn welcome(&mut self, conn: ConnHandle, id: NodeId, joiner: bool) {
        // a stalled worker socket must never freeze the single-threaded
        // shell: writes that block past the failure timeout error out and
        // the worker is treated as dead
        let _ = conn.writer.set_write_timeout(Some(self.reclaim_timeout));
        self.writers.insert(id, conn.writer);
        self.digests.insert(id, conn.machine_digest);
        self.joiner_flag.insert(id, joiner);
        self.welcomed_at.insert(id, Instant::now());
        let shm_ns = self.shm_ns.clone();
        self.send_frame(id, &FromLeader::Welcome { worker: id, joiner, shm_ns });
    }

    /// Timeout-driven slot hygiene so a process that dies mid-handshake
    /// (or a scale-out no `edl worker` ever claims) cannot wedge the job:
    ///  * welcomed-but-never-registered workers: SEVER the socket (a late
    ///    `Register` must not resurrect the reclaimed id), reopen founder
    ///    slots, requeue joiner spawn slots;
    ///  * spawn slots no connection claimed within the timeout: tell the
    ///    core via [`Event::SpawnFailed`] so the §3.1 in-flight guard
    ///    releases and the pending operation aborts with a typed error.
    fn reclaim_stale_welcomes(&mut self) -> Vec<Action> {
        let expired: Vec<NodeId> = self
            .welcomed_at
            .iter()
            .filter(|(_, t)| t.elapsed() > self.reclaim_timeout)
            .map(|(&id, _)| id)
            .collect();
        for id in expired {
            self.welcomed_at.remove(&id);
            self.digests.remove(&id);
            if let Some(w) = self.writers.remove(&id) {
                let _ = w.shutdown(std::net::Shutdown::Both);
            }
            let joiner = self.joiner_flag.remove(&id).unwrap_or(false);
            if joiner {
                self.pending_spawns.push_back((id, String::new(), true, Instant::now()));
            } else {
                self.founders_assigned = self.founders_assigned.saturating_sub(1);
            }
        }
        let mut actions = Vec::new();
        while let Some(&(id, _, _, born)) = self.pending_spawns.front() {
            if born.elapsed() <= self.reclaim_timeout {
                break;
            }
            self.pending_spawns.pop_front();
            actions.extend(self.core.handle(now_ms(), Event::SpawnFailed { id }));
        }
        actions
    }

    fn send_frame(&mut self, to: NodeId, msg: &FromLeader) {
        // the chaos seam: the SAME code path runs with faults armed — a
        // dropped frame here looks exactly like a flaky network to the
        // worker, and the protocol must recover on its own. The Welcome
        // handshake is exempt: connection setup is retried by the worker
        // process itself, and faulting it only tests the reclaim sweep.
        let mut copies = 1u32;
        if !matches!(msg, FromLeader::Welcome { .. }) {
            match self.faults.fate(0, to, tag::RPC) {
                FrameFate::Deliver => {}
                FrameFate::Drop => return,
                FrameFate::Duplicate => copies = 2,
                FrameFate::Delay(d) => std::thread::sleep(d),
            }
        }
        for _ in 0..copies {
            let dead = match self.writers.get_mut(&to) {
                Some(w) => wire::write_frame(w, &msg.encode()).is_err(),
                None => false,
            };
            if dead {
                // worker process gone: drop the route; the barrier-timeout
                // failure detector removes it from the job
                self.writers.remove(&to);
                break;
            }
        }
    }

    /// Push the full data-plane directory to every connected worker (sent
    /// whenever membership grows, BEFORE any Ok/SyncGo that could name the
    /// new peer — per-socket ordering then guarantees workers can dial
    /// every ring member they are told about).
    fn broadcast_peers(&mut self) {
        let peers: Vec<(NodeId, String, u64)> = self
            .directory
            .iter()
            .map(|(&id, a)| (id, a.clone(), self.digests.get(&id).copied().unwrap_or(0)))
            .collect();
        let msg = FromLeader::Peers { peers };
        let ids: Vec<NodeId> = self.writers.keys().copied().collect();
        for id in ids {
            self.send_frame(id, &msg);
        }
    }

    fn handle_wire(&mut self, msg: ToLeader) -> Vec<Action> {
        let mut actions = Vec::new();
        if let ToLeader::Register { worker, machine, data_addr, machine_digest } = &msg {
            self.welcomed_at.remove(worker);
            self.directory.insert(*worker, data_addr.clone());
            self.digests.insert(*worker, *machine_digest);
            self.broadcast_peers();
            if self.attached.insert(*worker) {
                let joiner = self.joiner_flag.get(worker).copied().unwrap_or(false);
                actions.extend(self.core.handle(
                    now_ms(),
                    Event::Worker(WorkerEvent::Attach {
                        id: *worker,
                        machine: machine.clone(),
                        joiner,
                    }),
                ));
            }
        }
        if let ToLeader::Goodbye { worker, .. } = &msg {
            let worker = *worker;
            self.writers.remove(&worker);
            self.directory.remove(&worker);
            self.digests.remove(&worker);
            self.attached.remove(&worker);
        }
        if let Some(ev) = msg.into_event() {
            actions.extend(self.core.handle(now_ms(), Event::Worker(ev)));
        }
        actions
    }

    /// Perform a batch of core actions; true once the job stopped.
    ///
    /// Consecutive `Send`s are coalesced and flushed per destination with
    /// ONE vectored write ([`wire::write_frames`]): a sync barrier or a
    /// scale commit emits a burst of small control frames to every
    /// worker, and with TCP_NODELAY each scalar write is a syscall plus a
    /// segment. Any non-Send action flushes first, so per-socket frame
    /// order is exactly what the scalar path produced.
    fn apply(&mut self, actions: Vec<Action>) -> bool {
        let mut shutdown = false;
        let mut burst: Vec<(NodeId, FromLeader)> = Vec::new();
        for a in actions {
            if let Action::Send { to, msg } = a {
                burst.push((to, FromLeader::from_ctrl(&msg)));
                continue;
            }
            self.flush_sends(&mut burst);
            match a {
                Action::Send { .. } => unreachable!("queued above"),
                Action::Reply { token, resp } => {
                    deliver_reply(&mut self.replies, token, resp);
                }
                Action::Spawn { id, machine, joiner } => {
                    if let Some(conn) = self.lobby.pop_front() {
                        self.welcome(conn, id, joiner);
                    } else {
                        self.pending_spawns.push_back((id, machine, joiner, Instant::now()));
                    }
                }
                Action::WriteCheckpoint { token, path, bytes } => {
                    perform_write_checkpoint(&mut self.replies, token, &path, &bytes);
                }
                Action::LoadCheckpoint { path } => {
                    let ev = perform_load_checkpoint(&path);
                    let more = self.core.handle(now_ms(), ev);
                    shutdown |= self.apply(more);
                }
                Action::Shutdown => shutdown = true,
            }
        }
        self.flush_sends(&mut burst);
        shutdown
    }

    /// Write a queued run of control frames, one vectored write per
    /// destination socket. The chaos seam is per FRAME, exactly as on the
    /// scalar path (same `fate` call order), so armed fault plans produce
    /// identical verdicts whether or not frames happened to batch.
    fn flush_sends(&mut self, burst: &mut Vec<(NodeId, FromLeader)>) {
        if burst.is_empty() {
            return;
        }
        if burst.len() == 1 {
            let (to, msg) = burst.pop().expect("len checked");
            self.send_frame(to, &msg);
            return;
        }
        let mut per: BTreeMap<NodeId, Vec<Vec<u8>>> = BTreeMap::new();
        for (to, msg) in burst.drain(..) {
            match self.faults.fate(0, to, tag::RPC) {
                FrameFate::Deliver => {}
                FrameFate::Drop => continue,
                FrameFate::Duplicate => {
                    per.entry(to).or_default().push(msg.encode());
                }
                FrameFate::Delay(d) => std::thread::sleep(d),
            }
            per.entry(to).or_default().push(msg.encode());
        }
        for (to, frames) in per {
            let dead = match self.writers.get_mut(&to) {
                Some(w) => wire::write_frames(w, &frames).is_err(),
                None => false,
            };
            if dead {
                // worker process gone: drop the route; the barrier-timeout
                // failure detector removes it from the job
                self.writers.remove(&to);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Table-1 handle
// ---------------------------------------------------------------------------

/// Cloneable [`JobControl`] handle to a [`LeaderEndpoint`] — what
/// `api::JobServer` serves to `edl ctl` processes.
#[derive(Clone)]
pub struct LeaderHandle {
    tx: Sender<In>,
    step_cell: Arc<StepCell>,
}

impl LeaderHandle {
    /// Blocking Table-1 round-trip into the deploy shell.
    pub fn call(&self, req: Request) -> Response {
        self.call_with_timeout(req, Duration::from_secs(600))
    }

    /// [`LeaderHandle::call`] with an explicit reply deadline — pollers
    /// that watch MANY jobs (the cluster master's per-tick status sweep)
    /// must never let one wedged leader stall the whole control plane.
    pub fn call_with_timeout(&self, req: Request, timeout: Duration) -> Response {
        let (rtx, rrx) = channel();
        if self.tx.send(In::Ctl(req, rtx)).is_err() {
            return Response::Err(ElasticError::Aborted("leader gone".into()));
        }
        rrx.recv_timeout(timeout)
            .unwrap_or(Response::Err(ElasticError::Aborted("leader timed out".into())))
    }

    /// Wait on the shell's step condvar (no status busy-poll, same
    /// mechanism as `ElasticTrainer::wait_step`).
    pub fn wait_step(&self, step: u64, timeout: Duration) -> bool {
        self.step_cell.wait(step, timeout)
    }
}

impl JobControl for LeaderHandle {
    fn scale_out(&mut self, machines: Vec<String>) -> Result<(), ElasticError> {
        self.call(Request::ScaleOut { machines }).unit()
    }
    fn scale_in(&mut self, workers: Vec<NodeId>) -> Result<(), ElasticError> {
        self.call(Request::ScaleIn { workers }).unit()
    }
    fn migrate(&mut self, remove: Vec<NodeId>, add: Vec<String>) -> Result<(), ElasticError> {
        self.call(Request::Migrate { remove, add }).unit()
    }
    fn profile(
        &mut self,
        min_p: u32,
        steps_per_level: u64,
    ) -> Result<Vec<ProfileRow>, ElasticError> {
        // the one shared sweep, driven over the handle (runs on the
        // JobServer connection thread, so it never stalls the leader shell)
        profile_sweep(
            &|req| self.call(req),
            &|step, timeout| self.wait_step(step, timeout),
            min_p,
            steps_per_level,
        )
    }
    fn status(&mut self) -> Result<JobStatus, ElasticError> {
        self.call(Request::Status).status()
    }
    fn checkpoint(&mut self, path: &str) -> Result<(), ElasticError> {
        self.call(Request::Checkpoint { path: path.to_string() }).unit()
    }
    fn restore(&mut self, path: &str) -> Result<(), ElasticError> {
        self.call(Request::Restore { path: path.to_string() }).unit()
    }
    fn stop(&mut self) -> Result<(), ElasticError> {
        self.call(Request::Stop).unit()
    }
}

// ---------------------------------------------------------------------------
// worker process
// ---------------------------------------------------------------------------

/// Everything `edl worker` needs to join a served job.
pub struct WorkerParams {
    pub leader_addr: String,
    pub machine: String,
    pub backend: Arc<dyn Backend>,
    pub corpus: Arc<Corpus>,
    pub lr: f32,
    /// must match the leader's [`config_digest`] or the handshake is
    /// refused (prevents silently training on mismatched data)
    pub config_digest: u64,
    /// run without a data plane: sends are blackholed, collectives skipped.
    /// Valid only when every worker of the job is headless (master
    /// `--headless-workers`); lets one box host hundreds of live jobs.
    pub headless: bool,
}

/// Run one worker process: handshake with the leader endpoint, stand up a
/// [`MixedNode`] data plane (shm rings to same-machine peers, TCP across
/// machines), bridge the control socket onto the channel pair
/// [`worker_loop`] expects, and train until `Stop` / graceful exit. This
/// is the same training loop the in-process engine runs — only the
/// transport differs.
pub fn run_worker(p: WorkerParams) -> anyhow::Result<()> {
    let stream = TcpStream::connect(&p.leader_addr)?;
    stream.set_nodelay(true)?; // §4.4
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;

    // -- handshake: Hello -> Welcome{id, joiner, shm_ns} --------------------
    let my_digest = machine_identity();
    wire::write_frame(
        &mut writer,
        &ToLeader::Hello {
            machine: p.machine.clone(),
            config_digest: p.config_digest,
            machine_digest: my_digest,
        }
        .encode(),
    )?;
    let (id, joiner, shm_ns) = loop {
        let raw = wire::read_frame(&mut reader)?;
        match FromLeader::decode(&raw)? {
            FromLeader::Welcome { worker, joiner, shm_ns } => break (worker, joiner, shm_ns),
            FromLeader::Reject { reason } => {
                anyhow::bail!("leader refused this worker: {reason}");
            }
            // a lobby release during shutdown: exit cleanly
            FromLeader::Stop => return Ok(()),
            _ => {}
        }
    };

    // -- data plane ---------------------------------------------------------
    // MixedNode: shm ring-buffers to peers whose machine digest matches
    // ours (negotiated from the Peers directory, no extra handshake), TCP
    // to everyone else. A digest of 0 (EDL_SHM=0, or no stable identity)
    // degrades every link to TCP.
    let directory: Arc<Mutex<HashMap<NodeId, String>>> = Arc::new(Mutex::new(HashMap::new()));
    // Headless workers bind no data plane at all: a NullNode blackholes
    // sends and times receives out instantly, and the registered address
    // is a placeholder no peer will ever dial (valid only when the whole
    // job is headless). `eff_digest` is 0 there — no shm negotiation.
    let (net, data_addr, eff_digest) = if p.headless {
        (None, format!("headless/{id}"), 0)
    } else {
        let n = MixedNode::start(id, directory.clone(), my_digest, &shm_ns)
            .map_err(|e| anyhow::anyhow!("data-plane bind failed: {e}"))?;
        let addr = n.addr().to_string();
        (Some(n), addr, my_digest)
    };
    let peer_digests = match &net {
        Some(n) => n.peer_digests(),
        None => Arc::new(Mutex::new(HashMap::new())),
    };
    // the grouping map must cover the whole ring, self included (the rx
    // bridge below only learns about OTHER peers)
    peer_digests.lock().unwrap_or_else(|e| e.into_inner()).insert(id, eff_digest);

    // -- control bridges ----------------------------------------------------
    let (ev_tx, ev_rx) = channel::<WorkerEvent>();
    let (ctrl_tx, ctrl_rx) = channel::<CtrlMsg>();

    // worker events -> rpc frames (Register is stamped with data_addr)
    let writer_bridge = std::thread::Builder::new()
        .name(format!("edl-worker-{id}-tx"))
        .spawn(move || {
            while let Ok(ev) = ev_rx.recv() {
                let Some(msg) = ToLeader::from_event(&ev, &data_addr) else { continue };
                if wire::write_frame(&mut writer, &msg.encode()).is_err() {
                    break;
                }
            }
        })
        .expect("spawn worker tx bridge");

    // rpc frames -> ctrl messages; Peers frames maintain the directory
    // (addresses for the TCP half, machine digests for shm negotiation
    // and hierarchical ring grouping)
    {
        let directory = directory.clone();
        let peer_digests = peer_digests.clone();
        std::thread::Builder::new()
            .name(format!("edl-worker-{id}-rx"))
            .spawn(move || loop {
                let Ok(raw) = wire::read_frame(&mut reader) else { break };
                let Ok(msg) = FromLeader::decode(&raw) else { break };
                match msg {
                    FromLeader::Peers { peers } => {
                        let mut d = directory.lock().unwrap_or_else(|e| e.into_inner());
                        let mut g = peer_digests.lock().unwrap_or_else(|e| e.into_inner());
                        for (pid, addr, digest) in peers {
                            d.insert(pid, addr);
                            if pid != id {
                                g.insert(pid, digest);
                            }
                        }
                    }
                    other => {
                        if let Some(ctrl) = other.into_ctrl() {
                            if ctrl_tx.send(ctrl).is_err() {
                                break;
                            }
                        }
                    }
                }
            })
            .expect("spawn worker rx bridge");
    }

    // -- the one true training loop ----------------------------------------
    match net {
        Some(n) => {
            let ctx = WorkerCtx {
                id,
                machine: p.machine,
                backend: p.backend,
                corpus: p.corpus,
                net: n,
                to_leader: ev_tx,
                ctrl: ctrl_rx,
                lr: p.lr,
                knobs: WorkerKnobs::new(),
                joiner,
                init_seed: 42,
                machine_digest: eff_digest,
                peer_digests,
                headless: false,
            };
            worker_loop(ctx);
        }
        None => {
            let ctx = WorkerCtx {
                id,
                machine: p.machine,
                backend: p.backend,
                corpus: p.corpus,
                net: NullNode::new(id),
                to_leader: ev_tx,
                ctrl: ctrl_rx,
                lr: p.lr,
                knobs: WorkerKnobs::new(),
                joiner,
                init_seed: 42,
                machine_digest: 0,
                peer_digests,
                headless: true,
            };
            worker_loop(ctx);
        }
    }
    // ctx (and its event sender) is gone; the tx bridge drains the last
    // frames (Goodbye) and exits — join it so they reach the leader
    let _ = writer_bridge.join();
    Ok(())
}
