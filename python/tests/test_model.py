"""L2 model tests: flat-param layout, forward shapes, loss sanity,
gradient correctness (numeric check), and training-step behaviour."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.CONFIGS["tiny"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, 0)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, CFG.vocab, (2, CFG.seq_len)), jnp.int32)


def test_param_count_matches_spec(params):
    assert params.shape == (M.param_count(CFG),)
    assert M.param_count(CFG) == sum(
        int(np.prod(s)) for _, s in M.param_spec(CFG)
    )


def test_flatten_unflatten_roundtrip(params):
    tree = M.unflatten(CFG, params)
    again = M.flatten(CFG, tree)
    np.testing.assert_array_equal(params, again)


def test_unflatten_shapes(params):
    tree = M.unflatten(CFG, params)
    for name, shape in M.param_spec(CFG):
        assert tree[name].shape == shape, name


def test_init_deterministic():
    a = M.init_params(CFG, 42)
    b = M.init_params(CFG, 42)
    np.testing.assert_array_equal(a, b)
    c = M.init_params(CFG, 43)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_init_layernorm_scales_are_one(params):
    tree = M.unflatten(CFG, params)
    np.testing.assert_array_equal(tree["lnf_s"], np.ones(CFG.d_model, np.float32))
    np.testing.assert_array_equal(tree["l0.ln1_s"], np.ones(CFG.d_model, np.float32))
    np.testing.assert_array_equal(tree["l0.b1"], np.zeros(CFG.d_ff, np.float32))


def test_forward_shape(params, tokens):
    logits = M.forward(CFG, M.unflatten(CFG, params), tokens)
    assert logits.shape == (2, CFG.seq_len, CFG.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_initial_loss_near_uniform(params, tokens):
    loss = float(M.fwd_loss(CFG, params, tokens))
    assert abs(loss - math.log(CFG.vocab)) < 1.0


def test_grad_step_returns_finite(params, tokens):
    loss, grads = M.grad_step(CFG, params, tokens)
    assert math.isfinite(float(loss))
    assert grads.shape == params.shape
    g = np.asarray(grads)
    assert np.all(np.isfinite(g))
    assert np.linalg.norm(g) > 0


def test_grad_matches_numeric(params, tokens):
    """Central-difference check on a handful of coordinates."""
    _, grads = M.grad_step(CFG, params, tokens)
    f = lambda p: float(M.fwd_loss(CFG, p, tokens))
    rng = np.random.default_rng(1)
    # pick coords with non-trivial gradient so the check is meaningful
    g = np.asarray(grads)
    big = np.argsort(-np.abs(g))[:200]
    coords = rng.choice(big, 4, replace=False)
    eps = 1e-2
    n = params.shape[0]
    for i in coords:
        e = np.zeros(n, np.float32)
        e[i] = eps
        num = (f(params + e) - f(params - e)) / (2 * eps)
        assert abs(num - g[i]) < 5e-2 * max(1.0, abs(g[i])) + 5e-3, (i, num, g[i])


def test_train_step_decreases_loss(params, tokens):
    loss0, p1 = M.train_step(CFG, params, tokens, jnp.float32(0.5))
    loss1, _ = M.train_step(CFG, p1, tokens, jnp.float32(0.5))
    assert float(loss1) < float(loss0)


def test_apply_update_direction(params):
    g = jnp.ones_like(params)
    p2 = M.apply_update(params, g, jnp.float32(0.1))
    np.testing.assert_allclose(np.asarray(p2), np.asarray(params) - 0.1, rtol=1e-6, atol=1e-6)


def test_grad_is_mean_over_batch(params):
    """grads(batch of 2 identical rows) == grads(batch of 1 row)."""
    rng = np.random.default_rng(2)
    row = rng.integers(0, CFG.vocab, (1, CFG.seq_len))
    t1 = jnp.asarray(row, jnp.int32)
    t2 = jnp.asarray(np.vstack([row, row]), jnp.int32)
    l1, g1 = M.grad_step(CFG, params, t1)
    l2, g2 = M.grad_step(CFG, params, t2)
    assert abs(float(l1) - float(l2)) < 1e-4
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-3, atol=1e-5)


def test_data_parallel_equivalence(params):
    """The paper's consistency semantics: grads averaged over two
    half-batches (weighted allreduce) equal grads of the full batch."""
    rng = np.random.default_rng(3)
    toks = rng.integers(0, CFG.vocab, (4, CFG.seq_len))
    full = jnp.asarray(toks, jnp.int32)
    a = jnp.asarray(toks[:2], jnp.int32)
    b = jnp.asarray(toks[2:], jnp.int32)
    lf, gf = M.grad_step(CFG, params, full)
    la, ga = M.grad_step(CFG, params, a)
    lb, gb = M.grad_step(CFG, params, b)
    np.testing.assert_allclose(
        (np.asarray(ga) + np.asarray(gb)) / 2, np.asarray(gf), rtol=1e-3, atol=1e-5
    )
    assert abs((float(la) + float(lb)) / 2 - float(lf)) < 1e-4
