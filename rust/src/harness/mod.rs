//! `edl::harness` — the deterministic chaos harness (FoundationDB-style
//! simulation testing for the whole EDL stack).
//!
//! Three pillars:
//!
//!  * [`fault`] — the injectable fault model: a [`FaultPlan`]
//!    (drop/delay/duplicate/partition/heal, keyed by `(from, to,
//!    tag-family)` and fault-clock time) that live layers accept behind a
//!    zero-cost-when-off hook ([`transport::FaultCell`]): `InProcHub`,
//!    `TcpNode`, the deploy control plane and the coordination KV all run
//!    their REAL code paths with faults armed;
//!  * [`chaos`] — seeded chaos schedules: one `u64` seed derives a
//!    reproducible script of worker kills, partitions, delayed/duplicated
//!    control frames, concurrent Grow/Shrink/Migrate decisions,
//!    checkpoints and leader restarts, executed against the real
//!    [`LeaderCore`](crate::coordinator::LeaderCore) under a virtual
//!    clock, with independent invariant mirrors checked after every
//!    event (step monotonicity, exactly-one-reply adjustment
//!    reconciliation, barrier-loss integrity, §4.3 exactly-once sample
//!    accounting, checkpoint-recovery convergence, liveness);
//!  * [`testutil`] — bounded condition-polling helpers shared by the e2e
//!    suites, so no test waits on a bare tuned `sleep`.
//!
//! `rust/tests/chaos.rs` runs hundreds of seeds per push, shrinks a
//! failing seed to its shortest failing script prefix, and prints the
//! exact repro command. DESIGN.md §6 documents the fault taxonomy and the
//! invariant list.

pub mod chaos;
pub mod fault;
pub mod mirrors;
pub mod testutil;

pub use chaos::{run_schedule, run_seed, ChaosFailure, ChaosReport, ChaosSchedule};
pub use fault::{FaultClock, FaultKind, FaultPlan, FaultRule, Family};
