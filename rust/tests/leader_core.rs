//! Deterministic protocol tests of the pure [`LeaderCore`] under a
//! virtual clock: the same recorded `(now_ms, Event)` trace must produce
//! byte-identical action logs on every replay, stale events from departed
//! workers must be dropped (never crash the leader), and the §4.2
//! stop-free switch must be scheduled at least the allowance ahead while
//! barriers keep flowing.

use edl::api::{ElasticError, Request, Response};
use edl::coordinator::replay::{replay, scheduled_join_step, ScriptedLeader};
use edl::coordinator::{
    Action, CtrlMsg, Event, LeaderCore, TrainerConfig, WorkerEvent,
};
use edl::worker::SimBackend;
use std::sync::Arc;

fn cfg() -> TrainerConfig {
    TrainerConfig { switch_allowance_ms: 500.0, ..TrainerConfig::default() }
}

fn scripted(n_founders: usize) -> ScriptedLeader {
    ScriptedLeader::new(cfg(), Arc::new(SimBackend::fast(16)), n_founders)
}

/// Drive a full protocol scenario and return the recorded trace: join 2
/// founders, train, scale out 2→3, train past the commit, scale in 3→2,
/// train, checkpoint-param flow, stop.
fn scenario_trace() -> Vec<(f64, Event)> {
    let mut l = scripted(2);
    l.join_worker(1, "m0", false);
    l.join_worker(2, "m0", false);
    l.run_barriers(6, 100.0);

    let (_t, acts) = l.request(Request::ScaleOut { machines: vec!["m1".into()] });
    let joiner = acts
        .iter()
        .find_map(|a| match a {
            Action::Spawn { id, .. } => Some(*id),
            _ => None,
        })
        .expect("spawn for the joiner");
    let acts = l.join_worker(joiner, "m1", true);
    let at = scheduled_join_step(&acts).expect("switch scheduled");
    while l.core.step() < at {
        l.run_barrier(100.0);
    }
    l.run_barriers(3, 80.0);

    let victim = *l.core.active_workers().last().unwrap();
    let (_t, _a) = l.request(Request::ScaleIn { workers: vec![victim] });
    let before = l.core.step();
    while l.core.step() < before + 10 && l.core.active_workers().contains(&victim) {
        l.run_barrier(80.0);
    }
    // the victim exits gracefully at the boundary
    l.feed(0.5, Event::Worker(WorkerEvent::Goodbye { id: victim, shard: None }));
    l.run_barriers(2, 80.0);

    // periodic ticks are part of real traces
    l.feed(25.0, Event::Tick);
    l.feed(25.0, Event::Tick);
    let (_t, _a) = l.request(Request::Status);
    let (_t, _a) = l.request(Request::Stop);
    l.trace
}

#[test]
fn same_trace_twice_yields_byte_identical_action_logs() {
    let trace = scenario_trace();
    assert!(trace.len() > 40, "scenario should be non-trivial: {}", trace.len());

    let mut core_a = LeaderCore::new(cfg(), Arc::new(SimBackend::fast(16)), cfg().assigner_for(4096), 2);
    let mut core_b = LeaderCore::new(cfg(), Arc::new(SimBackend::fast(16)), cfg().assigner_for(4096), 2);
    let log_a = replay(&mut core_a, &trace);
    let log_b = replay(&mut core_b, &trace);
    assert!(!log_a.is_empty());
    assert_eq!(log_a, log_b, "replaying the same trace must be deterministic");

    // and byte-identical as one blob (the acceptance criterion verbatim)
    assert_eq!(log_a.join("\n").into_bytes(), log_b.join("\n").into_bytes());

    // the reports agree too (loss history is ordered arithmetic)
    let ra = core_a.into_report();
    let rb = core_b.into_report();
    assert_eq!(ra.steps, rb.steps);
    assert_eq!(format!("{:?}", ra.loss_history), format!("{:?}", rb.loss_history));
    assert!(ra.events.iter().any(|e| e.what.contains("switch-committed")));
}

#[test]
fn late_sync_from_removed_worker_is_dropped_not_a_crash() {
    let mut l = scripted(3);
    l.join_worker(1, "m0", false);
    l.join_worker(2, "m0", false);
    l.join_worker(3, "m0", false);
    l.run_barriers(6, 50.0);

    // graceful scale-in of worker 3
    let (_t, _a) = l.request(Request::ScaleIn { workers: vec![3] });
    let before = l.core.step();
    while l.core.active_workers().contains(&3) && l.core.step() < before + 20 {
        l.run_barrier(50.0);
    }
    assert!(!l.core.active_workers().contains(&3), "victim should have exited the ring");
    l.feed(0.1, Event::Worker(WorkerEvent::Goodbye { id: 3, shard: None }));

    // the regression: a LATE Sync from the removed worker (it was slow to
    // die). The seed leader indexed `workers[&id]` on such paths and could
    // panic; the core must log-and-drop.
    let step = l.core.step();
    let acts = l.feed(
        1.0,
        Event::Worker(WorkerEvent::Sync {
            id: 3,
            step,
            loss: 0.5,
            weight: 8.0,
            step_ms: 50.0,
            shard: None,
        }),
    );
    assert!(acts.is_empty(), "stale sync must produce no actions: {acts:?}");
    // late Ready from the removed worker is equally harmless
    let acts = l.feed(1.0, Event::Worker(WorkerEvent::Ready { id: 3 }));
    assert!(acts.is_empty(), "stale ready must produce no actions: {acts:?}");

    // and the survivors keep training normally
    let acts = l.run_barrier(50.0);
    assert!(
        acts.iter().any(|a| matches!(a, Action::Send { msg: CtrlMsg::SyncGo { .. }, .. })),
        "barrier must still complete: {acts:?}"
    );
    let report = l.core.into_report();
    assert!(report.events.iter().any(|e| e.what.contains("stale-sync")));
}

#[test]
fn joiner_goodbye_before_commit_aborts_instead_of_wedging() {
    let mut l = scripted(2);
    l.join_worker(1, "m0", false);
    l.join_worker(2, "m0", false);
    l.run_barriers(4, 50.0);

    let (token, acts) = l.request(Request::ScaleOut { machines: vec!["m9".into()] });
    let joiner = acts
        .iter()
        .find_map(|a| match a {
            Action::Spawn { id, .. } => Some(*id),
            _ => None,
        })
        .unwrap();
    // the joiner attaches, then dies (goodbye) BEFORE ever becoming ready
    l.feed(1.0, Event::Worker(WorkerEvent::Attach { id: joiner, machine: "m9".into(), joiner: true }));
    let acts = l.feed(1.0, Event::Worker(WorkerEvent::Goodbye { id: joiner, shard: None }));
    let aborted = acts.iter().any(|a| {
        matches!(a, Action::Reply { token: t, resp: Response::Err(ElasticError::Aborted(_)) } if *t == token)
    });
    assert!(aborted, "pending scale-out must abort, got {acts:?}");

    // the job is adjustable again (not wedged on a ghost joiner)
    let (_t2, acts) = l.request(Request::ScaleIn { workers: vec![2] });
    assert!(
        !acts.iter().any(|a| matches!(
            a,
            Action::Reply { resp: Response::Err(ElasticError::AdjustmentInFlight), .. }
        )),
        "follow-up adjustment must be accepted: {acts:?}"
    );
}

#[test]
fn switch_scheduled_past_allowance_while_barriers_keep_flowing() {
    let step_ms = 50.0;
    let mut l = scripted(2);
    l.join_worker(1, "m0", false);
    l.join_worker(2, "m0", false);
    l.run_barriers(8, step_ms);

    let (_t, acts) = l.request(Request::ScaleOut { machines: vec!["m1".into()] });
    let joiner = acts
        .iter()
        .find_map(|a| match a {
            Action::Spawn { id, .. } => Some(*id),
            _ => None,
        })
        .unwrap();
    let acts = l.join_worker(joiner, "m1", true);
    let at = scheduled_join_step(&acts).expect("switch scheduled");
    let scheduled_from = l.core.step();

    // k = ceil(T_a / T_b): the joiner gets at least the allowance to
    // prepare, quantised to whole mini-batches
    let lag_ms = (at - scheduled_from) as f64 * step_ms;
    assert!(lag_ms >= 500.0, "lag {lag_ms}ms < allowance");
    assert!(lag_ms <= 500.0 + 2.0 * step_ms, "lag {lag_ms}ms overshoots");

    // stop-free: every barrier between scheduling and commit releases the
    // OLD ring — training never pauses for the joiner
    while l.core.step() < at {
        let step_before = l.core.step();
        let acts = l.run_barrier(step_ms);
        let syncgo = acts
            .iter()
            .filter(|a| matches!(a, Action::Send { msg: CtrlMsg::SyncGo { .. }, .. }))
            .count();
        assert_eq!(syncgo, 2, "barrier at step {step_before} must release both founders");
    }
    assert_eq!(l.core.active_workers().len(), 3, "switch committed at the boundary");
}

#[test]
fn checkpoint_and_restore_flow_through_shell_actions() {
    let mut l = scripted(2);
    l.join_worker(1, "m0", false);
    l.join_worker(2, "m0", false);
    l.run_barriers(5, 40.0);
    let ckpt_step = l.core.step();

    // checkpoint: core asks a worker for params...
    let (ctoken, acts) = l.request(Request::Checkpoint { path: "/virtual/ckpt.bin".into() });
    assert!(acts.iter().any(|a| matches!(a, Action::Send { msg: CtrlMsg::SendParams, .. })));
    // ...and turns the uploaded params into a WriteCheckpoint action
    let acts = l.feed(
        1.0,
        Event::Worker(WorkerEvent::Params {
            id: 1,
            step: ckpt_step,
            params: vec![0.25; 16],
        }),
    );
    let bytes = acts
        .iter()
        .find_map(|a| match a {
            Action::WriteCheckpoint { token, bytes, .. } if *token == ctoken => {
                Some(bytes.clone())
            }
            _ => None,
        })
        .expect("checkpoint bytes emitted for the shell to write");

    l.run_barriers(4, 40.0);
    assert!(l.core.step() > ckpt_step);

    // restore: LoadCheckpoint action out, CheckpointData event back in
    let (rtoken, acts) = l.request(Request::Restore { path: "/virtual/ckpt.bin".into() });
    assert!(acts.iter().any(|a| matches!(a, Action::LoadCheckpoint { .. })));
    let acts = l.feed(0.0, Event::CheckpointData { data: Some(bytes) });
    assert!(
        acts.iter()
            .any(|a| matches!(a, Action::Reply { token, resp: Response::Ok } if *token == rtoken)),
        "restore must ack: {acts:?}"
    );
    let restores = acts
        .iter()
        .filter(|a| matches!(a, Action::Send { msg: CtrlMsg::Restore { .. }, .. }))
        .count();
    assert_eq!(restores, 2, "both workers get the restored model");
    assert_eq!(l.core.step(), ckpt_step, "step rewinds to the checkpoint");

    // a missing checkpoint is a typed error, not a crash
    let (etoken, _a) = l.request(Request::Restore { path: "/virtual/nope.bin".into() });
    let acts = l.feed(0.0, Event::CheckpointData { data: None });
    assert!(acts.iter().any(|a| {
        matches!(a, Action::Reply { token, resp: Response::Err(ElasticError::Io(_)) } if *token == etoken)
    }));
}
