"""AOT export tests: the HLO-text artifacts must exist, be parseable HLO,
and the meta file must agree with the model spec."""

import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.export_config("tiny", str(out), batches=[1, 2])
    return str(out)


EXPECTED = [
    "tiny_init.hlo.txt",
    "tiny_apply.hlo.txt",
    "tiny_grad_b1.hlo.txt",
    "tiny_grad_b2.hlo.txt",
    "tiny_train_b1.hlo.txt",
    "tiny_train_b2.hlo.txt",
    "tiny_loss_b1.hlo.txt",
    "tiny.meta",
]


def test_all_artifacts_written(artifacts):
    for name in EXPECTED:
        path = os.path.join(artifacts, name)
        assert os.path.exists(path), name
        assert os.path.getsize(path) > 0, name


def test_hlo_text_has_entry(artifacts):
    for name in EXPECTED:
        if not name.endswith(".hlo.txt"):
            continue
        text = open(os.path.join(artifacts, name)).read()
        assert "ENTRY" in text, name
        assert "HloModule" in text, name


def test_grad_artifact_shapes(artifacts):
    """The grad entry computation must take (f32[P], s32[B,S]) and return
    a (f32[], f32[P]) tuple — the contract the Rust runtime relies on."""
    cfg = M.CONFIGS["tiny"]
    P, S = M.param_count(cfg), cfg.seq_len
    text = open(os.path.join(artifacts, "tiny_grad_b2.hlo.txt")).read()
    params = [l for l in text.splitlines() if "parameter(" in l]
    assert any(f"f32[{P}]" in l for l in params), "flat param input missing"
    assert any(f"s32[2,{S}]" in l for l in params), "token input missing"
    # the root of the entry computation returns (loss, grads); HLO text may
    # carry layout annotations like f32[P]{0}, so match the prefix
    assert f"(f32[], f32[{P}]" in text


def test_meta_file_contents(artifacts):
    cfg = M.CONFIGS["tiny"]
    meta = {}
    for line in open(os.path.join(artifacts, "tiny.meta")):
        k, v = line.split(None, 1)
        meta[k] = v.strip()
    assert int(meta["param_count"]) == M.param_count(cfg)
    assert int(meta["vocab"]) == cfg.vocab
    assert int(meta["seq_len"]) == cfg.seq_len
    assert meta["batches"] == "1,2"


def test_hlo_text_roundtrips_through_xla_parser(artifacts):
    """Simulate the Rust side: parse the text back into an XlaComputation."""
    from jax._src.lib import xla_client as xc

    backend = jax.devices("cpu")[0].client
    text = open(os.path.join(artifacts, "tiny_loss_b1.hlo.txt")).read()
    # xla_client exposes the same text parser the rust crate binds
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None


def test_exported_loss_matches_eager(artifacts):
    """Execute the lowered loss computation via jax and compare with eager."""
    import numpy as np

    cfg = M.CONFIGS["tiny"]
    params = M.init_params(cfg, 7)
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (1, cfg.seq_len)), jnp.int32
    )
    eager = float(M.fwd_loss(cfg, params, toks))
    lowered = jax.jit(lambda p, t: (M.fwd_loss(cfg, p, t),)).lower(params, toks)
    compiled = lowered.compile()
    (got,) = compiled(params, toks)
    assert abs(float(got) - eager) < 1e-5
