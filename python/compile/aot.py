"""AOT compile path: lower the L2 model (with L1 Pallas kernels inlined)
to HLO *text* artifacts that the Rust runtime loads via PJRT.

HLO text — NOT `.serialize()` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` 0.1.6 crate binds) rejects; the text
parser reassigns ids and round-trips cleanly.

Artifacts written to --out (default ../artifacts):

  <cfg>_init.hlo.txt                    (seed i32[])            -> (params,)
  <cfg>_grad_b<B>.hlo.txt               (params, tokens i32[B,S]) -> (loss, grads)
  <cfg>_apply.hlo.txt                   (params, grads, lr)     -> (params,)
  <cfg>_train_b<B>.hlo.txt              (params, tokens, lr)    -> (loss, params)
  <cfg>_loss_b<B0>.hlo.txt              (params, tokens)        -> (loss,)
  <cfg>.meta                            flat "key value" lines for Rust

Per-worker batch-size variants exist because HLO is fixed-shape: the paper
keeps the *aggregate* batch size constant under scaling (§3.1), so the
per-worker batch changes with parallelism and the Rust leader picks the
matching pre-compiled executable (one compiled executable per variant).

Python runs ONCE here; it is never on the Rust request path.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# Per-worker batch sizes exported per config. Aggregate batch = b * p, so
# these cover parallelism 1..32 at aggregate batch 32 (and more).
BATCH_VARIANTS = {
    "tiny": [1, 2, 4, 8, 16],
    "small": [1, 2, 4, 8, 16, 32],
    "base": [1, 2, 4, 8],
}
DEFAULT_CONFIGS = ["tiny", "small"]


def to_hlo_text(lowered, return_tuple=True) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def _write(out_dir, name, lowered, return_tuple=True):
    text = to_hlo_text(lowered, return_tuple)
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {name} ({len(text)} chars)")


def export_config(cfg_name: str, out_dir: str, batches=None):
    cfg = M.CONFIGS[cfg_name]
    P = M.param_count(cfg)
    S = cfg.seq_len
    batches = batches or BATCH_VARIANTS[cfg_name]
    print(f"config {cfg_name}: P={P} S={S} batches={batches}")

    f32 = jnp.float32
    params_spec = jax.ShapeDtypeStruct((P,), f32)
    lr_spec = jax.ShapeDtypeStruct((), f32)
    seed_spec = jax.ShapeDtypeStruct((), jnp.int32)

    _write(out_dir, f"{cfg_name}_init.hlo.txt",
           jax.jit(lambda s: (M.init_params(cfg, s),)).lower(seed_spec))
    _write(out_dir, f"{cfg_name}_apply.hlo.txt",
           jax.jit(lambda p, g, lr: (M.apply_update(p, g, lr),)).lower(
               params_spec, params_spec, lr_spec))
    # non-tuple variant: its output buffer feeds the next grad_step's
    # params input directly (device-resident params on the Rust hot path)
    _write(out_dir, f"{cfg_name}_applyb.hlo.txt",
           jax.jit(M.apply_update).lower(params_spec, params_spec, lr_spec),
           return_tuple=False)

    for b in batches:
        tok_spec = jax.ShapeDtypeStruct((b, S), jnp.int32)
        _write(out_dir, f"{cfg_name}_grad_b{b}.hlo.txt",
               jax.jit(functools.partial(M.grad_step, cfg)).lower(params_spec, tok_spec))
        _write(out_dir, f"{cfg_name}_train_b{b}.hlo.txt",
               jax.jit(functools.partial(M.train_step, cfg)).lower(
                   params_spec, tok_spec, lr_spec))

    eval_b = batches[0]
    tok_spec = jax.ShapeDtypeStruct((eval_b, S), jnp.int32)
    _write(out_dir, f"{cfg_name}_loss_b{eval_b}.hlo.txt",
           jax.jit(lambda p, t: (M.fwd_loss(cfg, p, t),)).lower(params_spec, tok_spec))

    with open(os.path.join(out_dir, f"{cfg_name}.meta"), "w") as f:
        f.write(f"name {cfg.name}\n")
        f.write(f"param_count {P}\n")
        f.write(f"vocab {cfg.vocab}\n")
        f.write(f"d_model {cfg.d_model}\n")
        f.write(f"n_layers {cfg.n_layers}\n")
        f.write(f"n_heads {cfg.n_heads}\n")
        f.write(f"d_ff {cfg.d_ff}\n")
        f.write(f"seq_len {S}\n")
        f.write(f"eval_batch {eval_b}\n")
        f.write("batches " + ",".join(str(b) for b in batches) + "\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default=",".join(DEFAULT_CONFIGS))
    ap.add_argument("--batches", default="", help="override batch list, csv")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    batches = [int(x) for x in args.batches.split(",") if x] or None
    for name in args.configs.split(","):
        export_config(name, args.out, batches)
    print("aot export complete")


if __name__ == "__main__":
    main()
