//! End-to-end test of the live multi-tenant control plane (PR 4
//! acceptance): a real `edl master` OS process on 2 machines × 2
//! simulated-GPU slots runs THREE concurrent jobs, each with its own
//! leader and `edl worker` OS processes. The ElasticTiresias policy —
//! the same object the simulator runs — must expand a job into idle GPUs
//! (stop-free Grow through Table-1 `scale_out`) and shrink it on
//! contention when later jobs arrive (graceful Shrink through
//! `scale_in`), with NO job ever restarting: every job's step counter,
//! observed through `edl ctl`-style Table-1 status polls resolved by
//! name via the master's coordination KV, must be monotone.

use edl::api::{JobClient, JobControl};
use edl::coordsvc::KvClient;
use edl::harness::testutil::poll_until;
use edl::master::proto::{JobInfo, MasterClient, SubmitSpec};
use std::collections::HashMap;
use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_edl")
}

/// Master process killed on drop so a failing assert can't leak it (its
/// worker children die with their leaders once the process exits).
struct MasterProc(Child);

impl Drop for MasterProc {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn jobs_by_name(mc: &mut MasterClient) -> HashMap<String, JobInfo> {
    mc.jobs().unwrap_or_default().into_iter().map(|j| (j.name.clone(), j)).collect()
}

fn wait_for(
    mc: &mut MasterClient,
    what: &str,
    timeout: Duration,
    mut pred: impl FnMut(&HashMap<String, JobInfo>) -> bool,
) -> HashMap<String, JobInfo> {
    // bounded condition-polling (harness::testutil): re-check real master
    // state on an interval instead of sleeping a tuned amount
    let mut last: HashMap<String, JobInfo> = HashMap::new();
    poll_until(timeout, Duration::from_millis(200), || {
        let jobs = jobs_by_name(mc);
        last = jobs.clone();
        pred(&jobs).then_some(jobs)
    })
    .unwrap_or_else(|| panic!("timed out waiting for {what}; jobs: {last:?}"))
}

/// Spawn an `edl master` daemon with extra flags and parse the control +
/// KV addresses it prints on stdout.
fn spawn_master(extra: &[&str]) -> (MasterProc, String, String) {
    let mut args = vec![
        "master",
        "--machines",
        "2",
        "--gpus",
        "2",
        "--scheduler",
        "elastic-tiresias",
        "--tick-ms",
        "200",
    ];
    args.extend_from_slice(extra);
    let mut child = Command::new(bin())
        .args(&args)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn edl master");
    let stdout = child.stdout.take().expect("master stdout");
    let master = MasterProc(child);

    let mut reader = BufReader::new(stdout);
    let (mut master_addr, mut kv_addr) = (String::new(), String::new());
    let deadline = Instant::now() + Duration::from_secs(60);
    while master_addr.is_empty() || kv_addr.is_empty() {
        assert!(Instant::now() < deadline, "master never printed its addresses");
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("read master stdout");
        assert!(n > 0, "master exited before printing its addresses");
        if let Some(rest) = line.strip_prefix("master-control ") {
            master_addr = rest.trim().to_string();
        } else if let Some(rest) = line.strip_prefix("kv ") {
            kv_addr = rest.trim().to_string();
        }
    }
    (master, master_addr, kv_addr)
}

/// PR 9 headless mode: the master spawns real `edl worker --headless`
/// processes — full control protocol (register, sync barriers, leases,
/// elasticity), but no data plane at all. Jobs must reach running, make
/// monotone step progress, finish, and leave the sharded inventory fully
/// free with the conservation invariant intact.
#[test]
fn headless_workers_run_jobs_without_a_data_plane() {
    let (_master, master_addr, _kv_addr) = spawn_master(&["--headless-workers"]);
    let mut mc = MasterClient::connect(&master_addr).expect("connect master");

    for name in ["hl1", "hl2", "hl3"] {
        mc.submit(&SubmitSpec {
            name: name.into(),
            gpus: 1,
            steps: 120,
            compute_ms: 5,
            ..Default::default()
        })
        .unwrap();
    }

    // every job trains without any gradient traffic
    wait_for(&mut mc, "headless jobs to make step progress", Duration::from_secs(120), |j| {
        ["hl1", "hl2", "hl3"].iter().all(|n| {
            j.get(*n).map(|i| i.step >= 10 || i.phase == "finished").unwrap_or(false)
        })
    });
    let finished =
        wait_for(&mut mc, "headless jobs to finish", Duration::from_secs(240), |j| {
            j.len() == 3 && j.values().all(|i| i.phase == "finished")
        });
    for i in finished.values() {
        assert_eq!(i.parallelism, 0, "finished headless job still holds GPUs: {i:?}");
        assert!(i.step >= 120, "headless job finished early: {i:?}");
    }

    // sharded-inventory invariants, observed over the wire
    let st = mc.stats().expect("master stats");
    assert!(st.conservation_ok, "inventory conservation violated: {st:?}");
    assert!(st.ticks > 0, "master reported no ticks: {st:?}");
    assert!(st.starts >= 3, "master reported fewer starts than jobs: {st:?}");
    let (free, cap) = st
        .shards
        .iter()
        .fold((0u64, 0u64), |(f, c), s| (f + s.free as u64, c + s.capacity as u64));
    assert_eq!(free, cap, "finished fleet must be fully free: {:?}", st.shards);

    mc.shutdown().expect("master shutdown");
}

#[test]
fn master_runs_three_concurrent_jobs_with_live_elasticity() {
    let mut child = Command::new(bin())
        .args([
            "master",
            "--machines",
            "2",
            "--gpus",
            "2",
            "--scheduler",
            "elastic-tiresias",
            "--tick-ms",
            "200",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn edl master");
    let stdout = child.stdout.take().expect("master stdout");
    let _master = MasterProc(child);

    // the daemon prints its control + KV addresses on stdout
    let mut reader = BufReader::new(stdout);
    let (mut master_addr, mut kv_addr) = (String::new(), String::new());
    let deadline = Instant::now() + Duration::from_secs(60);
    while master_addr.is_empty() || kv_addr.is_empty() {
        assert!(Instant::now() < deadline, "master never printed its addresses");
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("read master stdout");
        assert!(n > 0, "master exited before printing its addresses");
        if let Some(rest) = line.strip_prefix("master-control ") {
            master_addr = rest.trim().to_string();
        } else if let Some(rest) = line.strip_prefix("kv ") {
            kv_addr = rest.trim().to_string();
        }
    }

    let mut mc = MasterClient::connect(&master_addr).expect("connect master");

    // ---- job A alone: must be expanded into the idle GPUs (R2) ----------
    mc.submit(&SubmitSpec {
        name: "jobA".into(),
        gpus: 1,
        steps: 1_500,
        compute_ms: 10,
        ..Default::default()
    })
    .unwrap();
    let jobs = wait_for(&mut mc, "jobA to grow past its request", Duration::from_secs(90), |j| {
        j.get("jobA").map(|a| a.parallelism > 1).unwrap_or(false)
    });
    assert!(jobs["jobA"].peak_p > 1, "R2 never expanded jobA: {:?}", jobs["jobA"]);

    // ---- step monitor: Table-1 status by NAME through the KV ------------
    // (the §3.1 stop-free guarantee: steps never go backwards — a restart
    // would reset the counter)
    let stop = Arc::new(AtomicBool::new(false));
    let monitor = {
        let stop = stop.clone();
        let kv_addr = kv_addr.clone();
        std::thread::spawn(move || {
            let mut seen: HashMap<String, Vec<u64>> = HashMap::new();
            let mut conns: HashMap<String, JobClient> = HashMap::new();
            while !stop.load(Ordering::Relaxed) {
                for name in ["jobA", "jobB", "jobC"] {
                    if !conns.contains_key(name) {
                        // resolve the job's ctl address by name via the KV
                        let Ok(mut kv) = KvClient::connect(&kv_addr) else { continue };
                        let Ok(Some((raw, _))) = kv.get(&format!("edl/jobs/{name}/ctl")) else {
                            continue;
                        };
                        let addr = String::from_utf8_lossy(&raw).to_string();
                        if let Ok(c) = JobClient::connect(&addr) {
                            conns.insert(name.to_string(), c);
                        }
                    }
                    if let Some(c) = conns.get_mut(name) {
                        match c.status() {
                            Ok(st) => seen.entry(name.to_string()).or_default().push(st.step),
                            // job finished / leader gone: stop polling it
                            Err(_) => {
                                conns.remove(name);
                            }
                        }
                    }
                }
                std::thread::sleep(Duration::from_millis(150));
            }
            seen
        })
    };

    // ---- contention: jobs B and C force a graceful shrink of A (R0) -----
    for name in ["jobB", "jobC"] {
        mc.submit(&SubmitSpec {
            name: name.into(),
            gpus: 1,
            steps: 150,
            compute_ms: 10,
            ..Default::default()
        })
        .unwrap();
    }
    wait_for(
        &mut mc,
        "jobB and jobC to run concurrently with jobA",
        Duration::from_secs(120),
        |j| {
            ["jobB", "jobC"].iter().all(|n| {
                j.get(*n)
                    .map(|i| i.parallelism >= 1 || i.phase == "finished")
                    .unwrap_or(false)
            })
        },
    );

    // ---- everything completes; A grew AND shrank live -------------------
    let finished =
        wait_for(&mut mc, "all three jobs to finish", Duration::from_secs(240), |j| {
            j.len() == 3 && j.values().all(|i| i.phase == "finished")
        });
    let a = &finished["jobA"];
    assert!(a.peak_p > a.requested_p, "jobA never expanded into idle GPUs: {a:?}");
    assert!(a.grow_ops >= 1, "no live stop-free grow committed: {a:?}");
    assert!(a.shrink_ops >= 1, "no live graceful shrink on contention: {a:?}");
    for i in finished.values() {
        assert_eq!(i.parallelism, 0, "finished job still holds GPUs: {i:?}");
        assert!(i.step >= 150, "job finished before its step target: {i:?}");
    }

    // ---- step monotonicity: no job ever restarted -----------------------
    stop.store(true, Ordering::Relaxed);
    let seen = monitor.join().expect("monitor thread");
    assert!(
        seen.contains_key("jobA"),
        "monitor never resolved jobA through the KV: {seen:?}"
    );
    for (name, steps) in &seen {
        assert!(
            steps.windows(2).all(|w| w[0] <= w[1]),
            "{name} steps went backwards (a restart?): {steps:?}"
        );
    }

    // the monitor observed jobA across the shrink — its step trace spans
    // the contention window and still never decreased
    let a_steps = &seen["jobA"];
    assert!(a_steps.len() >= 3, "too few jobA status samples: {a_steps:?}");

    mc.shutdown().expect("master shutdown");
}
