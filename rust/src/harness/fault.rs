//! The fault model of the deterministic chaos harness: one [`FaultPlan`]
//! describes everything the harness may do to a frame in flight —
//! **drop**, **delay**, **duplicate**, **partition** (and **heal**) —
//! keyed by `(from, to, tag family)` and a fault-clock time window.
//!
//! The same plan drives two worlds:
//!
//!  * **live transports** — `FaultPlan` implements
//!    [`transport::FaultHook`], so it can be armed on an `InProcHub`, a
//!    `TcpNode`, a `deploy::LeaderEndpoint` control plane or a
//!    `coordsvc::KvServer` (all behind the zero-cost-when-off
//!    `FaultCell`); the clock is a shared atomic the test advances;
//!  * **the virtual cluster** (`harness::chaos`) — the executor calls
//!    [`FaultPlan::fate_at`] with its own virtual clock, so schedules are
//!    bit-reproducible.
//!
//! Probabilistic rules are decided by a pure hash of
//! `(seed, from, to, family, time-bucket)` — NOT by stateful RNG draws —
//! so the verdict for a given frame is independent of thread interleaving
//! and call order. Same seed ⇒ same fate, always.

use crate::transport::{tag, FaultHook, FrameFate, NodeId};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Coarse traffic classes a fault rule can target. Raw transport tags are
/// mapped down: everything that is not control traffic is `Data` (the
/// allreduce/broadcast tag space).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// allreduce / model-broadcast frames
    Data,
    /// worker ⇄ leader control frames (`rpc::ToLeader`/`FromLeader`)
    Rpc,
    /// coordination-KV requests (leases, election)
    Kv,
}

impl Family {
    /// Family of a raw transport tag.
    pub fn of_tag(t: u32) -> Family {
        match t {
            tag::RPC => Family::Rpc,
            tag::KV => Family::Kv,
            _ => Family::Data,
        }
    }
}

/// What a matching rule does to the frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    Drop,
    Duplicate,
    /// delay by this many fault-clock milliseconds
    Delay(u64),
}

/// One injectable fault: `kind` applied to frames matching the key within
/// `[from_ms, until_ms)` on the fault clock, with probability
/// `per_mille`/1000 (decided deterministically per frame).
#[derive(Debug, Clone)]
pub struct FaultRule {
    /// sending node (None = any)
    pub from: Option<NodeId>,
    /// receiving node (None = any)
    pub to: Option<NodeId>,
    /// traffic family (None = any)
    pub family: Option<Family>,
    /// active window on the fault clock, milliseconds
    pub from_ms: u64,
    pub until_ms: u64,
    /// probability in 1/1000 that a matching frame is affected
    pub per_mille: u32,
    pub kind: FaultKind,
}

impl FaultRule {
    /// An always-firing rule for the whole of time; builder-style setters
    /// narrow it.
    pub fn always(kind: FaultKind) -> FaultRule {
        FaultRule {
            from: None,
            to: None,
            family: None,
            from_ms: 0,
            until_ms: u64::MAX,
            per_mille: 1000,
            kind,
        }
    }

    pub fn from_node(mut self, n: NodeId) -> FaultRule {
        self.from = Some(n);
        self
    }
    pub fn to_node(mut self, n: NodeId) -> FaultRule {
        self.to = Some(n);
        self
    }
    pub fn family(mut self, f: Family) -> FaultRule {
        self.family = Some(f);
        self
    }
    pub fn window(mut self, from_ms: u64, until_ms: u64) -> FaultRule {
        self.from_ms = from_ms;
        self.until_ms = until_ms;
        self
    }
    pub fn per_mille(mut self, p: u32) -> FaultRule {
        self.per_mille = p.min(1000);
        self
    }

    fn matches(&self, from: NodeId, to: NodeId, family: Family, now_ms: u64) -> bool {
        now_ms >= self.from_ms
            && now_ms < self.until_ms
            && self.from.map(|f| f == from).unwrap_or(true)
            && self.to.map(|t| t == to).unwrap_or(true)
            && self.family.map(|f| f == family).unwrap_or(true)
    }
}

/// A symmetric partition: frames between the two node sets are dropped in
/// both directions within the window (healing = window end, or
/// [`FaultPlan::heal`]).
#[derive(Debug, Clone)]
struct Partition {
    a: Vec<NodeId>,
    b: Vec<NodeId>,
    from_ms: u64,
    until_ms: u64,
}

/// The shared fault clock: milliseconds on whatever timeline the owner
/// advances (virtual time in the chaos executor, test-driven wall offsets
/// in live tests). Cloning shares the underlying counter.
#[derive(Clone, Default)]
pub struct FaultClock(Arc<AtomicU64>);

impl FaultClock {
    pub fn new() -> FaultClock {
        FaultClock::default()
    }
    pub fn set_ms(&self, ms: u64) {
        self.0.store(ms, Ordering::Release);
    }
    pub fn advance_ms(&self, ms: u64) {
        self.0.fetch_add(ms, Ordering::AcqRel);
    }
    pub fn now_ms(&self) -> u64 {
        self.0.load(Ordering::Acquire)
    }
}

/// The full injectable-fault schedule. Construct once per test/run, add
/// rules and partitions, arm it on live layers (it is a
/// [`transport::FaultHook`]) or query [`FaultPlan::fate_at`] from the
/// virtual executor.
pub struct FaultPlan {
    seed: u64,
    clock: FaultClock,
    rules: Mutex<Vec<FaultRule>>,
    partitions: Mutex<Vec<Partition>>,
    /// frames affected so far (observability: tests assert faults actually
    /// fired instead of silently passing on a miswired hook)
    hits: AtomicU64,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Arc<FaultPlan> {
        Arc::new(FaultPlan {
            seed,
            clock: FaultClock::new(),
            rules: Mutex::new(Vec::new()),
            partitions: Mutex::new(Vec::new()),
            hits: AtomicU64::new(0),
        })
    }

    /// The clock live layers share; the owner advances it.
    pub fn clock(&self) -> FaultClock {
        self.clock.clone()
    }

    pub fn add(&self, rule: FaultRule) {
        self.rules.lock().unwrap().push(rule);
    }

    /// Partition node sets `a` and `b` (both directions) for the window.
    pub fn partition(&self, a: &[NodeId], b: &[NodeId], from_ms: u64, until_ms: u64) {
        self.partitions.lock().unwrap().push(Partition {
            a: a.to_vec(),
            b: b.to_vec(),
            from_ms,
            until_ms,
        });
    }

    /// Remove every rule and partition: the network is whole again.
    pub fn heal(&self) {
        self.rules.lock().unwrap().clear();
        self.partitions.lock().unwrap().clear();
    }

    /// How many frames any rule/partition has affected.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Deterministic per-frame coin: FNV-1a over the full frame key. Same
    /// inputs ⇒ same verdict regardless of thread timing.
    fn coin(&self, from: NodeId, to: NodeId, family: Family, now_ms: u64) -> u32 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325 ^ self.seed;
        let fam = match family {
            Family::Data => 0u64,
            Family::Rpc => 1,
            Family::Kv => 2,
        };
        for word in [from as u64, to as u64, fam, now_ms] {
            for b in word.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
        }
        (h % 1000) as u32
    }

    /// Fate of a frame at an explicit fault-clock time (the virtual
    /// executor's entry point). First matching partition, then the first
    /// matching rule whose coin lands, wins.
    pub fn fate_at(&self, from: NodeId, to: NodeId, family: Family, now_ms: u64) -> FrameFate {
        {
            let parts = self.partitions.lock().unwrap();
            for p in parts.iter() {
                if now_ms >= p.from_ms
                    && now_ms < p.until_ms
                    && ((p.a.contains(&from) && p.b.contains(&to))
                        || (p.b.contains(&from) && p.a.contains(&to)))
                {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return FrameFate::Drop;
                }
            }
        }
        let rules = self.rules.lock().unwrap();
        for r in rules.iter() {
            if r.matches(from, to, family, now_ms) && self.coin(from, to, family, now_ms) < r.per_mille
            {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return match r.kind {
                    FaultKind::Drop => FrameFate::Drop,
                    FaultKind::Duplicate => FrameFate::Duplicate,
                    FaultKind::Delay(ms) => FrameFate::Delay(Duration::from_millis(ms)),
                };
            }
        }
        FrameFate::Deliver
    }
}

impl FaultHook for FaultPlan {
    fn fate(&self, from: NodeId, to: NodeId, tag: u32) -> FrameFate {
        self.fate_at(from, to, Family::of_tag(tag), self.clock.now_ms())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rules_respect_key_and_window() {
        let plan = FaultPlan::new(1);
        plan.add(FaultRule::always(FaultKind::Drop).from_node(1).to_node(2).family(Family::Rpc).window(100, 200));
        assert_eq!(plan.fate_at(1, 2, Family::Rpc, 150), FrameFate::Drop);
        assert_eq!(plan.fate_at(1, 2, Family::Rpc, 99), FrameFate::Deliver);
        assert_eq!(plan.fate_at(1, 2, Family::Rpc, 200), FrameFate::Deliver);
        assert_eq!(plan.fate_at(1, 2, Family::Data, 150), FrameFate::Deliver);
        assert_eq!(plan.fate_at(2, 1, Family::Rpc, 150), FrameFate::Deliver);
        assert_eq!(plan.hits(), 1);
    }

    #[test]
    fn partition_is_symmetric_and_heals() {
        let plan = FaultPlan::new(2);
        plan.partition(&[1, 2], &[3], 0, 500);
        assert_eq!(plan.fate_at(1, 3, Family::Data, 10), FrameFate::Drop);
        assert_eq!(plan.fate_at(3, 2, Family::Rpc, 10), FrameFate::Drop);
        assert_eq!(plan.fate_at(1, 2, Family::Data, 10), FrameFate::Deliver);
        // heal by window end
        assert_eq!(plan.fate_at(1, 3, Family::Data, 500), FrameFate::Deliver);
        // explicit heal
        plan.partition(&[1], &[3], 0, u64::MAX);
        plan.heal();
        assert_eq!(plan.fate_at(1, 3, Family::Data, 10), FrameFate::Deliver);
    }

    #[test]
    fn probabilistic_rules_are_deterministic_and_calibrated() {
        let plan_a = FaultPlan::new(7);
        let plan_b = FaultPlan::new(7);
        for p in [&plan_a, &plan_b] {
            p.add(FaultRule::always(FaultKind::Drop).per_mille(300));
        }
        let mut dropped = 0;
        for t in 0..10_000u64 {
            let fa = plan_a.fate_at(1, 2, Family::Data, t);
            let fb = plan_b.fate_at(1, 2, Family::Data, t);
            assert_eq!(fa, fb, "same seed must give same fate at t={t}");
            if fa == FrameFate::Drop {
                dropped += 1;
            }
        }
        // ~30% with slack; a different seed decides differently
        assert!((2000..4000).contains(&dropped), "dropped={dropped}");
        let other = FaultPlan::new(8);
        other.add(FaultRule::always(FaultKind::Drop).per_mille(300));
        let diff = (0..10_000u64)
            .filter(|&t| other.fate_at(1, 2, Family::Data, t) != plan_a.fate_at(1, 2, Family::Data, t))
            .count();
        assert!(diff > 1000, "seeds should decide differently: {diff}");
    }

    #[test]
    fn hook_uses_shared_clock() {
        let plan = FaultPlan::new(3);
        plan.add(FaultRule::always(FaultKind::Duplicate).window(1000, 2000));
        let clock = plan.clock();
        assert_eq!(FaultHook::fate(&*plan, 1, 2, 0x4000_0000), FrameFate::Deliver);
        clock.set_ms(1500);
        assert_eq!(FaultHook::fate(&*plan, 1, 2, 0x4000_0000), FrameFate::Duplicate);
        clock.advance_ms(600);
        assert_eq!(FaultHook::fate(&*plan, 1, 2, 0x4000_0000), FrameFate::Deliver);
    }
}
