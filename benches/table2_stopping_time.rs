//! Table 2 — stopping time of scaling out (4 → 5 GPUs): how long EXISTING
//! workers stop training, stop-resume vs EDL, for five DNNs.
//!
//! Two layers:
//!  1. calibrated values from the device model (the paper's own numbers
//!     are the calibration target — asserted to preserve the >10× gap);
//!  2. a protocol-level measurement: the in-process engine runs a 4-worker
//!     job with device-model-scaled context-prep/compute delays and we
//!     measure the realized barrier stall around the switch — verifying
//!     the PROTOCOL (not the constants) produces a stop ≈ broadcast time,
//!     independent of the (hidden) context preparation.

use edl::allreduce::{broadcast_recv, broadcast_send};
use edl::coordinator::{ElasticTrainer, TrainerConfig};
use edl::data::corpus::Corpus;
use edl::gpu_sim::{edl_stop_time, stop_resume_overhead, Dnn};
use edl::transport::InProcHub;
use edl::util::json::{write_results, Json};
use edl::util::stats;
use edl::worker::SimBackend;
use std::sync::Arc;
use std::time::Duration;

const MODELS: [Dnn; 5] = [Dnn::AlexNet, Dnn::ResNet152, Dnn::ResNet50, Dnn::VGG19, Dnn::VGG16];

/// Wall time (s) to broadcast a `elems`-element model to `k` joiners over
/// the binomial relay tree (min of `tries` runs: the stopping-time cost of
/// the model-preparation step, which must scale O(log K), not O(K)).
fn broadcast_time(k: usize, elems: usize, tries: usize) -> f64 {
    let model = vec![1.25f32; elems];
    let mut best = f64::INFINITY;
    for _ in 0..tries {
        let hub = InProcHub::new();
        let dests: Vec<u32> = (1..=k as u32).collect();
        let mut src = hub.join(0);
        let joiners: Vec<_> = dests.iter().map(|&d| hub.join(d)).collect();
        let t = std::thread::scope(|s| {
            let handles: Vec<_> = joiners
                .into_iter()
                .map(|mut ep| {
                    let dests = dests.clone();
                    s.spawn(move || {
                        broadcast_recv(&mut ep, 0, &dests, 1, Duration::from_secs(30)).unwrap()
                    })
                })
                .collect();
            let t0 = std::time::Instant::now();
            broadcast_send(&mut src, &dests, 1, &model).unwrap();
            for h in handles {
                let got = h.join().unwrap();
                assert_eq!(got.len(), elems);
            }
            t0.elapsed().as_secs_f64()
        });
        best = best.min(t);
    }
    best
}

fn main() {
    println!("== Table 2: stopping time (s) of scaling out 4->5 ==");
    println!("{:<12} {:>12} {:>8} {:>8}", "model", "stop-resume", "EDL", "ratio");
    let mut out = Json::obj();
    for d in MODELS {
        let sr = stop_resume_overhead(d, 5);
        let edl = edl_stop_time(d);
        println!("{:<12} {:>11.1}s {:>7.2}s {:>7.0}x", d.spec().name, sr, edl, sr / edl);
        assert!(sr / edl > 10.0, "EDL must be an order of magnitude better");
        let mut r = Json::obj();
        r.set("stop_resume_s", sr).set("edl_s", edl).set("ratio", sr / edl);
        out.set(d.spec().name, r);
    }

    // broadcast scaling: the model-preparation transfer for K joiners
    // must cost O(log K) serial hops of pipelined refcounted segments —
    // acceptance: K=8 completes within 3x the single-joiner time
    println!("\n== model broadcast to K joiners (4.25M-element model) ==");
    let elems = 4_250_000;
    let t1 = broadcast_time(1, elems, 3);
    let t4 = broadcast_time(4, elems, 3);
    let t8 = broadcast_time(8, elems, 3);
    println!(
        "K=1 {:.1}ms   K=4 {:.1}ms ({:.2}x)   K=8 {:.1}ms ({:.2}x)",
        t1 * 1e3,
        t4 * 1e3,
        t4 / t1,
        t8 * 1e3,
        t8 / t1
    );
    assert!(
        t8 <= 3.0 * t1.max(1e-3),
        "tree broadcast must scale sub-linearly: K=8 {:.1}ms vs K=1 {:.1}ms",
        t8 * 1e3,
        t1 * 1e3
    );
    let mut b = Json::obj();
    b.set("elems", elems)
        .set("k1_s", t1)
        .set("k4_s", t4)
        .set("k8_s", t8)
        .set("k8_over_k1", t8 / t1.max(1e-9));
    out.set("broadcast_scaling", b);

    // protocol-level measurement: 4 workers, 50 ms/step, joiner ctx-prep
    // 3 s. The stall existing workers see must track the broadcast (ms),
    // NOT the 3 s context preparation.
    println!("\n== measured protocol stall around stop-free scale-out ==");
    let backend = SimBackend { compute_ms: 50, ctx_prep_ms: 3_000, ..SimBackend::fast(1 << 20) };
    let corpus = Arc::new(Corpus::markov(256, 16, 1 << 20, 9));
    let cfg = TrainerConfig { agg_batch: 32, n_partitions: 4096, ..Default::default() };
    let t = ElasticTrainer::start(cfg, Arc::new(backend), corpus, 4);
    assert!(t.wait_step(10, Duration::from_secs(120)));

    let t0 = std::time::Instant::now();
    let r = t.scale_out(vec!["m1".into()]);
    let e2e = t0.elapsed().as_secs_f64();
    assert!(r.is_ok(), "{r:?}");
    assert!(t.wait_step(t.status().step + 20, Duration::from_secs(60)));
    let report = t.stop();

    // realized stall = gap between consecutive barrier completions around
    // the switch, minus the normal step time
    let steps: Vec<f64> = report
        .loss_history
        .windows(2)
        .map(|w| w[1].wall_ms - w[0].wall_ms)
        .collect();
    let normal = stats::median(&steps);
    let worst = stats::max(&steps);
    let stall = (worst - normal) / 1e3;
    println!("normal step {:.0}ms; worst step {:.0}ms; implied stall {:.2}s; e2e {:.2}s", normal, worst, stall, e2e);
    assert!(
        stall < 1.5,
        "existing workers must not stop for the 3s context prep (stall={stall:.2}s)"
    );
    assert!(e2e > 2.5, "e2e must include the joiner's context preparation ({e2e:.2}s)");
    let mut m = Json::obj();
    m.set("normal_step_ms", normal).set("worst_step_ms", worst).set("stall_s", stall).set("e2e_s", e2e);
    out.set("measured_protocol", m);

    let path = write_results("table2_stopping_time", &out).unwrap();
    println!("\nshape checks OK; results -> {}", path.display());
}
