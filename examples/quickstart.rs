//! Quickstart: load the AOT artifacts and train the JAX transformer from
//! Rust on a single worker — no Python anywhere on this path.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Flags: --config tiny|small  --steps N  --batch B  --lr F

use edl::data::corpus::Corpus;
use edl::runtime::{artifacts_dir, Runtime};
use edl::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let config = args.str("config", "tiny");
    let steps = args.u64("steps", 30);
    let lr = args.f64("lr", 0.2) as f32;

    // 1. open the artifact family and compile the executables we need
    let rt = Runtime::open(artifacts_dir(), &config)?;
    let b = args.usize("batch", 4) as u32;
    anyhow::ensure!(rt.meta.batches.contains(&b), "batch {b} not exported; have {:?}", rt.meta.batches);
    println!(
        "model={} params={} vocab={} seq={}",
        rt.meta.name, rt.meta.param_count, rt.meta.vocab, rt.meta.seq_len
    );

    // 2. synthetic Markov corpus (structured => loss can fall well below
    //    the uniform baseline ln(vocab))
    let corpus = Corpus::markov(rt.meta.vocab, rt.meta.seq_len, 1024, 42);

    // 3. init params IN the artifact (same HLO the cluster runs)
    let mut params = rt.init_params(0)?;
    println!("uniform-baseline loss = ln({}) = {:.4}", rt.meta.vocab, (rt.meta.vocab as f32).ln());

    // 4. train: fused (grad+sgd) train_step artifact per mini-batch
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let tokens = corpus.batch(step * b as u64, b as u64);
        let (loss, new_params) = rt.train_step(&params, &tokens, b, lr)?;
        params = new_params;
        if step % 5 == 0 || step + 1 == steps {
            println!("step {step:>4}  loss {loss:.4}");
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "done: {steps} steps in {dt:.2}s ({:.1} samples/s)",
        steps as f64 * b as f64 / dt
    );
    Ok(())
}
