//! Trace-driven cluster-scheduling comparison (§6.3): Tiresias vs
//! Elastic-Tiresias on a synthetic Philly-like trace, printing Table-4
//! style JCT statistics and Fig-12 style utilization / efficiency means.
//!
//!     cargo run --release --example cluster_scheduling -- \
//!         --jobs 2000 --machines 36 --span-days 7

use edl::cluster::{ClusterSim, ScaleMode, Scheduler};
use edl::metrics::JctStats;
use edl::schedulers::{ElasticTiresias, Tiresias};
use edl::trace::{self, TraceConfig};
use edl::util::args::Args;

fn run(name: &str, sched: &mut dyn Scheduler, trace: &[trace::TraceJob], machines: usize) -> (JctStats, f64, f64) {
    let mut sim = ClusterSim::new(machines, 8, trace, ScaleMode::Edl);
    sim.run(sched, 1e9);
    let stats = JctStats::from(&sim.jcts());
    let util = sim.util_ts.time_weighted_mean();
    let eff = sim.cluster_eff_ts.time_weighted_mean();
    println!(
        "{name:<18} mean={:>9.0}s median={:>7.0}s p95={:>9.0}s  util={util:.3} cluster-eff={eff:.3}",
        stats.mean, stats.median, stats.p95
    );
    (stats, util, eff)
}

fn main() {
    let args = Args::from_env();
    let n_jobs = args.usize("jobs", 2_000);
    let machines = args.usize("machines", 36);
    let span_days = args.f64("span-days", 7.0);

    let trace = trace::generate(&TraceConfig {
        n_jobs,
        span_s: span_days * 86_400.0,
        ..Default::default()
    });
    println!(
        "== {} jobs over {:.0} days on {}x8 GPUs (Table 4 / Fig 12 setup) ==\n",
        n_jobs, span_days, machines
    );

    let (base, _, _) = run("Tiresias", &mut Tiresias::new(vec![500.0, 10_000.0]), &trace, machines);
    let (elastic, _, _) = run(
        "Elastic-Tiresias",
        &mut ElasticTiresias::new(vec![500.0, 10_000.0], 10, 0.5),
        &trace,
        machines,
    );

    println!("\nJCT reduction (mean):   {:.1}%  (paper Table 4: 89.5%)", elastic.reduction_vs(&base));
    let med = (1.0 - elastic.median / base.median) * 100.0;
    println!("JCT reduction (median): {med:.1}%  (paper Table 4: 48.1%)");
    let p95 = (1.0 - elastic.p95 / base.p95) * 100.0;
    println!("JCT reduction (p95):    {p95:.1}%  (paper Table 4 reports p95: 95.4%)");
}
