//! Table 3 — end-to-end time of EDL scale-in (5→4) and scale-out (4→5)
//! per DNN. The e2e scale-out time is dominated by the joiner's context
//! preparation (hidden from existing workers); scale-in completes within
//! a few seconds (graceful exit at the next switch boundary).
//!
//! Calibrated values from the device model + a protocol measurement with
//! the in-process engine verifying the RELATIONSHIPS: e2e-out ≈ ctx-prep,
//! e2e-in ≈ a couple of mini-batches, and neither stops existing workers.

use edl::coordinator::{ElasticTrainer, TrainerConfig};
use edl::data::corpus::Corpus;
use edl::gpu_sim::{edl_scale_in_e2e, edl_scale_out_e2e, Dnn};
use edl::util::json::{write_results, Json};
use edl::worker::SimBackend;
use std::sync::Arc;
use std::time::Duration;

const MODELS: [Dnn; 5] = [Dnn::AlexNet, Dnn::ResNet152, Dnn::ResNet50, Dnn::VGG19, Dnn::VGG16];

fn main() {
    println!("== Table 3: end-to-end scaling time (s) in EDL ==");
    println!("{:<12} {:>11} {:>11}", "model", "scale-in", "scale-out");
    let mut out = Json::obj();
    for d in MODELS {
        let si = edl_scale_in_e2e(d);
        let so = edl_scale_out_e2e(d);
        println!("{:<12} {:>10.1}s {:>10.1}s", d.spec().name, si, so);
        assert!(so > si, "scale-out (ctx prep) must dominate scale-in");
        let mut r = Json::obj();
        r.set("scale_in_s", si).set("scale_out_s", so);
        out.set(d.spec().name, r);
    }

    // protocol measurement: ctx-prep 2s, 40ms steps
    println!("\n== measured e2e on the live protocol (ctx-prep=2s, 40ms steps) ==");
    let backend = SimBackend { compute_ms: 40, ctx_prep_ms: 2_000, ..SimBackend::fast(1 << 18) };
    let corpus = Arc::new(Corpus::markov(256, 16, 1 << 20, 4));
    let cfg = TrainerConfig { agg_batch: 32, n_partitions: 4096, ..Default::default() };
    let t = ElasticTrainer::start(cfg, Arc::new(backend), corpus, 4);
    assert!(t.wait_step(10, Duration::from_secs(120)));

    let t0 = std::time::Instant::now();
    assert!(t.scale_out(vec!["m1".into()]).is_ok());
    let e2e_out = t0.elapsed().as_secs_f64();

    assert!(t.wait_step(t.status().step + 5, Duration::from_secs(60)));
    let victim = *t.status().workers.last().unwrap();
    let t0 = std::time::Instant::now();
    assert!(t.scale_in(vec![victim]).is_ok());
    let e2e_in = t0.elapsed().as_secs_f64();
    t.stop();

    println!("scale-out e2e {e2e_out:.2}s (>= ctx prep 2s);  scale-in e2e {e2e_in:.2}s");
    assert!(e2e_out >= 1.8, "scale-out e2e must include context prep");
    assert!(e2e_in < e2e_out, "scale-in must be much cheaper than scale-out");
    let mut m = Json::obj();
    m.set("e2e_out_s", e2e_out).set("e2e_in_s", e2e_in);
    out.set("measured_protocol", m);

    let path = write_results("table3_e2e_scaling", &out).unwrap();
    println!("\nshape checks OK; results -> {}", path.display());
}
