//! Elastic ring allreduce — the NCCL substitute (DESIGN.md §1).
//!
//! Implements the bandwidth-optimal ring algorithm the paper builds on
//! (§2.1): with N workers the tensor is split into N chunks; N−1
//! reduce-scatter steps leave each worker holding the full sum of one
//! chunk, then N−1 allgather steps circulate the reduced chunks. Total
//! traffic per worker: 2(N−1)/N × tensor bytes.
//!
//! Elasticity hooks:
//!  * the ring order is an explicit argument — the leader rebuilds it on
//!    every topology switch and workers swap it at the agreed mini-batch
//!    timestamp (§4.2);
//!  * `broadcast` implements single-source model transfer to joiners
//!    (stop-free scaling's model-preparation step);
//!  * weighted reduction supports the constant-aggregate-batch semantics
//!    (§3.1): each worker pre-scales its gradient by `weight` and the ring
//!    computes the plain sum, so unequal local batches still yield the
//!    exact full-batch mean gradient.

use crate::transport::{tag, NetError, PointToPoint};
use crate::wire::{Dec, Enc};
use std::time::Duration;

#[derive(Debug)]
pub enum ArError {
    NotInRing,
    RingTooSmall(usize),
    Net(NetError),
    Wire(crate::wire::WireError),
}

impl std::fmt::Display for ArError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArError::NotInRing => write!(f, "ring must contain this node"),
            ArError::RingTooSmall(n) => write!(f, "ring too small: {n}"),
            ArError::Net(e) => write!(f, "net: {e}"),
            ArError::Wire(e) => write!(f, "wire: {e}"),
        }
    }
}

impl std::error::Error for ArError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ArError::Net(e) => Some(e),
            ArError::Wire(e) => Some(e),
            _ => None,
        }
    }
}

impl From<NetError> for ArError {
    fn from(e: NetError) -> ArError {
        ArError::Net(e)
    }
}

impl From<crate::wire::WireError> for ArError {
    fn from(e: crate::wire::WireError) -> ArError {
        ArError::Wire(e)
    }
}

pub type Result<T> = std::result::Result<T, ArError>;

/// §Perf: decode an f32s payload (length-prefixed LE floats) by ADDING it
/// into `dst` in place — avoids the intermediate Vec allocation + copy of
/// `Dec::f32s` on the reduce-scatter hot path.
fn add_assign_from_payload(dst: &mut [f32], payload: &[u8]) -> Result<()> {
    let mut d = Dec::new(payload);
    let n = d.u32()? as usize;
    if n != dst.len() || payload.len() < 4 + n * 4 {
        return Err(ArError::Wire(crate::wire::WireError::Truncated {
            wanted: n * 4,
            have: payload.len().saturating_sub(4),
        }));
    }
    let raw = &payload[4..4 + n * 4];
    for (x, b) in dst.iter_mut().zip(raw.chunks_exact(4)) {
        *x += f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    }
    Ok(())
}

/// §Perf: decode an f32s payload by COPYING into `dst` in place
/// (allgather hot path).
fn copy_from_payload(dst: &mut [f32], payload: &[u8]) -> Result<()> {
    let mut d = Dec::new(payload);
    let n = d.u32()? as usize;
    if n != dst.len() || payload.len() < 4 + n * 4 {
        return Err(ArError::Wire(crate::wire::WireError::Truncated {
            wanted: n * 4,
            have: payload.len().saturating_sub(4),
        }));
    }
    let raw = &payload[4..4 + n * 4];
    unsafe {
        std::ptr::copy_nonoverlapping(raw.as_ptr(), dst.as_mut_ptr() as *mut u8, n * 4);
    }
    Ok(())
}

/// Chunk boundaries: split `len` into `n` nearly equal ranges.
pub fn chunks(len: usize, n: usize) -> Vec<(usize, usize)> {
    let base = len / n;
    let rem = len % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let sz = base + usize::from(i < rem);
        out.push((start, start + sz));
        start += sz;
    }
    out
}

/// In-place weighted-sum ring allreduce of `buf` across `ring`.
///
/// Every participant must call this with the same `ring` (order matters)
/// and the same `step` (used to namespace message tags so consecutive
/// allreduces never cross-talk). `weight` scales the local contribution
/// before summation.
pub fn ring_allreduce<N: PointToPoint>(
    net: &mut N,
    ring: &[u32],
    step: u64,
    buf: &mut [f32],
    weight: f32,
    timeout: Duration,
) -> Result<()> {
    let n = ring.len();
    if n == 0 {
        return Err(ArError::RingTooSmall(0));
    }
    let me = ring.iter().position(|&id| id == net.id()).ok_or(ArError::NotInRing)?;
    if weight != 1.0 {
        for x in buf.iter_mut() {
            *x *= weight;
        }
    }
    if n == 1 {
        return Ok(());
    }
    let right = ring[(me + 1) % n];
    let left = ring[(me + n - 1) % n];
    let bounds = chunks(buf.len(), n);
    let step_tag = tag::RING ^ ((step as u32) & 0xFFF) << 4;

    // --- reduce-scatter: after N-1 steps, chunk (me+1)%n holds the sum ---
    for s in 0..n - 1 {
        let send_chunk = (me + n - s) % n;
        let recv_chunk = (me + n - s - 1) % n;
        let (a, b) = bounds[send_chunk];
        let mut e = Enc::with_capacity(8 + (b - a) * 4);
        e.f32s(&buf[a..b]);
        net.send(right, step_tag + s as u32, e.into_bytes())?;
        let payload = net.recv_from(left, step_tag + s as u32, timeout)?;
        let (ra, rb) = bounds[recv_chunk];
        add_assign_from_payload(&mut buf[ra..rb], &payload)?;
    }

    // --- allgather: circulate the reduced chunks ---
    for s in 0..n - 1 {
        let send_chunk = (me + 1 + n - s) % n;
        let recv_chunk = (me + n - s) % n;
        let (a, b) = bounds[send_chunk];
        let mut e = Enc::with_capacity(8 + (b - a) * 4);
        e.f32s(&buf[a..b]);
        net.send(right, step_tag + 0x100 + s as u32, e.into_bytes())?;
        let payload = net.recv_from(left, step_tag + 0x100 + s as u32, timeout)?;
        let (ra, rb) = bounds[recv_chunk];
        copy_from_payload(&mut buf[ra..rb], &payload)?;
    }
    Ok(())
}

/// Single-source broadcast: `src` sends `buf` to each of `dests` directly
/// (the paper uses one existing worker to broadcast the model to all new
/// workers, §4.2).
pub fn broadcast_send<N: PointToPoint>(
    net: &mut N,
    dests: &[u32],
    step: u64,
    buf: &[f32],
) -> Result<()> {
    let t = tag::BCAST ^ ((step as u32) & 0xFFFF);
    for &d in dests {
        let mut e = Enc::with_capacity(8 + buf.len() * 4);
        e.f32s(buf);
        net.send(d, t, e.into_bytes())?;
    }
    Ok(())
}

/// Receive a broadcast model from `src`.
pub fn broadcast_recv<N: PointToPoint>(
    net: &mut N,
    src: u32,
    step: u64,
    timeout: Duration,
) -> Result<Vec<f32>> {
    let t = tag::BCAST ^ ((step as u32) & 0xFFFF);
    let payload = net.recv_from(src, t, timeout)?;
    Ok(Dec::new(&payload).f32s()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::InProcHub;
    use crate::util::{prop, rng::Pcg};

    const T: Duration = Duration::from_secs(20);

    fn run_allreduce(n: usize, len: usize, seed: u64, weighted: bool) -> (Vec<Vec<f32>>, Vec<f32>) {
        let hub = InProcHub::new();
        let ring: Vec<u32> = (0..n as u32).collect();
        let mut rng = Pcg::seeded(seed);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect();
        let weights: Vec<f32> = if weighted {
            let raw: Vec<f32> = (0..n).map(|_| 0.1 + rng.f64() as f32).collect();
            let s: f32 = raw.iter().sum();
            raw.iter().map(|w| w / s).collect()
        } else {
            vec![1.0; n]
        };
        let mut expected = vec![0f32; len];
        for (inp, w) in inputs.iter().zip(&weights) {
            for (e, x) in expected.iter_mut().zip(inp) {
                *e += *x * *w;
            }
        }
        // join ALL endpoints before any thread starts (otherwise an early
        // sender races a not-yet-registered peer)
        let eps: Vec<_> = (0..n).map(|i| hub.join(i as u32)).collect();
        let outputs: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = eps
                .into_iter()
                .enumerate()
                .map(|(i, mut ep)| {
                    let ring = ring.clone();
                    let mut buf = inputs[i].clone();
                    let w = weights[i];
                    s.spawn(move || {
                        ring_allreduce(&mut ep, &ring, 7, &mut buf, w, T).unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        (outputs, expected)
    }

    #[test]
    fn two_workers_sum() {
        let (outs, expected) = run_allreduce(2, 10, 1, false);
        for o in &outs {
            for (a, b) in o.iter().zip(&expected) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn many_workers_uneven_chunks() {
        // len not divisible by n exercises the remainder chunks
        let (outs, expected) = run_allreduce(5, 103, 2, false);
        for o in &outs {
            for (a, b) in o.iter().zip(&expected) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn single_worker_identity() {
        let hub = InProcHub::new();
        let mut ep = hub.join(0);
        let mut buf = vec![1.0f32, 2.0, 3.0];
        ring_allreduce(&mut ep, &[0], 0, &mut buf, 1.0, T).unwrap();
        assert_eq!(buf, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn weighted_mean_gradient() {
        let (outs, expected) = run_allreduce(4, 64, 3, true);
        for o in &outs {
            for (a, b) in o.iter().zip(&expected) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn len_smaller_than_ring() {
        let (outs, expected) = run_allreduce(4, 3, 4, false);
        for o in &outs {
            for (a, b) in o.iter().zip(&expected) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn chunks_partition_exactly() {
        prop::check("chunks-partition", 100, |rng| {
            let len = rng.gen_range(10_000) as usize;
            let n = 1 + rng.gen_range(32) as usize;
            let cs = chunks(len, n);
            if cs.len() != n {
                return Err("wrong count".into());
            }
            let mut pos = 0;
            for &(a, b) in &cs {
                if a != pos || b < a {
                    return Err(format!("bad chunk ({a},{b}) at pos {pos}"));
                }
                pos = b;
            }
            if pos != len {
                return Err("doesn't cover".into());
            }
            Ok(())
        });
    }

    #[test]
    fn allreduce_agreement_property() {
        // all workers end with identical buffers equal to the weighted sum
        prop::check("allreduce-agreement", 8, |rng| {
            let n = 2 + rng.gen_range(5) as usize;
            let len = 1 + rng.gen_range(300) as usize;
            let (outs, expected) = run_allreduce(n, len, rng.next_u64(), true);
            for o in &outs {
                for (i, (a, b)) in o.iter().zip(&expected).enumerate() {
                    if (a - b).abs() > 1e-3 {
                        return Err(format!("elt {i}: {a} vs {b}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn consecutive_steps_do_not_crosstalk() {
        // run two allreduces back-to-back on the same endpoints with
        // different step ids; results must both be exact
        let hub = InProcHub::new();
        let ring: Vec<u32> = vec![0, 1, 2];
        let eps: Vec<_> = (0..3).map(|i| hub.join(i as u32)).collect();
        let outs: Vec<(Vec<f32>, Vec<f32>)> = std::thread::scope(|s| {
            eps.into_iter()
                .enumerate()
                .map(|(i, mut ep)| {
                    let ring = ring.clone();
                    s.spawn(move || {
                        let mut b1 = vec![i as f32; 8];
                        ring_allreduce(&mut ep, &ring, 1, &mut b1, 1.0, T).unwrap();
                        let mut b2 = vec![(i * 10) as f32; 8];
                        ring_allreduce(&mut ep, &ring, 2, &mut b2, 1.0, T).unwrap();
                        (b1, b2)
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for (b1, b2) in &outs {
            assert!(b1.iter().all(|&x| (x - 3.0).abs() < 1e-6)); // 0+1+2
            assert!(b2.iter().all(|&x| (x - 30.0).abs() < 1e-6)); // 0+10+20
        }
    }

    #[test]
    fn broadcast_to_joiners() {
        let hub = InProcHub::new();
        let model = vec![3.5f32; 1000];
        let model2 = model.clone();
        std::thread::scope(|s| {
            let mut src = hub.join(0);
            let mut j1 = hub.join(1);
            let mut j2 = hub.join(2);
            s.spawn(move || broadcast_send(&mut src, &[1, 2], 5, &model2).unwrap());
            let r1 = s.spawn(move || broadcast_recv(&mut j1, 0, 5, T).unwrap());
            let r2 = s.spawn(move || broadcast_recv(&mut j2, 0, 5, T).unwrap());
            assert_eq!(r1.join().unwrap(), model);
            assert_eq!(r2.join().unwrap(), model);
        });
    }

    #[test]
    fn not_in_ring_rejected() {
        let hub = InProcHub::new();
        let mut ep = hub.join(9);
        let mut buf = vec![0f32; 4];
        assert!(matches!(
            ring_allreduce(&mut ep, &[0, 1], 0, &mut buf, 1.0, T),
            Err(ArError::NotInRing)
        ));
    }
}
