//! Wire protocol of the master control endpoint: what `edl submit` and
//! `edl master jobs` speak to the `edl master` daemon. Framed with the
//! shared [`crate::wire`] codec, same as every other control socket.

use crate::wire::{self, Dec, Enc, WireError};
use std::io::{BufReader, BufWriter};
use std::net::TcpStream;

/// A job submission (`edl submit`): what to run and when it is done.
/// Jobs run on the artifact-free simulated device backend, so a master
/// smoke cluster needs nothing but the `edl` binary.
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitSpec {
    /// unique job name (`edl ctl --job <name>` resolves it via the KV)
    pub name: String,
    /// DNN class for the analytic what-if model (`Dnn::by_name`;
    /// unknown names fall back to ResNet50)
    pub model: String,
    /// requested parallelism (GPUs)
    pub gpus: u32,
    /// the job completes once its step counter reaches this
    pub steps: u64,
    /// may the scheduler grow/shrink it (§5.1)
    pub elastic: bool,
    /// simulated-backend parameter count
    pub params: u64,
    /// simulated-backend compute delay (ms per 32-sample batch)
    pub compute_ms: u64,
}

impl Default for SubmitSpec {
    fn default() -> SubmitSpec {
        SubmitSpec {
            name: String::new(),
            model: "ResNet50".into(),
            gpus: 1,
            steps: 200,
            elastic: true,
            params: 512,
            compute_ms: 5,
        }
    }
}

/// One row of `edl master jobs`.
#[derive(Debug, Clone, PartialEq)]
pub struct JobInfo {
    pub name: String,
    /// "pending" | "running" | "stopping" | "finished"
    pub phase: String,
    pub requested_p: u32,
    /// GPUs currently held
    pub parallelism: u32,
    pub step: u64,
    /// high-water parallelism (shows R2 expansion happened)
    pub peak_p: u32,
    pub grow_ops: u32,
    pub shrink_ops: u32,
    /// the job leader's Table-1 TCP endpoint
    pub ctl_addr: String,
    /// machine label per held GPU
    pub machines: Vec<String>,
}

/// Aggregate state of one inventory shard (rack), for scale monitoring.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardStat {
    pub shard: u32,
    pub machines: u32,
    pub capacity: u32,
    pub free: u32,
    pub held: u32,
}

/// Scheduler-throughput counters served by `MasterRequest::Stats`: tick
/// latency percentiles (µs, over a sliding window of recent ticks),
/// accepted-decision counters, and per-shard inventory conservation.
#[derive(Debug, Clone, PartialEq)]
pub struct MasterStats {
    pub ticks: u64,
    pub tick_p50_us: u64,
    pub tick_p99_us: u64,
    pub tick_max_us: u64,
    /// decisions accepted by the engine (== starts + grows + shrinks)
    pub decisions: u64,
    pub starts: u64,
    pub grows: u64,
    pub shrinks: u64,
    pub stops: u64,
    pub jobs_total: u64,
    pub jobs_running: u64,
    /// `free + held == capacity` held on every shard at the last check
    pub conservation_ok: bool,
    pub shards: Vec<ShardStat>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum MasterRequest {
    Submit(SubmitSpec),
    Jobs,
    Shutdown,
    Stats,
    /// One page of the job table: up to `limit` rows starting at `from`.
    /// At hundreds of jobs, `Jobs` builds one giant sweep under the
    /// control lock; pagination bounds the per-request work.
    JobsPage { from: u64, limit: u64 },
}

#[derive(Debug, Clone, PartialEq)]
pub enum MasterResponse {
    Submitted { job: u64 },
    Jobs(Vec<JobInfo>),
    Ok,
    Err(String),
    Stats(MasterStats),
    /// `next` is the index to resume from; `next == total` ends the scan
    JobsPage { jobs: Vec<JobInfo>, next: u64, total: u64 },
}

impl SubmitSpec {
    fn encode_into(&self, e: &mut Enc) {
        e.str(&self.name)
            .str(&self.model)
            .u32(self.gpus)
            .u64(self.steps)
            .bool(self.elastic)
            .u64(self.params)
            .u64(self.compute_ms);
    }

    fn decode_from(d: &mut Dec) -> wire::Result<SubmitSpec> {
        Ok(SubmitSpec {
            name: d.str()?,
            model: d.str()?,
            gpus: d.u32()?,
            steps: d.u64()?,
            elastic: d.bool()?,
            params: d.u64()?,
            compute_ms: d.u64()?,
        })
    }
}

impl JobInfo {
    fn encode_into(&self, e: &mut Enc) {
        e.str(&self.name)
            .str(&self.phase)
            .u32(self.requested_p)
            .u32(self.parallelism)
            .u64(self.step)
            .u32(self.peak_p)
            .u32(self.grow_ops)
            .u32(self.shrink_ops)
            .str(&self.ctl_addr)
            .strs(&self.machines);
    }

    fn decode_from(d: &mut Dec) -> wire::Result<JobInfo> {
        Ok(JobInfo {
            name: d.str()?,
            phase: d.str()?,
            requested_p: d.u32()?,
            parallelism: d.u32()?,
            step: d.u64()?,
            peak_p: d.u32()?,
            grow_ops: d.u32()?,
            shrink_ops: d.u32()?,
            ctl_addr: d.str()?,
            machines: d.strs()?,
        })
    }
}

impl ShardStat {
    fn encode_into(&self, e: &mut Enc) {
        e.u32(self.shard).u32(self.machines).u32(self.capacity).u32(self.free).u32(self.held);
    }

    fn decode_from(d: &mut Dec) -> wire::Result<ShardStat> {
        Ok(ShardStat {
            shard: d.u32()?,
            machines: d.u32()?,
            capacity: d.u32()?,
            free: d.u32()?,
            held: d.u32()?,
        })
    }
}

impl MasterStats {
    fn encode_into(&self, e: &mut Enc) {
        e.u64(self.ticks)
            .u64(self.tick_p50_us)
            .u64(self.tick_p99_us)
            .u64(self.tick_max_us)
            .u64(self.decisions)
            .u64(self.starts)
            .u64(self.grows)
            .u64(self.shrinks)
            .u64(self.stops)
            .u64(self.jobs_total)
            .u64(self.jobs_running)
            .bool(self.conservation_ok)
            .u32(self.shards.len() as u32);
        for s in &self.shards {
            s.encode_into(e);
        }
    }

    fn decode_from(d: &mut Dec) -> wire::Result<MasterStats> {
        Ok(MasterStats {
            ticks: d.u64()?,
            tick_p50_us: d.u64()?,
            tick_p99_us: d.u64()?,
            tick_max_us: d.u64()?,
            decisions: d.u64()?,
            starts: d.u64()?,
            grows: d.u64()?,
            shrinks: d.u64()?,
            stops: d.u64()?,
            jobs_total: d.u64()?,
            jobs_running: d.u64()?,
            conservation_ok: d.bool()?,
            shards: {
                let n = d.u32()? as usize;
                (0..n).map(|_| ShardStat::decode_from(d)).collect::<wire::Result<_>>()?
            },
        })
    }
}

impl MasterRequest {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            MasterRequest::Submit(spec) => {
                e.u8(1);
                spec.encode_into(&mut e);
            }
            MasterRequest::Jobs => {
                e.u8(2);
            }
            MasterRequest::Shutdown => {
                e.u8(3);
            }
            MasterRequest::Stats => {
                e.u8(4);
            }
            MasterRequest::JobsPage { from, limit } => {
                e.u8(5).u64(*from).u64(*limit);
            }
        }
        e.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> wire::Result<MasterRequest> {
        let mut d = Dec::new(buf);
        match d.u8()? {
            1 => Ok(MasterRequest::Submit(SubmitSpec::decode_from(&mut d)?)),
            2 => Ok(MasterRequest::Jobs),
            3 => Ok(MasterRequest::Shutdown),
            4 => Ok(MasterRequest::Stats),
            5 => Ok(MasterRequest::JobsPage { from: d.u64()?, limit: d.u64()? }),
            tag => Err(WireError::BadTag { tag: tag as u32, ty: "master::MasterRequest" }),
        }
    }
}

impl MasterResponse {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            MasterResponse::Submitted { job } => {
                e.u8(1).u64(*job);
            }
            MasterResponse::Jobs(jobs) => {
                e.u8(2).u32(jobs.len() as u32);
                for j in jobs {
                    j.encode_into(&mut e);
                }
            }
            MasterResponse::Ok => {
                e.u8(3);
            }
            MasterResponse::Err(m) => {
                e.u8(4).str(m);
            }
            MasterResponse::Stats(stats) => {
                e.u8(5);
                stats.encode_into(&mut e);
            }
            MasterResponse::JobsPage { jobs, next, total } => {
                e.u8(6).u64(*next).u64(*total).u32(jobs.len() as u32);
                for j in jobs {
                    j.encode_into(&mut e);
                }
            }
        }
        e.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> wire::Result<MasterResponse> {
        let mut d = Dec::new(buf);
        match d.u8()? {
            1 => Ok(MasterResponse::Submitted { job: d.u64()? }),
            2 => {
                let n = d.u32()? as usize;
                let jobs =
                    (0..n).map(|_| JobInfo::decode_from(&mut d)).collect::<wire::Result<_>>()?;
                Ok(MasterResponse::Jobs(jobs))
            }
            3 => Ok(MasterResponse::Ok),
            4 => Ok(MasterResponse::Err(d.str()?)),
            5 => Ok(MasterResponse::Stats(MasterStats::decode_from(&mut d)?)),
            6 => {
                let next = d.u64()?;
                let total = d.u64()?;
                let n = d.u32()? as usize;
                let jobs =
                    (0..n).map(|_| JobInfo::decode_from(&mut d)).collect::<wire::Result<_>>()?;
                Ok(MasterResponse::JobsPage { jobs, next, total })
            }
            tag => Err(WireError::BadTag { tag: tag as u32, ty: "master::MasterResponse" }),
        }
    }
}

/// Blocking TCP client for the master control endpoint.
pub struct MasterClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl MasterClient {
    pub fn connect(addr: &str) -> std::io::Result<MasterClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(MasterClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    pub fn call(&mut self, req: &MasterRequest) -> anyhow::Result<MasterResponse> {
        wire::write_frame(&mut self.writer, &req.encode())
            .map_err(|e| anyhow::anyhow!("master request failed: {e}"))?;
        let raw = wire::read_frame(&mut self.reader)
            .map_err(|e| anyhow::anyhow!("master reply failed: {e}"))?;
        MasterResponse::decode(&raw).map_err(|e| anyhow::anyhow!("bad master reply: {e}"))
    }

    pub fn submit(&mut self, spec: &SubmitSpec) -> anyhow::Result<u64> {
        match self.call(&MasterRequest::Submit(spec.clone()))? {
            MasterResponse::Submitted { job } => Ok(job),
            MasterResponse::Err(m) => anyhow::bail!("submit rejected: {m}"),
            other => anyhow::bail!("unexpected submit reply: {other:?}"),
        }
    }

    /// Full job table, fetched page by page so the daemon never assembles
    /// one giant sweep under its control lock (hundreds of jobs => many
    /// small bounded requests instead of one unbounded one).
    pub fn jobs(&mut self) -> anyhow::Result<Vec<JobInfo>> {
        let mut out: Vec<JobInfo> = Vec::new();
        let mut from = 0u64;
        loop {
            let (page, next, total) = self.jobs_page(from, 64)?;
            let done = page.is_empty() || next >= total;
            out.extend(page);
            if done || out.len() as u64 >= total {
                return Ok(out);
            }
            from = next;
        }
    }

    /// One bounded page of the job table.
    pub fn jobs_page(&mut self, from: u64, limit: u64) -> anyhow::Result<(Vec<JobInfo>, u64, u64)> {
        match self.call(&MasterRequest::JobsPage { from, limit })? {
            MasterResponse::JobsPage { jobs, next, total } => Ok((jobs, next, total)),
            MasterResponse::Err(m) => anyhow::bail!("jobs query rejected: {m}"),
            other => anyhow::bail!("unexpected jobs reply: {other:?}"),
        }
    }

    pub fn stats(&mut self) -> anyhow::Result<MasterStats> {
        match self.call(&MasterRequest::Stats)? {
            MasterResponse::Stats(s) => Ok(s),
            MasterResponse::Err(m) => anyhow::bail!("stats query rejected: {m}"),
            other => anyhow::bail!("unexpected stats reply: {other:?}"),
        }
    }

    pub fn shutdown(&mut self) -> anyhow::Result<()> {
        match self.call(&MasterRequest::Shutdown)? {
            MasterResponse::Ok => Ok(()),
            MasterResponse::Err(m) => anyhow::bail!("shutdown rejected: {m}"),
            other => anyhow::bail!("unexpected shutdown reply: {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Pcg};

    fn rand_str(rng: &mut Pcg) -> String {
        let n = rng.gen_range(14) as usize;
        (0..n).map(|_| (b'a' + (rng.gen_range(26) as u8)) as char).collect()
    }

    fn rand_spec(rng: &mut Pcg) -> SubmitSpec {
        SubmitSpec {
            name: rand_str(rng),
            model: rand_str(rng),
            gpus: 1 + rng.gen_range(64) as u32,
            steps: rng.next_u64() >> 16,
            elastic: rng.gen_range(2) == 1,
            params: rng.next_u64() >> 32,
            compute_ms: rng.gen_range(1 << 16),
        }
    }

    fn rand_shard(rng: &mut Pcg, shard: u32) -> ShardStat {
        let machines = 1 + rng.gen_range(32) as u32;
        let capacity = machines * (1 + rng.gen_range(8) as u32);
        let held = rng.gen_range(u64::from(capacity) + 1) as u32;
        ShardStat { shard, machines, capacity, free: capacity - held, held }
    }

    fn rand_stats(rng: &mut Pcg) -> MasterStats {
        MasterStats {
            ticks: rng.next_u64() >> 16,
            tick_p50_us: rng.gen_range(1 << 20),
            tick_p99_us: rng.gen_range(1 << 24),
            tick_max_us: rng.gen_range(1 << 24),
            decisions: rng.next_u64() >> 32,
            starts: rng.gen_range(1 << 20),
            grows: rng.gen_range(1 << 20),
            shrinks: rng.gen_range(1 << 20),
            stops: rng.gen_range(1 << 20),
            jobs_total: rng.gen_range(1 << 16),
            jobs_running: rng.gen_range(1 << 16),
            conservation_ok: rng.gen_range(2) == 1,
            shards: (0..rng.gen_range(5) as u32).map(|s| rand_shard(rng, s)).collect(),
        }
    }

    fn rand_info(rng: &mut Pcg) -> JobInfo {
        JobInfo {
            name: rand_str(rng),
            phase: ["pending", "running", "stopping", "finished"]
                [rng.gen_range(4) as usize]
                .to_string(),
            requested_p: rng.gen_range(64) as u32,
            parallelism: rng.gen_range(64) as u32,
            step: rng.next_u64() >> 16,
            peak_p: rng.gen_range(64) as u32,
            grow_ops: rng.gen_range(1 << 10) as u32,
            shrink_ops: rng.gen_range(1 << 10) as u32,
            ctl_addr: format!("127.0.0.1:{}", rng.gen_range(65536)),
            machines: (0..rng.gen_range(6)).map(|_| rand_str(rng)).collect(),
        }
    }

    #[test]
    fn master_request_every_variant_roundtrips_property() {
        // random fields through every variant, mirroring the rpc property
        // tests (util::prop reports the failing seed for reproduction)
        prop::check("master-request-roundtrip", 200, |rng: &mut Pcg| {
            let reqs = vec![
                MasterRequest::Submit(rand_spec(rng)),
                MasterRequest::Jobs,
                MasterRequest::Shutdown,
                MasterRequest::Stats,
                MasterRequest::JobsPage { from: rng.next_u64() >> 32, limit: rng.gen_range(256) },
            ];
            for r in reqs {
                let back = MasterRequest::decode(&r.encode()).map_err(|e| e.to_string())?;
                if back != r {
                    return Err(format!("mismatch: {r:?} vs {back:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn master_response_every_variant_roundtrips_property() {
        prop::check("master-response-roundtrip", 200, |rng: &mut Pcg| {
            let resps = vec![
                MasterResponse::Submitted { job: rng.next_u64() },
                MasterResponse::Jobs((0..rng.gen_range(5)).map(|_| rand_info(rng)).collect()),
                MasterResponse::Ok,
                MasterResponse::Err(rand_str(rng)),
                MasterResponse::Stats(rand_stats(rng)),
                MasterResponse::JobsPage {
                    jobs: (0..rng.gen_range(5)).map(|_| rand_info(rng)).collect(),
                    next: rng.gen_range(1 << 16),
                    total: rng.gen_range(1 << 16),
                },
            ];
            for r in resps {
                let back = MasterResponse::decode(&r.encode()).map_err(|e| e.to_string())?;
                if back != r {
                    return Err(format!("mismatch: {r:?} vs {back:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn truncated_master_frames_rejected_never_panic() {
        // every proper prefix of every encoding must decode to a clean
        // error (a malformed/short frame must not crash the daemon)
        let mut rng = Pcg::seeded(0xB0A7);
        let frames: Vec<Vec<u8>> = vec![
            MasterRequest::Submit(rand_spec(&mut rng)).encode(),
            MasterRequest::Jobs.encode(),
            MasterRequest::Shutdown.encode(),
            MasterRequest::Stats.encode(),
            MasterRequest::JobsPage { from: 128, limit: 64 }.encode(),
        ];
        for full in frames {
            for cut in 0..full.len() {
                assert!(
                    MasterRequest::decode(&full[..cut]).is_err(),
                    "prefix of len {cut} of {full:?} decoded"
                );
            }
            assert!(MasterRequest::decode(&full).is_ok());
        }
        let frames: Vec<Vec<u8>> = vec![
            MasterResponse::Submitted { job: 77 }.encode(),
            MasterResponse::Jobs(vec![rand_info(&mut rng), rand_info(&mut rng)]).encode(),
            MasterResponse::Ok.encode(),
            MasterResponse::Err("no capacity".into()).encode(),
            MasterResponse::Stats(rand_stats(&mut rng)).encode(),
            MasterResponse::JobsPage { jobs: vec![rand_info(&mut rng)], next: 1, total: 9 }
                .encode(),
        ];
        for full in frames {
            for cut in 0..full.len() {
                assert!(
                    MasterResponse::decode(&full[..cut]).is_err(),
                    "prefix of len {cut} of {full:?} decoded"
                );
            }
            assert!(MasterResponse::decode(&full).is_ok());
        }
    }

    #[test]
    fn master_protocol_roundtrips() {
        let reqs = vec![
            MasterRequest::Submit(SubmitSpec {
                name: "jobA".into(),
                model: "VGG16".into(),
                gpus: 2,
                steps: 500,
                elastic: false,
                params: 1024,
                compute_ms: 7,
            }),
            MasterRequest::Jobs,
            MasterRequest::Shutdown,
            MasterRequest::Stats,
            MasterRequest::JobsPage { from: 0, limit: 32 },
        ];
        for r in reqs {
            assert_eq!(MasterRequest::decode(&r.encode()).unwrap(), r);
        }
        let resps = vec![
            MasterResponse::Submitted { job: 3 },
            MasterResponse::Jobs(vec![JobInfo {
                name: "jobA".into(),
                phase: "running".into(),
                requested_p: 1,
                parallelism: 3,
                step: 42,
                peak_p: 4,
                grow_ops: 2,
                shrink_ops: 1,
                ctl_addr: "127.0.0.1:9999".into(),
                machines: vec!["m1".into(), "m1".into(), "m2".into()],
            }]),
            MasterResponse::Ok,
            MasterResponse::Err("no capacity".into()),
            MasterResponse::Stats(MasterStats {
                ticks: 1000,
                tick_p50_us: 150,
                tick_p99_us: 900,
                tick_max_us: 1200,
                decisions: 420,
                starts: 200,
                grows: 180,
                shrinks: 40,
                stops: 120,
                jobs_total: 220,
                jobs_running: 100,
                conservation_ok: true,
                shards: vec![ShardStat { shard: 0, machines: 32, capacity: 256, free: 200, held: 56 }],
            }),
            MasterResponse::JobsPage { jobs: vec![], next: 0, total: 0 },
        ];
        for r in resps {
            assert_eq!(MasterResponse::decode(&r.encode()).unwrap(), r);
        }
        assert!(MasterRequest::decode(&[0]).is_err());
        assert!(MasterResponse::decode(&[9]).is_err());
    }
}
