//! Bit-deterministic elasticity, end to end (DESIGN.md §11).
//!
//! The EasyScale claim: with the fixed logical-shard schedule and
//! migrated virtual-worker RNG streams, the committed loss curve is a
//! pure function of the seed — independent of the physical worker count
//! and of WHEN the cluster grew, shrank or migrated. These tests compare
//! the trajectory-equality mirror ([`Trajectory`]) across runs:
//!
//!  * a quiet P=1 baseline vs the full PR-5 chaos storm of the same
//!    seed ⇒ byte-identical losses on every step both runs committed;
//!  * different calm worker counts ⇒ byte-identical curves;
//!  * the same storm replayed ⇒ byte-identical curves (and logs).
//!
//! Within-run redo consistency (a post-restore re-execution must commit
//! the exact bits of the first execution) is enforced by the mirror
//! inside every chaos run, including all of `tests/chaos.rs`.

use edl::harness::chaos::{run_schedule, ChaosReport, ChaosSchedule};

/// Three fixed storm seeds, also pinned by the `determinism-smoke` CI
/// job. Nothing special about them beyond being stable.
const SEEDS: [u64; 3] = [1, 2, 3];

/// Minimum steps the two curves must share for the comparison to mean
/// anything (quiesce alone guarantees ≥ 8 barriers per run).
const MIN_OVERLAP: usize = 5;

fn run(sched: &ChaosSchedule, what: &str) -> ChaosReport {
    run_schedule(sched)
        .unwrap_or_else(|f| panic!("{what} (seed {:#x}) failed:\n{f}", sched.seed))
}

fn assert_trajectories_equal(a: &ChaosReport, b: &ChaosReport, seed: u64, what: &str) {
    if let Some((step, x, y)) = a.trajectory.diverges_from(&b.trajectory) {
        panic!(
            "seed {seed:#x}: {what} diverged at step {step}: {x} ({:#010x}) vs {y} ({:#010x})",
            x.to_bits(),
            y.to_bits()
        );
    }
    let common = a.trajectory.common_steps(&b.trajectory);
    assert!(
        common >= MIN_OVERLAP,
        "seed {seed:#x}: {what} shared only {common} steps — comparison is vacuous"
    );
}

#[test]
fn p1_baseline_equals_chaos_storm_loss_curve() {
    for seed in SEEDS {
        let storm = ChaosSchedule::generate(seed, usize::MAX);
        // same data/seed knobs, one founder, no scale events: the
        // reference execution every elastic run must reproduce
        let base = ChaosSchedule { founders: 1, events: vec![], ..storm.clone() };
        let storm_report = run(&storm, "chaos storm");
        let base_report = run(&base, "P=1 baseline");
        assert!(
            !base_report.trajectory.is_empty() && !storm_report.trajectory.is_empty(),
            "seed {seed:#x}: a run committed no losses"
        );
        assert_trajectories_equal(&base_report, &storm_report, seed, "P=1 vs storm");
    }
}

#[test]
fn calm_worker_counts_share_one_loss_curve() {
    // no chaos at all — only the founding worker count differs
    for seed in SEEDS {
        let proto = ChaosSchedule::generate(seed, 0);
        let runs: Vec<ChaosReport> = [1usize, 2, 3, 4]
            .iter()
            .map(|&p| {
                run(
                    &ChaosSchedule { founders: p, events: vec![], ..proto.clone() },
                    "calm run",
                )
            })
            .collect();
        for pair in runs.windows(2) {
            assert_trajectories_equal(&pair[0], &pair[1], seed, "calm P vs P+1");
        }
    }
}

#[test]
fn storm_replay_is_bit_identical() {
    // the storm itself is deterministic: same schedule ⇒ same trajectory
    // AND the same event log, byte for byte
    let storm = ChaosSchedule::generate(SEEDS[0], usize::MAX);
    let a = run(&storm, "storm replay a");
    let b = run(&storm, "storm replay b");
    assert_trajectories_equal(&a, &b, SEEDS[0], "replay");
    assert_eq!(
        a.trajectory.len(),
        b.trajectory.len(),
        "replays committed different step sets"
    );
    assert_eq!(a.log, b.log, "replayed event logs differ");
}
