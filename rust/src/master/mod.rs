//! `edl master` — the live multi-job cluster engine (§2, §6): the second
//! implementation of the policy/engine split ([`crate::sched`]), next to
//! the discrete-event simulator.
//!
//! ```text
//!   edl submit ──MasterRequest──►┐
//!   edl master jobs ────────────►│ control endpoint
//!                                ▼
//!                         Master shell thread
//!             inventory ─ job table ─ policy tick (Scheduler)
//!                │                │
//!                │ Decision       │ per job
//!                ▼                ▼
//!         api::JobControl   deploy::LeaderEndpoint + JobServer
//!         (Grow/Shrink via  (one leader per job; `edl worker`
//!          Table-1 calls)    OS processes on machine slots)
//! ```
//!
//! The master owns the machine inventory (named machines × GPU slots),
//! accepts `edl submit` jobs, and for each started job spawns a per-job
//! leader ([`LeaderEndpoint`]) plus one `edl worker` OS process per
//! granted GPU slot — the PR 3 lobby/Spawn rendezvous does the matching,
//! so scale-out is stop-free across real process boundaries. A
//! [`Scheduler`] policy (the SAME objects the simulator runs) ticks on a
//! clock over the [`ClusterView`] and its [`Decision`]s are applied
//! through each job's Table-1 handle ([`crate::api::JobControl`]):
//!
//!  * `Start` — allocate slots, spawn leader + founder workers;
//!  * `Grow`  — reserve idle slots, spawn joiner workers, `scale_out`;
//!  * `Shrink`— `status` → newest workers → `scale_in`, slots returned
//!    to the machines the workers ran on (graceful, no restart);
//!  * `Preempt`/`Migrate` — refused: the master NEVER restarts a job
//!    (the paper's checkpoint/restart baseline is simulator-only).
//!
//! Every started job's Table-1 address is registered in the embedded
//! coordination KV under `edl/jobs/<name>/ctl` with a TTL lease the
//! master refreshes each tick, so `edl ctl --job <name> --kv <addr>`
//! resolves live jobs by name.

pub mod proto;

use crate::api::{JobControl, JobControlExt, JobServer, Request, Response};
use crate::coordinator::TrainerConfig;
use crate::coordsvc::{KvClient, KvServer};
use crate::deploy::{config_digest, LeaderEndpoint, LeaderHandle};
use crate::gpu_sim::{self, Dnn, HwConfig};
use crate::sched::{ClusterCtl, ClusterView, Decision, JobView, NoopScheduler, Scheduler};
use crate::schedulers::ElasticTiresias;
use crate::wire;
use crate::worker::{Backend, SimBackend};
use proto::{JobInfo, MasterRequest, MasterResponse, SubmitSpec};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Fixed sim-job data-pipeline shape, shared with `edl worker` defaults so
/// the [`config_digest`] handshake matches (see `deploy_digest` in
/// main.rs: samples / data-seed / params / seq / lr).
const SIM_SAMPLES: u64 = 4096;
const SIM_DATA_SEED: u64 = 1;
const SIM_LR: f32 = 0.05;
/// Aggregate batch of every master-run job (constant under scaling,
/// §3.1). Used for BOTH the leader's `TrainerConfig` and the policy's
/// what-if queries, so the analytic model describes the job that runs.
const SIM_AGG_BATCH: u32 = 32;

/// One named machine with a number of GPU slots.
#[derive(Debug, Clone)]
pub struct MachineSpec {
    pub name: String,
    pub gpus: u32,
}

pub struct MasterConfig {
    pub machines: Vec<MachineSpec>,
    /// scheduler tick period (ms)
    pub tick_ms: u64,
    /// TTL of the per-job ctl-address lease in the KV (ms)
    pub lease_ttl_ms: u64,
    /// master control endpoint bind address
    pub listen: String,
    /// embedded coordination-KV bind address
    pub kv_listen: String,
    /// binary to spawn worker processes from (default: this executable)
    pub worker_bin: Option<PathBuf>,
}

impl Default for MasterConfig {
    fn default() -> MasterConfig {
        MasterConfig {
            machines: vec![
                MachineSpec { name: "m1".into(), gpus: 2 },
                MachineSpec { name: "m2".into(), gpus: 2 },
            ],
            tick_ms: 250,
            lease_ttl_ms: 5_000,
            listen: "127.0.0.1:0".into(),
            kv_listen: "127.0.0.1:0".into(),
            worker_bin: None,
        }
    }
}

/// The running daemon: control endpoint + embedded KV + shell thread.
pub struct Master {
    /// control endpoint (`edl submit --master <addr>`)
    pub addr: String,
    /// embedded coordination KV (`edl ctl --job <name> --kv <addr>`)
    pub kv_addr: String,
    shell: Option<std::thread::JoinHandle<()>>,
    accept_stop: Arc<AtomicBool>,
    /// set by Drop so an abandoned Master tears its jobs down instead of
    /// leaking the shell thread and worker processes
    halt: Arc<AtomicBool>,
}

impl Master {
    pub fn start(
        cfg: MasterConfig,
        sched: Box<dyn Scheduler + Send>,
    ) -> anyhow::Result<Master> {
        anyhow::ensure!(!cfg.machines.is_empty(), "master needs at least one machine");
        anyhow::ensure!(
            cfg.machines.iter().all(|m| m.gpus >= 1),
            "every machine needs at least one GPU slot"
        );
        let kv = KvServer::start_on(&cfg.kv_listen)?;
        let kv_addr = kv.addr.clone();
        let listener = TcpListener::bind(&cfg.listen)?;
        let addr = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let (tx, rx) = channel::<MIn>();
        let accept_stop = Arc::new(AtomicBool::new(false));

        // accept loop: thread per connection, framed request/reply into
        // the shell's mailbox (the JobServer pattern)
        {
            let tx = tx.clone();
            let stop = accept_stop.clone();
            std::thread::Builder::new()
                .name("edl-master-accept".into())
                .spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                let tx = tx.clone();
                                std::thread::spawn(move || {
                                    let _ = serve_master_conn(stream, tx);
                                });
                            }
                            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(2));
                            }
                            Err(_) => break,
                        }
                    }
                })
                .expect("spawn master accept loop");
        }

        let worker_bin = match cfg.worker_bin.clone() {
            Some(p) => p,
            None => std::env::current_exe()?,
        };
        let hw = HwConfig {
            gpus_per_machine: cfg.machines.iter().map(|m| m.gpus).max().unwrap_or(1),
            ..HwConfig::default()
        };
        let free: Vec<u32> = cfg.machines.iter().map(|m| m.gpus).collect();
        let halt = Arc::new(AtomicBool::new(false));
        let shell = Shell {
            machines: cfg.machines,
            free,
            hw,
            jobs: Vec::new(),
            sched,
            rx,
            tx,
            kv,
            kv_client: None,
            start: Instant::now(),
            last_now: 0.0,
            last_tick: Instant::now(),
            tick_ms: cfg.tick_ms.max(50),
            lease_ttl_ms: cfg.lease_ttl_ms.max(500),
            worker_bin,
            accept_stop: accept_stop.clone(),
            halt: halt.clone(),
        };
        let shell = std::thread::Builder::new()
            .name("edl-master".into())
            .spawn(move || shell.run())
            .expect("spawn master shell");
        Ok(Master { addr, kv_addr, shell: Some(shell), accept_stop, halt })
    }

    /// Block until the master shuts down (a client sent `Shutdown`).
    pub fn join(mut self) {
        if let Some(h) = self.shell.take() {
            let _ = h.join();
        }
        self.accept_stop.store(true, Ordering::Relaxed);
    }
}

impl Drop for Master {
    fn drop(&mut self) {
        // an abandoned Master (drop without `join`) must not leak jobs:
        // the shell polls this flag every ≤100 ms, tears every job down
        // (stopping leaders, reaping worker processes) and exits
        self.halt.store(true, Ordering::Relaxed);
        self.accept_stop.store(true, Ordering::Relaxed);
    }
}

fn serve_master_conn(stream: TcpStream, tx: Sender<MIn>) -> wire::Result<()> {
    wire::serve_framed(stream, move |raw| {
        let resp = match MasterRequest::decode(raw) {
            Ok(req) => {
                let (rtx, rrx) = channel();
                if tx.send(MIn::Ctl(req, rtx)).is_ok() {
                    rrx.recv_timeout(Duration::from_secs(60))
                        .unwrap_or_else(|_| MasterResponse::Err("master unresponsive".into()))
                } else {
                    MasterResponse::Err("master stopped".into())
                }
            }
            Err(e) => MasterResponse::Err(format!("undecodable request: {e}")),
        };
        Ok(resp.encode())
    })
}

// ---------------------------------------------------------------------------
// shell
// ---------------------------------------------------------------------------

/// Which asynchronous Table-1 operation an executor thread ran.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Op {
    Grow,
    Shrink,
    Stop,
}

/// Outcome of an asynchronous Table-1 op, reported by its executor thread.
struct OpDone {
    job: usize,
    op: Op,
    ok: bool,
    /// Shrink: machine label per returned GPU slot
    freed: Vec<String>,
    /// Shrink: how many workers the committed scale-in removed (the
    /// inventory reconciles against this even if labels are missing)
    removed: usize,
    /// Grow: slots to un-reserve on failure
    undo: Vec<(usize, u32)>,
    /// Grow: first index of the joiner processes spawned for this op
    child_from: usize,
    err: String,
}

enum MIn {
    Ctl(MasterRequest, Sender<MasterResponse>),
    Done(OpDone),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Pending,
    Running,
    Stopping,
    Finished,
}

struct LiveJob {
    spec: SubmitSpec,
    model: Dnn,
    submit_s: f64,
    phase: Phase,
    endpoint: Option<LeaderEndpoint>,
    ctl: Option<JobServer<LeaderHandle>>,
    handle: Option<LeaderHandle>,
    ctl_addr: String,
    children: Vec<Child>,
    /// GPUs held per machine index
    held: Vec<u32>,
    /// a Table-1 op is in flight on an executor thread (§3.1 guard
    /// surfaced to the policy as `adjustable = false`)
    busy: bool,
    /// last `status` round-trip succeeded
    status_ok: bool,
    last_step: u64,
    peak_p: u32,
    grow_ops: u32,
    shrink_ops: u32,
    attained_gpu_s: f64,
}

impl LiveJob {
    fn held_p(&self) -> u32 {
        self.held.iter().sum()
    }
}

struct Shell {
    machines: Vec<MachineSpec>,
    free: Vec<u32>,
    hw: HwConfig,
    jobs: Vec<LiveJob>,
    sched: Box<dyn Scheduler + Send>,
    rx: Receiver<MIn>,
    tx: Sender<MIn>,
    kv: KvServer,
    /// lazily connected loopback client to the embedded KV: the per-tick
    /// lease sweep goes over the wire in ONE batched frame (OP_BATCH),
    /// the same path a remote coordination service would take
    kv_client: Option<KvClient>,
    start: Instant,
    last_now: f64,
    last_tick: Instant,
    tick_ms: u64,
    lease_ttl_ms: u64,
    worker_bin: PathBuf,
    accept_stop: Arc<AtomicBool>,
    halt: Arc<AtomicBool>,
}

impl Shell {
    fn run(mut self) {
        let poll = Duration::from_millis(self.tick_ms.min(100));
        let mut quit = false;
        while !quit && !self.halt.load(Ordering::Relaxed) {
            match self.rx.recv_timeout(poll) {
                Ok(MIn::Ctl(req, reply)) => {
                    let (resp, q) = self.handle_ctl(req);
                    let _ = reply.send(resp);
                    quit = q;
                }
                Ok(MIn::Done(done)) => self.finish_op(done),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
            if !quit && self.last_tick.elapsed() >= Duration::from_millis(self.tick_ms) {
                self.last_tick = Instant::now();
                self.tick();
            }
        }
        self.teardown();
        self.accept_stop.store(true, Ordering::Relaxed);
    }

    fn now_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn machine_ix(&self, name: &str) -> Option<usize> {
        self.machines.iter().position(|m| m.name == name)
    }

    // -- inventory ----------------------------------------------------------

    /// Reserve `p` GPU slots, most-free machines first (the simulator's
    /// packing). Returns None (and reserves nothing) if impossible.
    fn allocate(&mut self, p: u32) -> Option<Vec<(usize, u32)>> {
        if p == 0 || p > self.free.iter().sum::<u32>() {
            return None;
        }
        let mut need = p;
        let mut order: Vec<usize> = (0..self.machines.len()).collect();
        order.sort_by_key(|&m| std::cmp::Reverse(self.free[m]));
        let mut slots = Vec::new();
        for m in order {
            if need == 0 {
                break;
            }
            let take = self.free[m].min(need);
            if take > 0 {
                self.free[m] -= take;
                slots.push((m, take));
                need -= take;
            }
        }
        debug_assert_eq!(need, 0);
        Some(slots)
    }

    fn release(&mut self, slots: &[(usize, u32)]) {
        for &(m, g) in slots {
            self.free[m] += g;
        }
    }

    // -- control requests ---------------------------------------------------

    fn handle_ctl(&mut self, req: MasterRequest) -> (MasterResponse, bool) {
        match req {
            MasterRequest::Submit(spec) => {
                if spec.name.is_empty() {
                    return (MasterResponse::Err("job name must not be empty".into()), false);
                }
                if self.jobs.iter().any(|j| j.spec.name == spec.name) {
                    return (
                        MasterResponse::Err(format!("job {:?} already exists", spec.name)),
                        false,
                    );
                }
                let total: u32 = self.machines.iter().map(|m| m.gpus).sum();
                if spec.gpus == 0 || spec.gpus > total {
                    return (
                        MasterResponse::Err(format!(
                            "requested {} GPUs, cluster has {total}",
                            spec.gpus
                        )),
                        false,
                    );
                }
                let model = Dnn::by_name(&spec.model).unwrap_or(Dnn::ResNet50);
                let n_machines = self.machines.len();
                let submit_s = self.now_s();
                eprintln!("[master] submitted job {:?} ({} GPUs)", spec.name, spec.gpus);
                self.jobs.push(LiveJob {
                    spec,
                    model,
                    submit_s,
                    phase: Phase::Pending,
                    endpoint: None,
                    ctl: None,
                    handle: None,
                    ctl_addr: String::new(),
                    children: Vec::new(),
                    held: vec![0; n_machines],
                    busy: false,
                    status_ok: false,
                    last_step: 0,
                    peak_p: 0,
                    grow_ops: 0,
                    shrink_ops: 0,
                    attained_gpu_s: 0.0,
                });
                (MasterResponse::Submitted { job: self.jobs.len() as u64 - 1 }, false)
            }
            MasterRequest::Jobs => (MasterResponse::Jobs(self.job_infos()), false),
            MasterRequest::Shutdown => (MasterResponse::Ok, true),
        }
    }

    fn job_infos(&self) -> Vec<JobInfo> {
        self.jobs
            .iter()
            .map(|j| JobInfo {
                name: j.spec.name.clone(),
                phase: match j.phase {
                    Phase::Pending => "pending",
                    Phase::Running => "running",
                    Phase::Stopping => "stopping",
                    Phase::Finished => "finished",
                }
                .to_string(),
                requested_p: j.spec.gpus,
                parallelism: j.held_p(),
                step: j.last_step,
                peak_p: j.peak_p,
                grow_ops: j.grow_ops,
                shrink_ops: j.shrink_ops,
                ctl_addr: j.ctl_addr.clone(),
                machines: j
                    .held
                    .iter()
                    .enumerate()
                    .flat_map(|(m, &g)| {
                        std::iter::repeat(self.machines[m].name.clone()).take(g as usize)
                    })
                    .collect(),
            })
            .collect()
    }

    // -- the tick: poll jobs, refresh leases, run the policy ----------------

    fn tick(&mut self) {
        let now = self.now_s();
        let dt = (now - self.last_now).max(0.0);
        self.last_now = now;
        for ix in 0..self.jobs.len() {
            let held = self.jobs[ix].held_p();
            if held > 0 {
                self.jobs[ix].attained_gpu_s += held as f64 * dt;
            }
            if !matches!(self.jobs[ix].phase, Phase::Running) || self.jobs[ix].busy {
                continue;
            }
            // reap worker processes that exited gracefully (scale-in)
            self.jobs[ix].children.retain_mut(|c| !matches!(c.try_wait(), Ok(Some(_))));
            let Some(handle) = self.jobs[ix].handle.clone() else { continue };
            // short deadline: one wedged leader must not stall the sweep,
            // the lease refresh, or the policy tick for every other job
            match handle.call_with_timeout(Request::Status, Duration::from_secs(5)) {
                Response::Status(st) => {
                    let done = {
                        let j = &mut self.jobs[ix];
                        if st.step < j.last_step {
                            eprintln!(
                                "[master] WARNING job {:?} step went backwards: {} -> {}",
                                j.spec.name, j.last_step, st.step
                            );
                        }
                        j.last_step = j.last_step.max(st.step);
                        j.status_ok = true;
                        j.last_step >= j.spec.steps
                    };
                    if done {
                        self.begin_stop(ix);
                    }
                }
                _ => self.jobs[ix].status_ok = false,
            }
        }
        self.refresh_leases();
        // the policy tick: the SAME Scheduler objects the simulator runs
        let mut sched: Box<dyn Scheduler + Send> =
            std::mem::replace(&mut self.sched, Box::new(NoopScheduler));
        sched.replan(self);
        self.sched = sched;
        self.assert_inventory();
    }

    /// GPU-slot conservation (chaos-harness invariant): for every machine,
    /// free slots plus the slots every job holds must equal the machine's
    /// capacity — a violation means a Grow/Shrink/Stop path leaked or
    /// double-counted a slot. Loud failure beats silently shrinking the
    /// cluster: the master is the root of truth for the inventory.
    fn assert_inventory(&self) {
        for (m, spec) in self.machines.iter().enumerate() {
            let held: u32 = self.jobs.iter().map(|j| j.held[m]).sum();
            assert!(
                self.free[m] + held == spec.gpus,
                "inventory leak on {}: free {} + held {} != capacity {} \
                 (per-job held: {:?})",
                spec.name,
                self.free[m],
                held,
                spec.gpus,
                self.jobs.iter().map(|j| (j.spec.name.clone(), j.held[m])).collect::<Vec<_>>(),
            );
        }
    }

    fn lease_key(name: &str) -> String {
        format!("edl/jobs/{name}/ctl")
    }

    fn register_lease(&self, ix: usize) {
        let j = &self.jobs[ix];
        if j.ctl_addr.is_empty() {
            return;
        }
        self.kv.core().put(
            crate::util::now_ms() as u64,
            &Self::lease_key(&j.spec.name),
            j.ctl_addr.as_bytes(),
            Some(self.lease_ttl_ms),
        );
    }

    /// Per-tick lease sweep, batched: every running job's ctl lease goes
    /// to the KV in ONE framed round-trip (OP_BATCH over the loopback
    /// client — the exact path a remote etcd stand-in would see). Any
    /// connection trouble falls back to in-process puts against the
    /// embedded core, so a flaky loopback can never cost a lease.
    fn refresh_leases(&mut self) {
        let items: Vec<(String, Vec<u8>, u64)> = self
            .jobs
            .iter()
            .filter(|j| {
                matches!(j.phase, Phase::Running | Phase::Stopping) && !j.ctl_addr.is_empty()
            })
            .map(|j| {
                (Self::lease_key(&j.spec.name), j.ctl_addr.clone().into_bytes(), self.lease_ttl_ms)
            })
            .collect();
        if items.is_empty() {
            return;
        }
        if self.kv_client.is_none() {
            self.kv_client = KvClient::connect(&self.kv.addr).ok();
        }
        if let Some(kv) = self.kv_client.as_mut() {
            if kv.put_many(&items).is_ok() {
                return;
            }
            self.kv_client = None; // reconnect next tick
        }
        for (key, value, ttl) in &items {
            self.kv.core().put(crate::util::now_ms() as u64, key, value, Some(*ttl));
        }
    }

    // -- decision application ------------------------------------------------

    fn spawn_worker(
        &self,
        leader_addr: &str,
        machine: &str,
        spec: &SubmitSpec,
    ) -> std::io::Result<Child> {
        let args: Vec<String> = vec![
            "worker".into(),
            "--leader".into(),
            leader_addr.into(),
            "--machine".into(),
            machine.into(),
            "--backend".into(),
            "sim".into(),
            "--params".into(),
            spec.params.to_string(),
            "--compute-ms".into(),
            spec.compute_ms.to_string(),
            "--samples".into(),
            SIM_SAMPLES.to_string(),
            "--data-seed".into(),
            SIM_DATA_SEED.to_string(),
            "--lr".into(),
            format!("{SIM_LR}"),
        ];
        // the simulated cluster runs every "machine" on one host; stamping
        // the machine label as the worker's shm identity makes same-machine
        // workers negotiate shared-memory rings exactly as a real multi-node
        // deployment would (transport::machine_identity reads this first)
        Command::new(&self.worker_bin)
            .args(&args)
            .env("EDL_MACHINE_ID", machine)
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
    }

    /// `Start`: allocate slots, stand up the per-job leader + Table-1
    /// server, spawn founder worker processes, register the ctl lease.
    fn start_live_job(&mut self, ix: usize, p: u32) -> bool {
        if !matches!(self.jobs[ix].phase, Phase::Pending) {
            return false;
        }
        let Some(slots) = self.allocate(p) else { return false };
        let spec = self.jobs[ix].spec.clone();
        let backend = SimBackend {
            compute_ms: spec.compute_ms,
            ..SimBackend::fast(spec.params as usize)
        };
        let digest = config_digest(
            SIM_SAMPLES,
            SIM_DATA_SEED,
            backend.param_count(),
            backend.seq_len(),
            SIM_LR,
        );
        let cfg = TrainerConfig {
            agg_batch: SIM_AGG_BATCH,
            lr: SIM_LR,
            approx_recovery: true,
            failure_timeout: Duration::from_secs(20),
            ..Default::default()
        };
        let endpoint = match LeaderEndpoint::start(
            cfg,
            Arc::new(backend),
            SIM_SAMPLES,
            p as usize,
            "127.0.0.1:0",
            digest,
        ) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("[master] job {:?} leader failed to start: {e}", spec.name);
                self.release(&slots);
                return false;
            }
        };
        let ctl = match JobServer::start_on("127.0.0.1:0", endpoint.handle()) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("[master] job {:?} ctl server failed: {e}", spec.name);
                self.release(&slots);
                return false;
            }
        };
        let handle = endpoint.handle();
        let leader_addr = endpoint.addr.clone();
        let ctl_addr = ctl.addr.clone();
        let mut children = Vec::new();
        for &(m, g) in &slots {
            let machine = self.machines[m].name.clone();
            for _ in 0..g {
                match self.spawn_worker(&leader_addr, &machine, &spec) {
                    Ok(c) => children.push(c),
                    Err(e) => eprintln!(
                        "[master] job {:?} worker spawn on {machine} failed: {e}",
                        spec.name
                    ),
                }
            }
        }
        eprintln!(
            "[master] job {:?} started: p={p} ctl={ctl_addr} leader={leader_addr}",
            spec.name
        );
        {
            let j = &mut self.jobs[ix];
            for &(m, g) in &slots {
                j.held[m] += g;
            }
            j.endpoint = Some(endpoint);
            j.ctl = Some(ctl);
            j.handle = Some(handle);
            j.ctl_addr = ctl_addr;
            j.children = children;
            j.phase = Phase::Running;
            j.peak_p = p;
            j.status_ok = false;
        }
        self.register_lease(ix);
        true
    }

    /// `Grow`: reserve idle slots, spawn joiner processes into the
    /// leader's lobby, commit with ONE Table-1 `scale_out` (stop-free).
    fn grow_live(&mut self, ix: usize, to: u32) -> bool {
        let cur = self.jobs[ix].held_p();
        if !matches!(self.jobs[ix].phase, Phase::Running)
            || self.jobs[ix].busy
            || to <= cur
        {
            return false;
        }
        let Some(handle) = self.jobs[ix].handle.clone() else { return false };
        let Some(leader_addr) = self.jobs[ix].endpoint.as_ref().map(|e| e.addr.clone()) else {
            return false;
        };
        let Some(slots) = self.allocate(to - cur) else { return false };
        let spec = self.jobs[ix].spec.clone();
        let child_from = self.jobs[ix].children.len();
        // only slots whose joiner PROCESS actually spawned take part in
        // the scale-out; a failed fork must not make the leader wait for
        // a worker that will never connect
        let mut labels: Vec<String> = Vec::new();
        let mut used: Vec<u32> = vec![0; self.machines.len()];
        for &(m, g) in &slots {
            let machine = self.machines[m].name.clone();
            for _ in 0..g {
                match self.spawn_worker(&leader_addr, &machine, &spec) {
                    Ok(c) => {
                        self.jobs[ix].children.push(c);
                        labels.push(machine.clone());
                        used[m] += 1;
                    }
                    Err(e) => eprintln!(
                        "[master] job {:?} joiner spawn on {machine} failed: {e}",
                        spec.name
                    ),
                }
            }
        }
        // give back the slots that never got a worker process
        let unused: Vec<(usize, u32)> = slots
            .iter()
            .filter(|&&(m, g)| g > used[m])
            .map(|&(m, g)| (m, g - used[m]))
            .collect();
        self.release(&unused);
        if labels.is_empty() {
            return false;
        }
        let reserved: Vec<(usize, u32)> = used
            .iter()
            .enumerate()
            .filter(|&(_, &g)| g > 0)
            .map(|(m, &g)| (m, g))
            .collect();
        for &(m, g) in &reserved {
            self.jobs[ix].held[m] += g;
        }
        self.jobs[ix].busy = true;
        let tx = self.tx.clone();
        std::thread::spawn(move || {
            let mut h = handle;
            let r = ElasticTiresias::expand_job(&mut h, labels);
            let ok = r.is_ok();
            let err = r.err().map(|e| e.to_string()).unwrap_or_default();
            let _ = tx.send(MIn::Done(OpDone {
                job: ix,
                op: Op::Grow,
                ok,
                freed: Vec::new(),
                removed: 0,
                undo: reserved,
                child_from,
                err,
            }));
        });
        true
    }

    /// `Shrink`: graceful scale-in of the newest workers; their machine
    /// labels (from Table-1 `status`) say which slots come back.
    fn shrink_live(&mut self, ix: usize, to: u32) -> bool {
        let cur = self.jobs[ix].held_p();
        if !matches!(self.jobs[ix].phase, Phase::Running)
            || self.jobs[ix].busy
            || to == 0
            || to >= cur
        {
            return false;
        }
        let Some(handle) = self.jobs[ix].handle.clone() else { return false };
        let n = (cur - to) as usize;
        self.jobs[ix].busy = true;
        let tx = self.tx.clone();
        std::thread::spawn(move || {
            let mut h = handle;
            let (ok, freed, err) = match h.status() {
                Ok(st) if st.workers.len() > n => {
                    let k = st.workers.len() - n;
                    let victims = st.workers[k..].to_vec();
                    let freed: Vec<String> =
                        st.worker_machines.get(k..).map(|s| s.to_vec()).unwrap_or_default();
                    match h.scale_in_retry(victims, Duration::from_secs(30)) {
                        Ok(()) => (true, freed, String::new()),
                        Err(e) => (false, Vec::new(), e.to_string()),
                    }
                }
                Ok(_) => (false, Vec::new(), "shrink would remove every worker".into()),
                Err(e) => (false, Vec::new(), e.to_string()),
            };
            let _ = tx.send(MIn::Done(OpDone {
                job: ix,
                op: Op::Shrink,
                ok,
                freed,
                removed: n,
                undo: Vec::new(),
                child_from: usize::MAX,
                err,
            }));
        });
        true
    }

    /// The job reached its step target: graceful Table-1 `stop`.
    fn begin_stop(&mut self, ix: usize) {
        let Some(handle) = self.jobs[ix].handle.clone() else { return };
        self.jobs[ix].busy = true;
        self.jobs[ix].phase = Phase::Stopping;
        eprintln!(
            "[master] job {:?} reached step {} — stopping",
            self.jobs[ix].spec.name, self.jobs[ix].last_step
        );
        let tx = self.tx.clone();
        std::thread::spawn(move || {
            let resp = handle.call(Request::Stop);
            let ok = matches!(resp, Response::Ok);
            let err = if ok { String::new() } else { format!("{resp:?}") };
            let _ = tx.send(MIn::Done(OpDone {
                job: ix,
                op: Op::Stop,
                ok,
                freed: Vec::new(),
                removed: 0,
                undo: Vec::new(),
                child_from: usize::MAX,
                err,
            }));
        });
    }

    fn finish_op(&mut self, done: OpDone) {
        let OpDone { job, op, ok, freed, removed, undo, child_from, err } = done;
        self.jobs[job].busy = false;
        let name = self.jobs[job].spec.name.clone();
        match op {
            Op::Grow => {
                if ok {
                    let held = self.jobs[job].held_p();
                    self.jobs[job].grow_ops += 1;
                    self.jobs[job].peak_p = self.jobs[job].peak_p.max(held);
                    eprintln!("[master] job {name:?} grew to {held} GPUs (stop-free)");
                } else {
                    for &(m, g) in &undo {
                        self.free[m] += g;
                        self.jobs[job].held[m] = self.jobs[job].held[m].saturating_sub(g);
                    }
                    if child_from < self.jobs[job].children.len() {
                        let mut tail = self.jobs[job].children.split_off(child_from);
                        for c in &mut tail {
                            let _ = c.kill();
                            let _ = c.wait();
                        }
                    }
                    eprintln!("[master] job {name:?} grow failed: {err}");
                }
            }
            Op::Shrink => {
                if ok {
                    let mut returned = 0usize;
                    for label in &freed {
                        if let Some(m) = self.machine_ix(label) {
                            if self.jobs[job].held[m] > 0 {
                                self.free[m] += 1;
                                self.jobs[job].held[m] -= 1;
                                returned += 1;
                            }
                        }
                    }
                    // the scale-in committed `removed` workers: if some
                    // labels were missing/unresolvable, reconcile against
                    // the count so the inventory never leaks slots
                    while returned < removed {
                        let Some(m) = (0..self.machines.len())
                            .find(|&m| self.jobs[job].held[m] > 0)
                        else {
                            break;
                        };
                        self.free[m] += 1;
                        self.jobs[job].held[m] -= 1;
                        returned += 1;
                    }
                    self.jobs[job].shrink_ops += 1;
                    eprintln!(
                        "[master] job {name:?} shrank to {} GPUs (graceful)",
                        self.jobs[job].held_p()
                    );
                } else {
                    eprintln!("[master] job {name:?} shrink failed: {err}");
                }
            }
            Op::Stop => {
                if !ok {
                    eprintln!("[master] job {name:?} stop reported: {err}");
                }
                self.complete_job(job);
            }
        }
    }

    /// Tear one job down: return its slots, reap its processes, join the
    /// per-job leader + ctl server, drop the KV lease.
    fn complete_job(&mut self, ix: usize) {
        let held: Vec<(usize, u32)> = self.jobs[ix]
            .held
            .iter()
            .enumerate()
            .filter(|&(_, &g)| g > 0)
            .map(|(m, &g)| (m, g))
            .collect();
        self.release(&held);
        for g in self.jobs[ix].held.iter_mut() {
            *g = 0;
        }
        let mut children = std::mem::take(&mut self.jobs[ix].children);
        for c in &mut children {
            let _ = c.kill();
            let _ = c.wait();
        }
        self.jobs[ix].handle = None;
        if let Some(server) = self.jobs[ix].ctl.take() {
            let _ = server.shutdown();
        }
        if let Some(endpoint) = self.jobs[ix].endpoint.take() {
            let _ = endpoint.join();
        }
        self.kv.core().delete(&Self::lease_key(&self.jobs[ix].spec.name));
        self.jobs[ix].phase = Phase::Finished;
        eprintln!(
            "[master] job {:?} finished at step {}",
            self.jobs[ix].spec.name, self.jobs[ix].last_step
        );
    }

    fn teardown(&mut self) {
        for ix in 0..self.jobs.len() {
            if matches!(self.jobs[ix].phase, Phase::Running | Phase::Stopping) {
                if let Some(handle) = self.jobs[ix].handle.clone() {
                    let _ = handle.call(Request::Stop);
                }
                self.complete_job(ix);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// the master as a scheduling engine
// ---------------------------------------------------------------------------

impl ClusterView for Shell {
    fn now_s(&self) -> f64 {
        Shell::now_s(self)
    }
    fn n_machines(&self) -> usize {
        self.machines.len()
    }
    fn gpus_per_machine(&self) -> u32 {
        self.hw.gpus_per_machine
    }
    fn total_gpus(&self) -> u32 {
        self.machines.iter().map(|m| m.gpus).sum()
    }
    fn free_gpus(&self) -> u32 {
        self.free.iter().sum()
    }
    fn max_p_norm(&self) -> u32 {
        64
    }
    fn n_jobs(&self) -> usize {
        self.jobs.len()
    }
    fn job_view(&self, job: usize) -> JobView {
        let j = &self.jobs[job];
        let running = matches!(j.phase, Phase::Running);
        JobView {
            id: job as u64,
            model: j.model,
            requested_p: j.spec.gpus,
            current_p: if running { j.held_p() } else { 0 },
            global_batch: SIM_AGG_BATCH,
            submitted: true,
            pending: matches!(j.phase, Phase::Pending),
            running,
            // stopping jobs are out of the policy's hands
            finished: matches!(j.phase, Phase::Stopping | Phase::Finished),
            adjustable: running && !j.busy && j.status_ok && j.last_step >= 1,
            elastic: j.spec.elastic,
            submit_s: j.submit_s,
            attained_gpu_s: j.attained_gpu_s,
        }
    }
    fn predicted_throughput(&self, job: usize, p: u32) -> f64 {
        gpu_sim::throughput(self.jobs[job].model, p, SIM_AGG_BATCH, &self.hw)
    }
    fn predicted_efficiency(&self, job: usize, p: u32, max_p: u32) -> f64 {
        gpu_sim::efficiency(self.jobs[job].model, p, SIM_AGG_BATCH, max_p, &self.hw)
    }
}

impl ClusterCtl for Shell {
    fn submit(&mut self, d: Decision) -> bool {
        match d {
            Decision::Start { job, p } => self.start_live_job(job, p),
            Decision::Grow { job, to } => self.grow_live(job, to),
            Decision::Shrink { job, to } => self.shrink_live(job, to),
            // the live master NEVER restarts a job; checkpoint/restart
            // scheduling is the simulator-only baseline
            Decision::Preempt { .. } | Decision::Migrate { .. } => false,
        }
    }
}
