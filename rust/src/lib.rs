//! # EDL — Elastic Deep Learning in Multi-Tenant GPU Clusters
//!
//! A from-scratch reproduction of the EDL system (Wu et al., 2019) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the elastic coordination layer: ONE versioned
//!   Table-1 job-control surface ([`api`]: the `JobControl` trait served
//!   in-process, over TCP via `api::JobServer`/`JobClient`, and inside
//!   the simulator), leader election over a CAS/lease KV service
//!   ([`coordsvc`]), stop-free scale-out and graceful-exit scale-in as a
//!   pure, clock-injected state machine ([`coordinator`]'s `LeaderCore`)
//!   driven in-process, by the multi-process TCP deployment ([`deploy`]
//!   over [`rpc`] frames), and by a virtual-clock replay harness, an
//!   elastic ring-allreduce data plane
//!   ([`allreduce`] over [`transport`]), the dynamic data pipeline
//!   ([`data`]), plus the GPU-cluster simulation substrate the paper's
//!   evaluation needs: a calibrated device model ([`gpu_sim`]), a
//!   Philly-like trace generator ([`trace`]), and cluster scheduling as
//!   a policy/engine split ([`sched`]): the Tiresias / Elastic-Tiresias
//!   policies ([`schedulers`]) emit typed `Decision`s against an abstract
//!   `ClusterView`, applied by TWO engines — the discrete-event simulator
//!   ([`cluster`]) and the live multi-job cluster daemon ([`master`]),
//!   which runs one leader + worker OS processes per job and maps every
//!   decision onto the Table-1 surface ([`api`]).
//! * **L2** — a JAX transformer LM lowered once to HLO text
//!   (`python/compile/model.py`), executed from Rust via PJRT
//!   ([`runtime`]).
//! * **L1** — Pallas kernels for the compute hot-spots
//!   (`python/compile/kernels/`), inlined into the same HLO artifacts.
//!
//! Python is build-time only; the Rust binary is self-contained once
//! `make artifacts` has run. See DESIGN.md for the paper→repo map and
//! EXPERIMENTS.md for reproduced tables/figures.

pub mod allreduce;
pub mod api;
pub mod cluster;
pub mod coordinator;
pub mod coordsvc;
pub mod data;
pub mod deploy;
pub mod gpu_sim;
pub mod harness;
pub mod master;
pub mod metrics;
pub mod rpc;
pub mod runtime;
pub mod sched;
pub mod schedulers;
pub mod trace;
pub mod transport;
pub mod util;
pub mod verify;
pub mod wire;
pub mod worker;
