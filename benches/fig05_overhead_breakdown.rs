//! Fig 5 — decomposition of the scale-out overhead per DNN model: the
//! execution-context-preparation share (gray in the paper) vs topology
//! construction vs model preparation, plus the stop-resume total it
//! implies (40–80+ s, growing with parallelism).
//!
//! Also reports the REAL context-preparation breakdown measured on the
//! CPU substrate (PJRT client + HLO parse + compile per artifact), which
//! is the same phenomenon on this hardware.

use edl::gpu_sim::{scale_out_breakdown, stop_resume_overhead, ALL_DNNS};
use edl::runtime::{artifacts_dir, ModelMeta, Runtime};
use edl::util::json::{write_results, Json};

fn main() {
    println!("== Fig 5: scale-out overhead decomposition (1 joiner, p=2..8) ==");
    println!(
        "{:<12} {:>6} {:>12} {:>10} {:>10} {:>10} {:>12}",
        "model", "p", "ctx-prep", "topology", "model-prep", "total", "stop-resume"
    );
    let mut out = Json::obj();
    for d in ALL_DNNS {
        let mut rows = Json::Arr(vec![]);
        for p in [2u32, 4, 8] {
            let b = scale_out_breakdown(d, p);
            let sr = stop_resume_overhead(d, p);
            println!(
                "{:<12} {:>6} {:>11.1}s {:>9.2}s {:>9.2}s {:>9.1}s {:>11.1}s",
                d.spec().name,
                p,
                b.context_prep_s,
                b.topology_s,
                b.model_prep_s,
                b.total(),
                sr
            );
            assert!(
                b.context_prep_s > 0.8 * b.total(),
                "context prep must dominate (the Fig 5 observation)"
            );
            let mut r = Json::obj();
            r.set("p", p)
                .set("context_prep_s", b.context_prep_s)
                .set("topology_s", b.topology_s)
                .set("model_prep_s", b.model_prep_s)
                .set("stop_resume_s", sr);
            rows.push(r);
        }
        out.set(d.spec().name, rows);
    }

    // stop-resume grows with parallelism (§2.2 footnote: sequential init)
    for d in ALL_DNNS {
        assert!(stop_resume_overhead(d, 8) > stop_resume_overhead(d, 1));
    }

    // real CPU-substrate measurement: per-artifact parse+compile times
    if ModelMeta::load(artifacts_dir(), "tiny").is_ok() {
        println!("\n== measured context preparation on the CPU substrate (tiny) ==");
        let rt = Runtime::open(artifacts_dir(), "tiny").unwrap();
        let mut meas = Json::Arr(vec![]);
        for name in ["tiny_init", "tiny_grad_b8", "tiny_apply"] {
            let (_exe, t) = rt.load_with_timing(name).unwrap();
            println!("  {name:<16} parse {:>7.1}ms  compile {:>8.1}ms", t.parse_s * 1e3, t.compile_s * 1e3);
            let mut r = Json::obj();
            r.set("artifact", name).set("parse_s", t.parse_s).set("compile_s", t.compile_s);
            meas.push(r);
        }
        out.set("measured_cpu_substrate", meas);
    } else {
        println!("\n(artifacts not built; skipping measured breakdown)");
    }

    let path = write_results("fig05_overhead_breakdown", &out).unwrap();
    println!("\nshape checks OK; results -> {}", path.display());
}
