//! The EDL coordination layer (the paper's contribution, §3–§4): a leader
//! that manages an elastic set of training workers with
//!
//!  * **stop-free scale-out** — joiners prepare their execution context
//!    while training continues; the switch happens at a *future
//!    mini-batch timestamp* `t_cur + k` (k sized from a 500 ms allowance,
//!    §4.2) and one existing worker broadcasts the model;
//!  * **graceful-exit scale-in** — leavers hand their unprocessed data
//!    back at the agreed boundary; remaining workers never stop;
//!  * **merged migration** — scale-in + scale-out with ONE topology switch;
//!  * **straggler mitigation** — per-worker step times arrive with every
//!    gradient-sync request; consistent laggards are scaled in (§5.2);
//!  * **failure recovery** — approximate (drop the dead worker, repair the
//!    ring, redo the mini-batch) or consistent (restore from checkpoint),
//!    selected via [`TrainerConfig::approx_recovery`] (§4.2; the paper's
//!    `USE_APPX_RECOVERY` env switch is resolved once at config
//!    construction, see [`TrainerConfig::approx_recovery_from_env`]);
//!  * **dynamic data pipeline** — the leader owns the partition permutation
//!    and hands shards out on demand (§4.3, see `data::Assigner`).
//!
//! The leader here runs as a dedicated coordination thread (the §4.1
//! "application master" alternative the paper discusses; worker-attached
//! leadership and re-election are exercised against `coordsvc` in its own
//! tests and benches, since in-process threads share fate anyway).
//!
//! Scheduler-facing control goes exclusively through the Table-1 surface
//! in [`crate::api`]: [`ElasticTrainer`] implements
//! [`JobControl`](crate::api::JobControl) natively (the leader consumes
//! [`api::Request`](crate::api::Request) values straight off its command
//! channel), and `api::JobServer` exposes the same surface over TCP.

use crate::api::{ElasticError, JobControl, JobStatus, ProfileRow, Request, Response};
use crate::data::corpus::Corpus;
use crate::data::{Assigner, PartitionMeta, PartitionTable};
use crate::transport::{InProcHub, NodeId};
use crate::util::now_ms;
use crate::wire::{Dec, Enc};
use crate::worker::{worker_loop, Backend, WorkerCtx, WorkerKnobs};
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// control-plane messages (typed channels; the TCP wire forms live in `rpc`)
// ---------------------------------------------------------------------------

/// worker → leader events
#[derive(Debug)]
pub enum WorkerEvent {
    /// plumbing: the spawner attaches the worker's control mailbox
    Attach { id: NodeId, machine: String, ctrl: Sender<CtrlMsg>, knobs: Arc<WorkerKnobs>, joiner: bool },
    Register { id: NodeId, machine: String },
    Ready { id: NodeId },
    Sync { id: NodeId, step: u64, loss: f32, weight: f32, step_ms: f64, shard: Option<(u64, u64)> },
    NeedPartition { id: NodeId },
    ShardDone { id: NodeId },
    Goodbye { id: NodeId, shard: Option<(u64, u64)> },
    Params { id: NodeId, step: u64, params: Vec<f32> },
}

/// leader → worker control messages
#[derive(Debug, Clone)]
pub enum CtrlMsg {
    /// `joiners` is the broadcast-tree rank order (empty for founders):
    /// every joiner must receive the model with the same peer list so the
    /// binomial relay tree agrees on shape (see `allreduce::broadcast_recv`)
    Ok {
        join_at_step: u64,
        ring: Arc<Vec<NodeId>>,
        local_batch: u32,
        broadcast_src: NodeId,
        joiners: Arc<Vec<NodeId>>,
    },
    Assign { meta: PartitionMeta },
    NoData,
    SyncGo { ring: Arc<Vec<NodeId>>, sync_tag: u64, switch: Option<SwitchPlan> },
    SendParams,
    Restore { params: Arc<Vec<f32>>, at_step: u64 },
    Stop,
}

/// A committed topology switch (§4.2): executed by every worker at the end
/// of mini-batch `at_step − 1`.
#[derive(Debug, Clone)]
pub struct SwitchPlan {
    pub at_step: u64,
    pub ring: Arc<Vec<NodeId>>,
    pub local_batch: u32,
    pub broadcast_src: NodeId,
    pub joiners: Vec<NodeId>,
    pub exiting: Vec<NodeId>,
}

/// One entry of the training log.
#[derive(Debug, Clone)]
pub struct LossPoint {
    pub step: u64,
    pub loss: f32,
    pub parallelism: u32,
    pub wall_ms: f64,
}

/// Timeline events for experiment post-processing.
#[derive(Debug, Clone)]
pub struct EngineEvent {
    pub wall_ms: f64,
    pub step: u64,
    pub what: String,
}

/// Final report returned by [`ElasticTrainer::stop`].
#[derive(Debug, Default)]
pub struct TrainReport {
    pub loss_history: Vec<LossPoint>,
    pub events: Vec<EngineEvent>,
    pub steps: u64,
    pub epochs: u64,
}

// ---------------------------------------------------------------------------
// configuration
// ---------------------------------------------------------------------------

#[derive(Clone)]
pub struct TrainerConfig {
    /// aggregate batch size, constant under scaling (§3.1)
    pub agg_batch: u32,
    pub lr: f32,
    pub n_partitions: u64,
    pub seed: u64,
    /// timestamp allowance T_a (ms) for scheduling switches (§4.2)
    pub switch_allowance_ms: f64,
    /// barrier timeout before a silent worker is declared dead
    pub failure_timeout: Duration,
    /// automatic straggler scale-in (§5.2)
    pub straggler_mitigation: bool,
    /// straggler threshold: step time > `ratio` × group median ...
    pub straggler_ratio: f64,
    /// ... for `window` consecutive mini-batches
    pub straggler_window: u32,
    /// approximate (true) vs consistent (false) failure recovery (§4.2;
    /// paper default: consistent). The trainer only ever reads this
    /// explicit flag — CLI entrypoints that want the paper's
    /// `USE_APPX_RECOVERY` env switch resolve it ONCE at config
    /// construction via [`TrainerConfig::approx_recovery_from_env`].
    pub approx_recovery: bool,
    /// checkpoint file used by consistent recovery
    pub checkpoint_path: Option<PathBuf>,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            agg_batch: 32,
            lr: 0.1,
            n_partitions: 64,
            seed: 7,
            switch_allowance_ms: 500.0,
            failure_timeout: Duration::from_secs(30),
            straggler_mitigation: false,
            straggler_ratio: 1.2,
            straggler_window: 10,
            approx_recovery: false,
            checkpoint_path: None,
        }
    }
}

impl TrainerConfig {
    /// Resolve the paper's `USE_APPX_RECOVERY` environment switch. Called
    /// by CLI/config construction only — never by the trainer itself, so
    /// tests and libraries are independent of process-global state.
    pub fn approx_recovery_from_env() -> bool {
        std::env::var("USE_APPX_RECOVERY").map(|v| v == "1" || v == "true").unwrap_or(false)
    }
}

// ---------------------------------------------------------------------------
// leader
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum WState {
    Joining { ready: bool },
    Active,
}

struct WInfo {
    ctrl: Sender<CtrlMsg>,
    #[allow(dead_code)] // recorded for operator visibility / future placement logic
    machine: String,
    #[allow(dead_code)]
    knobs: Arc<WorkerKnobs>,
    state: WState,
    step_times: std::collections::VecDeque<f64>,
    straggle_hits: u32,
}

struct SyncInfo {
    loss: f32,
    weight: f32,
    #[allow(dead_code)] // per-step time also lands in WInfo::step_times
    step_ms: f64,
}

enum LeaderIn {
    W(WorkerEvent),
    /// a Table-1 request with its reply slot — the same `api::Request`
    /// values the TCP deployment decodes off the wire
    C(Request, Sender<Response>),
}

/// Spawns a worker thread; must send `WorkerEvent::Attach` before the
/// worker's own `Register`.
type Spawner = Arc<dyn Fn(NodeId, String, bool) + Send + Sync>;

struct Leader {
    cfg: TrainerConfig,
    backend: Arc<dyn Backend>,
    rx: Receiver<LeaderIn>,
    spawner: Spawner,
    /// founding-worker count: the job must not start before ALL founders
    /// have attached AND prepared (on a loaded host a founder's thread can
    /// lag arbitrarily behind its siblings)
    expected_founders: usize,
    workers: BTreeMap<NodeId, WInfo>,
    active: Vec<NodeId>,
    ring: Arc<Vec<NodeId>>,
    ring_version: u64,
    step: u64,
    started: bool,
    assigner: Assigner,
    sync_waiting: HashMap<NodeId, SyncInfo>,
    barrier_open_at: Option<Instant>,
    plan: Option<SwitchPlan>,
    op_reply: Option<Sender<Response>>,
    /// pending scale-out joiners not yet Ready
    joining: Vec<NodeId>,
    /// exit set for a migrate/scale-in combined op
    op_exiting: Vec<NodeId>,
    ckpt_reply: Option<(PathBuf, Sender<Response>)>,
    stop_reply: Option<Sender<Response>>,
    report: TrainReport,
    recent_barriers: std::collections::VecDeque<(Instant, f64)>,
    last_loss: f32,
    stopping: bool,
}

impl Leader {
    fn local_batch_for(&self, p: u32) -> u32 {
        let want = (self.cfg.agg_batch / p.max(1)).max(1);
        self.backend.pick_batch(want).unwrap_or(1)
    }

    /// k = ceil(T_a / T_b), clamped (§4.2)
    fn switch_k(&self) -> u64 {
        let avg_step_ms = if self.recent_barriers.len() >= 2 {
            let dts: Vec<f64> = self
                .recent_barriers
                .iter()
                .zip(self.recent_barriers.iter().skip(1))
                .map(|((a, _), (b, _))| (*b - *a).as_secs_f64() * 1e3)
                .collect();
            crate::util::stats::median(&dts).max(0.1)
        } else {
            100.0
        };
        ((self.cfg.switch_allowance_ms / avg_step_ms).ceil() as u64).clamp(1, 64)
    }

    fn event(&mut self, what: String) {
        self.report.events.push(EngineEvent { wall_ms: now_ms(), step: self.step, what });
    }

    fn throughput_sps(&self) -> f64 {
        if self.recent_barriers.len() < 2 {
            return 0.0;
        }
        let (t0, _) = self.recent_barriers.front().unwrap();
        let (t1, _) = self.recent_barriers.back().unwrap();
        let samples: f64 = self.recent_barriers.iter().skip(1).map(|&(_, w)| w as f64).sum();
        let dt = (*t1 - *t0).as_secs_f64();
        if dt <= 0.0 {
            0.0
        } else {
            samples / dt
        }
    }

    fn send_ctrl(&self, id: NodeId, msg: CtrlMsg) {
        if let Some(w) = self.workers.get(&id) {
            let _ = w.ctrl.send(msg);
        }
    }

    fn maybe_start_job(&mut self) {
        if self.started {
            return;
        }
        let founders: Vec<NodeId> = self.workers.keys().copied().collect();
        if founders.len() < self.expected_founders
            || !founders.iter().all(|id| {
                matches!(self.workers[id].state, WState::Joining { ready: true })
            })
        {
            return;
        }
        self.active = founders.clone();
        self.ring = Arc::new(founders.clone());
        let lb = self.local_batch_for(self.active.len() as u32);
        for id in founders {
            self.workers.get_mut(&id).unwrap().state = WState::Active;
            self.send_ctrl(
                id,
                CtrlMsg::Ok {
                    join_at_step: 0,
                    ring: self.ring.clone(),
                    local_batch: lb,
                    broadcast_src: 0,
                    joiners: Arc::new(Vec::new()),
                },
            );
        }
        self.started = true;
        self.event(format!("job-start p={}", self.active.len()));
    }

    /// all current joiners ready → schedule the switch (stop-free commit)
    fn maybe_commit_scale(&mut self) {
        if self.joining.is_empty() && self.op_exiting.is_empty() {
            return;
        }
        let all_ready = self
            .joining
            .iter()
            .all(|id| matches!(self.workers[id].state, WState::Joining { ready: true }));
        if !all_ready {
            return;
        }
        let at_step = self.step + self.switch_k();
        let mut new_ring: Vec<NodeId> =
            self.active.iter().copied().filter(|id| !self.op_exiting.contains(id)).collect();
        new_ring.extend(self.joining.iter().copied());
        assert!(!new_ring.is_empty(), "scale-in would remove every worker");
        let lb = self.local_batch_for(new_ring.len() as u32);
        let broadcast_src = *self
            .active
            .iter()
            .find(|id| !self.op_exiting.contains(id))
            .expect("need one surviving worker to broadcast");
        let plan = SwitchPlan {
            at_step,
            ring: Arc::new(new_ring),
            local_batch: lb,
            broadcast_src,
            joiners: self.joining.clone(),
            exiting: self.op_exiting.clone(),
        };
        let joiners = Arc::new(plan.joiners.clone());
        for &j in &self.joining {
            self.send_ctrl(
                j,
                CtrlMsg::Ok {
                    join_at_step: at_step,
                    ring: plan.ring.clone(),
                    local_batch: lb,
                    broadcast_src,
                    joiners: joiners.clone(),
                },
            );
        }
        self.event(format!(
            "switch-scheduled at_step={at_step} +{} -{} p_new={}",
            plan.joiners.len(),
            plan.exiting.len(),
            plan.ring.len()
        ));
        self.plan = Some(plan);
    }

    /// barrier complete for `self.step`: reply SyncGo to all active
    fn complete_barrier(&mut self) {
        let wsum: f32 = self.sync_waiting.values().map(|s| s.weight).sum();
        if wsum > 0.0 {
            let loss: f32 =
                self.sync_waiting.values().map(|s| s.loss * s.weight).sum::<f32>() / wsum;
            self.last_loss = loss;
            self.report.loss_history.push(LossPoint {
                step: self.step,
                loss,
                parallelism: self.active.len() as u32,
                wall_ms: now_ms(),
            });
        }
        // straggler statistics (§5.2)
        if self.cfg.straggler_mitigation && self.active.len() > 1 {
            self.update_stragglers();
        }
        self.recent_barriers.push_back((Instant::now(), wsum as f64));
        while self.recent_barriers.len() > 32 {
            self.recent_barriers.pop_front();
        }

        let sync_tag = (self.ring_version << 24) | (self.step & 0xFF_FFFF);
        let plan = self.plan.clone().filter(|p| p.at_step > self.step);
        for id in self.active.clone() {
            self.send_ctrl(
                id,
                CtrlMsg::SyncGo { ring: self.ring.clone(), sync_tag, switch: plan.clone() },
            );
        }
        self.sync_waiting.clear();
        self.barrier_open_at = None;
        self.step += 1;

        // commit the switch when the boundary is reached
        if let Some(plan) = self.plan.clone() {
            if self.step == plan.at_step {
                for id in &plan.exiting {
                    // Goodbye handles assigner return; drop from active below
                    let _ = id;
                }
                self.active = (*plan.ring).clone();
                self.ring = plan.ring.clone();
                self.ring_version += 1;
                for id in &plan.joiners {
                    if let Some(w) = self.workers.get_mut(id) {
                        w.state = WState::Active;
                    }
                }
                self.joining.clear();
                self.op_exiting.clear();
                self.plan = None;
                self.event(format!("switch-committed p={}", self.active.len()));
                if let Some(r) = self.op_reply.take() {
                    let _ = r.send(Response::Ok);
                }
            }
        }
    }

    fn update_stragglers(&mut self) {
        let mut medians: Vec<(NodeId, f64)> = Vec::new();
        for (&id, w) in &self.workers {
            if w.state == WState::Active && !w.step_times.is_empty() {
                let v: Vec<f64> = w.step_times.iter().copied().collect();
                medians.push((id, crate::util::stats::median(&v)));
            }
        }
        if medians.len() < 2 {
            return;
        }
        let all: Vec<f64> = medians.iter().map(|&(_, m)| m).collect();
        let group_median = crate::util::stats::median(&all);
        let mut victim = None;
        for &(id, m) in &medians {
            let w = self.workers.get_mut(&id).unwrap();
            if m > self.cfg.straggler_ratio * group_median
                && w.step_times.len() >= self.cfg.straggler_window as usize
            {
                w.straggle_hits += 1;
                if w.straggle_hits >= self.cfg.straggler_window {
                    victim = Some(id);
                }
            } else {
                w.straggle_hits = 0;
            }
        }
        if let Some(id) = victim {
            if self.plan.is_none() && self.joining.is_empty() && self.active.len() > 1 {
                self.event(format!("straggler-detected worker={id}"));
                self.op_exiting = vec![id];
                self.workers.get_mut(&id).unwrap().straggle_hits = 0;
                self.maybe_commit_scale();
            }
        }
    }

    /// detect dead workers at the barrier (§4.2 forced exit)
    fn check_failures(&mut self) {
        let Some(opened) = self.barrier_open_at else { return };
        if opened.elapsed() < self.cfg.failure_timeout {
            return;
        }
        let dead: Vec<NodeId> = self
            .active
            .iter()
            .copied()
            .filter(|id| !self.sync_waiting.contains_key(id))
            .collect();
        if dead.is_empty() || dead.len() >= self.active.len() {
            return;
        }
        self.event(format!("failure-detected dead={dead:?} step={}", self.step));
        for &d in &dead {
            self.assigner.worker_left(d);
            self.workers.remove(&d);
        }
        self.active.retain(|id| !dead.contains(id));
        self.ring = Arc::new(self.active.clone());
        self.ring_version += 1;
        // drop any in-flight plan that references dead workers
        if let Some(p) = &self.plan {
            if p.joiners.iter().chain(p.exiting.iter()).any(|id| dead.contains(id))
                || dead.contains(&p.broadcast_src)
            {
                self.plan = None;
                self.joining.clear();
                self.op_exiting.clear();
                if let Some(r) = self.op_reply.take() {
                    let _ = r.send(Response::Err(ElasticError::Aborted(
                        "worker failed mid-operation".into(),
                    )));
                }
            }
        }

        if !self.cfg.approx_recovery {
            if let Some(path) = self.cfg.checkpoint_path.clone() {
                if path.exists() {
                    if let Ok((at_step, params, asg)) = read_checkpoint(&path, self.cfg.seed) {
                        self.event(format!("consistent-recovery restore step={at_step}"));
                        self.assigner = asg;
                        self.assigner.reset_in_flight();
                        let params = Arc::new(params);
                        self.sync_waiting.clear();
                        self.barrier_open_at = None;
                        self.step = at_step;
                        for id in self.active.clone() {
                            self.send_ctrl(id, CtrlMsg::Restore { params: params.clone(), at_step });
                        }
                        return;
                    }
                }
            }
            self.event("consistent-recovery unavailable; falling back to approximate".into());
        }
        // approximate recovery: survivors redo the current mini-batch's
        // allreduce on the repaired ring — reply to those already waiting
        let sync_tag = (self.ring_version << 24) | (self.step & 0xFF_FFFF);
        for (&id, _) in self.sync_waiting.iter() {
            if let Some(w) = self.workers.get(&id) {
                let _ = w
                    .ctrl
                    .send(CtrlMsg::SyncGo { ring: self.ring.clone(), sync_tag, switch: None });
            }
        }
        // NOTE: waiting entries stay; stragglers of this step will re-Sync
        // and the barrier completes normally on the repaired active set.
        let survivors: Vec<NodeId> = self.sync_waiting.keys().copied().collect();
        if survivors.len() == self.active.len() {
            self.complete_barrier();
        }
    }

    fn handle_worker(&mut self, ev: WorkerEvent) {
        match ev {
            WorkerEvent::Attach { id, machine, ctrl, knobs, joiner } => {
                self.workers.insert(
                    id,
                    WInfo {
                        ctrl,
                        machine,
                        knobs,
                        state: WState::Joining { ready: false },
                        step_times: Default::default(),
                        straggle_hits: 0,
                    },
                );
                if joiner {
                    self.joining.push(id);
                }
            }
            WorkerEvent::Register { .. } => {}
            WorkerEvent::Ready { id } => {
                if let Some(w) = self.workers.get_mut(&id) {
                    w.state = WState::Joining { ready: true };
                }
                if self.started {
                    self.maybe_commit_scale();
                } else {
                    self.maybe_start_job();
                }
            }
            WorkerEvent::Sync { id, step, loss, weight, step_ms, shard } => {
                if step != self.step || !self.active.contains(&id) {
                    // stale sync from a worker that was mid-recovery
                    return;
                }
                if let Some((_pid, used)) = shard {
                    self.assigner.report_progress(id, used);
                }
                if let Some(w) = self.workers.get_mut(&id) {
                    w.step_times.push_back(step_ms);
                    while w.step_times.len() > self.cfg.straggler_window as usize {
                        w.step_times.pop_front();
                    }
                }
                if self.sync_waiting.is_empty() {
                    self.barrier_open_at = Some(Instant::now());
                }
                self.sync_waiting.insert(id, SyncInfo { loss, weight, step_ms });
                if self.active.iter().all(|a| self.sync_waiting.contains_key(a)) {
                    self.complete_barrier();
                }
            }
            WorkerEvent::NeedPartition { id } => {
                if self.assigner.pool_empty() {
                    if self.assigner.epoch_exhausted() {
                        self.assigner.advance_epoch();
                        self.report.epochs = self.assigner.epoch;
                        self.event(format!("epoch-advance -> {}", self.assigner.epoch));
                    } else {
                        self.send_ctrl(id, CtrlMsg::NoData);
                        return;
                    }
                }
                match self.assigner.next_partition(id) {
                    Some(meta) => self.send_ctrl(id, CtrlMsg::Assign { meta }),
                    None => self.send_ctrl(id, CtrlMsg::NoData),
                }
            }
            WorkerEvent::ShardDone { id } => {
                self.assigner.complete(id);
            }
            WorkerEvent::Goodbye { id, shard } => {
                if let Some((_pid, used)) = shard {
                    self.assigner.report_progress(id, used);
                }
                self.assigner.worker_left(id);
                self.workers.remove(&id);
                self.event(format!("goodbye worker={id}"));
            }
            WorkerEvent::Params { id: _, step, params } => {
                if let Some((path, reply)) = self.ckpt_reply.take() {
                    let mut e = Enc::with_capacity(params.len() * 4 + 256);
                    e.u64(step);
                    e.f32s(&params);
                    self.assigner.encode(&mut e);
                    match std::fs::write(&path, e.into_bytes()) {
                        Ok(()) => {
                            let _ = reply.send(Response::Ok);
                        }
                        Err(err) => {
                            let _ = reply.send(Response::Err(ElasticError::Io(err.to_string())));
                        }
                    }
                }
            }
        }
    }

    /// True while a parallelism adjustment is uncommitted (§3.1): new
    /// scaling requests get [`ElasticError::AdjustmentInFlight`].
    fn adjustment_in_flight(&self) -> bool {
        self.plan.is_some() || !self.joining.is_empty() || !self.started
    }

    fn handle_cmd(&mut self, req: Request, reply: Sender<Response>) {
        match req {
            Request::ScaleOut { machines } => {
                if self.adjustment_in_flight() {
                    let _ = reply.send(Response::Err(ElasticError::AdjustmentInFlight));
                    return;
                }
                if machines.is_empty() {
                    // no-op: nothing would ever commit, so ack immediately
                    let _ = reply.send(Response::Ok);
                    return;
                }
                self.event(format!("scale-out-request n={}", machines.len()));
                self.op_reply = Some(reply);
                for m in machines {
                    let id = next_node_id();
                    (self.spawner)(id, m, true);
                }
            }
            Request::ScaleIn { workers: ids } => {
                if self.adjustment_in_flight() {
                    let _ = reply.send(Response::Err(ElasticError::AdjustmentInFlight));
                    return;
                }
                if let Some(&bad) = ids.iter().find(|&id| !self.active.contains(id)) {
                    let _ = reply.send(Response::Err(ElasticError::UnknownWorker(bad)));
                    return;
                }
                if ids.len() >= self.active.len() {
                    let _ = reply.send(Response::Err(ElasticError::InvalidRequest(
                        "scale-in would remove every worker".into(),
                    )));
                    return;
                }
                if ids.is_empty() {
                    let _ = reply.send(Response::Ok);
                    return;
                }
                self.event(format!("scale-in-request ids={ids:?}"));
                self.op_exiting = ids;
                self.op_reply = Some(reply);
                self.maybe_commit_scale();
            }
            Request::Migrate { remove, add } => {
                if self.adjustment_in_flight() {
                    let _ = reply.send(Response::Err(ElasticError::AdjustmentInFlight));
                    return;
                }
                if let Some(&bad) = remove.iter().find(|&id| !self.active.contains(id)) {
                    let _ = reply.send(Response::Err(ElasticError::UnknownWorker(bad)));
                    return;
                }
                if remove.len() >= self.active.len() + add.len() {
                    let _ = reply.send(Response::Err(ElasticError::InvalidRequest(
                        "migration would empty the job".into(),
                    )));
                    return;
                }
                if remove.is_empty() && add.is_empty() {
                    let _ = reply.send(Response::Ok);
                    return;
                }
                self.event(format!("migrate-request -{} +{}", remove.len(), add.len()));
                let pure_removal = add.is_empty();
                self.op_exiting = remove;
                self.op_reply = Some(reply);
                for m in add {
                    let id = next_node_id();
                    (self.spawner)(id, m, true);
                }
                // commit: when all joiners are Ready — ONE switch; with no
                // joiners (pure-removal migrate) commit on the spot
                if pure_removal {
                    self.maybe_commit_scale();
                }
            }
            Request::Status => {
                let _ = reply.send(Response::Status(JobStatus {
                    parallelism: self.active.len() as u32,
                    step: self.step,
                    epoch: self.assigner.epoch,
                    throughput_sps: self.throughput_sps(),
                    last_loss: self.last_loss,
                    workers: self.active.clone(),
                }));
            }
            Request::Profile { .. } => {
                // the profile sweep is a multi-step measurement driven by
                // the engine (ElasticTrainer::profile) — it can never run
                // inside the leader's event loop without stalling training
                let _ = reply.send(Response::Err(ElasticError::InvalidRequest(
                    "profile is driven by the engine, not the leader".into(),
                )));
            }
            Request::Checkpoint { path } => {
                if let Some(&src) = self.active.first() {
                    self.ckpt_reply = Some((PathBuf::from(path), reply));
                    self.send_ctrl(src, CtrlMsg::SendParams);
                } else {
                    let _ = reply.send(Response::Err(ElasticError::InvalidRequest(
                        "no active workers".into(),
                    )));
                }
            }
            Request::Restore { path } => {
                match read_checkpoint(std::path::Path::new(&path), self.cfg.seed) {
                    Ok((at_step, params, asg)) => {
                        self.assigner = asg;
                        self.assigner.reset_in_flight();
                        self.step = at_step;
                        self.sync_waiting.clear();
                        self.barrier_open_at = None;
                        let params = Arc::new(params);
                        for id in self.active.clone() {
                            self.send_ctrl(id, CtrlMsg::Restore { params: params.clone(), at_step });
                        }
                        self.event(format!("manual-restore step={at_step}"));
                        let _ = reply.send(Response::Ok);
                    }
                    Err(e) => {
                        let _ = reply.send(Response::Err(ElasticError::Io(e.to_string())));
                    }
                }
            }
            Request::Stop => {
                self.stopping = true;
                for (_, w) in self.workers.iter() {
                    let _ = w.ctrl.send(CtrlMsg::Stop);
                }
                self.stop_reply = Some(reply);
            }
        }
    }

    fn run(mut self) -> TrainReport {
        loop {
            match self.rx.recv_timeout(Duration::from_millis(25)) {
                Ok(LeaderIn::W(ev)) => self.handle_worker(ev),
                Ok(LeaderIn::C(cmd, reply)) => self.handle_cmd(cmd, reply),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    if !self.stopping {
                        self.check_failures();
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
            if self.stopping {
                // drain replies then exit once workers are gone
                if let Some(r) = self.stop_reply.take() {
                    let _ = r.send(Response::Ok);
                }
                // brief drain window for Goodbyes
                let deadline = Instant::now() + Duration::from_millis(200);
                while let Ok(msg) = self.rx.recv_timeout(
                    deadline.saturating_duration_since(Instant::now()),
                ) {
                    if let LeaderIn::W(ev) = msg {
                        if matches!(ev, WorkerEvent::Goodbye { .. } | WorkerEvent::Sync { .. }) {
                            // ignore during shutdown
                        }
                    }
                }
                break;
            }
        }
        self.report.steps = self.step;
        self.report.epochs = self.assigner.epoch;
        self.report
    }
}

fn read_checkpoint(path: &std::path::Path, seed: u64) -> anyhow::Result<(u64, Vec<f32>, Assigner)> {
    let bytes = std::fs::read(path)?;
    let mut d = Dec::new(&bytes);
    let step = d.u64()?;
    let params = d.f32s()?;
    let asg = Assigner::decode(&mut d, seed)?;
    Ok((step, params, asg))
}

static NODE_IDS: AtomicU32 = AtomicU32::new(1);

fn next_node_id() -> NodeId {
    NODE_IDS.fetch_add(1, Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// engine
// ---------------------------------------------------------------------------

/// In-process elastic training engine: one leader thread + N worker
/// threads over an `InProcHub` data plane. This is the programmable
/// equivalent of `edl.init()` + the scheduler API of Table 1.
pub struct ElasticTrainer {
    tx: Sender<LeaderIn>,
    leader: Option<std::thread::JoinHandle<TrainReport>>,
    knobs: Arc<std::sync::Mutex<HashMap<NodeId, Arc<WorkerKnobs>>>>,
    worker_threads: Arc<std::sync::Mutex<Vec<std::thread::JoinHandle<()>>>>,
    pub hub: Arc<InProcHub>,
}

impl ElasticTrainer {
    /// Launch a job with `n_workers` founding workers.
    pub fn start(
        cfg: TrainerConfig,
        backend: Arc<dyn Backend>,
        corpus: Arc<Corpus>,
        n_workers: usize,
    ) -> ElasticTrainer {
        assert!(n_workers >= 1);
        let hub = InProcHub::new();
        let (tx, rx) = channel::<LeaderIn>();
        let knobs_map: Arc<std::sync::Mutex<HashMap<NodeId, Arc<WorkerKnobs>>>> =
            Arc::new(std::sync::Mutex::new(HashMap::new()));
        let threads: Arc<std::sync::Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(std::sync::Mutex::new(Vec::new()));

        let spawner: Spawner = {
            let hub = hub.clone();
            let backend = backend.clone();
            let corpus = corpus.clone();
            let tx = tx.clone();
            let knobs_map = knobs_map.clone();
            let threads = threads.clone();
            let lr = cfg.lr;
            Arc::new(move |id: NodeId, machine: String, joiner: bool| {
                let knobs = WorkerKnobs::new();
                knobs_map.lock().unwrap().insert(id, knobs.clone());
                let (ctrl_tx, ctrl_rx) = channel::<CtrlMsg>();
                let _ = tx.send(LeaderIn::W(WorkerEvent::Attach {
                    id,
                    machine: machine.clone(),
                    ctrl: ctrl_tx,
                    knobs: knobs.clone(),
                    joiner,
                }));
                let net = hub.join(id);
                let ctx = WorkerCtx {
                    id,
                    machine,
                    backend: backend.clone(),
                    corpus: corpus.clone(),
                    net,
                    to_leader: {
                        let tx = tx.clone();
                        let (wtx, wrx) = channel::<WorkerEvent>();
                        // bridge worker events into the leader mailbox
                        std::thread::spawn(move || {
                            while let Ok(ev) = wrx.recv() {
                                if tx.send(LeaderIn::W(ev)).is_err() {
                                    break;
                                }
                            }
                        });
                        wtx
                    },
                    ctrl: ctrl_rx,
                    lr,
                    knobs,
                    joiner,
                    init_seed: 42,
                };
                let handle = std::thread::Builder::new()
                    .name(format!("edl-worker-{id}"))
                    .spawn(move || worker_loop(ctx))
                    .expect("spawn worker");
                threads.lock().unwrap().push(handle);
            })
        };

        let corpus_samples = corpus.n_samples;
        let table = PartitionTable::new(corpus_samples, cfg.n_partitions.min(corpus_samples));
        let assigner = Assigner::new(table, cfg.seed);
        let leader = Leader {
            cfg,
            backend,
            rx,
            spawner: spawner.clone(),
            expected_founders: n_workers,
            workers: BTreeMap::new(),
            active: Vec::new(),
            ring: Arc::new(Vec::new()),
            ring_version: 0,
            step: 0,
            started: false,
            assigner,
            sync_waiting: HashMap::new(),
            barrier_open_at: None,
            plan: None,
            op_reply: None,
            joining: Vec::new(),
            op_exiting: Vec::new(),
            ckpt_reply: None,
            stop_reply: None,
            report: TrainReport::default(),
            recent_barriers: Default::default(),
            last_loss: f32::NAN,
            stopping: false,
        };
        let leader_handle = std::thread::Builder::new()
            .name("edl-leader".into())
            .spawn(move || leader.run())
            .expect("spawn leader");

        for _ in 0..n_workers {
            let id = next_node_id();
            spawner(id, "m0".to_string(), false);
        }

        ElasticTrainer { tx, leader: Some(leader_handle), knobs: knobs_map, worker_threads: threads, hub }
    }

    /// Blocking Table-1 round-trip to the leader — the same
    /// [`api::Request`](crate::api::Request) values the TCP deployment
    /// sends, minus the serialisation.
    pub fn call(&self, req: Request) -> Response {
        let (rtx, rrx) = channel();
        if self.tx.send(LeaderIn::C(req, rtx)).is_err() {
            return Response::Err(ElasticError::Aborted("leader gone".into()));
        }
        rrx.recv_timeout(Duration::from_secs(600))
            .unwrap_or(Response::Err(ElasticError::Aborted("leader timed out".into())))
    }

    /// `status` (Table 1), panicking on a dead leader (tests/benches).
    pub fn status(&self) -> JobStatus {
        self.try_status().expect("status")
    }

    pub fn try_status(&self) -> Result<JobStatus, ElasticError> {
        self.call(Request::Status).status()
    }

    /// `sclae_out` (sic, Table 1): add workers on the given machines.
    pub fn scale_out(&self, machines: Vec<String>) -> Result<(), ElasticError> {
        self.call(Request::ScaleOut { machines }).unit()
    }

    /// `sclae_in` (sic, Table 1): remove specific workers.
    pub fn scale_in(&self, ids: Vec<NodeId>) -> Result<(), ElasticError> {
        self.call(Request::ScaleIn { workers: ids }).unit()
    }

    /// merged migration (§5.2): one topology switch for -remove/+add
    pub fn migrate(&self, remove: Vec<NodeId>, add: Vec<String>) -> Result<(), ElasticError> {
        self.call(Request::Migrate { remove, add }).unit()
    }

    /// Write a consistent checkpoint (model + data-pipeline state).
    pub fn checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<(), ElasticError> {
        self.call(Request::Checkpoint { path: path.as_ref().to_string_lossy().into_owned() })
            .unit()
    }

    /// Restore model + data-pipeline state from a checkpoint.
    pub fn restore(&self, path: impl AsRef<std::path::Path>) -> Result<(), ElasticError> {
        self.call(Request::Restore { path: path.as_ref().to_string_lossy().into_owned() }).unit()
    }

    /// Wait until the leader's step counter reaches `step` (false on
    /// timeout or once the leader is gone).
    pub fn wait_step(&self, step: u64, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            match self.try_status() {
                Ok(st) if st.step >= step => return true,
                Ok(_) => {}
                Err(_) => return false,
            }
            if Instant::now() > deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// fault/straggler injection handle for worker `id`
    pub fn knobs(&self, id: NodeId) -> Option<Arc<WorkerKnobs>> {
        self.knobs.lock().unwrap().get(&id).cloned()
    }

    /// profile() from Table 1: measure throughput from the current
    /// parallelism down to `min_p` by repeated low-overhead scale-ins,
    /// `steps_per_level` mini-batches per level (§5.2). Panics if the
    /// leader is gone; see [`ElasticTrainer::try_profile`].
    pub fn profile(&self, min_p: u32, steps_per_level: u64) -> Vec<ProfileRow> {
        self.try_profile(min_p, steps_per_level).expect("profile")
    }

    /// Non-panicking [`ElasticTrainer::profile`] (the `JobControl` path —
    /// a remote scheduler gets a typed error, not a dead connection).
    pub fn try_profile(
        &self,
        min_p: u32,
        steps_per_level: u64,
    ) -> Result<Vec<ProfileRow>, ElasticError> {
        let mut rows = Vec::new();
        loop {
            let st = self.try_status()?;
            let p = st.parallelism;
            let start_step = st.step;
            if !self.wait_step(start_step + steps_per_level, Duration::from_secs(600)) {
                break;
            }
            let st2 = self.try_status()?;
            rows.push(ProfileRow {
                parallelism: p,
                throughput: st2.throughput_sps,
                per_gpu_throughput: st2.throughput_sps / p as f64,
                efficiency: 0.0, // normalised below over all rows
            });
            if p <= min_p {
                break;
            }
            let Some(&victim) = st2.workers.last() else { break };
            if self.scale_in(vec![victim]).is_err() {
                break;
            }
        }
        crate::api::normalise_efficiency(&mut rows);
        Ok(rows)
    }

    /// Stop the job and collect the training report.
    pub fn stop(mut self) -> TrainReport {
        let _ = self.call(Request::Stop);
        let report = self.leader.take().map(|h| h.join().unwrap()).unwrap_or_default();
        for h in self.worker_threads.lock().unwrap().drain(..) {
            let _ = h.join();
        }
        report
    }
}

// ---------------------------------------------------------------------------
// Table-1 trait impls
// ---------------------------------------------------------------------------

/// The live engine speaks the scheduler API natively. `stop` here only
/// signals the leader — use the consuming [`ElasticTrainer::stop`] to
/// also join the threads and collect the [`TrainReport`].
impl JobControl for ElasticTrainer {
    fn scale_out(&mut self, machines: Vec<String>) -> Result<(), ElasticError> {
        ElasticTrainer::scale_out(self, machines)
    }
    fn scale_in(&mut self, workers: Vec<NodeId>) -> Result<(), ElasticError> {
        ElasticTrainer::scale_in(self, workers)
    }
    fn migrate(&mut self, remove: Vec<NodeId>, add: Vec<String>) -> Result<(), ElasticError> {
        ElasticTrainer::migrate(self, remove, add)
    }
    fn profile(
        &mut self,
        min_p: u32,
        steps_per_level: u64,
    ) -> Result<Vec<ProfileRow>, ElasticError> {
        ElasticTrainer::try_profile(self, min_p, steps_per_level)
    }
    fn status(&mut self) -> Result<JobStatus, ElasticError> {
        self.try_status()
    }
    fn checkpoint(&mut self, path: &str) -> Result<(), ElasticError> {
        ElasticTrainer::checkpoint(self, path)
    }
    fn restore(&mut self, path: &str) -> Result<(), ElasticError> {
        ElasticTrainer::restore(self, path)
    }
    fn stop(&mut self) -> Result<(), ElasticError> {
        self.call(Request::Stop).unit()
    }
}

/// Shared-reference flavour: the engine's command channel is already
/// thread-safe, so `&ElasticTrainer` (e.g. behind an `Arc`) is a full
/// [`JobControl`] too — handy for driving one live job from several
/// policy threads.
impl JobControl for &ElasticTrainer {
    fn scale_out(&mut self, machines: Vec<String>) -> Result<(), ElasticError> {
        ElasticTrainer::scale_out(*self, machines)
    }
    fn scale_in(&mut self, workers: Vec<NodeId>) -> Result<(), ElasticError> {
        ElasticTrainer::scale_in(*self, workers)
    }
    fn migrate(&mut self, remove: Vec<NodeId>, add: Vec<String>) -> Result<(), ElasticError> {
        ElasticTrainer::migrate(*self, remove, add)
    }
    fn profile(
        &mut self,
        min_p: u32,
        steps_per_level: u64,
    ) -> Result<Vec<ProfileRow>, ElasticError> {
        ElasticTrainer::try_profile(*self, min_p, steps_per_level)
    }
    fn status(&mut self) -> Result<JobStatus, ElasticError> {
        ElasticTrainer::try_status(*self)
    }
    fn checkpoint(&mut self, path: &str) -> Result<(), ElasticError> {
        ElasticTrainer::checkpoint(*self, path)
    }
    fn restore(&mut self, path: &str) -> Result<(), ElasticError> {
        ElasticTrainer::restore(*self, path)
    }
    fn stop(&mut self) -> Result<(), ElasticError> {
        ElasticTrainer::call(*self, Request::Stop).unit()
    }
}
