//! Straggler mitigation demo (§5.2 / Fig 9b) on the real protocol: a
//! worker is slowed to ~75% effective speed; the leader detects it from
//! per-mini-batch sync-request timings and removes it with a low-overhead
//! scale-in; throughput recovers to ~(p-1)/p of normal.
//!
//!     cargo run --release --example straggler_mitigation -- --workers 4

use edl::coordinator::{ElasticTrainer, TrainerConfig};
use edl::data::corpus::Corpus;
use edl::util::args::Args;
use edl::worker::SimBackend;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let args = Args::from_env();
    let workers = args.usize("workers", 4);
    let compute_ms = args.u64("compute-ms", 30);

    let backend = SimBackend { compute_ms, ..SimBackend::fast(2048) };
    let corpus = Arc::new(Corpus::markov(256, 16, 1 << 20, 5));
    let cfg = TrainerConfig {
        agg_batch: 32,
        n_partitions: 4096,
        straggler_mitigation: true,
        straggler_ratio: 1.2,
        straggler_window: 10,
        approx_recovery: true,
        ..Default::default()
    };
    let t = ElasticTrainer::start(cfg, Arc::new(backend), corpus, workers);
    assert!(t.wait_step(10, Duration::from_secs(120)));

    let measure = |label: &str, secs: u64| {
        let s0 = t.status().step;
        let t0 = Instant::now();
        std::thread::sleep(Duration::from_secs(secs));
        let ds = t.status().step - s0;
        let sps = ds as f64 * 32.0 / t0.elapsed().as_secs_f64();
        println!("{label:<34} {sps:>8.1} samples/s (p={})", t.status().parallelism);
        sps
    };

    println!("== straggler mitigation ({workers} workers, {compute_ms}ms/step) ==\n");
    let normal = measure("normal", 4);

    // slow one worker: +1/3 of the step time (≈75% effective speed, §6.2)
    let victim = *t.status().workers.last().unwrap();
    t.knobs(victim).unwrap().straggle_ms.store(compute_ms / 3 + 1, Ordering::Relaxed);
    println!("\n[injected straggler on worker {victim}: +{}ms/step]", compute_ms / 3 + 1);
    let t_detect = Instant::now();
    let degraded = measure("degraded (straggler active)", 3);

    // wait for automatic detection + removal
    let deadline = Instant::now() + Duration::from_secs(120);
    while t.status().parallelism as usize == workers {
        assert!(Instant::now() < deadline, "straggler never removed");
        std::thread::sleep(Duration::from_millis(100));
    }
    println!(
        "\n[leader detected + removed straggler in {:.1}s (paper: <10s detect, <5s remove)]",
        t_detect.elapsed().as_secs_f64()
    );
    let recovered = measure("recovered (straggler removed)", 4);

    println!(
        "\ndegraded/normal   = {:.0}% (paper: ~75%)",
        degraded / normal * 100.0
    );
    println!(
        "recovered/normal  = {:.0}% (paper: ~94% with one fewer GPU)",
        recovered / normal * 100.0
    );
    let report = t.stop();
    let ev: Vec<_> = report.events.iter().filter(|e| e.what.contains("straggler")).collect();
    println!("\nevents: {ev:?}");
}
