//! Coordination-plane message types: scheduler ⇄ leader and worker ⇄ leader
//! (Table 1 of the paper + the §4.2 scaling protocol messages). Each type
//! carries a hand-rolled wire encoding (see `wire`) used by the TCP
//! deployment; the in-process trainer moves the same types through typed
//! channels without serialisation.

use crate::data::PartitionMeta;
use crate::transport::NodeId;
use crate::wire::{Dec, Enc, Result, WireError};

/// Scheduler → leader commands (the paper's Table 1 scheduler API;
/// `sclae_in`/`sclae_out` spelling follows the paper, aliased here).
#[derive(Debug, Clone, PartialEq)]
pub enum SchedCmd {
    /// remove specific GPUs/workers from the job
    ScaleIn { workers: Vec<NodeId> },
    /// add workers (opaque GPU info strings: "machine:gpu")
    ScaleOut { gpu_info: Vec<String> },
    /// profile the job over a parallelism range
    Profile { min_p: u32, max_p: u32 },
    /// migrate: scale-in `remove` and scale-out `add` with ONE topology switch
    Migrate { remove: Vec<NodeId>, add: Vec<String> },
    /// report job status
    Status,
}

/// Leader → scheduler replies.
#[derive(Debug, Clone, PartialEq)]
pub enum SchedReply {
    Ack,
    /// a scaling operation is already in flight — try again later (§3.1)
    Retry,
    Status { parallelism: u32, step: u64, throughput: f64 },
    ProfileResult { rows: Vec<ProfileRow> },
    Error { msg: String },
}

#[derive(Debug, Clone, PartialEq)]
pub struct ProfileRow {
    pub parallelism: u32,
    pub throughput: f64,
    pub per_gpu_throughput: f64,
    pub efficiency: f64,
}

/// Worker → leader messages.
#[derive(Debug, Clone, PartialEq)]
pub enum ToLeader {
    /// background-thread registration during stop-free scale-out (§4.2)
    Register { worker: NodeId, machine: String },
    /// context preparation finished; blocked awaiting OK
    Ready { worker: NodeId },
    /// per-mini-batch gradient synchronisation request; doubles as
    /// liveness signal and carries data-pipeline progress (§4.3)
    SyncRequest { worker: NodeId, step: u64, step_ms: f64, partition: u64, offset: u64 },
    /// worker needs the next data partition
    PartitionRequest { worker: NodeId },
    /// graceful exit report: unprocessed remainder of current partition
    Goodbye { worker: NodeId, partition: u64, offset: u64 },
}

/// Leader → worker messages.
#[derive(Debug, Clone, PartialEq)]
pub enum FromLeader {
    /// reply to PartitionRequest
    Assign { partition: PartitionMeta },
    /// no partitions left in this epoch
    EpochEnd { epoch: u64 },
    /// continue training, no change
    Proceed,
    /// switch to a new communication topology at mini-batch `at_step`
    Switch {
        at_step: u64,
        version: u64,
        ring: Vec<NodeId>,
        local_batch: u32,
        /// worker that must broadcast the model to joiners (one sender, §4.2)
        broadcast_src: NodeId,
        /// joining workers awaiting the model
        joiners: Vec<NodeId>,
        /// whether the receiving worker should exit at the switch point
        exit: bool,
    },
    /// job complete
    Stop,
    /// OK + future timestamp for a blocked new worker (stop-free scaling)
    Ok { join_at_step: u64 },
}

// ---------------------------------------------------------------------------
// wire encodings
// ---------------------------------------------------------------------------

fn enc_node_vec(e: &mut Enc, v: &[NodeId]) {
    e.u32(v.len() as u32);
    for &n in v {
        e.u32(n);
    }
}

fn dec_node_vec(d: &mut Dec) -> Result<Vec<NodeId>> {
    let n = d.u32()? as usize;
    (0..n).map(|_| d.u32()).collect()
}

impl SchedCmd {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            SchedCmd::ScaleIn { workers } => {
                e.u8(1);
                enc_node_vec(&mut e, workers);
            }
            SchedCmd::ScaleOut { gpu_info } => {
                e.u8(2).u32(gpu_info.len() as u32);
                for g in gpu_info {
                    e.str(g);
                }
            }
            SchedCmd::Profile { min_p, max_p } => {
                e.u8(3).u32(*min_p).u32(*max_p);
            }
            SchedCmd::Migrate { remove, add } => {
                e.u8(4);
                enc_node_vec(&mut e, remove);
                e.u32(add.len() as u32);
                for g in add {
                    e.str(g);
                }
            }
            SchedCmd::Status => {
                e.u8(5);
            }
        }
        e.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<SchedCmd> {
        let mut d = Dec::new(buf);
        match d.u8()? {
            1 => Ok(SchedCmd::ScaleIn { workers: dec_node_vec(&mut d)? }),
            2 => {
                let n = d.u32()? as usize;
                let gpu_info = (0..n).map(|_| d.str()).collect::<Result<_>>()?;
                Ok(SchedCmd::ScaleOut { gpu_info })
            }
            3 => Ok(SchedCmd::Profile { min_p: d.u32()?, max_p: d.u32()? }),
            4 => {
                let remove = dec_node_vec(&mut d)?;
                let n = d.u32()? as usize;
                let add = (0..n).map(|_| d.str()).collect::<Result<_>>()?;
                Ok(SchedCmd::Migrate { remove, add })
            }
            5 => Ok(SchedCmd::Status),
            tag => Err(WireError::BadTag { tag: tag as u32, ty: "SchedCmd" }),
        }
    }
}

impl SchedReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            SchedReply::Ack => {
                e.u8(1);
            }
            SchedReply::Retry => {
                e.u8(2);
            }
            SchedReply::Status { parallelism, step, throughput } => {
                e.u8(3).u32(*parallelism).u64(*step).f64(*throughput);
            }
            SchedReply::ProfileResult { rows } => {
                e.u8(4).u32(rows.len() as u32);
                for r in rows {
                    e.u32(r.parallelism).f64(r.throughput).f64(r.per_gpu_throughput).f64(r.efficiency);
                }
            }
            SchedReply::Error { msg } => {
                e.u8(5).str(msg);
            }
        }
        e.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<SchedReply> {
        let mut d = Dec::new(buf);
        match d.u8()? {
            1 => Ok(SchedReply::Ack),
            2 => Ok(SchedReply::Retry),
            3 => Ok(SchedReply::Status { parallelism: d.u32()?, step: d.u64()?, throughput: d.f64()? }),
            4 => {
                let n = d.u32()? as usize;
                let rows = (0..n)
                    .map(|_| {
                        Ok(ProfileRow {
                            parallelism: d.u32()?,
                            throughput: d.f64()?,
                            per_gpu_throughput: d.f64()?,
                            efficiency: d.f64()?,
                        })
                    })
                    .collect::<Result<_>>()?;
                Ok(SchedReply::ProfileResult { rows })
            }
            5 => Ok(SchedReply::Error { msg: d.str()? }),
            tag => Err(WireError::BadTag { tag: tag as u32, ty: "SchedReply" }),
        }
    }
}

impl ToLeader {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            ToLeader::Register { worker, machine } => {
                e.u8(1).u32(*worker).str(machine);
            }
            ToLeader::Ready { worker } => {
                e.u8(2).u32(*worker);
            }
            ToLeader::SyncRequest { worker, step, step_ms, partition, offset } => {
                e.u8(3).u32(*worker).u64(*step).f64(*step_ms).u64(*partition).u64(*offset);
            }
            ToLeader::PartitionRequest { worker } => {
                e.u8(4).u32(*worker);
            }
            ToLeader::Goodbye { worker, partition, offset } => {
                e.u8(5).u32(*worker).u64(*partition).u64(*offset);
            }
        }
        e.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<ToLeader> {
        let mut d = Dec::new(buf);
        match d.u8()? {
            1 => Ok(ToLeader::Register { worker: d.u32()?, machine: d.str()? }),
            2 => Ok(ToLeader::Ready { worker: d.u32()? }),
            3 => Ok(ToLeader::SyncRequest {
                worker: d.u32()?,
                step: d.u64()?,
                step_ms: d.f64()?,
                partition: d.u64()?,
                offset: d.u64()?,
            }),
            4 => Ok(ToLeader::PartitionRequest { worker: d.u32()? }),
            5 => Ok(ToLeader::Goodbye { worker: d.u32()?, partition: d.u64()?, offset: d.u64()? }),
            tag => Err(WireError::BadTag { tag: tag as u32, ty: "ToLeader" }),
        }
    }
}

impl FromLeader {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            FromLeader::Assign { partition } => {
                e.u8(1);
                partition.encode(&mut e);
            }
            FromLeader::EpochEnd { epoch } => {
                e.u8(2).u64(*epoch);
            }
            FromLeader::Proceed => {
                e.u8(3);
            }
            FromLeader::Switch { at_step, version, ring, local_batch, broadcast_src, joiners, exit } => {
                e.u8(4).u64(*at_step).u64(*version);
                enc_node_vec(&mut e, ring);
                e.u32(*local_batch).u32(*broadcast_src);
                enc_node_vec(&mut e, joiners);
                e.bool(*exit);
            }
            FromLeader::Stop => {
                e.u8(5);
            }
            FromLeader::Ok { join_at_step } => {
                e.u8(6).u64(*join_at_step);
            }
        }
        e.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<FromLeader> {
        let mut d = Dec::new(buf);
        match d.u8()? {
            1 => Ok(FromLeader::Assign { partition: PartitionMeta::decode(&mut d)? }),
            2 => Ok(FromLeader::EpochEnd { epoch: d.u64()? }),
            3 => Ok(FromLeader::Proceed),
            4 => Ok(FromLeader::Switch {
                at_step: d.u64()?,
                version: d.u64()?,
                ring: dec_node_vec(&mut d)?,
                local_batch: d.u32()?,
                broadcast_src: d.u32()?,
                joiners: dec_node_vec(&mut d)?,
                exit: d.bool()?,
            }),
            5 => Ok(FromLeader::Stop),
            6 => Ok(FromLeader::Ok { join_at_step: d.u64()? }),
            tag => Err(WireError::BadTag { tag: tag as u32, ty: "FromLeader" }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_cmd(c: SchedCmd) {
        assert_eq!(SchedCmd::decode(&c.encode()).unwrap(), c);
    }

    #[test]
    fn sched_cmd_roundtrips() {
        roundtrip_cmd(SchedCmd::ScaleIn { workers: vec![1, 2, 3] });
        roundtrip_cmd(SchedCmd::ScaleOut { gpu_info: vec!["m0:g1".into(), "m1:g7".into()] });
        roundtrip_cmd(SchedCmd::Profile { min_p: 2, max_p: 8 });
        roundtrip_cmd(SchedCmd::Migrate { remove: vec![5], add: vec!["m2:g0".into()] });
        roundtrip_cmd(SchedCmd::Status);
    }

    #[test]
    fn sched_reply_roundtrips() {
        for r in [
            SchedReply::Ack,
            SchedReply::Retry,
            SchedReply::Status { parallelism: 4, step: 100, throughput: 512.5 },
            SchedReply::ProfileResult {
                rows: vec![ProfileRow { parallelism: 2, throughput: 100.0, per_gpu_throughput: 50.0, efficiency: 0.9 }],
            },
            SchedReply::Error { msg: "bad".into() },
        ] {
            assert_eq!(SchedReply::decode(&r.encode()).unwrap(), r);
        }
    }

    #[test]
    fn to_leader_roundtrips() {
        for m in [
            ToLeader::Register { worker: 3, machine: "m1".into() },
            ToLeader::Ready { worker: 3 },
            ToLeader::SyncRequest { worker: 1, step: 42, step_ms: 123.4, partition: 7, offset: 99 },
            ToLeader::PartitionRequest { worker: 2 },
            ToLeader::Goodbye { worker: 1, partition: 7, offset: 512 },
        ] {
            assert_eq!(ToLeader::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn from_leader_roundtrips() {
        for m in [
            FromLeader::EpochEnd { epoch: 3 },
            FromLeader::Proceed,
            FromLeader::Switch {
                at_step: 100,
                version: 2,
                ring: vec![1, 2, 3],
                local_batch: 8,
                broadcast_src: 1,
                joiners: vec![3],
                exit: false,
            },
            FromLeader::Stop,
            FromLeader::Ok { join_at_step: 101 },
        ] {
            assert_eq!(FromLeader::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn bad_tag_rejected() {
        assert!(matches!(SchedCmd::decode(&[99]), Err(WireError::BadTag { .. })));
        assert!(matches!(ToLeader::decode(&[0]), Err(WireError::BadTag { .. })));
    }
}
