//! Fig 9 — (a) performance profiling time, EDL vs stop-resume: profiling
//! p ∈ [2,8] with 10 mini-batches per level; (b) straggler mitigation on
//! the live protocol.
//!
//! (a) stop-resume launches a fresh job per parallelism (paying context
//! prep every time); EDL starts once at max parallelism and scales IN
//! (cheap). Paper: EDL ≈ 20% of stop-resume's time.

use edl::coordinator::{ElasticTrainer, TrainerConfig};
use edl::data::corpus::Corpus;
use edl::gpu_sim::{edl_scale_in_e2e, step_time, stop_resume_overhead, Dnn, HwConfig};
use edl::util::json::{write_results, Json};
use edl::worker::SimBackend;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let hw = HwConfig::default();
    let mut out = Json::obj();

    // ---- (a) profiling time model, p in [2,8], 10 mini-batches each ----
    println!("== Fig 9a: profiling time p=2..8, 10 mini-batches per level ==");
    println!("{:<12} {:>12} {:>10} {:>8}", "model", "stop-resume", "EDL", "EDL/SR");
    for model in [Dnn::ResNet50, Dnn::VGG16, Dnn::ResNet152] {
        let b = 32 * 8;
        let mut t_sr = 0.0;
        let mut t_edl = 0.0;
        for p in 2..=8u32 {
            let batches = 10.0 * step_time(model, p, b, &hw);
            t_sr += stop_resume_overhead(model, p) + batches; // fresh launch per level
            t_edl += batches;
        }
        // EDL: ONE launch at p=8, then cheap scale-ins downwards
        t_edl += stop_resume_overhead(model, 8);
        t_edl += 6.0 * edl_scale_in_e2e(model) * 0.2; // stall felt by the job per scale-in
        let frac = t_edl / t_sr;
        println!("{:<12} {:>11.0}s {:>9.0}s {:>7.0}%", model.spec().name, t_sr, t_edl, frac * 100.0);
        assert!(frac < 0.5, "EDL profiling must be far cheaper: {frac}");
        let mut r = Json::obj();
        r.set("stop_resume_s", t_sr).set("edl_s", t_edl).set("fraction", frac);
        out.set(&format!("profiling_{}", model.spec().name), r);
    }
    println!("(paper: EDL ≈ 20% of stop-resume)");

    // ---- (a') live protocol: profile() on the engine ----
    println!("\n== Fig 9a (measured): engine profile() 4 -> 1 workers ==");
    let backend = SimBackend { compute_ms: 20, ..SimBackend::fast(4096) };
    let corpus = Arc::new(Corpus::markov(256, 16, 1 << 20, 6));
    let cfg = TrainerConfig { agg_batch: 32, n_partitions: 4096, ..Default::default() };
    let t = ElasticTrainer::start(cfg, Arc::new(backend), corpus.clone(), 4);
    assert!(t.wait_step(5, Duration::from_secs(60)));
    let t0 = Instant::now();
    let rows = t.profile(1, 10);
    let profile_wall = t0.elapsed().as_secs_f64();
    t.stop();
    println!("{:>4} {:>12} {:>12}", "p", "samples/s", "efficiency");
    let mut jrows = Json::Arr(vec![]);
    for r in &rows {
        println!("{:>4} {:>12.1} {:>12.3}", r.parallelism, r.throughput, r.efficiency);
        let mut jr = Json::obj();
        jr.set("p", r.parallelism).set("sps", r.throughput).set("efficiency", r.efficiency);
        jrows.push(jr);
    }
    println!("profile(4..1, 10 steps/level) wall time: {profile_wall:.2}s");
    assert_eq!(rows.len(), 4);
    out.set("measured_profile_rows", jrows);
    out.set("measured_profile_wall_s", profile_wall);

    // ---- (b) straggler mitigation on the live protocol ----
    println!("\n== Fig 9b (measured): straggler mitigation, 4 workers ==");
    let backend = SimBackend { compute_ms: 30, ..SimBackend::fast(4096) };
    let cfg = TrainerConfig {
        agg_batch: 32,
        n_partitions: 4096,
        straggler_mitigation: true,
        straggler_ratio: 1.2,
        straggler_window: 10,
        ..Default::default()
    };
    let t = ElasticTrainer::start(cfg, Arc::new(backend), corpus, 4);
    assert!(t.wait_step(15, Duration::from_secs(120)));
    let sps = |t: &ElasticTrainer, secs: u64| {
        let s0 = t.status().step;
        let i0 = Instant::now();
        std::thread::sleep(Duration::from_secs(secs));
        (t.status().step - s0) as f64 * 32.0 / i0.elapsed().as_secs_f64()
    };
    let normal = sps(&t, 3);
    let victim = *t.status().workers.last().unwrap();
    t.knobs(victim).unwrap().straggle_ms.store(11, Ordering::Relaxed); // ~+1/3 step
    let degraded = sps(&t, 3);
    let t_detect = Instant::now();
    let deadline = Instant::now() + Duration::from_secs(90);
    while t.status().parallelism == 4 {
        assert!(Instant::now() < deadline, "straggler never removed");
        std::thread::sleep(Duration::from_millis(50));
    }
    let detect_s = t_detect.elapsed().as_secs_f64();
    let recovered = sps(&t, 3);
    t.stop();
    println!("normal    {normal:>8.1} samples/s");
    println!("degraded  {degraded:>8.1} samples/s ({:.0}% of normal; paper ~75%)", degraded / normal * 100.0);
    println!("recovered {recovered:>8.1} samples/s ({:.0}% of normal; paper ~94%)", recovered / normal * 100.0);
    println!("detection+removal: {detect_s:.1}s (paper: <10s + <5s)");
    assert!(degraded < 0.92 * normal, "straggler must visibly degrade throughput");
    assert!(recovered > degraded, "removal must recover throughput");
    let mut r = Json::obj();
    r.set("normal_sps", normal)
        .set("degraded_sps", degraded)
        .set("recovered_sps", recovered)
        .set("detect_remove_s", detect_s);
    out.set("measured_straggler", r);

    let path = write_results("fig09_profiling_straggler", &out).unwrap();
    println!("\nshape checks OK; results -> {}", path.display());
}
