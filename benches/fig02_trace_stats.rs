//! Fig 2 — (a) cluster load variation over time and (b) job-size CDF,
//! regenerated from the calibrated synthetic Philly-like trace.
//! Paper reference points: p20 = 85 GPU·s, p90 = 58,330 GPU·s; the
//! cluster alternates between saturation and slack.

use edl::trace::{generate, stats_of, TraceConfig};
use edl::util::json::{write_results, Json};
use edl::util::stats;

fn main() {
    let cfg = TraceConfig { n_jobs: 30_000, ..Default::default() };
    let jobs = generate(&cfg);
    let st = stats_of(&jobs, cfg.span_s);

    println!("== Fig 2b: job-size distribution ({} jobs, {:.0} days) ==", st.n_jobs, cfg.span_s / 86_400.0);
    println!("{:>6} {:>14} {:>14}", "pct", "measured", "paper");
    println!("{:>6} {:>14.0} {:>14}", "p20", st.size_p20, 85);
    println!("{:>6} {:>14.0} {:>14}", "p50", st.size_p50, "-");
    println!("{:>6} {:>14.0} {:>14}", "p90", st.size_p90, 58_330);
    println!("{:>6} {:>14.0} {:>14}", "p99", st.size_p99, "-");
    let sizes: Vec<f64> = jobs.iter().map(|j| j.service_gpu_s).collect();
    let points = [10.0, 100.0, 1_000.0, 10_000.0, 100_000.0, 1_000_000.0];
    let cdf = stats::cdf_at(&sizes, &points);
    println!("\nCDF(size <= x):");
    for (x, c) in points.iter().zip(&cdf) {
        println!("  {:>10.0} GPU·s : {:>5.1}%", x, c * 100.0);
    }

    println!("\n== Fig 2a: hourly offered load (GPU·s demanded / s) ==");
    let peak = stats::percentile(&st.hourly_load, 95.0);
    let trough = stats::percentile(&st.hourly_load, 5.0);
    let mean = stats::mean(&st.hourly_load);
    println!("p5={trough:.1}  mean={mean:.1}  p95={peak:.1}  (peak/trough={:.1}x)", peak / trough.max(1e-9));
    // coarse day-by-day sparkline
    let per_day: Vec<f64> = st.hourly_load.chunks(24).map(stats::mean).collect();
    let max = stats::max(&per_day).max(1e-9);
    let bars: String = per_day
        .iter()
        .map(|&v| {
            let lvl = (v / max * 7.0).round() as usize;
            ['.', ':', '-', '=', '+', '*', '#', '@'][lvl.min(7)]
        })
        .collect();
    println!("daily load: {bars}");

    assert!(st.size_p90 / st.size_p20 > 100.0, "job sizes must span orders of magnitude");
    assert!(peak > 2.0 * trough.max(1e-9), "load must vary substantially");

    let mut out = Json::obj();
    out.set("p20", st.size_p20)
        .set("p50", st.size_p50)
        .set("p90", st.size_p90)
        .set("p99", st.size_p99)
        .set("paper_p20", 85.0)
        .set("paper_p90", 58_330.0)
        .set("hourly_load", st.hourly_load.as_slice());
    let path = write_results("fig02_trace_stats", &out).unwrap();
    println!("\nshape checks OK; results -> {}", path.display());
}
