//! Cluster schedulers: FIFO, Static, ElasticSimple (the Fig 11 pair),
//! Tiresias (discretized 2D-LAS, Gu et al. NSDI'19) and Elastic-Tiresias
//! (Tiresias + the paper's R1 compaction / R2 expansion rules, §5.1).
//!
//! Parallelism adjustments go through the Table-1 surface
//! ([`crate::api::JobControl`]) via each job's `sim.job(i)` handle — the
//! policy primitives [`ElasticTiresias::expand_job`] /
//! [`ElasticTiresias::shrink_job`] are written against the trait, so the
//! SAME code also drives a live `ElasticTrainer` (in-process or through
//! `api::JobClient` over TCP).

use crate::api::{ElasticError, JobControl, JobControlExt};
use crate::cluster::{ClusterSim, JobState, Scheduler};
use crate::gpu_sim;
use std::time::Duration;

/// How long the retry helpers wait out an in-flight adjustment (§3.1)
/// before giving up. Simulated handles never sleep here: scheduler rules
/// only touch jobs that are currently adjustable.
const RETRY_T: Duration = Duration::from_secs(30);

/// A simulated job that can accept an adjustment NOW. Guarding here (not
/// just at each rule's filter) keeps the wall-clock retry backoff in
/// [`JobControlExt`] from ever spinning against frozen simulator time.
fn adjustable(sim: &ClusterSim, i: usize) -> bool {
    matches!(sim.jobs[i].state, JobState::Running { paused_until, .. } if paused_until <= sim.now)
}

/// Grow job `i` to `target` GPUs through its Table-1 handle; false if the
/// adjustment was rejected (in flight / no resources).
fn grow_to(sim: &mut ClusterSim, i: usize, target: u32) -> bool {
    let p = sim.jobs[i].current_p();
    if target <= p || !adjustable(sim, i) {
        return false;
    }
    let machines = vec![String::from("sim-gpu"); (target - p) as usize];
    ElasticTiresias::expand_job(&mut sim.job(i), machines).is_ok()
}

/// Shrink job `i` to `target` GPUs through its Table-1 handle.
fn shrink_to(sim: &mut ClusterSim, i: usize, target: u32) -> bool {
    let p = sim.jobs[i].current_p();
    if target >= p || target == 0 || !adjustable(sim, i) {
        return false;
    }
    ElasticTiresias::shrink_job(&mut sim.job(i), p - target).is_ok()
}

/// Plain FIFO at requested parallelism (baseline / test harness).
#[derive(Default)]
pub struct FifoScheduler;

impl Scheduler for FifoScheduler {
    fn name(&self) -> &'static str {
        "fifo"
    }
    fn replan(&mut self, sim: &mut ClusterSim) {
        for i in sim.pending_jobs() {
            let p = sim.jobs[i].requested_p;
            if !sim.start_job(i, p) {
                break; // strict FIFO: no backfill past the head
            }
        }
    }
}

/// The Fig 11 "Static" strategy: every job runs with a fixed parallelism,
/// FIFO admission, pending queue when the cluster is full.
pub struct StaticScheduler {
    pub fixed_p: u32,
}

impl Scheduler for StaticScheduler {
    fn name(&self) -> &'static str {
        "static"
    }
    fn replan(&mut self, sim: &mut ClusterSim) {
        for i in sim.pending_jobs() {
            if !sim.start_job(i, self.fixed_p) {
                break;
            }
        }
    }
}

/// The Fig 11 "Elastic" strategy (§6.3 synthetic workload, verbatim from
/// the paper): new jobs go to the least-loaded machine; a machine's GPUs
/// are divided uniformly among its jobs; jobs scale out into idle GPUs as
/// long as throughput does not decrease (capped at one machine — beyond
/// it the big-model comm cost makes the gain negative anyway); when the
/// cluster fills up, running jobs shrink (R1-style, respecting the
/// `r`·p_default QoS floor) to admit newcomers.
pub struct ElasticSimple {
    pub default_p: u32,
    /// quality-of-service floor: a job keeps at least ceil(r * default_p)
    pub r: f64,
}

impl ElasticSimple {
    fn min_p(&self) -> u32 {
        ((self.r * self.default_p as f64).ceil() as u32).max(1)
    }

    /// uniform shares of the cluster for `n` jobs (machine-capped;
    /// remainder GPUs spread one-by-one over the first jobs)
    fn shares(&self, sim: &ClusterSim, n: u32) -> Vec<u32> {
        if n == 0 {
            return Vec::new();
        }
        let total = sim.total_gpus();
        let base = total / n;
        let rem = total % n;
        (0..n)
            .map(|i| {
                (base + u32::from(i < rem)).clamp(self.min_p(), sim.hw.gpus_per_machine)
            })
            .collect()
    }

    fn steerable(sim: &ClusterSim, i: usize) -> bool {
        sim.jobs[i].elastic
            && matches!(sim.jobs[i].state,
                JobState::Running { paused_until, .. } if paused_until <= sim.now)
    }
}

impl Scheduler for ElasticSimple {
    fn name(&self) -> &'static str {
        "elastic"
    }
    fn replan(&mut self, sim: &mut ClusterSim) {
        let pending = sim.pending_jobs();
        let mut running = sim.running_jobs();
        running.sort_by_key(|&i| sim.jobs[i].id);
        let n_after = (running.len() + pending.len()) as u32;
        let shares = self.shares(sim, n_after);

        // per-job targets: running jobs first (stable by id), newcomers last
        let targets: Vec<(usize, u32, bool)> = running
            .iter()
            .enumerate()
            .map(|(k, &i)| (i, shares[k], false))
            .chain(
                pending
                    .iter()
                    .enumerate()
                    .map(|(k, &i)| (i, shares[running.len() + k], true)),
            )
            .collect();

        // 1. shrink over-target jobs first (graceful exits are cheap)
        for &(i, target, is_new) in &targets {
            if !is_new && Self::steerable(sim, i) && sim.jobs[i].current_p() > target {
                shrink_to(sim, i, target);
            }
        }
        // 2. admit newcomers at their share
        for &(i, target, is_new) in &targets {
            if is_new {
                let p = target.min(sim.free_gpus().max(1));
                if p >= 1 && sim.free_gpus() >= p {
                    sim.start_job(i, p);
                }
            }
        }
        // 3. grow under-target jobs into remaining idle GPUs, but only
        //    while the throughput gain is non-negative (paper footnote 7)
        for &(i, target, is_new) in &targets {
            if is_new || !Self::steerable(sim, i) {
                continue;
            }
            let p = sim.jobs[i].current_p();
            if p >= target || sim.free_gpus() == 0 {
                continue;
            }
            let want = target.min(p + sim.free_gpus());
            let j = &sim.jobs[i];
            let b = j.global_batch();
            let s_now = gpu_sim::throughput(j.model, p, b, &sim.hw);
            let s_want = gpu_sim::throughput(j.model, want, b, &sim.hw);
            if s_want >= s_now {
                grow_to(sim, i, want);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Tiresias
// ---------------------------------------------------------------------------

/// Discretized two-dimensional least-attained-service scheduler.
/// Jobs sink from G0 to lower-priority queues as their attained service
/// (GPU·s) crosses the queue thresholds; scheduling is priority-then-FIFO;
/// preemption uses checkpoint/restart (modelled as launch overhead on
/// resume). `starve_promote_s`: waiting longer than this re-promotes to G0.
pub struct Tiresias {
    /// attained-service thresholds between queues (GPU·s): e.g. [500, 10_000]
    pub thresholds: Vec<f64>,
    pub starve_promote_s: f64,
    /// last time each job was running (for starvation detection)
    last_active: Vec<f64>,
}

impl Tiresias {
    pub fn new(thresholds: Vec<f64>) -> Tiresias {
        Tiresias { thresholds, starve_promote_s: 6.0 * 3600.0, last_active: Vec::new() }
    }

    fn queue_of(&self, attained: f64) -> usize {
        self.thresholds.iter().take_while(|&&t| attained >= t).count()
    }

    /// priority ordering: queue asc, then submit time asc
    fn plan(&mut self, sim: &mut ClusterSim) -> Vec<usize> {
        if self.last_active.len() < sim.jobs.len() {
            self.last_active.resize(sim.jobs.len(), 0.0);
        }
        let mut candidates: Vec<usize> = Vec::new();
        for i in 0..sim.jobs.len() {
            let j = &sim.jobs[i];
            if j.submit_s > sim.now || matches!(j.state, JobState::Finished { .. }) {
                continue;
            }
            candidates.push(i);
        }
        for &i in &candidates {
            let mut q = self.queue_of(sim.jobs[i].attained_gpu_s);
            // starvation: long-waiting jobs promoted to G0 (§5.1)
            let waiting = matches!(sim.jobs[i].state, JobState::Pending);
            if waiting && sim.now - self.last_active[i].max(sim.jobs[i].submit_s) > self.starve_promote_s {
                q = 0;
            }
            if !waiting {
                self.last_active[i] = sim.now;
            }
            sim.jobs[i].queue = q;
        }
        candidates.sort_by(|&a, &b| {
            (sim.jobs[a].queue, sim.jobs[a].submit_s)
                .partial_cmp(&(sim.jobs[b].queue, sim.jobs[b].submit_s))
                .unwrap()
        });
        // admit in priority order while capacity lasts
        let mut capacity = sim.total_gpus();
        let mut admitted = Vec::new();
        for &i in &candidates {
            let p = sim.jobs[i].requested_p;
            if p <= capacity {
                capacity -= p;
                admitted.push(i);
            }
        }
        // preempt running jobs not admitted, then start admitted pending
        for &i in &candidates {
            let running = matches!(
                sim.jobs[i].state,
                JobState::Running { .. } | JobState::ScalingOut { .. }
            );
            if running && !admitted.contains(&i) {
                sim.preempt_job(i);
            }
        }
        admitted
    }
}

impl Scheduler for Tiresias {
    fn name(&self) -> &'static str {
        "tiresias"
    }
    fn replan(&mut self, sim: &mut ClusterSim) {
        let admitted = self.plan(sim);
        for i in admitted {
            if matches!(sim.jobs[i].state, JobState::Pending) {
                let p = sim.jobs[i].requested_p;
                sim.start_job(i, p);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Elastic-Tiresias (§5.1)
// ---------------------------------------------------------------------------

/// Tiresias + the paper's two elasticity rules:
///  * **R1 compaction** — when more than `n_waiting_threshold` jobs wait,
///    shrink running jobs (never below ceil(r·p_requested), never jobs in
///    G0) to free GPUs for the highest-priority pending jobs, choosing the
///    shrink that maximises the GPU-efficiency gain;
///  * **R2 expansion** — when nothing waits and GPUs idle, grow the job
///    with the largest marginal throughput gain one GPU at a time.
pub struct ElasticTiresias {
    pub base: Tiresias,
    pub n_waiting_threshold: usize,
    pub r: f64,
    /// ablation switches (both on = the paper's Elastic-Tiresias)
    pub enable_r1: bool,
    pub enable_r2: bool,
}

impl ElasticTiresias {
    pub fn new(thresholds: Vec<f64>, n_waiting_threshold: usize, r: f64) -> ElasticTiresias {
        ElasticTiresias {
            base: Tiresias::new(thresholds),
            n_waiting_threshold,
            r,
            enable_r1: true,
            enable_r2: true,
        }
    }

    fn min_p(&self, requested: u32) -> u32 {
        ((self.r * requested as f64).ceil() as u32).max(1)
    }

    /// R2 expansion primitive: one Table-1 `scale_out` adding one worker
    /// per `machines` entry. Written against [`JobControl`], so the SAME
    /// policy code drives a [`SimJobHandle`](crate::cluster::SimJobHandle)
    /// in simulation and a live `ElasticTrainer` — in-process or behind
    /// `api::JobClient` over TCP. §3.1 in-flight rejections are retried
    /// with backoff by [`JobControlExt`].
    pub fn expand_job(
        job: &mut (impl JobControl + ?Sized),
        machines: Vec<String>,
    ) -> Result<(), ElasticError> {
        job.scale_out_retry(machines, RETRY_T)
    }

    /// R0/R1 shrink primitive: remove the `n` most recently added workers
    /// (`status` → victim ids → Table-1 `scale_in`), same-code-everywhere
    /// like [`ElasticTiresias::expand_job`].
    pub fn shrink_job(
        job: &mut (impl JobControl + ?Sized),
        n: u32,
    ) -> Result<(), ElasticError> {
        if n == 0 {
            return Ok(());
        }
        let st = job.status()?;
        if st.workers.len() as u32 <= n {
            return Err(ElasticError::InvalidRequest(
                "shrink would remove every worker".into(),
            ));
        }
        let victims = st.workers[st.workers.len() - n as usize..].to_vec();
        job.scale_in_retry(victims, RETRY_T)
    }

    /// efficiency gain of shrinking job i by one GPU
    fn shrink_gain(sim: &ClusterSim, i: usize, max_p: u32) -> f64 {
        let j = &sim.jobs[i];
        let p = j.current_p();
        if p <= 1 {
            return f64::MIN;
        }
        let b = j.global_batch();
        gpu_sim::efficiency(j.model, p - 1, b, max_p, &sim.hw)
            - gpu_sim::efficiency(j.model, p, b, max_p, &sim.hw)
    }

    fn shrinkable(&self, sim: &ClusterSim, i: usize) -> bool {
        let j = &sim.jobs[i];
        j.elastic
            && j.queue > 0 // never shrink G0 jobs (§5.1)
            && matches!(j.state, JobState::Running { paused_until, .. } if paused_until <= sim.now)
            && j.current_p() > self.min_p(j.requested_p)
    }
}

impl Scheduler for ElasticTiresias {
    fn name(&self) -> &'static str {
        "elastic-tiresias"
    }
    fn replan(&mut self, sim: &mut ClusterSim) {
        // base Tiresias allocation first
        let admitted = self.base.plan(sim);
        for &i in &admitted {
            if matches!(sim.jobs[i].state, JobState::Pending) {
                let p = sim.jobs[i].requested_p;
                sim.start_job(i, p);
            }
        }

        // R0 reclaim: expansion borrows only *idle* GPUs (§2.2: "scaled in
        // to return the resources when they need to be re-allocated") — as
        // soon as jobs wait, expanded jobs shrink back toward their
        // requested parallelism so newcomers can start. Graceful exits are
        // cheap, so reclaim is immediate.
        if self.enable_r2 {
            let mut pending = sim.pending_jobs();
            pending.sort_by(|&a, &b| {
                (sim.jobs[a].queue, sim.jobs[a].submit_s)
                    .partial_cmp(&(sim.jobs[b].queue, sim.jobs[b].submit_s))
                    .unwrap()
            });
            for w in pending {
                let want = sim.jobs[w].requested_p;
                if sim.free_gpus() >= want {
                    sim.start_job(w, want);
                    continue;
                }
                // reclaim from the most over-allocated expanded jobs first
                let mut expanded: Vec<usize> = sim
                    .running_jobs()
                    .into_iter()
                    .filter(|&i| {
                        sim.jobs[i].elastic
                            && sim.jobs[i].current_p() > sim.jobs[i].requested_p
                            && matches!(sim.jobs[i].state,
                                JobState::Running { paused_until, .. } if paused_until <= sim.now)
                    })
                    .collect();
                expanded.sort_by_key(|&i| {
                    std::cmp::Reverse(sim.jobs[i].current_p() - sim.jobs[i].requested_p)
                });
                for i in expanded {
                    if sim.free_gpus() >= want {
                        break;
                    }
                    let deficit = want - sim.free_gpus();
                    let surplus = sim.jobs[i].current_p() - sim.jobs[i].requested_p;
                    let give = surplus.min(deficit);
                    let p = sim.jobs[i].current_p();
                    shrink_to(sim, i, p - give);
                }
                if sim.free_gpus() >= want {
                    sim.start_job(w, want);
                } else {
                    break;
                }
            }
        }

        // R1 compaction — §5.1 intent: when the queue builds up, shrink
        // large/low-priority running jobs to get SMALL/high-priority jobs
        // (G0: the program-check / hyperparameter-search jobs Tiresias
        // protects) running and prevent head-of-line blocking. Compacting
        // for arbitrary large waiters under sustained overload inverts the
        // SJF discipline and inflates everyone's JCT (see the
        // ablation_elastic_rules example), so only G0 waiters qualify.
        let mut waiting = sim.pending_jobs();
        if self.enable_r1 && waiting.len() > self.n_waiting_threshold {
            waiting.retain(|&w| sim.jobs[w].queue == 0);
            waiting.sort_by(|&a, &b| {
                sim.jobs[a].submit_s.partial_cmp(&sim.jobs[b].submit_s).unwrap()
            });
            for w in waiting {
                let want = sim.jobs[w].requested_p;
                let max_p = sim.max_p_norm;
                let mut guard = 0;
                while sim.free_gpus() < want {
                    guard += 1;
                    if guard > 4096 {
                        break;
                    }
                    // victim with the best efficiency gain from shrinking
                    let mut best: Option<(usize, f64)> = None;
                    for i in sim.running_jobs() {
                        if self.shrinkable(sim, i) {
                            let g = Self::shrink_gain(sim, i, max_p);
                            if best.map(|(_, bg)| g > bg).unwrap_or(true) {
                                best = Some((i, g));
                            }
                        }
                    }
                    match best {
                        Some((i, _)) => {
                            let p = sim.jobs[i].current_p();
                            if !shrink_to(sim, i, p - 1) {
                                break;
                            }
                        }
                        None => break,
                    }
                }
                if sim.free_gpus() >= want {
                    sim.start_job(w, want);
                } else {
                    break; // can't help lower-priority waiters either
                }
            }
        }

        // R2 expansion: allocate idle GPUs greedily by marginal gain, then
        // merge each job's consecutive +1 grants into ONE scale operation
        // (one topology switch — §5.2's migration-merging idea applied to
        // expansion; issuing them one at a time would pay the scale-out
        // e2e latency per GPU)
        if self.enable_r2 && sim.pending_jobs().is_empty() && sim.free_gpus() > 0 {
            let mut budget = sim.free_gpus();
            // virtual parallelism during the greedy pass
            let mut virt: std::collections::HashMap<usize, u32> = std::collections::HashMap::new();
            let candidates: Vec<usize> = sim
                .running_jobs()
                .into_iter()
                .filter(|&i| {
                    sim.jobs[i].elastic
                        && matches!(sim.jobs[i].state,
                            JobState::Running { paused_until, .. } if paused_until <= sim.now)
                })
                .collect();
            for &i in &candidates {
                virt.insert(i, sim.jobs[i].current_p());
            }
            let mut guard = 0;
            while budget > 0 {
                guard += 1;
                if guard > 4096 {
                    break;
                }
                let mut best: Option<(usize, f64)> = None;
                for &i in &candidates {
                    let p = virt[&i];
                    let j = &sim.jobs[i];
                    let b = j.global_batch();
                    let s_p = gpu_sim::throughput(j.model, p, b, &sim.hw);
                    let s_p1 = gpu_sim::throughput(j.model, p + 1, b, &sim.hw);
                    let g = (s_p1 - s_p) / s_p;
                    if g > 0.0 && best.map(|(_, bg)| g > bg).unwrap_or(true) {
                        best = Some((i, g));
                    }
                }
                match best {
                    Some((i, _)) => {
                        *virt.get_mut(&i).unwrap() += 1;
                        budget -= 1;
                    }
                    None => break,
                }
            }
            for &i in &candidates {
                let target = virt[&i];
                if target > sim.jobs[i].current_p() {
                    grow_to(sim, i, target);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ScaleMode;
    use crate::gpu_sim::Dnn;
    use crate::metrics::JctStats;
    use crate::trace::TraceJob;

    fn mk_job(id: u64, submit: f64, gpus: u32, dur: f64, model: Dnn) -> TraceJob {
        TraceJob { id, submit_s: submit, gpus, service_gpu_s: dur * gpus as f64, model }
    }

    #[test]
    fn tiresias_queue_sinking() {
        let t = Tiresias::new(vec![500.0, 10_000.0]);
        assert_eq!(t.queue_of(0.0), 0);
        assert_eq!(t.queue_of(499.0), 0);
        assert_eq!(t.queue_of(500.0), 1);
        assert_eq!(t.queue_of(9_999.0), 1);
        assert_eq!(t.queue_of(10_000.0), 2);
    }

    #[test]
    fn tiresias_small_job_preempts_large() {
        // a long 8-GPU job holds the machine; a tiny job arrives later and
        // must run before the big one finishes (shortest-job-first-ish)
        let trace = vec![
            mk_job(0, 0.0, 8, 100_000.0, Dnn::ResNet50),
            mk_job(1, 5_000.0, 8, 60.0, Dnn::ResNet50),
        ];
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
        let mut sched = Tiresias::new(vec![500.0, 10_000.0]);
        sim.run(&mut sched, 5e6);
        let jct_small = sim.jobs[1].jct().unwrap();
        // without preemption it would wait ~95,000 s for the big job
        assert!(jct_small < 10_000.0, "small job JCT {jct_small}");
    }

    #[test]
    fn static_vs_elastic_cluster_efficiency_during_ramp() {
        // Fig 11 setup (scaled down): 2 machines × 8 GPUs, job every 30 s,
        // long jobs. The paper's measurement window is the ramp (jobs
        // arriving, none finishing): Static leaves GPUs idle while Elastic
        // expands into them, so Elastic's *cluster* efficiency is higher
        // (its per-GPU efficiency is lower early on — Fig 11b).
        let trace: Vec<TraceJob> =
            (0..8).map(|i| mk_job(i, i as f64 * 120.0, 4, 5_000.0, Dnn::ResNet50)).collect();
        let window = 1_100.0;
        let mut s_static = ClusterSim::new(2, 8, &trace, ScaleMode::Edl);
        s_static.run(&mut StaticScheduler { fixed_p: 4 }, window);

        let mut s_elastic = ClusterSim::new(2, 8, &trace, ScaleMode::Edl);
        s_elastic.run(&mut ElasticSimple { default_p: 4, r: 0.5 }, window);

        let ce_static = s_static.cluster_eff_ts.time_weighted_mean();
        let ce_elastic = s_elastic.cluster_eff_ts.time_weighted_mean();
        assert!(
            ce_elastic > ce_static,
            "elastic should beat static on cluster efficiency: {ce_elastic:.3} vs {ce_static:.3}"
        );
    }

    #[test]
    fn elastic_tiresias_expansion_reduces_jct_when_underloaded() {
        // sequential 2-GPU jobs on an 8-GPU machine: Tiresias leaves 6
        // GPUs idle; R2 expansion soaks them and finishes each job faster
        let trace: Vec<TraceJob> =
            (0..5).map(|i| mk_job(i, i as f64 * 3_000.0, 2, 1_200.0, Dnn::ResNet50)).collect();
        let mut base_sim = ClusterSim::new(1, 8, &trace, ScaleMode::Edl);
        base_sim.run(&mut Tiresias::new(vec![500.0, 10_000.0]), 5e6);
        let base_stats = JctStats::from(&base_sim.jcts());

        let mut el_sim = ClusterSim::new(1, 8, &trace, ScaleMode::Edl);
        el_sim.run(&mut ElasticTiresias::new(vec![500.0, 10_000.0], 10, 0.5), 5e6);
        let el_stats = JctStats::from(&el_sim.jcts());

        assert_eq!(base_stats.count, trace.len());
        assert_eq!(el_stats.count, trace.len());
        assert!(
            el_stats.mean < 0.8 * base_stats.mean,
            "expansion should cut JCT: elastic {:.0} vs tiresias {:.0}",
            el_stats.mean,
            base_stats.mean
        );
    }

    #[test]
    fn elastic_tiresias_no_regression_on_mixed_load() {
        // mixed over/under-loaded phases: elasticity must not materially
        // hurt JCT even when its rules fire frequently (the decisive win
        // shows on the full overloaded trace — see table4_fig12 bench)
        let mut trace = Vec::new();
        for w in 0..12u64 {
            let big = w % 3 == 0;
            trace.push(mk_job(
                w,
                w as f64 * 120.0,
                if big { 8 } else { 2 },
                if big { 4_000.0 } else { 300.0 },
                if big { Dnn::VGG19 } else { Dnn::ResNet50 },
            ));
        }
        let mut base_sim = ClusterSim::new(2, 8, &trace, ScaleMode::Edl);
        base_sim.run(&mut Tiresias::new(vec![500.0, 10_000.0]), 5e6);
        let base_stats = JctStats::from(&base_sim.jcts());

        let mut el_sim = ClusterSim::new(2, 8, &trace, ScaleMode::Edl);
        el_sim.run(&mut ElasticTiresias::new(vec![500.0, 10_000.0], 2, 0.5), 5e6);
        let el_stats = JctStats::from(&el_sim.jcts());

        assert_eq!(el_stats.count, trace.len());
        assert!(
            el_stats.mean < 1.15 * base_stats.mean,
            "elastic-tiresias {:.0} regressed vs tiresias {:.0}",
            el_stats.mean,
            base_stats.mean
        );
    }

    #[test]
    fn r2_expansion_fills_idle_gpus() {
        let trace = vec![mk_job(0, 0.0, 2, 5_000.0, Dnn::ResNet50)];
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
        let mut sched = ElasticTiresias::new(vec![500.0], 10, 0.5);
        // run a short while: the single job should be expanded beyond 2
        sim.run(&mut sched, 500.0);
        assert!(
            sim.jobs[0].current_p() > 2,
            "R2 should expand the only job: p={}",
            sim.jobs[0].current_p()
        );
    }

    #[test]
    fn r1_respects_qos_floor() {
        // one running 8-GPU job (out of G0) + many waiters: compaction must
        // not shrink below ceil(r * requested)
        let mut trace = vec![mk_job(0, 0.0, 8, 100_000.0, Dnn::ResNet50)];
        for i in 1..8 {
            trace.push(mk_job(i, 10_000.0, 4, 2_000.0, Dnn::ResNet50));
        }
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Edl);
        let mut sched = ElasticTiresias::new(vec![500.0], 1, 0.5);
        sim.run(&mut sched, 11_000.0);
        let p = sim.jobs[0].current_p();
        assert!(p >= 4 || matches!(sim.jobs[0].state, JobState::Pending),
            "job 0 shrunk below QoS floor: p={p}");
    }

    #[test]
    fn inelastic_jobs_skipped_by_rules() {
        let trace = vec![mk_job(0, 0.0, 2, 10_000.0, Dnn::ResNet50)];
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
        sim.jobs[0].elastic = false;
        let mut sched = ElasticTiresias::new(vec![500.0], 10, 0.5);
        sim.run(&mut sched, 300.0);
        assert_eq!(sim.jobs[0].current_p(), 2, "inelastic job must keep its parallelism");
    }
}
