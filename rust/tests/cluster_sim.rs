//! Cluster-simulator integration + property tests: conservation laws
//! (every job finishes exactly once, GPUs never oversubscribed or leaked),
//! scheduler comparisons under randomized workloads, and scale-mode
//! orderings (Ideal ≤ EDL ≤ stop-resume in JCT terms).

use edl::cluster::{ClusterSim, JobState, ScaleMode};
use edl::gpu_sim::{Dnn, ALL_DNNS};
use edl::metrics::JctStats;
use edl::schedulers::{ElasticTiresias, FifoScheduler, Tiresias};
use edl::trace::TraceJob;
use edl::util::prop;
use edl::util::rng::Pcg;

fn random_trace(rng: &mut Pcg, n: usize) -> Vec<TraceJob> {
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            t += rng.exponential(1.0 / 120.0);
            let gpus = *rng.choice(&[1u32, 2, 4, 8]);
            TraceJob {
                id: i as u64,
                submit_s: t,
                gpus,
                service_gpu_s: rng.uniform(50.0, 3_000.0) * gpus as f64,
                model: *rng.choice(&ALL_DNNS),
            }
        })
        .collect()
}

#[test]
fn all_jobs_finish_and_gpus_are_conserved_property() {
    prop::check("sim-conservation", 12, |rng| {
        let n = 10 + rng.gen_range(40) as usize;
        let trace = random_trace(rng, n);
        let machines = 1 + rng.gen_range(4) as usize;
        let mode = *rng.choice(&[ScaleMode::Ideal, ScaleMode::Edl, ScaleMode::StopResume]);
        let mut sim = ClusterSim::new(machines, 8, &trace, mode);
        let use_elastic = rng.bool_with(0.5);
        if use_elastic {
            sim.run(&mut ElasticTiresias::new(vec![500.0, 10_000.0], 5, 0.5), 1e9);
        } else {
            sim.run(&mut Tiresias::new(vec![500.0, 10_000.0]), 1e9);
        }
        // every job finished exactly once
        for j in &sim.jobs {
            if !matches!(j.state, JobState::Finished { .. }) {
                return Err(format!("job {} never finished ({:?})", j.id, j.state));
            }
            let jct = j.jct().ok_or("finished job without JCT")?;
            if jct <= 0.0 || !jct.is_finite() {
                return Err(format!("job {} bad JCT {jct}", j.id));
            }
            // work conservation: done == total
            if (j.done_work_s - j.total_work_s).abs() > 1e-6 * j.total_work_s + 1e-6 {
                return Err(format!("job {} work mismatch", j.id));
            }
        }
        // all GPUs returned
        if sim.free_gpus() != sim.total_gpus() {
            return Err(format!("leaked GPUs: {}/{}", sim.free_gpus(), sim.total_gpus()));
        }
        Ok(())
    });
}

#[test]
fn utilization_never_exceeds_one() {
    let mut rng = Pcg::seeded(4);
    let trace = random_trace(&mut rng, 60);
    let mut sim = ClusterSim::new(2, 8, &trace, ScaleMode::Edl);
    sim.run(&mut ElasticTiresias::new(vec![500.0], 3, 0.5), 1e9);
    for &(_, u) in &sim.util_ts.points {
        assert!((0.0..=1.0 + 1e-9).contains(&u), "util {u}");
    }
    for &(_, e) in &sim.cluster_eff_ts.points {
        assert!((0.0..=1.0 + 1e-9).contains(&e), "cluster eff {e}");
    }
}

#[test]
fn ideal_dominates_edl_dominates_stop_resume() {
    // same workload + same elastic scheduler; only the scale-cost model
    // changes: JCT(Ideal) <= JCT(EDL) <= JCT(SR) (allowing small noise)
    let mut rng = Pcg::seeded(9);
    let trace = random_trace(&mut rng, 40);
    let mut means = Vec::new();
    for mode in [ScaleMode::Ideal, ScaleMode::Edl, ScaleMode::StopResume] {
        let mut sim = ClusterSim::new(2, 8, &trace, mode);
        sim.run(&mut ElasticTiresias::new(vec![500.0, 10_000.0], 5, 0.5), 1e9);
        means.push(JctStats::from(&sim.jcts()).mean);
    }
    assert!(means[0] <= means[1] * 1.02, "Ideal {} vs EDL {}", means[0], means[1]);
    assert!(means[1] <= means[2] * 1.02, "EDL {} vs SR {}", means[1], means[2]);
}

#[test]
fn fifo_order_respected_without_preemption() {
    let trace: Vec<TraceJob> = (0..4)
        .map(|i| TraceJob {
            id: i,
            submit_s: i as f64,
            gpus: 8,
            service_gpu_s: 800.0,
            model: Dnn::ResNet50,
        })
        .collect();
    let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
    sim.run(&mut FifoScheduler::default(), 1e9);
    let mut finishes: Vec<(u64, f64)> =
        sim.jobs.iter().map(|j| (j.id, j.finish_s.unwrap())).collect();
    finishes.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let order: Vec<u64> = finishes.iter().map(|&(id, _)| id).collect();
    assert_eq!(order, vec![0, 1, 2, 3]);
}

#[test]
fn tiresias_beats_fifo_on_mixed_sizes() {
    // classic SJF-vs-FCFS result: short jobs behind a long one
    let mut trace = vec![TraceJob {
        id: 0,
        submit_s: 0.0,
        gpus: 8,
        service_gpu_s: 8.0 * 50_000.0,
        model: Dnn::ResNet50,
    }];
    for i in 1..10 {
        trace.push(TraceJob {
            id: i,
            submit_s: 10.0 * i as f64,
            gpus: 2,
            service_gpu_s: 2.0 * 100.0,
            model: Dnn::GoogLeNet,
        });
    }
    let mut fifo_sim = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
    fifo_sim.run(&mut FifoScheduler::default(), 1e9);
    let fifo = JctStats::from(&fifo_sim.jcts());

    let mut tir_sim = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
    tir_sim.run(&mut Tiresias::new(vec![500.0, 10_000.0]), 1e9);
    let tir = JctStats::from(&tir_sim.jcts());

    assert!(
        tir.median < 0.2 * fifo.median,
        "tiresias median {} should crush fifo {}",
        tir.median,
        fifo.median
    );
}

#[test]
fn stop_resume_scaling_pauses_job() {
    // direct check of the SR cost model: a scale under SR delays
    // completion by roughly the restart overhead vs Ideal
    let trace = vec![TraceJob {
        id: 0,
        submit_s: 0.0,
        gpus: 2,
        service_gpu_s: 2.0 * 300.0,
        model: Dnn::ResNet50,
    }];
    fn scale_at(sim: &mut ClusterSim, done: &mut bool) {
        for i in sim.pending_jobs() {
            sim.start_job(i, 2);
        }
        if !*done && sim.now > 50.0 {
            for i in sim.running_jobs() {
                if sim.scale_job(i, 4) {
                    *done = true;
                }
            }
        }
    }
    let mut ideal = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
    let mut done = false;
    ideal.run_with(|sim| scale_at(sim, &mut done), 1e9);
    let mut sr = ClusterSim::new(1, 8, &trace, ScaleMode::StopResume);
    let mut done = false;
    sr.run_with(|sim| scale_at(sim, &mut done), 1e9);
    let d_ideal = ideal.jobs[0].jct().unwrap();
    let d_sr = sr.jobs[0].jct().unwrap();
    // SR pays launch (~40s) + restart (~45s at p=4)
    assert!(d_sr > d_ideal + 40.0, "ideal={d_ideal:.0} sr={d_sr:.0}");
}
