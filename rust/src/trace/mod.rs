//! Philly-like workload trace generation and analysis.
//!
//! The paper's Figures 2, 3, 12 and Table 4 are driven by Microsoft's
//! production trace (≈2,300 GPUs, two months, >100,000 jobs). The trace is
//! not shipped here, so this module generates a synthetic trace calibrated
//! to the statistics the paper reports (DESIGN.md §1):
//!
//!  * job sizes (parallelism × runtime) span orders of magnitude with
//!    p20 ≈ 85 GPU·s and p90 ≈ 58,330 GPU·s (Fig 2b) — a lognormal body
//!    with a Pareto tail;
//!  * arrivals follow a diurnal + weekly pattern over two months so the
//!    cluster oscillates between saturation (queueing) and slack (Fig 2a);
//!  * idle intervals between consecutive jobs on a GPU come out power-law
//!    distributed with ≈40% under 4 minutes (Fig 3) — an emergent property
//!    measured by replaying the trace through the cluster simulator.

use crate::gpu_sim::{Dnn, ALL_DNNS};
use crate::util::rng::Pcg;
use crate::util::stats;

/// One training job in the trace.
#[derive(Debug, Clone)]
pub struct TraceJob {
    pub id: u64,
    /// submission time (s from trace start)
    pub submit_s: f64,
    /// user-requested parallelism
    pub gpus: u32,
    /// total service demand at the requested parallelism (GPU·s):
    /// gpus × runtime-at-requested-parallelism
    pub service_gpu_s: f64,
    pub model: Dnn,
}

impl TraceJob {
    /// runtime (s) when running at the requested parallelism
    pub fn duration_s(&self) -> f64 {
        self.service_gpu_s / self.gpus as f64
    }
}

/// Trace generation parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub n_jobs: usize,
    /// trace span in seconds (the paper's data covers two months)
    pub span_s: f64,
    /// mean arrival-rate multiplier at diurnal peak vs trough
    pub peak_to_trough: f64,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_jobs: 20_000,
            span_s: 60.0 * 86_400.0,
            peak_to_trough: 4.0,
            seed: 20_19,
        }
    }
}

/// Distribution of requested parallelism (powers of two dominate in the
/// Philly data; most jobs are small).
fn sample_parallelism(rng: &mut Pcg) -> u32 {
    const P: [(u32, f64); 7] =
        [(1, 0.45), (2, 0.18), (4, 0.16), (8, 0.12), (16, 0.06), (32, 0.02), (64, 0.01)];
    let w: Vec<f64> = P.iter().map(|&(_, w)| w).collect();
    P[rng.weighted_index(&w)].0
}

/// Job size (GPU·s): lognormal body + Pareto tail, calibrated so the
/// quantiles match Fig 2b (p20 ≈ 85, p90 ≈ 58,330 GPU·s).
fn sample_service(rng: &mut Pcg) -> f64 {
    if rng.bool_with(0.92) {
        // body: ln-space mean ~ ln(1200), sigma ~ 2.6
        rng.lognormal(7.1, 2.6).clamp(1.0, 5e5)
    } else {
        // heavy tail: multi-day distributed jobs
        rng.pareto(5e4, 0.9).min(5e6)
    }
}

/// Diurnal+weekly arrival intensity at time t (relative, mean ≈ 1).
pub fn arrival_intensity(t_s: f64, peak_to_trough: f64) -> f64 {
    let day = 86_400.0;
    let hour_phase = (t_s % day) / day * std::f64::consts::TAU;
    // peak mid-day, trough at night
    let diurnal = 1.0 + (peak_to_trough - 1.0) / (peak_to_trough + 1.0) * (hour_phase - std::f64::consts::PI).cos();
    let weekday = if ((t_s / day) as u64 % 7) >= 5 { 0.55 } else { 1.0 };
    diurnal * weekday
}

/// Generate a calibrated synthetic trace.
pub fn generate(cfg: &TraceConfig) -> Vec<TraceJob> {
    let mut rng = Pcg::seeded(cfg.seed);
    // thinning-based nonhomogeneous Poisson arrivals
    let base_rate = cfg.n_jobs as f64 / cfg.span_s * 1.6; // oversample, thin to intensity
    let mut jobs = Vec::with_capacity(cfg.n_jobs);
    let mut t = 0.0;
    let mut id = 0;
    while jobs.len() < cfg.n_jobs {
        t += rng.exponential(base_rate);
        if t > cfg.span_s {
            // wrap: keep density constant if we ran past the span
            t %= cfg.span_s;
        }
        let intensity = arrival_intensity(t, cfg.peak_to_trough);
        if !rng.bool_with((intensity / cfg.peak_to_trough).min(1.0)) {
            continue;
        }
        let gpus = sample_parallelism(&mut rng);
        let service = sample_service(&mut rng);
        jobs.push(TraceJob {
            id,
            submit_s: t,
            gpus,
            service_gpu_s: service,
            model: *rng.choice(&ALL_DNNS),
        });
        id += 1;
    }
    jobs.sort_by(|a, b| a.submit_s.partial_cmp(&b.submit_s).unwrap());
    for (i, j) in jobs.iter_mut().enumerate() {
        j.id = i as u64;
    }
    jobs
}

/// Summary statistics used by the Fig 2 benchmark.
pub struct TraceStats {
    pub n_jobs: usize,
    pub size_p20: f64,
    pub size_p50: f64,
    pub size_p90: f64,
    pub size_p99: f64,
    /// offered load (GPU·s demanded per second) in hourly buckets
    pub hourly_load: Vec<f64>,
}

pub fn stats_of(jobs: &[TraceJob], span_s: f64) -> TraceStats {
    let sizes: Vec<f64> = jobs.iter().map(|j| j.service_gpu_s).collect();
    let hours = (span_s / 3600.0).ceil() as usize;
    let mut hourly = vec![0.0; hours];
    for j in jobs {
        let h = (j.submit_s / 3600.0) as usize;
        if h < hours {
            hourly[h] += j.service_gpu_s;
        }
    }
    for v in hourly.iter_mut() {
        *v /= 3600.0;
    }
    TraceStats {
        n_jobs: jobs.len(),
        size_p20: stats::percentile(&sizes, 20.0),
        size_p50: stats::percentile(&sizes, 50.0),
        size_p90: stats::percentile(&sizes, 90.0),
        size_p99: stats::percentile(&sizes, 99.0),
        hourly_load: hourly,
    }
}

/// Save/load traces as a simple line format (id submit gpus service model).
pub fn save(jobs: &[TraceJob], path: &std::path::Path) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for j in jobs {
        writeln!(f, "{} {} {} {} {}", j.id, j.submit_s, j.gpus, j.service_gpu_s, j.model.spec().name)?;
    }
    Ok(())
}

pub fn load(path: &std::path::Path) -> std::io::Result<Vec<TraceJob>> {
    let text = std::fs::read_to_string(path)?;
    let mut jobs = Vec::new();
    for line in text.lines() {
        let mut it = line.split_whitespace();
        let id = it.next().unwrap().parse().unwrap();
        let submit_s = it.next().unwrap().parse().unwrap();
        let gpus = it.next().unwrap().parse().unwrap();
        let service_gpu_s = it.next().unwrap().parse().unwrap();
        let model = Dnn::by_name(it.next().unwrap()).unwrap();
        jobs.push(TraceJob { id, submit_s, gpus, service_gpu_s, model });
    }
    Ok(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_trace() -> Vec<TraceJob> {
        generate(&TraceConfig { n_jobs: 5_000, span_s: 14.0 * 86_400.0, ..Default::default() })
    }

    #[test]
    fn job_count_and_ordering() {
        let jobs = small_trace();
        assert_eq!(jobs.len(), 5_000);
        assert!(jobs.windows(2).all(|w| w[0].submit_s <= w[1].submit_s));
        assert!(jobs.iter().enumerate().all(|(i, j)| j.id == i as u64));
    }

    #[test]
    fn size_quantiles_match_paper_order_of_magnitude() {
        // Fig 2b: p20 = 85 GPU·s, p90 = 58,330 GPU·s. Accept the right
        // orders of magnitude (calibration, not exact replication).
        let jobs = generate(&TraceConfig { n_jobs: 30_000, ..Default::default() });
        let st = stats_of(&jobs, 60.0 * 86_400.0);
        assert!(st.size_p20 > 8.0 && st.size_p20 < 900.0, "p20={}", st.size_p20);
        assert!(st.size_p90 > 6_000.0 && st.size_p90 < 600_000.0, "p90={}", st.size_p90);
        assert!(st.size_p90 / st.size_p20 > 100.0, "spread too small");
    }

    #[test]
    fn parallelism_mostly_small_powers_of_two() {
        let jobs = small_trace();
        assert!(jobs.iter().all(|j| j.gpus.is_power_of_two()));
        let small = jobs.iter().filter(|j| j.gpus <= 4).count();
        assert!(small as f64 > 0.6 * jobs.len() as f64);
    }

    #[test]
    fn load_varies_over_time() {
        // Fig 2a: the cluster oscillates between saturation and slack
        let jobs = small_trace();
        let st = stats_of(&jobs, 14.0 * 86_400.0);
        let peak = stats::percentile(&st.hourly_load, 95.0);
        let trough = stats::percentile(&st.hourly_load, 5.0);
        assert!(peak > 2.0 * trough.max(1e-9), "peak={peak} trough={trough}");
    }

    #[test]
    fn intensity_diurnal_shape() {
        let noon = arrival_intensity(12.0 * 3600.0, 4.0);
        let midnight = arrival_intensity(0.0, 4.0);
        assert!(noon > midnight, "noon={noon} midnight={midnight}");
        // weekend dip (day 5 is a weekend day from trace start)
        let weekday = arrival_intensity(2.0 * 86_400.0 + 43_200.0, 4.0);
        let weekend = arrival_intensity(5.0 * 86_400.0 + 43_200.0, 4.0);
        assert!(weekend < weekday);
    }

    #[test]
    fn save_load_roundtrip() {
        let jobs = generate(&TraceConfig { n_jobs: 100, ..Default::default() });
        let tmp = std::env::temp_dir().join("edl_trace_test.txt");
        save(&jobs, &tmp).unwrap();
        let back = load(&tmp).unwrap();
        assert_eq!(back.len(), jobs.len());
        for (a, b) in jobs.iter().zip(&back) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.gpus, b.gpus);
            assert!((a.service_gpu_s - b.service_gpu_s).abs() < 1e-6);
            assert_eq!(a.model, b.model);
        }
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn deterministic_for_seed() {
        let a = generate(&TraceConfig { n_jobs: 500, seed: 1, ..Default::default() });
        let b = generate(&TraceConfig { n_jobs: 500, seed: 1, ..Default::default() });
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.submit_s == y.submit_s && x.service_gpu_s == y.service_gpu_s));
    }
}
