//! Transient idle-GPU experiment (§6.2 / Fig 10b) on the REAL protocol:
//! a job runs with 4 persistent workers; 1 transient worker joins via
//! stop-free scale-out and is revoked via graceful exit every interval.
//! Compares achieved throughput against the no-transient Baseline and the
//! zero-overhead Ideal, using the SimBackend with realistic per-step
//! compute and context-preparation delays so the protocol's overheads are
//! what is being measured.
//!
//!     cargo run --release --example transient_resources -- \
//!         --interval-s 8 --cycles 3 --compute-ms 40 --ctx-prep-ms 2000

use edl::coordinator::{ElasticTrainer, TrainerConfig};
use edl::data::corpus::Corpus;
use edl::util::args::Args;
use edl::worker::SimBackend;
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Clone, Copy, PartialEq)]
enum Scheme {
    /// never use the idle GPU: 4 workers throughout
    Baseline,
    /// borrow it with stop-free scale-out / graceful exit
    Edl,
    /// zero-overhead upper bound: the 5th worker is simply persistent
    Ideal,
}

fn run_scheme(
    name: &str,
    scheme: Scheme,
    interval: Duration,
    cycles: u32,
    compute_ms: u64,
    ctx_prep_ms: u64,
) -> f64 {
    let backend = SimBackend { compute_ms, ctx_prep_ms, ..SimBackend::fast(4096) };
    let corpus = Arc::new(Corpus::markov(256, 16, 1 << 20, 3));
    let cfg = TrainerConfig {
        agg_batch: 32,
        n_partitions: 4096,
        approx_recovery: true,
        ..Default::default()
    };
    let n0 = if scheme == Scheme::Ideal { 5 } else { 4 };
    let t = ElasticTrainer::start(cfg, Arc::new(backend), corpus, n0);
    assert!(t.wait_step(3, Duration::from_secs(120)), "warmup stalled");
    let step0 = t.status().step;
    let t0 = Instant::now();
    for _ in 0..cycles {
        if scheme == Scheme::Edl {
            // a GPU went idle: borrow it (stop-free scale-out)
            if let Err(e) = t.scale_out(vec!["idle-gpu".into()]) {
                println!("  [{name}] scale-out skipped: {e}");
            }
            std::thread::sleep(interval);
            // the GPU is revoked: graceful exit
            let st = t.status();
            if st.parallelism > 4 {
                let victim = *st.workers.last().unwrap();
                let _ = t.scale_in(vec![victim]);
            }
        } else {
            std::thread::sleep(interval);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let steps = t.status().step - step0;
    t.stop();
    steps as f64 * 32.0 / wall
}

fn main() {
    let args = Args::from_env();
    let interval = Duration::from_secs(args.u64("interval-s", 8));
    let cycles = args.u64("cycles", 3) as u32;
    let compute_ms = args.u64("compute-ms", 40);
    let ctx_prep_ms = args.u64("ctx-prep-ms", 2000);

    println!("== transient idle GPU usage (4 persistent + 1 transient) ==");
    println!(
        "interval={}s cycles={cycles} compute={compute_ms}ms/step ctx-prep={ctx_prep_ms}ms\n",
        interval.as_secs()
    );

    let baseline = run_scheme("baseline", Scheme::Baseline, interval, cycles, compute_ms, ctx_prep_ms);
    println!("Baseline (never use idle GPU):  {baseline:>8.1} samples/s");

    let edl = run_scheme("edl", Scheme::Edl, interval, cycles, compute_ms, ctx_prep_ms);
    println!("EDL  (stop-free scaling):       {edl:>8.1} samples/s");

    let ideal = run_scheme("ideal", Scheme::Ideal, interval, cycles, compute_ms, ctx_prep_ms);
    println!("Ideal (5th worker persistent):  {ideal:>8.1} samples/s");

    let frac = edl / ideal;
    println!("\nEDL achieves {:.0}% of Ideal (paper: ≥97% with 4-min intervals)", frac * 100.0);
    println!("EDL vs Baseline: {:+.0}%", (edl / baseline - 1.0) * 100.0);
    println!("(shorter intervals here stress the protocol harder than the paper's 4 min)");
}
