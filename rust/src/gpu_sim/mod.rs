//! Calibrated GPU-cluster device model — the substitute for the paper's
//! 8×8-V100 + 100 Gbps InfiniBand testbed (DESIGN.md §1).
//!
//! Every cluster-scale experiment (Figs 1, 5, 7–12, Tables 2–4) depends on
//! *relative* timing: per-mini-batch compute, ring-allreduce communication,
//! execution-context preparation, and model-broadcast time. This module
//! provides those as an analytic model calibrated against the constants
//! the paper itself reports:
//!
//!  * Table 2 — stop-resume stopping times (≈ context preparation) and
//!    EDL stopping times (≈ model broadcast) per DNN;
//!  * Table 3 — end-to-end scale-in/out durations;
//!  * Fig 1  — throughput / GPU-efficiency curves (diminishing returns for
//!    ResNet50; VGG19 throughput drop past 8 GPUs; VGG19@b384 efficiency
//!    peak at p=4 due to activation-memory pressure at small parallelism);
//!  * §2.2   — stop-resume overhead growing with parallelism (sequential
//!    GPU-device initialisation in TensorFlow).

/// The nine DNNs of TensorFlow's official benchmark suite the paper's
//  workloads draw from (§6.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dnn {
    AlexNet,
    ResNet50,
    ResNet101,
    ResNet152,
    VGG16,
    VGG19,
    Inception3,
    GoogLeNet,
    Bert,
}

pub const ALL_DNNS: [Dnn; 9] = [
    Dnn::AlexNet,
    Dnn::ResNet50,
    Dnn::ResNet101,
    Dnn::ResNet152,
    Dnn::VGG16,
    Dnn::VGG19,
    Dnn::Inception3,
    Dnn::GoogLeNet,
    Dnn::Bert,
];

/// Static per-model characteristics.
#[derive(Debug, Clone, Copy)]
pub struct DnnSpec {
    pub name: &'static str,
    /// gradient/model size (MB) — what ring allreduce moves per step
    pub params_mb: f64,
    /// single-V100 training throughput at a comfortable per-GPU batch
    /// (samples/sec) — calibrated from public tf_cnn_benchmarks numbers
    pub base_sps: f64,
    /// activation memory per sample (MB) — drives the small-parallelism
    /// efficiency dip (Fig 1, VGG19@b384)
    pub act_mb: f64,
    /// stop-resume stopping time (s) for a 4→5 scale, Table 2 row 1 —
    /// dominated by execution-context preparation (Fig 5, gray)
    pub sr_stop_s: f64,
    /// EDL stopping time (s), Table 2 row 2 — model broadcast only
    pub edl_stop_s: f64,
    /// EDL end-to-end scale-out (s), Table 3 — context prep on joiners
    pub scale_out_e2e_s: f64,
    /// EDL end-to-end scale-in (s), Table 3 — graceful exit
    pub scale_in_e2e_s: f64,
}

impl Dnn {
    pub fn spec(self) -> DnnSpec {
        match self {
            Dnn::AlexNet => DnnSpec { name: "AlexNet", params_mb: 233.0, base_sps: 3000.0, act_mb: 1.5, sr_stop_s: 30.0, edl_stop_s: 0.18, scale_out_e2e_s: 16.0, scale_in_e2e_s: 1.6 },
            Dnn::ResNet50 => DnnSpec { name: "ResNet50", params_mb: 98.0, base_sps: 360.0, act_mb: 9.0, sr_stop_s: 44.0, edl_stop_s: 0.67, scale_out_e2e_s: 21.0, scale_in_e2e_s: 1.8 },
            Dnn::ResNet101 => DnnSpec { name: "ResNet101", params_mb: 170.0, base_sps: 210.0, act_mb: 18.0, sr_stop_s: 58.0, edl_stop_s: 1.2, scale_out_e2e_s: 28.0, scale_in_e2e_s: 2.5 },
            Dnn::ResNet152 => DnnSpec { name: "ResNet152", params_mb: 230.0, base_sps: 150.0, act_mb: 25.0, sr_stop_s: 70.0, edl_stop_s: 1.8, scale_out_e2e_s: 36.0, scale_in_e2e_s: 3.3 },
            Dnn::VGG16 => DnnSpec { name: "VGG16", params_mb: 528.0, base_sps: 200.0, act_mb: 40.0, sr_stop_s: 35.0, edl_stop_s: 0.36, scale_out_e2e_s: 19.0, scale_in_e2e_s: 3.3 },
            Dnn::VGG19 => DnnSpec { name: "VGG19", params_mb: 548.0, base_sps: 170.0, act_mb: 50.0, sr_stop_s: 38.0, edl_stop_s: 0.71, scale_out_e2e_s: 20.0, scale_in_e2e_s: 3.3 },
            Dnn::Inception3 => DnnSpec { name: "Inception3", params_mb: 92.0, base_sps: 220.0, act_mb: 14.0, sr_stop_s: 50.0, edl_stop_s: 0.6, scale_out_e2e_s: 24.0, scale_in_e2e_s: 2.2 },
            Dnn::GoogLeNet => DnnSpec { name: "GoogLeNet", params_mb: 27.0, base_sps: 500.0, act_mb: 8.0, sr_stop_s: 32.0, edl_stop_s: 0.12, scale_out_e2e_s: 17.0, scale_in_e2e_s: 1.7 },
            Dnn::Bert => DnnSpec { name: "Bert", params_mb: 420.0, base_sps: 80.0, act_mb: 30.0, sr_stop_s: 62.0, edl_stop_s: 1.4, scale_out_e2e_s: 30.0, scale_in_e2e_s: 3.0 },
        }
    }

    pub fn by_name(name: &str) -> Option<Dnn> {
        ALL_DNNS.into_iter().find(|d| d.spec().name.eq_ignore_ascii_case(name))
    }
}

/// Hardware configuration of the simulated cluster.
#[derive(Debug, Clone, Copy)]
pub struct HwConfig {
    pub gpus_per_machine: u32,
    /// effective intra-machine allreduce bus bandwidth (GB/s, NVLink-class)
    pub local_bw_gbs: f64,
    /// effective cross-machine ring bandwidth (GB/s) — ~25 Gbit effective
    /// allreduce goodput over 100 Gbps IB with 2019-era Horovod/TCP stacks
    pub cross_bw_gbs: f64,
    /// GPU memory (MB)
    pub gpu_mem_mb: f64,
    /// per-allreduce-step latency (s) — dominates for tiny tensors
    pub step_latency_s: f64,
}

impl Default for HwConfig {
    fn default() -> Self {
        // the paper's testbed: 8× V100 SMX2 per machine, 100 Gbps IB
        HwConfig {
            gpus_per_machine: 8,
            local_bw_gbs: 60.0,
            cross_bw_gbs: 3.0,
            gpu_mem_mb: 16_000.0,
            step_latency_s: 30e-6,
        }
    }
}

/// Per-mini-batch time (s) for `model` on `p` GPUs with aggregate batch
/// `global_batch` (the paper keeps the aggregate constant under scaling).
pub fn step_time(model: Dnn, p: u32, global_batch: u32, hw: &HwConfig) -> f64 {
    assert!(p >= 1);
    let spec = model.spec();
    let b_local = global_batch as f64 / p as f64;

    // --- compute: base rate, degraded under activation-memory pressure ---
    let mem_frac = b_local * spec.act_mb / hw.gpu_mem_mb;
    // under-utilisation at tiny local batches (kernels can't fill the SMs)
    let small_batch_penalty = 1.0 + 0.35 / b_local.max(0.25);
    // memory-pressure slowdown: grows smoothly once activations exceed
    // ~30% of device memory; steep past 75% (swapping / cache thrash —
    // the paper's "insufficient cache space" note on VGG19@b384, §2.2)
    let pressure = if mem_frac > 0.3 {
        1.0 + 2.0 * (mem_frac - 0.3).powi(2) + if mem_frac > 0.75 { 4.0 * (mem_frac - 0.75) } else { 0.0 }
    } else {
        1.0
    };
    let compute_s = b_local / spec.base_sps * small_batch_penalty * pressure;

    // --- communication: bandwidth-optimal ring, slowest-link bound ---
    let comm_s = if p == 1 {
        0.0
    } else {
        let bw = if p <= hw.gpus_per_machine { hw.local_bw_gbs } else { hw.cross_bw_gbs };
        let volume_gb = 2.0 * (p as f64 - 1.0) / p as f64 * (spec.params_mb / 1000.0);
        volume_gb / bw + 2.0 * (p as f64 - 1.0) * hw.step_latency_s
    };

    // partial overlap of comm with the backward pass (Horovod-style tensor
    // fusion): the un-overlappable fraction grows as comm outpaces compute
    let exposed = if comm_s <= 0.0 {
        0.0
    } else {
        comm_s * 0.6 + comm_s * 0.4 * ((comm_s - compute_s).max(0.0) / comm_s)
    };
    compute_s + exposed
}

/// Aggregate training throughput (samples/s).
pub fn throughput(model: Dnn, p: u32, global_batch: u32, hw: &HwConfig) -> f64 {
    global_batch as f64 / step_time(model, p, global_batch, hw)
}

/// Per-GPU throughput t(p) (samples/s/GPU).
pub fn per_gpu_throughput(model: Dnn, p: u32, global_batch: u32, hw: &HwConfig) -> f64 {
    throughput(model, p, global_batch, hw) / p as f64
}

/// GPU efficiency per the paper's footnote 1: t(p) / t(p*) where
/// p* = argmax_q t(q), searched over 1..=max_p.
pub fn efficiency(model: Dnn, p: u32, global_batch: u32, max_p: u32, hw: &HwConfig) -> f64 {
    let t_p = per_gpu_throughput(model, p, global_batch, hw);
    let t_best = (1..=max_p)
        .map(|q| per_gpu_throughput(model, q, global_batch, hw))
        .fold(f64::MIN, f64::max);
    t_p / t_best
}

/// Stop-resume restart overhead (s) when restarting a job at parallelism
/// `p`: context prep grows with p because TensorFlow initialises the GPUs
/// of a machine sequentially (§2.2 footnote 5: 40→80+ s from 1→many GPUs).
pub fn stop_resume_overhead(model: Dnn, p: u32) -> f64 {
    let spec = model.spec();
    spec.sr_stop_s * (0.82 + 0.045 * p as f64)
}

/// Decomposition of the scale-out cost (Fig 5) at parallelism `p`.
#[derive(Debug, Clone, Copy)]
pub struct ScaleOutBreakdown {
    /// library loading + memory allocation + graph build + data pipeline (s)
    pub context_prep_s: f64,
    /// topology (re)construction: leader RPC + ring rebuild (s)
    pub topology_s: f64,
    /// model preparation: broadcast from one existing worker (s)
    pub model_prep_s: f64,
}

impl ScaleOutBreakdown {
    pub fn total(&self) -> f64 {
        self.context_prep_s + self.topology_s + self.model_prep_s
    }
}

pub fn scale_out_breakdown(model: Dnn, p: u32) -> ScaleOutBreakdown {
    let spec = model.spec();
    ScaleOutBreakdown {
        // sequential device init: grows with target parallelism (§2.2)
        context_prep_s: spec.scale_out_e2e_s * (0.82 + 0.045 * p as f64),
        topology_s: 0.050, // tens of sub-ms coordination messages (§4.4)
        model_prep_s: spec.edl_stop_s,
    }
}

/// EDL stopping time for scale-out = model broadcast only (§4.2 / Table 2).
pub fn edl_stop_time(model: Dnn) -> f64 {
    model.spec().edl_stop_s
}

/// EDL end-to-end scale-out/in times (Table 3).
pub fn edl_scale_out_e2e(model: Dnn) -> f64 {
    model.spec().scale_out_e2e_s
}
pub fn edl_scale_in_e2e(model: Dnn) -> f64 {
    model.spec().scale_in_e2e_s
}

#[cfg(test)]
mod tests {
    use super::*;

    const HW: HwConfig = HwConfig {
        gpus_per_machine: 8,
        local_bw_gbs: 60.0,
        cross_bw_gbs: 3.0,
        gpu_mem_mb: 16_000.0,
        step_latency_s: 30e-6,
    };

    #[test]
    fn resnet50_throughput_increases_with_diminishing_gains() {
        // Fig 1 shape: monotone throughput, diminishing marginal gains
        let b = 512;
        let th: Vec<f64> = [1u32, 2, 4, 8, 16].iter().map(|&p| throughput(Dnn::ResNet50, p, b, &HW)).collect();
        for w in th.windows(2) {
            assert!(w[1] > w[0], "throughput should rise: {th:?}");
        }
        let gain_2 = th[1] / th[0];
        let gain_16 = th[4] / th[3];
        assert!(gain_2 > gain_16, "gains should diminish: {th:?}");
    }

    #[test]
    fn resnet50_efficiency_decreases_with_parallelism() {
        let b = 512;
        let eff: Vec<f64> = [1u32, 2, 4, 8, 16].iter().map(|&p| efficiency(Dnn::ResNet50, p, b, 16, &HW)).collect();
        for w in eff.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "efficiency should fall: {eff:?}");
        }
        assert!((eff[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn vgg19_throughput_drops_past_8_gpus() {
        // Fig 1: VGG19's big model makes cross-machine comm dominate
        let b = 384;
        let t8 = throughput(Dnn::VGG19, 8, b, &HW);
        let t16 = throughput(Dnn::VGG19, 16, b, &HW);
        assert!(t16 < t8, "VGG19 should slow past one machine: t8={t8:.1} t16={t16:.1}");
    }

    #[test]
    fn vgg19_b384_efficiency_peaks_at_4() {
        // Fig 1 / §2.2: small parallelism -> huge local batch -> activation
        // memory pressure; best per-GPU throughput at p=4
        let b = 384;
        let best = (1u32..=16)
            .max_by(|&a, &q| {
                per_gpu_throughput(Dnn::VGG19, a, b, &HW)
                    .partial_cmp(&per_gpu_throughput(Dnn::VGG19, q, b, &HW))
                    .unwrap()
            })
            .unwrap();
        assert_eq!(best, 4, "VGG19@b384 efficiency should peak at p=4");
    }

    #[test]
    fn stop_resume_in_papers_range() {
        // §2.2: overhead grows with parallelism (sequential device init)
        for d in ALL_DNNS {
            let o1 = stop_resume_overhead(d, 1);
            let o8 = stop_resume_overhead(d, 8);
            assert!(o8 > o1, "{d:?}");
        }
        assert!(stop_resume_overhead(Dnn::ResNet152, 8) > 70.0);
        assert!(stop_resume_overhead(Dnn::AlexNet, 1) > 20.0);
    }

    #[test]
    fn edl_stop_an_order_of_magnitude_below_stop_resume() {
        // Table 2's headline: 0.18–1.8 s vs 30–70 s
        for d in ALL_DNNS {
            let s = d.spec();
            assert!(
                s.sr_stop_s / s.edl_stop_s > 10.0,
                "{}: {} vs {}",
                s.name,
                s.sr_stop_s,
                s.edl_stop_s
            );
        }
    }

    #[test]
    fn breakdown_dominated_by_context_prep() {
        // Fig 5: gray (context prep) dominates
        for d in ALL_DNNS {
            let b = scale_out_breakdown(d, 5);
            assert!(b.context_prep_s > 0.8 * b.total(), "{d:?}: {b:?}");
        }
    }

    #[test]
    fn step_time_positive_and_finite() {
        for d in ALL_DNNS {
            for p in [1u32, 2, 5, 8, 13, 32] {
                let t = step_time(d, p, 256, &HW);
                assert!(t.is_finite() && t > 0.0, "{d:?} p={p}: {t}");
            }
        }
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(Dnn::by_name("vgg19"), Some(Dnn::VGG19));
        assert_eq!(Dnn::by_name("ResNet50"), Some(Dnn::ResNet50));
        assert_eq!(Dnn::by_name("nope"), None);
    }
}
