//! End-to-end integration over the REAL PJRT runtime and AOT artifacts:
//! multi-worker data-parallel training of the JAX transformer with elastic
//! scaling mid-run. Requires `make artifacts` (the `tiny` config).

use edl::coordinator::{ElasticTrainer, TrainerConfig};
use edl::data::corpus::Corpus;
use edl::runtime::{artifacts_dir, ModelMeta, Runtime};
use edl::worker::PjrtBackend;
use std::sync::Arc;
use std::time::Duration;

const T: Duration = Duration::from_secs(600);

fn have_artifacts() -> bool {
    // artifacts are only usable when the real PJRT bindings are linked
    cfg!(feature = "pjrt") && ModelMeta::load(artifacts_dir(), "tiny").is_ok()
}

fn start_tiny(n: usize, agg_batch: u32) -> (ElasticTrainer, Arc<Corpus>) {
    let backend = Arc::new(PjrtBackend::new(artifacts_dir(), "tiny", agg_batch, 8).unwrap());
    let meta = backend.meta.clone();
    let corpus = Arc::new(Corpus::markov(meta.vocab, meta.seq_len, 4096, 3));
    let cfg = TrainerConfig {
        agg_batch,
        lr: 0.2,
        n_partitions: 64,
        seed: 9,
        approx_recovery: true,
        // PJRT-CPU workers oversubscribe the host cores (every client
        // spawns a full-size thread pool), so a barrier can legitimately
        // stall for tens of seconds around a topology switch — use a
        // failure timeout in the scheduler-retry class (§3.1: 60 s)
        failure_timeout: Duration::from_secs(120),
        ..Default::default()
    };
    (ElasticTrainer::start(cfg, backend, corpus.clone(), n), corpus)
}

#[test]
fn runtime_grad_matches_across_instances() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // two independent runtimes (as two workers would have) must agree
    let r1 = Runtime::open(artifacts_dir(), "tiny").unwrap();
    let r2 = Runtime::open(artifacts_dir(), "tiny").unwrap();
    let p1 = r1.init_params(42).unwrap();
    let p2 = r2.init_params(42).unwrap();
    assert_eq!(p1, p2, "same seed, same params");
    let toks: Vec<i32> = (0..r1.meta.seq_len as i32).map(|i| i % r1.meta.vocab as i32).collect();
    let (l1, g1) = r1.grad_step(&p1, &toks, 1).unwrap();
    let (l2, g2) = r2.grad_step(&p2, &toks, 1).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(g1, g2);
}

#[test]
fn runtime_train_step_decreases_loss() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = Runtime::open(artifacts_dir(), "tiny").unwrap();
    let corpus = Corpus::markov(rt.meta.vocab, rt.meta.seq_len, 64, 5);
    let mut params = rt.init_params(0).unwrap();
    let toks = corpus.batch(0, 4);
    let (l0, _) = rt.train_step(&params, &toks, 4, 0.5).map(|(l, p)| (l, { params = p; })).unwrap();
    let (l1, _np) = rt.train_step(&params, &toks, 4, 0.5).unwrap();
    assert!(l1 < l0, "loss should drop on repeated batch: {l0} -> {l1}");
}

#[test]
fn runtime_grad_then_apply_equals_train_step() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // the decomposed path (grad → allreduce(1 worker) → apply) must equal
    // the fused train_step artifact
    let rt = Runtime::open(artifacts_dir(), "tiny").unwrap();
    let corpus = Corpus::markov(rt.meta.vocab, rt.meta.seq_len, 16, 6);
    let params = rt.init_params(1).unwrap();
    let toks = corpus.batch(0, 2);
    let (loss_a, grads) = rt.grad_step(&params, &toks, 2).unwrap();
    let decomposed = rt.apply_update(&params, &grads, 0.1).unwrap();
    let (loss_b, fused) = rt.train_step(&params, &toks, 2, 0.1).unwrap();
    assert!((loss_a - loss_b).abs() < 1e-5);
    let max_diff = decomposed
        .iter()
        .zip(&fused)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-5, "max_diff={max_diff}");
}

#[test]
fn e2e_two_workers_train_and_scale() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (t, _corpus) = start_tiny(2, 16);
    assert!(t.wait_step(10, T), "2-worker training stalled");
    let st = t.status();
    assert_eq!(st.parallelism, 2);
    let loss_early = st.last_loss;
    assert!(loss_early.is_finite());

    // stop-free scale-out to 3 workers while training continues
    let r = t.scale_out(vec!["m1".into()]);
    assert!(r.is_ok(), "{r:?}");
    let st = t.status();
    assert_eq!(st.parallelism, 3);
    assert!(t.wait_step(st.step + 10, T), "training stalled after scale-out");

    // graceful scale-in back to 2
    let victim = *t.status().workers.last().unwrap();
    if let Err(e) = t.scale_in(vec![victim]) {
        panic!("scale_in(worker {victim}) failed: {e:?}");
    }
    let st = t.status();
    assert_eq!(st.parallelism, 2);
    assert!(t.wait_step(st.step + 5, T));

    let report = t.stop();
    let h = &report.loss_history;
    assert!(h.len() > 20);
    let first5: f32 = h[..5].iter().map(|p| p.loss).sum::<f32>() / 5.0;
    let last5: f32 = h[h.len() - 5..].iter().map(|p| p.loss).sum::<f32>() / 5.0;
    assert!(
        last5 < first5,
        "transformer loss should fall across scaling: {first5:.4} -> {last5:.4}"
    );
    assert!(h.iter().all(|p| p.loss.is_finite()));
}
