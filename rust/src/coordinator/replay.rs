//! Virtual-clock shell for [`LeaderCore`]: replay recorded event traces
//! through the real §4.1–§4.2 state machine with no threads, no I/O and
//! no wall clock.
//!
//! Two consumers:
//!
//!  * **deterministic protocol tests** (`rust/tests/leader_core.rs`):
//!    the same `(now_ms, Event)` trace fed twice yields byte-identical
//!    action logs — regressions in ordering, hashing or hidden time
//!    reads show up as a log diff;
//!  * **the cluster simulator's EDL cost model**
//!    ([`cluster::edl_switch_lag_s`](crate::cluster::edl_switch_lag_s)):
//!    instead of a hand-derived switch-timing formula, the simulator
//!    replays a scripted scale-out through the real core and reads the
//!    committed `at_step` off the resulting [`SwitchPlan`].

use super::core::{Action, Event, LeaderCore, ReqToken};
use super::{CtrlMsg, TrainerConfig, WorkerEvent};
use crate::api::Request;
use crate::transport::NodeId;
use crate::worker::Backend;
use std::sync::Arc;

/// A monotonically advancing virtual clock (milliseconds).
#[derive(Debug, Clone)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new(start_ms: f64) -> VirtualClock {
        VirtualClock { now: start_ms }
    }

    pub fn now_ms(&self) -> f64 {
        self.now
    }

    /// Advance by `ms` and return the new time.
    pub fn advance(&mut self, ms: f64) -> f64 {
        self.now += ms;
        self.now
    }
}

/// One recorded trace entry: the clock value and the event delivered at it.
pub type TraceEntry = (f64, Event);

/// Feed a recorded trace through `core`, returning one log line per
/// emitted action (`"<now_ms> <action debug>"`). Byte-identical across
/// replays of the same trace into a fresh core.
pub fn replay(core: &mut LeaderCore, trace: &[TraceEntry]) -> Vec<String> {
    let mut log = Vec::new();
    for (now, ev) in trace {
        for a in core.handle(*now, ev.clone()) {
            log.push(format!("{now:.3} {a:?}"));
        }
    }
    log
}

/// Convenience shell for scripting protocol scenarios against the core
/// under a virtual clock. Every event is recorded, so the accumulated
/// [`ScriptedLeader::trace`] can be replayed verbatim into a fresh core.
pub struct ScriptedLeader {
    pub core: LeaderCore,
    pub clock: VirtualClock,
    pub trace: Vec<TraceEntry>,
    pub log: Vec<String>,
    next_token: ReqToken,
}

impl ScriptedLeader {
    pub fn new(cfg: TrainerConfig, backend: Arc<dyn Backend>, n_founders: usize) -> ScriptedLeader {
        let assigner = cfg.assigner_for(4096);
        let core = LeaderCore::new(cfg, backend, assigner, n_founders);
        ScriptedLeader {
            core,
            clock: VirtualClock::new(0.0),
            trace: Vec::new(),
            log: Vec::new(),
            next_token: 0,
        }
    }

    /// Deliver `ev` after advancing the clock by `dt_ms`.
    pub fn feed(&mut self, dt_ms: f64, ev: Event) -> Vec<Action> {
        let now = self.clock.advance(dt_ms);
        self.trace.push((now, ev.clone()));
        let actions = self.core.handle(now, ev);
        for a in &actions {
            self.log.push(format!("{now:.3} {a:?}"));
        }
        actions
    }

    /// Attach + Ready a worker (the shell-side join sequence).
    pub fn join_worker(&mut self, id: NodeId, machine: &str, joiner: bool) -> Vec<Action> {
        let mut acts = self.feed(
            0.0,
            Event::Worker(WorkerEvent::Attach { id, machine: machine.to_string(), joiner }),
        );
        acts.extend(self.feed(0.0, Event::Worker(WorkerEvent::Ready { id })));
        acts
    }

    /// Issue a Table-1 request; returns the token the reply will carry.
    pub fn request(&mut self, req: Request) -> (ReqToken, Vec<Action>) {
        self.next_token += 1;
        let token = self.next_token;
        let acts = self.feed(0.0, Event::Request { token, req });
        (token, acts)
    }

    /// Complete one full gradient-sync barrier: every active worker
    /// reports `Sync` for the current step, `step_ms` apart in virtual
    /// time. Returns the actions of the final (barrier-completing) sync.
    pub fn run_barrier(&mut self, step_ms: f64) -> Vec<Action> {
        let step = self.core.step();
        let ids = self.core.active_workers();
        let mut last = Vec::new();
        for (i, id) in ids.iter().enumerate() {
            // the first arrival pays the whole step time; stragglers of
            // the same barrier trail by a negligible virtual epsilon
            let dt = if i == 0 { step_ms } else { 0.01 };
            last = self.feed(
                dt,
                Event::Worker(WorkerEvent::Sync {
                    id: *id,
                    step,
                    loss: 1.0 / (step + 1) as f32,
                    weight: 8.0,
                    step_ms,
                    shard: None,
                }),
            );
        }
        last
    }

    /// Drive `n` consecutive barriers at a fixed virtual step time.
    pub fn run_barriers(&mut self, n: usize, step_ms: f64) {
        for _ in 0..n {
            self.run_barrier(step_ms);
        }
    }
}

/// Scan a batch of actions for the `join_at_step` the leader scheduled
/// (the `CtrlMsg::Ok` sent to joiners when a switch is committed).
pub fn scheduled_join_step(actions: &[Action]) -> Option<u64> {
    actions.iter().find_map(|a| match a {
        Action::Send { msg: CtrlMsg::Ok { join_at_step, .. }, .. } => Some(*join_at_step),
        _ => None,
    })
}
