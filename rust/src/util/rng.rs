//! Deterministic PRNG (PCG32) plus the distribution helpers the trace
//! generator and simulators need. `rand`/`rand_distr` are not available in
//! the offline registry, so this is a from-scratch implementation of the
//! PCG-XSH-RR generator (O'Neill 2014) with Box–Muller normals, inverse-CDF
//! exponentials and Pareto power-law sampling.

/// PCG-XSH-RR 64/32 generator. Deterministic, seedable, stream-splittable.
#[derive(Clone, Debug, PartialEq)]
pub struct Pcg {
    state: u64,
    inc: u64,
    /// cached second normal from Box–Muller
    spare_normal: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1, spare_normal: None };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive an independent stream (for per-entity RNGs in the simulator).
    pub fn split(&mut self, stream: u64) -> Pcg {
        Pcg::new(self.next_u64(), stream)
    }

    /// Raw generator state `(state, inc)` for serialisation. The cached
    /// Box–Muller spare is NOT captured: a restored generator resumes on
    /// the underlying u32 stream, which is the only stream the elasticity
    /// protocol serialises (see `wire::Enc::pcg`).
    pub fn to_parts(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Pcg::to_parts`] output. Continues the
    /// u32 stream exactly where the serialised generator left off.
    pub fn from_parts(state: u64, inc: u64) -> Pcg {
        Pcg { state, inc, spare_normal: None }
    }

    /// Jump the generator forward by `delta` [`Pcg::next_u32`] draws in
    /// O(log delta) time (the standard PCG LCG jump-ahead: repeated
    /// squaring of the multiplier/increment pair). Used to re-derive a
    /// virtual worker's stream position at an arbitrary step without
    /// replaying the stream. Drops any cached Box–Muller spare, matching
    /// what stepping via `next_u32` would do.
    pub fn advance(&mut self, mut delta: u64) {
        let mut cur_mult = PCG_MULT;
        let mut cur_plus = self.inc;
        let mut acc_mult: u64 = 1;
        let mut acc_plus: u64 = 0;
        while delta > 0 {
            if delta & 1 == 1 {
                acc_mult = acc_mult.wrapping_mul(cur_mult);
                acc_plus = acc_plus.wrapping_mul(cur_mult).wrapping_add(cur_plus);
            }
            cur_plus = cur_mult.wrapping_add(1).wrapping_mul(cur_plus);
            cur_mult = cur_mult.wrapping_mul(cur_mult);
            delta >>= 1;
        }
        self.state = acc_mult.wrapping_mul(self.state).wrapping_add(acc_plus);
        self.spare_normal = None;
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi;
            } else if x.wrapping_mul(n).wrapping_add(n) > n {
                return hi;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.gen_range((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (caches the spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (1.0 - self.f64(), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal with given log-space mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Pareto (power law): x_min * U^(-1/alpha). Density ∝ x^-(alpha+1).
    pub fn pareto(&mut self, x_min: f64, alpha: f64) -> f64 {
        x_min * (1.0 - self.f64()).powf(-1.0 / alpha)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }

    /// Weighted index sampling (weights need not be normalised).
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// 128-bit multiply helper for Lemire range reduction.
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg::seeded(7);
        let mut b = Pcg::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::seeded(1);
        let mut b = Pcg::seeded(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg::seeded(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Pcg::seeded(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::seeded(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg::seeded(6);
        let n = 20_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn pareto_minimum_respected() {
        let mut r = Pcg::seeded(7);
        for _ in 0..1000 {
            assert!(r.pareto(3.0, 1.5) >= 3.0);
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Pcg::seeded(8);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_prefers_heavy() {
        let mut r = Pcg::seeded(9);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn parts_roundtrip_resumes_stream() {
        let mut a = Pcg::seeded(11);
        for _ in 0..17 {
            a.next_u32();
        }
        let (state, inc) = a.to_parts();
        let mut b = Pcg::from_parts(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn advance_matches_sequential_stepping() {
        for delta in [0u64, 1, 2, 3, 7, 64, 1000, 12345] {
            let mut jumped = Pcg::seeded(12);
            jumped.advance(delta);
            let mut stepped = Pcg::seeded(12);
            for _ in 0..delta {
                stepped.next_u32();
            }
            assert_eq!(
                jumped.next_u32(),
                stepped.next_u32(),
                "advance({delta}) diverged from {delta} sequential draws"
            );
        }
    }

    #[test]
    fn advance_composes() {
        // advance(a); advance(b) == advance(a + b)
        let mut split_jump = Pcg::new(13, 5);
        split_jump.advance(1000);
        split_jump.advance(234);
        let mut one_jump = Pcg::new(13, 5);
        one_jump.advance(1234);
        assert_eq!(split_jump, one_jump);
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg::seeded(10);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
