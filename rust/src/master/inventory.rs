//! Sharded machine×GPU-slot inventory for the live master.
//!
//! The PR 4 master kept one `free: Vec<u32>` owned by the shell thread, so
//! every allocate/release — and every policy tick — serialised on the shell.
//! At Philly scale (thousands of machines, hundreds of concurrent ops) that
//! single structure becomes the bottleneck the paper's §5 warns about.
//!
//! [`ShardedInventory`] splits the fleet into per-rack shards, each owning
//! its slice of the machine×slot maps behind its own mutex. The rules:
//!
//! - **At most one shard lock is ever held at a time.** Every touch goes
//!   through [`ShardedInventory::with_shard`], the single acquisition site;
//!   multi-shard operations (allocate, release, conservation checks) walk
//!   shards sequentially. No lock-order edges can exist, so the `edl verify`
//!   lock lint stays trivially clean and deadlock is impossible by
//!   construction.
//! - **Reads are lock-free.** Each shard mirrors its free-slot total in an
//!   atomic; [`ShardedInventory::free_gpus`] sums the mirrors without
//!   touching any mutex, which is what lets a policy tick assemble its
//!   `ClusterView` snapshot without stopping the world.
//! - **Placement is deterministic.** `allocate` computes the same
//!   most-free-first greedy order the unsharded master used (global sort by
//!   descending free count, index-stable tie-break), so a single-threaded
//!   caller gets byte-identical placements regardless of shard count — the
//!   golden decision-log tests depend on this.
//! - **Conservation is checkable per shard.** `free + held == capacity`
//!   must hold for every machine at all times; [`ShardedInventory::check_conservation`]
//!   verifies it shard by shard and the master asserts it every tick.

use super::MachineSpec;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// One rack's slice of the inventory. `free`/`held`/`cap` are indexed by
/// *local* machine index; `base` maps local index 0 back to the fleet-wide
/// machine index.
pub(crate) struct ShardState {
    pub free: Vec<u32>,
    pub held: Vec<u32>,
    pub cap: Vec<u32>,
}

struct Shard {
    /// fleet-wide index of this shard's first machine
    base: usize,
    state: Mutex<ShardState>,
    /// lock-free mirror of `state.free.iter().sum()`; advisory (readers may
    /// observe a value mid-update), authoritative state lives under the lock
    free_total: AtomicU64,
}

/// Aggregate counters for one shard, as reported by `edl master` stats.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardRow {
    pub shard: usize,
    pub machines: usize,
    pub capacity: u32,
    pub free: u32,
    pub held: u32,
}

/// The fleet: machine names plus per-rack shards of slot state. Shared by
/// the master shell, its decision executors, and its status pollers.
pub struct ShardedInventory {
    names: Vec<String>,
    by_name: HashMap<String, usize>,
    caps: Vec<u32>,
    rack_size: usize,
    total: u32,
    shards: Vec<Shard>,
}

impl ShardedInventory {
    /// Build from machine specs, `rack_size` machines per shard (the last
    /// shard may be short). `rack_size == usize::MAX` (or >= fleet size)
    /// yields one shard — the "unsharded" baseline configuration.
    pub fn new(machines: &[MachineSpec], rack_size: usize) -> ShardedInventory {
        assert!(!machines.is_empty(), "inventory needs at least one machine");
        let rack_size = rack_size.clamp(1, machines.len());
        let names: Vec<String> = machines.iter().map(|m| m.name.clone()).collect();
        let by_name = names.iter().enumerate().map(|(i, n)| (n.clone(), i)).collect();
        let caps: Vec<u32> = machines.iter().map(|m| m.gpus).collect();
        let total = caps.iter().sum();
        let shards = caps
            .chunks(rack_size)
            .enumerate()
            .map(|(i, chunk)| Shard {
                base: i * rack_size,
                free_total: AtomicU64::new(chunk.iter().map(|&c| u64::from(c)).sum()),
                state: Mutex::new(ShardState {
                    free: chunk.to_vec(),
                    held: vec![0; chunk.len()],
                    cap: chunk.to_vec(),
                }),
            })
            .collect();
        ShardedInventory { names, by_name, caps, rack_size, total, shards }
    }

    pub fn n_machines(&self) -> usize {
        self.names.len()
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn total_gpus(&self) -> u32 {
        self.total
    }

    pub fn machine_name(&self, m: usize) -> &str {
        &self.names[m]
    }

    pub fn machine_ix(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }

    pub fn capacity(&self, m: usize) -> u32 {
        self.caps[m]
    }

    fn shard_of(&self, m: usize) -> usize {
        m / self.rack_size
    }

    /// The single shard-lock acquisition site. `f` must not acquire any
    /// other lock (enforced by the repo lock-order lint: nothing is ever
    /// held when a shard lock is taken, and nothing is taken under one).
    fn with_shard<R>(&self, s: usize, f: impl FnOnce(&mut ShardState, &AtomicU64) -> R) -> R {
        let shard = &self.shards[s];
        let mut st = shard.state.lock().unwrap_or_else(|e| e.into_inner());
        f(&mut st, &shard.free_total)
    }

    /// Fleet-wide free slots, summed from the per-shard atomic mirrors.
    /// Never blocks on a shard lock; concurrent writers make the value
    /// advisory, but it is exact whenever no operation is in flight.
    pub fn free_gpus(&self) -> u32 {
        let sum: u64 = self.shards.iter().map(|s| s.free_total.load(Ordering::Acquire)).sum();
        sum.min(u64::from(u32::MAX)) as u32
    }

    /// Copy of the per-machine free counts, read one shard at a time.
    fn snapshot_free(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.names.len());
        for s in 0..self.shards.len() {
            self.with_shard(s, |st, _| out.extend_from_slice(&st.free));
        }
        out
    }

    /// Reserve `p` slots, most-free-machines-first (descending free count,
    /// machine index breaking ties — the exact order the unsharded master
    /// used). Returns `(machine, gpus)` pairs or `None` if the fleet cannot
    /// hold `p` slots. Under concurrent allocators a planned take may be
    /// gone by commit time; the remainder is replanned from a fresh
    /// snapshot, and on final failure every partial reservation is rolled
    /// back — `allocate` is all-or-nothing.
    pub fn allocate(&self, p: u32) -> Option<Vec<(usize, u32)>> {
        if p == 0 || p > self.free_gpus() {
            return None;
        }
        let mut got: Vec<(usize, u32)> = Vec::new();
        let mut need = p;
        // one pass per shard count + slack: each retry only happens because
        // a *concurrent* taker won a race, so a couple of replans settle it
        for _attempt in 0..4 {
            let free = self.snapshot_free();
            let mut order: Vec<usize> = (0..free.len()).collect();
            order.sort_by_key(|&m| std::cmp::Reverse(free[m]));
            // plan against the snapshot, skipping machines this job already
            // reserved from during an earlier attempt (one entry per machine
            // keeps release bookkeeping simple)
            let mut plan: Vec<(usize, u32)> = Vec::new();
            let mut planned = 0u32;
            for &m in &order {
                if planned == need {
                    break;
                }
                if free[m] == 0 || got.iter().any(|&(gm, _)| gm == m) {
                    continue;
                }
                let take = free[m].min(need - planned);
                plan.push((m, take));
                planned += take;
            }
            // commit shard by shard, taking what is still actually free
            plan.sort_by_key(|&(m, _)| m);
            for &(m, want) in &plan {
                let s = self.shard_of(m);
                let local = m - self.shards[s].base;
                let taken = self.with_shard(s, |st, free_total| {
                    let take = st.free[local].min(want);
                    if take > 0 {
                        st.free[local] -= take;
                        st.held[local] += take;
                        free_total.fetch_sub(u64::from(take), Ordering::AcqRel);
                    }
                    take
                });
                if taken > 0 {
                    got.push((m, taken));
                    need -= taken;
                }
            }
            if need == 0 {
                got.sort_by_key(|&(m, _)| m);
                return Some(got);
            }
        }
        // fleet drained out from under us: roll back, report failure
        self.release(&got);
        None
    }

    /// Return slots previously handed out by [`allocate`]. Panics (loudly,
    /// like the master's tick-time conservation assert) if a release would
    /// push a machine past its capacity — that means a double-free upstream.
    pub fn release(&self, slots: &[(usize, u32)]) {
        for &(m, g) in slots {
            if g == 0 {
                continue;
            }
            let s = self.shard_of(m);
            let local = m - self.shards[s].base;
            self.with_shard(s, |st, free_total| {
                assert!(
                    st.held[local] >= g && st.free[local] + g <= st.cap[local],
                    "inventory release over capacity: machine {m} free {} held {} cap {} release {g}",
                    st.free[local],
                    st.held[local],
                    st.cap[local],
                );
                st.free[local] += g;
                st.held[local] -= g;
                free_total.fetch_add(u64::from(g), Ordering::AcqRel);
            });
        }
    }

    /// Per-shard aggregate rows for `edl master` stats / the scale bench.
    pub fn shard_rows(&self) -> Vec<ShardRow> {
        (0..self.shards.len())
            .map(|s| {
                self.with_shard(s, |st, _| ShardRow {
                    shard: s,
                    machines: st.cap.len(),
                    capacity: st.cap.iter().sum(),
                    free: st.free.iter().sum(),
                    held: st.held.iter().sum(),
                })
            })
            .collect()
    }

    /// Copy of per-machine held counts (for the master's cross-check of
    /// job-table holdings against the inventory).
    pub fn held_by_machine(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.names.len());
        for s in 0..self.shards.len() {
            self.with_shard(s, |st, _| out.extend_from_slice(&st.held));
        }
        out
    }

    /// Verify `free + held == capacity` on every machine of every shard and
    /// that each shard's atomic mirror agrees with its locked state.
    /// Returns the first violation as a human-readable string.
    pub fn check_conservation(&self) -> Result<(), String> {
        for s in 0..self.shards.len() {
            let base = self.shards[s].base;
            let r = self.with_shard(s, |st, free_total| {
                for i in 0..st.cap.len() {
                    if st.free[i] + st.held[i] != st.cap[i] {
                        return Err(format!(
                            "shard {s} machine {}: free {} + held {} != cap {}",
                            base + i,
                            st.free[i],
                            st.held[i],
                            st.cap[i]
                        ));
                    }
                }
                let sum: u64 = st.free.iter().map(|&f| u64::from(f)).sum();
                let mirror = free_total.load(Ordering::Acquire);
                if sum != mirror {
                    return Err(format!("shard {s}: free mirror {mirror} != locked sum {sum}"));
                }
                Ok(())
            });
            r?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(n: usize, gpus: u32) -> Vec<MachineSpec> {
        (0..n).map(|i| MachineSpec { name: format!("m{}", i + 1), gpus }).collect()
    }

    /// the PR 4 master's unsharded greedy, kept verbatim as the placement
    /// oracle
    fn reference_allocate(free: &mut [u32], p: u32) -> Option<Vec<(usize, u32)>> {
        if p == 0 || p > free.iter().sum::<u32>() {
            return None;
        }
        let mut order: Vec<usize> = (0..free.len()).collect();
        order.sort_by_key(|&m| std::cmp::Reverse(free[m]));
        let mut out = Vec::new();
        let mut need = p;
        for m in order {
            if need == 0 {
                break;
            }
            let take = free[m].min(need);
            if take > 0 {
                free[m] -= take;
                need -= take;
                out.push((m, take));
            }
        }
        out.sort_by_key(|&(m, _)| m);
        Some(out)
    }

    #[test]
    fn basic_allocate_release_conserves() {
        let inv = ShardedInventory::new(&fleet(10, 4), 3);
        assert_eq!(inv.n_shards(), 4);
        assert_eq!(inv.total_gpus(), 40);
        assert_eq!(inv.free_gpus(), 40);
        let a = inv.allocate(6).expect("fits");
        assert_eq!(a.iter().map(|&(_, g)| g).sum::<u32>(), 6);
        assert_eq!(inv.free_gpus(), 34);
        inv.check_conservation().unwrap();
        inv.release(&a);
        assert_eq!(inv.free_gpus(), 40);
        inv.check_conservation().unwrap();
    }

    #[test]
    fn over_capacity_allocation_fails_cleanly() {
        let inv = ShardedInventory::new(&fleet(3, 2), 2);
        assert!(inv.allocate(0).is_none());
        assert!(inv.allocate(7).is_none());
        let a = inv.allocate(6).unwrap();
        assert!(inv.allocate(1).is_none());
        inv.release(&a);
        assert_eq!(inv.free_gpus(), 6);
        inv.check_conservation().unwrap();
    }

    #[test]
    fn placement_matches_unsharded_reference_for_any_rack_size() {
        // a deterministic allocate/release script must place identically on
        // 1 shard, small racks, and per-machine shards
        let specs: Vec<MachineSpec> = vec![4, 2, 8, 1, 4, 4, 2, 8]
            .into_iter()
            .enumerate()
            .map(|(i, g)| MachineSpec { name: format!("m{}", i + 1), gpus: g })
            .collect();
        let script: Vec<(bool, u32)> = vec![
            (true, 5),
            (true, 3),
            (true, 9),
            (false, 1), // release allocation #1
            (true, 4),
            (true, 8),
            (false, 2), // release allocation #2
            (true, 6),
        ];
        let mut oracle_free: Vec<u32> = specs.iter().map(|m| m.gpus).collect();
        let mut oracle_allocs: Vec<Vec<(usize, u32)>> = Vec::new();
        let mut oracle_log: Vec<Option<Vec<(usize, u32)>>> = Vec::new();
        for &(alloc, arg) in &script {
            if alloc {
                let r = reference_allocate(&mut oracle_free, arg);
                if let Some(a) = &r {
                    oracle_allocs.push(a.clone());
                }
                oracle_log.push(r);
            } else {
                for &(m, g) in &oracle_allocs[arg as usize] {
                    oracle_free[m] += g;
                }
            }
        }
        for rack in [1usize, 3, 8, usize::MAX] {
            let inv = ShardedInventory::new(&specs, rack);
            let mut allocs: Vec<Vec<(usize, u32)>> = Vec::new();
            let mut log: Vec<Option<Vec<(usize, u32)>>> = Vec::new();
            for &(alloc, arg) in &script {
                if alloc {
                    let r = inv.allocate(arg);
                    if let Some(a) = &r {
                        allocs.push(a.clone());
                    }
                    log.push(r);
                } else {
                    inv.release(&allocs[arg as usize]);
                }
            }
            assert_eq!(log, oracle_log, "rack_size {rack} diverged from reference");
            inv.check_conservation().unwrap();
        }
    }

    #[test]
    fn concurrent_hammer_conserves_every_shard() {
        use std::sync::Arc;
        let inv = Arc::new(ShardedInventory::new(&fleet(32, 4), 4));
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let inv = inv.clone();
                std::thread::spawn(move || {
                    let mut held: Vec<Vec<(usize, u32)>> = Vec::new();
                    for i in 0..400usize {
                        let p = 1 + ((t * 7 + i * 3) % 9) as u32;
                        if let Some(a) = inv.allocate(p) {
                            held.push(a);
                        }
                        // interleave releases so the fleet churns
                        if i % 3 == 0 {
                            if let Some(a) = held.pop() {
                                inv.release(&a);
                            }
                        }
                        if i % 10 == 0 {
                            inv.check_conservation().expect("mid-storm conservation");
                        }
                    }
                    for a in held {
                        inv.release(&a);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        inv.check_conservation().unwrap();
        assert_eq!(inv.free_gpus(), inv.total_gpus(), "all slots returned");
    }

    #[test]
    fn shard_rows_and_held_by_machine_agree() {
        let inv = ShardedInventory::new(&fleet(7, 2), 3);
        let a = inv.allocate(5).unwrap();
        let rows = inv.shard_rows();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows.iter().map(|r| r.machines).sum::<usize>(), 7);
        assert_eq!(rows.iter().map(|r| r.capacity).sum::<u32>(), 14);
        assert_eq!(rows.iter().map(|r| r.held).sum::<u32>(), 5);
        let held = inv.held_by_machine();
        assert_eq!(held.iter().sum::<u32>(), 5);
        for &(m, g) in &a {
            assert_eq!(held[m], g);
        }
        for r in &rows {
            assert_eq!(r.free + r.held, r.capacity);
        }
        inv.release(&a);
        assert!(inv.held_by_machine().iter().all(|&h| h == 0));
    }
}
