//! Cluster scheduling POLICIES: FIFO, Static, ElasticSimple (the Fig 11
//! pair), Tiresias (discretized 2D-LAS, Gu et al. NSDI'19) and
//! Elastic-Tiresias (Tiresias + the paper's R1 compaction / R2 expansion
//! rules, §5.1).
//!
//! Policies are pure planners over the policy/engine split
//! ([`crate::sched`]): they read a [`ClusterView`] (inventory, per-job
//! state, attained service, adjustability) and submit typed
//! [`Decision`]s. The SAME policy object drives both engines —
//! [`ClusterSim`](crate::cluster::ClusterSim) in simulation and the live
//! multi-job [`master`](crate::master) daemon, which maps each decision
//! onto the Table-1 surface ([`crate::api::JobControl`]) of a real job.
//!
//! The per-job scaling primitives [`ElasticTiresias::expand_job`] /
//! [`ElasticTiresias::shrink_job`] are written against [`JobControl`]
//! directly, so engines (and tests) apply Grow/Shrink decisions to a
//! simulated handle, an in-process `ElasticTrainer`, or a TCP
//! `JobClient` with the same code.

use crate::api::{ElasticError, JobControl, JobControlExt};
use crate::sched::{ClusterCtl, Decision, Scheduler};
use std::time::Duration;

/// How long the retry helpers wait out an in-flight adjustment (§3.1)
/// before giving up. Simulated handles never sleep here: scheduler rules
/// only touch jobs that are currently adjustable.
const RETRY_T: Duration = Duration::from_secs(30);

/// Jobs submitted and waiting for placement, by engine index.
fn pending_jobs(ctl: &dyn ClusterCtl) -> Vec<usize> {
    (0..ctl.n_jobs()).filter(|&i| ctl.job_view(i).pending).collect()
}

/// Jobs currently holding GPUs (running or mid-scale-out).
fn running_jobs(ctl: &dyn ClusterCtl) -> Vec<usize> {
    (0..ctl.n_jobs()).filter(|&i| ctl.job_view(i).running).collect()
}

/// Grow job `i` to `target` GPUs; false if the job cannot accept an
/// adjustment now or the engine rejects the decision.
fn grow_to(ctl: &mut dyn ClusterCtl, i: usize, target: u32) -> bool {
    let v = ctl.job_view(i);
    if target <= v.current_p || !v.adjustable {
        return false;
    }
    ctl.submit(Decision::Grow { job: i, to: target })
}

/// Shrink job `i` to `target` GPUs.
fn shrink_to(ctl: &mut dyn ClusterCtl, i: usize, target: u32) -> bool {
    let v = ctl.job_view(i);
    if target >= v.current_p || target == 0 || !v.adjustable {
        return false;
    }
    ctl.submit(Decision::Shrink { job: i, to: target })
}

/// Plain FIFO at requested parallelism (baseline / test harness).
#[derive(Default)]
pub struct FifoScheduler;

impl Scheduler for FifoScheduler {
    fn name(&self) -> &'static str {
        "fifo"
    }
    fn replan(&mut self, ctl: &mut dyn ClusterCtl) {
        for i in pending_jobs(ctl) {
            let p = ctl.job_view(i).requested_p;
            if !ctl.submit(Decision::Start { job: i, p }) {
                break; // strict FIFO: no backfill past the head
            }
        }
    }
}

/// The Fig 11 "Static" strategy: every job runs with a fixed parallelism,
/// FIFO admission, pending queue when the cluster is full.
pub struct StaticScheduler {
    pub fixed_p: u32,
}

impl Scheduler for StaticScheduler {
    fn name(&self) -> &'static str {
        "static"
    }
    fn replan(&mut self, ctl: &mut dyn ClusterCtl) {
        for i in pending_jobs(ctl) {
            if !ctl.submit(Decision::Start { job: i, p: self.fixed_p }) {
                break;
            }
        }
    }
}

/// The Fig 11 "Elastic" strategy (§6.3 synthetic workload, verbatim from
/// the paper): new jobs go to the least-loaded machine; a machine's GPUs
/// are divided uniformly among its jobs; jobs scale out into idle GPUs as
/// long as throughput does not decrease (capped at one machine — beyond
/// it the big-model comm cost makes the gain negative anyway); when the
/// cluster fills up, running jobs shrink (R1-style, respecting the
/// `r`·p_default QoS floor) to admit newcomers.
pub struct ElasticSimple {
    pub default_p: u32,
    /// quality-of-service floor: a job keeps at least ceil(r * default_p)
    pub r: f64,
}

impl ElasticSimple {
    fn min_p(&self) -> u32 {
        ((self.r * self.default_p as f64).ceil() as u32).max(1)
    }

    /// uniform shares of the cluster for `n` jobs (machine-capped;
    /// remainder GPUs spread one-by-one over the first jobs)
    fn shares(&self, ctl: &dyn ClusterCtl, n: u32) -> Vec<u32> {
        if n == 0 {
            return Vec::new();
        }
        let total = ctl.total_gpus();
        let base = total / n;
        let rem = total % n;
        (0..n)
            .map(|i| (base + u32::from(i < rem)).clamp(self.min_p(), ctl.gpus_per_machine()))
            .collect()
    }

    fn steerable(ctl: &dyn ClusterCtl, i: usize) -> bool {
        let v = ctl.job_view(i);
        v.elastic && v.adjustable
    }
}

impl Scheduler for ElasticSimple {
    fn name(&self) -> &'static str {
        "elastic"
    }
    fn replan(&mut self, ctl: &mut dyn ClusterCtl) {
        let pending = pending_jobs(ctl);
        let mut running = running_jobs(ctl);
        running.sort_by_key(|&i| ctl.job_view(i).id);
        let n_after = (running.len() + pending.len()) as u32;
        let shares = self.shares(ctl, n_after);

        // per-job targets: running jobs first (stable by id), newcomers last
        let targets: Vec<(usize, u32, bool)> = running
            .iter()
            .enumerate()
            .map(|(k, &i)| (i, shares[k], false))
            .chain(
                pending
                    .iter()
                    .enumerate()
                    .map(|(k, &i)| (i, shares[running.len() + k], true)),
            )
            .collect();

        // 1. shrink over-target jobs first (graceful exits are cheap)
        for &(i, target, is_new) in &targets {
            if !is_new && Self::steerable(ctl, i) && ctl.job_view(i).current_p > target {
                shrink_to(ctl, i, target);
            }
        }
        // 2. admit newcomers at their share
        for &(i, target, is_new) in &targets {
            if is_new {
                let p = target.min(ctl.free_gpus().max(1));
                if p >= 1 && ctl.free_gpus() >= p {
                    ctl.submit(Decision::Start { job: i, p });
                }
            }
        }
        // 3. grow under-target jobs into remaining idle GPUs, but only
        //    while the throughput gain is non-negative (paper footnote 7)
        for &(i, target, is_new) in &targets {
            if is_new || !Self::steerable(ctl, i) {
                continue;
            }
            let p = ctl.job_view(i).current_p;
            if p >= target || ctl.free_gpus() == 0 {
                continue;
            }
            let want = target.min(p + ctl.free_gpus());
            let s_now = ctl.predicted_throughput(i, p);
            let s_want = ctl.predicted_throughput(i, want);
            if s_want >= s_now {
                grow_to(ctl, i, want);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Tiresias
// ---------------------------------------------------------------------------

/// Discretized two-dimensional least-attained-service scheduler.
/// Jobs sink from G0 to lower-priority queues as their attained service
/// (GPU·s) crosses the queue thresholds; scheduling is priority-then-FIFO;
/// preemption uses checkpoint/restart (modelled as launch overhead on
/// resume). `starve_promote_s`: waiting longer than this re-promotes to G0.
pub struct Tiresias {
    /// attained-service thresholds between queues (GPU·s): e.g. [500, 10_000]
    pub thresholds: Vec<f64>,
    pub starve_promote_s: f64,
    /// last time each job was running (for starvation detection)
    last_active: Vec<f64>,
    /// queue index per job, recomputed by `plan` (policy state — engines
    /// know nothing about Tiresias queues)
    queues: Vec<usize>,
}

impl Tiresias {
    pub fn new(thresholds: Vec<f64>) -> Tiresias {
        Tiresias {
            thresholds,
            starve_promote_s: 6.0 * 3600.0,
            last_active: Vec::new(),
            queues: Vec::new(),
        }
    }

    fn queue_of(&self, attained: f64) -> usize {
        self.thresholds.iter().take_while(|&&t| attained >= t).count()
    }

    /// Queue index assigned to job `i` by the latest `plan`.
    pub fn queue(&self, i: usize) -> usize {
        self.queues.get(i).copied().unwrap_or(0)
    }

    /// priority ordering: queue asc, then submit time asc
    fn plan(&mut self, ctl: &mut dyn ClusterCtl) -> Vec<usize> {
        let n = ctl.n_jobs();
        if self.last_active.len() < n {
            self.last_active.resize(n, 0.0);
        }
        if self.queues.len() < n {
            self.queues.resize(n, 0);
        }
        let now = ctl.now_s();
        let mut candidates: Vec<usize> = Vec::new();
        for i in 0..n {
            let v = ctl.job_view(i);
            if !v.submitted || v.finished {
                continue;
            }
            candidates.push(i);
        }
        for &i in &candidates {
            let v = ctl.job_view(i);
            let mut q = self.queue_of(v.attained_gpu_s);
            // starvation: long-waiting jobs promoted to G0 (§5.1)
            let waiting = v.pending;
            if waiting && now - self.last_active[i].max(v.submit_s) > self.starve_promote_s {
                q = 0;
            }
            if !waiting {
                self.last_active[i] = now;
            }
            self.queues[i] = q;
        }
        candidates.sort_by(|&a, &b| {
            (self.queues[a], ctl.job_view(a).submit_s)
                .partial_cmp(&(self.queues[b], ctl.job_view(b).submit_s))
                .unwrap()
        });
        // admit in priority order while capacity lasts
        let mut capacity = ctl.total_gpus();
        let mut admitted = Vec::new();
        for &i in &candidates {
            let p = ctl.job_view(i).requested_p;
            if p <= capacity {
                capacity -= p;
                admitted.push(i);
            }
        }
        // preempt running jobs not admitted, then start admitted pending
        for &i in &candidates {
            if ctl.job_view(i).running && !admitted.contains(&i) {
                ctl.submit(Decision::Preempt { job: i });
            }
        }
        admitted
    }
}

impl Scheduler for Tiresias {
    fn name(&self) -> &'static str {
        "tiresias"
    }
    fn replan(&mut self, ctl: &mut dyn ClusterCtl) {
        let admitted = self.plan(ctl);
        for i in admitted {
            let v = ctl.job_view(i);
            if v.pending {
                ctl.submit(Decision::Start { job: i, p: v.requested_p });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Elastic-Tiresias (§5.1)
// ---------------------------------------------------------------------------

/// Tiresias + the paper's two elasticity rules:
///  * **R1 compaction** — when more than `n_waiting_threshold` jobs wait,
///    shrink running jobs (never below ceil(r·p_requested), never jobs in
///    G0) to free GPUs for the highest-priority pending jobs, choosing the
///    shrink that maximises the GPU-efficiency gain;
///  * **R2 expansion** — when nothing waits and GPUs idle, grow the job
///    with the largest marginal throughput gain one GPU at a time.
pub struct ElasticTiresias {
    pub base: Tiresias,
    pub n_waiting_threshold: usize,
    pub r: f64,
    /// ablation switches (both on = the paper's Elastic-Tiresias)
    pub enable_r1: bool,
    pub enable_r2: bool,
}

impl ElasticTiresias {
    pub fn new(thresholds: Vec<f64>, n_waiting_threshold: usize, r: f64) -> ElasticTiresias {
        ElasticTiresias {
            base: Tiresias::new(thresholds),
            n_waiting_threshold,
            r,
            enable_r1: true,
            enable_r2: true,
        }
    }

    fn min_p(&self, requested: u32) -> u32 {
        ((self.r * requested as f64).ceil() as u32).max(1)
    }

    /// R2 expansion primitive: one Table-1 `scale_out` adding one worker
    /// per `machines` entry. Written against [`JobControl`], so the SAME
    /// code applies a Grow decision to a
    /// [`SimJobHandle`](crate::cluster::SimJobHandle) in simulation and
    /// to a live job leader — in-process or behind `api::JobClient` over
    /// TCP. §3.1 in-flight rejections are retried with backoff by
    /// [`JobControlExt`].
    pub fn expand_job(
        job: &mut (impl JobControl + ?Sized),
        machines: Vec<String>,
    ) -> Result<(), ElasticError> {
        job.scale_out_retry(machines, RETRY_T)
    }

    /// R0/R1 shrink primitive: remove the `n` most recently added workers
    /// (`status` → victim ids → Table-1 `scale_in`), same-code-everywhere
    /// like [`ElasticTiresias::expand_job`].
    pub fn shrink_job(
        job: &mut (impl JobControl + ?Sized),
        n: u32,
    ) -> Result<(), ElasticError> {
        if n == 0 {
            return Ok(());
        }
        let st = job.status()?;
        if st.workers.len() as u32 <= n {
            return Err(ElasticError::InvalidRequest(
                "shrink would remove every worker".into(),
            ));
        }
        let victims = st.workers[st.workers.len() - n as usize..].to_vec();
        job.scale_in_retry(victims, RETRY_T)
    }

    /// efficiency gain of shrinking job i by one GPU
    fn shrink_gain(ctl: &dyn ClusterCtl, i: usize, max_p: u32) -> f64 {
        let p = ctl.job_view(i).current_p;
        if p <= 1 {
            return f64::MIN;
        }
        ctl.predicted_efficiency(i, p - 1, max_p) - ctl.predicted_efficiency(i, p, max_p)
    }

    fn shrinkable(&self, ctl: &dyn ClusterCtl, i: usize) -> bool {
        let v = ctl.job_view(i);
        v.elastic
            && self.base.queue(i) > 0 // never shrink G0 jobs (§5.1)
            && v.adjustable
            && v.current_p > self.min_p(v.requested_p)
    }
}

impl Scheduler for ElasticTiresias {
    fn name(&self) -> &'static str {
        "elastic-tiresias"
    }
    fn replan(&mut self, ctl: &mut dyn ClusterCtl) {
        // base Tiresias allocation first
        let admitted = self.base.plan(ctl);
        for &i in &admitted {
            let v = ctl.job_view(i);
            if v.pending {
                ctl.submit(Decision::Start { job: i, p: v.requested_p });
            }
        }

        // R0 reclaim: expansion borrows only *idle* GPUs (§2.2: "scaled in
        // to return the resources when they need to be re-allocated") — as
        // soon as jobs wait, expanded jobs shrink back toward their
        // requested parallelism so newcomers can start. Graceful exits are
        // cheap, so reclaim is immediate.
        if self.enable_r2 {
            let mut pending = pending_jobs(ctl);
            pending.sort_by(|&a, &b| {
                (self.base.queue(a), ctl.job_view(a).submit_s)
                    .partial_cmp(&(self.base.queue(b), ctl.job_view(b).submit_s))
                    .unwrap()
            });
            for w in pending {
                let want = ctl.job_view(w).requested_p;
                if ctl.free_gpus() >= want {
                    ctl.submit(Decision::Start { job: w, p: want });
                    continue;
                }
                // reclaim from the most over-allocated expanded jobs first
                let mut expanded: Vec<usize> = running_jobs(ctl)
                    .into_iter()
                    .filter(|&i| {
                        let v = ctl.job_view(i);
                        v.elastic && v.current_p > v.requested_p && v.adjustable
                    })
                    .collect();
                expanded.sort_by_key(|&i| {
                    let v = ctl.job_view(i);
                    std::cmp::Reverse(v.current_p - v.requested_p)
                });
                for i in expanded {
                    if ctl.free_gpus() >= want {
                        break;
                    }
                    let deficit = want - ctl.free_gpus();
                    let v = ctl.job_view(i);
                    let surplus = v.current_p - v.requested_p;
                    let give = surplus.min(deficit);
                    shrink_to(ctl, i, v.current_p - give);
                }
                if ctl.free_gpus() >= want {
                    ctl.submit(Decision::Start { job: w, p: want });
                } else {
                    break;
                }
            }
        }

        // R1 compaction — §5.1 intent: when the queue builds up, shrink
        // large/low-priority running jobs to get SMALL/high-priority jobs
        // (G0: the program-check / hyperparameter-search jobs Tiresias
        // protects) running and prevent head-of-line blocking. Compacting
        // for arbitrary large waiters under sustained overload inverts the
        // SJF discipline and inflates everyone's JCT (see the
        // ablation_elastic_rules example), so only G0 waiters qualify.
        let mut waiting = pending_jobs(ctl);
        if self.enable_r1 && waiting.len() > self.n_waiting_threshold {
            waiting.retain(|&w| self.base.queue(w) == 0);
            waiting.sort_by(|&a, &b| {
                ctl.job_view(a).submit_s.partial_cmp(&ctl.job_view(b).submit_s).unwrap()
            });
            for w in waiting {
                let want = ctl.job_view(w).requested_p;
                let max_p = ctl.max_p_norm();
                let mut guard = 0;
                while ctl.free_gpus() < want {
                    guard += 1;
                    if guard > 4096 {
                        break;
                    }
                    // victim with the best efficiency gain from shrinking
                    let mut best: Option<(usize, f64)> = None;
                    for i in running_jobs(ctl) {
                        if self.shrinkable(ctl, i) {
                            let g = Self::shrink_gain(ctl, i, max_p);
                            if best.map(|(_, bg)| g > bg).unwrap_or(true) {
                                best = Some((i, g));
                            }
                        }
                    }
                    match best {
                        Some((i, _)) => {
                            let p = ctl.job_view(i).current_p;
                            if !shrink_to(ctl, i, p - 1) {
                                break;
                            }
                        }
                        None => break,
                    }
                }
                if ctl.free_gpus() >= want {
                    ctl.submit(Decision::Start { job: w, p: want });
                } else {
                    break; // can't help lower-priority waiters either
                }
            }
        }

        // R2 expansion: allocate idle GPUs greedily by marginal gain, then
        // merge each job's consecutive +1 grants into ONE Grow decision
        // (one topology switch — §5.2's migration-merging idea applied to
        // expansion; issuing them one at a time would pay the scale-out
        // e2e latency per GPU)
        if self.enable_r2 && pending_jobs(ctl).is_empty() && ctl.free_gpus() > 0 {
            let mut budget = ctl.free_gpus();
            // virtual parallelism during the greedy pass
            let mut virt: std::collections::HashMap<usize, u32> = std::collections::HashMap::new();
            let candidates: Vec<usize> = running_jobs(ctl)
                .into_iter()
                .filter(|&i| {
                    let v = ctl.job_view(i);
                    v.elastic && v.adjustable
                })
                .collect();
            for &i in &candidates {
                virt.insert(i, ctl.job_view(i).current_p);
            }
            let mut guard = 0;
            while budget > 0 {
                guard += 1;
                if guard > 4096 {
                    break;
                }
                let mut best: Option<(usize, f64)> = None;
                for &i in &candidates {
                    let p = virt[&i];
                    let s_p = ctl.predicted_throughput(i, p);
                    let s_p1 = ctl.predicted_throughput(i, p + 1);
                    let g = (s_p1 - s_p) / s_p;
                    if g > 0.0 && best.map(|(_, bg)| g > bg).unwrap_or(true) {
                        best = Some((i, g));
                    }
                }
                match best {
                    Some((i, _)) => {
                        *virt.get_mut(&i).unwrap() += 1;
                        budget -= 1;
                    }
                    None => break,
                }
            }
            for &i in &candidates {
                let target = virt[&i];
                if target > ctl.job_view(i).current_p {
                    grow_to(ctl, i, target);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSim, JobState, ScaleMode};
    use crate::gpu_sim::Dnn;
    use crate::metrics::JctStats;
    use crate::trace::TraceJob;

    fn mk_job(id: u64, submit: f64, gpus: u32, dur: f64, model: Dnn) -> TraceJob {
        TraceJob { id, submit_s: submit, gpus, service_gpu_s: dur * gpus as f64, model }
    }

    #[test]
    fn tiresias_queue_sinking() {
        let t = Tiresias::new(vec![500.0, 10_000.0]);
        assert_eq!(t.queue_of(0.0), 0);
        assert_eq!(t.queue_of(499.0), 0);
        assert_eq!(t.queue_of(500.0), 1);
        assert_eq!(t.queue_of(9_999.0), 1);
        assert_eq!(t.queue_of(10_000.0), 2);
    }

    #[test]
    fn tiresias_small_job_preempts_large() {
        // a long 8-GPU job holds the machine; a tiny job arrives later and
        // must run before the big one finishes (shortest-job-first-ish)
        let trace = vec![
            mk_job(0, 0.0, 8, 100_000.0, Dnn::ResNet50),
            mk_job(1, 5_000.0, 8, 60.0, Dnn::ResNet50),
        ];
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
        let mut sched = Tiresias::new(vec![500.0, 10_000.0]);
        sim.run(&mut sched, 5e6);
        let jct_small = sim.jobs[1].jct().unwrap();
        // without preemption it would wait ~95,000 s for the big job
        assert!(jct_small < 10_000.0, "small job JCT {jct_small}");
    }

    #[test]
    fn static_vs_elastic_cluster_efficiency_during_ramp() {
        // Fig 11 setup (scaled down): 2 machines × 8 GPUs, job every 30 s,
        // long jobs. The paper's measurement window is the ramp (jobs
        // arriving, none finishing): Static leaves GPUs idle while Elastic
        // expands into them, so Elastic's *cluster* efficiency is higher
        // (its per-GPU efficiency is lower early on — Fig 11b).
        let trace: Vec<TraceJob> =
            (0..8).map(|i| mk_job(i, i as f64 * 120.0, 4, 5_000.0, Dnn::ResNet50)).collect();
        let window = 1_100.0;
        let mut s_static = ClusterSim::new(2, 8, &trace, ScaleMode::Edl);
        s_static.run(&mut StaticScheduler { fixed_p: 4 }, window);

        let mut s_elastic = ClusterSim::new(2, 8, &trace, ScaleMode::Edl);
        s_elastic.run(&mut ElasticSimple { default_p: 4, r: 0.5 }, window);

        let ce_static = s_static.cluster_eff_ts.time_weighted_mean();
        let ce_elastic = s_elastic.cluster_eff_ts.time_weighted_mean();
        assert!(
            ce_elastic > ce_static,
            "elastic should beat static on cluster efficiency: {ce_elastic:.3} vs {ce_static:.3}"
        );
    }

    #[test]
    fn elastic_tiresias_expansion_reduces_jct_when_underloaded() {
        // sequential 2-GPU jobs on an 8-GPU machine: Tiresias leaves 6
        // GPUs idle; R2 expansion soaks them and finishes each job faster
        let trace: Vec<TraceJob> =
            (0..5).map(|i| mk_job(i, i as f64 * 3_000.0, 2, 1_200.0, Dnn::ResNet50)).collect();
        let mut base_sim = ClusterSim::new(1, 8, &trace, ScaleMode::Edl);
        base_sim.run(&mut Tiresias::new(vec![500.0, 10_000.0]), 5e6);
        let base_stats = JctStats::from(&base_sim.jcts());

        let mut el_sim = ClusterSim::new(1, 8, &trace, ScaleMode::Edl);
        el_sim.run(&mut ElasticTiresias::new(vec![500.0, 10_000.0], 10, 0.5), 5e6);
        let el_stats = JctStats::from(&el_sim.jcts());

        assert_eq!(base_stats.count, trace.len());
        assert_eq!(el_stats.count, trace.len());
        assert!(
            el_stats.mean < 0.8 * base_stats.mean,
            "expansion should cut JCT: elastic {:.0} vs tiresias {:.0}",
            el_stats.mean,
            base_stats.mean
        );
    }

    #[test]
    fn elastic_tiresias_no_regression_on_mixed_load() {
        // mixed over/under-loaded phases: elasticity must not materially
        // hurt JCT even when its rules fire frequently (the decisive win
        // shows on the full overloaded trace — see table4_fig12 bench)
        let mut trace = Vec::new();
        for w in 0..12u64 {
            let big = w % 3 == 0;
            trace.push(mk_job(
                w,
                w as f64 * 120.0,
                if big { 8 } else { 2 },
                if big { 4_000.0 } else { 300.0 },
                if big { Dnn::VGG19 } else { Dnn::ResNet50 },
            ));
        }
        let mut base_sim = ClusterSim::new(2, 8, &trace, ScaleMode::Edl);
        base_sim.run(&mut Tiresias::new(vec![500.0, 10_000.0]), 5e6);
        let base_stats = JctStats::from(&base_sim.jcts());

        let mut el_sim = ClusterSim::new(2, 8, &trace, ScaleMode::Edl);
        el_sim.run(&mut ElasticTiresias::new(vec![500.0, 10_000.0], 2, 0.5), 5e6);
        let el_stats = JctStats::from(&el_sim.jcts());

        assert_eq!(el_stats.count, trace.len());
        assert!(
            el_stats.mean < 1.15 * base_stats.mean,
            "elastic-tiresias {:.0} regressed vs tiresias {:.0}",
            el_stats.mean,
            base_stats.mean
        );
    }

    #[test]
    fn r2_expansion_fills_idle_gpus() {
        let trace = vec![mk_job(0, 0.0, 2, 5_000.0, Dnn::ResNet50)];
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
        let mut sched = ElasticTiresias::new(vec![500.0], 10, 0.5);
        // run a short while: the single job should be expanded beyond 2
        sim.run(&mut sched, 500.0);
        assert!(
            sim.jobs[0].current_p() > 2,
            "R2 should expand the only job: p={}",
            sim.jobs[0].current_p()
        );
        // the expansion is visible in the decision log as Grow decisions
        assert!(
            sim.decision_log.iter().any(|(_, d)| matches!(d, Decision::Grow { job: 0, .. })),
            "decision log must record the R2 expansion: {:?}",
            sim.decision_log
        );
    }

    #[test]
    fn r1_respects_qos_floor() {
        // one running 8-GPU job (out of G0) + many waiters: compaction must
        // not shrink below ceil(r * requested)
        let mut trace = vec![mk_job(0, 0.0, 8, 100_000.0, Dnn::ResNet50)];
        for i in 1..8 {
            trace.push(mk_job(i, 10_000.0, 4, 2_000.0, Dnn::ResNet50));
        }
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Edl);
        let mut sched = ElasticTiresias::new(vec![500.0], 1, 0.5);
        sim.run(&mut sched, 11_000.0);
        let p = sim.jobs[0].current_p();
        assert!(p >= 4 || matches!(sim.jobs[0].state, JobState::Pending),
            "job 0 shrunk below QoS floor: p={p}");
    }

    #[test]
    fn inelastic_jobs_skipped_by_rules() {
        let trace = vec![mk_job(0, 0.0, 2, 10_000.0, Dnn::ResNet50)];
        let mut sim = ClusterSim::new(1, 8, &trace, ScaleMode::Ideal);
        sim.jobs[0].elastic = false;
        let mut sched = ElasticTiresias::new(vec![500.0], 10, 0.5);
        sim.run(&mut sched, 300.0);
        assert_eq!(sim.jobs[0].current_p(), 2, "inelastic job must keep its parallelism");
    }
}
