//! `edl master` — the live multi-job cluster engine (§2, §6): the second
//! implementation of the policy/engine split ([`crate::sched`]), next to
//! the discrete-event simulator.
//!
//! ```text
//!   edl submit ──MasterRequest──►┐
//!   edl master jobs ────────────►│ control endpoint
//!                                ▼
//!                         Master shell thread
//!        sharded inventory ─ job table ─ policy tick (Scheduler
//!        (per-rack locks)         │       over a ViewSnapshot)
//!                │ Decision       │ ExecTask queue
//!                ▼                ▼
//!         api::JobControl   executor pool ─ poller pool
//!         (Grow/Shrink via  (leader spawn, Table-1 calls,
//!          Table-1 calls)    bounded status sweeps)
//! ```
//!
//! The master owns the machine inventory (named machines × GPU slots,
//! sharded per rack — [`inventory::ShardedInventory`]), accepts
//! `edl submit` jobs, and for each started job spawns a per-job leader
//! ([`LeaderEndpoint`]) plus one `edl worker` OS process per granted GPU
//! slot. A [`Scheduler`] policy (the SAME objects the simulator runs)
//! ticks on a clock over an owned [`ViewSnapshot`](crate::sched::ViewSnapshot)
//! — assembled from lock-free per-shard counters, never holding a global
//! inventory lock — and its [`Decision`]s are validated and their slots
//! reserved synchronously (eager, per the sched contract), while the
//! slow Table-1/process work drains through a fixed executor pool:
//!
//!  * `Start` — allocate slots, spawn leader + founder workers;
//!  * `Grow`  — reserve idle slots, spawn joiner workers, `scale_out`;
//!  * `Shrink`— `status` → newest workers → `scale_in`, slots returned
//!    to the machines the workers ran on (graceful, no restart);
//!  * `Preempt`/`Migrate` — refused: the master NEVER restarts a job
//!    (the paper's checkpoint/restart baseline is simulator-only).
//!
//! Datacenter-scale knobs: `sim_slots` runs jobs as in-process virtual
//! step cadences (no leader, no worker processes) so one box hosts
//! hundreds of live jobs for the `perf_master_tick` bench;
//! `headless_workers` spawns `edl worker --headless` processes (control
//! plane only, no data plane); `pipeline = false` restores the serial
//! apply-per-tick engine as an in-bench baseline.
//!
//! Every started job's Table-1 address is registered in the embedded
//! coordination KV under `edl/jobs/<name>/ctl` with a TTL lease the
//! master refreshes each tick (batched `put_many`, chunked so one frame
//! never carries more than 512 leases), so `edl ctl --job <name> --kv
//! <addr>` resolves live jobs by name.

pub mod inventory;
pub mod proto;

use crate::api::{JobControl, JobControlExt, JobServer, Request, Response};
use crate::coordinator::TrainerConfig;
use crate::coordsvc::{KvClient, KvServer};
use crate::deploy::{config_digest, LeaderEndpoint, LeaderHandle};
use crate::gpu_sim::{self, Dnn, HwConfig};
use crate::sched::{
    ClusterCtl, ClusterView, Decision, JobView, NoopScheduler, Scheduler, SnapshotCtl,
};
use crate::schedulers::ElasticTiresias;
use crate::wire;
use crate::worker::{Backend, SimBackend};
use inventory::ShardedInventory;
use proto::{JobInfo, MasterRequest, MasterResponse, MasterStats, ShardStat, SubmitSpec};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Fixed sim-job data-pipeline shape, shared with `edl worker` defaults so
/// the [`config_digest`] handshake matches (see `deploy_digest` in
/// main.rs: samples / data-seed / params / seq / lr).
const SIM_SAMPLES: u64 = 4096;
const SIM_DATA_SEED: u64 = 1;
const SIM_LR: f32 = 0.05;
/// Aggregate batch of every master-run job (constant under scaling,
/// §3.1). Used for BOTH the leader's `TrainerConfig` and the policy's
/// what-if queries, so the analytic model describes the job that runs.
const SIM_AGG_BATCH: u32 = 32;
/// Sliding window of per-tick durations kept for the p50/p99 stats.
const TICK_WINDOW: usize = 4096;
/// Max leases per KV `put_many` frame.
const LEASE_CHUNK: usize = 512;

/// One named machine with a number of GPU slots.
#[derive(Debug, Clone)]
pub struct MachineSpec {
    pub name: String,
    pub gpus: u32,
}

pub struct MasterConfig {
    pub machines: Vec<MachineSpec>,
    /// scheduler tick period (ms)
    pub tick_ms: u64,
    /// TTL of the per-job ctl-address lease in the KV (ms)
    pub lease_ttl_ms: u64,
    /// master control endpoint bind address
    pub listen: String,
    /// embedded coordination-KV bind address
    pub kv_listen: String,
    /// binary to spawn worker processes from (default: this executable)
    pub worker_bin: Option<PathBuf>,
    /// machines per inventory shard (rack) — the lock granularity of the
    /// sharded inventory; `usize::MAX` means one shard (unsharded)
    pub rack_size: usize,
    /// run jobs as in-process virtual step cadences: no leader, no worker
    /// processes — one box hosts hundreds of "live" jobs (bench mode)
    pub sim_slots: bool,
    /// pass `--headless` to spawned `edl worker` processes (control plane
    /// only, no data plane — see DESIGN.md §10)
    pub headless_workers: bool,
    /// batched, pipelined decision application through the executor pool;
    /// `false` restores the serial apply-per-tick engine (the
    /// `perf_master_tick` in-bench baseline)
    pub pipeline: bool,
    /// executor threads draining the decision queue (pipeline mode)
    pub executors: usize,
    /// status-poll threads (pipeline mode; separate pool so a slow
    /// Table-1 op never starves the status sweep)
    pub pollers: usize,
}

impl Default for MasterConfig {
    fn default() -> MasterConfig {
        MasterConfig {
            machines: vec![
                MachineSpec { name: "m1".into(), gpus: 2 },
                MachineSpec { name: "m2".into(), gpus: 2 },
            ],
            tick_ms: 250,
            lease_ttl_ms: 5_000,
            listen: "127.0.0.1:0".into(),
            kv_listen: "127.0.0.1:0".into(),
            worker_bin: None,
            rack_size: 32,
            sim_slots: false,
            headless_workers: false,
            pipeline: true,
            executors: 4,
            pollers: 4,
        }
    }
}

/// The running daemon: control endpoint + embedded KV + shell thread.
pub struct Master {
    /// control endpoint (`edl submit --master <addr>`)
    pub addr: String,
    /// embedded coordination KV (`edl ctl --job <name> --kv <addr>`)
    pub kv_addr: String,
    shell: Option<std::thread::JoinHandle<()>>,
    accept_stop: Arc<AtomicBool>,
    /// set by Drop so an abandoned Master tears its jobs down instead of
    /// leaking the shell thread and worker processes
    halt: Arc<AtomicBool>,
}

impl Master {
    pub fn start(
        cfg: MasterConfig,
        sched: Box<dyn Scheduler + Send>,
    ) -> anyhow::Result<Master> {
        anyhow::ensure!(!cfg.machines.is_empty(), "master needs at least one machine");
        anyhow::ensure!(
            cfg.machines.iter().all(|m| m.gpus >= 1),
            "every machine needs at least one GPU slot"
        );
        let kv = KvServer::start_on(&cfg.kv_listen)?;
        let kv_addr = kv.addr.clone();
        let listener = TcpListener::bind(&cfg.listen)?;
        let addr = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let (tx, rx) = channel::<MIn>();
        let accept_stop = Arc::new(AtomicBool::new(false));

        // accept loop: thread per connection, framed request/reply into
        // the shell's mailbox (the JobServer pattern)
        {
            let tx = tx.clone();
            let stop = accept_stop.clone();
            std::thread::Builder::new()
                .name("edl-master-accept".into())
                .spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                let tx = tx.clone();
                                std::thread::spawn(move || {
                                    let _ = serve_master_conn(stream, tx);
                                });
                            }
                            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(2));
                            }
                            Err(_) => break,
                        }
                    }
                })
                .expect("spawn master accept loop");
        }

        let worker_bin = match cfg.worker_bin.clone() {
            Some(p) => p,
            None => std::env::current_exe()?,
        };
        let hw = HwConfig {
            gpus_per_machine: cfg.machines.iter().map(|m| m.gpus).max().unwrap_or(1),
            ..HwConfig::default()
        };
        let inv = Arc::new(ShardedInventory::new(&cfg.machines, cfg.rack_size));
        let exec_ctx = ExecCtx { worker_bin, headless: cfg.headless_workers };
        let (exec_tx, poll_tx) = if cfg.pipeline {
            (
                Some(spawn_pool("edl-master-exec", cfg.executors.max(1), &exec_ctx, &tx)),
                Some(spawn_pool("edl-master-poll", cfg.pollers.max(1), &exec_ctx, &tx)),
            )
        } else {
            (None, None)
        };
        let halt = Arc::new(AtomicBool::new(false));
        let shell = Shell {
            inv,
            hw,
            jobs: Vec::new(),
            sched,
            rx,
            tx,
            kv,
            kv_client: None,
            start: Instant::now(),
            last_now: 0.0,
            last_tick: Instant::now(),
            tick_ms: cfg.tick_ms.max(50),
            lease_ttl_ms: cfg.lease_ttl_ms.max(500),
            exec_ctx,
            exec_tx,
            poll_tx,
            sim_slots: cfg.sim_slots,
            stats: Stats::default(),
            accept_stop: accept_stop.clone(),
            halt: halt.clone(),
        };
        let shell = std::thread::Builder::new()
            .name("edl-master".into())
            .spawn(move || shell.run())
            .expect("spawn master shell");
        Ok(Master { addr, kv_addr, shell: Some(shell), accept_stop, halt })
    }

    /// Block until the master shuts down (a client sent `Shutdown`).
    pub fn join(mut self) {
        if let Some(h) = self.shell.take() {
            let _ = h.join();
        }
        self.accept_stop.store(true, Ordering::Relaxed);
    }
}

impl Drop for Master {
    fn drop(&mut self) {
        // an abandoned Master (drop without `join`) must not leak jobs:
        // the shell polls this flag every ≤100 ms, tears every job down
        // (stopping leaders, reaping worker processes) and exits
        self.halt.store(true, Ordering::Relaxed);
        self.accept_stop.store(true, Ordering::Relaxed);
    }
}

fn serve_master_conn(stream: TcpStream, tx: Sender<MIn>) -> wire::Result<()> {
    wire::serve_framed(stream, move |raw| {
        let resp = match MasterRequest::decode(raw) {
            Ok(req) => {
                let (rtx, rrx) = channel();
                if tx.send(MIn::Ctl(req, rtx)).is_ok() {
                    rrx.recv_timeout(Duration::from_secs(60))
                        .unwrap_or_else(|_| MasterResponse::Err("master unresponsive".into()))
                } else {
                    MasterResponse::Err("master stopped".into())
                }
            }
            Err(e) => MasterResponse::Err(format!("undecodable request: {e}")),
        };
        Ok(resp.encode())
    })
}

// ---------------------------------------------------------------------------
// executors: the decision pipeline
// ---------------------------------------------------------------------------

/// Which asynchronous operation an executor ran.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Op {
    Start,
    Grow,
    Shrink,
    Stop,
}

/// Everything a freshly started live job hands back to the shell.
struct StartPayload {
    endpoint: LeaderEndpoint,
    ctl: JobServer<LeaderHandle>,
    handle: LeaderHandle,
    children: Vec<Child>,
    ctl_addr: String,
}

/// Outcome of an executor-run operation, reported back to the shell. The
/// shell (sole owner of the job table and sole inventory mutator) commits
/// or rolls back the slot bookkeeping.
struct OpDone {
    job: usize,
    op: Op,
    ok: bool,
    /// Shrink: machine label per returned GPU slot
    freed: Vec<String>,
    /// Shrink: how many workers the committed scale-in removed (the
    /// inventory reconciles against this even if labels are missing)
    removed: usize,
    /// slots to un-reserve: on failure the whole reservation, on a
    /// partially-spawned Grow the unspawned remainder
    undo: Vec<(usize, u32)>,
    /// Grow: joiner processes for the shell to adopt
    children: Vec<Child>,
    /// Start (live): leader endpoint + ctl server + founders
    start: Option<Box<StartPayload>>,
    err: String,
}

impl OpDone {
    fn fail(job: usize, op: Op, undo: Vec<(usize, u32)>, err: String) -> OpDone {
        OpDone {
            job,
            op,
            ok: false,
            freed: Vec::new(),
            removed: 0,
            undo,
            children: Vec::new(),
            start: None,
            err,
        }
    }

    fn ok(job: usize, op: Op) -> OpDone {
        OpDone {
            job,
            op,
            ok: true,
            freed: Vec::new(),
            removed: 0,
            undo: Vec::new(),
            children: Vec::new(),
            start: None,
            err: String::new(),
        }
    }
}

enum MIn {
    Ctl(MasterRequest, Sender<MasterResponse>),
    Done(OpDone),
    PollDone { job: usize, step: Option<u64> },
}

/// One queued unit of decision work. Accepted decisions reserve their
/// slots synchronously on the shell; the slow half (process spawning,
/// Table-1 round-trips) runs here, concurrently across jobs. `live: None`
/// means the job is a `sim_slots` virtual job and the op completes
/// immediately.
enum ExecTask {
    Start {
        job: usize,
        spec: SubmitSpec,
        slots: Vec<(usize, u32)>,
        labels: Vec<String>,
        sim: bool,
    },
    Grow {
        job: usize,
        reserved: Vec<(usize, u32)>,
        labels: Vec<String>,
        live: Option<(LeaderHandle, String)>,
        spec: SubmitSpec,
    },
    Shrink { job: usize, n: usize, live: Option<LeaderHandle> },
    Stop { job: usize, live: Option<LeaderHandle> },
    Poll { job: usize, handle: LeaderHandle },
}

/// What an executor needs besides the task itself.
#[derive(Clone)]
struct ExecCtx {
    worker_bin: PathBuf,
    headless: bool,
}

/// `n` executor threads sharing one task queue. The shared receiver sits
/// behind a mutex; a thread holds it only while blocked in `recv`, so
/// pickup is serial but execution is concurrent. Dropping the returned
/// sender shuts the pool down.
fn spawn_pool(name: &str, n: usize, ctx: &ExecCtx, out: &Sender<MIn>) -> Sender<ExecTask> {
    let (tx, rx) = channel::<ExecTask>();
    let rx = Arc::new(Mutex::new(rx));
    for i in 0..n {
        let rx = rx.clone();
        let ctx = ctx.clone();
        let out = out.clone();
        std::thread::Builder::new()
            .name(format!("{name}-{i}"))
            .spawn(move || loop {
                let task = {
                    let r = rx.lock().unwrap_or_else(|e| e.into_inner());
                    r.recv()
                };
                let Ok(task) = task else { break };
                if out.send(run_task(task, &ctx)).is_err() {
                    break;
                }
            })
            .expect("spawn master executor");
    }
    tx
}

fn spawn_worker(
    ctx: &ExecCtx,
    leader_addr: &str,
    machine: &str,
    spec: &SubmitSpec,
) -> std::io::Result<Child> {
    let mut args: Vec<String> = vec![
        "worker".into(),
        "--leader".into(),
        leader_addr.into(),
        "--machine".into(),
        machine.into(),
        "--backend".into(),
        "sim".into(),
        "--params".into(),
        spec.params.to_string(),
        "--compute-ms".into(),
        spec.compute_ms.to_string(),
        "--samples".into(),
        SIM_SAMPLES.to_string(),
        "--data-seed".into(),
        SIM_DATA_SEED.to_string(),
        "--lr".into(),
        format!("{SIM_LR}"),
    ];
    if ctx.headless {
        args.push("--headless".into());
    }
    // the simulated cluster runs every "machine" on one host; stamping
    // the machine label as the worker's shm identity makes same-machine
    // workers negotiate shared-memory rings exactly as a real multi-node
    // deployment would (transport::machine_identity reads this first)
    Command::new(&ctx.worker_bin)
        .args(&args)
        .env("EDL_MACHINE_ID", machine)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
}

/// The executor body: one task in, one shell message out. Never touches
/// the inventory or the job table — commit/rollback happens on the shell.
fn run_task(task: ExecTask, ctx: &ExecCtx) -> MIn {
    match task {
        ExecTask::Start { job, spec, slots, labels, sim } => {
            if sim {
                let mut done = OpDone::ok(job, Op::Start);
                done.undo = slots;
                return MIn::Done(done);
            }
            run_start(job, spec, slots, labels, ctx)
        }
        ExecTask::Grow { job, reserved, labels, live, spec } => {
            let Some((handle, leader_addr)) = live else {
                return MIn::Done(OpDone::ok(job, Op::Grow));
            };
            run_grow(job, reserved, labels, handle, leader_addr, spec, ctx)
        }
        ExecTask::Shrink { job, n, live } => {
            let Some(handle) = live else {
                let mut done = OpDone::ok(job, Op::Shrink);
                done.removed = n;
                return MIn::Done(done);
            };
            run_shrink(job, n, handle)
        }
        ExecTask::Stop { job, live } => {
            let Some(handle) = live else {
                return MIn::Done(OpDone::ok(job, Op::Stop));
            };
            let resp = handle.call_with_timeout(Request::Stop, Duration::from_secs(30));
            let ok = matches!(resp, Response::Ok);
            let err = if ok { String::new() } else { format!("{resp:?}") };
            let mut done = OpDone::ok(job, Op::Stop);
            done.ok = ok;
            done.err = err;
            MIn::Done(done)
        }
        ExecTask::Poll { job, handle } => {
            // short deadline: one wedged leader must not hold a poller
            // thread hostage; the shell keeps `status_ok = false` until
            // a sweep comes back
            let step = match handle.call_with_timeout(Request::Status, Duration::from_secs(5)) {
                Response::Status(st) => Some(st.step),
                _ => None,
            };
            MIn::PollDone { job, step }
        }
    }
}

/// `Start` (live): stand up the per-job leader + Table-1 server, spawn
/// founder worker processes. Slot bookkeeping already happened at accept;
/// `slots` rides along only so a failure can be rolled back by the shell.
fn run_start(
    job: usize,
    spec: SubmitSpec,
    slots: Vec<(usize, u32)>,
    labels: Vec<String>,
    ctx: &ExecCtx,
) -> MIn {
    let backend =
        SimBackend { compute_ms: spec.compute_ms, ..SimBackend::fast(spec.params as usize) };
    let digest = config_digest(
        SIM_SAMPLES,
        SIM_DATA_SEED,
        backend.param_count(),
        backend.seq_len(),
        SIM_LR,
    );
    let cfg = TrainerConfig {
        agg_batch: SIM_AGG_BATCH,
        lr: SIM_LR,
        approx_recovery: true,
        failure_timeout: Duration::from_secs(20),
        ..Default::default()
    };
    let endpoint = match LeaderEndpoint::start(
        cfg,
        Arc::new(backend),
        SIM_SAMPLES,
        labels.len(),
        "127.0.0.1:0",
        digest,
    ) {
        Ok(e) => e,
        Err(e) => {
            return MIn::Done(OpDone::fail(
                job,
                Op::Start,
                slots,
                format!("leader failed to start: {e}"),
            ))
        }
    };
    let ctl = match JobServer::start_on("127.0.0.1:0", endpoint.handle()) {
        Ok(s) => s,
        Err(e) => {
            return MIn::Done(OpDone::fail(job, Op::Start, slots, format!("ctl server failed: {e}")))
        }
    };
    let handle = endpoint.handle();
    let leader_addr = endpoint.addr.clone();
    let ctl_addr = ctl.addr.clone();
    let mut children = Vec::new();
    for machine in &labels {
        match spawn_worker(ctx, &leader_addr, machine, &spec) {
            Ok(c) => children.push(c),
            Err(e) => {
                eprintln!("[master] job {:?} worker spawn on {machine} failed: {e}", spec.name)
            }
        }
    }
    eprintln!(
        "[master] job {:?} started: p={} ctl={ctl_addr} leader={leader_addr}",
        spec.name,
        labels.len()
    );
    let mut done = OpDone::ok(job, Op::Start);
    done.undo = slots;
    done.start = Some(Box::new(StartPayload { endpoint, ctl, handle, children, ctl_addr }));
    MIn::Done(done)
}

/// `Grow` (live): spawn joiner processes into the leader's lobby, commit
/// with ONE Table-1 `scale_out` (stop-free). Only slots whose joiner
/// PROCESS actually spawned take part; the unspawned remainder goes back
/// via `undo`, and a failed scale-out kills the joiners it spawned.
fn run_grow(
    job: usize,
    reserved: Vec<(usize, u32)>,
    labels: Vec<String>,
    handle: LeaderHandle,
    leader_addr: String,
    spec: SubmitSpec,
    ctx: &ExecCtx,
) -> MIn {
    // labels[i] belongs to reserved[unit_pos[i]]
    let unit_pos: Vec<usize> = reserved
        .iter()
        .enumerate()
        .flat_map(|(i, &(_, g))| std::iter::repeat(i).take(g as usize))
        .collect();
    let mut failed = vec![0u32; reserved.len()];
    let mut children: Vec<Child> = Vec::new();
    let mut spawned: Vec<String> = Vec::new();
    for (i, machine) in labels.iter().enumerate() {
        match spawn_worker(ctx, &leader_addr, machine, &spec) {
            Ok(c) => {
                children.push(c);
                spawned.push(machine.clone());
            }
            Err(e) => {
                failed[unit_pos[i]] += 1;
                eprintln!("[master] job {:?} joiner spawn on {machine} failed: {e}", spec.name);
            }
        }
    }
    if spawned.is_empty() {
        return MIn::Done(OpDone::fail(
            job,
            Op::Grow,
            reserved,
            "no joiner process could be spawned".into(),
        ));
    }
    let unspawned: Vec<(usize, u32)> = reserved
        .iter()
        .zip(&failed)
        .filter(|&(_, &f)| f > 0)
        .map(|(&(m, _), &f)| (m, f))
        .collect();
    let mut h = handle;
    match ElasticTiresias::expand_job(&mut h, spawned) {
        Ok(()) => {
            let mut done = OpDone::ok(job, Op::Grow);
            done.undo = unspawned;
            done.children = children;
            MIn::Done(done)
        }
        Err(e) => {
            // joiners never joined: reap them here (the shell never saw
            // them), roll back the whole reservation
            for c in &mut children {
                let _ = c.kill();
                let _ = c.wait();
            }
            MIn::Done(OpDone::fail(job, Op::Grow, reserved, e.to_string()))
        }
    }
}

/// `Shrink` (live): graceful scale-in of the newest workers; their
/// machine labels (from Table-1 `status`) say which slots come back.
fn run_shrink(job: usize, n: usize, handle: LeaderHandle) -> MIn {
    let mut h = handle;
    let (ok, freed, err) = match h.status() {
        Ok(st) if st.workers.len() > n => {
            let k = st.workers.len() - n;
            let victims = st.workers[k..].to_vec();
            let freed: Vec<String> =
                st.worker_machines.get(k..).map(|s| s.to_vec()).unwrap_or_default();
            match h.scale_in_retry(victims, Duration::from_secs(30)) {
                Ok(()) => (true, freed, String::new()),
                Err(e) => (false, Vec::new(), e.to_string()),
            }
        }
        Ok(_) => (false, Vec::new(), "shrink would remove every worker".into()),
        Err(e) => (false, Vec::new(), e.to_string()),
    };
    let mut done = OpDone::ok(job, Op::Shrink);
    done.ok = ok;
    done.freed = freed;
    done.removed = n;
    done.err = err;
    MIn::Done(done)
}

// ---------------------------------------------------------------------------
// shell
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Pending,
    /// slots reserved, leader/ctl standing up on an executor
    Starting,
    Running,
    Stopping,
    Finished,
}

/// Virtual step cadence of a `sim_slots` job: steps advance with wall
/// time at the simulated backend's per-batch compute rate, no processes.
struct SimSlot {
    started: Instant,
    compute_ms: u64,
}

impl SimSlot {
    fn step_now(&self) -> u64 {
        self.started.elapsed().as_millis() as u64 / self.compute_ms
    }
}

struct LiveJob {
    spec: SubmitSpec,
    model: Dnn,
    submit_s: f64,
    phase: Phase,
    endpoint: Option<LeaderEndpoint>,
    ctl: Option<JobServer<LeaderHandle>>,
    handle: Option<LeaderHandle>,
    ctl_addr: String,
    children: Vec<Child>,
    /// virtual step cadence (sim_slots jobs only)
    sim: Option<SimSlot>,
    /// GPUs held per machine index
    held: Vec<u32>,
    /// an operation is in flight on an executor (§3.1 guard surfaced to
    /// the policy as `adjustable = false`)
    busy: bool,
    /// a status poll is in flight on the poller pool
    in_poll: bool,
    /// last `status` round-trip succeeded
    status_ok: bool,
    last_step: u64,
    peak_p: u32,
    grow_ops: u32,
    shrink_ops: u32,
    attained_gpu_s: f64,
}

impl LiveJob {
    fn held_p(&self) -> u32 {
        self.held.iter().sum()
    }
}

/// Decision/tick counters, windowed tick latencies.
#[derive(Default)]
struct Stats {
    ticks: u64,
    tick_us: Vec<u64>,
    tick_cursor: usize,
    starts: u64,
    grows: u64,
    shrinks: u64,
    stops: u64,
    conservation_ok: bool,
}

impl Stats {
    fn record_tick(&mut self, dur: Duration) {
        self.ticks += 1;
        let us = dur.as_micros() as u64;
        if self.tick_us.len() < TICK_WINDOW {
            self.tick_us.push(us);
        } else {
            self.tick_us[self.tick_cursor] = us;
            self.tick_cursor = (self.tick_cursor + 1) % TICK_WINDOW;
        }
    }
}

struct Shell {
    inv: Arc<ShardedInventory>,
    hw: HwConfig,
    jobs: Vec<LiveJob>,
    sched: Box<dyn Scheduler + Send>,
    rx: Receiver<MIn>,
    tx: Sender<MIn>,
    kv: KvServer,
    /// lazily connected loopback client to the embedded KV: the per-tick
    /// lease sweep goes over the wire in batched frames (OP_BATCH), the
    /// same path a remote coordination service would take
    kv_client: Option<KvClient>,
    start: Instant,
    last_now: f64,
    last_tick: Instant,
    tick_ms: u64,
    lease_ttl_ms: u64,
    exec_ctx: ExecCtx,
    /// pipelined decision application (None = serial inline baseline)
    exec_tx: Option<Sender<ExecTask>>,
    poll_tx: Option<Sender<ExecTask>>,
    sim_slots: bool,
    stats: Stats,
    accept_stop: Arc<AtomicBool>,
    halt: Arc<AtomicBool>,
}

impl Shell {
    fn run(mut self) {
        self.stats.conservation_ok = true;
        let poll = Duration::from_millis(self.tick_ms.min(100));
        let mut quit = false;
        while !quit && !self.halt.load(Ordering::Relaxed) {
            match self.rx.recv_timeout(poll) {
                Ok(MIn::Ctl(req, reply)) => {
                    let (resp, q) = self.handle_ctl(req);
                    let _ = reply.send(resp);
                    quit = q;
                }
                Ok(m) => self.on_min(m),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
            if !quit && self.last_tick.elapsed() >= Duration::from_millis(self.tick_ms) {
                self.last_tick = Instant::now();
                self.tick();
            }
        }
        self.teardown();
        // dropping the task senders shuts the pools down; in-flight tasks
        // finish against a closed mailbox and their threads exit
        self.exec_tx = None;
        self.poll_tx = None;
        self.accept_stop.store(true, Ordering::Relaxed);
    }

    fn now_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn on_min(&mut self, m: MIn) {
        match m {
            MIn::Ctl(..) => unreachable!("ctl handled in run loop"),
            MIn::Done(done) => self.finish_op(done),
            MIn::PollDone { job, step } => {
                self.jobs[job].in_poll = false;
                match step {
                    Some(s) => self.note_step(job, s),
                    None => self.jobs[job].status_ok = false,
                }
            }
        }
    }

    /// Fold a status-sweep result into the job table; a job past its step
    /// target begins its graceful stop.
    fn note_step(&mut self, ix: usize, step: u64) {
        {
            let j = &mut self.jobs[ix];
            if step < j.last_step {
                eprintln!(
                    "[master] WARNING job {:?} step went backwards: {} -> {}",
                    j.spec.name, j.last_step, step
                );
            }
            j.last_step = j.last_step.max(step);
            j.status_ok = true;
        }
        if self.jobs[ix].last_step >= self.jobs[ix].spec.steps
            && matches!(self.jobs[ix].phase, Phase::Running)
            && !self.jobs[ix].busy
        {
            self.begin_stop(ix);
        }
    }

    /// Queue a task on the executor pool, or — serial baseline — run it
    /// inline and commit its outcome immediately.
    fn dispatch(&mut self, task: ExecTask) {
        match &self.exec_tx {
            Some(tx) => {
                let _ = tx.send(task);
            }
            None => {
                let m = run_task(task, &self.exec_ctx);
                self.on_min(m);
            }
        }
    }

    // -- control requests ---------------------------------------------------

    fn handle_ctl(&mut self, req: MasterRequest) -> (MasterResponse, bool) {
        match req {
            MasterRequest::Submit(spec) => {
                if spec.name.is_empty() {
                    return (MasterResponse::Err("job name must not be empty".into()), false);
                }
                if self.jobs.iter().any(|j| j.spec.name == spec.name) {
                    return (
                        MasterResponse::Err(format!("job {:?} already exists", spec.name)),
                        false,
                    );
                }
                let total = self.inv.total_gpus();
                if spec.gpus == 0 || spec.gpus > total {
                    return (
                        MasterResponse::Err(format!(
                            "requested {} GPUs, cluster has {total}",
                            spec.gpus
                        )),
                        false,
                    );
                }
                let model = Dnn::by_name(&spec.model).unwrap_or(Dnn::ResNet50);
                let n_machines = self.inv.n_machines();
                let submit_s = self.now_s();
                eprintln!("[master] submitted job {:?} ({} GPUs)", spec.name, spec.gpus);
                self.jobs.push(LiveJob {
                    spec,
                    model,
                    submit_s,
                    phase: Phase::Pending,
                    endpoint: None,
                    ctl: None,
                    handle: None,
                    ctl_addr: String::new(),
                    children: Vec::new(),
                    sim: None,
                    held: vec![0; n_machines],
                    busy: false,
                    in_poll: false,
                    status_ok: false,
                    last_step: 0,
                    peak_p: 0,
                    grow_ops: 0,
                    shrink_ops: 0,
                    attained_gpu_s: 0.0,
                });
                (MasterResponse::Submitted { job: self.jobs.len() as u64 - 1 }, false)
            }
            MasterRequest::Jobs => (MasterResponse::Jobs(self.job_infos()), false),
            MasterRequest::JobsPage { from, limit } => {
                let total = self.jobs.len() as u64;
                let from = from.min(total);
                let limit = limit.clamp(1, 256);
                let to = (from + limit).min(total);
                let jobs = (from..to).map(|i| self.job_info(i as usize)).collect();
                (MasterResponse::JobsPage { jobs, next: to, total }, false)
            }
            MasterRequest::Stats => (MasterResponse::Stats(self.stats_snapshot()), false),
            MasterRequest::Shutdown => (MasterResponse::Ok, true),
        }
    }

    fn job_info(&self, ix: usize) -> JobInfo {
        let j = &self.jobs[ix];
        JobInfo {
            name: j.spec.name.clone(),
            phase: match j.phase {
                Phase::Pending => "pending",
                Phase::Starting => "starting",
                Phase::Running => "running",
                Phase::Stopping => "stopping",
                Phase::Finished => "finished",
            }
            .to_string(),
            requested_p: j.spec.gpus,
            parallelism: j.held_p(),
            step: j.last_step,
            peak_p: j.peak_p,
            grow_ops: j.grow_ops,
            shrink_ops: j.shrink_ops,
            ctl_addr: j.ctl_addr.clone(),
            machines: j
                .held
                .iter()
                .enumerate()
                .flat_map(|(m, &g)| {
                    std::iter::repeat(self.inv.machine_name(m).to_string()).take(g as usize)
                })
                .collect(),
        }
    }

    fn job_infos(&self) -> Vec<JobInfo> {
        (0..self.jobs.len()).map(|i| self.job_info(i)).collect()
    }

    fn stats_snapshot(&self) -> MasterStats {
        let mut xs = self.stats.tick_us.clone();
        xs.sort_unstable();
        let pct = |q: f64| -> u64 {
            if xs.is_empty() {
                0
            } else {
                xs[((xs.len() - 1) as f64 * q).round() as usize]
            }
        };
        MasterStats {
            ticks: self.stats.ticks,
            tick_p50_us: pct(0.50),
            tick_p99_us: pct(0.99),
            tick_max_us: xs.last().copied().unwrap_or(0),
            decisions: self.stats.starts + self.stats.grows + self.stats.shrinks,
            starts: self.stats.starts,
            grows: self.stats.grows,
            shrinks: self.stats.shrinks,
            stops: self.stats.stops,
            jobs_total: self.jobs.len() as u64,
            jobs_running: self
                .jobs
                .iter()
                .filter(|j| matches!(j.phase, Phase::Starting | Phase::Running))
                .count() as u64,
            conservation_ok: self.stats.conservation_ok,
            shards: self
                .inv
                .shard_rows()
                .into_iter()
                .map(|r| ShardStat {
                    shard: r.shard as u32,
                    machines: r.machines as u32,
                    capacity: r.capacity,
                    free: r.free,
                    held: r.held,
                })
                .collect(),
        }
    }

    // -- the tick: poll jobs, refresh leases, run the policy ----------------

    fn tick(&mut self) {
        let t0 = Instant::now();
        let now = self.now_s();
        let dt = (now - self.last_now).max(0.0);
        self.last_now = now;
        for ix in 0..self.jobs.len() {
            let held = self.jobs[ix].held_p();
            if held > 0 {
                self.jobs[ix].attained_gpu_s += held as f64 * dt;
            }
            if !matches!(self.jobs[ix].phase, Phase::Running) || self.jobs[ix].busy {
                continue;
            }
            if self.jobs[ix].sim.is_some() {
                // virtual cadence: no round-trip, the "status" is a clock
                let step = self.jobs[ix].sim.as_ref().map(|s| s.step_now()).unwrap_or(0);
                self.note_step(ix, step);
                continue;
            }
            // reap worker processes that exited gracefully (scale-in)
            self.jobs[ix].children.retain_mut(|c| !matches!(c.try_wait(), Ok(Some(_))));
            let Some(handle) = self.jobs[ix].handle.clone() else { continue };
            match self.poll_tx.clone() {
                Some(ptx) => {
                    // pipelined sweep: at most one in-flight poll per job,
                    // each bounded by the 5 s deadline on a poller thread —
                    // hundreds of leaders never serialise the tick
                    if !self.jobs[ix].in_poll {
                        self.jobs[ix].in_poll = true;
                        let _ = ptx.send(ExecTask::Poll { job: ix, handle });
                    }
                }
                None => {
                    // serial baseline: block the tick on each leader in turn
                    match handle.call_with_timeout(Request::Status, Duration::from_secs(5)) {
                        Response::Status(st) => self.note_step(ix, st.step),
                        _ => self.jobs[ix].status_ok = false,
                    }
                }
            }
        }
        self.refresh_leases();
        // the policy tick: the SAME Scheduler objects the simulator runs,
        // planning over an owned snapshot (assembled from lock-free shard
        // mirrors — no global inventory lock is ever held here)
        let mut sched: Box<dyn Scheduler + Send> =
            std::mem::replace(&mut self.sched, Box::new(NoopScheduler));
        {
            let mut ctl = SnapshotCtl::new(&mut *self);
            sched.replan(&mut ctl);
        }
        self.sched = sched;
        self.assert_inventory();
        self.stats.record_tick(t0.elapsed());
    }

    /// GPU-slot conservation (chaos-harness invariant): every shard must
    /// satisfy `free + held == capacity` per machine, and the inventory's
    /// held counts must equal what the job table thinks it holds — a
    /// violation means a Start/Grow/Shrink/Stop path leaked or
    /// double-counted a slot. Loud failure beats silently shrinking the
    /// cluster: the master is the root of truth for the inventory.
    fn assert_inventory(&mut self) {
        let check = self.inv.check_conservation().and_then(|()| {
            let inv_held = self.inv.held_by_machine();
            for (m, &h) in inv_held.iter().enumerate() {
                let job_held: u32 = self.jobs.iter().map(|j| j.held[m]).sum();
                if job_held != h {
                    return Err(format!(
                        "machine {}: inventory holds {h}, jobs hold {job_held} (per-job: {:?})",
                        self.inv.machine_name(m),
                        self.jobs
                            .iter()
                            .filter(|j| j.held[m] > 0)
                            .map(|j| (j.spec.name.clone(), j.held[m]))
                            .collect::<Vec<_>>(),
                    ));
                }
            }
            Ok(())
        });
        self.stats.conservation_ok = check.is_ok();
        if let Err(e) = check {
            panic!("inventory conservation violated: {e}");
        }
    }

    fn lease_key(name: &str) -> String {
        format!("edl/jobs/{name}/ctl")
    }

    fn register_lease(&self, ix: usize) {
        let j = &self.jobs[ix];
        if j.ctl_addr.is_empty() {
            return;
        }
        self.kv.core().put(
            crate::util::now_ms() as u64,
            &Self::lease_key(&j.spec.name),
            j.ctl_addr.as_bytes(),
            Some(self.lease_ttl_ms),
        );
    }

    /// Per-tick lease sweep, batched: every running job's ctl lease goes
    /// to the KV in chunked framed round-trips (OP_BATCH over the
    /// loopback client — the exact path a remote etcd stand-in would
    /// see; ≤512 leases per frame keeps frames bounded at hundreds of
    /// jobs). Any connection trouble falls back to in-process puts
    /// against the embedded core, so a flaky loopback can never cost a
    /// lease.
    fn refresh_leases(&mut self) {
        let items: Vec<(String, Vec<u8>, u64)> = self
            .jobs
            .iter()
            .filter(|j| {
                matches!(j.phase, Phase::Running | Phase::Stopping) && !j.ctl_addr.is_empty()
            })
            .map(|j| {
                (Self::lease_key(&j.spec.name), j.ctl_addr.clone().into_bytes(), self.lease_ttl_ms)
            })
            .collect();
        if items.is_empty() {
            return;
        }
        if self.kv_client.is_none() {
            self.kv_client = KvClient::connect(&self.kv.addr).ok();
        }
        let mut sent = false;
        if let Some(kv) = self.kv_client.as_mut() {
            sent = items.chunks(LEASE_CHUNK).all(|c| kv.put_many(c).is_ok());
            if !sent {
                self.kv_client = None; // reconnect next tick
            }
        }
        if sent {
            return;
        }
        for (key, value, ttl) in &items {
            self.kv.core().put(crate::util::now_ms() as u64, key, value, Some(*ttl));
        }
    }

    // -- decision acceptance (the eager half of the pipeline) ---------------

    /// `Start`: reserve slots NOW (the policy's next view read sees them
    /// held), queue the slow half (leader + founders) on an executor.
    fn accept_start(&mut self, ix: usize, p: u32) -> bool {
        if ix >= self.jobs.len() || !matches!(self.jobs[ix].phase, Phase::Pending) {
            return false;
        }
        let Some(slots) = self.inv.allocate(p) else { return false };
        let labels: Vec<String> = slots
            .iter()
            .flat_map(|&(m, g)| {
                std::iter::repeat(self.inv.machine_name(m).to_string()).take(g as usize)
            })
            .collect();
        {
            let j = &mut self.jobs[ix];
            for &(m, g) in &slots {
                j.held[m] += g;
            }
            j.phase = Phase::Starting;
            j.busy = true;
            j.status_ok = false;
        }
        self.stats.starts += 1;
        let spec = self.jobs[ix].spec.clone();
        let sim = self.sim_slots;
        self.dispatch(ExecTask::Start { job: ix, spec, slots, labels, sim });
        true
    }

    /// `Grow`: reserve the delta NOW, queue joiner spawn + `scale_out`.
    fn accept_grow(&mut self, ix: usize, to: u32) -> bool {
        if ix >= self.jobs.len() {
            return false;
        }
        let cur = self.jobs[ix].held_p();
        if !matches!(self.jobs[ix].phase, Phase::Running) || self.jobs[ix].busy || to <= cur {
            return false;
        }
        let live = if self.jobs[ix].sim.is_some() {
            None
        } else {
            let Some(handle) = self.jobs[ix].handle.clone() else { return false };
            let Some(leader_addr) = self.jobs[ix].endpoint.as_ref().map(|e| e.addr.clone()) else {
                return false;
            };
            Some((handle, leader_addr))
        };
        let Some(reserved) = self.inv.allocate(to - cur) else { return false };
        let labels: Vec<String> = reserved
            .iter()
            .flat_map(|&(m, g)| {
                std::iter::repeat(self.inv.machine_name(m).to_string()).take(g as usize)
            })
            .collect();
        for &(m, g) in &reserved {
            self.jobs[ix].held[m] += g;
        }
        self.jobs[ix].busy = true;
        self.stats.grows += 1;
        let spec = self.jobs[ix].spec.clone();
        self.dispatch(ExecTask::Grow { job: ix, reserved, labels, live, spec });
        true
    }

    /// `Shrink`: mark busy, queue the graceful scale-in; slots come back
    /// when the executor reports which workers actually left.
    fn accept_shrink(&mut self, ix: usize, to: u32) -> bool {
        if ix >= self.jobs.len() {
            return false;
        }
        let cur = self.jobs[ix].held_p();
        if !matches!(self.jobs[ix].phase, Phase::Running)
            || self.jobs[ix].busy
            || to == 0
            || to >= cur
        {
            return false;
        }
        let live = if self.jobs[ix].sim.is_some() {
            None
        } else {
            let Some(handle) = self.jobs[ix].handle.clone() else { return false };
            Some(handle)
        };
        let n = (cur - to) as usize;
        self.jobs[ix].busy = true;
        self.stats.shrinks += 1;
        self.dispatch(ExecTask::Shrink { job: ix, n, live });
        true
    }

    /// The job reached its step target: graceful Table-1 `stop`.
    fn begin_stop(&mut self, ix: usize) {
        let live = self.jobs[ix].handle.clone();
        if self.jobs[ix].sim.is_none() && live.is_none() {
            return;
        }
        self.jobs[ix].busy = true;
        self.jobs[ix].phase = Phase::Stopping;
        self.stats.stops += 1;
        eprintln!(
            "[master] job {:?} reached step {} — stopping",
            self.jobs[ix].spec.name, self.jobs[ix].last_step
        );
        let live = if self.jobs[ix].sim.is_some() { None } else { live };
        self.dispatch(ExecTask::Stop { job: ix, live });
    }

    // -- commit/rollback of executor outcomes -------------------------------

    fn finish_op(&mut self, done: OpDone) {
        let OpDone { job, op, ok, freed, removed, undo, mut children, start, err } = done;
        self.jobs[job].busy = false;
        let name = self.jobs[job].spec.name.clone();
        if matches!(self.jobs[job].phase, Phase::Finished) {
            // teardown raced the executor: the job's slots are already
            // released; just reap whatever the op produced
            for c in &mut children {
                let _ = c.kill();
                let _ = c.wait();
            }
            if let Some(mut payload) = start {
                let _ = payload.handle.call_with_timeout(Request::Stop, Duration::from_secs(5));
                for c in &mut payload.children {
                    let _ = c.kill();
                    let _ = c.wait();
                }
                let _ = payload.ctl.shutdown();
            }
            return;
        }
        match op {
            Op::Start => {
                if ok {
                    if let Some(payload) = start {
                        let payload = *payload;
                        let j = &mut self.jobs[job];
                        j.endpoint = Some(payload.endpoint);
                        j.ctl = Some(payload.ctl);
                        j.handle = Some(payload.handle);
                        j.ctl_addr = payload.ctl_addr;
                        j.children = payload.children;
                    } else {
                        // sim slot: a virtual cadence stands in for the job
                        let j = &mut self.jobs[job];
                        j.ctl_addr = format!("sim://{name}");
                        j.sim = Some(SimSlot {
                            started: Instant::now(),
                            compute_ms: j.spec.compute_ms.max(1),
                        });
                    }
                    let held = self.jobs[job].held_p();
                    self.jobs[job].phase = Phase::Running;
                    self.jobs[job].peak_p = self.jobs[job].peak_p.max(held);
                    self.register_lease(job);
                } else {
                    // roll the reservation back; the job goes back in the
                    // queue and the policy will retry
                    for &(m, g) in &undo {
                        self.jobs[job].held[m] = self.jobs[job].held[m].saturating_sub(g);
                    }
                    self.inv.release(&undo);
                    self.jobs[job].phase = Phase::Pending;
                    eprintln!("[master] job {name:?} start failed: {err}");
                }
            }
            Op::Grow => {
                if ok {
                    // give back the slots whose joiner never spawned,
                    // adopt the ones that did
                    for &(m, g) in &undo {
                        self.jobs[job].held[m] = self.jobs[job].held[m].saturating_sub(g);
                    }
                    self.inv.release(&undo);
                    self.jobs[job].children.append(&mut children);
                    let held = self.jobs[job].held_p();
                    self.jobs[job].grow_ops += 1;
                    self.jobs[job].peak_p = self.jobs[job].peak_p.max(held);
                    eprintln!("[master] job {name:?} grew to {held} GPUs (stop-free)");
                } else {
                    for &(m, g) in &undo {
                        self.jobs[job].held[m] = self.jobs[job].held[m].saturating_sub(g);
                    }
                    self.inv.release(&undo);
                    // the executor reaped its own joiners; `children` is
                    // only non-empty on the ok path
                    for c in &mut children {
                        let _ = c.kill();
                        let _ = c.wait();
                    }
                    eprintln!("[master] job {name:?} grow failed: {err}");
                }
            }
            Op::Shrink => {
                if ok {
                    let mut back = vec![0u32; self.inv.n_machines()];
                    let mut returned = 0usize;
                    for label in &freed {
                        if let Some(m) = self.inv.machine_ix(label) {
                            if self.jobs[job].held[m] > 0 {
                                self.jobs[job].held[m] -= 1;
                                back[m] += 1;
                                returned += 1;
                            }
                        }
                    }
                    // the scale-in committed `removed` workers: if some
                    // labels were missing/unresolvable, reconcile against
                    // the count so the inventory never leaks slots
                    while returned < removed {
                        let Some(m) =
                            (0..self.inv.n_machines()).find(|&m| self.jobs[job].held[m] > 0)
                        else {
                            break;
                        };
                        self.jobs[job].held[m] -= 1;
                        back[m] += 1;
                        returned += 1;
                    }
                    let back: Vec<(usize, u32)> = back
                        .iter()
                        .enumerate()
                        .filter(|&(_, &g)| g > 0)
                        .map(|(m, &g)| (m, g))
                        .collect();
                    self.inv.release(&back);
                    self.jobs[job].shrink_ops += 1;
                    eprintln!(
                        "[master] job {name:?} shrank to {} GPUs (graceful)",
                        self.jobs[job].held_p()
                    );
                } else {
                    eprintln!("[master] job {name:?} shrink failed: {err}");
                }
            }
            Op::Stop => {
                if !ok {
                    eprintln!("[master] job {name:?} stop reported: {err}");
                }
                self.complete_job(job);
            }
        }
    }

    /// Tear one job down: return its slots, reap its processes, join the
    /// per-job leader + ctl server, drop the KV lease.
    fn complete_job(&mut self, ix: usize) {
        let held: Vec<(usize, u32)> = self.jobs[ix]
            .held
            .iter()
            .enumerate()
            .filter(|&(_, &g)| g > 0)
            .map(|(m, &g)| (m, g))
            .collect();
        for &(m, g) in &held {
            self.jobs[ix].held[m] -= g;
        }
        self.inv.release(&held);
        let mut children = std::mem::take(&mut self.jobs[ix].children);
        for c in &mut children {
            let _ = c.kill();
            let _ = c.wait();
        }
        self.jobs[ix].handle = None;
        self.jobs[ix].sim = None;
        if let Some(server) = self.jobs[ix].ctl.take() {
            let _ = server.shutdown();
        }
        if let Some(endpoint) = self.jobs[ix].endpoint.take() {
            let _ = endpoint.join();
        }
        self.kv.core().delete(&Self::lease_key(&self.jobs[ix].spec.name));
        self.jobs[ix].phase = Phase::Finished;
        eprintln!(
            "[master] job {:?} finished at step {}",
            self.jobs[ix].spec.name, self.jobs[ix].last_step
        );
    }

    fn teardown(&mut self) {
        for ix in 0..self.jobs.len() {
            if matches!(self.jobs[ix].phase, Phase::Starting | Phase::Running | Phase::Stopping) {
                if let Some(handle) = self.jobs[ix].handle.clone() {
                    let _ = handle.call_with_timeout(Request::Stop, Duration::from_secs(30));
                }
                self.complete_job(ix);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// the master as a scheduling engine
// ---------------------------------------------------------------------------

impl ClusterView for Shell {
    fn now_s(&self) -> f64 {
        Shell::now_s(self)
    }
    fn n_machines(&self) -> usize {
        self.inv.n_machines()
    }
    fn gpus_per_machine(&self) -> u32 {
        self.hw.gpus_per_machine
    }
    fn total_gpus(&self) -> u32 {
        self.inv.total_gpus()
    }
    fn free_gpus(&self) -> u32 {
        self.inv.free_gpus()
    }
    fn max_p_norm(&self) -> u32 {
        64
    }
    fn n_jobs(&self) -> usize {
        self.jobs.len()
    }
    fn job_view(&self, job: usize) -> JobView {
        let j = &self.jobs[job];
        // a Starting job already holds its slots: the policy must see it
        // as running (so it is neither double-started nor counted free)
        // but never adjustable (busy until the leader stands up)
        let running = matches!(j.phase, Phase::Running | Phase::Starting);
        JobView {
            id: job as u64,
            model: j.model,
            requested_p: j.spec.gpus,
            current_p: if running { j.held_p() } else { 0 },
            global_batch: SIM_AGG_BATCH,
            submitted: true,
            pending: matches!(j.phase, Phase::Pending),
            running,
            // stopping jobs are out of the policy's hands
            finished: matches!(j.phase, Phase::Stopping | Phase::Finished),
            adjustable: matches!(j.phase, Phase::Running)
                && !j.busy
                && j.status_ok
                && j.last_step >= 1,
            elastic: j.spec.elastic,
            submit_s: j.submit_s,
            attained_gpu_s: j.attained_gpu_s,
        }
    }
    fn predicted_throughput(&self, job: usize, p: u32) -> f64 {
        gpu_sim::throughput(self.jobs[job].model, p, SIM_AGG_BATCH, &self.hw)
    }
    fn predicted_efficiency(&self, job: usize, p: u32, max_p: u32) -> f64 {
        gpu_sim::efficiency(self.jobs[job].model, p, SIM_AGG_BATCH, max_p, &self.hw)
    }
}

impl ClusterCtl for Shell {
    fn submit(&mut self, d: Decision) -> bool {
        match d {
            Decision::Start { job, p } => self.accept_start(job, p),
            Decision::Grow { job, to } => self.accept_grow(job, to),
            Decision::Shrink { job, to } => self.accept_shrink(job, to),
            // the live master NEVER restarts a job; checkpoint/restart
            // scheduling is the simulator-only baseline
            Decision::Preempt { .. } | Decision::Migrate { .. } => false,
        }
    }
}
