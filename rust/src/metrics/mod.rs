//! Metrics: JCT statistics, utilization/efficiency time series, and the
//! GPU-resource-loss accounting used by Fig 8.

use crate::util::stats;

/// Job-completion-time statistics (Table 4 format).
#[derive(Debug, Clone, Default)]
pub struct JctStats {
    pub mean: f64,
    pub median: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
    pub count: usize,
}

impl JctStats {
    pub fn from(jcts: &[f64]) -> JctStats {
        if jcts.is_empty() {
            return JctStats::default();
        }
        JctStats {
            mean: stats::mean(jcts),
            median: stats::median(jcts),
            p95: stats::percentile(jcts, 95.0),
            p99: stats::percentile(jcts, 99.0),
            max: stats::max(jcts),
            count: jcts.len(),
        }
    }

    /// Percentage reduction of this vs a baseline (positive = improvement).
    pub fn reduction_vs(&self, baseline: &JctStats) -> f64 {
        if baseline.mean == 0.0 {
            return 0.0;
        }
        (1.0 - self.mean / baseline.mean) * 100.0
    }
}

/// A sampled time series (t, value) with helpers for the Fig 11/12 plots.
#[derive(Debug, Clone, Default)]
pub struct TimeSeries {
    pub points: Vec<(f64, f64)>,
}

impl TimeSeries {
    pub fn push(&mut self, t: f64, v: f64) {
        debug_assert!(self.points.last().map(|&(lt, _)| t >= lt).unwrap_or(true));
        self.points.push((t, v));
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Resample onto a uniform grid of `n` buckets over [t0, t1] using the
    /// step-function (last value carried forward) interpretation.
    pub fn resample(&self, t0: f64, t1: f64, n: usize) -> Vec<(f64, f64)> {
        assert!(n > 0 && t1 > t0);
        let mut out = Vec::with_capacity(n);
        let mut idx = 0usize;
        let mut last = self.points.first().map(|&(_, v)| v).unwrap_or(0.0);
        for i in 0..n {
            let t = t0 + (t1 - t0) * (i as f64 + 0.5) / n as f64;
            while idx < self.points.len() && self.points[idx].0 <= t {
                last = self.points[idx].1;
                idx += 1;
            }
            out.push((t, last));
        }
        out
    }

    /// Time-weighted mean over the observed span.
    pub fn time_weighted_mean(&self) -> f64 {
        if self.points.len() < 2 {
            return self.points.first().map(|&(_, v)| v).unwrap_or(0.0);
        }
        let mut tw = stats::TimeWeighted::default();
        for &(t, v) in &self.points {
            tw.observe(t, v);
        }
        tw.finish(self.points.last().unwrap().0)
    }
}

/// GPU resource loss accounting for a scaling operation (Fig 8):
/// `GPU × time` not spent training during the operation.
///
/// * stop-resume: ALL p_new GPUs idle for the full restart duration T;
/// * EDL: only the joining GPUs idle during context prep (T_e2e), and the
///   existing GPUs idle only during the brief stop (model broadcast, T_s).
#[derive(Debug, Clone, Copy)]
pub struct ResourceLoss {
    pub gpu_seconds: f64,
}

pub fn stop_resume_loss(p_old: u32, p_new: u32, restart_s: f64) -> ResourceLoss {
    // old workers stop, then the whole job restarts: everyone idles for T
    ResourceLoss { gpu_seconds: (p_old.max(p_new)) as f64 * restart_s }
}

pub fn edl_scale_out_loss(p_old: u32, added: u32, e2e_s: f64, stop_s: f64) -> ResourceLoss {
    ResourceLoss { gpu_seconds: added as f64 * e2e_s + p_old as f64 * stop_s }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jct_stats_basic() {
        let s = JctStats::from(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.count, 5);
        assert_eq!(s.median, 3.0);
        assert!((s.mean - 22.0).abs() < 1e-9);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn jct_reduction() {
        let base = JctStats::from(&[100.0; 4]);
        let ours = JctStats::from(&[10.0; 4]);
        assert!((ours.reduction_vs(&base) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn timeseries_resample_step_function() {
        let mut ts = TimeSeries::default();
        ts.push(0.0, 1.0);
        ts.push(10.0, 5.0);
        let r = ts.resample(0.0, 20.0, 4);
        assert_eq!(r.len(), 4);
        assert_eq!(r[0].1, 1.0); // t=2.5
        assert_eq!(r[1].1, 1.0); // t=7.5
        assert_eq!(r[2].1, 5.0); // t=12.5
        assert_eq!(r[3].1, 5.0); // t=17.5
    }

    #[test]
    fn timeseries_time_weighted_mean() {
        let mut ts = TimeSeries::default();
        ts.push(0.0, 0.0);
        ts.push(5.0, 10.0);
        ts.push(10.0, 10.0);
        assert!((ts.time_weighted_mean() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn fig8_edl_loss_an_order_below_stop_resume() {
        // ResNet50-ish numbers: SR restart 44 s, EDL e2e 21 s, stop 0.67 s
        let sr = stop_resume_loss(4, 5, 44.0);
        let edl = edl_scale_out_loss(4, 1, 21.0, 0.67);
        assert!(sr.gpu_seconds / edl.gpu_seconds > 5.0, "{} vs {}", sr.gpu_seconds, edl.gpu_seconds);
    }
}
