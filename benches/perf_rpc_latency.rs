//! §4.4 — coordination-message latency. The paper disables Nagle's
//! algorithm and measures 56 µs per message on its testbed; this bench
//! measures framed round-trips over loopback TCP with TCP_NODELAY (via
//! the TcpNode transport) and over the in-process hub, for the small
//! (hundreds of bytes) messages EDL exchanges every mini-batch.

use edl::transport::{InProcHub, PointToPoint, TcpNode};
use edl::util::json::{write_results, Json};
use edl::util::stats;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const N: usize = 2_000;
const T: Duration = Duration::from_secs(10);

fn main() {
    let payload = vec![0xA5u8; 256]; // typical coordination message size
    let mut out = Json::obj();

    // ---- loopback TCP with TCP_NODELAY -------------------------------------
    let dir = Arc::new(Mutex::new(HashMap::new()));
    let mut a = TcpNode::start(1, dir.clone()).unwrap();
    // register the echo node BEFORE the first send (directory race)
    let mut b = TcpNode::start(2, dir.clone()).unwrap();
    let echo = std::thread::spawn(move || {
        for _ in 0..N + 100 {
            match b.recv_any(T) {
                Ok(m) => {
                    let _ = b.send(m.from, m.tag + 1, m.payload);
                }
                Err(_) => break,
            }
        }
    });
    // warmup (connection establishment)
    for i in 0..100u32 {
        a.send(2, i, payload.clone()).unwrap();
        a.recv_from(2, i + 1, T).unwrap();
    }
    let mut lat_tcp = Vec::with_capacity(N);
    for i in 0..N as u32 {
        let t0 = Instant::now();
        a.send(2, 1000 + i, payload.clone()).unwrap();
        a.recv_from(2, 1001 + i, T).unwrap();
        lat_tcp.push(t0.elapsed().as_secs_f64() * 1e6 / 2.0); // one-way
    }
    echo.join().unwrap();
    report("TCP_NODELAY loopback", &lat_tcp, &mut out, "tcp");
    println!("  (paper: 56 µs average one-way on its testbed)");

    // ---- in-process hub ------------------------------------------------------
    let hub = InProcHub::new();
    let mut x = hub.join(1);
    let mut y = hub.join(2);
    let h = std::thread::spawn(move || {
        for _ in 0..N {
            match y.recv_any(T) {
                Ok(m) => {
                    let _ = y.send(m.from, m.tag + 1, m.payload);
                }
                Err(_) => break,
            }
        }
    });
    let mut lat_hub = Vec::with_capacity(N);
    for i in 0..N as u32 {
        let t0 = Instant::now();
        x.send(2, i, payload.clone()).unwrap();
        x.recv_from(2, i + 1, T).unwrap();
        lat_hub.push(t0.elapsed().as_secs_f64() * 1e6 / 2.0);
    }
    h.join().unwrap();
    report("in-process hub", &lat_hub, &mut out, "inproc");

    assert!(stats::median(&lat_tcp) < 2_000.0, "TCP latency out of range");
    let path = write_results("perf_rpc_latency", &out).unwrap();
    println!("\nresults -> {}", path.display());
}

fn report(name: &str, lat: &[f64], out: &mut Json, key: &str) {
    let p50 = stats::median(lat);
    let p99 = stats::percentile(lat, 99.0);
    let mean = stats::mean(lat);
    println!("{name}: mean={mean:.1}µs p50={p50:.1}µs p99={p99:.1}µs (n={})", lat.len());
    let mut r = Json::obj();
    r.set("mean_us", mean).set("p50_us", p50).set("p99_us", p99);
    out.set(key, r);
}
